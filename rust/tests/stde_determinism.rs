//! Determinism harness for STDE mode: stochastic estimation must stay
//! **bitwise reproducible** — the counter-based stream is a pure
//! function of `(seed, step, shard, index)`, so operator estimates and
//! whole training trajectories are identical for 1/2/4/8 worker
//! threads, and the stream itself is pinned by committed golden draws
//! (changing the mixing chain is a breaking change to every seeded
//! STDE run).

use ntangent::nn::{params, Mlp};
use ntangent::ntp::stde::sample_terms;
use ntangent::ntp::{CounterRng, ParallelPolicy, StdeConfig, StdeEngine};
use ntangent::pde::PdeProblem;
use ntangent::pinn::{
    train_pde_with_estimator, DerivEngine, EstimatorMode, MultiPinnSpec, TrainConfig,
};
use ntangent::util::prng::Prng;

// ------------------------------------------------------- golden stream

/// The committed golden draws: raw 64-bit outputs of the splitmix64
/// avalanche chain at hand-picked counter coordinates, cross-checked
/// against an independent implementation of the finalizer. Any change
/// to the chain shows up here before it silently reshuffles every
/// seeded run.
#[test]
fn counter_rng_stream_matches_committed_golden_draws() {
    let golden: &[((u64, u64, u64, u64), u64)] = &[
        ((0, 0, 0, 0), 0x552D_806A_62B9_7855),
        ((0, 0, 0, 1), 0x73A3_EE95_AACE_0D70),
        ((0, 1, 0, 0), 0x1D6E_5EEB_F56E_EE60),
        ((0, 0, 1, 0), 0x6AF8_A94F_C9C4_25F5),
        ((1, 0, 0, 0), 0x98F0_EF56_1B7B_1390),
        ((42, 7, 3, 9), 0xFB73_9183_2180_F4E4),
        ((0xDEAD_BEEF, 1000, 12, 34), 0x0ABF_74EB_D81A_DFF0),
    ];
    for &((seed, step, shard, index), want) in golden {
        let rng = CounterRng::new(seed);
        assert_eq!(
            rng.draw(step, shard, index),
            want,
            "draw({seed}, {step}, {shard}, {index})"
        );
        // uniform() is a fixed projection of the same draw.
        let u = rng.uniform(step, shard, index);
        let expect = (want >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        assert_eq!(u.to_bits(), expect.to_bits());
    }

    // Zone-rejected integer draws at the same coordinates.
    let rng = CounterRng::new(5);
    let got = [
        rng.below(0, 0, 0, 7),
        rng.below(0, 0, 1, 7),
        rng.below(1, 0, 0, 7),
        rng.below(1, 2, 3, 7),
    ];
    assert_eq!(got, [3, 3, 0, 2]);

    // Term sampling over a 10-term operator: the draws poisson10d
    // training at seed 11, K=2 actually consumes at steps 1..=3.
    let cfg = StdeConfig { seed: 11, samples: 2, antithetic: false };
    assert_eq!(sample_terms(&cfg, 10, 1, 0), vec![7, 9]);
    assert_eq!(sample_terms(&cfg, 10, 2, 0), vec![4, 6]);
    assert_eq!(sample_terms(&cfg, 10, 3, 0), vec![1, 2]);
    // Different shards draw different coordinates of the same stream.
    assert_ne!(sample_terms(&cfg, 10, 1, 0), sample_terms(&cfg, 10, 1, 1));
}

// -------------------------------------------------- estimate invariance

/// One STDE estimate is bitwise identical for every worker policy (the
/// policy only schedules the direction-stacked fused batch) and
/// bitwise reproducible across engine rebuilds.
#[test]
fn stde_estimates_are_bitwise_identical_across_thread_counts() {
    let problem = PdeProblem::Poisson10d;
    let mut rng = Prng::seeded(2);
    let mlp = Mlp::uniform(10, 8, 2, 1, &mut rng);
    let x = problem.sample_interior(12, &mut rng);
    let cfg = StdeConfig { seed: 77, samples: 4, antithetic: false };

    let want: Vec<Vec<u64>> = {
        let est = StdeEngine::new(problem.operator(), cfg);
        (0..4u64)
            .map(|s| est.estimate(&mlp, &x, s).values.data().iter().map(|v| v.to_bits()).collect())
            .collect()
    };
    // Consecutive steps resample — the stream moves.
    assert_ne!(want[0], want[1]);

    for threads in [1usize, 2, 4, 8] {
        let est = StdeEngine::with_policy(problem.operator(), cfg, ParallelPolicy::Fixed(threads));
        for (s, want_step) in want.iter().enumerate() {
            let got: Vec<u64> = est
                .estimate(&mlp, &x, s as u64)
                .values
                .data()
                .iter()
                .map(|v| v.to_bits())
                .collect();
            assert_eq!(want_step, &got, "t={threads} diverged at step {s}");
        }
    }
}

// ------------------------------------------------- trajectory invariance

fn train(policy: ParallelPolicy, chunk: usize, stde_seed: u64) -> ntangent::pinn::PdeTrainResult {
    let cfg = TrainConfig {
        width: 6,
        depth: 2,
        adam_epochs: 6,
        lbfgs_epochs: 4,
        adam_lr: 2e-3,
        seed: 3,
        log_every: 2,
        policy,
        chunk,
        ..TrainConfig::default()
    };
    let mut spec = MultiPinnSpec::for_problem(PdeProblem::Poisson10d);
    spec.n_interior = 24;
    spec.n_boundary = 12;
    train_pde_with_estimator(
        spec,
        &cfg,
        DerivEngine::Ntp,
        EstimatorMode::Stde { seed: stde_seed, samples: 2, antithetic: false },
    )
}

/// A full stochastic training run (Adam + L-BFGS with its batched line
/// search, per-step operator resampling) is bitwise identical for
/// 1/2/4/8 threads, across shard layouts including ragged and
/// single-shard chunkings. Per-shard draws are keyed by the *shard
/// index*, which is layout state, not scheduling state.
#[test]
fn stde_training_trajectories_are_bitwise_identical_across_thread_counts() {
    for &chunk in &[4usize, 9, 64] {
        let want = train(ParallelPolicy::Serial, chunk, 11);
        assert!(want.final_loss.is_finite());
        for threads in [1usize, 2, 4, 8] {
            let got = train(ParallelPolicy::Fixed(threads), chunk, 11);
            assert_eq!(
                want.final_loss.to_bits(),
                got.final_loss.to_bits(),
                "t={threads} chunk={chunk}: final loss"
            );
            assert_eq!(
                params::flatten(&want.mlp),
                params::flatten(&got.mlp),
                "t={threads} chunk={chunk}: trained weights"
            );
            assert_eq!(want.logs.len(), got.logs.len());
            for (la, lb) in want.logs.iter().zip(&got.logs) {
                assert_eq!(
                    la.loss.to_bits(),
                    lb.loss.to_bits(),
                    "t={threads} chunk={chunk}: epoch {}",
                    la.epoch
                );
            }
            assert_eq!(want.n_forward, got.n_forward);
            assert_eq!(want.n_backward, got.n_backward);
        }
    }
}

/// The stochastic stream is *engaged*: a different STDE seed sees
/// different draws and lands on a different trajectory (while each seed
/// remains reproducible on its own).
#[test]
fn stde_seed_changes_the_trajectory_reproducibly() {
    let a = train(ParallelPolicy::Fixed(2), 8, 11);
    let b = train(ParallelPolicy::Fixed(2), 8, 12);
    assert_ne!(
        params::flatten(&a.mlp),
        params::flatten(&b.mlp),
        "different STDE seeds must sample different term sequences"
    );
    let a2 = train(ParallelPolicy::Fixed(4), 8, 11);
    assert_eq!(params::flatten(&a.mlp), params::flatten(&a2.mlp));
}
