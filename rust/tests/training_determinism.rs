//! Determinism harness for the data-parallel training subsystem: the
//! sharded PINN objective ([`ParallelObjective`]) plus the policy-aware
//! optimizers must produce **bitwise identical** losses, gradients and
//! whole optimization trajectories for every [`ParallelPolicy`] —
//! 2/4/8 worker threads vs serial, including collocation counts that do
//! not divide the chunk size.
//!
//! Why bitwise equality is attainable: the shard layout and the pairwise
//! reduction tree depend only on the problem (never the thread count),
//! every shard tape performs the same float ops wherever it runs, and
//! the optimizers' reductions/updates are chunk-fixed (`util::par`). The
//! policy is pure scheduling.

use ntangent::nn::{params, Mlp};
use ntangent::ntp::ParallelPolicy;
use ntangent::opt::{Adam, Lbfgs, Objective};
use ntangent::pinn::{
    train_burgers_parallel, BurgersLossSpec, DerivEngine, ParallelObjective, TrainConfig,
};
use ntangent::tensor::Tensor;
use ntangent::util::prng::Prng;

fn spec_with(n_res: usize, n_org: usize) -> BurgersLossSpec {
    let mut spec = BurgersLossSpec::for_profile(1);
    spec.n_res = n_res;
    spec.n_org = n_org;
    spec.x_max = 1.5;
    spec
}

/// Build the objective with pinned init/cloud seeds so every policy sees
/// the identical problem, plus its initial θ.
fn build(
    policy: ParallelPolicy,
    chunk: usize,
    n_res: usize,
    n_org: usize,
    engine: DerivEngine,
) -> (ParallelObjective, Tensor) {
    let mut rng_init = Prng::seeded(11);
    let mlp = Mlp::uniform(1, 8, 2, 1, &mut rng_init);
    let mut rng_cloud = Prng::seeded(23);
    let obj = ParallelObjective::build(
        spec_with(n_res, n_org),
        &mlp,
        engine,
        policy,
        chunk,
        &mut rng_cloud,
    );
    let theta = obj.theta_init(&mlp);
    (obj, theta)
}

/// Loss and gradient bitwise-equal to serial for 2/4/8 threads and Auto,
/// across shard layouts including non-divisible collocation counts,
/// single-shard (chunk > cloud) and one-point-per-shard extremes.
#[test]
fn gradients_are_bitwise_identical_across_thread_counts() {
    for &(n_res, n_org, chunk) in &[
        (50usize, 10usize, 16usize), // ragged: 50 = 3*16 + 2
        (64, 16, 16),                // exact division
        (7, 3, 4),                   // tiny cloud, ragged
        (33, 9, 8),                  // ragged both sets
        (20, 6, 64),                 // chunk > cloud: single shard
        (12, 5, 1),                  // one point per shard
    ] {
        let (mut serial, theta) =
            build(ParallelPolicy::Serial, chunk, n_res, n_org, DerivEngine::Ntp);
        let (want_loss, want_grad) = serial.value_grad(&theta);
        let want_value = serial.value(&theta);
        assert_eq!(want_value.to_bits(), want_loss.to_bits());

        let mut policies = vec![
            ParallelPolicy::Fixed(2),
            ParallelPolicy::Fixed(4),
            ParallelPolicy::Fixed(8),
            ParallelPolicy::Auto,
        ];
        // More workers than shards must clamp, not panic.
        policies.push(ParallelPolicy::Fixed(64));
        for policy in policies {
            let (mut par, theta2) = build(policy, chunk, n_res, n_org, DerivEngine::Ntp);
            assert_eq!(theta, theta2, "init must not depend on the policy");
            let (loss, grad) = par.value_grad(&theta);
            assert_eq!(
                want_loss.to_bits(),
                loss.to_bits(),
                "{policy:?} n_res={n_res} chunk={chunk}: loss"
            );
            assert_eq!(
                want_grad, grad,
                "{policy:?} n_res={n_res} chunk={chunk}: gradient"
            );
            assert_eq!(want_value.to_bits(), par.value(&theta).to_bits());
        }
    }
}

/// The repeated-autodiff engine's shard tapes are policy-invariant too.
#[test]
fn autodiff_engine_gradients_are_bitwise_identical() {
    let (mut serial, theta) = build(ParallelPolicy::Serial, 8, 18, 6, DerivEngine::Autodiff);
    let (want_loss, want_grad) = serial.value_grad(&theta);
    let (mut par, _) = build(ParallelPolicy::Fixed(3), 8, 18, 6, DerivEngine::Autodiff);
    let (loss, grad) = par.value_grad(&theta);
    assert_eq!(want_loss.to_bits(), loss.to_bits());
    assert_eq!(want_grad, grad);
}

/// 50 Adam steps: θ (and hence the moment state that produced it) is
/// bitwise identical to serial at *every* step for 2/4/8 threads.
#[test]
fn adam_trajectory_is_bitwise_identical_over_50_steps() {
    let run = |policy: ParallelPolicy| -> Vec<Tensor> {
        let (mut obj, mut theta) = build(policy, 16, 50, 10, DerivEngine::Ntp);
        let mut adam = Adam::new(obj.dim(), 2e-3).with_policy(policy);
        let mut trace = Vec::with_capacity(50);
        for _ in 0..50 {
            adam.step(&mut obj, &mut theta);
            trace.push(theta.clone());
        }
        trace
    };
    let want = run(ParallelPolicy::Serial);
    for threads in [2usize, 4, 8] {
        let got = run(ParallelPolicy::Fixed(threads));
        for (step, (a, b)) in want.iter().zip(&got).enumerate() {
            assert_eq!(a, b, "t={threads} diverged at Adam step {step}");
        }
    }
}

/// 50 L-BFGS steps (backtracking line search, curvature history, the
/// works): θ bitwise identical to serial at every step. This exercises
/// the deterministic chunked inner products end-to-end.
#[test]
fn lbfgs_trajectory_is_bitwise_identical_over_50_steps() {
    let run = |policy: ParallelPolicy| -> (Vec<Tensor>, Vec<u64>) {
        let (mut obj, mut theta) = build(policy, 16, 50, 10, DerivEngine::Ntp);
        let mut lbfgs = Lbfgs::new(obj.dim()).with_policy(policy);
        let mut trace = Vec::with_capacity(50);
        let mut losses = Vec::with_capacity(50);
        for _ in 0..50 {
            let (loss, _) = lbfgs.step(&mut obj, &mut theta);
            trace.push(theta.clone());
            losses.push(loss.to_bits());
        }
        (trace, losses)
    };
    let (want, want_losses) = run(ParallelPolicy::Serial);
    for threads in [2usize, 4, 8] {
        let (got, got_losses) = run(ParallelPolicy::Fixed(threads));
        assert_eq!(want_losses, got_losses, "t={threads}: loss sequence");
        for (step, (a, b)) in want.iter().zip(&got).enumerate() {
            assert_eq!(a, b, "t={threads} diverged at L-BFGS step {step}");
        }
    }
}

/// End-to-end `train_burgers_parallel` (both phases, logging, counters):
/// final weights, λ and the whole logged loss sequence are bitwise equal
/// between serial and a 4-thread pool.
#[test]
fn trainer_end_to_end_is_bitwise_identical() {
    let run = |policy: ParallelPolicy| {
        let cfg = TrainConfig {
            width: 8,
            depth: 2,
            adam_epochs: 15,
            lbfgs_epochs: 10,
            adam_lr: 2e-3,
            seed: 5,
            log_every: 5,
            policy,
            chunk: 16,
            ..TrainConfig::default()
        };
        train_burgers_parallel(spec_with(48, 12), &cfg, DerivEngine::Ntp)
    };
    let a = run(ParallelPolicy::Serial);
    let b = run(ParallelPolicy::Fixed(4));
    assert_eq!(a.final_loss.to_bits(), b.final_loss.to_bits());
    assert_eq!(a.lambda.to_bits(), b.lambda.to_bits());
    assert_eq!(
        params::flatten(&a.mlp),
        params::flatten(&b.mlp),
        "trained weights diverged"
    );
    assert_eq!(a.logs.len(), b.logs.len());
    for (la, lb) in a.logs.iter().zip(&b.logs) {
        assert_eq!(la.loss.to_bits(), lb.loss.to_bits(), "epoch {}", la.epoch);
        assert_eq!(la.lambda.to_bits(), lb.lambda.to_bits());
    }
    // Same schedule ⇒ same evaluation counts.
    assert_eq!(a.n_forward, b.n_forward);
    assert_eq!(a.n_backward, b.n_backward);
}

/// The batched line-search path: `value_batch` fans the α-trials of one
/// wave through the shard pool as trials×shards tasks, but each trial's
/// per-shard losses still reduce over the same pairwise tree as a lone
/// `value` call — so batching is bitwise invisible, for every policy.
#[test]
fn value_batch_is_bitwise_identical_to_sequential_values() {
    let (mut serial, theta) = build(ParallelPolicy::Serial, 16, 50, 10, DerivEngine::Ntp);
    let trials: Vec<Tensor> = (0..5).map(|i| theta.scale(1.0 + 0.01 * i as f64)).collect();
    let want: Vec<u64> = trials.iter().map(|t| serial.value(t).to_bits()).collect();
    for policy in [
        ParallelPolicy::Serial,
        ParallelPolicy::Fixed(2),
        ParallelPolicy::Fixed(4),
        ParallelPolicy::Fixed(8),
        ParallelPolicy::Auto,
    ] {
        let (mut obj, _) = build(policy, 16, 50, 10, DerivEngine::Ntp);
        let got: Vec<u64> = obj.value_batch(&trials).iter().map(|v| v.to_bits()).collect();
        assert_eq!(want, got, "{policy:?}: batched losses");
    }
}

/// Concurrent use of one objective's shards from the outside (the shard
/// tapes are `Sync`): interleaving calls from a wrapper thread must not
/// perturb results.
#[test]
fn repeated_mixed_policy_calls_stay_identical() {
    let (mut obj, theta) = build(ParallelPolicy::Serial, 16, 50, 10, DerivEngine::Ntp);
    let (want_loss, want_grad) = obj.value_grad(&theta);
    for policy in [
        ParallelPolicy::Fixed(2),
        ParallelPolicy::Serial,
        ParallelPolicy::Fixed(8),
        ParallelPolicy::Auto,
        ParallelPolicy::Serial,
    ] {
        obj.set_policy(policy);
        let (loss, grad) = obj.value_grad(&theta);
        assert_eq!(want_loss.to_bits(), loss.to_bits(), "{policy:?}");
        assert_eq!(want_grad, grad, "{policy:?}");
    }
}
