//! Bitwise scalar-vs-SIMD identity for the runtime-dispatched kernels.
//!
//! The SIMD contract (see `docs/ARCHITECTURE.md`, "SIMD dispatch and the
//! bitwise contract") is that every vector body performs the exact same
//! IEEE-754 roundings in the exact same order as its scalar fallback, so
//! `NTANGENT_SIMD=scalar` and any vector ISA produce identical bits.
//! These tests pin a scalar engine and a vector engine in one process
//! (via `NtpEngine::with_isa`) and demand `to_bits` equality at
//! tile-straddling shapes, for all four activations, and through the
//! public GEMM / reduction / optimizer entry points.
//!
//! On hosts without a vector ISA (or under `NTANGENT_SIMD=scalar` builds
//! of CI's forced-scalar job) the vector half is skipped — `Isa::vector`
//! returns `None` — and only the dispatch-plumbing assertions run.

use ntangent::nn::Mlp;
use ntangent::ntp::{ActivationKind, NtpEngine, ParallelPolicy, SmoothActivation};
use ntangent::simd::{AdamCoeffs, Isa};
use ntangent::tensor::{linalg, Tensor};
use ntangent::util::prng::Prng;

/// `eprintln` + return when the host can only run scalar code: the CI
/// matrix covers a vector host, so skipping locally costs no coverage.
macro_rules! vector_or_skip {
    () => {
        match Isa::vector() {
            Some(v) => v,
            None => {
                eprintln!("skipping: no vector ISA on this host");
                return;
            }
        }
    };
}

/// The whole fused engine path — towers, power fills, the compiled
/// Faà di Bruno interpreter and the stacked GEMM — is bitwise
/// ISA-invariant for every activation, at batches straddling the
/// 128-element tile, including truncated orders. A parallel vector
/// engine rides along: SIMD must not perturb chunked determinism.
#[test]
fn engine_forward_is_bitwise_isa_invariant() {
    let vec_isa = vector_or_skip!();
    for kind in ActivationKind::ALL {
        let mut rng = Prng::seeded(0x51D0 + kind.index() as u64);
        let mlp = Mlp::uniform_with(1, 24, 3, 1, kind, &mut rng);
        let scalar = NtpEngine::with_isa(8, ParallelPolicy::Serial, Isa::Scalar);
        let vector = NtpEngine::with_isa(8, ParallelPolicy::Serial, vec_isa);
        let vector_par = NtpEngine::with_isa(8, ParallelPolicy::Fixed(3), vec_isa);
        assert_eq!(scalar.isa(), Isa::Scalar);
        assert_eq!(vector.isa(), vec_isa);
        for batch in [1usize, 5, 6, 32, 129] {
            let x = Tensor::rand_uniform(&[batch, 1], -1.5, 1.5, &mut rng);
            for n in [0usize, 1, 4, 8] {
                let want = scalar.forward_n(&mlp, &x, n);
                let got = vector.forward_n(&mlp, &x, n);
                let got_par = vector_par.forward_n(&mlp, &x, n);
                for (k, (a, b)) in want.iter().zip(&got).enumerate() {
                    assert_eq!(a, b, "{} B={batch} n={n} channel {k}", kind.name());
                }
                for (k, (a, b)) in want.iter().zip(&got_par).enumerate() {
                    assert_eq!(a, b, "{} B={batch} n={n} channel {k} (par)", kind.name());
                }
            }
        }
    }
}

/// The directional-jet path (stacked `[x; v]` seed GEMM + the same fused
/// kernel) is bitwise ISA-invariant for multi-input networks.
#[test]
fn directional_jets_are_bitwise_isa_invariant() {
    let vec_isa = vector_or_skip!();
    for kind in ActivationKind::ALL {
        let mut rng = Prng::seeded(0xD19 + kind.index() as u64);
        let mlp = Mlp::uniform_with(3, 16, 2, 1, kind, &mut rng);
        let scalar = NtpEngine::with_isa(6, ParallelPolicy::Serial, Isa::Scalar);
        let vector = NtpEngine::with_isa(6, ParallelPolicy::Serial, vec_isa);
        for batch in [1usize, 7, 40] {
            let x = Tensor::rand_uniform(&[batch, 3], -1.0, 1.0, &mut rng);
            let v = Tensor::rand_uniform(&[batch, 3], -1.0, 1.0, &mut rng);
            for n in [0usize, 1, 3, 6] {
                let want = scalar.forward_directional(&mlp, &x, &v, n);
                let got = vector.forward_directional(&mlp, &x, &v, n);
                for (k, (a, b)) in want.iter().zip(&got).enumerate() {
                    assert_eq!(a, b, "{} B={batch} n={n} channel {k}", kind.name());
                }
            }
        }
    }
}

/// The blocked GEMM through its ISA-pinned entry point: every shape —
/// micro-tile remainders in m and n, KC-straddling k — produces the
/// same bits under the vector micro-kernel as under the scalar one.
#[test]
fn blocked_gemm_is_bitwise_isa_invariant() {
    let vec_isa = vector_or_skip!();
    let mut rng = Prng::seeded(0x6E33);
    for (m, k, n) in [
        (1usize, 7usize, 1usize),
        (3, 9, 8),
        (5, 64, 9),
        (12, 200, 19),
        (4, 256, 8),
        (23, 300, 70),
    ] {
        let a = rng.normal_vec(m * k, 0.0, 1.0);
        let b = rng.normal_vec(n * k, 0.0, 1.0);
        // Different poison values so any cell left unwritten by either
        // path shows up as a mismatch (NAN would compare bit-equal).
        let mut c_scalar = vec![1.25f64; m * n];
        let mut c_vector = vec![-9.5f64; m * n];
        linalg::matmul_nt_block_into_with(Isa::Scalar, &a, &b, &mut c_scalar, m, k, n);
        linalg::matmul_nt_block_into_with(vec_isa, &a, &b, &mut c_vector, m, k, n);
        for (i, (x, y)) in c_scalar.iter().zip(&c_vector).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "[{m}x{k}]x[{n}x{k}] cell {i}: scalar {x} vs vector {y}"
            );
        }
    }
}

/// Reductions: the vector `dot`/`sum` reproduce the fixed 4-lane scalar
/// pattern exactly, at lengths around the unroll and tail boundaries.
#[test]
fn reductions_are_bitwise_isa_invariant() {
    let vec_isa = vector_or_skip!();
    let mut rng = Prng::seeded(0x0D07);
    for len in [0usize, 1, 3, 4, 5, 1023, 1024, 1025, 4096] {
        let a = rng.normal_vec(len, 0.0, 1.0);
        let b = rng.normal_vec(len, 0.0, 1.0);
        let want_dot = Isa::Scalar.dot(&a, &b);
        // The scalar arm is the historical `dot_unrolled` — the lane
        // convention every ISA must reproduce.
        assert_eq!(want_dot.to_bits(), linalg::dot_unrolled(&a, &b).to_bits(), "len={len}");
        assert_eq!(want_dot.to_bits(), vec_isa.dot(&a, &b).to_bits(), "dot len={len}");
        assert_eq!(
            Isa::Scalar.sum(&a).to_bits(),
            vec_isa.sum(&a).to_bits(),
            "sum len={len}"
        );
    }
}

/// Optimizer block updates (Adam moments + parameter step, SGD momentum)
/// are bitwise ISA-invariant on cloned state, across tail lengths.
#[test]
fn optimizer_blocks_are_bitwise_isa_invariant() {
    let vec_isa = vector_or_skip!();
    let co = AdamCoeffs { beta1: 0.9, beta2: 0.999, lr_t: 0.01, eps: 1e-8 };
    for len in [1usize, 3, 4, 127, 1024, 4097] {
        let mut rng = Prng::seeded(0xADA0 + len as u64);
        let g = rng.normal_vec(len, 0.0, 1.0);
        let m0 = rng.normal_vec(len, 0.0, 0.1);
        let v0: Vec<f64> = rng.normal_vec(len, 0.0, 0.1).iter().map(|x| x * x).collect();
        let th0 = rng.normal_vec(len, 0.0, 1.0);

        let (mut ms, mut vs, mut ths) = (m0.clone(), v0.clone(), th0.clone());
        let (mut mv, mut vv, mut thv) = (m0.clone(), v0.clone(), th0.clone());
        Isa::Scalar.adam_block(&mut ms, &mut vs, &mut ths, &g, co);
        vec_isa.adam_block(&mut mv, &mut vv, &mut thv, &g, co);
        for i in 0..len {
            assert_eq!(ms[i].to_bits(), mv[i].to_bits(), "adam m len={len} i={i}");
            assert_eq!(vs[i].to_bits(), vv[i].to_bits(), "adam v len={len} i={i}");
            assert_eq!(ths[i].to_bits(), thv[i].to_bits(), "adam th len={len} i={i}");
        }

        let (mut vel_s, mut th_s) = (m0.clone(), th0.clone());
        let (mut vel_v, mut th_v) = (m0.clone(), th0.clone());
        Isa::Scalar.sgd_block(&mut vel_s, &mut th_s, &g, 0.05, 0.9);
        vec_isa.sgd_block(&mut vel_v, &mut th_v, &g, 0.05, 0.9);
        for i in 0..len {
            assert_eq!(vel_s[i].to_bits(), vel_v[i].to_bits(), "sgd v len={len} i={i}");
            assert_eq!(th_s[i].to_bits(), th_v[i].to_bits(), "sgd th len={len} i={i}");
        }
    }
}

/// Activation derivative towers through the strided `tower_into` entry
/// point: every activation's tower planes are bitwise ISA-invariant at
/// partial-tile lengths (only the written cells are compared — the rest
/// of the out buffer is poisoned differently per run).
#[test]
fn activation_towers_are_bitwise_isa_invariant() {
    let vec_isa = vector_or_skip!();
    const STRIDE: usize = 128;
    for kind in ActivationKind::ALL {
        let act = kind.build_tower(8);
        let mut rng = Prng::seeded(0x70E + kind.index() as u64);
        for n in [0usize, 1, 2, 5, 8] {
            for len in [1usize, 3, 4, 11, 128] {
                let xs = rng.normal_vec(len, 0.0, 1.5);
                let mut out_s = vec![7.5f64; (n + 1) * STRIDE];
                let mut out_v = vec![-2.5f64; (n + 1) * STRIDE];
                act.tower_into(&xs, n, &mut out_s, STRIDE, Isa::Scalar);
                act.tower_into(&xs, n, &mut out_v, STRIDE, vec_isa);
                for k in 0..=n {
                    for e in 0..len {
                        let (a, b) = (out_s[k * STRIDE + e], out_v[k * STRIDE + e]);
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{} n={n} len={len} plane {k} elem {e}: {a} vs {b}",
                            kind.name()
                        );
                    }
                }
            }
        }
    }
}

/// The numeric-health probe `all_finite` (the resilience subsystem's
/// per-step scan over loss/gradient/tower tiles) is ISA-invariant: a
/// pure predicate has no roundings, but the vector bodies still have to
/// classify every lane position and the scalar tail exactly like
/// `f64::is_finite` — for NaN, +∞ and −∞ at every offset, at lengths
/// straddling the 4-lane blocks.
#[test]
fn all_finite_is_isa_invariant() {
    let vec_isa = vector_or_skip!();
    let mut rng = Prng::seeded(0xF1117E);
    for len in [1usize, 3, 4, 5, 8, 127, 1024, 1025] {
        let clean = rng.normal_vec(len, 0.0, 1e6);
        assert!(Isa::Scalar.all_finite(&clean), "scalar clean len={len}");
        assert!(vec_isa.all_finite(&clean), "vector clean len={len}");
        for poison in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            // Positions covering the first block's lanes, a mid block and
            // the tail.
            for pos in [0, 1, 2, 3, len / 2, len - 1] {
                let mut xs = clean.clone();
                xs[pos] = poison;
                assert!(!Isa::Scalar.all_finite(&xs), "scalar len={len} pos={pos}");
                assert!(!vec_isa.all_finite(&xs), "vector len={len} pos={pos}");
            }
        }
    }
    assert!(Isa::Scalar.all_finite(&[]));
    assert!(vec_isa.all_finite(&[]));
}

/// Dispatch plumbing: `resolve` honors explicit requests, falls back to
/// detection for `auto`/unknown, and the process-wide `Isa::active` is
/// exactly `resolve` applied to the `NTANGENT_SIMD` the process was
/// started with — which is what lets CI force scalar or vector runs of
/// this whole suite through the environment. Runs on every host.
#[test]
fn env_override_reaches_the_dispatcher() {
    assert_eq!(Isa::resolve(Some("scalar")), Isa::Scalar);
    assert_eq!(Isa::resolve(Some(" SCALAR ")), Isa::Scalar);
    assert_eq!(Isa::resolve(None), Isa::detect());
    assert_eq!(Isa::resolve(Some("auto")), Isa::detect());
    assert_eq!(Isa::resolve(Some("definitely-not-an-isa")), Isa::detect());
    // A vector request is honored iff the host can run it; the name
    // round-trips through resolve either way.
    if let Some(v) = Isa::vector() {
        assert_eq!(Isa::resolve(Some(v.name())), v);
    }
    #[cfg(not(target_arch = "aarch64"))]
    assert_eq!(Isa::resolve(Some("neon")), Isa::Scalar);
    #[cfg(not(target_arch = "x86_64"))]
    assert_eq!(Isa::resolve(Some("avx2")), Isa::Scalar);
    // No test in this binary mutates NTANGENT_SIMD, so the cached
    // process-wide choice must agree with re-resolving the environment.
    assert_eq!(
        Isa::active(),
        Isa::resolve(std::env::var("NTANGENT_SIMD").ok().as_deref())
    );
}
