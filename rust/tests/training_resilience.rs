//! Interruption matrix for the resilient trainer: kill-and-resume must
//! be **bitwise identical** to the uninterrupted trajectory (serial and
//! threaded, Adam and L-BFGS phases, exact and STDE estimators),
//! NaN-injection must trigger the deterministic recovery path for every
//! activation, exhausted retries must abort cleanly with a valid
//! last-good checkpoint, and the atomic checkpoint writer must survive a
//! simulated mid-write crash.
//!
//! Why bitwise resume is attainable: a checkpoint's [`ResumeState`]
//! carries everything the next optimizer step reads — θ, Adam moments,
//! L-BFGS curvature pairs *and* the carried-over gradient, the STDE draw
//! counter, and the recovery bookkeeping (retries / lr backoff / stall
//! counter). Restoring it replays the identical float ops the
//! uninterrupted run would have performed, for any thread count.

use std::path::PathBuf;

use ntangent::nn::{params, Checkpoint, ResumePhase};
use ntangent::ntp::{ActivationKind, ParallelPolicy};
use ntangent::pde::PdeProblem;
use ntangent::pinn::{
    train_burgers_parallel_resilient, train_pde_resilient, BurgersLossSpec, DerivEngine,
    EstimatorMode, FaultKind, FaultPlan, MultiPinnSpec, NumericError, ResilienceConfig,
    TrainConfig, TrainResult,
};

fn spec_with(n_res: usize, n_org: usize) -> BurgersLossSpec {
    let mut spec = BurgersLossSpec::for_profile(1);
    spec.n_res = n_res;
    spec.n_org = n_org;
    spec.x_max = 1.5;
    spec
}

fn cfg_with(policy: ParallelPolicy, activation: ActivationKind) -> TrainConfig {
    TrainConfig {
        width: 8,
        depth: 2,
        activation,
        adam_epochs: 12,
        lbfgs_epochs: 8,
        adam_lr: 2e-3,
        seed: 5,
        log_every: 50,
        policy,
        chunk: 16,
    }
}

/// A hermetic resilience config: never reads the `NTANGENT_FAULT` hook,
/// so the matrix cannot be perturbed from outside.
fn quiet_res() -> ResilienceConfig {
    ResilienceConfig {
        fault: FaultPlan::none(),
        ..ResilienceConfig::default()
    }
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(name)
}

fn assert_bitwise_equal(a: &TrainResult, b: &TrainResult, what: &str) {
    assert_eq!(
        a.final_loss.to_bits(),
        b.final_loss.to_bits(),
        "{what}: final loss"
    );
    assert_eq!(a.lambda.to_bits(), b.lambda.to_bits(), "{what}: lambda");
    assert_eq!(
        params::flatten(&a.mlp),
        params::flatten(&b.mlp),
        "{what}: trained weights"
    );
}

/// Kill-after-step-k, then resume from the on-disk checkpoint: the
/// stitched trajectory is bitwise identical to never having stopped —
/// serial and 4-thread, with the kill landing in the Adam phase
/// (mid-moment-state) and in the L-BFGS phase (mid-curvature-history,
/// with a carried-over gradient in flight).
#[test]
fn kill_and_resume_matches_the_uninterrupted_run_bitwise() {
    // (policy, global kill epoch, checkpoint cadence, tag). Global epochs
    // 0..12 are Adam, 12..20 L-BFGS.
    let matrix: [(ParallelPolicy, usize, usize, &str); 4] = [
        (ParallelPolicy::Serial, 7, 3, "serial-adam"),
        (ParallelPolicy::Fixed(4), 7, 3, "fixed4-adam"),
        (ParallelPolicy::Serial, 17, 2, "serial-lbfgs"),
        (ParallelPolicy::Fixed(4), 17, 2, "fixed4-lbfgs"),
    ];
    for (policy, kill_at, every, tag) in matrix {
        let cfg = cfg_with(policy, ActivationKind::Tanh);
        let baseline = train_burgers_parallel_resilient(
            spec_with(48, 12),
            &cfg,
            DerivEngine::Ntp,
            &quiet_res(),
            None,
        );
        assert!(!baseline.health.interrupted && baseline.health.aborted.is_none());

        let path = tmp(&format!("ntangent_resilience_kill_{tag}.json"));
        let interrupted_res = ResilienceConfig {
            checkpoint_path: Some(path.clone()),
            checkpoint_every: every,
            fault: FaultPlan::new(&[(FaultKind::Kill, kill_at)]),
            ..ResilienceConfig::default()
        };
        let interrupted = train_burgers_parallel_resilient(
            spec_with(48, 12),
            &cfg,
            DerivEngine::Ntp,
            &interrupted_res,
            None,
        );
        assert!(interrupted.health.interrupted, "{tag}: kill must interrupt");
        assert!(interrupted.health.checkpoint_error.is_none());

        let ck = Checkpoint::load(&path).expect("last-good checkpoint must load");
        let state = ck.resume.expect("mid-run checkpoint carries resume state");
        let expect_phase = if kill_at < cfg.adam_epochs {
            ResumePhase::Adam
        } else {
            ResumePhase::Lbfgs
        };
        assert_eq!(state.phase, expect_phase, "{tag}: checkpoint phase");
        assert!(
            state.epoch > 0 && state.epoch % every == 0,
            "{tag}: checkpoint must sit on the cadence, got epoch {}",
            state.epoch
        );
        if expect_phase == ResumePhase::Lbfgs {
            let lb = state.lbfgs.as_ref().expect("L-BFGS snapshot state");
            assert!(
                lb.last_grad.is_some(),
                "{tag}: the carried-over gradient must be serialized"
            );
        }

        let resume_res = ResilienceConfig {
            checkpoint_path: Some(path.clone()),
            checkpoint_every: every,
            fault: FaultPlan::none(),
            ..ResilienceConfig::default()
        };
        let resumed = train_burgers_parallel_resilient(
            spec_with(48, 12),
            &cfg,
            DerivEngine::Ntp,
            &resume_res,
            Some(&state),
        );
        assert_bitwise_equal(&baseline, &resumed, tag);
        assert_eq!(
            resumed.health.retries, baseline.health.retries,
            "{tag}: recovery bookkeeping must survive the resume"
        );

        // The resumed run's final checkpoint marks the completed
        // trajectory; resuming *that* runs zero further epochs and
        // returns the identical θ.
        let done = Checkpoint::load(&path).expect("final checkpoint");
        let done_state = done.resume.expect("final resume state");
        assert_eq!(done_state.phase, ResumePhase::Lbfgs);
        assert!(done_state.epoch >= cfg.lbfgs_epochs);
        let replay = train_burgers_parallel_resilient(
            spec_with(48, 12),
            &cfg,
            DerivEngine::Ntp,
            &quiet_res(),
            Some(&done_state),
        );
        assert_bitwise_equal(&baseline, &replay, tag);
        let _ = std::fs::remove_file(&path);
    }
}

/// The stochastic estimator path: a kill-and-resume STDE run rebuilds
/// its shards at the serialized draw counter and stays bitwise identical
/// to the uninterrupted run — the per-step operator resampling is keyed
/// off restored state, not wall-clock history.
#[test]
fn stde_kill_and_resume_matches_the_uninterrupted_run_bitwise() {
    let stde = EstimatorMode::Stde {
        seed: 11,
        samples: 2,
        antithetic: false,
    };
    let mut spec = MultiPinnSpec::for_problem(PdeProblem::Poisson10d);
    spec.n_interior = 24;
    spec.n_boundary = 12;
    // (policy, global kill epoch, checkpoint cadence): Adam is 0..6,
    // L-BFGS 6..10.
    for (policy, kill_at, every) in [
        (ParallelPolicy::Serial, 4, 2),
        (ParallelPolicy::Fixed(4), 4, 2),
        (ParallelPolicy::Fixed(2), 8, 1),
    ] {
        let cfg = TrainConfig {
            width: 6,
            depth: 2,
            adam_epochs: 6,
            lbfgs_epochs: 4,
            adam_lr: 2e-3,
            seed: 3,
            log_every: 50,
            policy,
            chunk: 9,
            ..TrainConfig::default()
        };
        let baseline = train_pde_resilient(spec, &cfg, DerivEngine::Ntp, stde, &quiet_res(), None);

        let path = tmp(&format!("ntangent_resilience_stde_{kill_at}_{every}.json"));
        let interrupted_res = ResilienceConfig {
            checkpoint_path: Some(path.clone()),
            checkpoint_every: every,
            fault: FaultPlan::new(&[(FaultKind::Kill, kill_at)]),
            ..ResilienceConfig::default()
        };
        let interrupted = train_pde_resilient(
            spec,
            &cfg,
            DerivEngine::Ntp,
            stde,
            &interrupted_res,
            None,
        );
        assert!(interrupted.health.interrupted);

        let state = Checkpoint::load(&path)
            .expect("STDE checkpoint must load")
            .resume
            .expect("resume state");
        assert!(
            state.stde_step > 0,
            "an STDE snapshot must carry the draw counter"
        );
        let resumed = train_pde_resilient(
            spec,
            &cfg,
            DerivEngine::Ntp,
            stde,
            &quiet_res(),
            Some(&state),
        );
        assert_eq!(
            baseline.final_loss.to_bits(),
            resumed.final_loss.to_bits(),
            "{policy:?} kill@{kill_at}: final loss"
        );
        assert_eq!(
            params::flatten(&baseline.mlp),
            params::flatten(&resumed.mlp),
            "{policy:?} kill@{kill_at}: trained weights"
        );
        let _ = std::fs::remove_file(&path);
    }
}

/// A transient injected NaN (loss or gradient) trips the guard, rolls
/// back, and completes with a finite loss for all four activation
/// towers — and the recovered trajectory is itself deterministic: two
/// identical faulted runs agree bitwise.
#[test]
fn nan_injection_recovers_deterministically_for_every_activation() {
    for activation in ActivationKind::ALL {
        for (kind, tag) in [(FaultKind::NanLoss, "nan-loss"), (FaultKind::NanGrad, "nan-grad")] {
            let cfg = cfg_with(ParallelPolicy::Fixed(2), activation);
            let run = || {
                let res = ResilienceConfig {
                    fault: FaultPlan::new(&[(kind, 4)]),
                    ..ResilienceConfig::default()
                };
                train_burgers_parallel_resilient(
                    spec_with(32, 8),
                    &cfg,
                    DerivEngine::Ntp,
                    &res,
                    None,
                )
            };
            let a = run();
            let name = activation.name();
            assert_eq!(a.health.retries, 1, "{name}/{tag}: exactly one rollback");
            assert!(a.health.aborted.is_none(), "{name}/{tag}: must recover");
            assert!(!a.health.interrupted);
            assert!(
                a.final_loss.is_finite(),
                "{name}/{tag}: recovered loss must be finite"
            );
            assert!(
                params::flatten(&a.mlp).data().iter().all(|v| v.is_finite()),
                "{name}/{tag}: recovered weights must be finite"
            );
            let b = run();
            assert_bitwise_equal(&a, &b, &format!("{name}/{tag} replay"));
        }
    }
}

/// Persistent divergence (a NaN re-injected on every retry) exhausts the
/// bounded retry budget and aborts cleanly: classified error, last-good
/// θ in the result, and a valid last-good checkpoint on disk — never a
/// panic, never a silent NaN.
#[test]
fn exhausted_retries_abort_cleanly_with_a_last_good_checkpoint() {
    let path = tmp("ntangent_resilience_abort.json");
    let cfg = cfg_with(ParallelPolicy::Serial, ActivationKind::Tanh);
    let res = ResilienceConfig {
        checkpoint_path: Some(path.clone()),
        checkpoint_every: 0,
        max_retries: 2,
        // Faults fire once each, so re-injecting at successive epochs
        // models a *persistent* fault the deterministic backoff cannot
        // outrun.
        fault: FaultPlan::new(&[
            (FaultKind::NanLoss, 2),
            (FaultKind::NanLoss, 3),
            (FaultKind::NanLoss, 4),
        ]),
        ..ResilienceConfig::default()
    };
    let result =
        train_burgers_parallel_resilient(spec_with(32, 8), &cfg, DerivEngine::Ntp, &res, None);
    match result.health.aborted {
        Some(NumericError::NonFiniteResidual { epoch }) => {
            assert_eq!(epoch, 4, "the third injection exhausts the budget")
        }
        other => panic!("expected a non-finite-residual abort, got {other:?}"),
    }
    assert_eq!(result.health.retries, 3, "max_retries + 1 trips");
    assert!(
        result.final_loss.is_finite(),
        "the abort result carries the last-good loss"
    );
    assert!(params::flatten(&result.mlp).data().iter().all(|v| v.is_finite()));

    // The last-good checkpoint is on disk, valid, and resumable.
    let ck = Checkpoint::load(&path).expect("abort must persist the last-good checkpoint");
    ck.validate().expect("last-good checkpoint validates");
    let state = ck.resume.expect("resume state");
    assert_eq!(state.phase, ResumePhase::Adam);
    assert!(state.theta.iter().all(|v| v.is_finite()));
    let _ = std::fs::remove_file(&path);
}

/// Atomic-write semantics under a simulated mid-write crash: a stale
/// `*.tmp` sibling (the moment before the rename) leaves the published
/// checkpoint untouched and loadable, while a torn *final* file fails
/// with the `corrupted` taxonomy instead of panicking.
#[test]
fn atomic_checkpoint_survives_a_simulated_midwrite_crash() {
    let path = tmp("ntangent_resilience_atomic.json");
    let cfg = TrainConfig {
        adam_epochs: 4,
        lbfgs_epochs: 2,
        ..cfg_with(ParallelPolicy::Serial, ActivationKind::Tanh)
    };
    let res = ResilienceConfig {
        checkpoint_path: Some(path.clone()),
        checkpoint_every: 2,
        fault: FaultPlan::none(),
        ..ResilienceConfig::default()
    };
    let trained =
        train_burgers_parallel_resilient(spec_with(24, 6), &cfg, DerivEngine::Ntp, &res, None);
    assert!(trained.health.checkpoint_error.is_none());
    let good = Checkpoint::load(&path).expect("published checkpoint loads");

    // Crash mid-save: the writer dies after producing a partial temp
    // file, before the rename. The published file must be unaffected.
    let tmp_sibling = path.with_file_name("ntangent_resilience_atomic.json.tmp");
    std::fs::write(&tmp_sibling, "{\"version\":1,\"theta\":[0.1,").unwrap();
    let reread = Checkpoint::load(&path).expect("stale temp file must not shadow the checkpoint");
    assert_eq!(reread.to_json().dump(), good.to_json().dump());

    // A torn final file (truncated rename target on a non-atomic
    // filesystem) fails with the clean `corrupted` taxonomy.
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::write(&path, &text[..text.len() / 2]).unwrap();
    let err = Checkpoint::load(&path).expect_err("torn file must be rejected");
    assert!(
        format!("{err:#}").contains("checkpoint corrupted"),
        "taxonomy lost: {err:#}"
    );
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&tmp_sibling);
}
