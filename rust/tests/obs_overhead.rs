//! Observability contract suite: instrumentation must never change a
//! result.
//!
//! The contract (`rust/src/obs/mod.rs`): spans, metrics and kernel-phase
//! sampling only read monotonic clocks and bump `AtomicU64`s — they never
//! touch a float buffer. So every traced computation here is compared
//! **bitwise** against its untraced twin: fused forwards under every
//! [`ParallelPolicy`], whole training trajectories (with and without a
//! telemetry stream), and stochastic STDE estimates. On top of that: the
//! histogram must be lossless under concurrent hammering, span stacks
//! must stay balanced across panics, and the `{"stats":"full"}` wire
//! quantiles must land in the same log-scale bucket as a client-side
//! histogram fed the same samples (the `bench serve` agreement bound).
//!
//! Tests that flip the process-wide enable flag serialize on
//! [`obs::test_guard`] — the flag is global and the harness is threaded.

use ntangent::coordinator::{protocol, Metrics};
use ntangent::nn::Mlp;
use ntangent::ntp::{NtpEngine, ParallelPolicy, StdeConfig, StdeEngine};
use ntangent::obs;
use ntangent::pde::PdeProblem;
use ntangent::pinn::{
    telemetry, train_burgers_parallel, train_burgers_resilient, BurgersLossSpec, DerivEngine,
    ResilienceConfig, TrainConfig,
};
use ntangent::tensor::Tensor;
use ntangent::util::json::Json;
use ntangent::util::prng::Prng;
use std::sync::Arc;

fn policies() -> Vec<ParallelPolicy> {
    vec![
        ParallelPolicy::Serial,
        ParallelPolicy::Fixed(2),
        ParallelPolicy::Fixed(4),
        ParallelPolicy::Auto,
    ]
}

fn assert_bitwise(a: &Tensor, b: &Tensor, what: &str) {
    assert_eq!(a.shape(), b.shape(), "{what}: shapes differ");
    for (i, (&x, &y)) in a.data().iter().zip(b.data()).enumerate() {
        assert!(
            x.to_bits() == y.to_bits(),
            "{what}: element {i} differs bitwise ({x:e} vs {y:e})"
        );
    }
}

fn small_spec() -> BurgersLossSpec {
    let mut spec = BurgersLossSpec::for_profile(1);
    spec.n_res = 24;
    spec.n_org = 8;
    spec.x_max = 1.5;
    spec
}

fn small_cfg() -> TrainConfig {
    TrainConfig {
        width: 10,
        depth: 2,
        adam_epochs: 25,
        lbfgs_epochs: 8,
        seed: 5,
        log_every: 5,
        ..TrainConfig::default()
    }
}

// ------------------------------------------------- bitwise identity

#[test]
fn traced_forwards_are_bitwise_identical_for_every_policy() {
    let _g = obs::test_guard();
    let mut rng = Prng::seeded(11);
    let mlp = Mlp::uniform(1, 16, 3, 1, &mut rng);
    let x = Tensor::rand_uniform(&[64, 1], -1.0, 1.0, &mut rng);
    let was_sample = obs::kernel_sample();
    for policy in policies() {
        let engine = NtpEngine::with_policy(4, policy);
        obs::set_enabled(false);
        let want = engine.forward_n(&mlp, &x, 4);
        obs::set_enabled(true);
        obs::set_kernel_sample(2); // aggressive sampling: worst case
        let got = engine.forward_n(&mlp, &x, 4);
        obs::set_enabled(false);
        assert_eq!(want.len(), got.len());
        for (k, (a, b)) in want.iter().zip(&got).enumerate() {
            assert_bitwise(a, b, &format!("forward_n {policy:?} channel {k}"));
        }
    }
    obs::set_kernel_sample(was_sample);
}

#[test]
fn traced_training_trajectories_are_bitwise_identical() {
    let _g = obs::test_guard();
    let cfg = TrainConfig {
        policy: ParallelPolicy::Fixed(2),
        ..small_cfg()
    };
    obs::set_enabled(false);
    let plain = train_burgers_parallel(small_spec(), &cfg, DerivEngine::Ntp);
    obs::set_enabled(true);
    let traced = train_burgers_parallel(small_spec(), &cfg, DerivEngine::Ntp);
    obs::set_enabled(false);
    assert_eq!(plain.final_loss.to_bits(), traced.final_loss.to_bits());
    assert_eq!(plain.lambda.to_bits(), traced.lambda.to_bits());
    assert_eq!(plain.logs.len(), traced.logs.len());
    for (a, b) in plain.logs.iter().zip(&traced.logs) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "epoch {}", a.epoch);
        assert_eq!(a.lambda.to_bits(), b.lambda.to_bits(), "epoch {}", a.epoch);
    }
}

#[test]
fn traced_stde_estimates_are_bitwise_identical() {
    let _g = obs::test_guard();
    let problem = PdeProblem::from_name("poisson10d").expect("library problem");
    let mut rng = Prng::seeded(7);
    let mlp = Mlp::uniform(problem.dim(), 12, 2, 1, &mut rng);
    let x = Tensor::rand_uniform(&[16, problem.dim()], -1.0, 1.0, &mut rng);
    let cfg = StdeConfig {
        seed: 3,
        samples: 4,
        antithetic: false,
    };
    for policy in policies() {
        let engine = StdeEngine::with_policy(problem.operator(), cfg, policy);
        obs::set_enabled(false);
        let want = engine.estimate(&mlp, &x, 0);
        obs::set_enabled(true);
        let got = engine.estimate(&mlp, &x, 0);
        obs::set_enabled(false);
        assert_eq!(want.n_directions, got.n_directions, "{policy:?}");
        assert_bitwise(&want.values, &got.values, &format!("stde {policy:?}"));
    }
}

// ------------------------------------------------- telemetry observer

#[test]
fn telemetry_stream_does_not_perturb_the_trajectory() {
    let _g = obs::test_guard();
    obs::set_enabled(false);
    let dir = std::env::temp_dir().join(format!("ntangent-obs-telemetry-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("steps.jsonl");
    let cfg = small_cfg();

    let silent = train_burgers_resilient(
        small_spec(),
        &cfg,
        DerivEngine::Ntp,
        &ResilienceConfig::default(),
        None,
    );
    let res = ResilienceConfig {
        telemetry_path: Some(path.clone()),
        ..ResilienceConfig::default()
    };
    let streamed =
        train_burgers_resilient(small_spec(), &cfg, DerivEngine::Ntp, &res, None);

    // The trajectory is bitwise unaffected by the side-channel.
    assert_eq!(silent.final_loss.to_bits(), streamed.final_loss.to_bits());
    assert_eq!(silent.lambda.to_bits(), streamed.lambda.to_bits());
    assert_eq!(silent.logs.len(), streamed.logs.len());
    for (a, b) in silent.logs.iter().zip(&streamed.logs) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "epoch {}", a.epoch);
    }

    // One record per accepted optimizer step, every line a
    // self-contained object. Guard retries may re-record a rolled-back
    // epoch, so the count is bounded below, not pinned.
    let rows = telemetry::read_jsonl(&std::fs::read_to_string(&path).unwrap());
    assert!(
        rows.len() >= cfg.adam_epochs,
        "{} records for {} adam epochs",
        rows.len(),
        cfg.adam_epochs
    );
    let first = &rows[0];
    assert_eq!(first.get("step").and_then(Json::as_usize), Some(0));
    assert_eq!(first.get("phase").and_then(Json::as_str), Some("adam"));
    assert!(first.get("grad_norm").and_then(Json::as_f64).unwrap() > 0.0);
    for row in &rows {
        assert!(row.get("loss").and_then(Json::as_f64).unwrap().is_finite());
        assert!(row.get("lambda").and_then(Json::as_f64).is_some());
        assert!(row.get("retries").and_then(Json::as_f64).is_some());
        assert!(row.get("lr_scale").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(row.get("step_ms").and_then(Json::as_f64).unwrap() >= 0.0);
    }
    // Both phases appear.
    assert!(rows
        .iter()
        .any(|r| r.get("phase").and_then(Json::as_str) == Some("lbfgs")));
    std::fs::remove_dir_all(&dir).ok();
}

// ------------------------------------------------- histogram + spans

#[test]
fn histogram_is_lossless_under_concurrent_hammering() {
    let hist = Arc::new(obs::Histogram::new());
    let threads = 8u64;
    let per = 10_000u64;
    let mut handles = Vec::new();
    for t in 0..threads {
        let h = Arc::clone(&hist);
        handles.push(std::thread::spawn(move || {
            for i in 0..per {
                h.record(t * per + i + 1);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let n = threads * per;
    let snap = hist.snapshot();
    assert_eq!(snap.count, n, "no record lost");
    assert_eq!(snap.sum, n * (n + 1) / 2, "exact sum conserved");
    assert_eq!(snap.max, n, "exact max conserved");
    assert_eq!(snap.buckets.iter().sum::<u64>(), n);
    let p50 = snap.percentile(0.50).unwrap();
    let p95 = snap.percentile(0.95).unwrap();
    let p99 = snap.percentile(0.99).unwrap();
    assert!(p50 <= p95 && p95 <= p99);
    // Bucket midpoints approximate the true quantiles to bucket width.
    assert!((p50 / (n as f64 / 2.0) - 1.0).abs() < 0.2, "p50 {p50}");
}

#[test]
fn span_stack_stays_balanced_across_panics() {
    let _g = obs::test_guard();
    obs::set_enabled(true);
    let r = std::panic::catch_unwind(|| {
        let _outer = obs::span("overhead.outer");
        let _inner = obs::span("overhead.inner");
        assert_eq!(obs::span_depth(), 2);
        panic!("boom");
    });
    assert!(r.is_err());
    assert_eq!(obs::span_depth(), 0, "unwind must pop both spans");
    // Tracing still works after the unwind.
    {
        let _s = obs::span("overhead.after");
        assert_eq!(obs::span_depth(), 1);
    }
    assert!(obs::span_report()
        .iter()
        .any(|n| n.name == "overhead.after"));
    obs::set_enabled(false);
}

// ------------------------------------------------- wire agreement

#[test]
fn wire_stats_and_client_histograms_agree_within_one_bucket() {
    // `bench serve` quotes client-side latencies from the same log-scale
    // histogram type the server's stats endpoint uses; feed both ends
    // one latency population and the quoted quantiles must land in the
    // same bucket (the unit the acceptance bound is stated in).
    let metrics = Metrics::with_workers(1);
    let client = obs::Histogram::new();
    let mut state = 0x9E37_79B9_7F4A_7C15u64;
    for _ in 0..4096 {
        state = state
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        let ns = 10_000 + state % 10_000_000; // 10 µs .. 10 ms
        metrics.record_latency_on(0, ns);
        client.record(ns);
    }
    let server = metrics.snapshot();
    let client_snap = client.snapshot();
    for q in [0.50, 0.95, 0.99] {
        let sb = server.latency.percentile_bucket(q).unwrap();
        let cb = client_snap.percentile_bucket(q).unwrap();
        assert!(
            sb.abs_diff(cb) <= 1,
            "q={q}: server bucket {sb} vs client bucket {cb}"
        );
    }

    // And the `{"stats":"full"}` reply quotes exactly the histogram's
    // own numbers.
    let line = protocol::encode_stats_full(&server);
    let doc = Json::parse(&line).expect("stats_full parses");
    let stats = doc.get("stats").expect("stats envelope");
    let p50_wire = stats
        .get("latency")
        .and_then(|l| l.get("p50"))
        .and_then(Json::as_f64)
        .expect("stats.latency.p50 present");
    assert_eq!(
        p50_wire.to_bits(),
        server.latency.percentile(0.50).unwrap().to_bits()
    );
    let p50_us = stats.get("p50_latency_us").and_then(Json::as_f64).unwrap();
    assert_eq!(p50_us.to_bits(), (p50_wire / 1e3).to_bits());
}
