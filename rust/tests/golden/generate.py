#!/usr/bin/env python3
"""Regenerate `fixture.rs` and `fixture_multi.rs` for the golden tests.

`fixture.rs` (univariate towers): a fixed 1 -> 6 -> 6 -> 1 MLP (weights
drawn once from a pinned numpy seed, embedded verbatim) and the reference
derivative channels u^(n), n = 0..=6, at pinned inputs for every
registered activation with mpmath at 60 decimal digits — an oracle fully
independent of the Rust engine (Taylor coefficients, not Faa di Bruno).

`fixture_multi.rs` (multivariate mixed partials): fixed 2-D and 3-D
networks with every mixed partial `∂^α u`, |α| <= 4, at pinned points —
computed with `mpmath.diff` partial orders, an oracle independent of both
the directional-jet assembly under test and the nested-tape baseline.
Also carries the OP4 block: the 4-D Laplacian (one pure-axis operator)
on a fixed 4-D net, the golden target for the STDE factor-wise plans
(`rust/tests/stde_statistics.rs`).

The Rust tests rebuild the same networks via `params::unflatten_into`
and assert the engines against these values to 1e-10.

Run from the repo root:  python3 rust/tests/golden/generate.py
"""

import math
import os

import numpy as np
from mpmath import mp, mpf, diff, erf, exp, log, sin, sqrt, tanh, taylor

mp.dps = 60

SIZES = [1, 6, 6, 1]
SEED = 20260728
X_PINNED = [-1.2, -0.4, 0.0, 0.5, 1.3]
N_MAX = 6
KINDS = ["tanh", "sin", "softplus", "gelu"]  # ActivationKind::ALL order

# Multivariate fixtures: (tag, sizes, seed, pinned points), |alpha| <= MULTI_ORDER.
MULTI_ORDER = 4
MULTI_NETS = [
    ("MULTI2", [2, 5, 5, 1], SEED + 1, [[-0.8, 0.3], [0.2, -0.5], [0.6, 0.9], [-0.1, -1.1]]),
    ("MULTI3", [3, 4, 4, 1], SEED + 2, [[0.4, -0.6, 0.2], [-0.9, 0.1, 0.7], [0.3, 0.8, -0.4]]),
]

# Pure-axis operator fixture: the 4-D Laplacian L[u] = sum_i d2u/dx_i^2 on
# a fixed 4-D net — the golden target the STDE factor-wise mini plans must
# reproduce exactly (rust/tests/stde_statistics.rs).
OP4 = (
    "OP4",
    [4, 4, 4, 1],
    SEED + 3,
    [[0.3, -0.7, 0.1, 0.5], [-0.2, 0.4, -0.9, 0.6], [0.8, 0.2, 0.5, -0.3]],
)


def make_weights(sizes=SIZES, seed=SEED):
    """Per-layer (W, b) f64 arrays, modest magnitudes (xavier-flavoured)."""
    rng = np.random.default_rng(seed)
    layers = []
    for fan_in, fan_out in zip(sizes, sizes[1:]):
        bound = math.sqrt(6.0 / (fan_in + fan_out))
        w = rng.uniform(-bound, bound, size=(fan_out, fan_in))
        b = rng.uniform(-0.3, 0.3, size=(fan_out,))
        layers.append((w, b))
    return layers


def act_fn(kind, z):
    if kind == "tanh":
        return tanh(z)
    if kind == "sin":
        return sin(z)
    if kind == "softplus":
        return log(1 + exp(z))
    if kind == "gelu":
        return z * (1 + erf(z / sqrt(2))) / 2
    raise ValueError(kind)


def forward(layers, kind, x):
    """Scalar network output at mpf x (hidden activation, linear head)."""
    h = [x]
    for li, (w, b) in enumerate(layers):
        z = [
            sum(mpf(w[j, k]) * h[k] for k in range(w.shape[1])) + mpf(b[j])
            for j in range(w.shape[0])
        ]
        h = z if li == len(layers) - 1 else [act_fn(kind, zj) for zj in z]
    assert len(h) == 1
    return h[0]


def derivatives(layers, kind, x0):
    """[f(x0), f'(x0), ..., f^(N_MAX)(x0)] as f64."""
    coeffs = taylor(lambda t: forward(layers, kind, t), mpf(x0), N_MAX)
    return [float(c * mp.factorial(n)) for n, c in enumerate(coeffs)]


def flatten(layers):
    theta = []
    for w, b in layers:
        theta.extend(w.flatten(order="C").tolist())
        theta.extend(b.tolist())
    return theta


def fmt(values, per_line=4, indent="    "):
    lines = []
    for i in range(0, len(values), per_line):
        chunk = ", ".join(f"{v!r}f64" for v in values[i : i + per_line])
        lines.append(indent + chunk + ",")
    return "\n".join(lines)


def forward_nd(layers, kind, xs):
    """Scalar network output at mpf coordinates xs (any input dim)."""
    h = [mpf(x) for x in xs]
    for li, (w, b) in enumerate(layers):
        z = [
            sum(mpf(w[j, k]) * h[k] for k in range(w.shape[1])) + mpf(b[j])
            for j in range(w.shape[0])
        ]
        h = z if li == len(layers) - 1 else [act_fn(kind, zj) for zj in z]
    assert len(h) == 1
    return h[0]


def multi_indices(dim, order):
    """All |alpha| = order compositions, first axis most significant
    descending — mirrors ntangent::ntp::multi::multi_indices."""
    if dim == 1:
        return [(order,)]
    out = []
    for v in range(order, -1, -1):
        for rest in multi_indices(dim - 1, order - v):
            out.append((v,) + rest)
    return out


def mixed_partial(layers, kind, point, alpha):
    """f64 value of ∂^alpha u at the point (mpmath.diff partial orders)."""
    if all(a == 0 for a in alpha):
        return float(forward_nd(layers, kind, point))
    f = lambda *xs: forward_nd(layers, kind, xs)
    return float(diff(f, tuple(point), tuple(alpha)))


def emit_multi(out, tag, sizes, seed, points):
    dim = sizes[0]
    layers = make_weights(sizes, seed)
    theta = flatten(layers)
    alphas = [a for m in range(MULTI_ORDER + 1) for a in multi_indices(dim, m)]
    out.append(f"pub const {tag}_SIZES: [usize; {len(sizes)}] = {sizes!r};".replace("'", ""))
    out.append("")
    out.append("/// Flat parameters in `params::flatten` order (W0, b0, W1, b1, ...).")
    out.append(f"pub const {tag}_THETA: [f64; {len(theta)}] = [")
    out.append(fmt(theta))
    out.append("];")
    out.append("")
    out.append("/// Pinned evaluation points (one coordinate row each).")
    out.append(f"pub const {tag}_X: [[f64; {dim}]; {len(points)}] = [")
    for p in points:
        out.append(f"    {list(p)!r},".replace("'", ""))
    out.append("];")
    out.append("")
    out.append(f"/// Every multi-index with |α| ≤ {MULTI_ORDER}, ascending order.")
    out.append(f"pub const {tag}_ALPHAS: [[usize; {dim}]; {len(alphas)}] = [")
    for a in alphas:
        out.append(f"    {list(a)!r},".replace("'", ""))
    out.append("];")
    out.append("")
    out.append(f"/// `EXPECTED[kind][alpha][point]`, kinds in `ActivationKind::ALL` order.")
    out.append(
        f"pub const {tag}_EXPECTED: [[[f64; {len(points)}]; {len(alphas)}]; {len(KINDS)}] = ["
    )
    values = []
    for kind in KINDS:
        out.append(f"    // {kind}")
        out.append("    [")
        for alpha in alphas:
            row = [mixed_partial(layers, kind, p, alpha) for p in points]
            values.extend(row)
            out.append("        [")
            out.append(fmt(row, per_line=2, indent="            "))
            out.append("        ],")
        out.append("    ],")
    out.append("];")
    out.append("")
    mags = [abs(v) for v in values if v != 0.0]
    return len(values), (min(mags), max(mags))


def emit_op4(out):
    """The 4-D pure-axis operator block: net + exact Laplacian values."""
    tag, sizes, seed, points = OP4
    dim = sizes[0]
    layers = make_weights(sizes, seed)
    theta = flatten(layers)
    out.append(f"pub const {tag}_SIZES: [usize; {len(sizes)}] = {sizes!r};".replace("'", ""))
    out.append("")
    out.append("/// Flat parameters in `params::flatten` order (W0, b0, W1, b1, ...).")
    out.append(f"pub const {tag}_THETA: [f64; {len(theta)}] = [")
    out.append(fmt(theta))
    out.append("];")
    out.append("")
    out.append("/// Pinned evaluation points (one coordinate row each).")
    out.append(f"pub const {tag}_X: [[f64; {dim}]; {len(points)}] = [")
    for p in points:
        out.append(f"    {list(p)!r},".replace("'", ""))
    out.append("];")
    out.append("")
    out.append("/// `LAPLACIAN[kind][point]`: the 4-D pure-axis operator")
    out.append("/// Σᵢ ∂²u/∂xᵢ², kinds in `ActivationKind::ALL` order (summed in")
    out.append("/// 60-digit precision, rounded once).")
    out.append(f"pub const {tag}_LAPLACIAN: [[f64; {len(points)}]; {len(KINDS)}] = [")
    values = []
    for kind in KINDS:
        f = lambda *xs: forward_nd(layers, kind, xs)
        row = []
        for p in points:
            acc = mpf(0)
            for i in range(dim):
                alpha = tuple(2 if j == i else 0 for j in range(dim))
                acc += diff(f, tuple(p), alpha)
            row.append(float(acc))
        values.extend(row)
        out.append(f"    // {kind}")
        out.append("    [")
        out.append(fmt(row, per_line=2, indent="        "))
        out.append("    ],")
    out.append("];")
    out.append("")
    mags = [abs(v) for v in values if v != 0.0]
    return len(values), (min(mags), max(mags))


def write_multi_fixture():
    out = []
    out.append("// Generated by rust/tests/golden/generate.py — do not edit by hand.")
    out.append("// Reference values: mpmath (60 digits) partial derivatives of fixed")
    out.append("// 2-D and 3-D networks — an oracle independent of both the")
    out.append("// directional-jet assembly under test and the nested-tape baseline —")
    out.append("// plus the OP4 4-D pure-axis operator block for the STDE plans.")
    out.append("#![allow(clippy::excessive_precision)]")
    out.append("#![allow(clippy::approx_constant)]")
    out.append("")
    total = 0
    for tag, sizes, seed, points in MULTI_NETS:
        count, (lo, hi) = emit_multi(out, tag, sizes, seed, points)
        total += count
        print(f"  {tag}: {count} expected values, |expected| range {lo:.3e} .. {hi:.3e}")
    count, (lo, hi) = emit_op4(out)
    total += count
    print(f"  OP4: {count} expected values, |expected| range {lo:.3e} .. {hi:.3e}")
    dest = os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "fixture_multi.rs"
    )
    with open(dest, "w") as fh:
        fh.write("\n".join(out))
    print(f"wrote {dest} ({total} expected values)")


def main():
    layers = make_weights()
    theta = flatten(layers)
    out = []
    out.append("// Generated by rust/tests/golden/generate.py — do not edit by hand.")
    out.append("// Reference values: mpmath (60 digits), Taylor coefficients of the")
    out.append("// scalar network — an oracle independent of the engine under test.")
    out.append("// (The `mod` declaration carries #[rustfmt::skip]; an inner tool")
    out.append("// attribute here would need unstable custom_inner_attributes.)")
    out.append("#![allow(clippy::excessive_precision)]")
    out.append("#![allow(clippy::approx_constant)]")
    out.append("")
    out.append(f"pub const GOLDEN_N: usize = {N_MAX};")
    out.append(f"pub const GOLDEN_SIZES: [usize; {len(SIZES)}] = {SIZES!r};".replace("'", ""))
    out.append("")
    out.append("/// Flat parameters in `params::flatten` order (W0, b0, W1, b1, ...).")
    out.append(f"pub const GOLDEN_THETA: [f64; {len(theta)}] = [")
    out.append(fmt(theta))
    out.append("];")
    out.append("")
    out.append("/// Pinned evaluation points.")
    out.append(f"pub const GOLDEN_X: [f64; {len(X_PINNED)}] = [")
    out.append(fmt(X_PINNED))
    out.append("];")
    out.append("")
    out.append("/// `EXPECTED[kind][order][point]`, kinds in `ActivationKind::ALL`")
    out.append(f"/// order ({', '.join(KINDS)}), orders 0..={N_MAX}.")
    out.append(
        f"pub const GOLDEN_EXPECTED: [[[f64; {len(X_PINNED)}]; {N_MAX + 1}]; {len(KINDS)}] = ["
    )
    for kind in KINDS:
        per_point = [derivatives(layers, kind, x0) for x0 in X_PINNED]
        out.append(f"    // {kind}")
        out.append("    [")
        for order in range(N_MAX + 1):
            row = [per_point[p][order] for p in range(len(X_PINNED))]
            out.append("        [")
            out.append(fmt(row, per_line=2, indent="            "))
            out.append("        ],")
        out.append("    ],")
    out.append("];")
    out.append("")

    dest = os.path.join(os.path.dirname(os.path.abspath(__file__)), "fixture.rs")
    with open(dest, "w") as fh:
        fh.write("\n".join(out))
    print(f"wrote {dest} ({len(theta)} params, {len(KINDS) * (N_MAX + 1) * len(X_PINNED)} expected values)")
    # Sanity: report magnitude range so tolerances stay meaningful.
    mags = [
        abs(v)
        for kind in KINDS
        for x0 in X_PINNED
        for v in derivatives(layers, kind, x0)
        if v != 0.0
    ]
    print(f"|expected| range: {min(mags):.3e} .. {max(mags):.3e}")
    write_multi_fixture()


if __name__ == "__main__":
    main()
