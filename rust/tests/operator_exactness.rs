//! Multivariate operator lockdown: directional-jet assembly against the
//! nested-tape baseline, exact recombination-matrix identities, and
//! bitwise thread-count determinism for the multivariate PINN objective
//! and trainer.
//!
//! The committed mpmath mixed-partial goldens live in
//! `golden_towers.rs` (`fixture_multi.rs`); this file holds the
//! engine-vs-engine and determinism contracts.

use ntangent::autodiff::{higher, Graph};
use ntangent::nn::{params, Mlp};
use ntangent::ntp::{
    multi_indices, ActivationKind, JetPlan, MultiJetEngine, ParallelPolicy,
};
use ntangent::pde::{DiffOperator, PdeProblem};
use ntangent::pinn::{train_pde, DerivEngine, MultiObjective, MultiPinnSpec, TrainConfig};
use ntangent::tensor::Tensor;
use ntangent::util::prng::Prng;
use ntangent::util::{allclose_slice, max_abs_diff};
use std::collections::HashMap;

/// Directional assembly equals the nested-tape mixed partials to 1e-10
/// for every multi-index (|α| ≤ 4 in 2-D, ≤ 3 in 3-D) and every
/// registered activation — two completely different exact algorithms.
#[test]
fn mixed_partials_match_nested_tape() {
    for (dim, n_max) in [(2usize, 4usize), (3, 3)] {
        for kind in ActivationKind::ALL {
            let mut rng = Prng::seeded(0xA1F + dim as u64 * 31 + kind.index() as u64);
            let mlp = Mlp::uniform_with(dim, 6, 2, 1, kind, &mut rng);
            let x = Tensor::rand_uniform(&[5, dim], -0.9, 0.9, &mut rng);
            let engine = MultiJetEngine::new(dim, n_max);
            let jet = engine.jet(&mlp, &x);

            let mut g = Graph::new();
            let pn = mlp.const_param_nodes(&mut g);
            let xn = g.input(x.shape());
            let u = mlp.forward_graph(&mut g, xn, &pn);
            for m in 1..=n_max {
                for alpha in multi_indices(dim, m) {
                    let node = higher::mixed_partial(&mut g, u, xn, &alpha);
                    let vals = g.eval(&[x.clone()], &[node]);
                    let got = jet.partial(&alpha);
                    assert!(
                        allclose_slice(got.data(), vals.get(node).data(), 1e-10, 1e-10),
                        "dim {dim} {} ∂^{alpha:?}: max diff {}",
                        kind.name(),
                        max_abs_diff(got.data(), vals.get(node).data())
                    );
                }
            }
        }
    }
}

/// The recombination rows are an exact inverse of the direction moment
/// matrix: `Σ_k w_k · (m!/β!) v_k^β = δ_{αβ}`, recomputed in plain f64
/// from the public plan API alone.
#[test]
fn recombination_matrices_are_exact_inverses() {
    fn multinom(alpha: &[usize]) -> f64 {
        let m: usize = alpha.iter().sum();
        let mut r: f64 = (1..=m).map(|i| i as f64).product();
        for &a in alpha {
            let fa: f64 = (1..=a).map(|i| i as f64).product();
            r /= fa;
        }
        r
    }
    for (dim, n) in [(1usize, 6usize), (2, 4), (2, 6), (3, 4)] {
        let plan = JetPlan::new(dim, n);
        for m in 1..=n {
            let multis = plan.multis(m);
            for (a, alpha) in multis.iter().enumerate() {
                let (ids, w) = plan.weights_for(alpha);
                for (b, beta) in multis.iter().enumerate() {
                    let mut acc = 0.0;
                    for (&id, &wk) in ids.iter().zip(w) {
                        let mut mom = multinom(beta);
                        for (&vi, &bi) in plan.directions()[id].iter().zip(beta.iter()) {
                            mom *= (vi as f64).powi(bi as i32);
                        }
                        acc += wk * mom;
                    }
                    let want = if a == b { 1.0 } else { 0.0 };
                    assert!(
                        (acc - want).abs() < 1e-9,
                        "dim={dim} n={n} m={m} α={alpha:?} β={beta:?}: {acc}"
                    );
                }
            }
        }
    }
}

/// The bench acceptance pair at a test-sized shape: the full operator
/// evaluation (including the order-4 biharmonic) assembled from jets
/// equals the nested-tape evaluation.
#[test]
fn operator_apply_matches_nested_tape() {
    for op in [DiffOperator::laplacian(2), DiffOperator::biharmonic(2)] {
        let mut rng = Prng::seeded(9);
        let mlp = Mlp::uniform(2, 7, 2, 1, &mut rng);
        let x = Tensor::rand_uniform(&[6, 2], -0.8, 0.8, &mut rng);
        let engine = MultiJetEngine::new(2, op.max_order());
        let jet = engine.jet(&mlp, &x);
        let got = op.apply(&jet);

        let mut g = Graph::new();
        let pn = mlp.const_param_nodes(&mut g);
        let xn = g.input(x.shape());
        let u = mlp.forward_graph(&mut g, xn, &pn);
        let mut partials = HashMap::new();
        for alpha in op.needed_partials() {
            let node = higher::mixed_partial(&mut g, u, xn, &alpha);
            partials.insert(alpha, node);
        }
        let lhs = op.apply_nodes(&mut g, &partials);
        let vals = g.eval(&[x.clone()], &[lhs]);
        assert!(
            allclose_slice(got.data(), vals.get(lhs).data(), 1e-10, 1e-10),
            "{}: max diff {}",
            op.describe(),
            max_abs_diff(got.data(), vals.get(lhs).data())
        );
    }
}

/// One loss/gradient evaluation of the multivariate objective is
/// bitwise identical across thread counts (ragged chunk layouts
/// included).
#[test]
fn multi_objective_is_bitwise_thread_invariant() {
    let mut rng_m = Prng::seeded(2);
    let mlp = Mlp::uniform(2, 8, 2, 1, &mut rng_m);
    let mut spec = MultiPinnSpec::for_problem(PdeProblem::Heat2d);
    spec.n_interior = 26; // 26/8 → ragged chunks
    spec.n_boundary = 10;
    let build = |threads: usize| {
        let policy = if threads <= 1 {
            ParallelPolicy::Serial
        } else {
            ParallelPolicy::Fixed(threads)
        };
        MultiObjective::build(
            spec,
            &mlp,
            DerivEngine::Ntp,
            policy,
            8,
            &mut Prng::seeded(5),
        )
    };
    let mut baseline = build(1);
    let theta = baseline.theta_init(&mlp);
    use ntangent::opt::Objective;
    let (l0, g0) = baseline.value_grad(&theta);
    for threads in [2usize, 4, 8] {
        let mut obj = build(threads);
        let (l, g) = obj.value_grad(&theta);
        assert_eq!(l0.to_bits(), l.to_bits(), "{threads} threads");
        assert_eq!(g0, g, "{threads} threads");
        assert_eq!(
            baseline.value(&theta).to_bits(),
            obj.value(&theta).to_bits(),
            "{threads} threads (value)"
        );
    }
}

/// The acceptance bar: whole short PDE training trajectories (Adam then
/// L-BFGS — sharded tapes, deterministic reductions, policy-split
/// optimizer updates) are **bitwise identical across 1/2/4/8 threads**.
#[test]
fn pde_training_trajectories_bitwise_identical_across_thread_counts() {
    let run = |threads: usize| {
        let mut spec = MultiPinnSpec::for_problem(PdeProblem::Poisson2d);
        spec.n_interior = 30;
        spec.n_boundary = 12;
        let cfg = TrainConfig {
            width: 8,
            depth: 2,
            adam_epochs: 8,
            lbfgs_epochs: 4,
            seed: 11,
            chunk: 8,
            policy: if threads <= 1 {
                ParallelPolicy::Serial
            } else {
                ParallelPolicy::Fixed(threads)
            },
            ..TrainConfig::default()
        };
        train_pde(spec, &cfg, DerivEngine::Ntp)
    };
    let want = run(1);
    let want_theta = params::flatten(&want.mlp);
    for threads in [2usize, 4, 8] {
        let got = run(threads);
        assert_eq!(
            want.final_loss.to_bits(),
            got.final_loss.to_bits(),
            "{threads} threads"
        );
        assert_eq!(want_theta, params::flatten(&got.mlp), "{threads} threads");
    }
}
