//! Differential and allocation harness for the fused element-tiled
//! n-TangentProp kernel: the fused `forward_n` (compiled Faà di Bruno
//! program + interleaved channel tiles + stacked-channel GEMM) against
//! the retained pre-fusion `forward_reference` path, plus the
//! steady-state allocation contract and the fused path's serial-vs-
//! parallel bitwise guarantee at tile-straddling shapes.

use ntangent::nn::Mlp;
use ntangent::ntp::{ActivationKind, NtpEngine, ParallelPolicy};
use ntangent::tensor::{alloc, Tensor};
use ntangent::util::allclose_slice;
use ntangent::util::prng::Prng;
#[cfg(feature = "reference-oracle")]
use ntangent::util::ptest;

/// The tentpole differential property: fused == reference to ≤ 1e-12,
/// for every registered activation, random architectures, ragged batch
/// sizes (straddling the 128-element tile on the `[B·width]` plane) and
/// every truncation `n ≤ n_max`. The oracle lives behind the
/// `reference-oracle` feature; CI runs this sweep in the featured job.
#[cfg(feature = "reference-oracle")]
#[test]
fn fused_forward_matches_reference_for_all_activations() {
    for kind in ActivationKind::ALL {
        ptest::check(
            ptest::Config { cases: 20, seed: 0xF00D + kind.index() as u64 },
            |rng: &mut Prng| {
                let width = 2 + rng.below(28) as usize;
                let depth = 1 + rng.below(4) as usize;
                // Batches chosen so B·width lands below, at and past the
                // tile boundary, including remainders.
                let batch = 1 + rng.below(90) as usize;
                let n_max = 1 + rng.below(8) as usize;
                let n = rng.below(n_max as u64 + 1) as usize;
                let mlp = Mlp::uniform_with(1, width, depth, 1, kind, rng);
                let x = Tensor::rand_uniform(&[batch, 1], -2.0, 2.0, rng);
                (mlp, x, n_max, n)
            },
            |(mlp, x, n_max, n)| {
                let engine = NtpEngine::new(*n_max);
                let fused = engine.forward_n(mlp, x, *n);
                let reference = engine.forward_reference(mlp, x, *n);
                if fused.len() != n + 1 {
                    return Err("channel count".into());
                }
                for (k, (a, b)) in fused.iter().zip(&reference).enumerate() {
                    if a.shape() != b.shape() {
                        return Err(format!("channel {k} shape mismatch"));
                    }
                    if !allclose_slice(a.data(), b.data(), 1e-12, 1e-12) {
                        return Err(format!(
                            "{} channel {k} diverged (n={n}, n_max={n_max}, B={})",
                            mlp.activation.name(),
                            x.shape()[0]
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}

/// The fused kernel's parallel path is bitwise identical to its serial
/// path at shapes where chunking changes the tile layout (each chunk
/// tiles its own `[B_chunk·width]` plane) — the determinism contract is
/// serial-vs-parallel of the *new* kernel.
#[test]
fn fused_parallel_is_bitwise_serial_at_tile_straddling_shapes() {
    for kind in ActivationKind::ALL {
        let mut rng = Prng::seeded(0x71E + kind.index() as u64);
        let mlp = Mlp::uniform_with(1, 24, 3, 1, kind, &mut rng);
        let serial = NtpEngine::new(5);
        // 24-wide planes: B = 5 puts a chunk below one tile, B = 11/32
        // straddle tiles unevenly per chunk, B = 129 spans many tiles.
        for batch in [5usize, 11, 32, 129] {
            let x = Tensor::rand_uniform(&[batch, 1], -1.5, 1.5, &mut rng);
            let want = serial.forward(&mlp, &x);
            for threads in [2usize, 3, 7] {
                let eng = NtpEngine::with_policy(5, ParallelPolicy::Fixed(threads));
                let got = eng.forward(&mlp, &x);
                for (k, (a, b)) in want.iter().zip(&got).enumerate() {
                    assert_eq!(a, b, "{} B={batch} t={threads} channel {k}", kind.name());
                }
            }
        }
    }
}

/// Steady-state allocation contract of the fused path: once the pooled
/// scratch is grown, a forward call allocates exactly the `n+1` returned
/// channel tensors — zero per-layer heap allocation goes through the
/// accounted tensor constructors, for every activation.
#[test]
fn fused_steady_state_allocates_only_outputs() {
    for kind in ActivationKind::ALL {
        let mut rng = Prng::seeded(0xA110C + kind.index() as u64);
        let (width, depth, batch, n) = (24usize, 3usize, 100usize, 5usize);
        let mlp = Mlp::uniform_with(1, width, depth, 1, kind, &mut rng);
        let x = Tensor::rand_uniform(&[batch, 1], -1.0, 1.0, &mut rng);
        let engine = NtpEngine::new(n);
        let cold = engine.forward(&mlp, &x);
        let (warm, bytes) = alloc::measure(|| engine.forward(&mlp, &x));
        for (a, b) in cold.iter().zip(&warm) {
            assert_eq!(a, b, "{}: scratch reuse changed results", kind.name());
        }
        let outputs = ((n + 1) * batch * mlp.output_dim() * 8) as u64;
        assert_eq!(
            bytes,
            outputs,
            "{}: fused warm forward allocated beyond its outputs",
            kind.name()
        );
    }
}

/// Truncation consistency on one engine: running `n < n_max` through the
/// fused kernel (which skips the unused program suffix) agrees with a
/// fresh engine built at exactly `n`.
#[test]
fn truncated_fused_forward_matches_exact_sized_engine() {
    let mut rng = Prng::seeded(0x7A17);
    let mlp = Mlp::uniform(1, 16, 2, 1, &mut rng);
    let x = Tensor::rand_uniform(&[37, 1], -1.2, 1.2, &mut rng);
    let big = NtpEngine::new(8);
    for n in 0..=8usize {
        let exact = NtpEngine::new(n);
        let a = big.forward_n(&mlp, &x, n);
        let b = exact.forward_n(&mlp, &x, n);
        assert_eq!(a.len(), b.len());
        for (k, (ta, tb)) in a.iter().zip(&b).enumerate() {
            assert!(
                allclose_slice(ta.data(), tb.data(), 1e-12, 1e-12),
                "n={n} channel {k}"
            );
        }
    }
}
