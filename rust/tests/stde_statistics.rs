//! Statistical lockdown of the STDE estimator (`ntp::stde`): every
//! probabilistic claim the module's docs make is asserted here against
//! the exact multivariate oracle ([`MultiJetEngine`]) at low dimension,
//! where the full plan is cheap enough to serve as ground truth.
//!
//! The estimator is a pure function of `(seed, step)`, so each of these
//! tests is bitwise reproducible — the statistical bounds are generous
//! (6σ CLT envelopes, 2x variance brackets), but a pass is a pass
//! forever, not a coin flip.

#[rustfmt::skip]
#[path = "golden/fixture_multi.rs"]
#[allow(dead_code)]
mod fixture_multi;

use fixture_multi::{OP4_LAPLACIAN, OP4_SIZES, OP4_THETA, OP4_X};
use ntangent::nn::{params, Mlp};
use ntangent::ntp::stde::{sample_terms, sampled_operator};
use ntangent::ntp::{ActivationKind, MultiJetEngine, StdeConfig, StdeEngine};
use ntangent::pde::DiffOperator;
use ntangent::tensor::Tensor;
use ntangent::util::prng::Prng;

/// A frozen net and cloud for `dim` inputs.
fn net_and_cloud(dim: usize, rows: usize, seed: u64) -> (Mlp, Tensor) {
    let mut rng = Prng::seeded(seed);
    let mlp = Mlp::uniform(dim, 6, 2, 1, &mut rng);
    let x = Tensor::rand_uniform(&[rows, dim], -0.9, 0.9, &mut rng);
    (mlp, x)
}

/// Batch-mean of the exact `L[u]` over `x` — the scalar the estimates
/// are compared against.
fn exact_mean(op: &DiffOperator, mlp: &Mlp, x: &Tensor) -> f64 {
    let engine = MultiJetEngine::new(op.dim(), op.max_order());
    let vals = op.apply(&engine.jet(mlp, x));
    vals.data().iter().sum::<f64>() / vals.data().len() as f64
}

/// Batch-mean STDE estimates at steps `0..n_steps`.
fn estimate_means(
    op: &DiffOperator,
    mlp: &Mlp,
    x: &Tensor,
    cfg: StdeConfig,
    n_steps: usize,
) -> Vec<f64> {
    let est = StdeEngine::new(op.clone(), cfg);
    (0..n_steps)
        .map(|s| {
            let e = est.estimate(mlp, x, s as u64);
            e.values.data().iter().sum::<f64>() / e.values.data().len() as f64
        })
        .collect()
}

fn mean(v: &[f64]) -> f64 {
    v.iter().sum::<f64>() / v.len() as f64
}

fn variance(v: &[f64]) -> f64 {
    let m = mean(v);
    v.iter().map(|s| (s - m) * (s - m)).sum::<f64>() / (v.len() - 1) as f64
}

// ------------------------------------------------------- unbiasedness

/// E[estimate] = exact operator value: the empirical mean over many
/// counter steps lands inside a 6σ CLT envelope around the exact value,
/// for both a d=2 operator with a mixed term and a d=3 one.
#[test]
fn stde_is_unbiased_against_the_exact_oracle() {
    let cases: Vec<(usize, DiffOperator)> = vec![
        (
            2,
            DiffOperator::new(2)
                .with_term(1.0, vec![2, 0])
                .with_term(1.0, vec![0, 2])
                .with_term(2.0, vec![1, 1]),
        ),
        (
            3,
            DiffOperator::new(3)
                .with_term(1.0, vec![2, 0, 0])
                .with_term(-3.0, vec![0, 2, 0])
                .with_term(0.5, vec![0, 1, 1])
                .with_term(2.0, vec![0, 0, 1]),
        ),
    ];
    for (dim, op) in cases {
        let (mlp, x) = net_and_cloud(dim, 8, 17 + dim as u64);
        let truth = exact_mean(&op, &mlp, &x);
        let n = 2000;
        let cfg = StdeConfig { seed: 101, samples: 1, antithetic: false };
        let means = estimate_means(&op, &mlp, &x, cfg, n);
        let m = mean(&means);
        let stderr = (variance(&means) / n as f64).sqrt();
        assert!(
            (m - truth).abs() <= 6.0 * stderr + 1e-12,
            "d={dim}: empirical mean {m} vs exact {truth} exceeds 6 standard errors ({stderr})"
        );
    }
}

// ----------------------------------------------------- variance decay

/// Var[estimate] ~ 1/K: independent-draw term subsampling halves the
/// variance when K doubles. `K·Var_K` stays inside a 2x bracket of the
/// K=1 variance across K = 1, 2, 4, 8.
#[test]
fn stde_variance_decays_like_one_over_k() {
    let op = DiffOperator::new(2)
        .with_term(1.0, vec![2, 0])
        .with_term(4.0, vec![0, 2])
        .with_term(-2.0, vec![1, 1]);
    let (mlp, x) = net_and_cloud(2, 4, 23);
    let n = 1500;
    let var_of = |k: usize| {
        let cfg = StdeConfig { seed: 7, samples: k, antithetic: false };
        variance(&estimate_means(&op, &mlp, &x, cfg, n))
    };
    let v1 = var_of(1);
    assert!(v1 > 0.0, "a 3-term operator subsampled at K=1 must fluctuate");
    for k in [2usize, 4, 8] {
        let scaled = k as f64 * var_of(k);
        let ratio = v1 / scaled;
        assert!(
            (0.5..=2.0).contains(&ratio),
            "K={k}: K*Var_K = {scaled} vs Var_1 = {v1} breaks the 1/K law (ratio {ratio})"
        );
    }
}

// -------------------------------------------------------- antithetics

/// Antithetic index reflection strictly cuts variance on an asymmetric
/// operator. The T=2, K=2 corner is exact by construction (each pair
/// covers both terms, so every step reproduces the full operator);
/// plain K=2 sampling keeps a strictly positive variance.
#[test]
fn antithetic_pairing_strictly_reduces_variance() {
    let op = DiffOperator::new(2)
        .with_term(1.0, vec![2, 0])
        .with_term(9.0, vec![0, 2]);
    let (mlp, x) = net_and_cloud(2, 4, 31);
    let n = 200;
    let plain = variance(&estimate_means(
        &op,
        &mlp,
        &x,
        StdeConfig { seed: 13, samples: 2, antithetic: false },
        n,
    ));
    let anti = variance(&estimate_means(
        &op,
        &mlp,
        &x,
        StdeConfig { seed: 13, samples: 2, antithetic: true },
        n,
    ));
    assert!(plain > 0.0, "plain K=2 on an asymmetric 2-term operator must fluctuate");
    assert!(
        anti < plain,
        "antithetic variance {anti} not below plain {plain}"
    );
    // With T=2 every antithetic pair is {t, 1-t}: the reweighted
    // operator equals the full operator and the estimator is exact.
    assert!(anti <= 1e-20, "T=2, K=2 antithetic pairs must be exact (variance {anti})");

    // A 3-term asymmetric operator exercises the non-degenerate case:
    // reflection still anticorrelates the draws, variance still drops.
    let op3 = DiffOperator::new(2)
        .with_term(1.0, vec![2, 0])
        .with_term(5.0, vec![1, 1])
        .with_term(25.0, vec![0, 2]);
    let plain3 = variance(&estimate_means(
        &op3,
        &mlp,
        &x,
        StdeConfig { seed: 19, samples: 2, antithetic: false },
        n,
    ));
    let anti3 = variance(&estimate_means(
        &op3,
        &mlp,
        &x,
        StdeConfig { seed: 19, samples: 2, antithetic: true },
        n,
    ));
    assert!(
        anti3 < plain3,
        "3-term antithetic variance {anti3} not below plain {plain3}"
    );
}

// ------------------------------------------------- per-sample corners

/// Per-sample exactness: only term *selection* is random — each
/// sampled term's factors recombine exactly. A single-term operator is
/// therefore reproduced to 1e-10 by every draw, including a nonlinear
/// product term, and a Horvitz–Thompson reweighting that happens to
/// cover every term once equals the exact operator.
#[test]
fn every_sample_is_exact_on_its_terms() {
    // One linear mixed term: every K=1 draw must be exact.
    let op = DiffOperator::new(2).with_term(3.0, vec![1, 1]);
    let (mlp, x) = net_and_cloud(2, 6, 41);
    let engine = MultiJetEngine::new(2, 2);
    let exact = op.apply(&engine.jet(&mlp, &x));
    let est = StdeEngine::new(op.clone(), StdeConfig { seed: 3, samples: 1, antithetic: false });
    for step in 0..5u64 {
        let e = est.estimate(&mlp, &x, step);
        for (i, (&a, &b)) in e.values.data().iter().zip(exact.data()).enumerate() {
            assert!(
                (a - b).abs() <= 1e-10 * (1.0 + b.abs()),
                "step {step}, row {i}: estimate {a} vs exact {b}"
            );
        }
    }

    // A nonlinear product term (u_x · u_y): factor products are exact too.
    let op = DiffOperator::new(2).with_product(2.0, vec![vec![1, 0], vec![0, 1]]);
    let exact = op.apply(&engine.jet(&mlp, &x));
    let est = StdeEngine::new(op.clone(), StdeConfig { seed: 5, samples: 2, antithetic: false });
    let e = est.estimate(&mlp, &x, 0);
    for (i, (&a, &b)) in e.values.data().iter().zip(exact.data()).enumerate() {
        assert!(
            (a - b).abs() <= 1e-10 * (1.0 + b.abs()),
            "row {i}: nonlinear estimate {a} vs exact {b}"
        );
    }

    // A draw covering every term once reweights back to the exact
    // operator (mult = 1, scale = T/K = 1).
    let op = DiffOperator::new(2)
        .with_term(1.0, vec![2, 0])
        .with_term(-2.0, vec![0, 2]);
    let sop = sampled_operator(&op, &[0, 1]);
    assert_eq!(sop, op);

    // sample_terms itself: K draws, all in range, antithetic pairs
    // reflected.
    let cfg = StdeConfig { seed: 9, samples: 6, antithetic: true };
    let draws = sample_terms(&cfg, 5, 0, 0);
    assert_eq!(draws.len(), 6);
    for pair in draws.chunks(2) {
        assert!(pair[0] < 5 && pair[1] < 5);
        assert_eq!(pair[1], 4 - pair[0], "antithetic partner must be index-reflected");
    }
}

/// The committed mpmath golden (`fixture_multi.rs`, OP4 block): the 4-D
/// Laplacian on the pinned net and points, reproduced to 1e-10 by both
/// the exact directional oracle and a full-coverage STDE draw pushed
/// through the factor-wise sparse plan — for every registered
/// activation tower.
#[test]
fn four_d_pure_axis_operator_matches_the_mpmath_golden() {
    let dim = OP4_SIZES[0];
    let op = DiffOperator::laplacian(dim);
    let x = Tensor::from_vec(
        OP4_X.iter().flat_map(|p| p.iter().copied()).collect(),
        &[OP4_X.len(), dim],
    );
    let theta = Tensor::from_vec(OP4_THETA.to_vec(), &[OP4_THETA.len()]);
    let oracle = MultiJetEngine::new(dim, op.max_order());
    for kind in ActivationKind::ALL {
        let mut mlp = Mlp::with_activation(&OP4_SIZES, kind, &mut Prng::seeded(0));
        params::unflatten_into(&mut mlp, &theta);
        let exact = op.apply(&oracle.jet(&mlp, &x));
        // A draw covering each of the 4 terms once reweights to the full
        // operator; apply_sampled routes it through the sparse pool.
        let est =
            StdeEngine::new(op.clone(), StdeConfig { seed: 1, samples: 4, antithetic: false });
        let stde = est.apply_sampled(&mlp, &x, &sampled_operator(&op, &[0, 1, 2, 3]));
        assert_eq!(stde.n_directions, 4, "one direction per pure axis");
        for (p, &want) in OP4_LAPLACIAN[kind.index()].iter().enumerate() {
            let tol = 1e-10 * (1.0 + want.abs());
            let (e, s) = (exact.data()[p], stde.values.data()[p]);
            assert!(
                (e - want).abs() <= tol,
                "{}: exact {e:.17e} vs golden {want:.17e} at point {p}",
                kind.name()
            );
            assert!(
                (s - want).abs() <= tol,
                "{}: stde {s:.17e} vs golden {want:.17e} at point {p}",
                kind.name()
            );
        }
    }
}
