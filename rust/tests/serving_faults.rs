//! Fault injection for the serving stack: misbehaving clients, protocol
//! violations, overload shedding, and shutdown under pipelined load.
//!
//! Every test drives the production `serve_tcp_with` stack over real
//! loopback TCP. The invariant under attack: a hostile or unlucky
//! client may get an error reply or a closed connection, but never a
//! hang, a panic, or a silently dropped in-flight request — and never
//! degraded service for *other* connections.

use ntangent::coordinator::{
    protocol, serve_tcp_with, BatcherConfig, EvalBackend, NativeBackend, OperatorServer, Service,
    ServiceHandle, TcpClient,
};
use ntangent::nn::Mlp;
use ntangent::ntp::{ActivationKind, ParallelPolicy};
use ntangent::util::json::Json;
use ntangent::util::prng::Prng;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A backend that sleeps per batch — makes queue-full windows and
/// shutdown races deterministic enough to provoke on one core.
struct SlowBackend {
    delay: Duration,
}

impl EvalBackend for SlowBackend {
    fn max_batch(&self) -> usize {
        4
    }
    fn n_channels(&self) -> usize {
        2
    }
    fn eval_batch(&mut self, xs: &[f64]) -> anyhow::Result<Vec<Vec<f64>>> {
        std::thread::sleep(self.delay);
        Ok(vec![xs.to_vec(), xs.iter().map(|x| 2.0 * x).collect()])
    }
}

fn native_service() -> (Service, Mlp) {
    let mut rng = Prng::seeded(41);
    let mlp = Mlp::uniform(1, 8, 2, 1, &mut rng);
    let backend = mlp.clone();
    let service = Service::start(
        move || Ok(Box::new(NativeBackend::new(backend, 2, 32)) as Box<dyn EvalBackend>),
        BatcherConfig::default(),
    );
    (service, mlp)
}

fn slow_service(delay_ms: u64, queue_depth: usize) -> Service {
    Service::start(
        move || {
            Ok(Box::new(SlowBackend {
                delay: Duration::from_millis(delay_ms),
            }) as Box<dyn EvalBackend>)
        },
        BatcherConfig {
            queue_depth,
            shed_retry_ms: 5,
            ..BatcherConfig::default()
        },
    )
}

/// Bind a loopback endpoint serving `handle` (operator front optional).
fn spawn_server(handle: ServiceHandle, ops: Option<Arc<OperatorServer>>) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || serve_tcp_with(listener, handle, ops));
    addr
}

fn timed_client(addr: &str) -> TcpClient {
    let client = TcpClient::connect(addr).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    client
}

/// Raw framed write: magic byte + u32 BE length + payload bytes.
fn raw_frame(payload: &[u8]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(5 + payload.len());
    buf.push(protocol::FRAME_MAGIC);
    buf.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    buf.extend_from_slice(payload);
    buf
}

/// A client that disconnects mid-frame must not disturb the service:
/// later connections (and concurrent ones) are served normally.
#[test]
fn mid_request_disconnect_leaves_server_healthy() {
    let (service, _) = native_service();
    let addr = spawn_server(service.handle(), None);

    for cut in [1usize, 3, 7] {
        let mut s = TcpStream::connect(&addr).unwrap();
        let frame = raw_frame(b"{\"points\": [0.25]}");
        s.write_all(&frame[..cut.min(frame.len() - 1)]).unwrap();
        drop(s); // disconnect with a partial frame on the wire
    }
    // Also: a full request whose connection dies before reading the
    // reply (the response write hits a closed socket).
    let mut s = TcpStream::connect(&addr).unwrap();
    s.write_all(&raw_frame(b"{\"points\": [0.5]}")).unwrap();
    drop(s);

    std::thread::sleep(Duration::from_millis(50));
    let mut client = timed_client(&addr);
    let channels = client.eval(&[0.1, 0.2]).unwrap();
    assert_eq!(channels.len(), 3);
    assert_eq!(channels[0].len(), 2);
    service.shutdown();
}

/// A stalled client (floods requests, never reads) only stalls itself:
/// a concurrent well-behaved connection keeps getting answers.
#[test]
fn stalled_client_does_not_block_others() {
    let (service, _) = native_service();
    let addr = spawn_server(service.handle(), None);

    // The stalled client: pipeline a pile of requests, read nothing.
    let mut stalled = TcpClient::connect(&addr).unwrap();
    for i in 0..200 {
        stalled.submit_eval(&[i as f64 * 0.01], None).unwrap();
    }
    // (Never recv; the connection writer may block on its socket
    // buffer, which must not affect anyone else.)

    let mut client = timed_client(&addr);
    let t0 = Instant::now();
    for i in 0..20 {
        let channels = client.eval(&[i as f64 * 0.05]).unwrap();
        assert_eq!(channels.len(), 3);
    }
    assert!(
        t0.elapsed() < Duration::from_secs(15),
        "well-behaved client starved behind a stalled one"
    );
    drop(stalled);
    service.shutdown();
}

/// An oversized frame declaration is answered with a protocol error
/// (without reading the payload) and the connection is closed.
#[test]
fn oversized_frame_is_rejected_with_an_error() {
    let (service, _) = native_service();
    let addr = spawn_server(service.handle(), None);

    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    let mut header = vec![protocol::FRAME_MAGIC];
    header.extend_from_slice(&((protocol::MAX_FRAME_LEN as u32) + 1).to_be_bytes());
    s.write_all(&header).unwrap();

    let mut reply = Vec::new();
    s.read_to_end(&mut reply).unwrap(); // reply then EOF (server closes)
    let text = String::from_utf8_lossy(&reply);
    let body = text
        .trim_start_matches(|c: char| c as u32 == protocol::FRAME_MAGIC as u32)
        .to_string();
    // Strip the reply's own frame header (magic + 4 length bytes).
    let json_start = body.find('{').expect("an error reply before close");
    let (msg, retry) = protocol::parse_error(&body[json_start..]).expect("an error payload");
    assert!(msg.contains("frame"), "unexpected error: {msg}");
    assert!(retry.is_none());
    service.shutdown();
}

/// A truncated frame (length promises more bytes than ever arrive)
/// ends in a clean close, and the endpoint stays healthy.
#[test]
fn truncated_frame_closes_cleanly() {
    let (service, _) = native_service();
    let addr = spawn_server(service.handle(), None);

    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    let mut msg = vec![protocol::FRAME_MAGIC];
    msg.extend_from_slice(&200u32.to_be_bytes());
    msg.extend_from_slice(b"{\"points\""); // 9 of the promised 200 bytes
    s.write_all(&msg).unwrap();
    s.shutdown(std::net::Shutdown::Write).unwrap();
    let mut reply = Vec::new();
    s.read_to_end(&mut reply).unwrap();
    assert!(reply.is_empty(), "truncated frame should close silently");

    let mut client = timed_client(&addr);
    assert_eq!(client.eval(&[0.3]).unwrap().len(), 3);
    service.shutdown();
}

/// Garbage JSON (framed or line-delimited) gets an error reply and the
/// connection keeps working for subsequent valid requests.
#[test]
fn garbage_json_gets_error_and_connection_survives() {
    let (service, _) = native_service();
    let addr = spawn_server(service.handle(), None);
    let mut client = timed_client(&addr);

    for garbage in ["{not json", "[1,2,3]", "{\"points\": \"nope\"}", "{}"] {
        client.submit_raw(garbage).unwrap();
        let reply = client.recv_raw().unwrap();
        assert!(
            protocol::parse_error(&reply).is_some(),
            "expected an error for {garbage:?}, got {reply}"
        );
    }
    // The same connection still serves valid traffic.
    assert_eq!(client.eval(&[0.4]).unwrap().len(), 3);
    service.shutdown();
}

/// Overload: a slow backend behind a depth-1 queue sheds the excess
/// with `{"error":"overloaded","retry_ms":…}`, the shed counter moves,
/// and honoring retry_ms eventually lands every request.
#[test]
fn shed_and_retry_roundtrip() {
    let service = slow_service(60, 1);
    let handle = service.handle();
    let addr = spawn_server(handle.clone(), None);
    let mut client = timed_client(&addr);

    let n = 16;
    for i in 0..n {
        client.submit_eval(&[i as f64], None).unwrap();
    }
    let mut served = 0usize;
    let mut shed_retry = Vec::new();
    for _ in 0..n {
        let reply = client.recv_raw().unwrap();
        match protocol::parse_error(&reply) {
            None => served += 1,
            Some((msg, retry)) => {
                assert_eq!(msg, "overloaded");
                shed_retry.push(retry.expect("shed replies carry retry_ms"));
            }
        }
    }
    assert!(served >= 1, "at least the queued request must be served");
    assert!(
        !shed_retry.is_empty(),
        "a depth-1 queue behind a 60ms backend must shed a 16-deep burst"
    );
    assert!(handle.metrics().shed >= shed_retry.len() as u64);

    // Retrying after the advertised back-off eventually succeeds.
    for &retry_ms in &shed_retry {
        let mut ok = false;
        for _ in 0..200 {
            std::thread::sleep(Duration::from_millis(retry_ms.max(1)));
            client.submit_eval(&[0.5], None).unwrap();
            if protocol::parse_error(&client.recv_raw().unwrap()).is_none() {
                ok = true;
                break;
            }
        }
        assert!(ok, "retry never succeeded");
    }
    service.shutdown();
}

/// A scripted server for the client-side shed-retry tests: answers the
/// first `sheds` requests with `{"error":"overloaded","retry_ms":…}`,
/// then (optionally) a real channels reply, and returns every request
/// payload it saw so the test can assert resubmissions are identical.
fn scripted_shed_server(
    sheds: usize,
    then_serve: bool,
) -> (String, std::thread::JoinHandle<Vec<String>>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        let total = sheds + usize::from(then_serve);
        let mut seen = Vec::new();
        for i in 0..total {
            let msg = match protocol::read_message(&mut reader).unwrap() {
                protocol::Incoming::Frame(s) | protocol::Incoming::Line(s) => s,
                protocol::Incoming::Eof => panic!("client hung up after {i} requests"),
            };
            seen.push(msg);
            let reply = if i < sheds {
                protocol::encode_shed(2)
            } else {
                protocol::encode_channels(&[vec![1.0], vec![2.0]])
            };
            protocol::write_frame(&mut writer, &reply).unwrap();
            writer.flush().unwrap();
        }
        seen
    });
    (addr, server)
}

/// `TcpClient::eval_with_retry` absorbs shed replies per the contract:
/// deterministic `retry_ms · attempt` back-off, identical resubmission,
/// counted retries, and the eventual real answer.
#[test]
fn eval_with_retry_honors_the_shed_contract() {
    let (addr, server) = scripted_shed_server(3, true);
    let mut client = timed_client(&addr);
    let t0 = Instant::now();
    let channels = client.eval_with_retry(&[0.25], None, 8).unwrap();
    assert_eq!(channels, vec![vec![1.0], vec![2.0]]);
    assert_eq!(client.shed_retries(), 3);
    // Jitterless back-off: 2·1 + 2·2 + 2·3 = 12 ms before the answer.
    assert!(t0.elapsed() >= Duration::from_millis(12));
    let seen = server.join().unwrap();
    assert_eq!(seen.len(), 4);
    assert!(
        seen.windows(2).all(|w| w[0] == w[1]),
        "resubmissions must be byte-identical: {seen:?}"
    );
}

/// Bounded retries: once `max_retries` sheds are absorbed, the next
/// shed surfaces as the error instead of looping forever.
#[test]
fn eval_with_retry_gives_up_after_max_retries() {
    let (addr, server) = scripted_shed_server(3, false);
    let mut client = timed_client(&addr);
    let err = client.eval_with_retry(&[0.5], None, 2).unwrap_err();
    assert!(format!("{err:#}").contains("overloaded"), "got: {err:#}");
    assert_eq!(client.shed_retries(), 2);
    assert_eq!(server.join().unwrap().len(), 3);
}

/// The satellite-fix regression: shutting down with a window of
/// pipelined requests in flight answers every one of them — drained
/// results or clean shutdown errors, never silence or a hang.
#[test]
fn shutdown_under_pipelined_load_answers_every_request() {
    let service = slow_service(10, 64);
    let addr = spawn_server(service.handle(), None);
    let mut client = timed_client(&addr);

    let n = 48;
    for i in 0..n {
        client.submit_eval(&[i as f64 * 0.1], None).unwrap();
    }
    // Shut down while the window is in flight.
    let shutdown = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(25));
        service.shutdown();
    });
    let mut answered = 0usize;
    let mut served = 0usize;
    let mut shutdown_errors = 0usize;
    for _ in 0..n {
        let reply = client.recv_raw().expect("every pipelined request gets a reply");
        answered += 1;
        match protocol::parse_error(&reply) {
            None => served += 1,
            Some((msg, _)) => {
                assert!(
                    msg.contains("shut down") || msg == "overloaded",
                    "unexpected error under shutdown: {msg}"
                );
                shutdown_errors += 1;
            }
        }
    }
    shutdown.join().unwrap();
    assert_eq!(answered, n);
    assert_eq!(served + shutdown_errors, n);
    assert!(served >= 1, "drain-on-shutdown should serve the queued prefix");
}

/// Requests racing a completed shutdown get clean errors (wire path).
#[test]
fn requests_after_shutdown_get_clean_errors() {
    let (service, _) = native_service();
    let addr = spawn_server(service.handle(), None);
    let mut client = timed_client(&addr);
    assert_eq!(client.eval(&[0.2]).unwrap().len(), 3);
    service.shutdown();
    client.submit_eval(&[0.3], None).unwrap();
    let reply = client.recv_raw().unwrap();
    let (msg, _) = protocol::parse_error(&reply).expect("an error after shutdown");
    assert!(msg.contains("shut down"), "got: {msg}");
}

/// 30-second mixed-traffic soak (run via `--ignored` in CI's stress
/// job): pipelined clients with random disconnects, all four
/// activation towers, dim-1 operator requests and stats probes; on
/// every gracefully drained connection received == sent, and metrics
/// counters are monotone throughout.
#[test]
#[ignore]
fn soak_mixed_traffic_for_30s() {
    let (service, _) = native_service();
    let handle = service.handle();
    let mut rng = Prng::seeded(4242);
    let op_mlp = Mlp::uniform(1, 8, 2, 1, &mut rng);
    let ops = Arc::new(
        OperatorServer::new(op_mlp, ParallelPolicy::Serial)
            .with_metrics(handle.metrics_handle()),
    );
    let addr = spawn_server(handle.clone(), Some(ops));
    let deadline = Instant::now() + Duration::from_secs(30);

    let mut workers = Vec::new();
    for t in 0..2u64 {
        let addr = addr.clone();
        workers.push(std::thread::spawn(move || {
            let mut rng = Prng::seeded(900 + t);
            let mut sent_total = 0u64;
            let mut received_total = 0u64;
            let mut errors = 0u64;
            while Instant::now() < deadline {
                // One connection "segment": pipeline a random burst,
                // drain it fully, then (randomly) reconnect.
                let mut client = timed_client(&addr);
                let burst = 20 + rng.below(60) as usize;
                let mut sent = 0usize;
                for _ in 0..burst {
                    let kind = rng.below(10);
                    let ok = if kind < 6 {
                        let pts: Vec<f64> = (0..4).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
                        let act = match rng.below(5) {
                            0 => None,
                            i => Some(ActivationKind::ALL[(i - 1) as usize]),
                        };
                        client.submit_eval(&pts, act).is_ok()
                    } else if kind < 9 {
                        let pts: Vec<Vec<f64>> =
                            (0..3).map(|_| vec![rng.uniform_in(-1.0, 1.0)]).collect();
                        client.submit_operator(&pts, "d2", None).is_ok()
                    } else {
                        client.submit_raw("{\"cmd\":\"stats\"}").is_ok()
                    };
                    if ok {
                        sent += 1;
                    }
                }
                for _ in 0..sent {
                    match client.recv_raw() {
                        Ok(reply) => {
                            received_total += 1;
                            if protocol::parse_error(&reply).is_some() {
                                errors += 1;
                            }
                        }
                        Err(e) => panic!("pipelined reply dropped: {e}"),
                    }
                }
                sent_total += sent as u64;
                // Every segment tears its connection down after
                // draining, exercising reconnect churn under load.
                drop(client);
            }
            (sent_total, received_total, errors)
        }));
    }

    // Metrics monotonicity probe alongside the load.
    let mut last = (0u64, 0u64, 0u64, 0u64);
    while Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(1500));
        let s = handle.metrics();
        let now = (s.requests, s.errors, s.plan_hits + s.plan_misses, s.shed);
        assert!(
            now.0 >= last.0 && now.1 >= last.1 && now.2 >= last.2 && now.3 >= last.3,
            "metrics went backwards: {last:?} -> {now:?}"
        );
        last = now;
    }

    let mut grand_sent = 0u64;
    let mut grand_received = 0u64;
    for w in workers {
        let (sent, received, errors) = w.join().expect("soak worker panicked");
        assert_eq!(sent, received, "dropped responses under soak");
        assert_eq!(errors, 0, "unexpected error replies under soak");
        grand_sent += sent;
        grand_received += received;
    }
    assert!(grand_sent > 0 && grand_sent == grand_received);

    // Final stats sanity: the counters parse and cover the traffic.
    let mut client = timed_client(&addr);
    let stats = client.stats().unwrap();
    let doc = Json::parse(&stats).unwrap();
    let served = doc
        .get("stats")
        .and_then(|s| s.get("requests"))
        .and_then(Json::as_f64)
        .unwrap_or(0.0);
    assert!(served > 0.0);
    service.shutdown();
}
