//! The repository's central guarantee, tested end to end at scale:
//! n-TangentProp is EXACT — it computes the same derivatives as repeated
//! autodifferentiation, for every architecture/batch/order combination,
//! and the PINN losses built on top of either engine agree to machine
//! precision.

use ntangent::autodiff::{higher, Graph};
use ntangent::nn::Mlp;
use ntangent::ntp::{ActivationKind, NtpEngine, SmoothActivation, Tanh};
use ntangent::pinn::BurgersProfile;
use ntangent::tensor::Tensor;
use ntangent::util::prng::Prng;
use ntangent::util::{allclose_slice, ptest};

#[test]
fn exactness_across_architectures_orders_and_activations() {
    // Wider sweep than the unit tests: deeper nets, higher orders, and
    // every registered activation.
    ptest::check(
        ptest::Config { cases: 40, seed: 0xE0E0 },
        |rng: &mut Prng| {
            let width = 2 + rng.below(30) as usize;
            let depth = 1 + rng.below(4) as usize;
            let batch = 1 + rng.below(8) as usize;
            let n = 1 + rng.below(7) as usize;
            let kind = ActivationKind::ALL[rng.below(ActivationKind::ALL.len() as u64) as usize];
            let mlp = Mlp::uniform_with(1, width, depth, 1, kind, rng);
            let x = Tensor::rand_uniform(&[batch, 1], -2.0, 2.0, rng);
            (mlp, x, n)
        },
        |(mlp, x, n)| {
            let engine = NtpEngine::new(*n);
            let ntp = engine.forward(mlp, x);
            let mut g = Graph::new();
            let xn = g.input(x.shape());
            let pn = mlp.const_param_nodes(&mut g);
            let u = mlp.forward_graph(&mut g, xn, &pn);
            let stack = higher::derivative_stack(&mut g, u, xn, *n);
            let vals = g.eval(&[x.clone()], &stack);
            for order in 0..=*n {
                if !allclose_slice(
                    ntp[order].data(),
                    vals.get(stack[order]).data(),
                    1e-7,
                    1e-8,
                ) {
                    return Err(format!(
                        "{} order {order} mismatch (n={n})",
                        mlp.activation.name()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Acceptance criterion, spelled out per activation: the n-TP forward
/// stack matches the repeated-autodiff stack to 1e-7 relative tolerance
/// at orders 0..=6 on randomized architectures.
#[test]
fn every_activation_matches_autodiff_to_order_6() {
    for kind in ActivationKind::ALL {
        ptest::check(
            ptest::Config { cases: 8, seed: 0xAC70 + kind.index() as u64 },
            |rng: &mut Prng| {
                let width = 2 + rng.below(16) as usize;
                let depth = 1 + rng.below(3) as usize;
                let batch = 1 + rng.below(4) as usize;
                let mlp = Mlp::uniform_with(1, width, depth, 1, kind, rng);
                let x = Tensor::rand_uniform(&[batch, 1], -1.5, 1.5, rng);
                (mlp, x)
            },
            |(mlp, x)| {
                let n = 6;
                let engine = NtpEngine::new(n);
                let ntp = engine.forward(mlp, x);
                let mut g = Graph::new();
                let xn = g.input(x.shape());
                let pn = mlp.const_param_nodes(&mut g);
                let u = mlp.forward_graph(&mut g, xn, &pn);
                let stack = higher::derivative_stack(&mut g, u, xn, n);
                let vals = g.eval(&[x.clone()], &stack);
                for order in 0..=n {
                    if !allclose_slice(
                        ntp[order].data(),
                        vals.get(stack[order]).data(),
                        1e-7,
                        1e-8,
                    ) {
                        return Err(format!("{} order {order} mismatch", kind.name()));
                    }
                }
                Ok(())
            },
        );
    }
}

#[test]
fn non_uniform_architectures() {
    // Varying widths per layer (the formalism doesn't require uniformity).
    let mut rng = Prng::seeded(0xF1);
    for sizes in [
        vec![1usize, 7, 3, 1],
        vec![1, 3, 17, 5, 1],
        vec![1, 2, 2, 2, 2, 1],
    ] {
        let mlp = Mlp::new(&sizes, &mut rng);
        let x = Tensor::linspace(-1.0, 1.0, 6).reshape(&[6, 1]);
        let n = 4;
        let ntp = NtpEngine::new(n).forward(&mlp, &x);
        let mut g = Graph::new();
        let xn = g.input(x.shape());
        let pn = mlp.const_param_nodes(&mut g);
        let u = mlp.forward_graph(&mut g, xn, &pn);
        let stack = higher::derivative_stack(&mut g, u, xn, n);
        let vals = g.eval(&[x.clone()], &stack);
        for order in 0..=n {
            assert!(
                allclose_slice(ntp[order].data(), vals.get(stack[order]).data(), 1e-8, 1e-9),
                "sizes {sizes:?} order {order}"
            );
        }
    }
}

#[test]
fn tanh_tower_against_independent_sine_composition() {
    // Independent oracle: compose tanh∘sin with Faà di Bruno scalar tables
    // and compare to autodiff of tanh(sin x).
    let fdb = ntangent::ntp::FaaDiBruno::new(6);
    let tanh = Tanh::new(6);
    let sine = ntangent::ntp::Sine;
    let mut g = Graph::new();
    let x = g.input(&[5, 1]);
    // tanh(sin(x)) via tape: sin not a primitive, so use tanh(tanh(x))
    // instead — both smooth compositions.
    let inner = g.tanh(x);
    let u = g.tanh(inner);
    let stack = higher::derivative_stack(&mut g, u, x, 6);
    let xv = Tensor::linspace(-1.2, 1.2, 5).reshape(&[5, 1]);
    let vals = g.eval(&[xv.clone()], &stack);
    for (i, &xi) in xv.data().iter().enumerate() {
        let g_tower = tanh.tower_scalar(xi, 6); // inner tanh derivatives
        let f_tower = tanh.tower_scalar(xi.tanh(), 6); // outer at tanh(x)
        for n in 1..=6 {
            let expect = fdb.compose_scalar(n, &f_tower, &g_tower);
            let got = vals.get(stack[n]).data()[i];
            let tol = 1e-8 * expect.abs().max(1.0);
            assert!(
                (got - expect).abs() < tol,
                "n={n} x={xi}: {got} vs {expect}"
            );
        }
    }
    let _ = sine; // sine used elsewhere; silence potential dead import
}

#[test]
fn burgers_residual_vanishes_for_exact_channels_any_profile() {
    // Feed the exact derivative channels through the tape residual and
    // check all Sobolev orders vanish — ties ground truth, tape ops and
    // the Leibniz expansion together.
    for k in 1..=4usize {
        let profile = BurgersProfile::new(k);
        let n = profile.n_derivs();
        let xs: Vec<f64> = vec![-1.1, -0.3, 0.45, 1.7];
        let mut g = Graph::new();
        let chans: Vec<_> = (0..=n)
            .map(|order| {
                let col: Vec<f64> = xs
                    .iter()
                    .map(|&x| profile.derivatives_true(x, n)[order])
                    .collect();
                g.constant(Tensor::from_vec(col, &[xs.len(), 1]))
            })
            .collect();
        let xn = g.constant(Tensor::from_vec(xs.clone(), &[xs.len(), 1]));
        let lam = g.constant(Tensor::scalar(profile.lambda_smooth()));
        let r = ntangent::pinn::residual_derivative_nodes(&mut g, &chans, xn, lam, n - 1);
        let vals = g.eval(&[], &r);
        for (j, &rid) in r.iter().enumerate() {
            let worst = vals.get(rid).max_abs();
            // Higher residual orders involve U^{(j+1)} ~ (j+1)! near ±1;
            // scale tolerance accordingly.
            let scale: f64 = (1..=(j + 2)).map(|v| v as f64).product();
            assert!(
                worst < 1e-6 * scale.max(1.0),
                "k={k} ∂^{j}R = {worst:.3e}"
            );
        }
    }
}

#[test]
fn derivative_magnitude_at_origin_matches_factorial_law() {
    // U^{(2k+1)}(0) = (2k+1)! for C=1 — the quantity the high-order loss
    // term normalizes by; checked here through the full stack.
    for k in 1..=3usize {
        let profile = BurgersProfile::new(k);
        let n = 2 * k + 1;
        let d = profile.derivatives_true(0.0, n);
        let fact: f64 = (1..=n).map(|v| v as f64).product();
        assert!(
            (d[n] / fact - 1.0).abs() < 1e-6,
            "k={k}: {} vs {fact}",
            d[n]
        );
    }
}
