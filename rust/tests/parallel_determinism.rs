//! Determinism/exactness harness for the parallel execution subsystem:
//! chunked multi-threaded `forward_n` must be *bitwise identical* to the
//! serial pass — same per-row float ops, only the scheduling differs —
//! across every registered activation, awkward batch/thread combinations
//! (B not divisible by the chunk count), and repeated mixed-mode calls on
//! one shared engine.

use ntangent::nn::Mlp;
use ntangent::ntp::{ActivationKind, NtpEngine, ParallelPolicy};
use ntangent::tensor::Tensor;
use ntangent::util::prng::Prng;

fn assert_bitwise_eq(want: &[Tensor], got: &[Tensor], ctx: &str) {
    assert_eq!(want.len(), got.len(), "{ctx}: channel count");
    for (k, (a, b)) in want.iter().zip(got).enumerate() {
        // Tensor equality is exact (shape + every f64 bit pattern short
        // of NaN, which the smooth towers never produce).
        assert_eq!(a, b, "{ctx}: channel {k} not bitwise identical");
    }
}

/// 2/4/8 worker threads vs serial, for random batches across all
/// activations, including batches not divisible by the chunk count.
#[test]
fn parallel_forward_is_bitwise_identical_to_serial() {
    for kind in ActivationKind::ALL {
        let mut rng = Prng::seeded(0xD00 + kind.index() as u64);
        let mlp = Mlp::uniform_with(1, 16, 3, 1, kind, &mut rng);
        let serial = NtpEngine::new(5);
        for &batch in &[1usize, 2, 3, 5, 7, 8, 9, 17, 33, 64, 101] {
            let x = Tensor::rand_uniform(&[batch, 1], -1.5, 1.5, &mut rng);
            let want = serial.forward(&mlp, &x);
            for &threads in &[2usize, 4, 8] {
                let engine = NtpEngine::with_policy(5, ParallelPolicy::Fixed(threads));
                let got = engine.forward(&mlp, &x);
                assert_bitwise_eq(
                    &want,
                    &got,
                    &format!("{} B={batch} t={threads}", kind.name()),
                );
            }
        }
    }
}

/// The Auto policy (whatever worker count it picks on this host, small
/// and large batches) is also bitwise-stable.
#[test]
fn auto_policy_is_bitwise_identical_to_serial() {
    let mut rng = Prng::seeded(0xA07);
    for kind in ActivationKind::ALL {
        let mlp = Mlp::uniform_with(1, 12, 2, 1, kind, &mut rng);
        let serial = NtpEngine::new(4);
        let auto = NtpEngine::with_policy(4, ParallelPolicy::Auto);
        for &batch in &[3usize, 64, 700] {
            let x = Tensor::rand_uniform(&[batch, 1], -1.0, 1.0, &mut rng);
            assert_bitwise_eq(
                &serial.forward(&mlp, &x),
                &auto.forward(&mlp, &x),
                &format!("{} auto B={batch}", kind.name()),
            );
        }
    }
}

/// Truncated orders under parallelism: `forward_n` at n < n_max chunks
/// the same way and stays bitwise equal to serial.
#[test]
fn truncated_orders_stay_bitwise_identical() {
    let mut rng = Prng::seeded(0x77AB);
    let mlp = Mlp::uniform(1, 10, 2, 1, &mut rng);
    let serial = NtpEngine::new(6);
    let parallel = NtpEngine::with_policy(6, ParallelPolicy::Fixed(3));
    let x = Tensor::rand_uniform(&[25, 1], -1.2, 1.2, &mut rng);
    for n in 0..=6 {
        assert_bitwise_eq(
            &serial.forward_n(&mlp, &x, n),
            &parallel.forward_n(&mlp, &x, n),
            &format!("n={n}"),
        );
    }
}

/// One engine, interleaved serial-sized and parallel-sized calls with
/// changing shapes: the scratch pool must not leak state between calls
/// (every call re-checked against a fresh serial engine).
#[test]
fn interleaved_shapes_do_not_leak_scratch_state() {
    let engine = NtpEngine::with_policy(4, ParallelPolicy::Fixed(4));
    for (seed, width, batch) in [
        (1u64, 6usize, 2usize),
        (2, 12, 61),
        (3, 6, 2),
        (4, 8, 33),
        (5, 12, 4),
    ] {
        let mut rng = Prng::seeded(seed);
        let mlp = Mlp::uniform(1, width, 2, 1, &mut rng);
        let x = Tensor::rand_uniform(&[batch, 1], -1.0, 1.0, &mut rng);
        let got = engine.forward(&mlp, &x);
        let want = NtpEngine::new(4).forward(&mlp, &x);
        assert_bitwise_eq(&want, &got, &format!("seed={seed} B={batch}"));
    }
}

/// Thread counts exceeding the batch (more workers than rows) clamp
/// instead of panicking, and still produce identical output.
#[test]
fn more_threads_than_rows_is_safe_and_identical() {
    let mut rng = Prng::seeded(0xBEEF);
    let mlp = Mlp::uniform(1, 8, 2, 1, &mut rng);
    let serial = NtpEngine::new(3);
    let parallel = NtpEngine::with_policy(3, ParallelPolicy::Fixed(64));
    for batch in [1usize, 2, 5] {
        let x = Tensor::rand_uniform(&[batch, 1], -1.0, 1.0, &mut rng);
        assert_bitwise_eq(
            &serial.forward(&mlp, &x),
            &parallel.forward(&mlp, &x),
            &format!("B={batch} t=64"),
        );
    }
}
