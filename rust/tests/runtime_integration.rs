//! Integration: the AOT bridge end to end.
//!
//! Loads the HLO artifacts produced by `make artifacts`, executes them on
//! the PJRT CPU client, and checks the numerics against the native Rust
//! n-TangentProp engine — the cross-language exactness guarantee.
//!
//! Requires `make artifacts`; tests are skipped (with a message) when the
//! bundle is missing so `cargo test` still works on a fresh checkout.

use ntangent::nn::{params, Mlp};
use ntangent::ntp::NtpEngine;
use ntangent::runtime::{ArtifactManifest, Runtime};
use ntangent::tensor::Tensor;
use ntangent::util::prng::Prng;
use std::path::Path;

fn manifest() -> Option<ArtifactManifest> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match ArtifactManifest::load(&dir) {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("skipping runtime integration test: run `make artifacts` first");
            None
        }
    }
}

/// Build an MLP matching the artifact spec and its flat theta.
fn mlp_for(spec_sizes: &[usize], seed: u64) -> (Mlp, Tensor) {
    let mut rng = Prng::seeded(seed);
    let mlp = Mlp::new(spec_sizes, &mut rng);
    let theta = params::flatten(&mlp);
    (mlp, theta)
}

#[test]
fn ntp_fwd_artifact_matches_native_engine() {
    let Some(manifest) = manifest() else { return };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    for name in ["ntp_fwd_d3", "ntp_fwd_d7"] {
        let spec = manifest.get(name).unwrap();
        let n = spec.n_derivs.unwrap();
        let batch = spec.batch.unwrap();
        let exe = rt.load_hlo_text(&manifest.path_of(spec)).unwrap();

        let (mlp, theta) = mlp_for(&spec.sizes, 0xA0 + n as u64);
        let mut rng = Prng::seeded(7);
        let x = Tensor::rand_uniform(&[batch, 1], -1.5, 1.5, &mut rng);

        let out = exe.run(&[theta.clone(), x.clone()]).unwrap();
        assert_eq!(out.len(), 1, "{name}");
        let stacked = &out[0];
        assert_eq!(stacked.shape(), &[n + 1, batch], "{name}");

        let native = NtpEngine::new(n).forward(&mlp, &x);
        for order in 0..=n {
            let pjrt_row = &stacked.data()[order * batch..(order + 1) * batch];
            let nat = native[order].data();
            for (i, (a, b)) in pjrt_row.iter().zip(nat).enumerate() {
                let tol = 1e-8 * b.abs().max(1.0);
                assert!(
                    (a - b).abs() < tol,
                    "{name} order {order} sample {i}: pjrt {a} vs native {b}"
                );
            }
        }
    }
}

#[test]
fn autodiff_artifact_matches_ntp_artifact() {
    // The exactness claim across engines *and* languages: the JAX
    // nested-grad artifact equals the JAX n-TangentProp artifact.
    let Some(manifest) = manifest() else { return };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let ntp_spec = manifest.get("ntp_fwd_d3").unwrap();
    let ad_spec = manifest.get("autodiff_fwd_d3").unwrap();
    let batch = ntp_spec.batch.unwrap();

    let (_, theta) = mlp_for(&ntp_spec.sizes, 0xB0);
    let mut rng = Prng::seeded(9);
    let x = Tensor::rand_uniform(&[batch, 1], -1.0, 1.0, &mut rng);

    let ntp_exe = rt.load_hlo_text(&manifest.path_of(ntp_spec)).unwrap();
    let ad_exe = rt.load_hlo_text(&manifest.path_of(ad_spec)).unwrap();
    let a = ntp_exe.run(&[theta.clone(), x.clone()]).unwrap();
    let b = ad_exe.run(&[theta, x]).unwrap();
    let (a, b) = (&a[0], &b[0]);
    assert_eq!(a.shape(), b.shape());
    for (i, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
        assert!(
            (x - y).abs() < 1e-8 * y.abs().max(1.0),
            "element {i}: {x} vs {y}"
        );
    }
}

#[test]
fn pinn_vg_artifact_returns_finite_loss_and_grads() {
    let Some(manifest) = manifest() else { return };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let spec = manifest.get("pinn_vg_k1").unwrap();
    let exe = rt.load_hlo_text(&manifest.path_of(spec)).unwrap();

    let (_, theta) = mlp_for(&spec.sizes, 0xC0);
    let m = theta.numel();
    let mut rng = Prng::seeded(11);
    let x_res = Tensor::rand_uniform(&[256, 1], -2.0, 2.0, &mut rng);
    let x_org = Tensor::rand_uniform(&[32, 1], -0.1, 0.1, &mut rng);
    let lam_raw = Tensor::from_vec(vec![0.0], &[]); // scalar

    let out = exe.run(&[theta, lam_raw, x_res, x_org]).unwrap();
    assert_eq!(out.len(), 3, "loss, g_theta, g_lam");
    let loss = out[0].data()[0];
    assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
    assert_eq!(out[1].numel(), m);
    assert!(out[1].data().iter().all(|g| g.is_finite()));
    assert!(out[2].data()[0].is_finite());
    // λ gradient should be non-zero at init (the inverse signal exists).
    assert!(out[2].data()[0].abs() > 0.0);
}

#[test]
fn pjrt_training_step_loop_decreases_loss() {
    // A miniature of the end-to-end story: Rust owns the optimizer, PJRT
    // executes the compiled value+grad, python is nowhere in the loop.
    let Some(manifest) = manifest() else { return };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let spec = manifest.get("pinn_vg_k1").unwrap();
    let exe = rt.load_hlo_text(&manifest.path_of(spec)).unwrap();

    let (_, theta0) = mlp_for(&spec.sizes, 0xD0);
    let m = theta0.numel();
    let mut rng = Prng::seeded(13);
    let x_res = Tensor::rand_uniform(&[256, 1], -2.0, 2.0, &mut rng);
    let x_org = Tensor::rand_uniform(&[32, 1], -0.1, 0.1, &mut rng);

    let mut theta = theta0;
    let mut lam_raw = 0.0f64;
    let mut adam = ntangent::opt::Adam::new(m, 2e-3);
    let mut lam_m = 0.0f64;
    let mut lam_v = 0.0f64;
    let mut first = None;
    let mut last = 0.0;
    for step in 1..=30 {
        let out = exe
            .run(&[
                theta.clone(),
                Tensor::from_vec(vec![lam_raw], &[]),
                x_res.clone(),
                x_org.clone(),
            ])
            .unwrap();
        last = out[0].data()[0];
        first.get_or_insert(last);
        adam.apply(&mut theta, &out[1]);
        // Scalar Adam for λ_raw.
        let g = out[2].data()[0];
        lam_m = 0.9 * lam_m + 0.1 * g;
        lam_v = 0.999 * lam_v + 0.001 * g * g;
        let mh = lam_m / (1.0 - 0.9f64.powi(step));
        let vh = lam_v / (1.0 - 0.999f64.powi(step));
        lam_raw -= 2e-3 * mh / (vh.sqrt() + 1e-8);
    }
    let first = first.unwrap();
    assert!(
        last < first,
        "PJRT training loop did not reduce loss: {first} -> {last}"
    );
}
