//! Coordinator integration: correctness under concurrency, batching
//! behaviour, failure injection, and (when artifacts are present) the
//! PJRT backend through the full service stack.

use ntangent::coordinator::service::TcpClient;
use ntangent::coordinator::{
    BatcherConfig, EvalBackend, NativeBackend, PjrtBackend, Service,
};
use ntangent::nn::{params, Mlp};
use ntangent::ntp::NtpEngine;
use ntangent::runtime::{ArtifactManifest, Runtime};
use ntangent::tensor::Tensor;
use ntangent::util::prng::Prng;
use std::path::Path;
use std::time::Duration;

fn fixture() -> (Mlp, Service) {
    let mut rng = Prng::seeded(0x51);
    let mlp = Mlp::uniform(1, 12, 2, 1, &mut rng);
    let backend_mlp = mlp.clone();
    let service = Service::start(
        move || Ok(Box::new(NativeBackend::new(backend_mlp, 3, 32)) as _),
        BatcherConfig {
            max_wait: Duration::from_micros(500),
        },
    );
    (mlp, service)
}

#[test]
fn heavy_concurrency_every_request_answered_once_correctly() {
    let (mlp, service) = fixture();
    let engine = NtpEngine::new(3);
    let n_threads = 16;
    let reqs_per_thread = 25;
    let mut threads = Vec::new();
    for t in 0..n_threads {
        let handle = service.handle();
        threads.push(std::thread::spawn(move || {
            let mut rng = Prng::seeded(t as u64);
            let mut results = Vec::new();
            for _ in 0..reqs_per_thread {
                let len = 1 + rng.below(40) as usize; // some exceed the cap
                let pts = rng.uniform_vec(len, -1.5, 1.5);
                let channels = handle.eval(&pts).expect("eval failed");
                results.push((pts, channels));
            }
            results
        }));
    }
    let mut total = 0;
    for th in threads {
        for (pts, channels) in th.join().unwrap() {
            let x = Tensor::from_vec(pts.clone(), &[pts.len(), 1]);
            let direct = engine.forward(&mlp, &x);
            assert_eq!(channels.len(), 4);
            for order in 0..=3 {
                assert_eq!(channels[order].len(), pts.len());
                for (a, b) in channels[order].iter().zip(direct[order].data()) {
                    assert!((a - b).abs() < 1e-10, "value corruption");
                }
            }
            total += 1;
        }
    }
    let m = service.handle().metrics();
    assert_eq!(m.requests, total as u64);
    assert_eq!(m.errors, 0);
    assert_eq!(m.points, m.batched_points, "all points must flow through the batcher");
    service.shutdown();
}

#[test]
fn failing_backend_reports_errors_not_hangs() {
    struct Flaky {
        calls: usize,
    }
    impl EvalBackend for Flaky {
        fn max_batch(&self) -> usize {
            8
        }
        fn n_channels(&self) -> usize {
            1
        }
        fn eval_batch(&mut self, xs: &[f64]) -> anyhow::Result<Vec<Vec<f64>>> {
            self.calls += 1;
            if self.calls % 2 == 0 {
                anyhow::bail!("injected failure");
            }
            Ok(vec![xs.to_vec()])
        }
    }
    let service = Service::start(
        move || Ok(Box::new(Flaky { calls: 0 }) as _),
        BatcherConfig::default(),
    );
    let handle = service.handle();
    let mut ok = 0;
    let mut err = 0;
    for _ in 0..10 {
        match handle.eval(&[1.0]) {
            Ok(_) => ok += 1,
            Err(_) => err += 1,
        }
    }
    assert!(ok > 0 && err > 0, "ok={ok} err={err}");
    assert_eq!(handle.metrics().errors as usize, err);
    service.shutdown();
}

#[test]
fn tcp_malformed_requests_get_error_replies() {
    let (_, service) = fixture();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let handle = service.handle();
    std::thread::spawn(move || ntangent::coordinator::service::serve_tcp(listener, handle));

    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    for bad in ["garbage", "{\"points\":[]}", "{\"cmd\":\"nope\"}"] {
        writer.write_all(bad.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"error\""), "reply to {bad}: {line}");
    }
    // Connection still usable afterwards.
    let mut client = TcpClient::connect(&addr).unwrap();
    assert!(client.eval(&[0.5]).is_ok());
    service.shutdown();
}

#[test]
fn pjrt_backend_through_service_matches_native() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if ArtifactManifest::load(&dir).is_err() {
        eprintln!("skipping pjrt service test: run `make artifacts`");
        return;
    }
    // The artifact architecture is fixed (1,24,24,24,1); build a matching
    // random parameter vector shared by both paths.
    let mut rng = Prng::seeded(0x77);
    let mlp = Mlp::uniform(1, 24, 3, 1, &mut rng);
    let theta = params::flatten(&mlp);

    let dir2 = dir.clone();
    let theta2 = theta.clone();
    let service = Service::start(
        move || {
            let manifest = ArtifactManifest::load(&dir2)?;
            let spec = manifest.get("ntp_fwd_d3")?.clone();
            let rt = Runtime::cpu()?;
            let exe = rt.load_hlo_text(&manifest.path_of(&spec))?;
            Ok(Box::new(PjrtBackend::new(
                exe,
                theta2,
                spec.batch.unwrap(),
                spec.n_derivs.unwrap(),
            )) as _)
        },
        BatcherConfig::default(),
    );
    let handle = service.handle();
    let pts: Vec<f64> = (0..40).map(|i| -1.0 + i as f64 * 0.05).collect();
    let channels = handle.eval(&pts).expect("pjrt eval");
    let native = NtpEngine::new(3).forward(&mlp, &Tensor::from_vec(pts.clone(), &[40, 1]));
    for order in 0..=3 {
        for (a, b) in channels[order].iter().zip(native[order].data()) {
            assert!(
                (a - b).abs() < 1e-8 * b.abs().max(1.0),
                "order {order}: {a} vs {b}"
            );
        }
    }
    service.shutdown();
}
