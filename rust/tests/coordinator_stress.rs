//! Coordinator integration: correctness under concurrency, batching
//! behaviour, the sharded multi-worker pool under mixed-activation
//! hammering, shutdown/drain semantics, failure injection, and (when
//! artifacts are present) the PJRT backend through the full service
//! stack.

use ntangent::coordinator::service::TcpClient;
use ntangent::coordinator::{
    BatcherConfig, EvalBackend, NativeBackend, PjrtBackend, Service,
};
use ntangent::nn::{params, Mlp};
use ntangent::ntp::{ActivationKind, NtpEngine, ParallelPolicy};
use ntangent::runtime::{ArtifactManifest, Runtime};
use ntangent::tensor::Tensor;
use ntangent::util::prng::Prng;
use std::path::Path;
use std::time::Duration;

fn fixture() -> (Mlp, Service) {
    let mut rng = Prng::seeded(0x51);
    let mlp = Mlp::uniform(1, 12, 2, 1, &mut rng);
    let backend_mlp = mlp.clone();
    let service = Service::start(
        move || Ok(Box::new(NativeBackend::new(backend_mlp, 3, 32)) as _),
        BatcherConfig {
            max_wait: Duration::from_micros(500),
            ..BatcherConfig::default()
        },
    );
    (mlp, service)
}

#[test]
fn heavy_concurrency_every_request_answered_once_correctly() {
    let (mlp, service) = fixture();
    let engine = NtpEngine::new(3);
    let n_threads = 16;
    let reqs_per_thread = 25;
    let mut threads = Vec::new();
    for t in 0..n_threads {
        let handle = service.handle();
        threads.push(std::thread::spawn(move || {
            let mut rng = Prng::seeded(t as u64);
            let mut results = Vec::new();
            for _ in 0..reqs_per_thread {
                let len = 1 + rng.below(40) as usize; // some exceed the cap
                let pts = rng.uniform_vec(len, -1.5, 1.5);
                let channels = handle.eval(&pts).expect("eval failed");
                results.push((pts, channels));
            }
            results
        }));
    }
    let mut total = 0;
    for th in threads {
        for (pts, channels) in th.join().unwrap() {
            let x = Tensor::from_vec(pts.clone(), &[pts.len(), 1]);
            let direct = engine.forward(&mlp, &x);
            assert_eq!(channels.len(), 4);
            for order in 0..=3 {
                assert_eq!(channels[order].len(), pts.len());
                for (a, b) in channels[order].iter().zip(direct[order].data()) {
                    assert!((a - b).abs() < 1e-10, "value corruption");
                }
            }
            total += 1;
        }
    }
    let m = service.handle().metrics();
    assert_eq!(m.requests, total as u64);
    assert_eq!(m.errors, 0);
    assert_eq!(m.points, m.batched_points, "all points must flow through the batcher");
    service.shutdown();
}

/// Hammer a 4-worker sharded pool (parallel native backends) with
/// mixed-activation requests from 16 client threads: every response must
/// match a direct single-threaded `NtpEngine` evaluation of the
/// retagged model, no errors, all shards busy.
#[test]
fn multi_worker_pool_survives_mixed_activation_hammering() {
    let mut rng = Prng::seeded(0x52);
    let mlp = Mlp::uniform(1, 12, 2, 1, &mut rng);
    let backend_mlp = mlp.clone();
    let service = Service::start_pool(
        move |_w| {
            Ok(Box::new(NativeBackend::new_parallel(
                backend_mlp.clone(),
                3,
                32,
                ParallelPolicy::Fixed(2),
            )) as _)
        },
        4,
        BatcherConfig {
            max_wait: Duration::from_micros(500),
            ..BatcherConfig::default()
        },
    );
    let engine = NtpEngine::new(3);
    let n_threads = 16;
    let reqs_per_thread = 20;
    let mut threads = Vec::new();
    for t in 0..n_threads {
        let handle = service.handle();
        threads.push(std::thread::spawn(move || {
            let mut rng = Prng::seeded(0x9000 + t as u64);
            let mut results = Vec::new();
            for _ in 0..reqs_per_thread {
                let kind = ActivationKind::ALL[rng.below(4) as usize];
                let len = 1 + rng.below(40) as usize; // some exceed the cap
                let pts = rng.uniform_vec(len, -1.5, 1.5);
                let channels = handle.eval_with(&pts, Some(kind)).expect("eval failed");
                results.push((kind, pts, channels));
            }
            results
        }));
    }
    let mut total = 0u64;
    for th in threads {
        for (kind, pts, channels) in th.join().unwrap() {
            let mut retagged = mlp.clone();
            retagged.activation = kind;
            let x = Tensor::from_vec(pts.clone(), &[pts.len(), 1]);
            let direct = engine.forward(&retagged, &x);
            assert_eq!(channels.len(), 4);
            for order in 0..=3 {
                assert_eq!(channels[order].len(), pts.len());
                for (a, b) in channels[order].iter().zip(direct[order].data()) {
                    // The parallel backend is bitwise-equal to serial, so
                    // the whole service stack must be exact.
                    assert_eq!(a, b, "value corruption ({} order {order})", kind.name());
                }
            }
            total += 1;
        }
    }
    let m = service.handle().metrics();
    assert_eq!(m.requests, total);
    assert_eq!(m.errors, 0);
    assert_eq!(m.points, m.batched_points, "all points must flow through a batcher");
    assert_eq!(m.workers.len(), 4);
    // One activation per shard; 16 threads × 20 random draws make every
    // shard's traffic overwhelmingly likely (P[miss] < 1e-35 per shard).
    for (w, ws) in m.workers.iter().enumerate() {
        assert!(ws.requests > 0, "worker {w} never served");
    }
    let batch_sum: u64 = m.workers.iter().map(|w| w.batches).sum();
    assert_eq!(batch_sum, m.batches, "per-worker batches must sum to the total");
    service.shutdown();
}

/// Shutdown with traffic still in flight: clients racing `shutdown()`
/// either get a correct answer or a clean "shut down" error — never a
/// hang, never a corrupt value — and the workers all join (drain
/// semantics; the deterministic drain ordering is covered by the batcher
/// unit test `shutdown_drains_already_queued_requests`).
#[test]
fn shutdown_under_load_drains_without_deadlock_or_corruption() {
    let mut rng = Prng::seeded(0x53);
    let mlp = Mlp::uniform(1, 10, 2, 1, &mut rng);
    for round in 0..3u64 {
        let backend_mlp = mlp.clone();
        let service = Service::start_pool(
            move |_w| Ok(Box::new(NativeBackend::new(backend_mlp.clone(), 2, 16)) as _),
            2,
            BatcherConfig::default(),
        );
        let mut clients = Vec::new();
        for t in 0..8u64 {
            let handle = service.handle();
            let mlp = mlp.clone();
            clients.push(std::thread::spawn(move || {
                let engine = NtpEngine::new(2);
                let mut rng = Prng::seeded(round * 100 + t);
                let mut answered = 0usize;
                let mut rejected = 0usize;
                for _ in 0..50 {
                    let kind = ActivationKind::ALL[rng.below(4) as usize];
                    let pt = rng.uniform_vec(1, -1.0, 1.0);
                    match handle.eval_with(&pt, Some(kind)) {
                        Ok(channels) => {
                            let mut retagged = mlp.clone();
                            retagged.activation = kind;
                            let direct = engine
                                .forward(&retagged, &Tensor::from_vec(pt.clone(), &[1, 1]));
                            for order in 0..=2 {
                                assert_eq!(
                                    channels[order][0],
                                    direct[order].data()[0],
                                    "corrupt value during shutdown race"
                                );
                            }
                            answered += 1;
                        }
                        Err(_) => rejected += 1, // clean rejection is fine
                    }
                }
                (answered, rejected)
            }));
        }
        // Guarantee the pool served at least one request this round, let
        // the clients race a little, then pull the plug mid-flight.
        assert!(service.handle().eval(&[0.1]).is_ok());
        std::thread::sleep(Duration::from_millis(2));
        service.shutdown(); // joins all workers; must not deadlock
        let mut completed = 0;
        for c in clients {
            let (a, r) = c.join().unwrap();
            completed += a + r;
        }
        assert_eq!(completed, 8 * 50, "round {round}: a client hung");
    }
}

#[test]
fn failing_backend_reports_errors_not_hangs() {
    struct Flaky {
        calls: usize,
    }
    impl EvalBackend for Flaky {
        fn max_batch(&self) -> usize {
            8
        }
        fn n_channels(&self) -> usize {
            1
        }
        fn eval_batch(&mut self, xs: &[f64]) -> anyhow::Result<Vec<Vec<f64>>> {
            self.calls += 1;
            if self.calls % 2 == 0 {
                anyhow::bail!("injected failure");
            }
            Ok(vec![xs.to_vec()])
        }
    }
    let service = Service::start(
        move || Ok(Box::new(Flaky { calls: 0 }) as _),
        BatcherConfig::default(),
    );
    let handle = service.handle();
    let mut ok = 0;
    let mut err = 0;
    for _ in 0..10 {
        match handle.eval(&[1.0]) {
            Ok(_) => ok += 1,
            Err(_) => err += 1,
        }
    }
    assert!(ok > 0 && err > 0, "ok={ok} err={err}");
    assert_eq!(handle.metrics().errors as usize, err);
    service.shutdown();
}

#[test]
fn tcp_malformed_requests_get_error_replies() {
    let (_, service) = fixture();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let handle = service.handle();
    std::thread::spawn(move || ntangent::coordinator::service::serve_tcp(listener, handle));

    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    for bad in ["garbage", "{\"points\":[]}", "{\"cmd\":\"nope\"}"] {
        writer.write_all(bad.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"error\""), "reply to {bad}: {line}");
    }
    // Connection still usable afterwards.
    let mut client = TcpClient::connect(&addr).unwrap();
    assert!(client.eval(&[0.5]).is_ok());
    service.shutdown();
}

#[test]
fn pjrt_backend_through_service_matches_native() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if ArtifactManifest::load(&dir).is_err() {
        eprintln!("skipping pjrt service test: run `make artifacts`");
        return;
    }
    // The artifact architecture is fixed (1,24,24,24,1); build a matching
    // random parameter vector shared by both paths.
    let mut rng = Prng::seeded(0x77);
    let mlp = Mlp::uniform(1, 24, 3, 1, &mut rng);
    let theta = params::flatten(&mlp);

    let dir2 = dir.clone();
    let theta2 = theta.clone();
    let service = Service::start(
        move || {
            let manifest = ArtifactManifest::load(&dir2)?;
            let spec = manifest.get("ntp_fwd_d3")?.clone();
            let rt = Runtime::cpu()?;
            let exe = rt.load_hlo_text(&manifest.path_of(&spec))?;
            Ok(Box::new(PjrtBackend::new(
                exe,
                theta2,
                spec.batch.unwrap(),
                spec.n_derivs.unwrap(),
            )) as _)
        },
        BatcherConfig::default(),
    );
    let handle = service.handle();
    let pts: Vec<f64> = (0..40).map(|i| -1.0 + i as f64 * 0.05).collect();
    let channels = handle.eval(&pts).expect("pjrt eval");
    let native = NtpEngine::new(3).forward(&mlp, &Tensor::from_vec(pts.clone(), &[40, 1]));
    for order in 0..=3 {
        for (a, b) in channels[order].iter().zip(native[order].data()) {
            assert!(
                (a - b).abs() < 1e-8 * b.abs().max(1.0),
                "order {order}: {a} vs {b}"
            );
        }
    }
    service.shutdown();
}
