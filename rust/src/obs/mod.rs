//! Crate-wide observability: hierarchical spans, a unified metrics
//! registry, and kernel-phase profiling hooks.
//!
//! Everything in this module obeys one contract, enforced by
//! `rust/tests/obs_overhead.rs`:
//!
//! > **Instrumentation never touches the float path.** Spans and metrics
//! > only read monotonic clocks and bump `AtomicU64`s; they never read or
//! > write a numeric buffer that feeds a computation. An instrumented run
//! > is therefore **bitwise identical** to an uninstrumented one, for
//! > every [`crate::ntp::ParallelPolicy`] and both estimator modes.
//!
//! The subsystem has three pieces:
//!
//! - [`span`] — hierarchical scoped timers on thread-local span stacks.
//!   [`span::span`] returns a RAII guard; nesting builds a global span
//!   *tree* aggregated by `(parent, name)` with lock-free counters on the
//!   warm path. Disabled (the default), a span is a single relaxed atomic
//!   load.
//! - [`registry`] — process-wide named counters, gauges and fixed-bucket
//!   log-scale histograms with lock-free `AtomicU64` buckets. One
//!   histogram type defines p50/p95/p99 everywhere: the serving metrics,
//!   `bench serve`, and the `{"stats":"full"}` wire reply all quote it.
//! - [`export`] — Prometheus text exposition and a JSON snapshot of the
//!   registry plus the span tree.
//!
//! Tracing is enabled by `NTANGENT_TRACE=1` (read once per process),
//! programmatically via [`set_enabled`] / [`ObsConfig`], or by the CLI
//! flags (`serve --obs`, `ntangent trace …`). Kernel-phase sampling
//! inside the fused tile loop is bounded by recording only every
//! [`kernel_sample`]-th tile (`NTANGENT_TRACE_SAMPLE`, default 16), which
//! keeps the measured overhead of a fully traced fused forward under the
//! 2% budget pinned by `BENCH_obs.json` (`ntangent bench obs`).

pub mod export;
pub mod registry;
pub mod span;

pub use registry::{registry, Counter, Gauge, Histogram, HistogramSnapshot, Registry};
pub use span::{reset_spans, span, span_depth, span_report, ScopedSpan, SpanNodeReport};

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::Once;
use std::time::Instant;

static ENABLED: AtomicBool = AtomicBool::new(false);
static KERNEL_SAMPLE: AtomicU32 = AtomicU32::new(16);
static INIT: Once = Once::new();

fn init_from_env() {
    INIT.call_once(|| {
        if let Ok(v) = std::env::var("NTANGENT_TRACE") {
            let on = matches!(v.as_str(), "1" | "true" | "on" | "yes");
            ENABLED.store(on, Ordering::Relaxed);
        }
        if let Ok(v) = std::env::var("NTANGENT_TRACE_SAMPLE") {
            if let Ok(k) = v.parse::<u32>() {
                KERNEL_SAMPLE.store(k.max(1), Ordering::Relaxed);
            }
        }
    });
}

/// Is tracing enabled? One relaxed atomic load on the warm path (the
/// `NTANGENT_TRACE` environment variable is consulted once per process).
#[inline]
pub fn enabled() -> bool {
    init_from_env();
    ENABLED.load(Ordering::Relaxed)
}

/// Enable or disable tracing for the whole process (CLI flags and tests;
/// overrides whatever `NTANGENT_TRACE` said).
pub fn set_enabled(on: bool) {
    init_from_env();
    ENABLED.store(on, Ordering::Relaxed);
}

/// Record a kernel-phase sample every `k`-th tile (≥ 1).
pub fn set_kernel_sample(k: u32) {
    init_from_env();
    KERNEL_SAMPLE.store(k.max(1), Ordering::Relaxed);
}

/// Current kernel-phase sampling stride.
#[inline]
pub fn kernel_sample() -> u32 {
    init_from_env();
    KERNEL_SAMPLE.load(Ordering::Relaxed)
}

/// Programmatic observability configuration (the struct form of the
/// `NTANGENT_TRACE` / `NTANGENT_TRACE_SAMPLE` environment knobs).
#[derive(Clone, Copy, Debug)]
pub struct ObsConfig {
    /// Master switch: spans, kernel-phase sampling, serving segments.
    pub enabled: bool,
    /// Kernel-phase sampling stride (record every k-th tile).
    pub kernel_sample: u32,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            enabled: false,
            kernel_sample: 16,
        }
    }
}

impl ObsConfig {
    /// Apply this configuration process-wide.
    pub fn apply(&self) {
        set_enabled(self.enabled);
        set_kernel_sample(self.kernel_sample);
    }
}

// --------------------------------------------------------------- kernel

/// The six phases of the fused n-TangentProp tile kernel
/// (`rust/src/ntp/forward.rs`), in sweep order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum KernelPhase {
    /// Channel slices copied into the interleaved tile.
    Pack = 0,
    /// Activation derivative tower σ⁽⁰˙˙ⁿ⁾(y₀).
    Tower = 1,
    /// Channel power planes y_jᶜ.
    Powers = 2,
    /// Compiled Faà di Bruno interpreter (the ξ accumulation).
    Interpret = 3,
    /// Tile results copied back out to the channel planes.
    Unpack = 4,
    /// Stacked-channel GEMM + bias (once per layer, not per tile).
    Gemm = 5,
}

/// Phase names, indexed by `KernelPhase as usize`.
pub const KERNEL_PHASES: [&str; 6] = ["pack", "tower", "powers", "interpret", "unpack", "gemm"];

/// Metric-name table for the per-phase counters, indexed like
/// [`KERNEL_PHASES`] — registered lazily on first flush.
const PHASE_METRIC: [&str; 6] = [
    "kernel_pack_ns",
    "kernel_tower_ns",
    "kernel_powers_ns",
    "kernel_interpret_ns",
    "kernel_unpack_ns",
    "kernel_gemm_ns",
];

fn phase_label(p: usize) -> &'static str {
    match p {
        0 => "pack",
        1 => "tower",
        2 => "powers",
        3 => "interpret",
        4 => "unpack",
        _ => "gemm",
    }
}

/// A per-call accumulator for sampled kernel-phase timings.
///
/// Created once per fused forward chunk; the tile loop asks it for a
/// [`PhaseTimer`] per tile (live on every `kernel_sample()`-th tile, inert
/// otherwise) and laps it between phases. All state is fixed-size and on
/// the stack — **no allocation, no float access** — and a single
/// [`flush`](PhaseAccum::flush) at the end of the chunk folds the sums
/// into the global registry counters. When tracing is disabled the whole
/// accumulator is a handful of dead branches.
#[derive(Debug)]
pub struct PhaseAccum {
    ns: [u64; 6],
    tiles: u64,
    samples: u64,
    every: u64,
    active: bool,
}

impl PhaseAccum {
    /// A fresh accumulator; captures the enable flag and sampling stride.
    #[inline]
    pub fn new() -> PhaseAccum {
        let active = enabled();
        PhaseAccum {
            ns: [0; 6],
            tiles: 0,
            samples: 0,
            every: if active { kernel_sample() as u64 } else { 1 },
            active,
        }
    }

    /// Start the next tile. Returns a live timer on sampled tiles, an
    /// inert one otherwise.
    #[inline]
    pub fn tile(&mut self) -> PhaseTimer {
        let idx = self.tiles;
        self.tiles += 1;
        if self.active && idx % self.every == 0 {
            self.samples += 1;
            PhaseTimer(Some(Instant::now()))
        } else {
            PhaseTimer(None)
        }
    }

    /// Start a non-tile (per-layer) timer — live whenever tracing is on.
    #[inline]
    pub fn start(&self) -> PhaseTimer {
        if self.active {
            PhaseTimer(Some(Instant::now()))
        } else {
            PhaseTimer(None)
        }
    }

    /// Charge the time since the timer's last lap to `phase` and restart
    /// the timer (no-op for inert timers).
    #[inline]
    pub fn lap(&mut self, t: &mut PhaseTimer, phase: KernelPhase) {
        if let Some(prev) = t.0 {
            let now = Instant::now();
            self.ns[phase as usize] += now.duration_since(prev).as_nanos() as u64;
            t.0 = Some(now);
        }
    }

    /// Fold the accumulated phase times into the global registry
    /// (`kernel_*_ns` counters plus `kernel_tiles` / `kernel_samples`).
    pub fn flush(self) {
        if !self.active || self.tiles == 0 {
            return;
        }
        let reg = registry();
        for (i, &ns) in self.ns.iter().enumerate() {
            if ns > 0 {
                reg.counter(PHASE_METRIC[i]).add(ns);
            }
        }
        reg.counter("kernel_tiles").add(self.tiles);
        reg.counter("kernel_samples").add(self.samples);
    }
}

impl Default for PhaseAccum {
    fn default() -> Self {
        PhaseAccum::new()
    }
}

/// A phase stopwatch handed out by [`PhaseAccum`]; `None` inside means
/// the tile was not sampled (or tracing is off) and every lap is free.
#[derive(Debug)]
pub struct PhaseTimer(Option<Instant>);

/// Snapshot of the accumulated kernel-phase counters:
/// `(phase name, total ns)` for each phase with data, plus
/// `(tiles, samples)` totals.
pub fn kernel_phase_totals() -> (Vec<(&'static str, u64)>, u64, u64) {
    let reg = registry();
    let mut phases = Vec::new();
    for (i, metric) in PHASE_METRIC.iter().enumerate() {
        let v = reg.counter(metric).get();
        if v > 0 {
            phases.push((phase_label(i), v));
        }
    }
    (
        phases,
        reg.counter("kernel_tiles").get(),
        reg.counter("kernel_samples").get(),
    )
}

/// A tiny helper for one-shot durations outside the span tree: returns
/// elapsed nanoseconds since `t0` as `u64` (saturating).
#[inline]
pub fn ns_since(t0: Instant) -> u64 {
    t0.elapsed().as_nanos() as u64
}

/// Shared latency-unit conversion used by every surface that prints
/// histogram data (stats wire reply, `bench serve`, `trace`).
#[inline]
pub fn ns_to_us(ns: f64) -> f64 {
    ns / 1_000.0
}

/// Serializes tests that flip the process-wide enable flag or reset the
/// registry/span tree (the flag is global, the test harness is
/// threaded). Not part of the public API.
#[doc(hidden)]
pub fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static M: std::sync::Mutex<()> = std::sync::Mutex::new(());
    M.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_round_trips() {
        let _g = test_guard();
        let was = enabled();
        let cfg = ObsConfig {
            enabled: true,
            kernel_sample: 7,
        };
        cfg.apply();
        assert!(enabled());
        assert_eq!(kernel_sample(), 7);
        ObsConfig {
            enabled: was,
            kernel_sample: 16,
        }
        .apply();
    }

    #[test]
    fn phase_accum_is_inert_when_disabled() {
        let mut acc = PhaseAccum {
            ns: [0; 6],
            tiles: 0,
            samples: 0,
            every: 1,
            active: false,
        };
        let mut t = acc.tile();
        acc.lap(&mut t, KernelPhase::Pack);
        assert_eq!(acc.samples, 0);
        assert_eq!(acc.ns, [0; 6]);
        acc.flush(); // must not register anything
    }

    #[test]
    fn phase_accum_samples_every_kth_tile() {
        let mut acc = PhaseAccum {
            ns: [0; 6],
            tiles: 0,
            samples: 0,
            every: 4,
            active: true,
        };
        for _ in 0..16 {
            let mut t = acc.tile();
            acc.lap(&mut t, KernelPhase::Interpret);
        }
        assert_eq!(acc.tiles, 16);
        assert_eq!(acc.samples, 4);
    }
}
