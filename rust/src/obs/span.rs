//! Hierarchical scoped spans on thread-local span stacks.
//!
//! [`span`] pushes a node onto the calling thread's span stack and
//! returns a RAII [`ScopedSpan`]; dropping it (normal exit, early return
//! **or unwind**) pops the stack and charges the elapsed monotonic time
//! to the node. Nodes are identified by `(parent node, interned name)`,
//! so nesting builds a process-wide span *tree*:
//!
//! ```text
//! train.epoch                 600 × 1.21s
//! ├─ pinn.shard_eval          600 × 0.96s
//! │  └─ ntp.forward          4800 × 0.80s
//! └─ opt.adam_step            600 × 0.11s
//! ```
//!
//! Names are interned once into a fixed table (call sites pass
//! `&'static str` literals); the warm path for an existing node is a
//! read-locked `HashMap` hit plus two relaxed `fetch_add`s. When tracing
//! is disabled ([`super::enabled`] is false) a span is one relaxed
//! atomic load and the guard is inert — the float path never changes
//! either way, so traced and untraced runs are bitwise identical.

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{OnceLock, RwLock};
use std::time::Instant;

/// Sentinel parent of top-level spans.
const ROOT: usize = usize::MAX;

struct Node {
    name: &'static str,
    parent: usize,
    count: AtomicU64,
    total_ns: AtomicU64,
}

struct Tree {
    nodes: RwLock<Vec<Node>>,
    index: RwLock<HashMap<(usize, &'static str), usize>>,
}

fn tree() -> &'static Tree {
    static CELL: OnceLock<Tree> = OnceLock::new();
    CELL.get_or_init(|| Tree {
        nodes: RwLock::new(Vec::new()),
        index: RwLock::new(HashMap::new()),
    })
}

thread_local! {
    static STACK: RefCell<Vec<usize>> = const { RefCell::new(Vec::new()) };
}

/// Resolve (or create) the node for `name` under `parent`.
fn resolve(parent: usize, name: &'static str) -> usize {
    let t = tree();
    if let Some(&id) = t.index.read().expect("span index poisoned").get(&(parent, name)) {
        return id;
    }
    let mut index = t.index.write().expect("span index poisoned");
    if let Some(&id) = index.get(&(parent, name)) {
        return id;
    }
    let mut nodes = t.nodes.write().expect("span nodes poisoned");
    let id = nodes.len();
    nodes.push(Node {
        name,
        parent,
        count: AtomicU64::new(0),
        total_ns: AtomicU64::new(0),
    });
    index.insert((parent, name), id);
    id
}

/// Open a span named `name` under the calling thread's current span (or
/// at the tree root). Returns the RAII guard that closes it; keep the
/// guard alive for the duration of the region:
///
/// ```
/// let _sp = ntangent::obs::span("docs.example");
/// // … timed region …
/// ```
#[inline]
pub fn span(name: &'static str) -> ScopedSpan {
    if !super::enabled() {
        return ScopedSpan { live: None };
    }
    let parent = STACK.with(|s| s.borrow().last().copied().unwrap_or(ROOT));
    let node = resolve(parent, name);
    STACK.with(|s| s.borrow_mut().push(node));
    ScopedSpan {
        live: Some((node, Instant::now())),
    }
}

/// RAII guard returned by [`span`]; closes the span on drop (including
/// during unwinding, so span stacks stay balanced under panics and early
/// returns — see `rust/tests/obs_overhead.rs`).
#[must_use = "a span guard times the scope it lives in; bind it to a variable"]
pub struct ScopedSpan {
    live: Option<(usize, Instant)>,
}

impl Drop for ScopedSpan {
    fn drop(&mut self) {
        let Some((node, start)) = self.live.take() else {
            return;
        };
        let ns = start.elapsed().as_nanos() as u64;
        STACK.with(|s| {
            let mut st = s.borrow_mut();
            debug_assert_eq!(st.last().copied(), Some(node), "span stack out of balance");
            st.pop();
        });
        let nodes = tree().nodes.read().expect("span nodes poisoned");
        // `get`, not indexing: a reset_spans() between open and close
        // invalidates the id, and the closure is then simply dropped.
        if let Some(n) = nodes.get(node) {
            n.count.fetch_add(1, Ordering::Relaxed);
            n.total_ns.fetch_add(ns, Ordering::Relaxed);
        }
    }
}

/// Current depth of the calling thread's span stack (0 outside any
/// span) — used by the balance tests.
pub fn span_depth() -> usize {
    STACK.with(|s| s.borrow().len())
}

/// One node of a [`span_report`] snapshot.
#[derive(Clone, Debug)]
pub struct SpanNodeReport {
    /// Interned span name.
    pub name: &'static str,
    /// Number of times the span closed.
    pub count: u64,
    /// Total nanoseconds across all closures.
    pub total_ns: u64,
    /// Child spans, in creation order.
    pub children: Vec<SpanNodeReport>,
}

impl SpanNodeReport {
    fn render_into(&self, out: &mut String, prefix: &str, last: bool, top: bool) {
        if top {
            out.push_str(&format!(
                "{}  {} × {:.3} ms\n",
                self.name,
                self.count,
                self.total_ns as f64 / 1e6
            ));
        } else {
            out.push_str(&format!(
                "{}{}─ {}  {} × {:.3} ms\n",
                prefix,
                if last { "└" } else { "├" },
                self.name,
                self.count,
                self.total_ns as f64 / 1e6
            ));
        }
        let child_prefix = if top {
            String::new()
        } else {
            format!("{}{}  ", prefix, if last { " " } else { "│" })
        };
        for (i, c) in self.children.iter().enumerate() {
            c.render_into(out, &child_prefix, i + 1 == self.children.len(), false);
        }
    }
}

/// Snapshot the global span tree as a forest of top-level spans.
pub fn span_report() -> Vec<SpanNodeReport> {
    let nodes = tree().nodes.read().expect("span nodes poisoned");
    fn build(nodes: &[Node], parent: usize) -> Vec<SpanNodeReport> {
        nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.parent == parent)
            .map(|(id, n)| SpanNodeReport {
                name: n.name,
                count: n.count.load(Ordering::Relaxed),
                total_ns: n.total_ns.load(Ordering::Relaxed),
                children: build(nodes, id),
            })
            .collect()
    }
    build(&nodes, ROOT)
}

/// Pretty-print the current span tree (the `ntangent trace` renderer).
pub fn render_tree() -> String {
    let forest = span_report();
    if forest.is_empty() {
        return "(no spans recorded — is tracing enabled?)\n".to_string();
    }
    let mut out = String::new();
    for root in &forest {
        root.render_into(&mut out, "", true, true);
    }
    out
}

/// Clear the global span tree (counts *and* structure). Only call
/// between runs — concurrent open spans keep stale node ids, so their
/// closures are dropped harmlessly against the fresh tree.
pub fn reset_spans() {
    let t = tree();
    let mut index = t.index.write().expect("span index poisoned");
    let mut nodes = t.nodes.write().expect("span nodes poisoned");
    index.clear();
    nodes.clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    // Span tests mutate the global enable flag; serialize them (with
    // every other flag-flipping test in the crate).
    fn lock() -> std::sync::MutexGuard<'static, ()> {
        super::super::test_guard()
    }

    #[test]
    fn disabled_spans_are_inert() {
        let _g = lock();
        let was = super::super::enabled();
        super::super::set_enabled(false);
        {
            let _a = span("test.disabled");
            assert_eq!(span_depth(), 0);
        }
        super::super::set_enabled(was);
    }

    #[test]
    fn nesting_builds_a_tree() {
        let _g = lock();
        let was = super::super::enabled();
        super::super::set_enabled(true);
        {
            let _a = span("test.outer");
            assert_eq!(span_depth(), 1);
            {
                let _b = span("test.inner");
                assert_eq!(span_depth(), 2);
            }
            assert_eq!(span_depth(), 1);
        }
        assert_eq!(span_depth(), 0);
        let report = span_report();
        let outer = report
            .iter()
            .find(|n| n.name == "test.outer")
            .expect("outer span recorded");
        assert!(outer.count >= 1);
        assert!(outer.children.iter().any(|c| c.name == "test.inner"));
        let txt = render_tree();
        assert!(txt.contains("test.outer"));
        assert!(txt.contains("test.inner"));
        super::super::set_enabled(was);
    }

    #[test]
    fn guard_drop_balances_on_unwind() {
        let _g = lock();
        let was = super::super::enabled();
        super::super::set_enabled(true);
        let r = std::panic::catch_unwind(|| {
            let _a = span("test.panic");
            panic!("boom");
        });
        assert!(r.is_err());
        assert_eq!(span_depth(), 0, "unwind must pop the span stack");
        super::super::set_enabled(was);
    }
}
