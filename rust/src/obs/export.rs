//! Export surfaces for the registry and span tree: Prometheus text
//! exposition and a JSON snapshot.
//!
//! Both render the same data: every registered counter, gauge and
//! histogram (see [`super::registry`]) plus, in the JSON form, the
//! hierarchical span tree. The Prometheus form follows the text
//! exposition format (`# TYPE` lines, cumulative `le` buckets, `_sum` /
//! `_count`), with every metric prefixed `ntangent_`.

use super::registry::{registry, HistogramSnapshot};
use super::span::{span_report, SpanNodeReport};
use crate::util::json::Json;

/// Render every registered metric in the Prometheus text exposition
/// format. Histogram buckets are emitted cumulatively with their
/// inclusive upper bounds as `le` labels (occupied buckets only, plus
/// `+Inf`).
pub fn prometheus() -> String {
    let reg = registry();
    let mut out = String::new();
    for (name, v) in reg.counters() {
        out.push_str(&format!("# TYPE ntangent_{name} counter\n"));
        out.push_str(&format!("ntangent_{name} {v}\n"));
    }
    for (name, v) in reg.gauges() {
        out.push_str(&format!("# TYPE ntangent_{name} gauge\n"));
        out.push_str(&format!("ntangent_{name} {v}\n"));
    }
    for (name, snap) in reg.histograms() {
        out.push_str(&format!("# TYPE ntangent_{name} histogram\n"));
        let mut cum = 0u64;
        for (lower, count) in snap.occupied() {
            cum += count;
            // `lower` is the bucket's inclusive lower bound; the next
            // bucket's lower bound is this one's exclusive upper, so it
            // serves as the Prometheus `le` boundary.
            out.push_str(&format!(
                "ntangent_{name}_bucket{{le=\"{lower}\"}} {cum}\n"
            ));
        }
        out.push_str(&format!(
            "ntangent_{name}_bucket{{le=\"+Inf\"}} {}\n",
            snap.count
        ));
        out.push_str(&format!("ntangent_{name}_sum {}\n", snap.sum));
        out.push_str(&format!("ntangent_{name}_count {}\n", snap.count));
    }
    out
}

fn span_json(n: &SpanNodeReport) -> Json {
    Json::obj(vec![
        ("name", Json::Str(n.name.to_string())),
        ("count", Json::Num(n.count as f64)),
        ("total_ns", Json::Num(n.total_ns as f64)),
        (
            "children",
            Json::Arr(n.children.iter().map(span_json).collect()),
        ),
    ])
}

fn hist_json(pairs: Vec<(String, HistogramSnapshot)>) -> Json {
    Json::Obj(
        pairs
            .into_iter()
            .map(|(name, snap)| (name, snap.to_json()))
            .collect(),
    )
}

/// JSON snapshot of the whole observability state: counters, gauges,
/// histograms (with p50/p95/p99 and occupied buckets) and the span
/// tree. The payload behind `ntangent trace … --json`.
pub fn json_snapshot() -> Json {
    let reg = registry();
    let counters = Json::Obj(
        reg.counters()
            .into_iter()
            .map(|(k, v)| (k, Json::Num(v as f64)))
            .collect(),
    );
    let gauges = Json::Obj(
        reg.gauges()
            .into_iter()
            .map(|(k, v)| (k, Json::Num(v as f64)))
            .collect(),
    );
    Json::obj(vec![
        ("counters", counters),
        ("gauges", gauges),
        ("histograms", hist_json(reg.histograms())),
        (
            "spans",
            Json::Arr(span_report().iter().map(span_json).collect()),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prometheus_renders_all_families() {
        registry().counter("export_test_counter").add(3);
        registry().gauge("export_test_gauge").set(7);
        let h = registry().histogram("export_test_hist");
        h.record(1000);
        h.record(2000);
        let text = prometheus();
        assert!(text.contains("# TYPE ntangent_export_test_counter counter"));
        assert!(text.contains("ntangent_export_test_gauge 7"));
        assert!(text.contains("# TYPE ntangent_export_test_hist histogram"));
        assert!(text.contains("ntangent_export_test_hist_count 2"));
        assert!(text.contains("ntangent_export_test_hist_sum 3000"));
        assert!(text.contains("le=\"+Inf\"} 2"));
    }

    #[test]
    fn json_snapshot_parses_back() {
        registry().counter("export_json_counter").inc();
        let v = Json::parse(&json_snapshot().dump()).expect("snapshot is valid JSON");
        assert!(v.get("counters").is_some());
        assert!(v.get("histograms").is_some());
        assert!(v.get("spans").is_some());
        assert!(
            v.get("counters")
                .and_then(|c| c.get("export_json_counter"))
                .and_then(Json::as_f64)
                .unwrap_or(0.0)
                >= 1.0
        );
    }
}
