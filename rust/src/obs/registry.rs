//! The unified metrics registry: named counters, gauges and fixed-bucket
//! log-scale histograms, all lock-free on the record path.
//!
//! Every family is an `AtomicU64`-backed cell created on first use with
//! [`Registry::counter`] / [`Registry::gauge`] / [`Registry::histogram`]
//! and shared as an `Arc` — the registry lock is only taken to *resolve a
//! name*, never to record. [`Histogram`] is the crate's single definition
//! of latency percentiles: `coordinator::Metrics`, `bench serve`, and the
//! `{"stats":"full"}` wire reply all quote the same bucketing, so p50/p95
//! /p99 agree everywhere by construction (to within one bucket).

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, RwLock};

// ------------------------------------------------------------- counters

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    /// A fresh zeroed counter.
    pub fn new() -> Counter {
        Counter::default()
    }

    /// Increment by 1.
    #[inline]
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    /// Increment by `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    #[inline]
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }

    /// Reset to zero (tests / bench legs).
    pub fn reset(&self) {
        self.v.store(0, Ordering::Relaxed);
    }
}

/// A last-write-wins gauge (u64 semantics: sizes, depths, flags).
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    /// A fresh zeroed gauge.
    pub fn new() -> Gauge {
        Gauge::default()
    }

    /// Set the gauge.
    #[inline]
    pub fn set(&self, v: u64) {
        self.v.store(v, Ordering::Relaxed);
    }

    /// Current value.
    #[inline]
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

// ----------------------------------------------------------- histograms

/// Number of histogram buckets: exact buckets for values `0..=7`, then
/// 4 log-scale sub-buckets per power of two across the rest of the
/// `u64` range (≈ ±9.5% relative resolution). `8 + 61·4 = 252`, and
/// every index is reachable — the layout has no dead buckets, so the
/// bound functions below are total and strictly monotone.
pub const HIST_BUCKETS: usize = 252;

/// Bucket index of a recorded value (log scale, 4 buckets per octave).
#[inline]
fn bucket_of(v: u64) -> usize {
    if v < 8 {
        return v as usize; // exact buckets for 0..=7
    }
    let lz = 63 - v.leading_zeros() as usize; // floor(log2 v), ≥ 3
    let sub = ((v >> (lz - 2)) & 0b11) as usize;
    8 + (lz - 3) * 4 + sub
}

/// Inclusive lower bound of bucket `i` (the smallest value it holds).
fn bucket_lower(i: usize) -> u64 {
    if i < 8 {
        return i as u64;
    }
    let lz = 3 + (i - 8) / 4; // ≤ 63 for every valid index
    let sub = ((i - 8) % 4) as u64;
    (4 + sub) << (lz - 2)
}

/// Exclusive upper bound of bucket `i`.
fn bucket_upper(i: usize) -> u64 {
    if i + 1 >= HIST_BUCKETS {
        return u64::MAX;
    }
    bucket_lower(i + 1)
}

/// Representative value reported for bucket `i` (its midpoint) — what
/// percentile queries return, so "within one bucket" is the quantile
/// error bound.
fn bucket_mid(i: usize) -> f64 {
    if i < 8 {
        return i as f64; // exact buckets
    }
    let lo = bucket_lower(i) as f64;
    let hi = bucket_upper(i).min(bucket_lower(i).saturating_mul(2)) as f64;
    (lo + hi) / 2.0
}

/// A fixed-bucket log-scale histogram with lock-free `AtomicU64`
/// buckets. Records are wait-free (one bucket `fetch_add` plus count /
/// sum / max updates); snapshots and percentiles read a consistent-enough
/// relaxed view. Exact `sum` and `max` are carried alongside the buckets,
/// so mean and max stay *exact* even though quantiles are bucketed.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    /// A fresh empty histogram.
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one observation.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Number of recorded observations.
    #[inline]
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Exact sum of recorded observations.
    #[inline]
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Exact maximum recorded observation (0 when empty).
    #[inline]
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Fold this histogram's contents into `other` (per-connection →
    /// global aggregation).
    pub fn merge_into(&self, other: &Histogram) {
        for (i, b) in self.buckets.iter().enumerate() {
            let v = b.load(Ordering::Relaxed);
            if v > 0 {
                other.buckets[i].fetch_add(v, Ordering::Relaxed);
            }
        }
        other.count.fetch_add(self.count(), Ordering::Relaxed);
        other.sum.fetch_add(self.sum(), Ordering::Relaxed);
        other.max.fetch_max(self.max(), Ordering::Relaxed);
    }

    /// Reset all buckets and totals (tests / bench legs).
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }

    /// Consistent point-in-time copy of the histogram.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut buckets = [0u64; HIST_BUCKETS];
        for (dst, src) in buckets.iter_mut().zip(&self.buckets) {
            *dst = src.load(Ordering::Relaxed);
        }
        // Derive the count from the copied buckets so quantiles are
        // self-consistent even if records raced the copy.
        let count: u64 = buckets.iter().sum();
        HistogramSnapshot {
            buckets,
            count,
            sum: self.sum(),
            max: self.max(),
        }
    }

    /// Quantile `q ∈ [0, 1]` (bucket midpoint; `None` when empty).
    pub fn percentile(&self, q: f64) -> Option<f64> {
        self.snapshot().percentile(q)
    }
}

/// Point-in-time copy of a [`Histogram`].
#[derive(Clone, Debug, PartialEq)]
pub struct HistogramSnapshot {
    /// Per-bucket observation counts.
    pub buckets: [u64; HIST_BUCKETS],
    /// Total observations (sum of `buckets`).
    pub count: u64,
    /// Exact sum of observations.
    pub sum: u64,
    /// Exact maximum observation.
    pub max: u64,
}

impl HistogramSnapshot {
    /// Quantile `q ∈ [0, 1]` as the midpoint of the bucket holding the
    /// `⌈q·count⌉`-th observation (`None` when empty).
    pub fn percentile(&self, q: f64) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Some(bucket_mid(i));
            }
        }
        Some(bucket_mid(HIST_BUCKETS - 1))
    }

    /// Index of the bucket holding quantile `q` (`None` when empty) —
    /// the unit the "within one bucket" acceptance bound is stated in.
    pub fn percentile_bucket(&self, q: f64) -> Option<usize> {
        if self.count == 0 {
            return None;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Some(i);
            }
        }
        Some(HIST_BUCKETS - 1)
    }

    /// Mean observation (exact, from the carried sum; 0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Occupied buckets as `(lower_bound, count)` pairs — the compact
    /// wire/JSON form.
    pub fn occupied(&self) -> Vec<(u64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (bucket_lower(i), c))
            .collect()
    }

    /// JSON form used by the `{"stats":"full"}` reply and `bench serve`
    /// (`count`, exact `sum`/`max`, p50/p95/p99, occupied buckets).
    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .occupied()
            .into_iter()
            .map(|(lo, c)| Json::Arr(vec![Json::Num(lo as f64), Json::Num(c as f64)]))
            .collect();
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("sum", Json::Num(self.sum as f64)),
            ("max", Json::Num(self.max as f64)),
            ("p50", Json::Num(self.percentile(0.50).unwrap_or(0.0))),
            ("p95", Json::Num(self.percentile(0.95).unwrap_or(0.0))),
            ("p99", Json::Num(self.percentile(0.99).unwrap_or(0.0))),
            ("buckets", Json::Arr(buckets)),
        ])
    }
}

// ------------------------------------------------------------- registry

/// The process-wide named-metric registry. Families are created on first
/// use and live for the process; names are reported in sorted order.
#[derive(Default)]
pub struct Registry {
    counters: RwLock<BTreeMap<String, Arc<Counter>>>,
    gauges: RwLock<BTreeMap<String, Arc<Gauge>>>,
    hists: RwLock<BTreeMap<String, Arc<Histogram>>>,
}

/// The process-wide [`Registry`].
pub fn registry() -> &'static Registry {
    static CELL: OnceLock<Registry> = OnceLock::new();
    CELL.get_or_init(Registry::default)
}

fn get_or_create<T: Default>(map: &RwLock<BTreeMap<String, Arc<T>>>, name: &str) -> Arc<T> {
    if let Some(v) = map.read().expect("registry poisoned").get(name) {
        return v.clone();
    }
    let mut w = map.write().expect("registry poisoned");
    w.entry(name.to_string()).or_default().clone()
}

impl Registry {
    /// The counter named `name`, created on first use.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        get_or_create(&self.counters, name)
    }

    /// The gauge named `name`, created on first use.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        get_or_create(&self.gauges, name)
    }

    /// The histogram named `name`, created on first use.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        get_or_create(&self.hists, name)
    }

    /// Register an externally owned histogram under `name` (the
    /// coordinator's per-worker latency histograms live inside
    /// `Metrics` but still export through the registry).
    pub fn register_histogram(&self, name: &str, h: Arc<Histogram>) {
        self.hists
            .write()
            .expect("registry poisoned")
            .insert(name.to_string(), h);
    }

    /// Sorted `(name, value)` snapshot of every counter.
    pub fn counters(&self) -> Vec<(String, u64)> {
        self.counters
            .read()
            .expect("registry poisoned")
            .iter()
            .map(|(k, v)| (k.clone(), v.get()))
            .collect()
    }

    /// Sorted `(name, value)` snapshot of every gauge.
    pub fn gauges(&self) -> Vec<(String, u64)> {
        self.gauges
            .read()
            .expect("registry poisoned")
            .iter()
            .map(|(k, v)| (k.clone(), v.get()))
            .collect()
    }

    /// Sorted `(name, snapshot)` of every histogram.
    pub fn histograms(&self) -> Vec<(String, HistogramSnapshot)> {
        self.hists
            .read()
            .expect("registry poisoned")
            .iter()
            .map(|(k, v)| (k.clone(), v.snapshot()))
            .collect()
    }

    /// Zero every counter and histogram (gauges are left as-is): used
    /// between bench legs and in tests.
    pub fn reset(&self) {
        for (_, c) in self.counters.read().expect("registry poisoned").iter() {
            c.reset();
        }
        for (_, h) in self.hists.read().expect("registry poisoned").iter() {
            h.reset();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_layout_is_monotone_and_total() {
        let mut prev = 0;
        for i in 1..HIST_BUCKETS {
            let lo = bucket_lower(i);
            assert!(lo > prev, "bucket {i} lower {lo} <= {prev}");
            prev = lo;
        }
        // The top value lands in the top bucket — no index is dead.
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
        // Every value maps into the bucket whose bounds contain it.
        for v in [0u64, 1, 2, 3, 4, 5, 7, 8, 100, 1_000, 123_456, u64::MAX / 3, u64::MAX] {
            let i = bucket_of(v);
            assert!(bucket_lower(i) <= v, "v={v} i={i}");
            assert!(v < bucket_upper(i) || i == HIST_BUCKETS - 1, "v={v} i={i}");
        }
    }

    #[test]
    fn histogram_mean_max_are_exact() {
        let h = Histogram::new();
        for v in [100u64, 200, 300, 400] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 4);
        assert_eq!(s.sum, 1000);
        assert_eq!(s.max, 400);
        assert_eq!(s.mean(), 250.0);
    }

    #[test]
    fn percentiles_land_within_one_bucket() {
        let h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v * 1000); // 1µs .. 1ms in ns
        }
        let s = h.snapshot();
        let p50 = s.percentile(0.50).unwrap();
        let p99 = s.percentile(0.99).unwrap();
        // True p50 = 500_000, p99 = 990_000; bucket resolution ≈ ±10%.
        assert!((p50 - 500_000.0).abs() / 500_000.0 < 0.2, "p50={p50}");
        assert!((p99 - 990_000.0).abs() / 990_000.0 < 0.2, "p99={p99}");
        assert!(s.percentile(0.0).unwrap() <= p50);
        assert!(p50 <= p99);
    }

    #[test]
    fn merge_conserves_counts() {
        let a = Histogram::new();
        let b = Histogram::new();
        for v in [10u64, 20, 30] {
            a.record(v);
        }
        for v in [40u64, 50] {
            b.record(v);
        }
        a.merge_into(&b);
        let s = b.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.sum, 150);
        assert_eq!(s.max, 50);
    }

    #[test]
    fn registry_names_are_shared() {
        let c1 = registry().counter("test_registry_shared");
        let c2 = registry().counter("test_registry_shared");
        c1.inc();
        c2.add(2);
        assert_eq!(c1.get(), 3);
        assert!(Arc::ptr_eq(&c1, &c2));
    }
}
