//! Collocation-point samplers for PINN training.
//!
//! The Burgers experiments use (a) a grid or uniform-random cloud over the
//! training domain for the residual loss, (b) a tight cluster around the
//! origin for the high-order smoothness term L* (appendix A: "samples
//! taken from a small subset of collocation points centered at the
//! origin"), and (c) fixed boundary/normalization points.

use crate::nn::Mlp;
use crate::ntp::{NtpEngine, ParallelPolicy};
use crate::tensor::Tensor;
use crate::util::prng::Prng;

/// `n` evenly spaced points on `[lo, hi]`, shaped `[n, 1]`.
pub fn grid_points(lo: f64, hi: f64, n: usize) -> Tensor {
    Tensor::linspace(lo, hi, n).reshape(&[n, 1])
}

/// `n` uniform-random points on `[lo, hi)`, shaped `[n, 1]`.
pub fn random_points(lo: f64, hi: f64, n: usize, rng: &mut Prng) -> Tensor {
    Tensor::rand_uniform(&[n, 1], lo, hi, rng)
}

/// `n` points clustered around `center` with spread `radius` (uniform in
/// the interval), shaped `[n, 1]` — the L* sampling near the origin.
pub fn cluster_points(center: f64, radius: f64, n: usize, rng: &mut Prng) -> Tensor {
    Tensor::rand_uniform(&[n, 1], center - radius, center + radius, rng)
}

/// Evaluate the derivative channels `[u, u', ..., u^(n)]` of a trained
/// network over a collocation tensor `xs: [B, 1]`, chunking the batch
/// across threads per `policy`.
///
/// This is the post-training collocation hot path (validation grids,
/// profile curves, residual audits over dense clouds): per-point work is
/// independent, so the parallel result is bitwise identical to serial.
pub fn eval_channels(mlp: &Mlp, xs: &Tensor, n: usize, policy: ParallelPolicy) -> Vec<Tensor> {
    NtpEngine::with_policy(n, policy).forward(mlp, xs)
}

/// Latin-hypercube-style stratified 1-D sample: one uniform draw per
/// equal-width stratum, shuffled. Lower variance than iid uniform for the
/// same budget — used by the Sobolev-training example.
pub fn stratified_points(lo: f64, hi: f64, n: usize, rng: &mut Prng) -> Tensor {
    let width = (hi - lo) / n as f64;
    let mut xs: Vec<f64> = (0..n)
        .map(|i| lo + width * (i as f64 + rng.uniform()))
        .collect();
    rng.shuffle(&mut xs);
    Tensor::from_vec(xs, &[n, 1])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_endpoints_and_shape() {
        let g = grid_points(-2.0, 2.0, 9);
        assert_eq!(g.shape(), &[9, 1]);
        assert_eq!(g.data()[0], -2.0);
        assert_eq!(g.data()[8], 2.0);
    }

    #[test]
    fn random_points_in_range() {
        let mut rng = Prng::seeded(5);
        let pts = random_points(-1.0, 3.0, 200, &mut rng);
        assert!(pts.data().iter().all(|x| (-1.0..3.0).contains(x)));
    }

    #[test]
    fn cluster_is_tight() {
        let mut rng = Prng::seeded(6);
        let pts = cluster_points(0.0, 0.05, 100, &mut rng);
        assert!(pts.data().iter().all(|x| x.abs() <= 0.05));
    }

    #[test]
    fn eval_channels_matches_direct_engine_bitwise() {
        let mut rng = Prng::seeded(8);
        let mlp = Mlp::uniform(1, 10, 2, 1, &mut rng);
        let xs = grid_points(-1.5, 1.5, 41);
        let direct = NtpEngine::new(3).forward(&mlp, &xs);
        for policy in [
            ParallelPolicy::Serial,
            ParallelPolicy::Fixed(3),
            ParallelPolicy::Auto,
        ] {
            let got = eval_channels(&mlp, &xs, 3, policy);
            for (k, (a, b)) in direct.iter().zip(&got).enumerate() {
                assert_eq!(a, b, "{policy:?} channel {k}");
            }
        }
    }

    #[test]
    fn stratified_covers_every_stratum() {
        let mut rng = Prng::seeded(7);
        let n = 50;
        let pts = stratified_points(0.0, 1.0, n, &mut rng);
        let mut hit = vec![false; n];
        for &x in pts.data() {
            hit[((x * n as f64) as usize).min(n - 1)] = true;
        }
        assert!(hit.iter().all(|&h| h));
    }
}
