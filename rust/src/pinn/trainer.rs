//! Two-phase PINN trainer (Adam exploration → L-BFGS refinement), the
//! paper's training schedule for the self-similar Burgers profiles, with
//! per-epoch logging of loss, λ and wall-clock — everything Figs 6-10 need.

use super::burgers::BurgersProfile;
use super::loss::{BurgersLossSpec, DerivEngine, PinnObjective};
use crate::nn::Mlp;
use crate::ntp::ActivationKind;
use crate::opt::{Adam, Lbfgs, LbfgsStatus, Objective};
use crate::tensor::Tensor;
use crate::util::prng::Prng;
use std::time::Instant;

/// Training configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub width: usize,
    pub depth: usize,
    /// Hidden activation of the PINN (tanh is the paper's choice; sine
    /// gives SIREN-style spectral behaviour, softplus/GELU are the other
    /// registered smooth towers).
    pub activation: ActivationKind,
    pub adam_epochs: usize,
    pub lbfgs_epochs: usize,
    pub adam_lr: f64,
    pub seed: u64,
    /// Record a log entry every `log_every` epochs (and always the last).
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        // CPU-scaled defaults; the paper's A6000 schedule is 15k + 30k
        // (reachable here via --adam-epochs/--lbfgs-epochs).
        TrainConfig {
            width: 24,
            depth: 3,
            activation: ActivationKind::Tanh,
            adam_epochs: 300,
            lbfgs_epochs: 300,
            adam_lr: 1e-3,
            seed: 0,
            log_every: 10,
        }
    }
}

/// One logged epoch.
#[derive(Clone, Debug)]
pub struct EpochLog {
    pub epoch: usize,
    /// "adam" or "lbfgs".
    pub phase: &'static str,
    pub loss: f64,
    pub lambda: f64,
    /// Cumulative training wall-clock seconds at this epoch.
    pub elapsed: f64,
}

/// Result of a training run.
pub struct TrainResult {
    pub mlp: Mlp,
    pub lambda: f64,
    pub final_loss: f64,
    pub logs: Vec<EpochLog>,
    /// Total wall-clock seconds.
    pub seconds: f64,
    /// Forward-only / forward+backward evaluation counts.
    pub n_forward: u64,
    pub n_backward: u64,
    pub engine: DerivEngine,
    pub profile: BurgersProfile,
}

impl TrainResult {
    /// |λ - 1/(2k)| — the inverse-problem error metric of the appendix.
    pub fn lambda_error(&self) -> f64 {
        (self.lambda - self.profile.lambda_smooth()).abs()
    }

    /// L2 error of `u` against the true profile on a fresh grid.
    pub fn solution_l2_error(&self, n_pts: usize) -> f64 {
        let xs = super::collocation::grid_points(-1.5, 1.5, n_pts);
        let u = self.mlp.forward(&xs);
        let mut acc = 0.0;
        for (i, &x) in xs.data().iter().enumerate() {
            let d = u.data()[i] - self.profile.u_true(x);
            acc += d * d;
        }
        (acc / n_pts as f64).sqrt()
    }
}

/// Train a PINN for the k-th Burgers profile with the chosen derivative
/// engine. This is the end-to-end driver behind Figs 6-10.
pub fn train_burgers(
    spec: BurgersLossSpec,
    cfg: &TrainConfig,
    engine: DerivEngine,
) -> TrainResult {
    let profile = spec.profile;
    let mut rng = Prng::seeded(cfg.seed);
    let mlp = Mlp::uniform_with(1, cfg.width, cfg.depth, 1, cfg.activation, &mut rng);
    let mut obj = PinnObjective::build(spec, &mlp, engine, &mut rng);
    let mut theta = obj.theta_init(&mlp);

    let mut logs = Vec::new();
    let start = Instant::now();
    let mut log = |obj: &PinnObjective, epoch, phase, loss, theta: &Tensor, force: bool| {
        if force || epoch % cfg.log_every == 0 {
            logs.push(EpochLog {
                epoch,
                phase,
                loss,
                lambda: obj.lambda_of(theta),
                elapsed: start.elapsed().as_secs_f64(),
            });
        }
    };

    // Phase 1: Adam.
    let mut adam = Adam::new(obj.dim(), cfg.adam_lr);
    for epoch in 0..cfg.adam_epochs {
        let loss = adam.step(&mut obj, &mut theta);
        log(&obj, epoch, "adam", loss, &theta, epoch + 1 == cfg.adam_epochs);
    }

    // Phase 2: L-BFGS with (forward-only) backtracking line search.
    let mut lbfgs = Lbfgs::new(obj.dim());
    let mut last_loss = f64::INFINITY;
    for epoch in 0..cfg.lbfgs_epochs {
        let (loss, status) = lbfgs.step(&mut obj, &mut theta);
        last_loss = loss;
        log(
            &obj,
            cfg.adam_epochs + epoch,
            "lbfgs",
            loss,
            &theta,
            epoch + 1 == cfg.lbfgs_epochs,
        );
        if status == LbfgsStatus::Converged {
            break;
        }
    }

    let seconds = start.elapsed().as_secs_f64();
    TrainResult {
        mlp: obj.mlp_of(&theta),
        lambda: obj.lambda_of(&theta),
        final_loss: if last_loss.is_finite() {
            last_loss
        } else {
            obj.value(&theta)
        },
        logs,
        seconds,
        n_forward: obj.n_forward,
        n_backward: obj.n_backward,
        engine,
        profile,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> TrainConfig {
        TrainConfig {
            width: 12,
            depth: 2,
            activation: ActivationKind::Tanh,
            adam_epochs: 150,
            lbfgs_epochs: 120,
            adam_lr: 2e-3,
            seed: 3,
            log_every: 10,
        }
    }

    fn quick_spec() -> BurgersLossSpec {
        let mut spec = BurgersLossSpec::for_profile(1);
        spec.n_res = 48;
        spec.n_org = 12;
        spec.x_max = 1.5;
        spec
    }

    #[test]
    fn short_training_reduces_loss_and_moves_lambda() {
        let result = train_burgers(quick_spec(), &quick_cfg(), DerivEngine::Ntp);
        let first = result.logs.first().unwrap();
        let last = result.logs.last().unwrap();
        assert!(
            last.loss < first.loss * 0.1,
            "loss {} -> {}",
            first.loss,
            last.loss
        );
        // λ should move toward 1/2 from the bracket midpoint (2/3).
        let lam_err_start = (first.lambda - 0.5).abs();
        assert!(
            result.lambda_error() < lam_err_start,
            "λ error {} (start {lam_err_start})",
            result.lambda_error()
        );
        // Counts recorded: L-BFGS must have used forward-only evals.
        assert!(result.n_forward > 0 && result.n_backward > 0);
    }

    #[test]
    fn engines_produce_identical_trajectories() {
        // Same seed ⇒ identical collocation, init and (exact) derivatives,
        // so the *training trajectory* must match between engines — the
        // strongest exactness statement for the end-to-end system.
        let mut cfg = quick_cfg();
        cfg.adam_epochs = 30;
        cfg.lbfgs_epochs = 10;
        let a = train_burgers(quick_spec(), &cfg, DerivEngine::Ntp);
        let b = train_burgers(quick_spec(), &cfg, DerivEngine::Autodiff);
        assert!(
            (a.final_loss - b.final_loss).abs() < 1e-6 * b.final_loss.abs().max(1e-9),
            "{} vs {}",
            a.final_loss,
            b.final_loss
        );
        assert!((a.lambda - b.lambda).abs() < 1e-7);
    }

    #[test]
    fn logs_are_monotone_in_epoch_and_time() {
        let result = train_burgers(quick_spec(), &quick_cfg(), DerivEngine::Ntp);
        for w in result.logs.windows(2) {
            assert!(w[1].epoch > w[0].epoch);
            assert!(w[1].elapsed >= w[0].elapsed);
        }
        assert_eq!(result.logs.last().unwrap().phase, "lbfgs");
    }
}
