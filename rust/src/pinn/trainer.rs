//! Two-phase PINN trainer (Adam exploration → L-BFGS refinement), the
//! paper's training schedule for the self-similar Burgers profiles, with
//! per-epoch logging of loss, λ and wall-clock — everything Figs 6-10 need.
//!
//! Two entry points share the schedule:
//!
//! - [`train_burgers`] — the monolithic single-tape objective
//!   ([`PinnObjective`]), the seed behaviour.
//! - [`train_burgers_parallel`] — the sharded data-parallel objective
//!   ([`ParallelObjective`]): gradient accumulation over fixed collocation
//!   chunks on a [`ParallelPolicy`]-sized worker pool, bitwise
//!   reproducible for every policy (CLI: `ntangent train --threads N`).

use super::burgers::BurgersProfile;
use super::loss::{BurgersLossSpec, DerivEngine, PinnObjective};
use super::multi::{MultiObjective, MultiPinnSpec};
use super::parallel::ParallelObjective;
use super::resilience::{probe_step, FaultKind, NumericError, ResilienceConfig, RunHealth};
use super::telemetry::{StepRecord, TelemetryWriter};
use crate::nn::{AdamResume, Checkpoint, LbfgsResume, Mlp, ResumePhase, ResumeState};
use crate::ntp::{ActivationKind, EstimatorMode, ParallelPolicy};
use crate::opt::{Adam, Lbfgs, LbfgsStatus, Objective};
use crate::pde::PdeProblem;
use crate::tensor::Tensor;
use crate::util::prng::Prng;
use std::time::Instant;

/// Training configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Hidden-layer width.
    pub width: usize,
    /// Number of hidden layers.
    pub depth: usize,
    /// Hidden activation of the PINN (tanh is the paper's choice; sine
    /// gives SIREN-style spectral behaviour, softplus/GELU are the other
    /// registered smooth towers).
    pub activation: ActivationKind,
    /// Adam (exploration) epochs.
    pub adam_epochs: usize,
    /// L-BFGS (refinement) epochs.
    pub lbfgs_epochs: usize,
    /// Adam learning rate.
    pub adam_lr: f64,
    /// PRNG seed (network init + collocation sampling).
    pub seed: u64,
    /// Record a log entry every `log_every` epochs (and always the last).
    pub log_every: usize,
    /// Worker-thread policy for the data-parallel training path (used by
    /// [`train_burgers_parallel`] for shard evaluation and by the
    /// optimizers for their deterministic reductions). Purely a
    /// scheduling knob: any policy produces bitwise-identical results.
    pub policy: ParallelPolicy,
    /// Collocation rows per shard for [`train_burgers_parallel`].
    pub chunk: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        // CPU-scaled defaults; the paper's A6000 schedule is 15k + 30k
        // (reachable here via --adam-epochs/--lbfgs-epochs).
        TrainConfig {
            width: 24,
            depth: 3,
            activation: ActivationKind::Tanh,
            adam_epochs: 300,
            lbfgs_epochs: 300,
            adam_lr: 1e-3,
            seed: 0,
            log_every: 10,
            policy: ParallelPolicy::Serial,
            chunk: super::parallel::DEFAULT_CHUNK_ROWS,
        }
    }
}

/// One logged epoch.
#[derive(Clone, Debug)]
pub struct EpochLog {
    /// Global epoch index (Adam epochs count from 0, L-BFGS continues).
    pub epoch: usize,
    /// "adam" or "lbfgs".
    pub phase: &'static str,
    /// Loss at the start of the epoch.
    pub loss: f64,
    /// Inverse parameter λ after the epoch.
    pub lambda: f64,
    /// Cumulative training wall-clock seconds at this epoch.
    pub elapsed: f64,
}

/// Result of a training run.
pub struct TrainResult {
    /// The trained network.
    pub mlp: Mlp,
    /// Final inverse parameter λ.
    pub lambda: f64,
    /// Final loss.
    pub final_loss: f64,
    /// Per-epoch log entries.
    pub logs: Vec<EpochLog>,
    /// Total wall-clock seconds.
    pub seconds: f64,
    /// Forward-only evaluation count.
    pub n_forward: u64,
    /// Forward+backward evaluation count.
    pub n_backward: u64,
    /// The derivative engine that computed the channels.
    pub engine: DerivEngine,
    /// The Burgers profile trained against.
    pub profile: BurgersProfile,
    /// Numeric-health record (guards, recovery, interruption).
    pub health: RunHealth,
}

impl TrainResult {
    /// |λ - 1/(2k)| — the inverse-problem error metric of the appendix.
    pub fn lambda_error(&self) -> f64 {
        (self.lambda - self.profile.lambda_smooth()).abs()
    }

    /// L2 error of `u` against the true profile on a fresh grid.
    pub fn solution_l2_error(&self, n_pts: usize) -> f64 {
        let xs = super::collocation::grid_points(-1.5, 1.5, n_pts);
        let u = self.mlp.forward(&xs);
        let mut acc = 0.0;
        for (i, &x) in xs.data().iter().enumerate() {
            let d = u.data()[i] - self.profile.u_true(x);
            acc += d * d;
        }
        (acc / n_pts as f64).sqrt()
    }
}

/// An [`Objective`] plus the PINN accessors the two-phase schedule needs
/// (λ extraction, network reconstruction, evaluation counters).
///
/// Implemented by the monolithic [`PinnObjective`] and the sharded
/// [`ParallelObjective`], so both drive the identical schedule.
pub trait TrainableObjective: Objective {
    /// λ extracted from the flat parameter vector.
    fn lambda_at(&self, theta: &Tensor) -> f64;
    /// The network part of `theta` as an [`Mlp`].
    fn network_at(&self, theta: &Tensor) -> Mlp;
    /// Initial flat parameter vector for `mlp`.
    fn init_theta(&self, mlp: &Mlp) -> Tensor;
    /// `(n_forward, n_backward)` evaluation counts so far.
    fn eval_counts(&self) -> (u64, u64);
    /// Estimator draw counter for resume checkpoints (always 0 for
    /// exact objectives).
    fn estimator_step(&self) -> u64 {
        0
    }
    /// Pin the estimator draw counter without advancing it (resume
    /// hook; no-op for exact objectives).
    fn restore_estimator_step(&mut self, _step: u64) {}
}

impl TrainableObjective for PinnObjective {
    fn lambda_at(&self, theta: &Tensor) -> f64 {
        self.lambda_of(theta)
    }
    fn network_at(&self, theta: &Tensor) -> Mlp {
        self.mlp_of(theta)
    }
    fn init_theta(&self, mlp: &Mlp) -> Tensor {
        self.theta_init(mlp)
    }
    fn eval_counts(&self) -> (u64, u64) {
        (self.n_forward, self.n_backward)
    }
}

impl TrainableObjective for ParallelObjective {
    fn lambda_at(&self, theta: &Tensor) -> f64 {
        self.lambda_of(theta)
    }
    fn network_at(&self, theta: &Tensor) -> Mlp {
        self.mlp_of(theta)
    }
    fn init_theta(&self, mlp: &Mlp) -> Tensor {
        self.theta_init(mlp)
    }
    fn eval_counts(&self) -> (u64, u64) {
        (self.n_forward, self.n_backward)
    }
}

impl TrainableObjective for MultiObjective {
    /// Multivariate PDE objectives carry no inverse parameter; λ reads
    /// as 0 in the epoch logs.
    fn lambda_at(&self, _theta: &Tensor) -> f64 {
        0.0
    }
    fn network_at(&self, theta: &Tensor) -> Mlp {
        self.mlp_of(theta)
    }
    fn init_theta(&self, mlp: &Mlp) -> Tensor {
        self.theta_init(mlp)
    }
    fn eval_counts(&self) -> (u64, u64) {
        (self.n_forward, self.n_backward)
    }
    fn estimator_step(&self) -> u64 {
        self.stde_step()
    }
    fn restore_estimator_step(&mut self, step: u64) {
        MultiObjective::restore_estimator_step(self, step);
    }
}

/// Train a PINN for the k-th Burgers profile with the chosen derivative
/// engine on the monolithic single-tape objective. This is the end-to-end
/// driver behind Figs 6-10.
pub fn train_burgers(
    spec: BurgersLossSpec,
    cfg: &TrainConfig,
    engine: DerivEngine,
) -> TrainResult {
    train_burgers_resilient(spec, cfg, engine, &ResilienceConfig::default(), None)
}

/// [`train_burgers`] with an explicit [`ResilienceConfig`] (checkpoint
/// cadence, guards, recovery, fault injection) and an optional
/// [`ResumeState`] from a previous run's checkpoint.
///
/// Resuming requires the **same** `spec`/`cfg`/`engine` as the original
/// run: the collocation cloud and network init are re-derived from
/// `cfg.seed`, and only then is a restart bitwise identical to the
/// uninterrupted trajectory (`rust/tests/training_resilience.rs`).
pub fn train_burgers_resilient(
    spec: BurgersLossSpec,
    cfg: &TrainConfig,
    engine: DerivEngine,
    res: &ResilienceConfig,
    resume: Option<&ResumeState>,
) -> TrainResult {
    let profile = spec.profile;
    let mut rng = Prng::seeded(cfg.seed);
    let mlp = Mlp::uniform_with(1, cfg.width, cfg.depth, 1, cfg.activation, &mut rng);
    let obj = PinnObjective::build(spec, &mlp, engine, &mut rng);
    run_schedule(obj, &mlp, cfg, engine, profile, res, resume)
}

/// Train a PINN on the **sharded data-parallel objective**: the
/// collocation cloud is split into fixed `cfg.chunk`-row shards, each
/// epoch evaluates shard losses/gradients on a `cfg.policy`-sized worker
/// pool, and partial gradients are combined with a deterministic pairwise
/// tree reduction — so the whole 50-step-and-beyond trajectory (Adam
/// moments, L-BFGS curvature pairs, θ itself) is **bitwise identical**
/// for every policy (`rust/tests/training_determinism.rs`).
///
/// ```
/// use ntangent::ntp::ParallelPolicy;
/// use ntangent::pinn::{train_burgers_parallel, BurgersLossSpec, DerivEngine, TrainConfig};
///
/// let mut spec = BurgersLossSpec::for_profile(1);
/// spec.n_res = 16; // keep the doc-example quick
/// spec.n_org = 4;
/// let cfg = TrainConfig {
///     width: 6,
///     depth: 2,
///     adam_epochs: 3,
///     lbfgs_epochs: 2,
///     policy: ParallelPolicy::Fixed(2),
///     chunk: 8,
///     ..TrainConfig::default()
/// };
/// let result = train_burgers_parallel(spec, &cfg, DerivEngine::Ntp);
/// assert!(result.final_loss.is_finite());
/// assert_eq!(result.logs.last().unwrap().phase, "lbfgs");
/// ```
pub fn train_burgers_parallel(
    spec: BurgersLossSpec,
    cfg: &TrainConfig,
    engine: DerivEngine,
) -> TrainResult {
    train_burgers_parallel_resilient(spec, cfg, engine, &ResilienceConfig::default(), None)
}

/// [`train_burgers_parallel`] with an explicit [`ResilienceConfig`] and
/// an optional [`ResumeState`] — same resume contract as
/// [`train_burgers_resilient`], and the restart stays bitwise identical
/// for **every** `cfg.policy` (the shard layout is policy-invariant).
pub fn train_burgers_parallel_resilient(
    spec: BurgersLossSpec,
    cfg: &TrainConfig,
    engine: DerivEngine,
    res: &ResilienceConfig,
    resume: Option<&ResumeState>,
) -> TrainResult {
    let profile = spec.profile;
    let mut rng = Prng::seeded(cfg.seed);
    let mlp = Mlp::uniform_with(1, cfg.width, cfg.depth, 1, cfg.activation, &mut rng);
    let obj = ParallelObjective::build(spec, &mlp, engine, cfg.policy, cfg.chunk, &mut rng);
    run_schedule(obj, &mlp, cfg, engine, profile, res, resume)
}

/// Result of a multi-dimensional PDE training run (see [`train_pde`]).
pub struct PdeTrainResult {
    /// The trained network (`problem.dim()` inputs, one output).
    pub mlp: Mlp,
    /// Final loss.
    pub final_loss: f64,
    /// Per-epoch log entries (λ reads as 0 — no inverse parameter).
    pub logs: Vec<EpochLog>,
    /// Total wall-clock seconds.
    pub seconds: f64,
    /// Forward-only evaluation count.
    pub n_forward: u64,
    /// Forward+backward evaluation count.
    pub n_backward: u64,
    /// The derivative engine that computed the mixed partials.
    pub engine: DerivEngine,
    /// The estimator the objective evaluated its residual with.
    pub estimator: EstimatorMode,
    /// The library problem trained against.
    pub problem: PdeProblem,
    /// Numeric-health record (guards, recovery, interruption).
    pub health: RunHealth,
}

impl PdeTrainResult {
    /// RMS PDE residual `|L[u] − f|` over a fresh interior cloud. Exact
    /// runs go through the fused directional-jet engine; STDE runs use
    /// the sampled estimator at counter step 0 (the exact plan can be
    /// combinatorially intractable at the run's dimension).
    pub fn residual_rms(&self, n_pts: usize, seed: u64) -> f64 {
        let mut rng = Prng::seeded(seed);
        let x = self.problem.sample_interior(n_pts, &mut rng);
        let r = match self.estimator.stde_config() {
            None => {
                super::multi::residual_values(self.problem, &self.mlp, &x, ParallelPolicy::Serial)
            }
            Some(cfg) => super::multi::residual_values_estimated(
                self.problem,
                &self.mlp,
                &x,
                cfg,
                0,
                ParallelPolicy::Serial,
            ),
        };
        (r.data().iter().map(|v| v * v).sum::<f64>() / n_pts as f64).sqrt()
    }

    /// L2 error of `u` against the exact solution over a fresh interior
    /// cloud.
    pub fn solution_l2_error(&self, n_pts: usize, seed: u64) -> f64 {
        let mut rng = Prng::seeded(seed);
        let x = self.problem.sample_interior(n_pts, &mut rng);
        let u = self.mlp.forward(&x);
        let truth = self.problem.u_exact_rows(&x);
        let acc: f64 = u
            .data()
            .iter()
            .zip(truth.data())
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        (acc / n_pts as f64).sqrt()
    }
}

/// Train a PINN against a library PDE problem on the sharded
/// multivariate objective ([`MultiObjective`]) with the same two-phase
/// Adam → L-BFGS schedule as the Burgers drivers
/// (`ntangent train --pde <name>`). Bitwise reproducible for every
/// `cfg.policy`, like every sharded trainer in this module.
pub fn train_pde(spec: MultiPinnSpec, cfg: &TrainConfig, engine: DerivEngine) -> PdeTrainResult {
    train_pde_with_estimator(spec, cfg, engine, EstimatorMode::Exact)
}

/// [`train_pde`] with an explicit [`EstimatorMode`] — the entry point of
/// the high-dimensional STDE runs (`ntangent train --pde heat100d
/// --estimator stde`). Stochastic runs resample the operator term set
/// every gradient step from the counter-based stream; trajectories stay
/// bitwise identical for every `cfg.policy`
/// (`rust/tests/stde_determinism.rs`).
pub fn train_pde_with_estimator(
    spec: MultiPinnSpec,
    cfg: &TrainConfig,
    engine: DerivEngine,
    estimator: EstimatorMode,
) -> PdeTrainResult {
    train_pde_resilient(spec, cfg, engine, estimator, &ResilienceConfig::default(), None)
}

/// [`train_pde_with_estimator`] with an explicit [`ResilienceConfig`]
/// and an optional [`ResumeState`]. STDE runs serialize their draw
/// counter in the checkpoint and re-pin it on resume, so even the
/// stochastic trajectories restart bitwise identical to the
/// uninterrupted run.
pub fn train_pde_resilient(
    spec: MultiPinnSpec,
    cfg: &TrainConfig,
    engine: DerivEngine,
    estimator: EstimatorMode,
    res: &ResilienceConfig,
    resume: Option<&ResumeState>,
) -> PdeTrainResult {
    let problem = spec.problem;
    let mut rng = Prng::seeded(cfg.seed);
    let mlp = Mlp::uniform_with(
        problem.dim(),
        cfg.width,
        cfg.depth,
        1,
        cfg.activation,
        &mut rng,
    );
    let obj = MultiObjective::build_with_estimator(
        spec, &mlp, engine, cfg.policy, cfg.chunk, &mut rng, estimator,
    );
    let mut run = schedule_resilient(obj, &mlp, cfg, res, resume);
    let final_loss = if run.last_loss.is_finite() {
        run.last_loss
    } else {
        run.obj.value(&run.theta)
    };
    let (n_forward, n_backward) = run.obj.eval_counts();
    PdeTrainResult {
        mlp: run.obj.network_at(&run.theta),
        final_loss,
        logs: run.logs,
        seconds: run.seconds,
        n_forward,
        n_backward,
        engine,
        estimator,
        problem,
        health: run.health,
    }
}

/// Everything the two-phase schedule produces, before it is wrapped
/// into a problem-specific result.
struct ScheduleRun<O> {
    obj: O,
    theta: Tensor,
    logs: Vec<EpochLog>,
    seconds: f64,
    last_loss: f64,
    health: RunHealth,
}

/// Wrap a finished schedule into the Burgers [`TrainResult`].
fn run_schedule<O: TrainableObjective>(
    obj: O,
    mlp: &Mlp,
    cfg: &TrainConfig,
    engine: DerivEngine,
    profile: BurgersProfile,
    res: &ResilienceConfig,
    resume: Option<&ResumeState>,
) -> TrainResult {
    let baseline = obj.eval_counts();
    let run = schedule_resilient(obj, mlp, cfg, res, resume);
    finish_burgers_run(run, engine, profile, baseline).0
}

/// Package a finished schedule as a [`TrainResult`] and hand the
/// objective back for reuse. `baseline` is the objective's evaluation
/// counters on entry, so reused objectives report **per-run** counts.
fn finish_burgers_run<O: TrainableObjective>(
    mut run: ScheduleRun<O>,
    engine: DerivEngine,
    profile: BurgersProfile,
    baseline: (u64, u64),
) -> (TrainResult, O) {
    let final_loss = if run.last_loss.is_finite() {
        run.last_loss
    } else {
        run.obj.value(&run.theta)
    };
    let (n_forward, n_backward) = run.obj.eval_counts();
    let result = TrainResult {
        mlp: run.obj.network_at(&run.theta),
        lambda: run.obj.lambda_at(&run.theta),
        final_loss,
        logs: run.logs,
        seconds: run.seconds,
        n_forward: n_forward - baseline.0,
        n_backward: n_backward - baseline.1,
        engine,
        profile,
        health: run.health,
    };
    (result, run.obj)
}

/// Drive the schedule on an **already built** sharded objective and
/// return it alongside the result, so training sweeps reuse one shard
/// pool (the per-chunk compiled tapes — the dominant per-run build
/// cost) across runs instead of rebuilding it per run
/// ([`crate::bench::profiles::run_sweep`]; the ROADMAP carried sweep
/// debt). `mlp` must be the network the objective was built from. The
/// objective's policy is aligned to `cfg.policy` — a pure scheduling
/// change — and the trajectory is bitwise identical to
/// [`train_burgers_parallel_resilient`] on a fresh build.
pub fn train_burgers_sharded(
    mut obj: ParallelObjective,
    mlp: &Mlp,
    cfg: &TrainConfig,
    res: &ResilienceConfig,
    resume: Option<&ResumeState>,
) -> (TrainResult, ParallelObjective) {
    obj.set_policy(cfg.policy);
    let profile = obj.spec.profile;
    let engine = obj.engine;
    let baseline = obj.eval_counts();
    let run = schedule_resilient(obj, mlp, cfg, res, resume);
    finish_burgers_run(run, engine, profile, baseline)
}

/// Capture the full mid-trajectory state as a [`ResumeState`] (the
/// in-memory rollback snapshot, and the payload of every on-disk
/// checkpoint).
#[allow(clippy::too_many_arguments)]
fn snapshot_of<O: TrainableObjective>(
    obj: &O,
    theta: &Tensor,
    phase: ResumePhase,
    epoch: usize,
    adam: Option<&Adam>,
    lbfgs: Option<&Lbfgs>,
    retries: u64,
    ls_failures: u64,
    lr_scale: f64,
) -> ResumeState {
    let adam = adam.map(|a| {
        let (m, v, t) = a.export_state();
        AdamResume { m, v, t }
    });
    let lbfgs = lbfgs.map(|l| {
        let (s, y, last_grad) = l.export_state();
        LbfgsResume { s, y, last_grad }
    });
    ResumeState {
        phase,
        epoch,
        theta: theta.data().to_vec(),
        adam,
        lbfgs,
        stde_step: obj.estimator_step(),
        retries,
        ls_failures,
        lr_scale,
    }
}

/// Atomically persist a snapshot as a checkpoint (network weights from
/// the snapshot's θ plus the full resume state). Write failures degrade
/// durability, not the trajectory: the first one is recorded in the
/// health report and the run continues.
fn write_checkpoint<O: TrainableObjective>(
    obj: &O,
    snap: &ResumeState,
    res: &ResilienceConfig,
    health: &mut RunHealth,
) {
    let Some(path) = &res.checkpoint_path else {
        return;
    };
    let theta = Tensor::from_vec(snap.theta.clone(), &[snap.theta.len()]);
    let mut ck = Checkpoint::from_mlp(&obj.network_at(&theta));
    ck.resume = Some(snap.clone());
    if let Err(e) = ck.save(path) {
        if health.checkpoint_error.is_none() {
            health.checkpoint_error = Some(format!("{e:#}"));
        }
    }
}

/// The shared two-phase schedule: Adam exploration, then L-BFGS with a
/// forward-only backtracking line search. Both optimizers run with
/// `cfg.policy` so their reductions/updates stay thread-count-invariant.
///
/// This is the **resilient** schedule:
///
/// - every step's loss/gradient/θ are probed with the SIMD
///   [`crate::simd::Isa::all_finite`] reduction (read-only — healthy
///   trajectories are bit-for-bit unaffected);
/// - on a tripped probe it rolls back to the last in-memory snapshot and
///   applies the deterministic intervention schedule (Adam learning rate
///   scaled by `lr_backoff^retries`; L-BFGS curvature memory dropped),
///   aborting cleanly after `max_retries` with the last-good checkpoint
///   written;
/// - snapshots are serialized to `checkpoint_path` on the configured
///   cadence, and a `resume` state restarts the trajectory **bitwise
///   identical** to never having stopped, for any thread count and
///   either estimator mode;
/// - the [`super::resilience::FaultPlan`] hook injects NaNs or a
///   simulated crash at configured epochs so every one of these paths is
///   testable.
fn schedule_resilient<O: TrainableObjective>(
    mut obj: O,
    mlp: &Mlp,
    cfg: &TrainConfig,
    res: &ResilienceConfig,
    resume: Option<&ResumeState>,
) -> ScheduleRun<O> {
    let mut theta = match resume {
        Some(r) => Tensor::from_vec(r.theta.clone(), &[r.theta.len()]),
        None => obj.init_theta(mlp),
    };
    assert_eq!(
        theta.numel(),
        obj.dim(),
        "resume state does not match the objective dimension"
    );

    let mut fault = res.fault.clone();
    let mut health = RunHealth::default();
    let mut retries = resume.map_or(0, |r| r.retries);
    let mut ls_failures = resume.map_or(0, |r| r.ls_failures);
    let mut lr_scale = resume.map_or(1.0, |r| r.lr_scale);
    if let Some(r) = resume {
        obj.restore_estimator_step(r.stde_step);
    }
    health.retries = retries;

    let (start_phase, start_epoch) =
        resume.map_or((ResumePhase::Adam, 0), |r| (r.phase, r.epoch));

    let mut logs = Vec::new();
    let start = Instant::now();
    // Pure observer: it reads values the schedule already computed and
    // never feeds anything back, so the trajectory is bitwise identical
    // with or without a telemetry path (`rust/tests/obs_overhead.rs`).
    let mut telemetry = TelemetryWriter::create(res.telemetry_path.as_deref());
    let log = |logs: &mut Vec<EpochLog>, obj: &O, epoch, phase, loss, theta: &Tensor, force: bool| {
        if force || epoch % cfg.log_every == 0 {
            logs.push(EpochLog {
                epoch,
                phase,
                loss,
                lambda: obj.lambda_at(theta),
                elapsed: start.elapsed().as_secs_f64(),
            });
        }
    };
    let restore_theta = |snap: &ResumeState| Tensor::from_vec(snap.theta.clone(), &[snap.theta.len()]);

    let mut last_loss = f64::INFINITY;

    // Phase 1: Adam.
    if start_phase == ResumePhase::Adam {
        let mut adam = Adam::new(obj.dim(), cfg.adam_lr * lr_scale).with_policy(cfg.policy);
        if let Some(a) = resume.and_then(|r| r.adam.as_ref()) {
            adam.restore_state(&a.m, &a.v, a.t);
        }
        let mut snap = snapshot_of(
            &obj, &theta, ResumePhase::Adam, start_epoch, Some(&adam), None,
            retries, ls_failures, lr_scale,
        );
        let mut epoch = start_epoch;
        while epoch < cfg.adam_epochs {
            if fault.take(FaultKind::Kill, epoch) {
                // Simulated crash: stop without writing anything further.
                health.interrupted = true;
                health.retries = retries;
                let seconds = start.elapsed().as_secs_f64();
                return ScheduleRun { obj, theta, logs, seconds, last_loss: f64::NAN, health };
            }
            let step_start = Instant::now();
            let (mut loss, mut grad) = obj.value_grad(&theta);
            if fault.take(FaultKind::NanLoss, epoch) {
                loss = f64::NAN;
            }
            if fault.take(FaultKind::NanGrad, epoch) {
                grad.data_mut()[0] = f64::NAN;
            }
            adam.apply(&mut theta, &grad);
            if res.guard {
                if let Some(err) = probe_step(loss, Some(grad.data()), theta.data(), epoch) {
                    retries += 1;
                    health.retries = retries;
                    if retries > res.max_retries {
                        // Clean abort at the last-good state.
                        theta = restore_theta(&snap);
                        obj.restore_estimator_step(snap.stde_step);
                        write_checkpoint(&obj, &snap, res, &mut health);
                        health.aborted = Some(err);
                        let seconds = start.elapsed().as_secs_f64();
                        return ScheduleRun {
                            obj, theta, logs, seconds, last_loss: f64::NAN, health,
                        };
                    }
                    // Deterministic intervention: roll back to the
                    // snapshot and back the learning rate off — a pure
                    // function of (snapshot, retries), so recovery is as
                    // reproducible as the trajectory itself.
                    lr_scale = res.lr_backoff.powi(retries as i32);
                    theta = restore_theta(&snap);
                    match &snap.adam {
                        Some(a) => adam.restore_state(&a.m, &a.v, a.t),
                        None => adam.reset(),
                    }
                    adam.lr = cfg.adam_lr * lr_scale;
                    obj.restore_estimator_step(snap.stde_step);
                    epoch = snap.epoch;
                    snap.retries = retries;
                    snap.lr_scale = lr_scale;
                    continue;
                }
            }
            if telemetry.is_active() {
                let grad_norm = grad.data().iter().map(|g| g * g).sum::<f64>().sqrt();
                telemetry.record(&StepRecord {
                    step: epoch,
                    phase: "adam",
                    loss,
                    grad_norm: Some(grad_norm),
                    lambda: obj.lambda_at(&theta),
                    retries,
                    lr_scale,
                    step_ms: step_start.elapsed().as_secs_f64() * 1e3,
                    elapsed_s: start.elapsed().as_secs_f64(),
                });
            }
            log(&mut logs, &obj, epoch, "adam", loss, &theta, epoch + 1 == cfg.adam_epochs);
            epoch += 1;
            let take_snap = res.snapshot_every > 0 && epoch % res.snapshot_every == 0;
            let take_ck = res.checkpoint_path.is_some()
                && res.checkpoint_every > 0
                && epoch % res.checkpoint_every == 0;
            if take_snap || take_ck {
                snap = snapshot_of(
                    &obj, &theta, ResumePhase::Adam, epoch, Some(&adam), None,
                    retries, ls_failures, lr_scale,
                );
                if take_ck {
                    write_checkpoint(&obj, &snap, res, &mut health);
                }
            }
        }
    }

    // Phase 2: L-BFGS with (forward-only) backtracking line search.
    let mut lbfgs = Lbfgs::new(obj.dim()).with_policy(cfg.policy);
    let lb_start = if start_phase == ResumePhase::Lbfgs {
        if let Some(l) = resume.and_then(|r| r.lbfgs.as_ref()) {
            lbfgs.restore_state(&l.s, &l.y, l.last_grad.as_deref());
        }
        start_epoch
    } else {
        0
    };
    let mut snap = snapshot_of(
        &obj, &theta, ResumePhase::Lbfgs, lb_start, None, Some(&lbfgs),
        retries, ls_failures, lr_scale,
    );
    let mut epoch = lb_start;
    while epoch < cfg.lbfgs_epochs {
        let global = cfg.adam_epochs + epoch;
        if fault.take(FaultKind::Kill, global) {
            health.interrupted = true;
            health.retries = retries;
            let seconds = start.elapsed().as_secs_f64();
            return ScheduleRun { obj, theta, logs, seconds, last_loss: f64::NAN, health };
        }
        let step_start = Instant::now();
        let (mut loss, status) = lbfgs.step(&mut obj, &mut theta);
        if fault.take(FaultKind::NanLoss, global) {
            loss = f64::NAN;
        }
        if fault.take(FaultKind::NanGrad, global) {
            // The gradient is internal to the L-BFGS step; poison θ —
            // the same downstream effect a corrupted update would have.
            theta.data_mut()[0] = f64::NAN;
        }
        if res.guard {
            let mut err =
                probe_step(loss, lbfgs.last_grad().map(|g| g.data()), theta.data(), global);
            if err.is_none() {
                if status == LbfgsStatus::LineSearchFailed {
                    // One failure is routine (history is dropped and the
                    // next step restarts from steepest descent); two in a
                    // row means the run is stalled.
                    ls_failures += 1;
                    if ls_failures >= 2 {
                        err = Some(NumericError::LineSearchFailed { epoch: global });
                    }
                } else {
                    ls_failures = 0;
                }
            }
            if let Some(e) = err {
                retries += 1;
                health.retries = retries;
                if retries > res.max_retries {
                    theta = restore_theta(&snap);
                    obj.restore_estimator_step(snap.stde_step);
                    write_checkpoint(&obj, &snap, res, &mut health);
                    health.aborted = Some(e);
                    let seconds = start.elapsed().as_secs_f64();
                    return ScheduleRun { obj, theta, logs, seconds, last_loss: f64::NAN, health };
                }
                // Deterministic intervention: roll back and drop the
                // curvature memory (a trust-region-style restart from
                // steepest descent).
                theta = restore_theta(&snap);
                lbfgs.reset();
                obj.restore_estimator_step(snap.stde_step);
                lr_scale = res.lr_backoff.powi(retries as i32);
                ls_failures = 0;
                epoch = snap.epoch;
                snap.retries = retries;
                snap.lr_scale = lr_scale;
                snap.ls_failures = 0;
                continue;
            }
        }
        last_loss = loss;
        if telemetry.is_active() {
            telemetry.record(&StepRecord {
                step: global,
                phase: "lbfgs",
                loss,
                // L-BFGS keeps its gradient internal to the line search;
                // the last accepted gradient's norm is the honest proxy.
                grad_norm: lbfgs
                    .last_grad()
                    .map(|g| g.data().iter().map(|x| x * x).sum::<f64>().sqrt()),
                lambda: obj.lambda_at(&theta),
                retries,
                lr_scale,
                step_ms: step_start.elapsed().as_secs_f64() * 1e3,
                elapsed_s: start.elapsed().as_secs_f64(),
            });
        }
        log(
            &mut logs, &obj, global, "lbfgs", loss, &theta,
            epoch + 1 == cfg.lbfgs_epochs,
        );
        epoch += 1;
        if status == LbfgsStatus::Converged {
            break;
        }
        let take_snap = res.snapshot_every > 0 && epoch % res.snapshot_every == 0;
        let take_ck = res.checkpoint_path.is_some()
            && res.checkpoint_every > 0
            && epoch % res.checkpoint_every == 0;
        if take_snap || take_ck {
            snap = snapshot_of(
                &obj, &theta, ResumePhase::Lbfgs, epoch, None, Some(&lbfgs),
                retries, ls_failures, lr_scale,
            );
            if take_ck {
                write_checkpoint(&obj, &snap, res, &mut health);
            }
        }
    }

    // Final checkpoint: the completed trajectory (resuming it runs zero
    // further epochs and returns the identical θ).
    if res.checkpoint_path.is_some() {
        let fin = snapshot_of(
            &obj, &theta, ResumePhase::Lbfgs, epoch.max(cfg.lbfgs_epochs), None, Some(&lbfgs),
            retries, ls_failures, lr_scale,
        );
        write_checkpoint(&obj, &fin, res, &mut health);
    }

    health.retries = retries;
    let seconds = start.elapsed().as_secs_f64();
    ScheduleRun { obj, theta, logs, seconds, last_loss, health }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::params;

    fn quick_cfg() -> TrainConfig {
        TrainConfig {
            width: 12,
            depth: 2,
            activation: ActivationKind::Tanh,
            adam_epochs: 150,
            lbfgs_epochs: 120,
            adam_lr: 2e-3,
            seed: 3,
            log_every: 10,
            ..TrainConfig::default()
        }
    }

    fn quick_spec() -> BurgersLossSpec {
        let mut spec = BurgersLossSpec::for_profile(1);
        spec.n_res = 48;
        spec.n_org = 12;
        spec.x_max = 1.5;
        spec
    }

    #[test]
    fn short_training_reduces_loss_and_moves_lambda() {
        let result = train_burgers(quick_spec(), &quick_cfg(), DerivEngine::Ntp);
        let first = result.logs.first().unwrap();
        let last = result.logs.last().unwrap();
        assert!(
            last.loss < first.loss * 0.1,
            "loss {} -> {}",
            first.loss,
            last.loss
        );
        // λ should move toward 1/2 from the bracket midpoint (2/3).
        let lam_err_start = (first.lambda - 0.5).abs();
        assert!(
            result.lambda_error() < lam_err_start,
            "λ error {} (start {lam_err_start})",
            result.lambda_error()
        );
        // Counts recorded: L-BFGS must have used forward-only evals.
        assert!(result.n_forward > 0 && result.n_backward > 0);
    }

    #[test]
    fn engines_produce_identical_trajectories() {
        // Same seed ⇒ identical collocation, init and (exact) derivatives,
        // so the *training trajectory* must match between engines — the
        // strongest exactness statement for the end-to-end system.
        let mut cfg = quick_cfg();
        cfg.adam_epochs = 30;
        cfg.lbfgs_epochs = 10;
        let a = train_burgers(quick_spec(), &cfg, DerivEngine::Ntp);
        let b = train_burgers(quick_spec(), &cfg, DerivEngine::Autodiff);
        assert!(
            (a.final_loss - b.final_loss).abs() < 1e-6 * b.final_loss.abs().max(1e-9),
            "{} vs {}",
            a.final_loss,
            b.final_loss
        );
        assert!((a.lambda - b.lambda).abs() < 1e-7);
    }

    #[test]
    fn logs_are_monotone_in_epoch_and_time() {
        let result = train_burgers(quick_spec(), &quick_cfg(), DerivEngine::Ntp);
        for w in result.logs.windows(2) {
            assert!(w[1].epoch > w[0].epoch);
            assert!(w[1].elapsed >= w[0].elapsed);
        }
        assert_eq!(result.logs.last().unwrap().phase, "lbfgs");
    }

    /// The sharded trainer follows (numerically) the same optimization as
    /// the monolithic one: same seed ⇒ same init and collocation, and the
    /// trajectories only differ by floating-point summation order, so the
    /// short-run results must agree to tight tolerance.
    #[test]
    fn parallel_trainer_tracks_monolithic_trainer() {
        let mut cfg = quick_cfg();
        cfg.adam_epochs = 25;
        cfg.lbfgs_epochs = 0;
        let mono = train_burgers(quick_spec(), &cfg, DerivEngine::Ntp);
        let shd = train_burgers_parallel(quick_spec(), &cfg, DerivEngine::Ntp);
        assert!(
            (mono.final_loss - shd.final_loss).abs()
                < 1e-6 * mono.final_loss.abs().max(1e-9),
            "{} vs {}",
            mono.final_loss,
            shd.final_loss
        );
        assert!((mono.lambda - shd.lambda).abs() < 1e-7);
        let wa = params::flatten(&mono.mlp);
        let wb = params::flatten(&shd.mlp);
        assert!(
            crate::util::allclose_slice(wa.data(), wb.data(), 1e-6, 1e-8),
            "weights diverged: max {}",
            crate::util::max_abs_diff(wa.data(), wb.data())
        );
    }

    /// Short end-to-end multivariate run: the PDE trainer drives the
    /// same schedule and makes progress on a 2-D problem.
    #[test]
    fn pde_training_reduces_loss() {
        let spec = MultiPinnSpec {
            problem: PdeProblem::Poisson2d,
            n_interior: 48,
            n_boundary: 16,
            w_residual: 1.0,
            w_bc: 10.0,
        };
        let cfg = TrainConfig {
            width: 10,
            depth: 2,
            adam_epochs: 120,
            lbfgs_epochs: 60,
            adam_lr: 2e-3,
            seed: 4,
            ..TrainConfig::default()
        };
        let result = train_pde(spec, &cfg, DerivEngine::Ntp);
        let first = result.logs.first().unwrap();
        let last = result.logs.last().unwrap();
        assert!(
            last.loss < first.loss * 0.5,
            "loss {} -> {}",
            first.loss,
            last.loss
        );
        assert!(result.residual_rms(64, 1).is_finite());
        assert!(result.solution_l2_error(64, 2).is_finite());
        assert!(result.n_forward > 0 && result.n_backward > 0);
        assert_eq!(result.problem, PdeProblem::Poisson2d);
    }

    /// Short end-to-end parallel run: loss decreases and the logs carry
    /// both phases, exactly as for the monolithic trainer.
    #[test]
    fn parallel_training_reduces_loss() {
        let mut cfg = quick_cfg();
        cfg.adam_epochs = 80;
        cfg.lbfgs_epochs = 40;
        cfg.policy = ParallelPolicy::Fixed(2);
        cfg.chunk = 16;
        let result = train_burgers_parallel(quick_spec(), &cfg, DerivEngine::Ntp);
        let first = result.logs.first().unwrap();
        let last = result.logs.last().unwrap();
        assert!(
            last.loss < first.loss * 0.5,
            "loss {} -> {}",
            first.loss,
            last.loss
        );
        assert!(result.n_forward > 0 && result.n_backward > 0);
    }
}
