//! The self-similar Burgers profile problem (paper §IV-C1).
//!
//! Under `u(x,t) = (1-t)^λ U(x(1-t)^{-1-λ})` Burgers' equation becomes the
//! profile ODE
//!
//! ```text
//! -λ U + ((1+λ) X + U) U' = 0                                  (7)
//! ```
//!
//! with implicit solution `X = -U - C·U^{1 + 1/λ}` (8). Smooth, odd
//! (physically realizable) profiles exist exactly at `λ = 1/(2k)`:
//! `X = -U - C·U^{2k+1}`. The k-th profile is found by a PINN constrained
//! to `λ ∈ [1/(2k+1), 1/(2k-1)]` with a smoothness penalty on the
//! `2k`-th derivative of the residual near the origin — requiring
//! `2k+1` derivatives of the network, which is what makes this the
//! paper's showcase for n-TangentProp (profiles 3 and 4 are infeasible
//! with repeated autodiff).
//!
//! Ground truth: the implicit relation is solved by a safeguarded Newton
//! iteration, and *exact* higher derivatives come from power-series
//! reversion of the polynomial relation (see [`super::series`]) — no
//! finite differences anywhere.

use super::series;

/// The k-th smooth self-similar Burgers profile (k = 1, 2, 3, 4, ...).
#[derive(Clone, Copy, Debug)]
pub struct BurgersProfile {
    /// Profile index; the smooth exponent is `λ = 1/(2k)`.
    pub k: usize,
    /// Normalization constant `C > 0` of the family member (we pin C = 1;
    /// the paper's normalization is equivalent up to rescaling).
    pub c: f64,
}

impl BurgersProfile {
    /// The `k`-th self-similar profile (`k = 1..=4` in the paper).
    pub fn new(k: usize) -> BurgersProfile {
        assert!(k >= 1, "profile index starts at 1");
        BurgersProfile { k, c: 1.0 }
    }

    /// The smooth exponent `λ = 1/(2k)` this profile converges to.
    pub fn lambda_smooth(&self) -> f64 {
        1.0 / (2 * self.k) as f64
    }

    /// The λ search range `[1/(2k+1), 1/(2k-1)]` (paper §IV-C1).
    pub fn lambda_range(&self) -> (f64, f64) {
        (
            1.0 / (2 * self.k + 1) as f64,
            1.0 / (2 * self.k - 1) as f64,
        )
    }

    /// Number of network derivatives the training loss needs: the
    /// smoothness term penalizes `∂^{2k} R`, and `R` contains `U'`,
    /// so `n = 2k + 1` (3, 5, 7, 9 for k = 1..4 — matching the paper).
    pub fn n_derivs(&self) -> usize {
        2 * self.k + 1
    }

    /// Degree of the implicit polynomial: `X = -U - C·U^{2k+1}`.
    pub fn poly_degree(&self) -> usize {
        2 * self.k + 1
    }

    /// `X(U) = -U - C·U^{2k+1}`.
    pub fn x_of_u(&self, u: f64) -> f64 {
        -u - self.c * u.powi(self.poly_degree() as i32)
    }

    /// `dX/dU = -1 - C·(2k+1)·U^{2k}` (always ≤ -1: X(U) strictly
    /// decreasing, so U(X) is single-valued and strictly decreasing).
    pub fn dx_du(&self, u: f64) -> f64 {
        -1.0 - self.c * self.poly_degree() as f64 * u.powi((self.poly_degree() - 1) as i32)
    }

    /// Solve `X = -U - C·U^{2k+1}` for `U` (safeguarded Newton; exact to
    /// ~1e-14). The profile is odd: `U(-X) = -U(X)`.
    pub fn u_true(&self, x: f64) -> f64 {
        if x == 0.0 {
            return 0.0;
        }
        // U(X) has sign opposite to X; bracket accordingly.
        let (mut lo, mut hi) = if x > 0.0 {
            // U in [-(x+1), 0]: X(-(x+1)) = (x+1) + C(x+1)^(2k+1) >= x.
            (-(x + 1.0), 0.0)
        } else {
            (0.0, -x + 1.0)
        };
        let mut u = -x / (1.0 + self.c); // decent initial guess near 0
        if !(lo..=hi).contains(&u) {
            u = 0.5 * (lo + hi);
        }
        for _ in 0..100 {
            let f = self.x_of_u(u) - x;
            if f.abs() < 1e-15 * (1.0 + x.abs()) {
                break;
            }
            // Maintain the bracket: X(U) is decreasing in U.
            if f > 0.0 {
                lo = u;
            } else {
                hi = u;
            }
            let step = f / self.dx_du(u);
            let next = u - step;
            u = if next > lo && next < hi {
                next
            } else {
                0.5 * (lo + hi)
            };
        }
        u
    }

    /// Exact derivatives `[U, U', ..., U^(n)]` at `x`, via power-series
    /// reversion of the implicit polynomial around the solution point.
    pub fn derivatives_true(&self, x: f64, n: usize) -> Vec<f64> {
        let u0 = self.u_true(x);
        // Local series of X(U) around u0: X(u0 + v) = x + Σ_{m>=1} a_m v^m.
        let deg = self.poly_degree();
        let mut poly = vec![0.0; deg + 1];
        poly[1] = -1.0;
        poly[deg] = -self.c;
        let shifted = series::shift_poly(&poly, u0, n + 2);
        // Zero the constant term (it equals x) to get the series of X - x.
        let mut a = shifted;
        a[0] = 0.0;
        if a.len() < 2 {
            a.resize(2, 0.0);
        }
        // Revert: v(X - x) series, then derivatives are k!·b_k.
        let b = series::revert(&a, n + 1);
        let mut derivs = series::derivatives_from_taylor(&b[..=n.min(b.len() - 1)]);
        derivs[0] = u0;
        derivs.resize(n + 1, 0.0);
        derivs
    }

    /// Residual of the profile ODE (7) given `U, U'` at `x` and `λ`.
    pub fn residual(&self, lambda: f64, x: f64, u: f64, du: f64) -> f64 {
        -lambda * u + ((1.0 + lambda) * x + u) * du
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest;

    #[test]
    fn lambda_values_match_paper() {
        for (k, lam, range) in [
            (1, 0.5, (1.0 / 3.0, 1.0)),
            (2, 0.25, (0.2, 1.0 / 3.0)),
            (3, 1.0 / 6.0, (1.0 / 7.0, 0.2)),
            (4, 0.125, (1.0 / 9.0, 1.0 / 7.0)),
        ] {
            let p = BurgersProfile::new(k);
            assert!((p.lambda_smooth() - lam).abs() < 1e-15);
            let (lo, hi) = p.lambda_range();
            assert!((lo - range.0).abs() < 1e-15 && (hi - range.1).abs() < 1e-15);
        }
        assert_eq!(BurgersProfile::new(1).n_derivs(), 3);
        assert_eq!(BurgersProfile::new(4).n_derivs(), 9);
    }

    #[test]
    fn u_true_satisfies_implicit_relation() {
        ptest::quickcheck(
            |rng| {
                let k = 1 + rng.below(4) as usize;
                let x = rng.uniform_in(-10.0, 10.0);
                (k, x)
            },
            |&(k, x)| {
                let p = BurgersProfile::new(k);
                let u = p.u_true(x);
                let back = p.x_of_u(u);
                if (back - x).abs() < 1e-10 * (1.0 + x.abs()) {
                    Ok(())
                } else {
                    Err(format!("X(U({x})) = {back}"))
                }
            },
        );
    }

    #[test]
    fn profile_is_odd_and_decreasing() {
        let p = BurgersProfile::new(2);
        for x in [0.1, 0.5, 1.0, 3.0] {
            assert!((p.u_true(-x) + p.u_true(x)).abs() < 1e-12);
        }
        let mut prev = f64::INFINITY;
        for i in 0..50 {
            let x = -2.0 + 4.0 * i as f64 / 49.0;
            let u = p.u_true(x);
            assert!(u < prev + 1e-12);
            prev = u;
        }
    }

    #[test]
    fn derivatives_satisfy_the_ode() {
        // With λ = 1/(2k): -λU + ((1+λ)X + U)U' must vanish identically.
        ptest::quickcheck(
            |rng| {
                let k = 1 + rng.below(3) as usize;
                let x = rng.uniform_in(-2.0, 2.0);
                (k, x)
            },
            |&(k, x)| {
                let p = BurgersProfile::new(k);
                let d = p.derivatives_true(x, 1);
                let r = p.residual(p.lambda_smooth(), x, d[0], d[1]);
                if r.abs() < 1e-9 {
                    Ok(())
                } else {
                    Err(format!("residual {r} at x={x}"))
                }
            },
        );
    }

    #[test]
    fn derivatives_at_origin_closed_form() {
        // At X=0: U=0, U'(0) = -1 (from dX/dU = -1), and the first 2k
        // higher derivatives vanish except U^{(2k+1)}(0) which comes from
        // the C·U^{2k+1} term.
        for k in 1..=3 {
            let p = BurgersProfile::new(k);
            let n = 2 * k + 1;
            let d = p.derivatives_true(0.0, n);
            assert!((d[0]).abs() < 1e-14);
            assert!((d[1] + 1.0).abs() < 1e-12, "U'(0) = {}", d[1]);
            for (order, item) in d.iter().enumerate().take(n).skip(2) {
                assert!(item.abs() < 1e-9, "k={k} d{order} = {item}");
            }
            // Differentiating X = -U - C U^{2k+1} (2k+1) times at 0:
            // 1 = -U^{(2k+1)}(0)·0! ... leading term gives
            // U^{(2k+1)}(0) = -(2k+1)!·C·(U'(0))^{2k+1} - ... For C=1,
            // U'(0)=-1: the value is +(2k+1)! (sign: odd power of -1 and
            // the leading minus cancel).
            let fact: f64 = (1..=n).map(|i| i as f64).product();
            assert!(
                (d[n] - fact).abs() < 1e-6 * fact,
                "k={k}: U^{{({n})}}(0) = {} expected {fact}",
                d[n]
            );
        }
    }

    #[test]
    fn derivatives_match_finite_differences_low_order() {
        let p = BurgersProfile::new(1);
        for x in [-1.3, -0.4, 0.2, 0.9, 2.0] {
            let d = p.derivatives_true(x, 2);
            let h = 1e-5;
            let fd1 = (p.u_true(x + h) - p.u_true(x - h)) / (2.0 * h);
            let fd2 = (p.u_true(x + h) - 2.0 * p.u_true(x) + p.u_true(x - h)) / (h * h);
            assert!((d[1] - fd1).abs() < 1e-8 * (1.0 + fd1.abs()), "x={x}");
            assert!((d[2] - fd2).abs() < 1e-4 * (1.0 + fd2.abs()), "x={x}");
        }
    }

    #[test]
    fn far_field_amplitude_grows_sublinearly() {
        // As |X| -> inf, U ~ -sign(X)(|X|/C)^{1/(2k+1)}.
        let p = BurgersProfile::new(1);
        let x = 1e6;
        let u = p.u_true(x);
        let expect = -(x).powf(1.0 / 3.0);
        assert!((u / expect - 1.0).abs() < 1e-2, "u={u} expect~{expect}");
    }
}
