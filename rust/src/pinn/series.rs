//! Truncated power-series arithmetic and series reversion.
//!
//! Used by the Burgers ground-truth solver: the profile is defined
//! *implicitly* by the polynomial relation `X = -U - C·U^(2k+1)`
//! (eq. (8) of the paper), so around any point we know the Taylor series
//! of `X(U)` exactly and obtain `U(X)`'s derivatives — to machine
//! precision, at any order — by reverting the series. This avoids the
//! noise floor of finite differences, which becomes unusable around the
//! 5th derivative and would make the "learned vs true" curves of
//! Figs 7-10 meaningless at high orders.

/// Multiply truncated series `a(t)·b(t)` keeping terms below `len`.
pub fn mul_trunc(a: &[f64], b: &[f64], len: usize) -> Vec<f64> {
    let mut out = vec![0.0; len];
    for (i, &ai) in a.iter().enumerate().take(len) {
        if ai == 0.0 {
            continue;
        }
        for (j, &bj) in b.iter().enumerate().take(len - i) {
            out[i + j] += ai * bj;
        }
    }
    out
}

/// Given `x(u) = Σ_{m>=1} a_m u^m` with `a_1 != 0` (series with zero
/// constant term), return `b` with `u(x) = Σ_{m>=1} b_m x^m` truncated to
/// `n_terms` coefficients (index 0 = constant term = 0).
///
/// Classical iterative reversion: match coefficients of `x(u(x)) = x`
/// order by order; `b_n` appears linearly through the `a_1 u` term.
pub fn revert(a: &[f64], n_terms: usize) -> Vec<f64> {
    assert!(a.len() >= 2, "need at least the linear coefficient");
    assert!(a[0] == 0.0, "series must have zero constant term");
    assert!(a[1] != 0.0, "linear coefficient must be nonzero");
    let len = n_terms.max(2);
    let mut b = vec![0.0; len];
    b[1] = 1.0 / a[1];

    // powers[m] = (u(x))^m truncated, updated incrementally as b grows.
    for n in 2..len {
        // Compute coefficient of x^n in Σ_{m=2..n} a_m (u_{<n}(x))^m,
        // where u_{<n} uses b_1..b_{n-1} (higher coefficients cannot
        // contribute to x^n for m >= 2 since every term has >= 2 factors).
        let u_partial = &b[..n]; // b[0..n-1] known, index < n
        let mut pow = u_partial.to_vec(); // u^1
        let mut residual = 0.0;
        for m in 2..=n {
            pow = mul_trunc(&pow, u_partial, n + 1);
            if m < a.len() && a[m] != 0.0 && n < pow.len() {
                residual += a[m] * pow[n];
            }
        }
        b[n] = -residual / a[1];
    }
    b
}

/// Evaluate a series `Σ c_m t^m` at `t` (Horner).
pub fn eval(coeffs: &[f64], t: f64) -> f64 {
    let mut acc = 0.0;
    for &c in coeffs.iter().rev() {
        acc = acc * t + c;
    }
    acc
}

/// Derivative values `f^{(k)}(x0) = k! c_k` from Taylor coefficients.
pub fn derivatives_from_taylor(coeffs: &[f64]) -> Vec<f64> {
    let mut fact = 1.0;
    coeffs
        .iter()
        .enumerate()
        .map(|(k, &c)| {
            if k > 0 {
                fact *= k as f64;
            }
            c * fact
        })
        .collect()
}

/// Shift a polynomial: coefficients of `p(u0 + v)` in `v`, truncated.
/// (Builds the local series of the implicit relation around the solution
/// point.)
pub fn shift_poly(coeffs: &[f64], u0: f64, len: usize) -> Vec<f64> {
    // Horner-style synthetic division repeated: p(u0+v) coefficients are
    // successive remainders of division by (u - u0).
    let mut work = coeffs.to_vec();
    let n = coeffs.len();
    let mut out = vec![0.0; n.min(len)];
    for item in out.iter_mut() {
        // Evaluate and divide by (u - u0) via synthetic division.
        let mut rem = 0.0;
        for j in (0..work.len()).rev() {
            let tmp = work[j];
            work[j] = rem;
            rem = rem * u0 + tmp;
        }
        *item = rem;
        // The quotient sits in work[0..len-1]; drop the stale top slot.
        work.pop();
        if work.is_empty() {
            break;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::allclose_slice;

    #[test]
    fn mul_trunc_basic() {
        // (1 + t)(1 - t) = 1 - t^2
        let p = mul_trunc(&[1.0, 1.0], &[1.0, -1.0], 4);
        assert_eq!(p, vec![1.0, 0.0, -1.0, 0.0]);
    }

    #[test]
    fn revert_geometric() {
        // x = u/(1-u) = u + u² + u³ + ... ⇒ u = x/(1+x) = x - x² + x³ - ...
        let a: Vec<f64> = std::iter::once(0.0).chain(std::iter::repeat(1.0)).take(10).collect();
        let b = revert(&a, 8);
        let expect: Vec<f64> = (0..8)
            .map(|m| {
                if m == 0 {
                    0.0
                } else if m % 2 == 1 {
                    1.0
                } else {
                    -1.0
                }
            })
            .collect();
        assert!(allclose_slice(&b, &expect, 1e-12, 1e-12), "{b:?}");
    }

    #[test]
    fn revert_satisfies_composition() {
        // Arbitrary series; check x(u(x)) = x through order 9.
        let a = [0.0, 2.0, -0.5, 0.25, 1.5, 0.0, -0.75];
        let b = revert(&a, 10);
        // Compose: c = a(b(x)).
        let mut pow = b.clone();
        let mut comp = vec![0.0; 10];
        for m in 1..a.len() {
            if m > 1 {
                pow = mul_trunc(&pow, &b, 10);
            }
            for i in 0..10 {
                comp[i] += a[m] * pow[i];
            }
        }
        let mut expect = vec![0.0; 10];
        expect[1] = 1.0;
        assert!(allclose_slice(&comp, &expect, 1e-10, 1e-10), "{comp:?}");
    }

    #[test]
    fn eval_horner() {
        assert_eq!(eval(&[1.0, 2.0, 3.0], 2.0), 17.0);
    }

    #[test]
    fn derivatives_factorials() {
        let d = derivatives_from_taylor(&[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(d, vec![1.0, 1.0, 2.0, 6.0]);
    }

    #[test]
    fn shift_poly_matches_expansion() {
        // p(u) = u² ; p(1 + v) = 1 + 2v + v²
        let s = shift_poly(&[0.0, 0.0, 1.0], 1.0, 3);
        assert!(allclose_slice(&s, &[1.0, 2.0, 1.0], 1e-14, 1e-14));
        // p(u) = -u - u³ at u0 = 0.5: p = -0.625 - 1.75v - 1.5v² - v³
        let s2 = shift_poly(&[0.0, -1.0, 0.0, -1.0], 0.5, 4);
        assert!(allclose_slice(&s2, &[-0.625, -1.75, -1.5, -1.0], 1e-14, 1e-14), "{s2:?}");
    }
}
