//! Per-step training telemetry: one JSON line per global epoch.
//!
//! The resilient schedule ([`crate::pinn::trainer`]) can stream a
//! [`StepRecord`] per optimizer step to a JSONL file (`ntangent train
//! --telemetry <path>`, or [`ResilienceConfig::telemetry_path`]). The
//! writer is strictly an *observer*: it reads values the schedule
//! already computed (loss, λ, gradient norm, retry count, timings) and
//! never feeds anything back, so a telemetered trajectory is bitwise
//! identical to a silent one (`rust/tests/obs_overhead.rs`).
//!
//! Each line is a self-contained JSON object, so the file tails cleanly
//! mid-run and survives crashes at any line boundary (partially written
//! final lines are skipped by [`read_jsonl`]):
//!
//! ```json
//! {"step":12,"phase":"adam","loss":4.1e-3,"grad_norm":0.82,
//!  "lambda":0.97,"retries":0,"lr_scale":1.0,"step_ms":6.4,"elapsed_s":0.08}
//! ```
//!
//! Write failures degrade durability, not correctness: the first error
//! is reported on stderr and the writer goes quiet, exactly like the
//! checkpoint writer's failure contract.
//!
//! [`ResilienceConfig::telemetry_path`]: crate::pinn::ResilienceConfig::telemetry_path

use crate::util::json::Json;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// One optimizer step's observables, serialized as one JSONL line.
#[derive(Clone, Debug)]
pub struct StepRecord {
    /// Global epoch index (Adam epochs from 0, L-BFGS continuing).
    pub step: usize,
    /// Schedule phase (`"adam"` / `"lbfgs"`).
    pub phase: &'static str,
    /// The step's loss.
    pub loss: f64,
    /// ℓ₂ norm of the step's gradient (for L-BFGS, the last accepted
    /// gradient from the line search; `None` before one exists).
    pub grad_norm: Option<f64>,
    /// Current self-similar λ estimate.
    pub lambda: f64,
    /// Recovery interventions consumed so far.
    pub retries: u64,
    /// Deterministic learning-rate backoff factor in effect
    /// (`lr_backoff^retries`; 1.0 on a healthy run).
    pub lr_scale: f64,
    /// Wall-clock duration of this step in milliseconds.
    pub step_ms: f64,
    /// Wall-clock seconds since the schedule started.
    pub elapsed_s: f64,
}

impl StepRecord {
    /// The record as one JSON object.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("step", Json::Num(self.step as f64)),
            ("phase", Json::Str(self.phase.to_string())),
            ("loss", Json::Num(self.loss)),
        ];
        if let Some(g) = self.grad_norm {
            fields.push(("grad_norm", Json::Num(g)));
        }
        fields.push(("lambda", Json::Num(self.lambda)));
        fields.push(("retries", Json::Num(self.retries as f64)));
        fields.push(("lr_scale", Json::Num(self.lr_scale)));
        fields.push(("step_ms", Json::Num(self.step_ms)));
        fields.push(("elapsed_s", Json::Num(self.elapsed_s)));
        Json::obj(fields)
    }
}

/// A line-buffered JSONL telemetry sink. `None` path = a no-op writer
/// (the schedule calls it unconditionally; disabled it is two branches).
pub struct TelemetryWriter {
    out: Option<BufWriter<File>>,
    failed: bool,
}

impl TelemetryWriter {
    /// A writer appending to `path`, or a no-op writer for `None`. An
    /// unopenable path is reported on stderr and disables the writer —
    /// a telemetry hook must never take the run down.
    pub fn create(path: Option<&Path>) -> TelemetryWriter {
        let out = path.and_then(|p| match File::create(p) {
            Ok(f) => Some(BufWriter::new(f)),
            Err(e) => {
                eprintln!("telemetry disabled: cannot create {}: {e}", p.display());
                None
            }
        });
        TelemetryWriter { out, failed: false }
    }

    /// The no-op writer.
    pub fn disabled() -> TelemetryWriter {
        TelemetryWriter {
            out: None,
            failed: false,
        }
    }

    /// Is this writer actually writing anywhere?
    pub fn is_active(&self) -> bool {
        self.out.is_some() && !self.failed
    }

    /// Append one record as a JSON line and flush it (each line is a
    /// durable unit, like the checkpoint writer's rename contract).
    pub fn record(&mut self, rec: &StepRecord) {
        if self.failed {
            return;
        }
        if let Some(w) = &mut self.out {
            let line = rec.to_json().dump();
            let io = w
                .write_all(line.as_bytes())
                .and_then(|()| w.write_all(b"\n"))
                .and_then(|()| w.flush());
            if let Err(e) = io {
                eprintln!("telemetry disabled after write failure: {e}");
                self.failed = true;
            }
        }
    }
}

/// Parse a telemetry JSONL file back into JSON objects, skipping blank
/// and partially-written (non-parsing) lines — the read half of the
/// crash-safety contract, used by the CLI and CI's telemetry check.
pub fn read_jsonl(text: &str) -> Vec<Json> {
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .filter_map(|l| Json::parse(l).ok())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(step: usize) -> StepRecord {
        StepRecord {
            step,
            phase: "adam",
            loss: 0.5 / (step + 1) as f64,
            grad_norm: Some(1.25),
            lambda: 0.96,
            retries: 0,
            lr_scale: 1.0,
            step_ms: 3.5,
            elapsed_s: 0.01 * step as f64,
        }
    }

    #[test]
    fn record_serializes_all_fields() {
        let line = sample(7).to_json().dump();
        for key in [
            "\"step\":7",
            "\"phase\":\"adam\"",
            "\"loss\"",
            "\"grad_norm\"",
            "\"lambda\"",
            "\"retries\"",
            "\"lr_scale\"",
            "\"step_ms\"",
            "\"elapsed_s\"",
        ] {
            assert!(line.contains(key), "missing {key} in {line}");
        }
        // The gradient norm is omitted (not null) when absent.
        let mut rec = sample(8);
        rec.grad_norm = None;
        assert!(!rec.to_json().dump().contains("grad_norm"));
    }

    #[test]
    fn writer_round_trips_through_a_file() {
        let dir = std::env::temp_dir().join(format!("ntangent-telemetry-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        let mut w = TelemetryWriter::create(Some(&path));
        assert!(w.is_active());
        for step in 0..5 {
            w.record(&sample(step));
        }
        drop(w);
        let text = std::fs::read_to_string(&path).unwrap();
        let rows = read_jsonl(&text);
        assert_eq!(rows.len(), 5);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.get("step").and_then(Json::as_usize), Some(i));
            assert_eq!(row.get("phase").and_then(Json::as_str), Some("adam"));
            assert!(row.get("loss").and_then(Json::as_f64).unwrap() > 0.0);
        }
        // A truncated final line (simulated crash) is skipped, earlier
        // lines still parse.
        let truncated = format!("{text}{{\"step\":99,\"pha");
        assert_eq!(read_jsonl(&truncated).len(), 5);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn disabled_writer_is_inert() {
        let mut w = TelemetryWriter::disabled();
        assert!(!w.is_active());
        w.record(&sample(0)); // must not panic
        let mut bad = TelemetryWriter::create(Some(Path::new(
            "/nonexistent-ntangent-dir/trace.jsonl",
        )));
        assert!(!bad.is_active());
        bad.record(&sample(0));
    }
}
