//! Physics-informed neural network (PINN) training framework.
//!
//! Implements the paper's §II/§IV-C experimental setup: MSE residual
//! losses with Sobolev terms (eq. 2), a high-order smoothness term near
//! the origin (appendix A), boundary/normalization anchors, inverse
//! parameters (the self-similar exponent λ), collocation samplers, and a
//! two-phase Adam → L-BFGS trainer that can drive either derivative
//! engine (n-TangentProp or repeated autodiff) for the timing comparisons
//! of Figs 6-10.
//!
//! Training comes in two flavours sharing one schedule
//! ([`trainer::TrainableObjective`]):
//!
//! - [`PinnObjective`] / [`train_burgers`] — one monolithic tape over the
//!   full collocation cloud (the seed behaviour).
//! - [`ParallelObjective`] / [`train_burgers_parallel`] — the cloud
//!   sharded into fixed row-chunks, one tape per shard, per-shard
//!   losses/gradients accumulated on a
//!   [`crate::ntp::ParallelPolicy`]-sized worker pool and combined with a
//!   deterministic pairwise tree reduction: **bitwise identical for every
//!   thread count** (`rust/tests/training_determinism.rs`).

pub mod burgers;
pub mod collocation;
pub mod loss;
pub mod parallel;
pub mod series;
pub mod trainer;

pub use burgers::BurgersProfile;
pub use collocation::{
    cluster_points, eval_channels, grid_points, random_points, stratified_points,
};
pub use loss::{residual_derivative_nodes, BurgersLossSpec, DerivEngine, PinnObjective};
pub use parallel::{ParallelObjective, DEFAULT_CHUNK_ROWS};
pub use trainer::{
    train_burgers, train_burgers_parallel, EpochLog, TrainConfig, TrainableObjective, TrainResult,
};
