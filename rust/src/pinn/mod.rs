//! Physics-informed neural network (PINN) training framework.
//!
//! Implements the paper's §II/§IV-C experimental setup: MSE residual
//! losses with Sobolev terms (eq. 2), a high-order smoothness term near
//! the origin (appendix A), boundary/normalization anchors, inverse
//! parameters (the self-similar exponent λ), collocation samplers, and a
//! two-phase Adam → L-BFGS trainer that can drive either derivative
//! engine (n-TangentProp or repeated autodiff) for the timing comparisons
//! of Figs 6-10.

pub mod burgers;
pub mod collocation;
pub mod loss;
pub mod series;
pub mod trainer;

pub use burgers::BurgersProfile;
pub use collocation::{
    cluster_points, eval_channels, grid_points, random_points, stratified_points,
};
pub use loss::{residual_derivative_nodes, BurgersLossSpec, DerivEngine, PinnObjective};
pub use trainer::{train_burgers, EpochLog, TrainConfig, TrainResult};
