//! Physics-informed neural network (PINN) training framework.
//!
//! Implements the paper's §II/§IV-C experimental setup: MSE residual
//! losses with Sobolev terms (eq. 2), a high-order smoothness term near
//! the origin (appendix A), boundary/normalization anchors, inverse
//! parameters (the self-similar exponent λ), collocation samplers, and a
//! two-phase Adam → L-BFGS trainer that can drive either derivative
//! engine (n-TangentProp or repeated autodiff) for the timing comparisons
//! of Figs 6-10.
//!
//! Training comes in two flavours sharing one schedule
//! ([`trainer::TrainableObjective`]):
//!
//! - [`PinnObjective`] / [`train_burgers`] — one monolithic tape over the
//!   full collocation cloud (the seed behaviour).
//! - [`ParallelObjective`] / [`train_burgers_parallel`] — the cloud
//!   sharded into fixed row-chunks, one tape per shard, per-shard
//!   losses/gradients accumulated on a
//!   [`crate::ntp::ParallelPolicy`]-sized worker pool and combined with a
//!   deterministic pairwise tree reduction: **bitwise identical for every
//!   thread count** (`rust/tests/training_determinism.rs`).
//!
//! Multi-dimensional PDE problems train through the same sharded
//! machinery: [`MultiObjective`] / [`train_pde`] fit a scalar field to a
//! [`crate::pde::PdeProblem`] with operator residuals whose mixed
//! partials come from batched directional n-TangentProp passes (or the
//! nested-tape baseline for differential testing) — see
//! [`crate::ntp::multi`] and `rust/tests/operator_exactness.rs`. High-
//! dimensional problems (`poisson10d`, `heat100d`, `hjb10d`) swap the
//! exact plan for stochastic Taylor derivative estimation
//! ([`EstimatorMode::Stde`], [`crate::ntp::stde`]): the operator's term
//! set is resampled every gradient step from a counter-based stream, so
//! even the stochastic trajectories stay bitwise thread-count-invariant
//! (`rust/tests/stde_determinism.rs`).
//!
//! The loss recipes themselves live in one shared term-builder
//! (`terms`): the monolithic and sharded Burgers objectives compile the
//! identical term list (with their historical scaling sequences
//! preserved bit for bit), and the multivariate objective composes the
//! same shard/θ-layout/term pieces instead of copying them.

pub mod burgers;
pub mod collocation;
pub mod loss;
pub mod multi;
pub mod parallel;
pub mod resilience;
pub mod series;
pub mod telemetry;
pub(crate) mod terms;
pub mod trainer;

pub use burgers::BurgersProfile;
pub use collocation::{
    cluster_points, eval_channels, grid_points, random_points, stratified_points,
};
pub use crate::ntp::{EstimatorMode, StdeConfig};
pub use loss::{residual_derivative_nodes, BurgersLossSpec, DerivEngine, PinnObjective};
pub use multi::{residual_values, residual_values_estimated, MultiObjective, MultiPinnSpec};
pub use parallel::{ParallelObjective, DEFAULT_CHUNK_ROWS};
pub use resilience::{FaultKind, FaultPlan, NumericError, ResilienceConfig, RunHealth};
pub use telemetry::{StepRecord, TelemetryWriter};
pub use trainer::{
    train_burgers, train_burgers_parallel, train_burgers_parallel_resilient,
    train_burgers_resilient, train_burgers_sharded, train_pde, train_pde_resilient,
    train_pde_with_estimator, EpochLog, PdeTrainResult, TrainConfig, TrainableObjective,
    TrainResult,
};
