//! Multi-dimensional PINN training: operator residuals over 2-D/3-D
//! collocation clouds, sharded through the same deterministic machinery
//! as the Burgers trainer.
//!
//! [`MultiObjective`] fits a scalar field `u(x)` to a
//! [`PdeProblem`] by minimizing
//!
//! ```text
//! L = (w_res/N_int)·Σ_int |L[u](x) − f(x)|² + (w_bc/N_bc)·Σ_bc |u(x) − u*(x)|²
//! ```
//!
//! (order-4 problems add the second boundary trace their well-posedness
//! needs — see [`PdeProblem::boundary_operator`] — through the same
//! machinery), with the mixed partials inside `L[u]` coming from either
//! derivative engine:
//!
//! - [`DerivEngine::Ntp`] records one **directional** n-TangentProp pass
//!   per compiled [`JetPlan`] direction
//!   ([`crate::ntp::NtpEngine::forward_graph_directional`]) and
//!   recombines the order-`m` channels into exact `∂^α u` nodes — the
//!   quasilinear path;
//! - [`DerivEngine::Autodiff`] nests backward passes per multi-index
//!   ([`crate::autodiff::higher::mixed_partial`]) — the exponential
//!   baseline, kept as the differential-testing oracle.
//!
//! The collocation clouds shard into fixed `chunk`-row tapes evaluated
//! on a [`ParallelPolicy`] worker pool with pairwise-tree combination,
//! so — exactly like the Burgers trainer — **training trajectories are
//! bitwise identical for every thread count**
//! (`rust/tests/operator_exactness.rs`).
//!
//! Beyond the exact plan's envelope, [`EstimatorMode::Stde`] swaps the
//! [`JetPlan`] for the sparse [`StdePlan`] pool and **resamples the
//! operator's term set every gradient step** from the counter-based
//! stream ([`crate::ntp::stde`]): shard `s` at step `t` draws at
//! counter `(seed, t, s)`, a pure function of the coordinates, so the
//! stochastic trajectories keep the same bitwise thread-count
//! invariance (`rust/tests/stde_determinism.rs`).

use super::loss::DerivEngine;
use super::terms::{
    chunk_rows, eval_shards_grad, eval_shards_value, eval_shards_value_batch, Shard,
    TermAccumulator, TermScale, ThetaLayout,
};
use crate::autodiff::{higher, Graph, NodeId};
use crate::nn::Mlp;
use crate::ntp::stde::{sample_terms, sampled_operator};
use crate::ntp::{
    EstimatorMode, JetPlan, MultiJetEngine, NtpEngine, ParallelPolicy, RecombinationPlan,
    StdeConfig, StdeEngine, StdePlan,
};
use crate::opt::Objective;
use crate::pde::{DiffOperator, PdeProblem};
use crate::tensor::Tensor;
use crate::util::{par, prng::Prng};
use std::collections::HashMap;

/// Hyper-parameters of a multi-dimensional PDE objective.
#[derive(Clone, Copy, Debug)]
pub struct MultiPinnSpec {
    /// The library problem being fitted.
    pub problem: PdeProblem,
    /// Interior (residual) collocation points.
    pub n_interior: usize,
    /// Boundary (Dirichlet) collocation points.
    pub n_boundary: usize,
    /// Weight of the residual term.
    pub w_residual: f64,
    /// Weight of the boundary term.
    pub w_bc: f64,
}

impl MultiPinnSpec {
    /// Defaults sized for CPU training runs.
    pub fn for_problem(problem: PdeProblem) -> MultiPinnSpec {
        MultiPinnSpec {
            problem,
            n_interior: 256,
            n_boundary: 64,
            w_residual: 1.0,
            w_bc: 10.0,
        }
    }
}

/// The sharded multivariate PINN objective (see the module docs).
///
/// Flat parameter layout: the network parameters only (no inverse
/// parameter), `dim() = M`.
///
/// ```
/// use ntangent::nn::Mlp;
/// use ntangent::ntp::ParallelPolicy;
/// use ntangent::opt::Objective;
/// use ntangent::pde::PdeProblem;
/// use ntangent::pinn::{DerivEngine, MultiObjective, MultiPinnSpec};
/// use ntangent::util::prng::Prng;
///
/// let mut spec = MultiPinnSpec::for_problem(PdeProblem::Poisson2d);
/// spec.n_interior = 24; // keep the doc-example quick
/// spec.n_boundary = 8;
/// let mut rng = Prng::seeded(3);
/// let mlp = Mlp::uniform(2, 8, 2, 1, &mut rng);
/// let mut obj = MultiObjective::build(
///     spec,
///     &mlp,
///     DerivEngine::Ntp,
///     ParallelPolicy::Fixed(2),
///     8, // collocation rows per shard
///     &mut rng,
/// );
/// let theta = obj.theta_init(&mlp);
/// let (loss, grad) = obj.value_grad(&theta);
/// assert!(loss.is_finite());
/// assert_eq!(grad.numel(), obj.dim());
/// assert!(obj.n_shards() > 1);
/// ```
pub struct MultiObjective {
    shards: Vec<Shard>,
    layout: ThetaLayout,
    policy: ParallelPolicy,
    chunk: usize,
    /// The spec this objective was built from.
    pub spec: MultiPinnSpec,
    /// Which engine computes the mixed partials on every shard tape.
    pub engine: DerivEngine,
    /// How the operator residual is evaluated (exact plan vs STDE).
    pub estimator: EstimatorMode,
    stde: Option<StdeState>,
    /// Full interior collocation cloud (kept for inspection/reporting).
    pub x_int: Tensor,
    /// Full boundary cloud.
    pub x_bc: Tensor,
    /// Count of forward-only evaluations.
    pub n_forward: u64,
    /// Count of gradient evaluations.
    pub n_backward: u64,
}

impl MultiObjective {
    /// Build the sharded objective: sample clouds, compile one
    /// [`JetPlan`] for the problem's operator, then one loss+gradient
    /// tape per `chunk`-row slice (interior chunk `s` on shard `s`,
    /// boundary chunks on the trailing shards). `policy` only schedules
    /// shard evaluation — results are bitwise independent of it.
    pub fn build(
        spec: MultiPinnSpec,
        mlp: &Mlp,
        engine: DerivEngine,
        policy: ParallelPolicy,
        chunk: usize,
        rng: &mut Prng,
    ) -> MultiObjective {
        MultiObjective::build_with_estimator(
            spec,
            mlp,
            engine,
            policy,
            chunk,
            rng,
            EstimatorMode::Exact,
        )
    }

    /// [`MultiObjective::build`] with an explicit [`EstimatorMode`].
    ///
    /// `Exact` compiles the combinatorial [`JetPlan`] (the low-`d`
    /// oracle). `Stde` compiles the operator's sparse [`StdePlan`]
    /// pool once and **resamples the operator term set every gradient
    /// step**: shard `s` at step `t` draws terms at counter
    /// `(seed, t, s)`, so stochastic trajectories stay bitwise
    /// identical for every thread count. Forward-only `value` calls
    /// between gradient steps reuse the current draw — the L-BFGS line
    /// search must probe the same sampled objective it is descending.
    #[allow(clippy::too_many_arguments)]
    pub fn build_with_estimator(
        spec: MultiPinnSpec,
        mlp: &Mlp,
        engine: DerivEngine,
        policy: ParallelPolicy,
        chunk: usize,
        rng: &mut Prng,
        estimator: EstimatorMode,
    ) -> MultiObjective {
        assert!(chunk >= 1, "chunk must be >= 1");
        assert!(spec.n_interior >= 1, "need at least one interior point");
        let dim = spec.problem.dim();
        assert_eq!(
            mlp.input_dim(),
            dim,
            "network input dim must match the problem"
        );
        assert_eq!(mlp.output_dim(), 1, "PDE residuals need a scalar field");

        let x_int = spec.problem.sample_interior(spec.n_interior, rng);
        let x_bc = spec.problem.sample_boundary(spec.n_boundary, rng);

        let op = spec.problem.operator();
        let n = op.max_order();
        let ntp = NtpEngine::new(n);

        let int_chunks = chunk_rows(&x_int, chunk);
        let bc_chunks = chunk_rows(&x_bc, chunk);
        let n_shards = int_chunks.len().max(bc_chunks.len()).max(1);
        // Boundary chunks trail (mirrors the Burgers layout: the heavier
        // residual chunks lead). A pure function of (spec, chunk).
        let bc_offset = n_shards - bc_chunks.len();

        let (shards, stde) = match estimator.stde_config() {
            None => {
                assert!(
                    !spec.problem.needs_stde(),
                    "{}'s exact plan is combinatorially intractable — train with EstimatorMode::Stde",
                    spec.problem.name()
                );
                let plan = JetPlan::new(dim, n);
                let shards: Vec<Shard> = (0..n_shards)
                    .map(|s| {
                        build_multi_shard(
                            &spec,
                            mlp,
                            engine,
                            &ntp,
                            &plan,
                            &op,
                            int_chunks.get(s),
                            bc_chunks.get(s.wrapping_sub(bc_offset)),
                        )
                    })
                    .collect();
                (shards, None)
            }
            Some(cfg) => {
                assert!(
                    matches!(engine, DerivEngine::Ntp),
                    "STDE estimation runs on the directional n-TangentProp engine"
                );
                assert!(
                    spec.problem.boundary_operator().is_none(),
                    "STDE mode supports first-trace boundary conditions only"
                );
                let plan = StdePlan::new(&op);
                let state = StdeState {
                    op,
                    plan,
                    ntp,
                    mlp: mlp.clone(),
                    cfg,
                    int_chunks,
                    bc_chunks,
                    bc_offset,
                    step: 0,
                };
                let shards = state.build_shards(&spec, engine, policy);
                (shards, Some(state))
            }
        };

        MultiObjective {
            shards,
            layout: ThetaLayout::new(mlp, None),
            policy,
            chunk,
            spec,
            engine,
            estimator,
            stde,
            x_int,
            x_bc,
            n_forward: 0,
            n_backward: 0,
        }
    }

    /// Number of shards (tapes) the clouds were split into.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Collocation rows per shard this objective was built with.
    pub fn chunk(&self) -> usize {
        self.chunk
    }

    /// The policy evaluating the shards.
    pub fn policy(&self) -> ParallelPolicy {
        self.policy
    }

    /// Change the evaluation policy (purely a scheduling knob; results
    /// stay bitwise identical).
    pub fn set_policy(&mut self, policy: ParallelPolicy) {
        self.policy = policy;
    }

    /// Total node count across all shard tapes.
    pub fn graph_len(&self) -> usize {
        self.shards.iter().map(|s| s.graph.len()).sum()
    }

    /// Counter step of the current STDE draw (0 until the first
    /// gradient evaluation; always 0 in exact mode).
    pub fn stde_step(&self) -> u64 {
        self.stde.as_ref().map_or(0, |s| s.step)
    }

    /// Pin the STDE draw counter to `step` and rebuild the shard tapes at
    /// that draw **without** advancing it — the resume hook. A trainer
    /// restarting from a checkpoint taken at counter `step` calls this so
    /// forward-only probes see the same sampled objective the
    /// uninterrupted run had, and the next `value_grad` advances to
    /// `step + 1` exactly as it would have. No-op in exact mode.
    pub fn restore_estimator_step(&mut self, step: u64) {
        if let Some(state) = self.stde.as_mut() {
            state.step = step;
            self.shards = state.build_shards(&self.spec, self.engine, self.policy);
        }
    }

    /// Initial flat parameter vector (the MLP weights).
    pub fn theta_init(&self, mlp: &Mlp) -> Tensor {
        self.layout.theta_init(mlp)
    }

    /// Write `theta` into an MLP for evaluation.
    pub fn mlp_of(&self, theta: &Tensor) -> Mlp {
        self.layout.mlp_of(theta)
    }
}

impl Objective for MultiObjective {
    fn value_grad(&mut self, theta: &Tensor) -> (f64, Tensor) {
        // STDE mode: a fresh term draw per gradient step. Resampling
        // happens *here* (never in `value`) so forward-only line-search
        // probes descend the same sampled objective.
        if let Some(state) = self.stde.as_mut() {
            state.step += 1;
            self.shards = state.build_shards(&self.spec, self.engine, self.policy);
        }
        self.n_backward += 1;
        eval_shards_grad(&self.shards, &self.layout.inputs_of(theta), self.policy)
    }

    fn value(&mut self, theta: &Tensor) -> f64 {
        self.n_forward += 1;
        eval_shards_value(&self.shards, &self.layout.inputs_of(theta), self.policy)
    }

    fn value_batch(&mut self, thetas: &[Tensor]) -> Vec<f64> {
        self.n_forward += thetas.len() as u64;
        let inputs: Vec<Vec<Tensor>> = thetas.iter().map(|t| self.layout.inputs_of(t)).collect();
        eval_shards_value_batch(&self.shards, &inputs, self.policy)
    }

    fn dim(&self) -> usize {
        self.layout.dim()
    }
}

/// The frozen STDE machinery of one objective: the full operator, its
/// compiled sparse direction pool, the collocation chunk layout and the
/// current counter step. Shard tapes are *derived* state — rebuilt from
/// here on every gradient step with a fresh term draw.
struct StdeState {
    op: DiffOperator,
    plan: StdePlan,
    ntp: NtpEngine,
    /// Shape template only — parameter values enter each tape through
    /// its input slots at eval time, so tapes rebuilt mid-training see
    /// the current θ like any other shard.
    mlp: Mlp,
    cfg: StdeConfig,
    int_chunks: Vec<Tensor>,
    bc_chunks: Vec<Tensor>,
    bc_offset: usize,
    step: u64,
}

impl StdeState {
    /// One tape per shard for the current counter step: shard `s` draws
    /// its own terms at `(seed, step, s)` and compiles the reweighted
    /// sampled operator over its interior slice (boundary terms keep
    /// exact forward values). Tape construction runs on the worker pool
    /// — each tape is a pure function of `(state, s)`, so the layout
    /// stays policy-invariant.
    fn build_shards(
        &self,
        spec: &MultiPinnSpec,
        engine: DerivEngine,
        policy: ParallelPolicy,
    ) -> Vec<Shard> {
        let n_shards = self.int_chunks.len().max(self.bc_chunks.len()).max(1);
        let workers = par::workers_for_tasks(policy, n_shards);
        par::run_indexed(n_shards, workers, |s| {
            let interior = self.int_chunks.get(s);
            let sampled = interior.map(|_| {
                let draws = sample_terms(&self.cfg, self.op.terms().len(), self.step, s as u64);
                sampled_operator(&self.op, &draws)
            });
            build_multi_shard(
                spec,
                &self.mlp,
                engine,
                &self.ntp,
                &self.plan,
                sampled.as_ref().unwrap_or(&self.op),
                interior,
                self.bc_chunks.get(s.wrapping_sub(self.bc_offset)),
            )
        })
    }
}

/// Record every needed mixed-partial node for one interior slice.
#[allow(clippy::too_many_arguments)]
fn partial_nodes(
    g: &mut Graph,
    mlp: &Mlp,
    engine: DerivEngine,
    ntp: &NtpEngine,
    plan: &dyn RecombinationPlan,
    op: &DiffOperator,
    param_nodes: &[NodeId],
    xn: NodeId,
    batch: usize,
) -> HashMap<Vec<usize>, NodeId> {
    let needed = op.needed_partials();
    let dim = plan.dim();
    let mut partials: HashMap<Vec<usize>, NodeId> = HashMap::new();
    match engine {
        DerivEngine::Ntp => {
            // Which directions are needed, and to what order each.
            let mut need_order = vec![0usize; plan.n_directions()];
            let mut need_u = false;
            for alpha in &needed {
                let m: usize = alpha.iter().sum();
                if m == 0 {
                    need_u = true;
                    continue;
                }
                let (ids, _) = plan.weights_for(alpha);
                for &id in ids {
                    need_order[id] = need_order[id].max(m);
                }
            }
            // One recorded directional pass per needed direction.
            let mut jets: Vec<Option<Vec<NodeId>>> = vec![None; plan.n_directions()];
            for (id, &mo) in need_order.iter().enumerate() {
                if mo == 0 {
                    continue;
                }
                let dir = &plan.directions()[id];
                let vdata: Vec<f64> = (0..batch)
                    .flat_map(|_| dir.iter().map(|&c| c as f64))
                    .collect();
                let vn = g.constant(Tensor::from_vec(vdata, &[batch, dim]));
                jets[id] = Some(ntp.forward_graph_directional(g, mlp, xn, vn, param_nodes, mo));
            }
            // u itself: order 0 of any recorded curve (or a plain
            // forward when the operator is derivative-free).
            if need_u {
                let u = match jets.iter().flatten().next() {
                    Some(j) => j[0],
                    None => mlp.forward_graph(g, xn, param_nodes),
                };
                partials.insert(vec![0; dim], u);
            }
            // ∂^α = Σ_k w_k · (order-m channel of direction k).
            for alpha in &needed {
                let m: usize = alpha.iter().sum();
                if m == 0 {
                    continue;
                }
                let (ids, w) = plan.weights_for(alpha);
                let mut node: Option<NodeId> = None;
                for (&id, &wk) in ids.iter().zip(w) {
                    let chan = jets[id].as_ref().expect("pass recorded for every needed dir")[m];
                    let term = g.scale(chan, wk);
                    node = Some(match node {
                        None => term,
                        Some(a) => g.add(a, term),
                    });
                }
                partials.insert(
                    alpha.clone(),
                    node.expect("order ≥ 1 recombination has directions"),
                );
            }
        }
        DerivEngine::Autodiff => {
            let u = mlp.forward_graph(g, xn, param_nodes);
            for alpha in &needed {
                let node = if alpha.iter().all(|&a| a == 0) {
                    u
                } else {
                    higher::mixed_partial(g, u, xn, alpha)
                };
                partials.insert(alpha.clone(), node);
            }
        }
    }
    partials
}

/// Build one shard's tape: the operator residual over its interior
/// slice plus the Dirichlet term over its boundary slice, sum-of-squares
/// pre-scaled by the global point counts, then a single `backward`.
#[allow(clippy::too_many_arguments)]
fn build_multi_shard(
    spec: &MultiPinnSpec,
    mlp: &Mlp,
    engine: DerivEngine,
    ntp: &NtpEngine,
    plan: &dyn RecombinationPlan,
    op: &DiffOperator,
    interior: Option<&Tensor>,
    boundary: Option<&Tensor>,
) -> Shard {
    let mut g = Graph::new();
    let param_nodes = mlp.input_param_nodes(&mut g);
    let mut acc = TermAccumulator::new();

    // --- Operator residual over the interior slice ----------------------
    if let Some(x) = interior {
        let xn = g.constant(x.clone());
        let partials = partial_nodes(
            &mut g,
            mlp,
            engine,
            ntp,
            plan,
            op,
            &param_nodes,
            xn,
            x.shape()[0],
        );
        let lhs = op.apply_nodes(&mut g, &partials);
        let src = g.constant(spec.problem.source_rows(x));
        let r = g.sub(lhs, src);
        let scale = TermScale::ScaledSum {
            coeff: spec.w_residual / spec.n_interior as f64,
        };
        let term = scale.square_term(&mut g, r);
        acc.push(&mut g, term);
    }

    // --- Dirichlet boundary term ----------------------------------------
    if let Some(x) = boundary {
        let xn = g.constant(x.clone());
        let u = mlp.forward_graph(&mut g, xn, &param_nodes);
        let target = g.constant(spec.problem.u_exact_rows(x));
        let dr = g.sub(u, target);
        let scale = TermScale::ScaledSum {
            coeff: spec.w_bc / spec.n_boundary as f64,
        };
        let term = scale.square_term(&mut g, dr);
        acc.push(&mut g, term);

        // Second boundary condition for order-4 problems (`u` alone does
        // not determine a biharmonic field): pin the operator trace —
        // e.g. `Δu` on ∂Ω — against its exact values, through the same
        // directional/nested partial machinery as the interior residual.
        if let Some(bop) = spec.problem.boundary_operator() {
            let partials = partial_nodes(
                &mut g,
                mlp,
                engine,
                ntp,
                plan,
                &bop,
                &param_nodes,
                xn,
                x.shape()[0],
            );
            let lhs = bop.apply_nodes(&mut g, &partials);
            let bt = g.constant(spec.problem.boundary_operator_rows(x));
            let br = g.sub(lhs, bt);
            let term = scale.square_term(&mut g, br);
            acc.push(&mut g, term);
        }
    }

    let loss = acc
        .finish()
        .expect("shard has at least one loss term");
    let grads = g.backward(loss, &param_nodes);
    Shard { graph: g, loss, grads }
}

/// Pointwise PDE residual `L[u](x) − f(x)` of a trained network over a
/// cloud `x: [B, dim]`, evaluated through the fused directional-jet
/// engine (the post-training validation hot path).
pub fn residual_values(
    problem: PdeProblem,
    mlp: &Mlp,
    x: &Tensor,
    policy: ParallelPolicy,
) -> Tensor {
    let op = problem.operator();
    let engine = MultiJetEngine::with_policy(problem.dim(), op.max_order(), policy);
    let jet = engine.jet(mlp, x);
    let lhs = op.apply(&jet);
    lhs.sub(&problem.source_rows(x))
}

/// Stochastic counterpart of [`residual_values`]: the Horvitz–Thompson
/// operator estimate at counter `step` minus the source term — unbiased
/// in expectation over the draw, and the only tractable validation path
/// for problems whose exact plan is combinatorial (`heat100d`).
/// Bitwise deterministic in `(cfg.seed, step)`.
pub fn residual_values_estimated(
    problem: PdeProblem,
    mlp: &Mlp,
    x: &Tensor,
    cfg: StdeConfig,
    step: u64,
    policy: ParallelPolicy,
) -> Tensor {
    let est = StdeEngine::with_policy(problem.operator(), cfg, policy);
    est.estimate(mlp, x, step)
        .values
        .sub(&problem.source_rows(x))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::allclose_slice;

    fn tiny_spec(problem: PdeProblem) -> MultiPinnSpec {
        MultiPinnSpec {
            problem,
            n_interior: 20,
            n_boundary: 8,
            w_residual: 1.0,
            w_bc: 5.0,
        }
    }

    #[test]
    fn objective_is_send_and_sync() {
        fn assert_sync<T: Sync>() {}
        fn assert_send<T: Send>() {}
        assert_sync::<MultiObjective>();
        assert_send::<MultiObjective>();
    }

    /// The two derivative engines build completely different graphs
    /// (directional recombination vs nested backward) — their loss and
    /// gradient must still agree on every kind of library problem,
    /// including the nonlinear KdV product and the biharmonic second
    /// boundary condition.
    #[test]
    fn engines_agree_on_loss_and_grad() {
        for problem in [
            PdeProblem::Poisson2d,
            PdeProblem::Heat2d,
            PdeProblem::Kdv,
            PdeProblem::Biharmonic2d,
        ] {
            let mut rng = Prng::seeded(42);
            let mlp = Mlp::uniform(2, 6, 2, 1, &mut rng);
            let mut rng_a = Prng::seeded(7);
            let mut rng_b = Prng::seeded(7);
            let mut obj_ntp = MultiObjective::build(
                tiny_spec(problem),
                &mlp,
                DerivEngine::Ntp,
                ParallelPolicy::Serial,
                8,
                &mut rng_a,
            );
            let mut obj_ad = MultiObjective::build(
                tiny_spec(problem),
                &mlp,
                DerivEngine::Autodiff,
                ParallelPolicy::Serial,
                8,
                &mut rng_b,
            );
            assert_eq!(obj_ntp.x_int, obj_ad.x_int);
            let theta = obj_ntp.theta_init(&mlp);
            let (l1, g1) = obj_ntp.value_grad(&theta);
            let (l2, g2) = obj_ad.value_grad(&theta);
            assert!(
                (l1 - l2).abs() <= 1e-8 * l2.abs().max(1.0),
                "{}: {l1} vs {l2}",
                problem.name()
            );
            assert!(
                allclose_slice(g1.data(), g2.data(), 1e-6, 1e-8),
                "{}: grad max diff {}",
                problem.name(),
                crate::util::max_abs_diff(g1.data(), g2.data())
            );
        }
    }

    #[test]
    fn policy_change_is_bitwise_invisible() {
        let mut rng_m = Prng::seeded(1);
        let mlp = Mlp::uniform(2, 6, 2, 1, &mut rng_m);
        let mut rng_a = Prng::seeded(9);
        let mut rng_b = Prng::seeded(9);
        let mut serial = MultiObjective::build(
            tiny_spec(PdeProblem::Poisson2d),
            &mlp,
            DerivEngine::Ntp,
            ParallelPolicy::Serial,
            4,
            &mut rng_a,
        );
        let mut fixed = MultiObjective::build(
            tiny_spec(PdeProblem::Poisson2d),
            &mlp,
            DerivEngine::Ntp,
            ParallelPolicy::Fixed(3),
            4,
            &mut rng_b,
        );
        let theta = serial.theta_init(&mlp);
        let (l1, g1) = serial.value_grad(&theta);
        let (l2, g2) = fixed.value_grad(&theta);
        assert_eq!(l1.to_bits(), l2.to_bits());
        assert_eq!(g1, g2);
        assert_eq!(serial.value(&theta).to_bits(), fixed.value(&theta).to_bits());
    }

    /// Analytic gradient against central finite differences of the
    /// objective's own forward value.
    #[test]
    fn gradient_matches_finite_differences() {
        let mut rng = Prng::seeded(3);
        let mlp = Mlp::uniform(2, 5, 2, 1, &mut rng);
        let mut obj = MultiObjective::build(
            tiny_spec(PdeProblem::Heat2d),
            &mlp,
            DerivEngine::Ntp,
            ParallelPolicy::Serial,
            8,
            &mut rng,
        );
        let theta = obj.theta_init(&mlp);
        let (_, grad) = obj.value_grad(&theta);
        let eps = 1e-6;
        for &i in &[0usize, 3, 11, theta.numel() - 1] {
            let mut tp = theta.clone();
            tp.data_mut()[i] += eps;
            let mut tm = theta.clone();
            tm.data_mut()[i] -= eps;
            let fd = (obj.value(&tp) - obj.value(&tm)) / (2.0 * eps);
            assert!(
                (grad.data()[i] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                "coord {i}: {} vs fd {fd}",
                grad.data()[i]
            );
        }
    }

    /// The residual of the *exact* solution field is what training
    /// minimizes: a network that matches u* on a cloud has residual
    /// values equal to L[u*] − f ≈ 0... which a random network does not.
    /// Here we check the evaluation path plumbing: residual_values
    /// matches a manual jet evaluation bitwise.
    #[test]
    fn residual_values_match_manual_jet_eval() {
        let mut rng = Prng::seeded(5);
        let problem = PdeProblem::Poisson2d;
        let mlp = Mlp::uniform(2, 6, 2, 1, &mut rng);
        let x = problem.sample_interior(11, &mut rng);
        let r = residual_values(problem, &mlp, &x, ParallelPolicy::Serial);
        let op = problem.operator();
        let engine = MultiJetEngine::new(2, op.max_order());
        let jet = engine.jet(&mlp, &x);
        let want = op.apply(&jet).sub(&problem.source_rows(&x));
        assert_eq!(r, want);
    }

    #[test]
    fn counters_and_sizes_track() {
        let mut rng = Prng::seeded(6);
        let mlp = Mlp::uniform(2, 5, 2, 1, &mut rng);
        let mut obj = MultiObjective::build(
            tiny_spec(PdeProblem::Wave2d),
            &mlp,
            DerivEngine::Ntp,
            ParallelPolicy::Serial,
            64, // chunk > n_interior: one interior shard
            &mut rng,
        );
        assert_eq!(obj.n_shards(), 1);
        assert!(obj.graph_len() > 0);
        let theta = obj.theta_init(&mlp);
        let v = obj.value(&theta);
        let (vg, _) = obj.value_grad(&theta);
        assert_eq!(v, vg);
        assert_eq!(obj.n_forward, 1);
        assert_eq!(obj.n_backward, 1);
    }

    fn build_stde(policy: ParallelPolicy) -> (MultiObjective, Tensor) {
        let mut rng_m = Prng::seeded(1);
        let mlp = Mlp::uniform(10, 6, 2, 1, &mut rng_m);
        let mut rng = Prng::seeded(9);
        let mut spec = MultiPinnSpec::for_problem(PdeProblem::Poisson10d);
        spec.n_interior = 12;
        spec.n_boundary = 6;
        let obj = MultiObjective::build_with_estimator(
            spec,
            &mlp,
            DerivEngine::Ntp,
            policy,
            4,
            &mut rng,
            EstimatorMode::Stde { seed: 11, samples: 2, antithetic: false },
        );
        let theta = obj.theta_init(&mlp);
        (obj, theta)
    }

    /// STDE mode: the sampled objective is bitwise policy-invariant
    /// (draws are counter-keyed by `(step, shard)`, never by thread),
    /// gradient steps advance the draw, and forward-only probes do not.
    #[test]
    fn stde_objective_is_deterministic_and_resamples_per_step() {
        let (mut serial, theta) = build_stde(ParallelPolicy::Serial);
        let (mut fixed, theta2) = build_stde(ParallelPolicy::Fixed(4));
        assert_eq!(theta, theta2);
        let (l1, g1) = serial.value_grad(&theta);
        let (l2, g2) = fixed.value_grad(&theta);
        assert_eq!(l1.to_bits(), l2.to_bits());
        assert_eq!(g1, g2);
        assert_eq!(serial.stde_step(), 1);
        // Forward-only probes reuse the step-1 draw...
        assert_eq!(serial.value(&theta).to_bits(), l1.to_bits());
        assert_eq!(serial.stde_step(), 1);
        // ...and the next gradient step draws afresh.
        let (l3, _) = serial.value_grad(&theta);
        assert_eq!(serial.stde_step(), 2);
        assert!(l3.is_finite());
    }

    /// `value_batch` must return exactly what per-trial `value` calls
    /// would — bitwise, for every policy — so the batched line search
    /// cannot perturb trajectories.
    #[test]
    fn value_batch_matches_sequential_values_bitwise() {
        let mut rng_m = Prng::seeded(2);
        let mlp = Mlp::uniform(2, 6, 2, 1, &mut rng_m);
        let mut rng = Prng::seeded(4);
        let mut obj = MultiObjective::build(
            tiny_spec(PdeProblem::Poisson2d),
            &mlp,
            DerivEngine::Ntp,
            ParallelPolicy::Fixed(3),
            4,
            &mut rng,
        );
        let theta = obj.theta_init(&mlp);
        let trials: Vec<Tensor> = (0..5)
            .map(|k| {
                let mut t = theta.clone();
                for v in t.data_mut() {
                    *v *= 1.0 + 0.01 * k as f64;
                }
                t
            })
            .collect();
        let want: Vec<u64> = trials.iter().map(|t| obj.value(t).to_bits()).collect();
        let got: Vec<u64> = obj
            .value_batch(&trials)
            .into_iter()
            .map(f64::to_bits)
            .collect();
        assert_eq!(want, got);
        assert_eq!(obj.n_forward, 10);
    }
}
