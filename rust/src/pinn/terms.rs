//! Shared loss-tape machinery for every PINN objective: the compiled
//! shard (graph + loss + gradient nodes), the deterministic shard-set
//! evaluators, the loss-term builders, the flat-θ layout, and the single
//! Burgers loss recipe.
//!
//! Before this module, the monolithic [`super::PinnObjective`] and the
//! sharded [`super::ParallelObjective`] each carried their own copy of
//! the Burgers term list and the θ accessors, kept in sync by a
//! cross-check test (the hand-sync debt flagged in the PR 3 notes). Now
//! both call [`build_burgers_shard`] with a [`LossScaling`] that
//! reproduces their historical op sequences *exactly* — `mean(r²)·w` for
//! the monolithic tape, `(Σr²)·(w/N_global)` for shards — so the
//! numerics (and the bitwise determinism contracts) are unchanged, and
//! the multivariate [`super::MultiObjective`] composes the same pieces
//! instead of adding a third copy.

use super::loss::{lambda_from_raw, lambda_node, residual_derivative_nodes, BurgersLossSpec};
use super::DerivEngine;
use crate::autodiff::{higher, Graph, NodeId};
use crate::nn::{params, Mlp};
use crate::ntp::{NtpEngine, ParallelPolicy};
use crate::tensor::Tensor;
use crate::util::par;

/// One compiled loss/gradient tape over a slice of the collocation
/// data. Evaluation is pure (`&self`), so shards are shared by reference
/// across worker threads.
pub(crate) struct Shard {
    /// The recorded tape.
    pub graph: Graph,
    /// The scalar loss node.
    pub loss: NodeId,
    /// Gradient nodes, one per input slot in flat-θ order.
    pub grads: Vec<NodeId>,
}

impl Shard {
    /// `(loss_s, ∇loss_s)` — one forward + one backward over this tape.
    pub fn eval_grad(&self, inputs: &[Tensor]) -> (f64, Tensor) {
        let mut targets = self.grads.clone();
        targets.push(self.loss);
        let mut vals = self.graph.eval(inputs, &targets);
        let loss = vals.get(self.loss).item();
        // Move (don't clone) the gradients out of the value store; they
        // are copied exactly once, into the flat vector.
        let gts: Vec<Tensor> = self.grads.iter().map(|&id| vals.take(id)).collect();
        (loss, params::flatten_tensors(&gts))
    }

    /// Loss only — the cheap forward-only path (L-BFGS line searches).
    pub fn eval_value(&self, inputs: &[Tensor]) -> f64 {
        self.graph.eval(inputs, &[self.loss]).get(self.loss).item()
    }
}

/// Evaluate every shard's loss+gradient on a `policy`-sized worker pool
/// and combine with the deterministic pairwise tree — bitwise identical
/// for every policy (the shard layout and the tree shape depend only on
/// the shard count).
pub(crate) fn eval_shards_grad(
    shards: &[Shard],
    inputs: &[Tensor],
    policy: ParallelPolicy,
) -> (f64, Tensor) {
    let workers = par::workers_for_tasks(policy, shards.len());
    let results = par::run_indexed(shards.len(), workers, |s| shards[s].eval_grad(inputs));
    let loss = par::tree_reduce(results.iter().map(|(l, _)| *l).collect(), |a, b| a + b)
        .expect("objective has at least one shard");
    let grad = par::tree_reduce(
        results.into_iter().map(|(_, g)| g).collect::<Vec<_>>(),
        |mut a, b| {
            for (x, &y) in a.data_mut().iter_mut().zip(b.data()) {
                *x += y;
            }
            a
        },
    )
    .expect("objective has at least one shard");
    (loss, grad)
}

/// Forward-only twin of [`eval_shards_grad`].
pub(crate) fn eval_shards_value(
    shards: &[Shard],
    inputs: &[Tensor],
    policy: ParallelPolicy,
) -> f64 {
    let workers = par::workers_for_tasks(policy, shards.len());
    let losses = par::run_indexed(shards.len(), workers, |s| shards[s].eval_value(inputs));
    par::tree_reduce(losses, |a, b| a + b).expect("objective has at least one shard")
}

/// Batched forward-only evaluation: several trial parameter vectors
/// (`inputs[t]` is trial `t`'s per-slot input list) fanned through one
/// `trials × shards` task grid, each trial's shard losses combined with
/// the **same** pairwise tree as [`eval_shards_value`] — so every entry
/// is bitwise equal to a standalone `eval_shards_value` call on that
/// trial, for every policy. This is the line-search fast path: α-trials
/// are data-independent, so they pipeline through the shard pool
/// together instead of serializing one pool sweep per trial.
pub(crate) fn eval_shards_value_batch(
    shards: &[Shard],
    inputs: &[Vec<Tensor>],
    policy: ParallelPolicy,
) -> Vec<f64> {
    let tasks = shards.len() * inputs.len();
    let workers = par::workers_for_tasks(policy, tasks);
    let losses = par::run_indexed(tasks, workers, |t| {
        shards[t % shards.len()].eval_value(&inputs[t / shards.len()])
    });
    losses
        .chunks(shards.len())
        .map(|trial| {
            par::tree_reduce(trial.to_vec(), |a, b| a + b)
                .expect("objective has at least one shard")
        })
        .collect()
}

/// Slice a `[B, d]` collocation tensor into `ceil(B/chunk)` row chunks
/// (any column count — 1-D Burgers clouds and d-D PDE clouds alike).
pub(crate) fn chunk_rows(x: &Tensor, chunk: usize) -> Vec<Tensor> {
    let b = x.shape()[0];
    let d = x.shape()[1];
    (0..b.div_ceil(chunk))
        .map(|c| {
            let lo = c * chunk;
            let hi = (lo + chunk).min(b);
            Tensor::from_vec(x.data()[lo * d..hi * d].to_vec(), &[hi - lo, d])
        })
        .collect()
}

/// Running sum of loss-term nodes.
pub(crate) struct TermAccumulator {
    acc: Option<NodeId>,
}

impl TermAccumulator {
    pub fn new() -> TermAccumulator {
        TermAccumulator { acc: None }
    }

    /// Add `term` onto the running loss.
    pub fn push(&mut self, g: &mut Graph, term: NodeId) {
        self.acc = Some(match self.acc {
            None => term,
            Some(a) => g.add(a, term),
        });
    }

    /// The accumulated loss node (`None` when no terms were pushed).
    pub fn finish(self) -> Option<NodeId> {
        self.acc
    }
}

/// How a squared-residual term is normalized — each variant reproduces
/// one historical op sequence bit for bit.
#[derive(Clone, Copy, Debug)]
pub(crate) enum TermScale {
    /// `mean(r²) · weight` — the monolithic objective's sequence.
    Mean {
        /// Term weight applied after the mean.
        weight: f64,
    },
    /// `(Σ r²) · coeff` with the global point count pre-folded into
    /// `coeff` — the sharded sequence (`Σ_s L_s` sums to the full loss).
    ScaledSum {
        /// Combined `weight / N_global` coefficient.
        coeff: f64,
    },
}

impl TermScale {
    /// Record the scaled square of `r` on `g`.
    pub fn square_term(self, g: &mut Graph, r: NodeId) -> NodeId {
        match self {
            TermScale::Mean { weight } => {
                let ms = g.mean_square(r);
                g.scale(ms, weight)
            }
            TermScale::ScaledSum { coeff } => {
                let sq = g.mul(r, r);
                let sum = g.sum_all(sq);
                g.scale(sum, coeff)
            }
        }
    }
}

/// Flat parameter-vector layout shared by every objective:
/// `[mlp params (W0, b0, ...)] (+ λ_raw when an inverse parameter
/// exists)`, with the λ re-parameterization and the per-slot input
/// splitting in one place.
pub(crate) struct ThetaLayout {
    template: Mlp,
    n_params: usize,
    lambda_range: Option<(f64, f64)>,
}

impl ThetaLayout {
    pub fn new(mlp: &Mlp, lambda_range: Option<(f64, f64)>) -> ThetaLayout {
        ThetaLayout {
            template: mlp.clone(),
            n_params: mlp.n_params(),
            lambda_range,
        }
    }

    /// Flat dimension (`M` params, plus the λ_raw slot when present).
    pub fn dim(&self) -> usize {
        self.n_params + usize::from(self.lambda_range.is_some())
    }

    /// Initial flat vector: current MLP weights (+ `λ_raw = 0`, i.e. λ
    /// mid-bracket, when an inverse parameter exists).
    pub fn theta_init(&self, mlp: &Mlp) -> Tensor {
        let flat = params::flatten(mlp);
        let mut data = flat.into_vec();
        if self.lambda_range.is_some() {
            data.push(0.0);
        }
        Tensor::from_vec(data, &[self.dim()])
    }

    /// λ from the flat vector (0 for objectives without an inverse
    /// parameter).
    pub fn lambda_of(&self, theta: &Tensor) -> f64 {
        match self.lambda_range {
            Some(range) => lambda_from_raw(theta.data()[self.n_params], range),
            None => 0.0,
        }
    }

    /// The network part of `theta` as an [`Mlp`].
    pub fn mlp_of(&self, theta: &Tensor) -> Mlp {
        let mut mlp = self.template.clone();
        let flat = Tensor::from_vec(theta.data()[..self.n_params].to_vec(), &[self.n_params]);
        params::unflatten_into(&mut mlp, &flat);
        mlp
    }

    /// Per-slot input tensors in tape order (`W0, b0, W1, b1, ...`
    /// + λ_raw when present).
    pub fn inputs_of(&self, theta: &Tensor) -> Vec<Tensor> {
        assert_eq!(theta.numel(), self.dim(), "theta length");
        let flat = Tensor::from_vec(theta.data()[..self.n_params].to_vec(), &[self.n_params]);
        let mut inputs = params::split_like(&self.template, &flat);
        if self.lambda_range.is_some() {
            inputs.push(Tensor::from_vec(vec![theta.data()[self.n_params]], &[1]));
        }
        inputs
    }
}

/// The three anchor points and their target values.
pub(crate) struct BcData {
    /// Anchor points `[3, 1]`.
    pub x: Tensor,
    /// `u` targets.
    pub u: Vec<f64>,
    /// `u'` targets.
    pub du: Vec<f64>,
}

impl BcData {
    /// The spec's anchors: origin plus both domain ends (pins the
    /// `C = 1` family member).
    pub fn for_spec(spec: &BurgersLossSpec) -> BcData {
        let bc_xs = vec![0.0, -spec.x_max, spec.x_max];
        BcData {
            x: Tensor::from_vec(bc_xs.clone(), &[3, 1]),
            u: bc_xs.iter().map(|&x| spec.profile.u_true(x)).collect(),
            du: bc_xs
                .iter()
                .map(|&x| spec.profile.derivatives_true(x, 1)[1])
                .collect(),
        }
    }
}

/// The collocation slices one Burgers tape covers (`None` = not on this
/// shard; the monolithic objective passes all three).
pub(crate) struct BurgersSlices<'a> {
    /// Residual (Sobolev) collocation slice.
    pub res: Option<&'a Tensor>,
    /// Near-origin (L*) slice.
    pub org: Option<&'a Tensor>,
    /// Anchor data (shard 0 / monolithic only).
    pub bc: Option<&'a BcData>,
}

/// Which historical op sequence the loss terms use.
#[derive(Clone, Copy, Debug)]
pub(crate) enum LossScaling {
    /// Monolithic: `mean(r²)·weight` per term.
    MeanWeighted,
    /// Sharded: `(Σr²)·(weight/N_global)` per term, so shard losses and
    /// gradients sum exactly to the full objective.
    GlobalPrescaled,
}

/// Build one Burgers loss tape — **the** Burgers recipe, shared by the
/// monolithic and the sharded objective. Term order: Sobolev residual
/// terms, the high-order origin term L*, then the anchors; a single
/// `backward` wrt `[params..., λ_raw]`.
pub(crate) fn build_burgers_shard(
    spec: &BurgersLossSpec,
    mlp: &Mlp,
    engine: DerivEngine,
    ntp: &NtpEngine,
    lambda_range: (f64, f64),
    slices: BurgersSlices<'_>,
    scaling: LossScaling,
) -> Shard {
    let n = spec.profile.n_derivs();
    let k2 = 2 * spec.profile.k;

    let mut g = Graph::new();
    let param_nodes = mlp.input_param_nodes(&mut g);
    let lambda_raw = g.input(&[1]);
    let lambda = lambda_node(&mut g, lambda_raw, lambda_range);

    let channels_at = |g: &mut Graph, x_const: &Tensor, order: usize| -> Vec<NodeId> {
        let xn = g.constant(x_const.clone());
        match engine {
            DerivEngine::Ntp => ntp.forward_graph(g, mlp, xn, &param_nodes, order),
            DerivEngine::Autodiff => {
                let u = mlp.forward_graph(g, xn, &param_nodes);
                higher::derivative_stack(g, u, xn, order)
            }
        }
    };

    let mut acc = TermAccumulator::new();

    // --- Sobolev residual terms over the domain slice -------------------
    if let Some(x) = slices.res {
        let u = channels_at(&mut g, x, spec.m_sobolev + 1);
        let xn = g.constant(x.clone());
        let r_nodes = residual_derivative_nodes(&mut g, &u, xn, lambda, spec.m_sobolev);
        for (j, &r) in r_nodes.iter().enumerate() {
            let scale = match scaling {
                LossScaling::MeanWeighted => TermScale::Mean { weight: spec.q_weights[j] },
                LossScaling::GlobalPrescaled => TermScale::ScaledSum {
                    coeff: spec.q_weights[j] / spec.n_res as f64,
                },
            };
            let term = scale.square_term(&mut g, r);
            acc.push(&mut g, term);
        }
    }

    // --- High-order smoothness near the origin (L*) ---------------------
    if let Some(x) = slices.org {
        let u = channels_at(&mut g, x, n);
        let xn = g.constant(x.clone());
        let r_org = residual_derivative_nodes(&mut g, &u, xn, lambda, k2);
        // Normalize by the term's natural magnitude so one weight works
        // across profiles (the (2k)-th residual derivative ~ (2k+1)!).
        let fact: f64 = (1..=(k2 + 1)).map(|i| i as f64).product();
        let scale = match scaling {
            LossScaling::MeanWeighted => TermScale::Mean { weight: spec.w_high / (fact * fact) },
            LossScaling::GlobalPrescaled => TermScale::ScaledSum {
                coeff: spec.w_high / (fact * fact * spec.n_org as f64),
            },
        };
        let term = scale.square_term(&mut g, r_org[k2]);
        acc.push(&mut g, term);
    }

    // --- Anchor terms ---------------------------------------------------
    if let Some(bc) = slices.bc {
        let u_bc = channels_at(&mut g, &bc.x, 1);
        let target_u = g.constant(Tensor::from_vec(bc.u.clone(), &[3, 1]));
        let target_du = g.constant(Tensor::from_vec(bc.du.clone(), &[3, 1]));
        let du0 = g.sub(u_bc[0], target_u);
        let ms_u = g.mean_square(du0);
        let du1 = g.sub(u_bc[1], target_du);
        let ms_du = g.mean_square(du1);
        let bc_sum = g.add(ms_u, ms_du);
        let term = g.scale(bc_sum, spec.w_bc);
        acc.push(&mut g, term);
    }

    let loss = acc.finish().expect("shard has at least one loss term");
    let mut wrt = param_nodes.clone();
    wrt.push(lambda_raw);
    let grads = g.backward(loss, &wrt);

    Shard { graph: g, loss, grads }
}
