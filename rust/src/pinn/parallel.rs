//! Data-parallel PINN training: the collocation cloud sharded into fixed
//! row-chunks, one loss/gradient tape per shard, combined with a
//! deterministic pairwise tree reduction.
//!
//! The Burgers loss is a weighted sum of *independent per-collocation-
//! point* residual terms (plus three anchor points), so gradient
//! accumulation is embarrassingly data-parallel — the same structure the
//! inference path exploits row-wise in [`crate::ntp::NtpEngine::forward_n`].
//! [`ParallelObjective`] builds one compiled graph ("tape") per shard
//! with *sum*-of-squares terms pre-scaled by the **global** point counts,
//! so the shard losses and gradients sum exactly to the full objective:
//!
//! ```text
//! L = Σ_s L_s,   ∇L = Σ_s ∇L_s
//! L_s = Σ_j (Q_j/N_res)·Σ_{x∈res_s}|∂^j R|²
//!     + (w_high/((2k+1)!² N_org))·Σ_{x∈org_s}|∂^{2k}R|²
//!     + [s = 0]·w_bc·(anchor terms)
//! ```
//!
//! # Determinism
//!
//! The result is **bitwise identical** for every [`ParallelPolicy`]:
//!
//! - The shard layout depends only on the spec and the `chunk` size,
//!   never on the thread count.
//! - Each shard's tape is built once on the construction thread and
//!   evaluated purely (`Graph::eval` is `&self`), so a shard performs
//!   the exact same float operations wherever it runs.
//! - Per-shard losses and gradients are combined with
//!   [`crate::util::par::tree_reduce`], whose shape is a pure function of the shard
//!   count.
//!
//! `rust/tests/training_determinism.rs` locks this down (2/4/8 threads
//! vs serial, including non-divisible collocation counts and 50-step
//! optimizer trajectories).

use super::loss::{BurgersLossSpec, DerivEngine};
use super::terms::{
    build_burgers_shard, chunk_rows, eval_shards_grad, eval_shards_value,
    eval_shards_value_batch, BcData, BurgersSlices, LossScaling, Shard, ThetaLayout,
};
use crate::nn::Mlp;
use crate::ntp::{NtpEngine, ParallelPolicy};
use crate::opt::Objective;
use crate::tensor::Tensor;
use crate::util::prng::Prng;

/// Default collocation rows per shard (see [`ParallelObjective::build`]).
///
/// Small enough that the default Burgers cloud (128 + 32 points) splits
/// into several shards per core, large enough that one shard's tape
/// evaluation amortizes the scheduling overhead.
pub const DEFAULT_CHUNK_ROWS: usize = 32;

/// The sharded, data-parallel PINN objective.
///
/// Drop-in counterpart of [`super::PinnObjective`] (same flat parameter
/// layout `[mlp params..., λ_raw]`, same λ re-parameterization, same loss
/// up to floating-point summation order) whose `value`/`value_grad`
/// evaluate the shards on a pool of scoped worker threads chosen by a
/// [`ParallelPolicy`] and tree-reduce the partial results
/// deterministically.
///
/// ```
/// use ntangent::nn::Mlp;
/// use ntangent::ntp::ParallelPolicy;
/// use ntangent::opt::Objective;
/// use ntangent::pinn::{BurgersLossSpec, DerivEngine, ParallelObjective};
/// use ntangent::util::prng::Prng;
///
/// let mut spec = BurgersLossSpec::for_profile(1);
/// spec.n_res = 24; // keep the doc-example quick
/// spec.n_org = 8;
/// let mut rng = Prng::seeded(7);
/// let mlp = Mlp::uniform(1, 8, 2, 1, &mut rng);
/// let mut obj = ParallelObjective::build(
///     spec,
///     &mlp,
///     DerivEngine::Ntp,
///     ParallelPolicy::Fixed(2),
///     8, // collocation rows per shard
///     &mut rng,
/// );
/// let theta = obj.theta_init(&mlp);
/// let (loss, grad) = obj.value_grad(&theta);
/// assert!(loss.is_finite());
/// assert_eq!(grad.numel(), obj.dim());
/// assert!(obj.n_shards() > 1);
/// ```
pub struct ParallelObjective {
    shards: Vec<Shard>,
    layout: ThetaLayout,
    policy: ParallelPolicy,
    chunk: usize,
    /// The loss hyper-parameters this objective was built from.
    pub spec: BurgersLossSpec,
    /// Which engine computes the derivative channels on every shard tape.
    pub engine: DerivEngine,
    /// Full residual collocation set (kept for inspection/reporting).
    pub x_res: Tensor,
    /// Full near-origin collocation set.
    pub x_org: Tensor,
    /// Anchor points.
    pub x_bc: Tensor,
    /// Count of forward-only evaluations.
    pub n_forward: u64,
    /// Count of gradient evaluations (forward + backward per shard).
    pub n_backward: u64,
}

impl ParallelObjective {
    /// Build the sharded objective for a fresh problem instance.
    ///
    /// Collocation sets are sampled exactly as [`super::PinnObjective::build`]
    /// does (same `rng` consumption order), then split into fixed
    /// `chunk`-row shards: residual chunk `s` lands on shard `s`, the
    /// origin chunks fill the trailing shards (load balance against the
    /// anchor terms on shard 0). `policy` decides how many threads
    /// evaluate the shards; the result is bitwise independent of that
    /// choice.
    pub fn build(
        spec: BurgersLossSpec,
        mlp: &Mlp,
        engine: DerivEngine,
        policy: ParallelPolicy,
        chunk: usize,
        rng: &mut Prng,
    ) -> ParallelObjective {
        assert!(chunk >= 1, "chunk must be >= 1");
        let n = spec.profile.n_derivs();
        let lambda_range = spec.profile.lambda_range();

        // Collocation sets — identical sampling to the monolithic build.
        let x_res = super::collocation::stratified_points(-spec.x_max, spec.x_max, spec.n_res, rng);
        let x_org = super::collocation::cluster_points(0.0, spec.origin_radius, spec.n_org, rng);
        let bc = BcData::for_spec(&spec);

        let res_chunks = chunk_rows(&x_res, chunk);
        let org_chunks = chunk_rows(&x_org, chunk);
        let n_shards = res_chunks.len().max(org_chunks.len()).max(1);
        // Load balance: anchors sit on shard 0, so the (high-order,
        // heavier) origin chunks go on the *trailing* shards. Still a
        // pure function of (spec, chunk) — never of the thread count —
        // so the determinism guarantee is untouched.
        let org_offset = n_shards - org_chunks.len();

        let ntp = NtpEngine::new(n);
        let shards: Vec<Shard> = (0..n_shards)
            .map(|s| {
                build_burgers_shard(
                    &spec,
                    mlp,
                    engine,
                    &ntp,
                    lambda_range,
                    BurgersSlices {
                        res: res_chunks.get(s),
                        org: org_chunks.get(s.wrapping_sub(org_offset)),
                        bc: if s == 0 { Some(&bc) } else { None },
                    },
                    LossScaling::GlobalPrescaled,
                )
            })
            .collect();

        ParallelObjective {
            shards,
            layout: ThetaLayout::new(mlp, Some(lambda_range)),
            policy,
            chunk,
            spec,
            engine,
            x_res,
            x_org,
            x_bc: bc.x,
            n_forward: 0,
            n_backward: 0,
        }
    }

    /// Number of shards (tapes) the collocation cloud was split into.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Collocation rows per shard this objective was built with.
    pub fn chunk(&self) -> usize {
        self.chunk
    }

    /// The policy evaluating the shards.
    pub fn policy(&self) -> ParallelPolicy {
        self.policy
    }

    /// Change the evaluation policy. Purely a scheduling knob: results
    /// stay bitwise identical (the shard layout is fixed at build time).
    pub fn set_policy(&mut self, policy: ParallelPolicy) {
        self.policy = policy;
    }

    /// Total node count across all shard tapes — the size metric the
    /// training benchmarks report.
    pub fn graph_len(&self) -> usize {
        self.shards.iter().map(|s| s.graph.len()).sum()
    }

    /// Initial flat parameter vector: current MLP weights + `λ_raw = 0`
    /// (λ starts mid-bracket).
    pub fn theta_init(&self, mlp: &Mlp) -> Tensor {
        self.layout.theta_init(mlp)
    }

    /// Extract λ from the flat vector.
    pub fn lambda_of(&self, theta: &Tensor) -> f64 {
        self.layout.lambda_of(theta)
    }

    /// Write the network part of `theta` into an MLP for evaluation.
    pub fn mlp_of(&self, theta: &Tensor) -> Mlp {
        self.layout.mlp_of(theta)
    }
}

impl Objective for ParallelObjective {
    fn value_grad(&mut self, theta: &Tensor) -> (f64, Tensor) {
        self.n_backward += 1;
        eval_shards_grad(&self.shards, &self.layout.inputs_of(theta), self.policy)
    }

    fn value(&mut self, theta: &Tensor) -> f64 {
        self.n_forward += 1;
        eval_shards_value(&self.shards, &self.layout.inputs_of(theta), self.policy)
    }

    fn value_batch(&mut self, thetas: &[Tensor]) -> Vec<f64> {
        self.n_forward += thetas.len() as u64;
        let inputs: Vec<Vec<Tensor>> = thetas.iter().map(|t| self.layout.inputs_of(t)).collect();
        eval_shards_value_batch(&self.shards, &inputs, self.policy)
    }

    fn dim(&self) -> usize {
        self.layout.dim()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pinn::PinnObjective;
    use crate::util::allclose_slice;

    fn tiny_spec() -> BurgersLossSpec {
        let mut spec = BurgersLossSpec::for_profile(1);
        spec.n_res = 20;
        spec.n_org = 6;
        spec
    }

    /// Shards must be shareable by reference across scoped threads.
    #[test]
    fn objective_is_send_and_sync() {
        fn assert_sync<T: Sync>() {}
        fn assert_send<T: Send>() {}
        assert_sync::<ParallelObjective>();
        assert_send::<ParallelObjective>();
        assert_sync::<Shard>();
    }

    #[test]
    fn sharded_loss_and_grad_match_monolithic() {
        for engine in [DerivEngine::Ntp, DerivEngine::Autodiff] {
            let mut rng = Prng::seeded(42);
            let mlp = Mlp::uniform(1, 6, 2, 1, &mut rng);
            let mut rng_a = Prng::seeded(7);
            let mut rng_b = Prng::seeded(7);
            let mut mono = PinnObjective::build(tiny_spec(), &mlp, engine, &mut rng_a);
            let mut shd = ParallelObjective::build(
                tiny_spec(),
                &mlp,
                engine,
                ParallelPolicy::Serial,
                8,
                &mut rng_b,
            );
            assert_eq!(shd.n_shards(), 3); // ceil(20/8) residual chunks
            // Identical rng consumption ⇒ identical collocation clouds.
            assert_eq!(mono.x_res, shd.x_res);
            assert_eq!(mono.x_org, shd.x_org);

            let theta = mono.theta_init(&mlp);
            let (l1, g1) = mono.value_grad(&theta);
            let (l2, g2) = shd.value_grad(&theta);
            assert!(
                (l1 - l2).abs() <= 1e-10 * l1.abs().max(1.0),
                "{engine:?}: {l1} vs {l2}"
            );
            assert!(
                allclose_slice(g1.data(), g2.data(), 1e-8, 1e-10),
                "{engine:?}: grad max diff {}",
                crate::util::max_abs_diff(g1.data(), g2.data())
            );
            assert_eq!(shd.value(&theta), l2, "value() must match value_grad()");
            assert_eq!(shd.lambda_of(&theta), mono.lambda_of(&theta));
        }
    }

    #[test]
    fn policy_change_is_bitwise_invisible() {
        let mut rng_a = Prng::seeded(9);
        let mut rng_b = Prng::seeded(9);
        let mut rng_m = Prng::seeded(1);
        let mlp = Mlp::uniform(1, 6, 2, 1, &mut rng_m);
        let mut serial = ParallelObjective::build(
            tiny_spec(),
            &mlp,
            DerivEngine::Ntp,
            ParallelPolicy::Serial,
            4,
            &mut rng_a,
        );
        let mut fixed = ParallelObjective::build(
            tiny_spec(),
            &mlp,
            DerivEngine::Ntp,
            ParallelPolicy::Fixed(3),
            4,
            &mut rng_b,
        );
        let theta = serial.theta_init(&mlp);
        let (l1, g1) = serial.value_grad(&theta);
        let (l2, g2) = fixed.value_grad(&theta);
        assert_eq!(l1.to_bits(), l2.to_bits());
        assert_eq!(g1, g2);
        assert_eq!(serial.value(&theta).to_bits(), fixed.value(&theta).to_bits());
    }

    #[test]
    fn counters_and_sizes_track() {
        let mut rng = Prng::seeded(3);
        let mlp = Mlp::uniform(1, 5, 2, 1, &mut rng);
        let mut obj = ParallelObjective::build(
            tiny_spec(),
            &mlp,
            DerivEngine::Ntp,
            ParallelPolicy::Serial,
            64, // chunk > n_res: everything lands on one shard
            &mut rng,
        );
        assert_eq!(obj.n_shards(), 1);
        assert!(obj.graph_len() > 0);
        let theta = obj.theta_init(&mlp);
        let v = obj.value(&theta);
        let (vg, _) = obj.value_grad(&theta);
        assert_eq!(v, vg);
        assert_eq!(obj.n_forward, 1);
        assert_eq!(obj.n_backward, 1);
    }
}
