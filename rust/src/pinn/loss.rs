//! The Burgers PINN loss, built once as an autodiff graph with parameters
//! (and the inverse coefficient λ) as inputs.
//!
//! Loss structure (paper eq. (2) + appendix A):
//!
//! ```text
//! L(θ, λ) =  Σ_{j=0..m} Q_j · mean |∂_x^j R|²      over the domain cloud
//!          + w_high     · mean |∂_x^{2k} R|²       near the origin (L*)
//!          + w_bc       · anchor terms             (normalization/BC)
//! R(x) = -λ U + ((1+λ) x + U) U'
//! ```
//!
//! `∂_x^j R` is expanded symbolically with the Leibniz rule in terms of
//! the derivative channels `U^{(i)}` (so the *only* derivative engine in
//! play is the one under test):
//!
//! ```text
//! ∂^j R = -λ U^{(j)} + (1+λ)(x U^{(j+1)} + j U^{(j)})
//!         + Σ_{i=0..j} C(j,i) U^{(i)} U^{(j+1-i)}
//! ```
//!
//! The channels come either from n-TangentProp recorded on the tape
//! (quasilinear) or from repeated autodiff (exponential baseline) — the
//! head-to-head of Fig. 6.

use super::burgers::BurgersProfile;
use super::terms::{build_burgers_shard, BcData, BurgersSlices, LossScaling, Shard, ThetaLayout};
use crate::autodiff::{Graph, NodeId};
use crate::nn::Mlp;
use crate::ntp::NtpEngine;
use crate::opt::Objective;
use crate::tensor::Tensor;
use crate::util::prng::Prng;

/// Which derivative engine computes the channels `U^{(i)}`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DerivEngine {
    /// n-TangentProp forward recorded on the tape (the paper's method).
    Ntp,
    /// Repeated reverse-mode autodiff (the baseline).
    Autodiff,
}

/// Hyper-parameters of the Burgers PINN loss.
#[derive(Clone, Debug)]
pub struct BurgersLossSpec {
    /// The profile being trained against.
    pub profile: BurgersProfile,
    /// Sobolev order `m` on the residual (paper trains with m = 1).
    pub m_sobolev: usize,
    /// Relative weights `Q_j`, length `m_sobolev + 1`.
    pub q_weights: Vec<f64>,
    /// Weight of the high-order origin term L*.
    pub w_high: f64,
    /// Weight of the anchor/BC terms.
    pub w_bc: f64,
    /// Residual collocation points.
    pub n_res: usize,
    /// Near-origin points for L*.
    pub n_org: usize,
    /// Training domain `[-x_max, x_max]`.
    pub x_max: f64,
    /// Radius of the origin cluster.
    pub origin_radius: f64,
}

impl BurgersLossSpec {
    /// Paper-flavored defaults for profile `k`.
    pub fn for_profile(k: usize) -> BurgersLossSpec {
        BurgersLossSpec {
            profile: BurgersProfile::new(k),
            m_sobolev: 1,
            q_weights: vec![1.0, 0.1],
            // Tuned on profile 2 (see EXPERIMENTS.md §Runs): the
            // factorial-normalized L* term needs substantial weight to
            // give λ a decisive gradient at higher profiles.
            w_high: 20.0,
            w_bc: 10.0,
            n_res: 128,
            n_org: 32,
            x_max: 2.0,
            origin_radius: 0.1,
        }
    }
}

/// A compiled PINN objective: graph built once, evaluated per step.
///
/// Flat parameter layout: `[mlp params (W0,b0,...), λ_raw]`, so
/// `dim() = M + 1`. λ is re-parameterized as
/// `λ = lo + (hi-lo)·sigmoid(λ_raw)` to stay inside the profile's bracket.
///
/// The loss recipe itself lives in the shared term builder
/// (`pinn::terms::build_burgers_shard`, `MeanWeighted` scaling) — the
/// same code path the sharded [`super::ParallelObjective`] compiles per
/// shard, so the two can never drift apart.
pub struct PinnObjective {
    shard: Shard,
    layout: ThetaLayout,
    /// The loss hyper-parameters this objective was built from.
    pub spec: BurgersLossSpec,
    /// Which derivative engine computes the channels.
    pub engine: DerivEngine,
    /// Residual collocation set (kept for inspection/reporting).
    pub x_res: Tensor,
    /// Near-origin collocation set.
    pub x_org: Tensor,
    /// Anchor points.
    pub x_bc: Tensor,
    /// Count of graph evaluations (forward passes).
    pub n_forward: u64,
    /// Count of gradient evaluations (forward + backward).
    pub n_backward: u64,
}

fn binom(n: usize, k: usize) -> f64 {
    let mut acc = 1.0;
    for i in 0..k {
        acc = acc * (n - i) as f64 / (i + 1) as f64;
    }
    acc
}

/// sigmoid on the tape: `σ(x) = 0.5·(tanh(x/2) + 1)`.
fn sigmoid_node(g: &mut Graph, x: NodeId) -> NodeId {
    let half = g.scale(x, 0.5);
    let t = g.tanh(half);
    let shifted = g.add_scalar(t, 1.0);
    g.scale(shifted, 0.5)
}

/// Record the λ re-parameterization `λ = lo + (hi−lo)·σ(λ_raw)` on the
/// tape (shared by the monolithic and the sharded objective so both train
/// the exact same λ surface).
pub fn lambda_node(g: &mut Graph, lambda_raw: NodeId, range: (f64, f64)) -> NodeId {
    let sig = sigmoid_node(g, lambda_raw);
    let (lo, hi) = range;
    let scaled = g.scale(sig, hi - lo);
    g.add_scalar(scaled, lo)
}

/// Scalar twin of [`lambda_node`]: λ from an unconstrained `λ_raw`.
pub fn lambda_from_raw(raw: f64, range: (f64, f64)) -> f64 {
    let s = 0.5 * ((0.5 * raw).tanh() + 1.0);
    range.0 + (range.1 - range.0) * s
}

/// Build `∂_x^j R` for `j = 0..=j_max` from channels `u[i] = U^{(i)}`
/// (`[B,1]` nodes), the collocation constant `x` and the λ node (`[1]`).
pub fn residual_derivative_nodes(
    g: &mut Graph,
    u: &[NodeId],
    x: NodeId,
    lambda: NodeId,
    j_max: usize,
) -> Vec<NodeId> {
    assert!(
        u.len() > j_max + 1,
        "need channels up to order {} for residual order {j_max}",
        j_max + 1
    );
    let bshape = g.shape(u[0]).to_vec();
    let lam_b = g.broadcast_scalar(lambda, &bshape);
    let one_plus = g.add_scalar(lambda, 1.0);
    let one_plus_b = g.broadcast_scalar(one_plus, &bshape);

    (0..=j_max)
        .map(|j| {
            // -λ U^{(j)}
            let t1 = {
                let m = g.mul(lam_b, u[j]);
                g.neg(m)
            };
            // (1+λ)(x U^{(j+1)} + j U^{(j)})
            let t2 = {
                let xu = g.mul(x, u[j + 1]);
                let inner = if j == 0 {
                    xu
                } else {
                    let ju = g.scale(u[j], j as f64);
                    g.add(xu, ju)
                };
                g.mul(one_plus_b, inner)
            };
            // Σ_i C(j,i) U^{(i)} U^{(j+1-i)}
            let mut t3: Option<NodeId> = None;
            for i in 0..=j {
                let prod = g.mul(u[i], u[j + 1 - i]);
                let term = g.scale(prod, binom(j, i));
                t3 = Some(match t3 {
                    None => term,
                    Some(acc) => g.add(acc, term),
                });
            }
            let partial = g.add(t1, t2);
            g.add(partial, t3.unwrap())
        })
        .collect()
}

impl PinnObjective {
    /// Build the objective graph for a fresh problem instance.
    ///
    /// `mlp` provides the architecture (weights are *inputs*, not baked).
    pub fn build(
        spec: BurgersLossSpec,
        mlp: &Mlp,
        engine: DerivEngine,
        rng: &mut Prng,
    ) -> PinnObjective {
        let n = spec.profile.n_derivs(); // 2k+1 channels
        let lambda_range = spec.profile.lambda_range();

        // Collocation sets.
        let x_res = super::collocation::stratified_points(-spec.x_max, spec.x_max, spec.n_res, rng);
        let x_org = super::collocation::cluster_points(0.0, spec.origin_radius, spec.n_org, rng);
        let bc = BcData::for_spec(&spec);

        let ntp = NtpEngine::new(n);
        let shard = build_burgers_shard(
            &spec,
            mlp,
            engine,
            &ntp,
            lambda_range,
            BurgersSlices {
                res: Some(&x_res),
                org: Some(&x_org),
                bc: Some(&bc),
            },
            LossScaling::MeanWeighted,
        );

        PinnObjective {
            shard,
            layout: ThetaLayout::new(mlp, Some(lambda_range)),
            spec,
            engine,
            x_res,
            x_org,
            x_bc: bc.x,
            n_forward: 0,
            n_backward: 0,
        }
    }

    /// Initial flat parameter vector: current MLP weights + λ_raw = 0
    /// (i.e. λ starts mid-bracket).
    pub fn theta_init(&self, mlp: &Mlp) -> Tensor {
        self.layout.theta_init(mlp)
    }

    /// Extract λ from the flat vector.
    pub fn lambda_of(&self, theta: &Tensor) -> f64 {
        self.layout.lambda_of(theta)
    }

    /// Write the network part of `theta` into an MLP for evaluation.
    pub fn mlp_of(&self, theta: &Tensor) -> Mlp {
        self.layout.mlp_of(theta)
    }

    /// Graph size (node count) — reported by the training benchmarks.
    pub fn graph_len(&self) -> usize {
        self.shard.graph.len()
    }
}

impl Objective for PinnObjective {
    fn value_grad(&mut self, theta: &Tensor) -> (f64, Tensor) {
        self.n_backward += 1;
        self.shard.eval_grad(&self.layout.inputs_of(theta))
    }

    fn value(&mut self, theta: &Tensor) -> f64 {
        // Forward-only evaluation — the cheap path the L-BFGS line search
        // exploits (no gradient subgraph is touched).
        self.n_forward += 1;
        self.shard.eval_value(&self.layout.inputs_of(theta))
    }

    fn dim(&self) -> usize {
        self.layout.dim()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::allclose_slice;

    fn tiny_spec(k: usize) -> BurgersLossSpec {
        let mut spec = BurgersLossSpec::for_profile(k);
        spec.n_res = 16;
        spec.n_org = 8;
        spec
    }

    #[test]
    fn engines_agree_on_loss_and_grad() {
        let mut rng = Prng::seeded(42);
        let mlp = Mlp::uniform(1, 6, 2, 1, &mut rng);
        let spec = tiny_spec(1);
        let mut rng_a = Prng::seeded(7);
        let mut rng_b = Prng::seeded(7);
        let mut obj_ntp = PinnObjective::build(spec.clone(), &mlp, DerivEngine::Ntp, &mut rng_a);
        let mut obj_ad = PinnObjective::build(spec, &mlp, DerivEngine::Autodiff, &mut rng_b);
        let theta = obj_ntp.theta_init(&mlp);

        let (l1, g1) = obj_ntp.value_grad(&theta);
        let (l2, g2) = obj_ad.value_grad(&theta);
        assert!((l1 - l2).abs() < 1e-9 * l2.abs().max(1.0), "{l1} vs {l2}");
        assert!(
            allclose_slice(g1.data(), g2.data(), 1e-6, 1e-9),
            "grad mismatch, max {}",
            crate::util::max_abs_diff(g1.data(), g2.data())
        );
        // λ gradient specifically must match (the inverse-problem signal).
        let m = obj_ntp.dim() - 1;
        assert!((g1.data()[m] - g2.data()[m]).abs() < 1e-8);
    }

    /// The PINN objective must agree between derivative engines for every
    /// registered activation (the tape records generic towers).
    #[test]
    fn engines_agree_on_loss_and_grad_for_every_activation() {
        use crate::ntp::ActivationKind;
        for kind in ActivationKind::ALL {
            let mut rng = Prng::seeded(43 + kind.index() as u64);
            let mlp = Mlp::uniform_with(1, 5, 2, 1, kind, &mut rng);
            let spec = tiny_spec(1);
            let mut rng_a = Prng::seeded(8);
            let mut rng_b = Prng::seeded(8);
            let mut obj_ntp =
                PinnObjective::build(spec.clone(), &mlp, DerivEngine::Ntp, &mut rng_a);
            let mut obj_ad = PinnObjective::build(spec, &mlp, DerivEngine::Autodiff, &mut rng_b);
            let theta = obj_ntp.theta_init(&mlp);

            let (l1, g1) = obj_ntp.value_grad(&theta);
            let (l2, g2) = obj_ad.value_grad(&theta);
            assert!(
                (l1 - l2).abs() < 1e-9 * l2.abs().max(1.0),
                "{}: {l1} vs {l2}",
                kind.name()
            );
            assert!(
                allclose_slice(g1.data(), g2.data(), 1e-6, 1e-9),
                "{}: grad mismatch, max {}",
                kind.name(),
                crate::util::max_abs_diff(g1.data(), g2.data())
            );
        }
    }

    #[test]
    fn loss_vanishes_on_true_solution_channels() {
        // Evaluate the residual nodes directly on exact channels: R^{(j)}
        // must be ~0 at λ = 1/(2k).
        let profile = BurgersProfile::new(1);
        let xs = [-1.5, -0.7, 0.3, 1.1];
        let n = 3;
        let mut g = Graph::new();
        let mut chan_data = vec![vec![0.0; xs.len()]; n + 1];
        for (col, &x) in xs.iter().enumerate() {
            let d = profile.derivatives_true(x, n);
            for (i, &di) in d.iter().enumerate() {
                chan_data[i][col] = di;
            }
        }
        let chans: Vec<NodeId> = chan_data
            .iter()
            .map(|c| g.constant(Tensor::from_vec(c.clone(), &[xs.len(), 1])))
            .collect();
        let xn = g.constant(Tensor::from_vec(xs.to_vec(), &[xs.len(), 1]));
        let lam = g.constant(Tensor::scalar(profile.lambda_smooth()));
        let r = residual_derivative_nodes(&mut g, &chans, xn, lam, 2);
        let vals = g.eval(&[], &r);
        for (j, &rid) in r.iter().enumerate() {
            let worst = vals.get(rid).max_abs();
            assert!(worst < 1e-7, "∂^{j} R = {worst}");
        }
    }

    #[test]
    fn residual_derivatives_match_autodiff_of_residual() {
        // Leibniz expansion == differentiating R(x) directly on the tape.
        let mut rng = Prng::seeded(11);
        let mlp = Mlp::uniform(1, 5, 2, 1, &mut rng);
        let xs = Tensor::from_vec(vec![-0.8, 0.1, 0.9], &[3, 1]);
        let lambda = 0.37;
        let jmax = 2;

        // Path A: Leibniz nodes from ntp channels.
        let engine = NtpEngine::new(jmax + 1);
        let mut g = Graph::new();
        let pn = mlp.const_param_nodes(&mut g);
        let xn = g.constant(xs.clone());
        let chans = engine.forward_graph(&mut g, &mlp, xn, &pn, jmax + 1);
        let lam = g.constant(Tensor::scalar(lambda));
        let r_nodes = residual_derivative_nodes(&mut g, &chans, xn, lam, jmax);
        let vals = g.eval(&[], &r_nodes);

        // Path B: build R(x) with x as input, differentiate repeatedly.
        let mut g2 = Graph::new();
        let x2 = g2.input(&[3, 1]);
        let pn2 = mlp.const_param_nodes(&mut g2);
        let u = mlp.forward_graph(&mut g2, x2, &pn2);
        let s = g2.sum_all(u);
        let du = g2.backward(s, &[x2])[0];
        let lam2 = g2.constant(Tensor::full(&[3, 1], lambda));
        let lu = g2.mul(lam2, u);
        let nlu = g2.neg(lu);
        let xl = g2.scale(x2, 1.0 + lambda);
        let adv = g2.add(xl, u);
        let advu = g2.mul(adv, du);
        let r = g2.add(nlu, advu);
        let mut r_stack = vec![r];
        let mut cur = r;
        for _ in 0..jmax {
            let sr = g2.sum_all(cur);
            cur = g2.backward(sr, &[x2])[0];
            r_stack.push(cur);
        }
        let vals2 = g2.eval(&[xs.clone()], &r_stack);

        for j in 0..=jmax {
            assert!(
                allclose_slice(
                    vals.get(r_nodes[j]).data(),
                    vals2.get(r_stack[j]).data(),
                    1e-9,
                    1e-10
                ),
                "order {j}"
            );
        }
    }

    #[test]
    fn lambda_mapping_respects_bracket() {
        let mut rng = Prng::seeded(1);
        let mlp = Mlp::uniform(1, 4, 1, 1, &mut rng);
        let obj = PinnObjective::build(tiny_spec(2), &mlp, DerivEngine::Ntp, &mut rng);
        let (lo, hi) = BurgersProfile::new(2).lambda_range();
        for raw in [-50.0, -1.0, 0.0, 1.0, 50.0] {
            let mut theta = obj.theta_init(&mlp);
            let m = theta.numel() - 1;
            theta.data_mut()[m] = raw;
            let lam = obj.lambda_of(&theta);
            assert!(lam > lo - 1e-12 && lam < hi + 1e-12, "λ={lam}");
        }
        // raw = 0 → mid-bracket.
        let theta = obj.theta_init(&mlp);
        assert!((obj.lambda_of(&theta) - 0.5 * (lo + hi)).abs() < 1e-12);
    }

    #[test]
    fn value_matches_value_grad_loss() {
        let mut rng = Prng::seeded(2);
        let mlp = Mlp::uniform(1, 5, 2, 1, &mut rng);
        let mut obj = PinnObjective::build(tiny_spec(1), &mlp, DerivEngine::Ntp, &mut rng);
        let theta = obj.theta_init(&mlp);
        let v = obj.value(&theta);
        let (vg, _) = obj.value_grad(&theta);
        assert_eq!(v, vg);
        assert_eq!(obj.n_forward, 1);
        assert_eq!(obj.n_backward, 1);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let mut rng = Prng::seeded(3);
        let mlp = Mlp::uniform(1, 4, 1, 1, &mut rng);
        let mut obj = PinnObjective::build(tiny_spec(1), &mlp, DerivEngine::Ntp, &mut rng);
        let theta = obj.theta_init(&mlp);
        let (_, grad) = obj.value_grad(&theta);
        let eps = 1e-6;
        // Spot-check a few coordinates including λ_raw.
        for &i in &[0usize, 3, theta.numel() - 1] {
            let mut tp = theta.clone();
            tp.data_mut()[i] += eps;
            let mut tm = theta.clone();
            tm.data_mut()[i] -= eps;
            let fd = (obj.value(&tp) - obj.value(&tm)) / (2.0 * eps);
            assert!(
                (grad.data()[i] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                "coord {i}: {} vs fd {fd}",
                grad.data()[i]
            );
        }
    }
}
