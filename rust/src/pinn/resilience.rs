//! Failure model of the trainer: numeric health guards, classified
//! [`NumericError`]s, deterministic divergence recovery, and
//! deterministic fault injection.
//!
//! A multi-hour derivative-supervised run (the workload the paper's
//! quasilinear forward passes make affordable) fails in a handful of
//! stereotyped ways: an activation tower overflows (`softplus`/`gelu`
//! exponentials), a residual goes NaN, a line search collapses, or the
//! process is killed mid-write. This module gives the trainer the same
//! failure model the serving stack got in the fault-suite work:
//!
//! - **Guards** ([`probe_step`]): after every optimizer step the loss,
//!   the gradient and θ are scanned with the SIMD-dispatched
//!   [`Isa::all_finite`] reduction and failures classified into the
//!   [`NumericError`] taxonomy. The probes are read-only — a healthy
//!   trajectory is bit-for-bit unaffected by guarding.
//! - **Recovery**: on a tripped guard the schedule rolls back to its
//!   last in-memory snapshot and applies a *deterministic* intervention
//!   (Adam learning rate scaled by `lr_backoff^retries`; L-BFGS
//!   curvature memory dropped), bounded by `max_retries` before a clean
//!   abort that still persists the last-good checkpoint. Because the
//!   intervention is a pure function of `(snapshot, retries)`, recovery
//!   itself is reproducible — interrupted-and-resumed runs take the
//!   identical recovery path.
//! - **Fault injection** ([`FaultPlan`]): the `NTANGENT_FAULT`
//!   environment hook (`nan-loss@5;nan-grad@12;kill@20`) injects
//!   non-finite values or a simulated crash at configured global epochs,
//!   mirroring the serving fault suite. Faults fire **once** and are
//!   consumed, so a rolled-back trajectory passes the fault point
//!   cleanly on the retry — exactly the transient-fault shape the
//!   recovery path exists for.

use crate::simd::Isa;
use std::fmt;
use std::path::PathBuf;

/// Classified numeric-health failures detected by the training guards.
/// The `epoch` is the global epoch index (Adam epochs from 0, L-BFGS
/// continuing) at which the probe tripped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NumericError {
    /// The loss evaluated to ±∞ — the signature of an activation-tower
    /// overflow (e.g. `softplus`/`gelu` exponentials) blowing up before
    /// producing a NaN.
    TowerOverflow {
        /// Global epoch of the tripped probe.
        epoch: usize,
    },
    /// The loss evaluated to NaN — a non-finite residual somewhere in
    /// the collocation cloud.
    NonFiniteResidual {
        /// Global epoch of the tripped probe.
        epoch: usize,
    },
    /// A gradient block contains NaN/∞.
    NonFiniteGradient {
        /// Global epoch of the tripped probe.
        epoch: usize,
    },
    /// The parameter vector itself contains NaN/∞ (a poisoned update).
    NonFiniteTheta {
        /// Global epoch of the tripped probe.
        epoch: usize,
    },
    /// The L-BFGS line search failed on consecutive steps — the run is
    /// stalled and retrying the same direction cannot help.
    LineSearchFailed {
        /// Global epoch of the tripped probe.
        epoch: usize,
    },
}

impl NumericError {
    /// The global epoch the probe tripped at.
    pub fn epoch(&self) -> usize {
        match self {
            NumericError::TowerOverflow { epoch }
            | NumericError::NonFiniteResidual { epoch }
            | NumericError::NonFiniteGradient { epoch }
            | NumericError::NonFiniteTheta { epoch }
            | NumericError::LineSearchFailed { epoch } => *epoch,
        }
    }

    /// Stable taxonomy tag (`tower-overflow`, `non-finite-residual`,
    /// `non-finite-gradient`, `non-finite-theta`, `line-search-failed`).
    pub fn kind(&self) -> &'static str {
        match self {
            NumericError::TowerOverflow { .. } => "tower-overflow",
            NumericError::NonFiniteResidual { .. } => "non-finite-residual",
            NumericError::NonFiniteGradient { .. } => "non-finite-gradient",
            NumericError::NonFiniteTheta { .. } => "non-finite-theta",
            NumericError::LineSearchFailed { .. } => "line-search-failed",
        }
    }
}

impl fmt::Display for NumericError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "numeric {} at epoch {}", self.kind(), self.epoch())
    }
}

impl std::error::Error for NumericError {}

/// Scan one optimizer step's outputs for numeric poison and classify the
/// first failure found. `loss` is the step's loss (pass a finite
/// sentinel if the step produced none), `grad` the gradient if one was
/// materialized this step, `theta` the post-update parameter vector. All
/// vector scans go through the SIMD-dispatched [`Isa::all_finite`]
/// reduction; the probe is read-only and cannot perturb the trajectory.
pub fn probe_step(
    loss: f64,
    grad: Option<&[f64]>,
    theta: &[f64],
    epoch: usize,
) -> Option<NumericError> {
    if loss.is_nan() {
        return Some(NumericError::NonFiniteResidual { epoch });
    }
    if loss.is_infinite() {
        return Some(NumericError::TowerOverflow { epoch });
    }
    let isa = Isa::active();
    if let Some(g) = grad {
        if !isa.all_finite(g) {
            return Some(NumericError::NonFiniteGradient { epoch });
        }
    }
    if !isa.all_finite(theta) {
        return Some(NumericError::NonFiniteTheta { epoch });
    }
    None
}

/// What a [`FaultPlan`] entry injects.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Replace the step's loss with NaN.
    NanLoss,
    /// Poison the step's gradient (Adam phase) or θ (L-BFGS phase, where
    /// the gradient is internal to the step) with NaN.
    NanGrad,
    /// Simulate a crash: the schedule stops immediately, writing no
    /// further checkpoints — resume must work from what is already on
    /// disk, exactly like a real kill.
    Kill,
}

impl FaultKind {
    fn name(self) -> &'static str {
        match self {
            FaultKind::NanLoss => "nan-loss",
            FaultKind::NanGrad => "nan-grad",
            FaultKind::Kill => "kill",
        }
    }

    fn from_name(name: &str) -> Option<FaultKind> {
        match name {
            "nan-loss" => Some(FaultKind::NanLoss),
            "nan-grad" => Some(FaultKind::NanGrad),
            "kill" => Some(FaultKind::Kill),
            _ => None,
        }
    }
}

/// A deterministic fault-injection schedule: `(kind, global epoch)`
/// pairs, each firing **once**. Parsed from the `NTANGENT_FAULT`
/// environment variable (`nan-loss@5;nan-grad@12;kill@20`) or built
/// in-process by tests.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    faults: Vec<(FaultKind, usize, bool)>, // (kind, epoch, consumed)
}

impl FaultPlan {
    /// The empty plan (no injection).
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Build a plan in-process (test harnesses).
    pub fn new(faults: &[(FaultKind, usize)]) -> FaultPlan {
        FaultPlan {
            faults: faults.iter().map(|&(k, e)| (k, e, false)).collect(),
        }
    }

    /// Parse a `kind@epoch;kind@epoch` spec.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut faults = Vec::new();
        for part in spec.split(';').filter(|p| !p.trim().is_empty()) {
            let (kind, at) = part
                .trim()
                .split_once('@')
                .ok_or_else(|| format!("fault '{part}' is not kind@epoch"))?;
            let kind = FaultKind::from_name(kind.trim())
                .ok_or_else(|| format!("unknown fault kind '{kind}'"))?;
            let epoch: usize = at
                .trim()
                .parse()
                .map_err(|_| format!("fault epoch '{at}' is not a number"))?;
            faults.push((kind, epoch, false));
        }
        Ok(FaultPlan { faults })
    }

    /// Read the `NTANGENT_FAULT` hook. A malformed spec is reported on
    /// stderr and ignored (a debug hook must never take a run down on
    /// its own).
    pub fn from_env() -> FaultPlan {
        match std::env::var("NTANGENT_FAULT") {
            Ok(spec) => match FaultPlan::parse(&spec) {
                Ok(plan) => plan,
                Err(e) => {
                    eprintln!("NTANGENT_FAULT ignored: {e}");
                    FaultPlan::none()
                }
            },
            Err(_) => FaultPlan::none(),
        }
    }

    /// True if the plan holds no (remaining) faults.
    pub fn is_empty(&self) -> bool {
        self.faults.iter().all(|&(_, _, consumed)| consumed)
    }

    /// Fire-once check: returns `true` (and consumes the entry) if an
    /// unconsumed `kind` fault is scheduled at `epoch`. A rolled-back
    /// trajectory passing `epoch` again sees nothing — the transient
    /// fault has already happened.
    pub fn take(&mut self, kind: FaultKind, epoch: usize) -> bool {
        for f in &mut self.faults {
            if f.0 == kind && f.1 == epoch && !f.2 {
                f.2 = true;
                return true;
            }
        }
        false
    }
}

/// Configuration of the resilient schedule: guarding, snapshot/checkpoint
/// cadence, the bounded deterministic recovery schedule, and fault
/// injection.
#[derive(Clone, Debug)]
pub struct ResilienceConfig {
    /// Where periodic + final checkpoints go (`None` = no disk
    /// checkpoints; in-memory rollback snapshots are still taken).
    pub checkpoint_path: Option<PathBuf>,
    /// Write a checkpoint every this many global epochs (`0` = only at
    /// the end of the run). Ignored without a `checkpoint_path`.
    pub checkpoint_every: usize,
    /// Take an in-memory rollback snapshot every this many global epochs
    /// (phase starts always snapshot). Checkpoint writes snapshot too.
    pub snapshot_every: usize,
    /// Recovery attempts before the run aborts cleanly (writing the
    /// last-good checkpoint).
    pub max_retries: u64,
    /// Deterministic Adam learning-rate backoff: after `r` retries the
    /// rate is `adam_lr * lr_backoff^r`.
    pub lr_backoff: f64,
    /// Enable the numeric health guards (read-only probes; disabling
    /// restores the fail-late seed behaviour).
    pub guard: bool,
    /// Fault-injection schedule (defaults to the `NTANGENT_FAULT` hook).
    pub fault: FaultPlan,
    /// Write one JSON line of per-step telemetry (loss, gradient norm,
    /// retries, step timing — see [`crate::pinn::telemetry`]) per global
    /// epoch to this path (`None` = no telemetry; the trajectory is
    /// bitwise unaffected either way).
    pub telemetry_path: Option<PathBuf>,
}

impl Default for ResilienceConfig {
    fn default() -> Self {
        ResilienceConfig {
            checkpoint_path: None,
            checkpoint_every: 0,
            snapshot_every: 25,
            max_retries: 3,
            lr_backoff: 0.5,
            guard: true,
            fault: FaultPlan::from_env(),
            telemetry_path: None,
        }
    }
}

/// Health record of a finished (or stopped) schedule, attached to every
/// training result.
#[derive(Clone, Debug, Default)]
pub struct RunHealth {
    /// A `kill` fault stopped the run mid-trajectory (resume from the
    /// last on-disk checkpoint to continue).
    pub interrupted: bool,
    /// The run diverged and exhausted its retries; the result carries
    /// the last-good parameters, and the last-good checkpoint was
    /// written if a path was configured.
    pub aborted: Option<NumericError>,
    /// Recovery interventions consumed over the whole run.
    pub retries: u64,
    /// First checkpoint-write failure, if any (the run itself continues;
    /// durability, not correctness, is what degraded).
    pub checkpoint_error: Option<String>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_classifies_in_priority_order() {
        let ok = [0.0, 1.0, -2.0];
        let bad = [0.0, f64::NAN, 1.0];
        assert_eq!(probe_step(1.0, Some(&ok), &ok, 3), None);
        assert_eq!(
            probe_step(f64::NAN, Some(&bad), &bad, 3),
            Some(NumericError::NonFiniteResidual { epoch: 3 })
        );
        assert_eq!(
            probe_step(f64::INFINITY, None, &ok, 4),
            Some(NumericError::TowerOverflow { epoch: 4 })
        );
        assert_eq!(
            probe_step(1.0, Some(&bad), &bad, 5),
            Some(NumericError::NonFiniteGradient { epoch: 5 })
        );
        assert_eq!(
            probe_step(1.0, None, &bad, 6),
            Some(NumericError::NonFiniteTheta { epoch: 6 })
        );
    }

    #[test]
    fn numeric_error_reports_kind_and_epoch() {
        let e = NumericError::TowerOverflow { epoch: 9 };
        assert_eq!(e.kind(), "tower-overflow");
        assert_eq!(e.epoch(), 9);
        assert_eq!(format!("{e}"), "numeric tower-overflow at epoch 9");
    }

    #[test]
    fn fault_plan_parses_and_fires_once() {
        let mut plan = FaultPlan::parse("nan-loss@5; kill@20 ;nan-grad@5").unwrap();
        assert!(!plan.take(FaultKind::NanLoss, 4));
        assert!(plan.take(FaultKind::NanLoss, 5));
        assert!(!plan.take(FaultKind::NanLoss, 5), "faults are consumed");
        assert!(plan.take(FaultKind::NanGrad, 5));
        assert!(plan.take(FaultKind::Kill, 20));
        assert!(plan.is_empty());

        assert!(FaultPlan::parse("nan-loss").is_err());
        assert!(FaultPlan::parse("explode@3").is_err());
        assert!(FaultPlan::parse("kill@x").is_err());
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }
}
