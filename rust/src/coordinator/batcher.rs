//! Dynamic batcher: coalesce queued requests into backend-sized batches.
//!
//! Policy (vLLM-router-style continuous batching, one loop per worker):
//! take the oldest request, then greedily drain the queue — waiting up to
//! `max_wait` for stragglers — until the batch capacity is filled, run the
//! backend once, and scatter slices back to each caller. Requests larger
//! than the capacity are split across consecutive backend calls.
//!
//! Each worker of a pool runs its own `run_loop` on its own queue (the
//! [`crate::coordinator::ServiceHandle`] shards requests per activation),
//! tagging its metrics with its worker id. On shutdown a worker first
//! drains everything still queued, so no accepted request is dropped.

use super::backend::EvalBackend;
use super::metrics::Metrics;
use crate::ntp::ActivationKind;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// How long to wait for additional requests once one is pending.
    pub max_wait: Duration,
    /// Bound of each worker's ingress queue (in messages). Submissions
    /// beyond it are shed on the wire path with an
    /// `{"error":"overloaded","retry_ms":…}` response (in-process
    /// callers block instead — natural backpressure).
    pub queue_depth: usize,
    /// Retry hint (milliseconds) carried by shed responses.
    pub shed_retry_ms: u64,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_wait: Duration::from_micros(200),
            queue_depth: 1024,
            shed_retry_ms: 50,
        }
    }
}

/// One queued evaluation request.
pub struct Request {
    /// Points to evaluate the derivative stack at.
    pub points: Vec<f64>,
    /// Optional per-request activation override (`None` = the served
    /// model's own activation). Requests are only coalesced with others
    /// of the same activation — the backend runs one tower per batch.
    pub activation: Option<ActivationKind>,
    /// When the request entered the queue (latency metric).
    pub enqueued: Instant,
    /// Channel the response is sent on.
    pub resp: Sender<Response>,
}

/// Queue message: work or an explicit stop (the handle is cloneable, so
/// channel-closure alone cannot signal shutdown).
pub enum Msg {
    /// An evaluation request.
    Eval(Request),
    /// Drain the queue, then stop the worker.
    Shutdown,
}

/// The response: `channels[k][i]` = `u^(k)` at `points[i]`, or an error
/// message.
pub type Response = Result<Vec<Vec<f64>>, String>;

/// Run one worker's batching loop (metrics tagged with `worker`) until
/// the channel closes or [`Msg::Shutdown`] arrives; the queue is drained
/// before returning so every accepted request gets an answer.
pub fn run_loop(
    mut backend: Box<dyn EvalBackend>,
    rx: Receiver<Msg>,
    cfg: BatcherConfig,
    metrics: Arc<Metrics>,
    worker: usize,
) {
    let cap = backend.max_batch();
    loop {
        // Block for the first request.
        let first = match rx.recv() {
            Ok(Msg::Eval(r)) => r,
            Ok(Msg::Shutdown) | Err(_) => {
                drain_queue(backend.as_mut(), &rx, cap, &metrics, worker);
                return;
            }
        };
        let mut pending = vec![first];
        let mut total: usize = pending[0].points.len();
        let mut stop = false;

        // Greedily coalesce more requests up to capacity.
        let deadline = Instant::now() + cfg.max_wait;
        while total < cap {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Eval(r)) => {
                    total += r.points.len();
                    pending.push(r);
                }
                Ok(Msg::Shutdown) => {
                    stop = true;
                    break;
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    stop = true;
                    break;
                }
            }
        }

        serve_batch(backend.as_mut(), &pending, cap, &metrics, worker);
        if stop {
            drain_queue(backend.as_mut(), &rx, cap, &metrics, worker);
            return;
        }
    }
}

/// Serve whatever is still queued at shutdown: requests enqueued before
/// the shutdown signal must not be dropped (asserted by the coordinator
/// stress suite).
fn drain_queue(
    backend: &mut dyn EvalBackend,
    rx: &Receiver<Msg>,
    cap: usize,
    metrics: &Metrics,
    worker: usize,
) {
    let mut pending = Vec::new();
    loop {
        match rx.try_recv() {
            Ok(Msg::Eval(r)) => pending.push(r),
            Ok(Msg::Shutdown) => continue,
            Err(_) => break,
        }
    }
    if !pending.is_empty() {
        serve_batch(backend, &pending, cap, metrics, worker);
    }
}

/// Evaluate a group of requests against the backend and scatter results.
/// Requests are grouped by activation (arrival order preserved within a
/// group); each group makes its own backend calls.
fn serve_batch(
    backend: &mut dyn EvalBackend,
    pending: &[Request],
    cap: usize,
    metrics: &Metrics,
    worker: usize,
) {
    let mut activations: Vec<Option<ActivationKind>> = Vec::new();
    for req in pending {
        if !activations.contains(&req.activation) {
            activations.push(req.activation);
        }
    }
    for activation in activations {
        let group: Vec<&Request> = pending
            .iter()
            .filter(|r| r.activation == activation)
            .collect();
        serve_group(backend, &group, activation, cap, metrics, worker);
    }
}

/// Evaluate same-activation requests as coalesced backend batches.
fn serve_group(
    backend: &mut dyn EvalBackend,
    group: &[&Request],
    activation: Option<ActivationKind>,
    cap: usize,
    metrics: &Metrics,
    worker: usize,
) {
    // Flatten all points, tracking (request, offset, len).
    let mut flat: Vec<f64> = Vec::new();
    let mut spans = Vec::with_capacity(group.len());
    for req in group {
        spans.push((flat.len(), req.points.len()));
        flat.extend_from_slice(&req.points);
    }

    // Evaluate in capacity-sized chunks, concatenating channel outputs.
    // `batch_start` splits each request's latency into its queue-wait
    // segment (enqueue → here) and the shared execute segment below.
    let batch_start = Instant::now();
    let n_channels = backend.n_channels();
    let mut channels: Vec<Vec<f64>> = vec![Vec::with_capacity(flat.len()); n_channels];
    let mut error: Option<String> = None;
    for chunk in flat.chunks(cap) {
        match backend.eval_batch_act(chunk, activation) {
            Ok(out) => {
                metrics.record_batch(worker, chunk.len());
                for (k, col) in out.into_iter().enumerate() {
                    channels[k].extend(col);
                }
            }
            Err(e) => {
                error = Some(e.to_string());
                break;
            }
        }
    }
    let exec_ns = batch_start.elapsed().as_nanos() as u64;

    for (req, &(off, len)) in group.iter().zip(&spans) {
        let result = match &error {
            Some(msg) => {
                metrics.record_error(worker);
                Err(msg.clone())
            }
            None => Ok(channels
                .iter()
                .map(|c| c[off..off + len].to_vec())
                .collect()),
        };
        let queue_ns = batch_start
            .saturating_duration_since(req.enqueued)
            .as_nanos() as u64;
        metrics.record_request(worker, len);
        metrics.record_latency_on(worker, req.enqueued.elapsed().as_nanos() as u64);
        metrics.record_segments(queue_ns, exec_ns);
        // Receiver may have hung up; that's fine.
        let _ = req.resp.send(result);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::EvalBackend;
    use anyhow::Result;
    use std::sync::mpsc;

    /// Backend that records batch sizes and returns x and 2x as channels.
    struct Probe {
        cap: usize,
        batches: Vec<usize>,
        fail: bool,
    }

    impl EvalBackend for Probe {
        fn max_batch(&self) -> usize {
            self.cap
        }
        fn n_channels(&self) -> usize {
            2
        }
        fn eval_batch(&mut self, xs: &[f64]) -> Result<Vec<Vec<f64>>> {
            if self.fail {
                anyhow::bail!("backend down");
            }
            self.batches.push(xs.len());
            Ok(vec![xs.to_vec(), xs.iter().map(|x| 2.0 * x).collect()])
        }
    }

    fn request(points: Vec<f64>) -> (Request, mpsc::Receiver<Response>) {
        request_act(points, None)
    }

    fn request_act(
        points: Vec<f64>,
        activation: Option<ActivationKind>,
    ) -> (Request, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        (
            Request {
                points,
                activation,
                enqueued: Instant::now(),
                resp: tx,
            },
            rx,
        )
    }

    #[test]
    fn coalesces_and_preserves_per_request_values() {
        let metrics = Metrics::default();
        let mut backend = Probe { cap: 8, batches: vec![], fail: false };
        let (r1, rx1) = request(vec![1.0, 2.0]);
        let (r2, rx2) = request(vec![3.0]);
        serve_batch(&mut backend, &[r1, r2], 8, &metrics, 0);
        let a = rx1.recv().unwrap().unwrap();
        let b = rx2.recv().unwrap().unwrap();
        assert_eq!(a[0], vec![1.0, 2.0]);
        assert_eq!(a[1], vec![2.0, 4.0]);
        assert_eq!(b[0], vec![3.0]);
        assert_eq!(b[1], vec![6.0]);
        assert_eq!(backend.batches, vec![3]); // one coalesced call
        let s = metrics.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.batches, 1);
    }

    /// Backend that echoes points and records which activation each
    /// batch ran under.
    struct ActProbe {
        seen: Vec<(Option<ActivationKind>, usize)>,
    }

    impl EvalBackend for ActProbe {
        fn max_batch(&self) -> usize {
            16
        }
        fn n_channels(&self) -> usize {
            1
        }
        fn eval_batch(&mut self, xs: &[f64]) -> Result<Vec<Vec<f64>>> {
            self.seen.push((None, xs.len()));
            Ok(vec![xs.to_vec()])
        }
        fn eval_batch_act(
            &mut self,
            xs: &[f64],
            activation: Option<ActivationKind>,
        ) -> Result<Vec<Vec<f64>>> {
            self.seen.push((activation, xs.len()));
            Ok(vec![xs.to_vec()])
        }
    }

    #[test]
    fn mixed_activation_requests_batch_per_activation() {
        let metrics = Metrics::default();
        let mut backend = ActProbe { seen: vec![] };
        let (r1, rx1) = request_act(vec![1.0], None);
        let (r2, rx2) = request_act(vec![2.0, 3.0], Some(ActivationKind::Sine));
        let (r3, rx3) = request_act(vec![4.0], None);
        serve_batch(&mut backend, &[r1, r2, r3], 16, &metrics, 0);
        assert_eq!(rx1.recv().unwrap().unwrap()[0], vec![1.0]);
        assert_eq!(rx2.recv().unwrap().unwrap()[0], vec![2.0, 3.0]);
        assert_eq!(rx3.recv().unwrap().unwrap()[0], vec![4.0]);
        // Two backend calls: the coalesced default group and the sine group.
        assert_eq!(
            backend.seen,
            vec![(None, 2), (Some(ActivationKind::Sine), 2)]
        );
        assert_eq!(metrics.snapshot().requests, 3);
    }

    #[test]
    fn splits_oversize_requests() {
        let metrics = Metrics::default();
        let mut backend = Probe { cap: 4, batches: vec![], fail: false };
        let pts: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let (r, rx) = request(pts.clone());
        serve_batch(&mut backend, &[r], 4, &metrics, 0);
        let out = rx.recv().unwrap().unwrap();
        assert_eq!(out[0], pts);
        assert_eq!(backend.batches, vec![4, 4, 2]);
    }

    #[test]
    fn backend_errors_propagate() {
        let metrics = Metrics::default();
        let mut backend = Probe { cap: 4, batches: vec![], fail: true };
        let (r, rx) = request(vec![1.0]);
        serve_batch(&mut backend, &[r], 4, &metrics, 0);
        let out = rx.recv().unwrap();
        assert!(out.is_err());
        assert_eq!(metrics.snapshot().errors, 1);
    }

    #[test]
    fn run_loop_shuts_down_when_senders_drop() {
        let metrics = Arc::new(Metrics::default());
        let backend = Probe { cap: 4, batches: vec![], fail: false };
        let (tx, rx) = mpsc::channel::<Msg>();
        let handle = std::thread::spawn({
            let metrics = metrics.clone();
            move || run_loop(Box::new(backend), rx, BatcherConfig::default(), metrics, 0)
        });
        let (r, resp_rx) = request(vec![0.5]);
        tx.send(Msg::Eval(r)).unwrap();
        let out = resp_rx.recv().unwrap().unwrap();
        assert_eq!(out[0], vec![0.5]);
        drop(tx);
        handle.join().unwrap();
    }

    #[test]
    fn run_loop_stops_on_shutdown_message() {
        let metrics = Arc::new(Metrics::default());
        let backend = Probe { cap: 4, batches: vec![], fail: false };
        let (tx, rx) = mpsc::channel::<Msg>();
        let worker = std::thread::spawn({
            let metrics = metrics.clone();
            move || run_loop(Box::new(backend), rx, BatcherConfig::default(), metrics, 0)
        });
        tx.send(Msg::Shutdown).unwrap();
        worker.join().unwrap(); // must return even though tx is alive
        drop(tx);
    }

    /// Requests enqueued before the shutdown signal are still served —
    /// the loop drains its queue on the way out instead of dropping work.
    #[test]
    fn shutdown_drains_already_queued_requests() {
        let metrics = Arc::new(Metrics::with_workers(1));
        let backend = Probe { cap: 8, batches: vec![], fail: false };
        let (tx, rx) = mpsc::channel::<Msg>();
        // Queue order: one request, the shutdown signal, then three more
        // requests that are only reachable via the drain path.
        let (r1, rx1) = request(vec![1.0]);
        tx.send(Msg::Eval(r1)).unwrap();
        tx.send(Msg::Shutdown).unwrap();
        let mut waiting = vec![rx1];
        let mut want = vec![vec![1.0]];
        for i in 2..5 {
            let pts = vec![i as f64];
            let (r, rxr) = request(pts.clone());
            tx.send(Msg::Eval(r)).unwrap();
            waiting.push(rxr);
            want.push(pts);
        }
        run_loop(Box::new(backend), rx, BatcherConfig::default(), metrics.clone(), 0);
        for (rxr, pts) in waiting.iter().zip(&want) {
            let out = rxr.recv().expect("request dropped at shutdown").unwrap();
            assert_eq!(&out[0], pts);
        }
        let s = metrics.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.workers[0].requests, 4);
    }
}
