//! Service assembly: sharded bounded request queues + a batcher worker
//! pool + a pipelined TCP front.
//!
//! A service runs `W ≥ 1` batcher workers, each with its own backend and
//! its own **bounded** queue. The handle shards requests across the
//! queues by their (optional) activation override — `kind.index() % W`,
//! default traffic on shard 0 — so batches for different activation
//! towers run concurrently while same-activation requests still coalesce
//! into full backend batches on their shard.
//!
//! Backpressure: wire-path submissions ([`ServiceHandle::submit_with`])
//! never block — a full shard queue sheds the request with
//! [`SubmitError::Overloaded`], which the connection loop answers with
//! `{"error":"overloaded","retry_ms":…}`. In-process callers
//! ([`ServiceHandle::eval_with`]) block on the bounded queue instead,
//! which is the natural backpressure for code that would otherwise just
//! spin resubmitting.
//!
//! Connections are persistent and **pipelined**: the per-connection
//! reader parses length-framed (or legacy newline) requests and hands
//! each reply slot to a writer thread that answers strictly in request
//! order, so a client may keep up to [`PIPELINE_WINDOW`] requests in
//! flight on one connection and batcher evals from *different* requests
//! overlap. See `docs/PROTOCOL.md` for the framing and shed contract.

use super::backend::EvalBackend;
use super::batcher::{run_loop, BatcherConfig, Msg, Request, Response};
use super::metrics::Metrics;
use super::protocol::{self, Incoming, ReadError};
use crate::ntp::ActivationKind;
use anyhow::{anyhow, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, sync_channel, Receiver, SyncSender, TryRecvError, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Most replies a connection keeps in flight (reader-to-writer slots).
/// When the window is full the reader stops pulling requests off the
/// socket, so a client that floods faster than it reads stalls itself
/// without buffering unboundedly on the server.
pub const PIPELINE_WINDOW: usize = 256;

/// A running evaluation service (a pool of batcher workers).
pub struct Service {
    handle: ServiceHandle,
    workers: Vec<JoinHandle<()>>,
}

/// Cheap cloneable handle for submitting requests; shards per activation
/// across the worker queues.
#[derive(Clone)]
pub struct ServiceHandle {
    txs: Vec<SyncSender<Msg>>,
    metrics: Arc<Metrics>,
    shed_retry_ms: u64,
}

/// Why a non-blocking submission was rejected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The target shard's bounded queue is full; retry after the hinted
    /// back-off (the wire path turns this into a shed response).
    Overloaded {
        /// Suggested client back-off in milliseconds.
        retry_ms: u64,
    },
    /// The service has shut down; the request can never be served.
    Closed,
}

/// An accepted, not-yet-answered evaluation (from
/// [`ServiceHandle::submit_with`]).
pub struct PendingEval {
    rx: Receiver<Response>,
}

impl PendingEval {
    /// Block until the batcher answers. A worker that exits before
    /// answering (shutdown race) surfaces as a clean error.
    pub fn wait(self) -> Result<Vec<Vec<f64>>> {
        self.rx
            .recv()
            .map_err(|_| anyhow!("service is shut down"))?
            .map_err(|e| anyhow!(e))
    }

    fn into_receiver(self) -> Receiver<Response> {
        self.rx
    }
}

impl Service {
    /// Spawn a single batcher worker. The backend is built *inside* the
    /// worker thread by `factory` (PJRT executables are not `Send`); a
    /// factory error shuts the shard down and surfaces on `eval`.
    pub fn start<F>(factory: F, cfg: BatcherConfig) -> Service
    where
        F: FnOnce() -> Result<Box<dyn EvalBackend>> + Send + 'static,
    {
        let cell = Mutex::new(Some(factory));
        Service::start_pool(
            move |_| {
                let f = cell
                    .lock()
                    .expect("factory cell poisoned")
                    .take()
                    .expect("single-worker factory runs once");
                f()
            },
            1,
            cfg,
        )
    }

    /// Spawn a pool of `workers` batcher workers. `factory(w)` is called
    /// inside worker `w`'s thread to build that shard's backend, so each
    /// worker owns an independent backend (and native backends can carry
    /// their own [`crate::ntp::ParallelPolicy`]).
    ///
    /// ```
    /// use ntangent::coordinator::{BatcherConfig, NativeBackend, Service};
    /// use ntangent::nn::Mlp;
    /// use ntangent::util::prng::Prng;
    ///
    /// let mut rng = Prng::seeded(7);
    /// let mlp = Mlp::uniform(1, 8, 2, 1, &mut rng);
    /// let service = Service::start_pool(
    ///     move |_worker| Ok(Box::new(NativeBackend::new(mlp.clone(), 3, 64)) as _),
    ///     2, // two batcher workers (activation shards)
    ///     BatcherConfig::default(),
    /// );
    /// let handle = service.handle();
    /// let channels = handle.eval(&[0.0, 0.5]).unwrap();
    /// assert_eq!(channels.len(), 4); // u, u', u'', u'''
    /// assert_eq!(channels[0].len(), 2); // one value per requested point
    /// service.shutdown(); // drains the queues before joining
    /// ```
    pub fn start_pool<F>(factory: F, workers: usize, cfg: BatcherConfig) -> Service
    where
        F: Fn(usize) -> Result<Box<dyn EvalBackend>> + Send + Sync + 'static,
    {
        let workers = workers.max(1);
        let metrics = Arc::new(Metrics::with_workers(workers));
        let factory = Arc::new(factory);
        let mut txs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = sync_channel::<Msg>(cfg.queue_depth.max(1));
            txs.push(tx);
            let metrics = metrics.clone();
            let factory = factory.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("ntangent-batcher-{w}"))
                    .spawn(move || match factory(w) {
                        Ok(backend) => run_loop(backend, rx, cfg, metrics, w),
                        Err(e) => {
                            eprintln!("ntangent service: backend {w} init failed: {e:#}");
                            drop(rx); // closes the shard queue; evals error out
                        }
                    })
                    .expect("spawning batcher thread"),
            );
        }
        Service {
            handle: ServiceHandle {
                txs,
                metrics,
                shed_retry_ms: cfg.shed_retry_ms,
            },
            workers: handles,
        }
    }

    /// A cheap cloneable handle for submitting requests.
    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }

    /// Shut down: signal every worker (handle clones may still exist —
    /// their subsequent `eval` calls error out), let each drain its
    /// queue, and join them all. In-flight pipelined TCP requests get
    /// their drained responses (or a clean shutdown error from the
    /// connection writer) — never a silent drop.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        if self.workers.is_empty() {
            return;
        }
        for tx in &self.handle.txs {
            let _ = tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.stop();
    }
}

impl ServiceHandle {
    /// Number of batcher workers behind this handle.
    pub fn workers(&self) -> usize {
        self.txs.len()
    }

    /// The shard a request with this activation routes to.
    fn shard_of(&self, activation: Option<ActivationKind>) -> usize {
        match activation {
            Some(kind) => kind.index() % self.txs.len(),
            None => 0,
        }
    }

    /// Evaluate points (blocking): returns `channels[k][i]`.
    pub fn eval(&self, points: &[f64]) -> Result<Vec<Vec<f64>>> {
        self.eval_with(points, None)
    }

    /// Evaluate points with an optional per-request activation override
    /// (`None` = the served model's own activation). Blocks while the
    /// shard queue is full (in-process backpressure) — the wire path
    /// uses [`ServiceHandle::submit_with`] and sheds instead.
    pub fn eval_with(
        &self,
        points: &[f64],
        activation: Option<ActivationKind>,
    ) -> Result<Vec<Vec<f64>>> {
        let (tx, rx) = channel::<Response>();
        self.txs[self.shard_of(activation)]
            .send(Msg::Eval(Request {
                points: points.to_vec(),
                activation,
                enqueued: Instant::now(),
                resp: tx,
            }))
            .map_err(|_| anyhow!("service is shut down"))?;
        rx.recv()
            .map_err(|_| anyhow!("service is shut down"))?
            .map_err(|e| anyhow!(e))
    }

    /// Submit without blocking: enqueue on the target shard if it has
    /// room, else shed. The returned [`PendingEval`] resolves on
    /// [`PendingEval::wait`] (or feeds the pipelined connection writer).
    pub fn submit_with(
        &self,
        points: &[f64],
        activation: Option<ActivationKind>,
    ) -> std::result::Result<PendingEval, SubmitError> {
        let (tx, rx) = channel::<Response>();
        let msg = Msg::Eval(Request {
            points: points.to_vec(),
            activation,
            enqueued: Instant::now(),
            resp: tx,
        });
        match self.txs[self.shard_of(activation)].try_send(msg) {
            Ok(()) => Ok(PendingEval { rx }),
            Err(TrySendError::Full(_)) => {
                self.metrics.record_shed();
                Err(SubmitError::Overloaded {
                    retry_ms: self.shed_retry_ms,
                })
            }
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
        }
    }

    /// Snapshot of the global + per-worker metrics.
    pub fn metrics(&self) -> super::metrics::MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// The shared live counters (e.g. to attach to an
    /// [`OperatorServer`], so operator-path cache hits and errors land
    /// in the same stats document).
    pub fn metrics_handle(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }
}

/// Direct evaluator behind the wire protocol's multivariate
/// `points_nd` + `operator` requests: holds the served model and
/// answers each request with one direction-stacked
/// [`crate::ntp::MultiJetEngine`] pass.
///
/// Operator requests bypass the batcher queues — every request is a
/// self-contained fused batch already (`D · B` rows), so dynamic
/// batching would only add latency. Compiled operators and engines come
/// from the process-wide [`crate::pde::cache`] keyed on
/// `(dim, spec)` / `(dim, n, policy)`, so across requests, connections
/// and servers each distinct operator compiles exactly once; per-request
/// activation overrides retag the served weights exactly as on the
/// scalar path (plans are activation-independent — see the cache keying
/// rules in `docs/ARCHITECTURE.md`).
pub struct OperatorServer {
    mlp: crate::nn::Mlp,
    policy: crate::ntp::ParallelPolicy,
    metrics: Option<Arc<Metrics>>,
    cached: bool,
}

/// Highest operator order [`OperatorServer::eval`] accepts — the
/// documented `JetPlan` envelope. The spec is client-chosen, so without
/// a bound a parseable-but-extreme request (`"d99"`) would drive
/// unbounded plan compilation (and eventually an exact-arithmetic
/// overflow panic) on the connection thread instead of an error reply.
pub const MAX_SERVED_OPERATOR_ORDER: usize = 8;

impl OperatorServer {
    /// Serve `mlp` (any input dim) with the given batch-parallel policy,
    /// using the shared compile cache.
    pub fn new(mlp: crate::nn::Mlp, policy: crate::ntp::ParallelPolicy) -> OperatorServer {
        OperatorServer {
            mlp,
            policy,
            metrics: None,
            cached: true,
        }
    }

    /// [`OperatorServer::new`] with the compile cache disabled: every
    /// request recompiles its operator and engine. The pre-cache
    /// behaviour, kept as the `bench serve` baseline leg.
    pub fn uncached(mlp: crate::nn::Mlp, policy: crate::ntp::ParallelPolicy) -> OperatorServer {
        OperatorServer {
            cached: false,
            ..OperatorServer::new(mlp, policy)
        }
    }

    /// Attach shared metrics: cache hits/misses (and errors) recorded
    /// per request land in the service's stats document.
    pub fn with_metrics(mut self, metrics: Arc<Metrics>) -> OperatorServer {
        self.metrics = Some(metrics);
        self
    }

    /// Evaluate `(u, L[u])` at the requested points. `operator` is a
    /// library problem name or a [`crate::pde::DiffOperator::parse`]
    /// spec over the served model's input dim, of order ≤
    /// [`MAX_SERVED_OPERATOR_ORDER`]; `activation` optionally retags
    /// the served weights for this request.
    ///
    /// Every call bumps the `serve_operator_requests` (and, on failure,
    /// `serve_operator_errors`) [`crate::obs`] registry counters, which
    /// the stats wire replies surface as `operator_requests` /
    /// `operator_errors`.
    pub fn eval(
        &self,
        points: &[Vec<f64>],
        operator: &str,
        activation: Option<ActivationKind>,
    ) -> std::result::Result<(Vec<f64>, Vec<f64>), String> {
        crate::obs::registry().counter("serve_operator_requests").inc();
        let _span = crate::obs::span("serve.operator");
        let out = self.eval_inner(points, operator, activation);
        if out.is_err() {
            crate::obs::registry().counter("serve_operator_errors").inc();
        }
        out
    }

    fn eval_inner(
        &self,
        points: &[Vec<f64>],
        operator: &str,
        activation: Option<ActivationKind>,
    ) -> std::result::Result<(Vec<f64>, Vec<f64>), String> {
        let dim = self.mlp.input_dim();
        if points.iter().any(|p| p.len() != dim) {
            return Err(format!("served model expects {dim}-dimensional points"));
        }
        let (op, op_hit) = if self.cached {
            crate::pde::cache::shared_operator(operator, dim)?
        } else {
            (Arc::new(crate::pde::resolve_operator(operator, dim)?), false)
        };
        if let Some(m) = &self.metrics {
            m.record_plan_lookup(op_hit);
        }
        if op.max_order() > MAX_SERVED_OPERATOR_ORDER {
            return Err(format!(
                "operator order {} exceeds the served maximum {MAX_SERVED_OPERATOR_ORDER}",
                op.max_order()
            ));
        }
        let (engine, engine_hit) = if self.cached {
            crate::pde::cache::shared_engine(dim, op.max_order(), self.policy)
        } else {
            (
                Arc::new(crate::ntp::MultiJetEngine::with_policy(
                    dim,
                    op.max_order(),
                    self.policy,
                )),
                false,
            )
        };
        if let Some(m) = &self.metrics {
            m.record_plan_lookup(engine_hit);
        }
        let flat: Vec<f64> = points.iter().flatten().copied().collect();
        let x = crate::tensor::Tensor::from_vec(flat, &[points.len(), dim]);
        let retagged;
        let model = match activation {
            Some(kind) if kind != self.mlp.activation => {
                let mut m = self.mlp.clone();
                m.activation = kind;
                retagged = m;
                &retagged
            }
            _ => &self.mlp,
        };
        let jet = engine.jet(model, &x);
        let u = jet.value();
        let vals = op.apply(&jet);
        Ok((u.data().to_vec(), vals.data().to_vec()))
    }
}

/// Serve the wire protocol on `listener`, one thread per connection,
/// until the process exits. Returns only on accept errors. Operator
/// requests are rejected; use [`serve_tcp_with`] to serve them.
pub fn serve_tcp(listener: TcpListener, handle: ServiceHandle) -> Result<()> {
    serve_tcp_with(listener, handle, None)
}

/// [`serve_tcp`] with an optional [`OperatorServer`] answering the
/// multivariate `points_nd` + `operator` requests.
pub fn serve_tcp_with(
    listener: TcpListener,
    handle: ServiceHandle,
    operators: Option<Arc<OperatorServer>>,
) -> Result<()> {
    for stream in listener.incoming() {
        let stream = stream.context("accept failed")?;
        let handle = handle.clone();
        let operators = operators.clone();
        std::thread::spawn(move || {
            let _ = serve_connection_with(stream, handle, operators.as_deref());
        });
    }
    Ok(())
}

/// One connection: read requests, write responses in order (no
/// operator support; see [`serve_connection_with`]).
pub fn serve_connection(stream: TcpStream, handle: ServiceHandle) -> Result<()> {
    serve_connection_with(stream, handle, None)
}

/// One reply slot handed from the connection reader to its writer.
enum PendingReply {
    /// Computed inline on the reader thread (errors, stats, shed,
    /// operator results).
    Ready {
        /// Reply with framing (vs a newline-terminated line).
        framed: bool,
        /// The encoded JSON payload.
        payload: String,
    },
    /// A batcher eval still in flight; the writer blocks on it when its
    /// turn comes, preserving request order while later requests keep
    /// being parsed and enqueued (that overlap *is* the pipelining).
    Waiting {
        /// Reply with framing (vs a newline-terminated line).
        framed: bool,
        /// The batcher's response channel.
        rx: Receiver<Response>,
    },
}

/// One connection with optional operator support: a reader loop (this
/// thread) plus an in-order writer thread, pipelined up to
/// [`PIPELINE_WINDOW`] requests.
pub fn serve_connection_with(
    stream: TcpStream,
    handle: ServiceHandle,
    operators: Option<&OperatorServer>,
) -> Result<()> {
    let writer_stream = stream.try_clone().context("cloning stream")?;
    let (tx, rx) = sync_channel::<PendingReply>(PIPELINE_WINDOW);
    let writer_metrics = handle.metrics_handle();
    let writer = std::thread::Builder::new()
        .name("ntangent-conn-writer".to_string())
        .spawn(move || write_replies(writer_stream, rx, writer_metrics))
        .expect("spawning connection writer");

    let mut reader = BufReader::new(stream);
    loop {
        let (framed, text) = match protocol::read_message(&mut reader) {
            Ok(Incoming::Frame(s)) => (true, s),
            Ok(Incoming::Line(s)) => (false, s),
            Ok(Incoming::Eof) => break,
            Err(e @ (ReadError::TooLarge { .. } | ReadError::BadUtf8)) => {
                // Protocol violation: answer once, then close — the
                // stream position is no longer trustworthy. Reply
                // framed iff the offending message was framed (BadUtf8
                // only arises from frames; lines are checked per byte).
                let framed = !matches!(e, ReadError::TooLarge { framed: false, .. });
                let _ = tx.send(PendingReply::Ready {
                    framed,
                    payload: protocol::encode_error(&e.to_string()),
                });
                break;
            }
            Err(ReadError::Io(_)) => break, // disconnect / truncated frame
        };
        if text.trim().is_empty() {
            continue;
        }
        let reply = match protocol::parse_request(&text) {
            Ok(protocol::WireRequest::Eval { points, activation }) => {
                match handle.submit_with(&points, activation) {
                    Ok(pending) => PendingReply::Waiting {
                        framed,
                        rx: pending.into_receiver(),
                    },
                    Err(SubmitError::Overloaded { retry_ms }) => PendingReply::Ready {
                        framed,
                        payload: protocol::encode_shed(retry_ms),
                    },
                    Err(SubmitError::Closed) => PendingReply::Ready {
                        framed,
                        payload: protocol::encode_error("service is shut down"),
                    },
                }
            }
            Ok(protocol::WireRequest::EvalOperator {
                points,
                operator,
                activation,
            }) => PendingReply::Ready {
                framed,
                payload: match operators {
                    Some(srv) => match srv.eval(&points, &operator, activation) {
                        Ok((u, vals)) => protocol::encode_operator_values(&u, &vals),
                        Err(e) => protocol::encode_error(&e),
                    },
                    None => protocol::encode_error(
                        "this endpoint serves no operator evaluator (scalar checkpoints only)",
                    ),
                },
            },
            Ok(protocol::WireRequest::Stats) => PendingReply::Ready {
                framed,
                payload: protocol::encode_stats(&handle.metrics()),
            },
            Ok(protocol::WireRequest::StatsFull) => PendingReply::Ready {
                framed,
                payload: protocol::encode_stats_full(&handle.metrics()),
            },
            Err(e) => PendingReply::Ready {
                framed,
                payload: protocol::encode_error(&e),
            },
        };
        if tx.send(reply).is_err() {
            break; // writer exited (client stopped reading / disconnected)
        }
    }
    drop(tx); // writer drains the in-flight window, then exits
    let _ = writer.join();
    Ok(())
}

/// The connection writer: answer reply slots strictly in order,
/// buffering while more replies are immediately available and flushing
/// before any blocking wait (so no completed reply is ever stuck behind
/// an incomplete one).
///
/// Each reply's encode-and-buffer segment is recorded into a
/// connection-local [`crate::obs::Histogram`] that folds into the
/// service-wide `write` histogram when the connection closes (one merge
/// per connection instead of one shared-cacheline touch per reply).
fn write_replies(stream: TcpStream, rx: Receiver<PendingReply>, metrics: Arc<Metrics>) {
    let conn_write = crate::obs::Histogram::new();
    write_replies_inner(stream, rx, &conn_write);
    conn_write.merge_into(&metrics.write);
}

fn write_replies_inner(
    stream: TcpStream,
    rx: Receiver<PendingReply>,
    conn_write: &crate::obs::Histogram,
) {
    let mut w = BufWriter::new(stream);
    loop {
        let next = match rx.try_recv() {
            Ok(p) => p,
            Err(TryRecvError::Empty) => {
                if w.flush().is_err() {
                    return;
                }
                match rx.recv() {
                    Ok(p) => p,
                    Err(_) => return, // reader closed; window fully drained
                }
            }
            Err(TryRecvError::Disconnected) => break,
        };
        let (framed, payload) = match next {
            PendingReply::Ready { framed, payload } => (framed, payload),
            PendingReply::Waiting { framed, rx: resp } => {
                let r = match resp.try_recv() {
                    Ok(r) => r,
                    Err(TryRecvError::Empty) => {
                        if w.flush().is_err() {
                            return;
                        }
                        // Worker gone before answering = shutdown race:
                        // the client gets a clean error, not silence.
                        resp.recv()
                            .unwrap_or_else(|_| Err("service is shut down".to_string()))
                    }
                    Err(TryRecvError::Disconnected) => Err("service is shut down".to_string()),
                };
                let payload = match r {
                    Ok(channels) => protocol::encode_channels(&channels),
                    Err(e) => protocol::encode_error(&e),
                };
                (framed, payload)
            }
        };
        let started = Instant::now();
        let io = if framed {
            protocol::write_frame(&mut w, &payload)
        } else {
            w.write_all(payload.as_bytes())
                .and_then(|()| w.write_all(b"\n"))
        };
        conn_write.record(started.elapsed().as_nanos() as u64);
        if io.is_err() {
            return; // client gone; reader unblocks on its next send
        }
    }
    let _ = w.flush();
}

/// A minimal blocking TCP client for the wire protocol (used by the
/// examples, tests and the benchmark harness). Requests are
/// length-framed; the stream is reused across requests, and the
/// `submit_*`/`recv_*` pairs pipeline many requests over it (responses
/// arrive strictly in submission order).
pub struct TcpClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    shed_retries: u64,
}

impl TcpClient {
    /// Connect to a serving `ntangent serve` endpoint.
    pub fn connect(addr: &str) -> Result<TcpClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        let writer = BufWriter::new(stream.try_clone()?);
        Ok(TcpClient {
            reader: BufReader::new(stream),
            writer,
            shed_retries: 0,
        })
    }

    /// Bound every subsequent `recv_*` by a socket read timeout
    /// (`None` = block forever). Lets harnesses turn a hung server into
    /// a test failure instead of a hang.
    pub fn set_read_timeout(&self, timeout: Option<std::time::Duration>) -> Result<()> {
        self.reader
            .get_ref()
            .set_read_timeout(timeout)
            .context("setting read timeout")
    }

    /// Queue one scalar evaluation request (pipelined; pair with
    /// [`TcpClient::recv_channels`] in submission order).
    pub fn submit_eval(
        &mut self,
        points: &[f64],
        activation: Option<ActivationKind>,
    ) -> Result<()> {
        let req = protocol::encode_request(points, activation);
        self.submit_raw(&req)
    }

    /// Queue one operator evaluation request (pair with
    /// [`TcpClient::recv_operator`]).
    pub fn submit_operator(
        &mut self,
        points: &[Vec<f64>],
        operator: &str,
        activation: Option<ActivationKind>,
    ) -> Result<()> {
        let req = protocol::encode_operator_request(points, operator, activation);
        self.submit_raw(&req)
    }

    /// Queue one raw JSON payload as a framed request.
    pub fn submit_raw(&mut self, payload: &str) -> Result<()> {
        protocol::write_frame(&mut self.writer, payload).context("writing request frame")
    }

    /// Receive the next response payload (framed or line — flushes any
    /// queued requests first).
    pub fn recv_raw(&mut self) -> Result<String> {
        self.writer.flush().context("flushing requests")?;
        match protocol::read_message(&mut self.reader) {
            Ok(Incoming::Frame(s) | Incoming::Line(s)) => Ok(s),
            Ok(Incoming::Eof) => Err(anyhow!("server closed the connection")),
            Err(e) => Err(anyhow!("reading response: {e}")),
        }
    }

    /// Receive and decode the next `channels` response.
    pub fn recv_channels(&mut self) -> Result<Vec<Vec<f64>>> {
        let line = self.recv_raw()?;
        protocol::parse_channels(&line).map_err(|e| anyhow!(e))
    }

    /// Receive and decode the next operator response `(u, L[u])`.
    pub fn recv_operator(&mut self) -> Result<(Vec<f64>, Vec<f64>)> {
        let line = self.recv_raw()?;
        protocol::parse_operator_values(&line).map_err(|e| anyhow!(e))
    }

    /// Evaluate points with the served model's own activation.
    pub fn eval(&mut self, points: &[f64]) -> Result<Vec<Vec<f64>>> {
        self.eval_with(points, None)
    }

    /// Evaluate with an optional activation override; `None` sends a
    /// field-free request (wire-compatible with old servers).
    pub fn eval_with(
        &mut self,
        points: &[f64],
        activation: Option<ActivationKind>,
    ) -> Result<Vec<Vec<f64>>> {
        self.submit_eval(points, activation)?;
        self.recv_channels()
    }

    /// [`TcpClient::eval_with`] honoring the shed contract
    /// (`docs/PROTOCOL.md`): an `{"error":"overloaded","retry_ms":…}`
    /// reply makes the client back off `retry_ms · attempt` milliseconds
    /// — jitterless, so harnesses replay identical schedules — and
    /// resubmit the identical request, up to `max_retries` times before
    /// surfacing the shed as an error. Absorbed sheds are counted in
    /// [`TcpClient::shed_retries`].
    pub fn eval_with_retry(
        &mut self,
        points: &[f64],
        activation: Option<ActivationKind>,
        max_retries: usize,
    ) -> Result<Vec<Vec<f64>>> {
        let mut attempt = 0usize;
        loop {
            self.submit_eval(points, activation)?;
            let line = self.recv_raw()?;
            match protocol::parse_error(&line) {
                Some((msg, Some(retry_ms))) if msg == "overloaded" && attempt < max_retries => {
                    attempt += 1;
                    self.shed_retries += 1;
                    std::thread::sleep(std::time::Duration::from_millis(
                        retry_ms.saturating_mul(attempt as u64),
                    ));
                }
                _ => return protocol::parse_channels(&line).map_err(|e| anyhow!(e)),
            }
        }
    }

    /// Cumulative count of shed replies this client has absorbed by
    /// backing off and resubmitting ([`TcpClient::eval_with_retry`]).
    pub fn shed_retries(&self) -> u64 {
        self.shed_retries
    }

    /// Evaluate a differential operator at multi-dimensional points:
    /// returns `(u, L[u])` (needs a server started with an
    /// [`OperatorServer`]).
    pub fn eval_operator(
        &mut self,
        points: &[Vec<f64>],
        operator: &str,
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        self.submit_operator(points, operator, None)?;
        self.recv_operator()
    }

    /// Fetch the stats response (raw JSON).
    pub fn stats(&mut self) -> Result<String> {
        self.submit_raw("{\"cmd\":\"stats\"}")?;
        self.recv_raw()
    }

    /// Fetch the full observability document (`{"stats":"full"}` — the
    /// plain stats plus latency-segment histograms, per-worker
    /// percentiles, cache occupancy and registry counters) as raw JSON.
    pub fn stats_full(&mut self) -> Result<String> {
        self.submit_raw("{\"stats\":\"full\"}")?;
        self.recv_raw()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::NativeBackend;
    use crate::nn::Mlp;
    use crate::ntp::NtpEngine;
    use crate::tensor::Tensor;
    use crate::util::prng::Prng;

    fn test_service() -> (Service, Mlp) {
        let mut rng = Prng::seeded(123);
        let mlp = Mlp::uniform(1, 8, 2, 1, &mut rng);
        let backend_mlp = mlp.clone();
        let service = Service::start(
            move || Ok(Box::new(NativeBackend::new(backend_mlp, 2, 16)) as Box<dyn EvalBackend>),
            BatcherConfig::default(),
        );
        (service, mlp)
    }

    #[test]
    fn in_process_roundtrip_matches_direct() {
        let (service, mlp) = test_service();
        let handle = service.handle();
        let points = [0.3, -0.7, 1.1];
        let channels = handle.eval(&points).unwrap();
        let direct = NtpEngine::new(2).forward(&mlp, &Tensor::from_vec(points.to_vec(), &[3, 1]));
        for k in 0..3 {
            assert_eq!(channels[k].as_slice(), direct[k].data(), "channel {k}");
        }
        assert_eq!(handle.metrics().requests, 1);
        service.shutdown();
    }

    #[test]
    fn submit_wait_matches_blocking_eval() {
        let (service, mlp) = test_service();
        let handle = service.handle();
        let pending = handle.submit_with(&[0.2, -0.4], None).unwrap();
        let channels = pending.wait().unwrap();
        let direct =
            NtpEngine::new(2).forward(&mlp, &Tensor::from_vec(vec![0.2, -0.4], &[2, 1]));
        for k in 0..3 {
            assert_eq!(channels[k].as_slice(), direct[k].data(), "channel {k}");
        }
        service.shutdown();
    }

    #[test]
    fn concurrent_clients_each_get_their_answer() {
        let (service, mlp) = test_service();
        let mut threads = Vec::new();
        for t in 0..8 {
            let handle = service.handle();
            threads.push(std::thread::spawn(move || {
                let pt = t as f64 * 0.1;
                let channels = handle.eval(&[pt]).unwrap();
                (pt, channels[0][0])
            }));
        }
        let engine = NtpEngine::new(2);
        for th in threads {
            let (pt, got) = th.join().unwrap();
            let expect = engine.forward(&mlp, &Tensor::from_vec(vec![pt], &[1, 1]))[0].data()[0];
            assert_eq!(got, expect);
        }
        let m = service.handle().metrics();
        assert_eq!(m.requests, 8);
        assert!(m.batches <= 8); // some coalescing may or may not happen
        service.shutdown();
    }

    #[test]
    fn tcp_front_roundtrip() {
        let (service, mlp) = test_service();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = service.handle();
        std::thread::spawn(move || serve_tcp(listener, handle));

        let mut client = TcpClient::connect(&addr).unwrap();
        let channels = client.eval(&[0.25, 0.5]).unwrap();
        let direct =
            NtpEngine::new(2).forward(&mlp, &Tensor::from_vec(vec![0.25, 0.5], &[2, 1]));
        for k in 0..3 {
            for (a, b) in channels[k].iter().zip(direct[k].data()) {
                assert!((a - b).abs() < 1e-12);
            }
        }
        let stats = client.stats().unwrap();
        assert!(stats.contains("\"requests\""));
        // The full document parses and carries the segment histograms
        // with a latency count matching the served traffic.
        let full = client.stats_full().unwrap();
        let doc = crate::util::json::Json::parse(&full).unwrap();
        let stats = doc.get("stats").expect("stats object");
        for key in ["latency", "queue_wait", "execute", "write", "cache", "counters"] {
            assert!(stats.get(key).is_some(), "missing {key}");
        }
        let count = stats
            .get("latency")
            .and_then(|h| h.get("count"))
            .and_then(crate::util::json::Json::as_f64)
            .unwrap();
        assert_eq!(count, 1.0);
        service.shutdown();
    }

    /// The same TCP connection answers many pipelined requests strictly
    /// in submission order.
    #[test]
    fn tcp_pipelining_preserves_order() {
        let (service, mlp) = test_service();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = service.handle();
        std::thread::spawn(move || serve_tcp(listener, handle));

        let mut client = TcpClient::connect(&addr).unwrap();
        let engine = NtpEngine::new(2);
        let n = 64;
        for i in 0..n {
            client.submit_eval(&[i as f64 * 0.01], None).unwrap();
        }
        for i in 0..n {
            let channels = client.recv_channels().unwrap();
            let direct =
                engine.forward(&mlp, &Tensor::from_vec(vec![i as f64 * 0.01], &[1, 1]));
            assert_eq!(channels[0].as_slice(), direct[0].data(), "request {i}");
        }
        assert_eq!(service.handle().metrics().requests, n as u64);
        service.shutdown();
    }

    /// Operator requests over TCP: a 2-D model served with an
    /// [`OperatorServer`] answers `(u, L[u])` matching the direct jet
    /// evaluation; endpoints without one reject the request; scalar
    /// requests on the same connection keep working.
    #[test]
    fn tcp_front_serves_operator_requests() {
        use crate::ntp::{MultiJetEngine, ParallelPolicy};
        use crate::pde::DiffOperator;
        let (service, _) = test_service();
        let mut rng = Prng::seeded(77);
        let mlp2 = Mlp::uniform(2, 6, 2, 1, &mut rng);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = service.handle();
        let ops = Arc::new(
            OperatorServer::new(mlp2.clone(), ParallelPolicy::Serial)
                .with_metrics(handle.metrics_handle()),
        );
        std::thread::spawn(move || serve_tcp_with(listener, handle, Some(ops)));

        let mut client = TcpClient::connect(&addr).unwrap();
        let pts = vec![vec![0.1, 0.2], vec![-0.4, 0.6]];
        let (u, vals) = client.eval_operator(&pts, "d20+d02").unwrap();
        let x = Tensor::from_vec(vec![0.1, 0.2, -0.4, 0.6], &[2, 2]);
        let op = DiffOperator::laplacian(2);
        let engine = MultiJetEngine::new(2, 2);
        let jet = engine.jet(&mlp2, &x);
        assert_eq!(u, jet.value().data().to_vec());
        assert_eq!(vals, op.apply(&jet).data().to_vec());
        // A repeat of the same operator hits the compile cache and is
        // bitwise identical.
        let (u2, vals2) = client.eval_operator(&pts, "d20+d02").unwrap();
        assert_eq!(u2, u);
        assert_eq!(vals2, vals);
        let m = service.handle().metrics();
        assert!(m.plan_hits >= 2, "second request should hit: {m:?}");
        // Wrong arity, unknown operators and orders beyond the served
        // cap surface as protocol errors (never connection drops).
        assert!(client.eval_operator(&[vec![0.1]], "d20+d02").is_err());
        assert!(client.eval_operator(&pts, "bogus_op").is_err());
        assert!(client.eval_operator(&pts, "d90").is_err()); // order 9 > cap 8
        // Scalar requests still work on the same connection.
        assert_eq!(client.eval(&[0.25]).unwrap().len(), 3);
        service.shutdown();

        // An endpoint without an OperatorServer rejects operator requests.
        let (service2, _) = test_service();
        let listener2 = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr2 = listener2.local_addr().unwrap().to_string();
        let handle2 = service2.handle();
        std::thread::spawn(move || serve_tcp(listener2, handle2));
        let mut client2 = TcpClient::connect(&addr2).unwrap();
        assert!(client2.eval_operator(&pts, "d20+d02").is_err());
        service2.shutdown();
    }

    /// Per-request activation overrides on the operator path retag the
    /// served weights exactly like the scalar path does.
    #[test]
    fn operator_server_applies_activation_overrides() {
        use crate::ntp::{MultiJetEngine, ParallelPolicy};
        let mut rng = Prng::seeded(78);
        let mlp2 = Mlp::uniform(2, 6, 2, 1, &mut rng);
        let srv = OperatorServer::new(mlp2.clone(), ParallelPolicy::Serial);
        let pts = vec![vec![0.15, -0.3], vec![0.4, 0.2]];
        for kind in ActivationKind::ALL {
            let (u, vals) = srv.eval(&pts, "d20+d02", Some(kind)).unwrap();
            let mut retagged = mlp2.clone();
            retagged.activation = kind;
            let engine = MultiJetEngine::new(2, 2);
            let x = Tensor::from_vec(vec![0.15, -0.3, 0.4, 0.2], &[2, 2]);
            let jet = engine.jet(&retagged, &x);
            assert_eq!(u, jet.value().data().to_vec(), "{}", kind.name());
            assert_eq!(
                vals,
                crate::pde::DiffOperator::laplacian(2).apply(&jet).data().to_vec(),
                "{}",
                kind.name()
            );
        }
        // Cached and uncached servers agree bitwise.
        let unc = OperatorServer::uncached(mlp2, ParallelPolicy::Serial);
        assert_eq!(
            srv.eval(&pts, "d20+d02", None).unwrap(),
            unc.eval(&pts, "d20+d02", None).unwrap()
        );
    }

    #[test]
    fn eval_after_shutdown_errors() {
        let (service, _) = test_service();
        let handle = service.handle();
        service.shutdown();
        assert!(handle.eval(&[0.0]).is_err());
        assert_eq!(handle.submit_with(&[0.0], None).unwrap_err(), SubmitError::Closed);
    }

    /// Wire compatibility: a raw request line *without* an `activation`
    /// field must behave exactly as before the field existed — the served
    /// (tanh) model answers, newline-terminated.
    #[test]
    fn legacy_requests_without_activation_field_serve_tanh() {
        let (service, mlp) = test_service();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = service.handle();
        std::thread::spawn(move || serve_tcp(listener, handle));

        let stream = TcpStream::connect(&addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        writer.write_all(b"{\"points\": [0.4, -0.2]}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let channels = protocol::parse_channels(line.trim()).unwrap();

        let direct =
            NtpEngine::new(2).forward(&mlp, &Tensor::from_vec(vec![0.4, -0.2], &[2, 1]));
        assert_eq!(channels.len(), 3);
        for k in 0..3 {
            assert_eq!(channels[k].as_slice(), direct[k].data(), "channel {k}");
        }
        service.shutdown();
    }

    /// A 4-worker pool: requests shard per activation, every shard
    /// answers correctly, and the per-worker metrics show the spread.
    #[test]
    fn worker_pool_shards_by_activation() {
        use crate::ntp::ActivationKind;
        let mut rng = Prng::seeded(321);
        let mlp = Mlp::uniform(1, 8, 2, 1, &mut rng);
        let backend_mlp = mlp.clone();
        let service = Service::start_pool(
            move |_w| {
                Ok(Box::new(NativeBackend::new(backend_mlp.clone(), 2, 16)) as Box<dyn EvalBackend>)
            },
            4,
            BatcherConfig::default(),
        );
        let handle = service.handle();
        assert_eq!(handle.workers(), 4);
        let points = [0.2, -0.6];
        for kind in ActivationKind::ALL {
            let channels = handle.eval_with(&points, Some(kind)).unwrap();
            let mut retagged = mlp.clone();
            retagged.activation = kind;
            let direct = NtpEngine::new(2)
                .forward(&retagged, &Tensor::from_vec(points.to_vec(), &[2, 1]));
            for k in 0..3 {
                assert_eq!(channels[k].as_slice(), direct[k].data(), "{}", kind.name());
            }
        }
        let m = handle.metrics();
        assert_eq!(m.requests, 4);
        assert_eq!(m.workers.len(), 4);
        // One activation per shard (4 kinds, 4 workers): every worker
        // served exactly one request.
        for (w, ws) in m.workers.iter().enumerate() {
            assert_eq!(ws.requests, 1, "worker {w}");
            assert!(ws.batches >= 1, "worker {w}");
        }
        service.shutdown();
    }

    /// Pool with fewer workers than activations: sharding wraps around
    /// and default (no-override) traffic lands on shard 0.
    #[test]
    fn worker_pool_wraps_shards_and_routes_default_to_zero() {
        use crate::ntp::ActivationKind;
        let mut rng = Prng::seeded(322);
        let mlp = Mlp::uniform(1, 6, 2, 1, &mut rng);
        let backend_mlp = mlp.clone();
        let service = Service::start_pool(
            move |_w| {
                Ok(Box::new(NativeBackend::new(backend_mlp.clone(), 2, 16)) as Box<dyn EvalBackend>)
            },
            2,
            BatcherConfig::default(),
        );
        let handle = service.handle();
        handle.eval(&[0.1]).unwrap(); // default → worker 0
        handle.eval_with(&[0.2], Some(ActivationKind::Sine)).unwrap(); // index 1 → worker 1
        handle.eval_with(&[0.3], Some(ActivationKind::Softplus)).unwrap(); // index 2 → worker 0
        let m = handle.metrics();
        assert_eq!(m.workers[0].requests, 2);
        assert_eq!(m.workers[1].requests, 1);
        service.shutdown();
    }

    /// Per-request activation selection through the full service stack.
    #[test]
    fn activation_requests_select_towers() {
        use crate::ntp::ActivationKind;
        let (service, mlp) = test_service();
        let handle = service.handle();
        let points = [0.3, -0.7];
        for kind in ActivationKind::ALL {
            let channels = handle.eval_with(&points, Some(kind)).unwrap();
            let mut retagged = mlp.clone();
            retagged.activation = kind;
            let direct = NtpEngine::new(2)
                .forward(&retagged, &Tensor::from_vec(points.to_vec(), &[2, 1]));
            for k in 0..3 {
                assert_eq!(
                    channels[k].as_slice(),
                    direct[k].data(),
                    "{} channel {k}",
                    kind.name()
                );
            }
        }
        service.shutdown();
    }
}
