//! Service assembly: sharded request queues + a batcher worker pool +
//! optional TCP front.
//!
//! A service runs `W ≥ 1` batcher workers, each with its own backend and
//! its own queue. The handle shards requests across the queues by their
//! (optional) activation override — `kind.index() % W`, default traffic
//! on shard 0 — so batches for different activation towers run
//! concurrently while same-activation requests still coalesce into full
//! backend batches on their shard.

use super::backend::EvalBackend;
use super::batcher::{run_loop, BatcherConfig, Msg, Request, Response};
use super::metrics::Metrics;
use super::protocol;
use crate::ntp::ActivationKind;
use anyhow::{anyhow, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// A running evaluation service (a pool of batcher workers).
pub struct Service {
    handle: ServiceHandle,
    workers: Vec<JoinHandle<()>>,
}

/// Cheap cloneable handle for submitting requests; shards per activation
/// across the worker queues.
#[derive(Clone)]
pub struct ServiceHandle {
    txs: Vec<Sender<Msg>>,
    metrics: Arc<Metrics>,
}

impl Service {
    /// Spawn a single batcher worker. The backend is built *inside* the
    /// worker thread by `factory` (PJRT executables are not `Send`); a
    /// factory error shuts the shard down and surfaces on `eval`.
    pub fn start<F>(factory: F, cfg: BatcherConfig) -> Service
    where
        F: FnOnce() -> Result<Box<dyn EvalBackend>> + Send + 'static,
    {
        let cell = Mutex::new(Some(factory));
        Service::start_pool(
            move |_| {
                let f = cell
                    .lock()
                    .expect("factory cell poisoned")
                    .take()
                    .expect("single-worker factory runs once");
                f()
            },
            1,
            cfg,
        )
    }

    /// Spawn a pool of `workers` batcher workers. `factory(w)` is called
    /// inside worker `w`'s thread to build that shard's backend, so each
    /// worker owns an independent backend (and native backends can carry
    /// their own [`crate::ntp::ParallelPolicy`]).
    ///
    /// ```
    /// use ntangent::coordinator::{BatcherConfig, NativeBackend, Service};
    /// use ntangent::nn::Mlp;
    /// use ntangent::util::prng::Prng;
    ///
    /// let mut rng = Prng::seeded(7);
    /// let mlp = Mlp::uniform(1, 8, 2, 1, &mut rng);
    /// let service = Service::start_pool(
    ///     move |_worker| Ok(Box::new(NativeBackend::new(mlp.clone(), 3, 64)) as _),
    ///     2, // two batcher workers (activation shards)
    ///     BatcherConfig::default(),
    /// );
    /// let handle = service.handle();
    /// let channels = handle.eval(&[0.0, 0.5]).unwrap();
    /// assert_eq!(channels.len(), 4); // u, u', u'', u'''
    /// assert_eq!(channels[0].len(), 2); // one value per requested point
    /// service.shutdown(); // drains the queues before joining
    /// ```
    pub fn start_pool<F>(factory: F, workers: usize, cfg: BatcherConfig) -> Service
    where
        F: Fn(usize) -> Result<Box<dyn EvalBackend>> + Send + Sync + 'static,
    {
        let workers = workers.max(1);
        let metrics = Arc::new(Metrics::with_workers(workers));
        let factory = Arc::new(factory);
        let mut txs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = channel::<Msg>();
            txs.push(tx);
            let metrics = metrics.clone();
            let factory = factory.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("ntangent-batcher-{w}"))
                    .spawn(move || match factory(w) {
                        Ok(backend) => run_loop(backend, rx, cfg, metrics, w),
                        Err(e) => {
                            eprintln!("ntangent service: backend {w} init failed: {e:#}");
                            drop(rx); // closes the shard queue; evals error out
                        }
                    })
                    .expect("spawning batcher thread"),
            );
        }
        Service {
            handle: ServiceHandle { txs, metrics },
            workers: handles,
        }
    }

    /// A cheap cloneable handle for submitting requests.
    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }

    /// Shut down: signal every worker (handle clones may still exist —
    /// their subsequent `eval` calls error out), let each drain its
    /// queue, and join them all.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        if self.workers.is_empty() {
            return;
        }
        for tx in &self.handle.txs {
            let _ = tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.stop();
    }
}

impl ServiceHandle {
    /// Number of batcher workers behind this handle.
    pub fn workers(&self) -> usize {
        self.txs.len()
    }

    /// The shard a request with this activation routes to.
    fn shard_of(&self, activation: Option<ActivationKind>) -> usize {
        match activation {
            Some(kind) => kind.index() % self.txs.len(),
            None => 0,
        }
    }

    /// Evaluate points (blocking): returns `channels[k][i]`.
    pub fn eval(&self, points: &[f64]) -> Result<Vec<Vec<f64>>> {
        self.eval_with(points, None)
    }

    /// Evaluate points with an optional per-request activation override
    /// (`None` = the served model's own activation).
    pub fn eval_with(
        &self,
        points: &[f64],
        activation: Option<ActivationKind>,
    ) -> Result<Vec<Vec<f64>>> {
        let (tx, rx) = channel::<Response>();
        self.txs[self.shard_of(activation)]
            .send(Msg::Eval(Request {
                points: points.to_vec(),
                activation,
                enqueued: Instant::now(),
                resp: tx,
            }))
            .map_err(|_| anyhow!("service is shut down"))?;
        rx.recv()
            .map_err(|_| anyhow!("service is shut down"))?
            .map_err(|e| anyhow!(e))
    }

    /// Snapshot of the global + per-worker metrics.
    pub fn metrics(&self) -> super::metrics::MetricsSnapshot {
        self.metrics.snapshot()
    }
}

/// Direct evaluator behind the wire protocol's multivariate
/// `points_nd` + `operator` requests: holds the served model and
/// answers each request with one direction-stacked
/// [`crate::ntp::MultiJetEngine`] pass.
///
/// Operator requests bypass the batcher queues — every request is a
/// self-contained fused batch already (`D · B` rows), so dynamic
/// batching would only add latency. Plans are compiled per request
/// (cheap: a small exact rational solve) because the operator is
/// client-chosen.
pub struct OperatorServer {
    mlp: crate::nn::Mlp,
    policy: crate::ntp::ParallelPolicy,
}

/// Highest operator order [`OperatorServer::eval`] accepts — the
/// documented `JetPlan` envelope. The spec is client-chosen, so without
/// a bound a parseable-but-extreme request (`"d99"`) would drive
/// unbounded plan compilation (and eventually an exact-arithmetic
/// overflow panic) on the connection thread instead of an error reply.
pub const MAX_SERVED_OPERATOR_ORDER: usize = 8;

impl OperatorServer {
    /// Serve `mlp` (any input dim) with the given batch-parallel policy.
    pub fn new(mlp: crate::nn::Mlp, policy: crate::ntp::ParallelPolicy) -> OperatorServer {
        OperatorServer { mlp, policy }
    }

    /// Evaluate `(u, L[u])` at the requested points. `operator` is a
    /// library problem name or a [`crate::pde::DiffOperator::parse`]
    /// spec over the served model's input dim, of order ≤
    /// [`MAX_SERVED_OPERATOR_ORDER`].
    pub fn eval(
        &self,
        points: &[Vec<f64>],
        operator: &str,
    ) -> std::result::Result<(Vec<f64>, Vec<f64>), String> {
        let dim = self.mlp.input_dim();
        if points.iter().any(|p| p.len() != dim) {
            return Err(format!("served model expects {dim}-dimensional points"));
        }
        let op = crate::pde::resolve_operator(operator, dim)?;
        if op.max_order() > MAX_SERVED_OPERATOR_ORDER {
            return Err(format!(
                "operator order {} exceeds the served maximum {MAX_SERVED_OPERATOR_ORDER}",
                op.max_order()
            ));
        }
        let flat: Vec<f64> = points.iter().flatten().copied().collect();
        let x = crate::tensor::Tensor::from_vec(flat, &[points.len(), dim]);
        let engine = crate::ntp::MultiJetEngine::with_policy(dim, op.max_order(), self.policy);
        let jet = engine.jet(&self.mlp, &x);
        let u = jet.value();
        let vals = op.apply(&jet);
        Ok((u.data().to_vec(), vals.data().to_vec()))
    }
}

/// Serve the JSON-lines protocol on `listener`, one thread per connection,
/// until the process exits. Returns only on accept errors. Operator
/// requests are rejected; use [`serve_tcp_with`] to serve them.
pub fn serve_tcp(listener: TcpListener, handle: ServiceHandle) -> Result<()> {
    serve_tcp_with(listener, handle, None)
}

/// [`serve_tcp`] with an optional [`OperatorServer`] answering the
/// multivariate `points_nd` + `operator` requests.
pub fn serve_tcp_with(
    listener: TcpListener,
    handle: ServiceHandle,
    operators: Option<Arc<OperatorServer>>,
) -> Result<()> {
    for stream in listener.incoming() {
        let stream = stream.context("accept failed")?;
        let handle = handle.clone();
        let operators = operators.clone();
        std::thread::spawn(move || {
            let _ = serve_connection_with(stream, handle, operators.as_deref());
        });
    }
    Ok(())
}

/// One connection: read request lines, write response lines (no
/// operator support; see [`serve_connection_with`]).
pub fn serve_connection(stream: TcpStream, handle: ServiceHandle) -> Result<()> {
    serve_connection_with(stream, handle, None)
}

/// One connection with optional operator support.
pub fn serve_connection_with(
    stream: TcpStream,
    handle: ServiceHandle,
    operators: Option<&OperatorServer>,
) -> Result<()> {
    let mut writer = stream.try_clone().context("cloning stream")?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line.context("reading request line")?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match protocol::parse_request(&line) {
            Ok(protocol::WireRequest::Eval { points, activation }) => {
                match handle.eval_with(&points, activation) {
                    Ok(channels) => protocol::encode_channels(&channels),
                    Err(e) => protocol::encode_error(&e.to_string()),
                }
            }
            Ok(protocol::WireRequest::EvalOperator { points, operator }) => match operators {
                Some(srv) => match srv.eval(&points, &operator) {
                    Ok((u, vals)) => protocol::encode_operator_values(&u, &vals),
                    Err(e) => protocol::encode_error(&e),
                },
                None => protocol::encode_error(
                    "this endpoint serves no operator evaluator (scalar checkpoints only)",
                ),
            },
            Ok(protocol::WireRequest::Stats) => protocol::encode_stats(&handle.metrics()),
            Err(e) => protocol::encode_error(&e),
        };
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}

/// A minimal blocking TCP client for the JSON-lines protocol (used by the
/// examples, tests and the benchmark harness).
pub struct TcpClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl TcpClient {
    /// Connect to a serving `ntangent serve` endpoint.
    pub fn connect(addr: &str) -> Result<TcpClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        let writer = stream.try_clone()?;
        Ok(TcpClient {
            reader: BufReader::new(stream),
            writer,
        })
    }

    /// Evaluate points with the served model's own activation.
    pub fn eval(&mut self, points: &[f64]) -> Result<Vec<Vec<f64>>> {
        self.eval_with(points, None)
    }

    /// Evaluate with an optional activation override; `None` sends a
    /// field-free request (wire-compatible with old servers).
    pub fn eval_with(
        &mut self,
        points: &[f64],
        activation: Option<ActivationKind>,
    ) -> Result<Vec<Vec<f64>>> {
        let req = protocol::encode_request(points, activation);
        self.writer.write_all(req.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        protocol::parse_channels(line.trim()).map_err(|e| anyhow!(e))
    }

    /// Evaluate a differential operator at multi-dimensional points:
    /// returns `(u, L[u])` (needs a server started with an
    /// [`OperatorServer`]).
    pub fn eval_operator(
        &mut self,
        points: &[Vec<f64>],
        operator: &str,
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        let req = protocol::encode_operator_request(points, operator);
        self.writer.write_all(req.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        protocol::parse_operator_values(line.trim()).map_err(|e| anyhow!(e))
    }

    /// Fetch the stats response line (raw JSON).
    pub fn stats(&mut self) -> Result<String> {
        self.writer.write_all(b"{\"cmd\":\"stats\"}\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(line.trim().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::NativeBackend;
    use crate::nn::Mlp;
    use crate::ntp::NtpEngine;
    use crate::tensor::Tensor;
    use crate::util::prng::Prng;

    fn test_service() -> (Service, Mlp) {
        let mut rng = Prng::seeded(123);
        let mlp = Mlp::uniform(1, 8, 2, 1, &mut rng);
        let backend_mlp = mlp.clone();
        let service = Service::start(
            move || Ok(Box::new(NativeBackend::new(backend_mlp, 2, 16)) as Box<dyn EvalBackend>),
            BatcherConfig::default(),
        );
        (service, mlp)
    }

    #[test]
    fn in_process_roundtrip_matches_direct() {
        let (service, mlp) = test_service();
        let handle = service.handle();
        let points = [0.3, -0.7, 1.1];
        let channels = handle.eval(&points).unwrap();
        let direct = NtpEngine::new(2).forward(&mlp, &Tensor::from_vec(points.to_vec(), &[3, 1]));
        for k in 0..3 {
            assert_eq!(channels[k].as_slice(), direct[k].data(), "channel {k}");
        }
        assert_eq!(handle.metrics().requests, 1);
        service.shutdown();
    }

    #[test]
    fn concurrent_clients_each_get_their_answer() {
        let (service, mlp) = test_service();
        let mut threads = Vec::new();
        for t in 0..8 {
            let handle = service.handle();
            threads.push(std::thread::spawn(move || {
                let pt = t as f64 * 0.1;
                let channels = handle.eval(&[pt]).unwrap();
                (pt, channels[0][0])
            }));
        }
        let engine = NtpEngine::new(2);
        for th in threads {
            let (pt, got) = th.join().unwrap();
            let expect = engine.forward(&mlp, &Tensor::from_vec(vec![pt], &[1, 1]))[0].data()[0];
            assert_eq!(got, expect);
        }
        let m = service.handle().metrics();
        assert_eq!(m.requests, 8);
        assert!(m.batches <= 8); // some coalescing may or may not happen
        service.shutdown();
    }

    #[test]
    fn tcp_front_roundtrip() {
        let (service, mlp) = test_service();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = service.handle();
        std::thread::spawn(move || serve_tcp(listener, handle));

        let mut client = TcpClient::connect(&addr).unwrap();
        let channels = client.eval(&[0.25, 0.5]).unwrap();
        let direct =
            NtpEngine::new(2).forward(&mlp, &Tensor::from_vec(vec![0.25, 0.5], &[2, 1]));
        for k in 0..3 {
            for (a, b) in channels[k].iter().zip(direct[k].data()) {
                assert!((a - b).abs() < 1e-12);
            }
        }
        let stats = client.stats().unwrap();
        assert!(stats.contains("\"requests\""));
        service.shutdown();
    }

    /// Operator requests over TCP: a 2-D model served with an
    /// [`OperatorServer`] answers `(u, L[u])` matching the direct jet
    /// evaluation; endpoints without one reject the request; scalar
    /// requests on the same connection keep working.
    #[test]
    fn tcp_front_serves_operator_requests() {
        use crate::ntp::{MultiJetEngine, ParallelPolicy};
        use crate::pde::DiffOperator;
        let (service, _) = test_service();
        let mut rng = Prng::seeded(77);
        let mlp2 = Mlp::uniform(2, 6, 2, 1, &mut rng);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = service.handle();
        let ops = Arc::new(OperatorServer::new(mlp2.clone(), ParallelPolicy::Serial));
        std::thread::spawn(move || serve_tcp_with(listener, handle, Some(ops)));

        let mut client = TcpClient::connect(&addr).unwrap();
        let pts = vec![vec![0.1, 0.2], vec![-0.4, 0.6]];
        let (u, vals) = client.eval_operator(&pts, "d20+d02").unwrap();
        let x = Tensor::from_vec(vec![0.1, 0.2, -0.4, 0.6], &[2, 2]);
        let op = DiffOperator::laplacian(2);
        let engine = MultiJetEngine::new(2, 2);
        let jet = engine.jet(&mlp2, &x);
        assert_eq!(u, jet.value().data().to_vec());
        assert_eq!(vals, op.apply(&jet).data().to_vec());
        // Wrong arity, unknown operators and orders beyond the served
        // cap surface as protocol errors (never connection drops).
        assert!(client.eval_operator(&[vec![0.1]], "d20+d02").is_err());
        assert!(client.eval_operator(&pts, "bogus_op").is_err());
        assert!(client.eval_operator(&pts, "d90").is_err()); // order 9 > cap 8
        // Scalar requests still work on the same connection.
        assert_eq!(client.eval(&[0.25]).unwrap().len(), 3);
        service.shutdown();

        // An endpoint without an OperatorServer rejects operator requests.
        let (service2, _) = test_service();
        let listener2 = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr2 = listener2.local_addr().unwrap().to_string();
        let handle2 = service2.handle();
        std::thread::spawn(move || serve_tcp(listener2, handle2));
        let mut client2 = TcpClient::connect(&addr2).unwrap();
        assert!(client2.eval_operator(&pts, "d20+d02").is_err());
        service2.shutdown();
    }

    #[test]
    fn eval_after_shutdown_errors() {
        let (service, _) = test_service();
        let handle = service.handle();
        service.shutdown();
        assert!(handle.eval(&[0.0]).is_err());
    }

    /// Wire compatibility: a raw request line *without* an `activation`
    /// field must behave exactly as before the field existed — the served
    /// (tanh) model answers.
    #[test]
    fn legacy_requests_without_activation_field_serve_tanh() {
        let (service, mlp) = test_service();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = service.handle();
        std::thread::spawn(move || serve_tcp(listener, handle));

        let stream = TcpStream::connect(&addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        writer.write_all(b"{\"points\": [0.4, -0.2]}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let channels = protocol::parse_channels(line.trim()).unwrap();

        let direct =
            NtpEngine::new(2).forward(&mlp, &Tensor::from_vec(vec![0.4, -0.2], &[2, 1]));
        assert_eq!(channels.len(), 3);
        for k in 0..3 {
            assert_eq!(channels[k].as_slice(), direct[k].data(), "channel {k}");
        }
        service.shutdown();
    }

    /// A 4-worker pool: requests shard per activation, every shard
    /// answers correctly, and the per-worker metrics show the spread.
    #[test]
    fn worker_pool_shards_by_activation() {
        use crate::ntp::ActivationKind;
        let mut rng = Prng::seeded(321);
        let mlp = Mlp::uniform(1, 8, 2, 1, &mut rng);
        let backend_mlp = mlp.clone();
        let service = Service::start_pool(
            move |_w| {
                Ok(Box::new(NativeBackend::new(backend_mlp.clone(), 2, 16)) as Box<dyn EvalBackend>)
            },
            4,
            BatcherConfig::default(),
        );
        let handle = service.handle();
        assert_eq!(handle.workers(), 4);
        let points = [0.2, -0.6];
        for kind in ActivationKind::ALL {
            let channels = handle.eval_with(&points, Some(kind)).unwrap();
            let mut retagged = mlp.clone();
            retagged.activation = kind;
            let direct = NtpEngine::new(2)
                .forward(&retagged, &Tensor::from_vec(points.to_vec(), &[2, 1]));
            for k in 0..3 {
                assert_eq!(channels[k].as_slice(), direct[k].data(), "{}", kind.name());
            }
        }
        let m = handle.metrics();
        assert_eq!(m.requests, 4);
        assert_eq!(m.workers.len(), 4);
        // One activation per shard (4 kinds, 4 workers): every worker
        // served exactly one request.
        for (w, ws) in m.workers.iter().enumerate() {
            assert_eq!(ws.requests, 1, "worker {w}");
            assert!(ws.batches >= 1, "worker {w}");
        }
        service.shutdown();
    }

    /// Pool with fewer workers than activations: sharding wraps around
    /// and default (no-override) traffic lands on shard 0.
    #[test]
    fn worker_pool_wraps_shards_and_routes_default_to_zero() {
        use crate::ntp::ActivationKind;
        let mut rng = Prng::seeded(322);
        let mlp = Mlp::uniform(1, 6, 2, 1, &mut rng);
        let backend_mlp = mlp.clone();
        let service = Service::start_pool(
            move |_w| {
                Ok(Box::new(NativeBackend::new(backend_mlp.clone(), 2, 16)) as Box<dyn EvalBackend>)
            },
            2,
            BatcherConfig::default(),
        );
        let handle = service.handle();
        handle.eval(&[0.1]).unwrap(); // default → worker 0
        handle.eval_with(&[0.2], Some(ActivationKind::Sine)).unwrap(); // index 1 → worker 1
        handle.eval_with(&[0.3], Some(ActivationKind::Softplus)).unwrap(); // index 2 → worker 0
        let m = handle.metrics();
        assert_eq!(m.workers[0].requests, 2);
        assert_eq!(m.workers[1].requests, 1);
        service.shutdown();
    }

    /// Per-request activation selection through the full service stack.
    #[test]
    fn activation_requests_select_towers() {
        use crate::ntp::ActivationKind;
        let (service, mlp) = test_service();
        let handle = service.handle();
        let points = [0.3, -0.7];
        for kind in ActivationKind::ALL {
            let channels = handle.eval_with(&points, Some(kind)).unwrap();
            let mut retagged = mlp.clone();
            retagged.activation = kind;
            let direct = NtpEngine::new(2)
                .forward(&retagged, &Tensor::from_vec(points.to_vec(), &[2, 1]));
            for k in 0..3 {
                assert_eq!(
                    channels[k].as_slice(),
                    direct[k].data(),
                    "{} channel {k}",
                    kind.name()
                );
            }
        }
        service.shutdown();
    }
}
