//! Service assembly: request queue + batcher worker + optional TCP front.

use super::backend::EvalBackend;
use super::batcher::{run_loop, BatcherConfig, Msg, Request, Response};
use super::metrics::Metrics;
use super::protocol;
use crate::ntp::ActivationKind;
use anyhow::{anyhow, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// A running evaluation service (single batcher worker).
pub struct Service {
    handle: ServiceHandle,
    worker: Option<JoinHandle<()>>,
}

/// Cheap cloneable handle for submitting requests.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: Sender<Msg>,
    metrics: Arc<Metrics>,
}

impl Service {
    /// Spawn the batcher worker. The backend is built *inside* the worker
    /// thread by `factory` (PJRT executables are not `Send`); a factory
    /// error shuts the service down and surfaces on the first `eval`.
    pub fn start<F>(factory: F, cfg: BatcherConfig) -> Service
    where
        F: FnOnce() -> Result<Box<dyn EvalBackend>> + Send + 'static,
    {
        let metrics = Arc::new(Metrics::default());
        let (tx, rx) = channel::<Msg>();
        let worker = std::thread::Builder::new()
            .name("ntangent-batcher".into())
            .spawn({
                let metrics = metrics.clone();
                move || match factory() {
                    Ok(backend) => run_loop(backend, rx, cfg, metrics),
                    Err(e) => {
                        eprintln!("ntangent service: backend init failed: {e:#}");
                        drop(rx); // closes the queue; evals error out
                    }
                }
            })
            .expect("spawning batcher thread");
        Service {
            handle: ServiceHandle { tx, metrics },
            worker: Some(worker),
        }
    }

    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }

    /// Shut down: signal the worker (handle clones may still exist — their
    /// subsequent `eval` calls error out) and join it.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        if let Some(w) = self.worker.take() {
            let _ = self.handle.tx.send(Msg::Shutdown);
            let _ = w.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.stop();
    }
}

impl ServiceHandle {
    /// Evaluate points (blocking): returns `channels[k][i]`.
    pub fn eval(&self, points: &[f64]) -> Result<Vec<Vec<f64>>> {
        self.eval_with(points, None)
    }

    /// Evaluate points with an optional per-request activation override
    /// (`None` = the served model's own activation).
    pub fn eval_with(
        &self,
        points: &[f64],
        activation: Option<ActivationKind>,
    ) -> Result<Vec<Vec<f64>>> {
        let (tx, rx) = channel::<Response>();
        self.tx
            .send(Msg::Eval(Request {
                points: points.to_vec(),
                activation,
                enqueued: Instant::now(),
                resp: tx,
            }))
            .map_err(|_| anyhow!("service is shut down"))?;
        rx.recv()
            .map_err(|_| anyhow!("service is shut down"))?
            .map_err(|e| anyhow!(e))
    }

    pub fn metrics(&self) -> super::metrics::MetricsSnapshot {
        self.metrics.snapshot()
    }
}

/// Serve the JSON-lines protocol on `listener`, one thread per connection,
/// until the process exits. Returns only on accept errors.
pub fn serve_tcp(listener: TcpListener, handle: ServiceHandle) -> Result<()> {
    for stream in listener.incoming() {
        let stream = stream.context("accept failed")?;
        let handle = handle.clone();
        std::thread::spawn(move || {
            let _ = serve_connection(stream, handle);
        });
    }
    Ok(())
}

/// One connection: read request lines, write response lines.
pub fn serve_connection(stream: TcpStream, handle: ServiceHandle) -> Result<()> {
    let mut writer = stream.try_clone().context("cloning stream")?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line.context("reading request line")?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match protocol::parse_request(&line) {
            Ok(protocol::WireRequest::Eval { points, activation }) => {
                match handle.eval_with(&points, activation) {
                    Ok(channels) => protocol::encode_channels(&channels),
                    Err(e) => protocol::encode_error(&e.to_string()),
                }
            }
            Ok(protocol::WireRequest::Stats) => protocol::encode_stats(&handle.metrics()),
            Err(e) => protocol::encode_error(&e),
        };
        writer.write_all(reply.as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}

/// A minimal blocking TCP client for the JSON-lines protocol (used by the
/// examples, tests and the benchmark harness).
pub struct TcpClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl TcpClient {
    pub fn connect(addr: &str) -> Result<TcpClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        let writer = stream.try_clone()?;
        Ok(TcpClient {
            reader: BufReader::new(stream),
            writer,
        })
    }

    pub fn eval(&mut self, points: &[f64]) -> Result<Vec<Vec<f64>>> {
        self.eval_with(points, None)
    }

    /// Evaluate with an optional activation override; `None` sends a
    /// field-free request (wire-compatible with old servers).
    pub fn eval_with(
        &mut self,
        points: &[f64],
        activation: Option<ActivationKind>,
    ) -> Result<Vec<Vec<f64>>> {
        let req = protocol::encode_request(points, activation);
        self.writer.write_all(req.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        protocol::parse_channels(line.trim()).map_err(|e| anyhow!(e))
    }

    pub fn stats(&mut self) -> Result<String> {
        self.writer.write_all(b"{\"cmd\":\"stats\"}\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(line.trim().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::NativeBackend;
    use crate::nn::Mlp;
    use crate::ntp::NtpEngine;
    use crate::tensor::Tensor;
    use crate::util::prng::Prng;

    fn test_service() -> (Service, Mlp) {
        let mut rng = Prng::seeded(123);
        let mlp = Mlp::uniform(1, 8, 2, 1, &mut rng);
        let backend_mlp = mlp.clone();
        let service = Service::start(
            move || Ok(Box::new(NativeBackend::new(backend_mlp, 2, 16)) as Box<dyn EvalBackend>),
            BatcherConfig::default(),
        );
        (service, mlp)
    }

    #[test]
    fn in_process_roundtrip_matches_direct() {
        let (service, mlp) = test_service();
        let handle = service.handle();
        let points = [0.3, -0.7, 1.1];
        let channels = handle.eval(&points).unwrap();
        let direct = NtpEngine::new(2).forward(&mlp, &Tensor::from_vec(points.to_vec(), &[3, 1]));
        for k in 0..3 {
            assert_eq!(channels[k].as_slice(), direct[k].data(), "channel {k}");
        }
        assert_eq!(handle.metrics().requests, 1);
        service.shutdown();
    }

    #[test]
    fn concurrent_clients_each_get_their_answer() {
        let (service, mlp) = test_service();
        let mut threads = Vec::new();
        for t in 0..8 {
            let handle = service.handle();
            threads.push(std::thread::spawn(move || {
                let pt = t as f64 * 0.1;
                let channels = handle.eval(&[pt]).unwrap();
                (pt, channels[0][0])
            }));
        }
        let engine = NtpEngine::new(2);
        for th in threads {
            let (pt, got) = th.join().unwrap();
            let expect = engine.forward(&mlp, &Tensor::from_vec(vec![pt], &[1, 1]))[0].data()[0];
            assert_eq!(got, expect);
        }
        let m = service.handle().metrics();
        assert_eq!(m.requests, 8);
        assert!(m.batches <= 8); // some coalescing may or may not happen
        service.shutdown();
    }

    #[test]
    fn tcp_front_roundtrip() {
        let (service, mlp) = test_service();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = service.handle();
        std::thread::spawn(move || serve_tcp(listener, handle));

        let mut client = TcpClient::connect(&addr).unwrap();
        let channels = client.eval(&[0.25, 0.5]).unwrap();
        let direct =
            NtpEngine::new(2).forward(&mlp, &Tensor::from_vec(vec![0.25, 0.5], &[2, 1]));
        for k in 0..3 {
            for (a, b) in channels[k].iter().zip(direct[k].data()) {
                assert!((a - b).abs() < 1e-12);
            }
        }
        let stats = client.stats().unwrap();
        assert!(stats.contains("\"requests\""));
        service.shutdown();
    }

    #[test]
    fn eval_after_shutdown_errors() {
        let (service, _) = test_service();
        let handle = service.handle();
        service.shutdown();
        assert!(handle.eval(&[0.0]).is_err());
    }

    /// Wire compatibility: a raw request line *without* an `activation`
    /// field must behave exactly as before the field existed — the served
    /// (tanh) model answers.
    #[test]
    fn legacy_requests_without_activation_field_serve_tanh() {
        let (service, mlp) = test_service();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = service.handle();
        std::thread::spawn(move || serve_tcp(listener, handle));

        let stream = TcpStream::connect(&addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        writer.write_all(b"{\"points\": [0.4, -0.2]}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let channels = protocol::parse_channels(line.trim()).unwrap();

        let direct =
            NtpEngine::new(2).forward(&mlp, &Tensor::from_vec(vec![0.4, -0.2], &[2, 1]));
        assert_eq!(channels.len(), 3);
        for k in 0..3 {
            assert_eq!(channels[k].as_slice(), direct[k].data(), "channel {k}");
        }
        service.shutdown();
    }

    /// Per-request activation selection through the full service stack.
    #[test]
    fn activation_requests_select_towers() {
        use crate::ntp::ActivationKind;
        let (service, mlp) = test_service();
        let handle = service.handle();
        let points = [0.3, -0.7];
        for kind in ActivationKind::ALL {
            let channels = handle.eval_with(&points, Some(kind)).unwrap();
            let mut retagged = mlp.clone();
            retagged.activation = kind;
            let direct = NtpEngine::new(2)
                .forward(&retagged, &Tensor::from_vec(points.to_vec(), &[2, 1]));
            for k in 0..3 {
                assert_eq!(
                    channels[k].as_slice(),
                    direct[k].data(),
                    "{} channel {k}",
                    kind.name()
                );
            }
        }
        service.shutdown();
    }
}
