//! Wire protocol for the TCP front: one JSON object per line.
//!
//! Request:  `{"points": [0.1, 0.2, ...]}`
//!           `{"cmd": "stats"}`
//! Response: `{"channels": [[u...], [u'...], ...]}`
//!           `{"error": "..."}`
//!           `{"stats": {...}}`

use super::metrics::MetricsSnapshot;
use crate::util::json::Json;

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum WireRequest {
    Eval { points: Vec<f64> },
    Stats,
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<WireRequest, String> {
    let v = Json::parse(line).map_err(|e| e.to_string())?;
    if let Some(cmd) = v.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "stats" => Ok(WireRequest::Stats),
            other => Err(format!("unknown cmd '{other}'")),
        };
    }
    let points = v
        .get("points")
        .and_then(Json::as_f64_vec)
        .ok_or_else(|| "request must have numeric 'points' array".to_string())?;
    if points.is_empty() {
        return Err("'points' must be non-empty".to_string());
    }
    Ok(WireRequest::Eval { points })
}

/// Encode an evaluation response.
pub fn encode_channels(channels: &[Vec<f64>]) -> String {
    let arr = Json::Arr(channels.iter().map(|c| Json::num_arr(c)).collect());
    Json::obj(vec![("channels", arr)]).dump()
}

/// Encode an error response.
pub fn encode_error(msg: &str) -> String {
    Json::obj(vec![("error", Json::Str(msg.to_string()))]).dump()
}

/// Encode a stats response.
pub fn encode_stats(s: &MetricsSnapshot) -> String {
    Json::obj(vec![(
        "stats",
        Json::obj(vec![
            ("requests", Json::Num(s.requests as f64)),
            ("points", Json::Num(s.points as f64)),
            ("batches", Json::Num(s.batches as f64)),
            ("errors", Json::Num(s.errors as f64)),
            ("mean_latency_us", Json::Num(s.mean_latency_us)),
            ("max_latency_us", Json::Num(s.max_latency_us)),
            ("mean_batch_fill", Json::Num(s.mean_batch_fill)),
        ]),
    )])
    .dump()
}

/// Decode an evaluation response (client side).
pub fn parse_channels(line: &str) -> Result<Vec<Vec<f64>>, String> {
    let v = Json::parse(line).map_err(|e| e.to_string())?;
    if let Some(err) = v.get("error").and_then(Json::as_str) {
        return Err(err.to_string());
    }
    v.get("channels")
        .and_then(Json::as_arr)
        .ok_or_else(|| "missing 'channels'".to_string())?
        .iter()
        .map(|c| c.as_f64_vec().ok_or_else(|| "bad channel".to_string()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_eval_request() {
        let r = parse_request(r#"{"points": [0.5, -1.0]}"#).unwrap();
        assert_eq!(r, WireRequest::Eval { points: vec![0.5, -1.0] });
    }

    #[test]
    fn parses_stats_request() {
        assert_eq!(parse_request(r#"{"cmd": "stats"}"#).unwrap(), WireRequest::Stats);
        assert!(parse_request(r#"{"cmd": "bogus"}"#).is_err());
    }

    #[test]
    fn rejects_bad_requests() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"points": []}"#).is_err());
        assert!(parse_request(r#"{"points": ["a"]}"#).is_err());
        assert!(parse_request(r#"{}"#).is_err());
    }

    #[test]
    fn channels_roundtrip() {
        let channels = vec![vec![1.0, 2.0], vec![-0.5, 0.25]];
        let line = encode_channels(&channels);
        assert_eq!(parse_channels(&line).unwrap(), channels);
    }

    #[test]
    fn error_roundtrip() {
        let line = encode_error("boom");
        assert_eq!(parse_channels(&line).unwrap_err(), "boom");
    }

    #[test]
    fn stats_encode_mentions_fields() {
        let s = MetricsSnapshot {
            requests: 3,
            points: 10,
            batches: 2,
            batched_points: 10,
            errors: 0,
            mean_latency_us: 12.5,
            max_latency_us: 20.0,
            mean_batch_fill: 1.5,
        };
        let line = encode_stats(&s);
        assert!(line.contains("\"requests\":3"));
        assert!(line.contains("mean_batch_fill"));
    }
}
