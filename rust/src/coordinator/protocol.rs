//! Wire protocol for the TCP front: one JSON object per line.
//!
//! Request:  `{"points": [0.1, 0.2, ...]}`
//!           `{"points": [...], "activation": "sin"}`
//!           `{"points_nd": [[0.1, 0.2], ...], "operator": "d20+d02"}`
//!           `{"cmd": "stats"}`
//! Response: `{"channels": [[u...], [u'...], ...]}`
//!           `{"u": [...], "operator": [...]}`
//!           `{"error": "..."}`
//!           `{"stats": {...}}`
//!
//! The `activation` field is optional and selects the derivative tower
//! applied to the served weights (any registered
//! [`ActivationKind`] name). Requests without it behave exactly as
//! before the field existed: the backend evaluates with the served
//! model's own activation (tanh for every pre-existing checkpoint), so
//! the protocol stays wire-compatible.
//!
//! `points_nd` + `operator` is the multivariate request form: each
//! point is one row of coordinates (arity = the served model's input
//! dim), and `operator` is a library problem name or a
//! [`crate::pde::DiffOperator::parse`] spec. The response carries the
//! field values `u` and the operator values `L[u]` at every point.
//! Scalar requests are untouched — the extension is wire-compatible.

use super::metrics::MetricsSnapshot;
use crate::ntp::ActivationKind;
use crate::util::json::Json;

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum WireRequest {
    /// Evaluate the derivative stack at `points`.
    Eval {
        /// Points to evaluate at.
        points: Vec<f64>,
        /// `None` = the served model's own activation (wire-compatible
        /// default).
        activation: Option<ActivationKind>,
    },
    /// Evaluate a differential operator at multi-dimensional points.
    EvalOperator {
        /// Points, one coordinate row each (equal arity).
        points: Vec<Vec<f64>>,
        /// Operator: a library problem name or a parseable spec.
        operator: String,
    },
    /// Return the service metrics snapshot.
    Stats,
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<WireRequest, String> {
    let v = Json::parse(line).map_err(|e| e.to_string())?;
    if let Some(cmd) = v.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "stats" => Ok(WireRequest::Stats),
            other => Err(format!("unknown cmd '{other}'")),
        };
    }
    if let Some(rows) = v.get("points_nd") {
        let rows = rows
            .as_arr()
            .ok_or_else(|| "'points_nd' must be an array of coordinate rows".to_string())?;
        let points: Vec<Vec<f64>> = rows
            .iter()
            .map(|r| {
                r.as_f64_vec()
                    .ok_or_else(|| "every 'points_nd' row must be a numeric array".to_string())
            })
            .collect::<Result<_, _>>()?;
        if points.is_empty() {
            return Err("'points_nd' must be non-empty".to_string());
        }
        let dim = points[0].len();
        if dim == 0 || points.iter().any(|p| p.len() != dim) {
            return Err("'points_nd' rows must share a non-zero arity".to_string());
        }
        let operator = v
            .get("operator")
            .and_then(Json::as_str)
            .ok_or_else(|| "'points_nd' requests need an 'operator' string".to_string())?
            .to_string();
        return Ok(WireRequest::EvalOperator { points, operator });
    }
    let points = v
        .get("points")
        .and_then(Json::as_f64_vec)
        .ok_or_else(|| "request must have numeric 'points' array".to_string())?;
    if points.is_empty() {
        return Err("'points' must be non-empty".to_string());
    }
    let activation = match v.get("activation") {
        None => None,
        Some(a) => {
            let name = a
                .as_str()
                .ok_or_else(|| "'activation' must be a string".to_string())?;
            Some(
                ActivationKind::from_name(name)
                    .ok_or_else(|| format!("unknown activation '{name}'"))?,
            )
        }
    };
    Ok(WireRequest::Eval { points, activation })
}

/// Encode an evaluation request (client side).
pub fn encode_request(points: &[f64], activation: Option<ActivationKind>) -> String {
    let mut fields = vec![("points", Json::num_arr(points))];
    if let Some(kind) = activation {
        fields.push(("activation", Json::Str(kind.name().to_string())));
    }
    Json::obj(fields).dump()
}

/// Encode an operator-evaluation request (client side).
pub fn encode_operator_request(points: &[Vec<f64>], operator: &str) -> String {
    let rows = Json::Arr(points.iter().map(|p| Json::num_arr(p)).collect());
    Json::obj(vec![
        ("points_nd", rows),
        ("operator", Json::Str(operator.to_string())),
    ])
    .dump()
}

/// Encode an operator-evaluation response: the field values `u` and the
/// operator values `L[u]`, one per requested point.
pub fn encode_operator_values(u: &[f64], values: &[f64]) -> String {
    Json::obj(vec![
        ("u", Json::num_arr(u)),
        ("operator", Json::num_arr(values)),
    ])
    .dump()
}

/// Decode an operator-evaluation response (client side): `(u, L[u])`.
pub fn parse_operator_values(line: &str) -> Result<(Vec<f64>, Vec<f64>), String> {
    let v = Json::parse(line).map_err(|e| e.to_string())?;
    if let Some(err) = v.get("error").and_then(Json::as_str) {
        return Err(err.to_string());
    }
    let u = v
        .get("u")
        .and_then(Json::as_f64_vec)
        .ok_or_else(|| "missing 'u'".to_string())?;
    let vals = v
        .get("operator")
        .and_then(Json::as_f64_vec)
        .ok_or_else(|| "missing 'operator'".to_string())?;
    Ok((u, vals))
}

/// Encode an evaluation response.
pub fn encode_channels(channels: &[Vec<f64>]) -> String {
    let arr = Json::Arr(channels.iter().map(|c| Json::num_arr(c)).collect());
    Json::obj(vec![("channels", arr)]).dump()
}

/// Encode an error response.
pub fn encode_error(msg: &str) -> String {
    Json::obj(vec![("error", Json::Str(msg.to_string()))]).dump()
}

/// Encode a stats response (includes one object per batcher worker).
pub fn encode_stats(s: &MetricsSnapshot) -> String {
    let workers = Json::Arr(
        s.workers
            .iter()
            .map(|w| {
                Json::obj(vec![
                    ("requests", Json::Num(w.requests as f64)),
                    ("batches", Json::Num(w.batches as f64)),
                    ("batched_points", Json::Num(w.batched_points as f64)),
                    ("errors", Json::Num(w.errors as f64)),
                ])
            })
            .collect(),
    );
    Json::obj(vec![(
        "stats",
        Json::obj(vec![
            ("requests", Json::Num(s.requests as f64)),
            ("points", Json::Num(s.points as f64)),
            ("batches", Json::Num(s.batches as f64)),
            ("errors", Json::Num(s.errors as f64)),
            ("mean_latency_us", Json::Num(s.mean_latency_us)),
            ("max_latency_us", Json::Num(s.max_latency_us)),
            ("mean_batch_fill", Json::Num(s.mean_batch_fill)),
            ("workers", workers),
        ]),
    )])
    .dump()
}

/// Decode an evaluation response (client side).
pub fn parse_channels(line: &str) -> Result<Vec<Vec<f64>>, String> {
    let v = Json::parse(line).map_err(|e| e.to_string())?;
    if let Some(err) = v.get("error").and_then(Json::as_str) {
        return Err(err.to_string());
    }
    v.get("channels")
        .and_then(Json::as_arr)
        .ok_or_else(|| "missing 'channels'".to_string())?
        .iter()
        .map(|c| c.as_f64_vec().ok_or_else(|| "bad channel".to_string()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_eval_request() {
        let r = parse_request(r#"{"points": [0.5, -1.0]}"#).unwrap();
        assert_eq!(
            r,
            WireRequest::Eval { points: vec![0.5, -1.0], activation: None }
        );
    }

    #[test]
    fn parses_activation_field() {
        let r = parse_request(r#"{"points": [0.5], "activation": "sin"}"#).unwrap();
        assert_eq!(
            r,
            WireRequest::Eval {
                points: vec![0.5],
                activation: Some(ActivationKind::Sine)
            }
        );
        assert!(parse_request(r#"{"points": [0.5], "activation": "relu"}"#).is_err());
        assert!(parse_request(r#"{"points": [0.5], "activation": 3}"#).is_err());
    }

    #[test]
    fn encode_request_roundtrips() {
        for activation in [None, Some(ActivationKind::Gelu)] {
            let line = encode_request(&[0.25, -0.5], activation);
            let parsed = parse_request(&line).unwrap();
            assert_eq!(
                parsed,
                WireRequest::Eval { points: vec![0.25, -0.5], activation }
            );
        }
        // Wire compatibility: no field at all unless requested.
        assert!(!encode_request(&[1.0], None).contains("activation"));
    }

    #[test]
    fn parses_operator_request() {
        let r = parse_request(r#"{"points_nd": [[0.1, 0.2], [0.3, 0.4]], "operator": "d20+d02"}"#)
            .unwrap();
        assert_eq!(
            r,
            WireRequest::EvalOperator {
                points: vec![vec![0.1, 0.2], vec![0.3, 0.4]],
                operator: "d20+d02".to_string()
            }
        );
        // Missing operator, empty rows, ragged arity: rejected.
        assert!(parse_request(r#"{"points_nd": [[0.1, 0.2]]}"#).is_err());
        assert!(parse_request(r#"{"points_nd": [], "operator": "d20"}"#).is_err());
        assert!(parse_request(r#"{"points_nd": [[0.1], [0.2, 0.3]], "operator": "d2"}"#).is_err());
        assert!(parse_request(r#"{"points_nd": [0.1], "operator": "d2"}"#).is_err());
    }

    #[test]
    fn operator_request_roundtrips() {
        let pts = vec![vec![0.25, -0.5], vec![0.5, 0.75]];
        let line = encode_operator_request(&pts, "heat2d");
        let parsed = parse_request(&line).unwrap();
        assert_eq!(
            parsed,
            WireRequest::EvalOperator { points: pts, operator: "heat2d".to_string() }
        );
        // Scalar requests never grow the new fields.
        assert!(!encode_request(&[1.0], None).contains("points_nd"));
    }

    #[test]
    fn operator_values_roundtrip() {
        let line = encode_operator_values(&[1.0, 2.0], &[-0.5, 0.25]);
        assert_eq!(
            parse_operator_values(&line).unwrap(),
            (vec![1.0, 2.0], vec![-0.5, 0.25])
        );
        assert!(parse_operator_values(&encode_error("nope")).is_err());
    }

    #[test]
    fn parses_stats_request() {
        assert_eq!(parse_request(r#"{"cmd": "stats"}"#).unwrap(), WireRequest::Stats);
        assert!(parse_request(r#"{"cmd": "bogus"}"#).is_err());
    }

    #[test]
    fn rejects_bad_requests() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"points": []}"#).is_err());
        assert!(parse_request(r#"{"points": ["a"]}"#).is_err());
        assert!(parse_request(r#"{}"#).is_err());
    }

    #[test]
    fn channels_roundtrip() {
        let channels = vec![vec![1.0, 2.0], vec![-0.5, 0.25]];
        let line = encode_channels(&channels);
        assert_eq!(parse_channels(&line).unwrap(), channels);
    }

    #[test]
    fn error_roundtrip() {
        let line = encode_error("boom");
        assert_eq!(parse_channels(&line).unwrap_err(), "boom");
    }

    #[test]
    fn stats_encode_mentions_fields() {
        use super::super::metrics::WorkerSnapshot;
        let s = MetricsSnapshot {
            requests: 3,
            points: 10,
            batches: 2,
            batched_points: 10,
            errors: 0,
            mean_latency_us: 12.5,
            max_latency_us: 20.0,
            mean_batch_fill: 1.5,
            workers: vec![WorkerSnapshot {
                requests: 3,
                batches: 2,
                batched_points: 10,
                errors: 0,
            }],
        };
        let line = encode_stats(&s);
        assert!(line.contains("\"requests\":3"));
        assert!(line.contains("mean_batch_fill"));
        assert!(line.contains("\"workers\""));
        assert!(line.contains("\"batched_points\":10"));
    }
}
