//! Wire protocol for the TCP front: one JSON object per line.
//!
//! Request:  `{"points": [0.1, 0.2, ...]}`
//!           `{"points": [...], "activation": "sin"}`
//!           `{"cmd": "stats"}`
//! Response: `{"channels": [[u...], [u'...], ...]}`
//!           `{"error": "..."}`
//!           `{"stats": {...}}`
//!
//! The `activation` field is optional and selects the derivative tower
//! applied to the served weights (any registered
//! [`ActivationKind`] name). Requests without it behave exactly as
//! before the field existed: the backend evaluates with the served
//! model's own activation (tanh for every pre-existing checkpoint), so
//! the protocol stays wire-compatible.

use super::metrics::MetricsSnapshot;
use crate::ntp::ActivationKind;
use crate::util::json::Json;

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum WireRequest {
    /// Evaluate the derivative stack at `points`.
    Eval {
        /// Points to evaluate at.
        points: Vec<f64>,
        /// `None` = the served model's own activation (wire-compatible
        /// default).
        activation: Option<ActivationKind>,
    },
    /// Return the service metrics snapshot.
    Stats,
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<WireRequest, String> {
    let v = Json::parse(line).map_err(|e| e.to_string())?;
    if let Some(cmd) = v.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "stats" => Ok(WireRequest::Stats),
            other => Err(format!("unknown cmd '{other}'")),
        };
    }
    let points = v
        .get("points")
        .and_then(Json::as_f64_vec)
        .ok_or_else(|| "request must have numeric 'points' array".to_string())?;
    if points.is_empty() {
        return Err("'points' must be non-empty".to_string());
    }
    let activation = match v.get("activation") {
        None => None,
        Some(a) => {
            let name = a
                .as_str()
                .ok_or_else(|| "'activation' must be a string".to_string())?;
            Some(
                ActivationKind::from_name(name)
                    .ok_or_else(|| format!("unknown activation '{name}'"))?,
            )
        }
    };
    Ok(WireRequest::Eval { points, activation })
}

/// Encode an evaluation request (client side).
pub fn encode_request(points: &[f64], activation: Option<ActivationKind>) -> String {
    let mut fields = vec![("points", Json::num_arr(points))];
    if let Some(kind) = activation {
        fields.push(("activation", Json::Str(kind.name().to_string())));
    }
    Json::obj(fields).dump()
}

/// Encode an evaluation response.
pub fn encode_channels(channels: &[Vec<f64>]) -> String {
    let arr = Json::Arr(channels.iter().map(|c| Json::num_arr(c)).collect());
    Json::obj(vec![("channels", arr)]).dump()
}

/// Encode an error response.
pub fn encode_error(msg: &str) -> String {
    Json::obj(vec![("error", Json::Str(msg.to_string()))]).dump()
}

/// Encode a stats response (includes one object per batcher worker).
pub fn encode_stats(s: &MetricsSnapshot) -> String {
    let workers = Json::Arr(
        s.workers
            .iter()
            .map(|w| {
                Json::obj(vec![
                    ("requests", Json::Num(w.requests as f64)),
                    ("batches", Json::Num(w.batches as f64)),
                    ("batched_points", Json::Num(w.batched_points as f64)),
                    ("errors", Json::Num(w.errors as f64)),
                ])
            })
            .collect(),
    );
    Json::obj(vec![(
        "stats",
        Json::obj(vec![
            ("requests", Json::Num(s.requests as f64)),
            ("points", Json::Num(s.points as f64)),
            ("batches", Json::Num(s.batches as f64)),
            ("errors", Json::Num(s.errors as f64)),
            ("mean_latency_us", Json::Num(s.mean_latency_us)),
            ("max_latency_us", Json::Num(s.max_latency_us)),
            ("mean_batch_fill", Json::Num(s.mean_batch_fill)),
            ("workers", workers),
        ]),
    )])
    .dump()
}

/// Decode an evaluation response (client side).
pub fn parse_channels(line: &str) -> Result<Vec<Vec<f64>>, String> {
    let v = Json::parse(line).map_err(|e| e.to_string())?;
    if let Some(err) = v.get("error").and_then(Json::as_str) {
        return Err(err.to_string());
    }
    v.get("channels")
        .and_then(Json::as_arr)
        .ok_or_else(|| "missing 'channels'".to_string())?
        .iter()
        .map(|c| c.as_f64_vec().ok_or_else(|| "bad channel".to_string()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_eval_request() {
        let r = parse_request(r#"{"points": [0.5, -1.0]}"#).unwrap();
        assert_eq!(
            r,
            WireRequest::Eval { points: vec![0.5, -1.0], activation: None }
        );
    }

    #[test]
    fn parses_activation_field() {
        let r = parse_request(r#"{"points": [0.5], "activation": "sin"}"#).unwrap();
        assert_eq!(
            r,
            WireRequest::Eval {
                points: vec![0.5],
                activation: Some(ActivationKind::Sine)
            }
        );
        assert!(parse_request(r#"{"points": [0.5], "activation": "relu"}"#).is_err());
        assert!(parse_request(r#"{"points": [0.5], "activation": 3}"#).is_err());
    }

    #[test]
    fn encode_request_roundtrips() {
        for activation in [None, Some(ActivationKind::Gelu)] {
            let line = encode_request(&[0.25, -0.5], activation);
            let parsed = parse_request(&line).unwrap();
            assert_eq!(
                parsed,
                WireRequest::Eval { points: vec![0.25, -0.5], activation }
            );
        }
        // Wire compatibility: no field at all unless requested.
        assert!(!encode_request(&[1.0], None).contains("activation"));
    }

    #[test]
    fn parses_stats_request() {
        assert_eq!(parse_request(r#"{"cmd": "stats"}"#).unwrap(), WireRequest::Stats);
        assert!(parse_request(r#"{"cmd": "bogus"}"#).is_err());
    }

    #[test]
    fn rejects_bad_requests() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"points": []}"#).is_err());
        assert!(parse_request(r#"{"points": ["a"]}"#).is_err());
        assert!(parse_request(r#"{}"#).is_err());
    }

    #[test]
    fn channels_roundtrip() {
        let channels = vec![vec![1.0, 2.0], vec![-0.5, 0.25]];
        let line = encode_channels(&channels);
        assert_eq!(parse_channels(&line).unwrap(), channels);
    }

    #[test]
    fn error_roundtrip() {
        let line = encode_error("boom");
        assert_eq!(parse_channels(&line).unwrap_err(), "boom");
    }

    #[test]
    fn stats_encode_mentions_fields() {
        use super::super::metrics::WorkerSnapshot;
        let s = MetricsSnapshot {
            requests: 3,
            points: 10,
            batches: 2,
            batched_points: 10,
            errors: 0,
            mean_latency_us: 12.5,
            max_latency_us: 20.0,
            mean_batch_fill: 1.5,
            workers: vec![WorkerSnapshot {
                requests: 3,
                batches: 2,
                batched_points: 10,
                errors: 0,
            }],
        };
        let line = encode_stats(&s);
        assert!(line.contains("\"requests\":3"));
        assert!(line.contains("mean_batch_fill"));
        assert!(line.contains("\"workers\""));
        assert!(line.contains("\"batched_points\":10"));
    }
}
