//! Wire protocol for the TCP front: JSON messages, length-framed or
//! newline-delimited.
//!
//! Request:  `{"points": [0.1, 0.2, ...]}`
//!           `{"points": [...], "activation": "sin"}`
//!           `{"points_nd": [[0.1, 0.2], ...], "operator": "d20+d02"}`
//!           `{"points_nd": [...], "operator": "...", "activation": "sin"}`
//!           `{"cmd": "stats"}`
//!           `{"stats": "full"}`
//! Response: `{"channels": [[u...], [u'...], ...]}`
//!           `{"u": [...], "operator": [...]}`
//!           `{"error": "..."}`
//!           `{"error": "overloaded", "retry_ms": 50}`
//!           `{"stats": {...}}`
//!
//! # Transport: frames and lines
//!
//! Each message travels in one of two interchangeable transports,
//! chosen per message by its first byte:
//!
//! - **Framed** (the persistent-connection transport): a
//!   [`FRAME_MAGIC`] byte, a big-endian `u32` payload length, then that
//!   many bytes of UTF-8 JSON. Frames carry no trailing newline and may
//!   be pipelined back-to-back; replies to framed requests are framed.
//!   Payloads above [`MAX_FRAME_LEN`] are rejected.
//! - **Line** (the legacy transport): one JSON object terminated by
//!   `\n`. Replies to line requests are newline-terminated, keeping
//!   every pre-existing client wire-compatible. Lines are capped at
//!   [`MAX_FRAME_LEN`] bytes so an unterminated stream cannot buffer
//!   unboundedly.
//!
//! [`read_message`] dispatches between the two on the server and client
//! alike (`0x9E` is never the first byte of JSON text, so the
//! discrimination is unambiguous).
//!
//! The `activation` field is optional and selects the derivative tower
//! applied to the served weights (any registered
//! [`ActivationKind`] name). Requests without it behave exactly as
//! before the field existed: the backend evaluates with the served
//! model's own activation (tanh for every pre-existing checkpoint), so
//! the protocol stays wire-compatible.
//!
//! `points_nd` + `operator` is the multivariate request form: each
//! point is one row of coordinates (arity = the served model's input
//! dim), and `operator` is a library problem name or a
//! [`crate::pde::DiffOperator::parse`] spec. The response carries the
//! field values `u` and the operator values `L[u]` at every point.
//! Scalar requests are untouched — the extension is wire-compatible.

use super::metrics::MetricsSnapshot;
use crate::ntp::ActivationKind;
use crate::util::json::Json;
use std::io::{BufRead, Read, Write};

/// First byte of a length-framed message (never the first byte of
/// JSON text, so framed and line transports coexist on one stream).
pub const FRAME_MAGIC: u8 = 0x9E;

/// Largest accepted frame payload (and line length) in bytes. Bounds
/// per-connection buffering against malicious or broken clients.
pub const MAX_FRAME_LEN: usize = 4 << 20;

/// One message read off the stream, tagged with its transport.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Incoming {
    /// A length-framed payload; the reply must be framed.
    Frame(String),
    /// A newline-terminated line; the reply must be a line.
    Line(String),
    /// Clean end of stream (no partial message pending).
    Eof,
}

/// Why [`read_message`] failed.
#[derive(Debug)]
pub enum ReadError {
    /// Declared frame length (or accumulated line length) exceeds
    /// [`MAX_FRAME_LEN`]. `framed` tags the transport so the server can
    /// shape its final error reply before closing.
    TooLarge {
        /// Whether the oversized message was a frame (vs a line).
        framed: bool,
        /// The declared or accumulated length in bytes.
        len: usize,
    },
    /// Frame payload was not valid UTF-8.
    BadUtf8,
    /// The stream failed or ended mid-message (truncated frame,
    /// disconnect); nothing can be replied.
    Io(std::io::Error),
}

impl std::fmt::Display for ReadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReadError::TooLarge { framed, len } => write!(
                f,
                "message of {len} bytes exceeds the {MAX_FRAME_LEN}-byte limit ({})",
                if *framed { "framed" } else { "line" }
            ),
            ReadError::BadUtf8 => write!(f, "frame payload is not valid UTF-8"),
            ReadError::Io(e) => write!(f, "reading message: {e}"),
        }
    }
}

impl std::error::Error for ReadError {}

impl From<std::io::Error> for ReadError {
    fn from(e: std::io::Error) -> ReadError {
        ReadError::Io(e)
    }
}

/// Write one framed message: [`FRAME_MAGIC`], big-endian `u32` length,
/// payload. The caller flushes (framed writers batch pipelined replies).
pub fn write_frame(w: &mut impl Write, payload: &str) -> std::io::Result<()> {
    w.write_all(&[FRAME_MAGIC])?;
    w.write_all(&(payload.len() as u32).to_be_bytes())?;
    w.write_all(payload.as_bytes())
}

/// Read the next message, framed or line, off `r` (see the module docs
/// for the transport rules). Interstitial `\r`/`\n`/space bytes between
/// messages are skipped, so framed and line traffic can interleave.
pub fn read_message(r: &mut impl BufRead) -> Result<Incoming, ReadError> {
    // Skip inter-message whitespace and find the discriminating byte.
    let first = loop {
        let buf = r.fill_buf()?;
        if buf.is_empty() {
            return Ok(Incoming::Eof);
        }
        match buf[0] {
            b'\n' | b'\r' | b' ' | b'\t' => r.consume(1),
            b => break b,
        }
    };
    if first == FRAME_MAGIC {
        r.consume(1);
        let mut len_bytes = [0u8; 4];
        r.read_exact(&mut len_bytes)?;
        let len = u32::from_be_bytes(len_bytes) as usize;
        if len > MAX_FRAME_LEN {
            return Err(ReadError::TooLarge { framed: true, len });
        }
        let mut payload = vec![0u8; len];
        r.read_exact(&mut payload)?;
        let text = String::from_utf8(payload).map_err(|_| ReadError::BadUtf8)?;
        return Ok(Incoming::Frame(text));
    }
    // Line transport: accumulate up to the newline, capped.
    let mut line: Vec<u8> = Vec::new();
    loop {
        let buf = r.fill_buf()?;
        if buf.is_empty() {
            // EOF with a partial line: serve it (matches the legacy
            // `BufRead::lines` behaviour for unterminated final lines).
            break;
        }
        let nl = buf.iter().position(|&b| b == b'\n');
        let take = nl.map(|i| i + 1).unwrap_or(buf.len());
        if line.len() + take > MAX_FRAME_LEN {
            return Err(ReadError::TooLarge {
                framed: false,
                len: line.len() + take,
            });
        }
        line.extend_from_slice(&buf[..take]);
        r.consume(take);
        if nl.is_some() {
            break;
        }
    }
    let text = String::from_utf8(line).map_err(|_| ReadError::BadUtf8)?;
    Ok(Incoming::Line(text.trim_end_matches(['\n', '\r']).to_string()))
}

/// A parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum WireRequest {
    /// Evaluate the derivative stack at `points`.
    Eval {
        /// Points to evaluate at.
        points: Vec<f64>,
        /// `None` = the served model's own activation (wire-compatible
        /// default).
        activation: Option<ActivationKind>,
    },
    /// Evaluate a differential operator at multi-dimensional points.
    EvalOperator {
        /// Points, one coordinate row each (equal arity).
        points: Vec<Vec<f64>>,
        /// Operator: a library problem name or a parseable spec.
        operator: String,
        /// `None` = the served model's own activation (wire-compatible
        /// default, as for scalar requests).
        activation: Option<ActivationKind>,
    },
    /// Return the service metrics snapshot.
    Stats,
    /// Return the full observability document: the plain stats plus
    /// latency-segment histograms, per-worker percentiles, compile-cache
    /// occupancy and the registry counters (`{"stats": "full"}`).
    StatsFull,
}

/// Parse the optional `activation` field of a request object.
fn parse_activation(v: &Json) -> Result<Option<ActivationKind>, String> {
    match v.get("activation") {
        None => Ok(None),
        Some(a) => {
            let name = a
                .as_str()
                .ok_or_else(|| "'activation' must be a string".to_string())?;
            Ok(Some(
                ActivationKind::from_name(name)
                    .ok_or_else(|| format!("unknown activation '{name}'"))?,
            ))
        }
    }
}

/// Parse one request message (a framed payload or a line).
pub fn parse_request(line: &str) -> Result<WireRequest, String> {
    let v = Json::parse(line).map_err(|e| e.to_string())?;
    if let Some(cmd) = v.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "stats" => Ok(WireRequest::Stats),
            other => Err(format!("unknown cmd '{other}'")),
        };
    }
    if let Some(detail) = v.get("stats") {
        return match detail.as_str() {
            Some("full") => Ok(WireRequest::StatsFull),
            _ => Err("'stats' requests take the string \"full\"".to_string()),
        };
    }
    if let Some(rows) = v.get("points_nd") {
        let rows = rows
            .as_arr()
            .ok_or_else(|| "'points_nd' must be an array of coordinate rows".to_string())?;
        let points: Vec<Vec<f64>> = rows
            .iter()
            .map(|r| {
                r.as_f64_vec()
                    .ok_or_else(|| "every 'points_nd' row must be a numeric array".to_string())
            })
            .collect::<Result<_, _>>()?;
        if points.is_empty() {
            return Err("'points_nd' must be non-empty".to_string());
        }
        let dim = points[0].len();
        if dim == 0 || points.iter().any(|p| p.len() != dim) {
            return Err("'points_nd' rows must share a non-zero arity".to_string());
        }
        let operator = v
            .get("operator")
            .and_then(Json::as_str)
            .ok_or_else(|| "'points_nd' requests need an 'operator' string".to_string())?
            .to_string();
        let activation = parse_activation(&v)?;
        return Ok(WireRequest::EvalOperator {
            points,
            operator,
            activation,
        });
    }
    let points = v
        .get("points")
        .and_then(Json::as_f64_vec)
        .ok_or_else(|| "request must have numeric 'points' array".to_string())?;
    if points.is_empty() {
        return Err("'points' must be non-empty".to_string());
    }
    let activation = parse_activation(&v)?;
    Ok(WireRequest::Eval { points, activation })
}

/// Encode an evaluation request (client side).
pub fn encode_request(points: &[f64], activation: Option<ActivationKind>) -> String {
    let mut fields = vec![("points", Json::num_arr(points))];
    if let Some(kind) = activation {
        fields.push(("activation", Json::Str(kind.name().to_string())));
    }
    Json::obj(fields).dump()
}

/// Encode an operator-evaluation request (client side); `activation`
/// optionally overrides the served model's tower, exactly as for scalar
/// requests (`None` emits no field — wire-compatible with old servers).
pub fn encode_operator_request(
    points: &[Vec<f64>],
    operator: &str,
    activation: Option<ActivationKind>,
) -> String {
    let rows = Json::Arr(points.iter().map(|p| Json::num_arr(p)).collect());
    let mut fields = vec![
        ("points_nd", rows),
        ("operator", Json::Str(operator.to_string())),
    ];
    if let Some(kind) = activation {
        fields.push(("activation", Json::Str(kind.name().to_string())));
    }
    Json::obj(fields).dump()
}

/// Encode an operator-evaluation response: the field values `u` and the
/// operator values `L[u]`, one per requested point.
pub fn encode_operator_values(u: &[f64], values: &[f64]) -> String {
    Json::obj(vec![
        ("u", Json::num_arr(u)),
        ("operator", Json::num_arr(values)),
    ])
    .dump()
}

/// Decode an operator-evaluation response (client side): `(u, L[u])`.
pub fn parse_operator_values(line: &str) -> Result<(Vec<f64>, Vec<f64>), String> {
    let v = Json::parse(line).map_err(|e| e.to_string())?;
    if let Some(err) = v.get("error").and_then(Json::as_str) {
        return Err(err.to_string());
    }
    let u = v
        .get("u")
        .and_then(Json::as_f64_vec)
        .ok_or_else(|| "missing 'u'".to_string())?;
    let vals = v
        .get("operator")
        .and_then(Json::as_f64_vec)
        .ok_or_else(|| "missing 'operator'".to_string())?;
    Ok((u, vals))
}

/// Encode an evaluation response.
pub fn encode_channels(channels: &[Vec<f64>]) -> String {
    let arr = Json::Arr(channels.iter().map(|c| Json::num_arr(c)).collect());
    Json::obj(vec![("channels", arr)]).dump()
}

/// Encode an error response.
pub fn encode_error(msg: &str) -> String {
    Json::obj(vec![("error", Json::Str(msg.to_string()))]).dump()
}

/// Encode the backpressure shed response: the target worker's ingress
/// queue is full, retry after `retry_ms` milliseconds.
pub fn encode_shed(retry_ms: u64) -> String {
    Json::obj(vec![
        ("error", Json::Str("overloaded".to_string())),
        ("retry_ms", Json::Num(retry_ms as f64)),
    ])
    .dump()
}

/// Decode an error response (client side): `Some((message, retry_ms))`
/// if the payload is an error, `None` otherwise. `retry_ms` is set on
/// shed responses — the retry contract is: back off that long, then
/// resubmit the identical request.
pub fn parse_error(line: &str) -> Option<(String, Option<u64>)> {
    let v = Json::parse(line).ok()?;
    let msg = v.get("error").and_then(Json::as_str)?.to_string();
    let retry_ms = v.get("retry_ms").and_then(Json::as_f64).map(|ms| ms as u64);
    Some((msg, retry_ms))
}

/// The compile-cache occupancy object shared by both stats replies:
/// engine/scalar-engine/operator entry counts from
/// [`crate::pde::cache::cache_sizes`] plus lifetime operator evictions.
fn cache_stats_json() -> Json {
    let (engines, scalar_engines, operators) = crate::pde::cache::cache_sizes();
    let (_, evictions) = crate::pde::cache::operator_cache_stats();
    Json::obj(vec![
        ("engines", Json::Num(engines as f64)),
        ("scalar_engines", Json::Num(scalar_engines as f64)),
        ("operators", Json::Num(operators as f64)),
        ("operator_evictions", Json::Num(evictions as f64)),
    ])
}

/// The counter fields shared by both stats replies (everything except
/// the histogram documents and per-worker percentiles).
fn stats_fields(s: &MetricsSnapshot, workers: Json) -> Vec<(&'static str, Json)> {
    let reg = crate::obs::registry();
    vec![
        ("requests", Json::Num(s.requests as f64)),
        ("points", Json::Num(s.points as f64)),
        ("batches", Json::Num(s.batches as f64)),
        ("errors", Json::Num(s.errors as f64)),
        ("shed", Json::Num(s.shed as f64)),
        ("plan_hits", Json::Num(s.plan_hits as f64)),
        ("plan_misses", Json::Num(s.plan_misses as f64)),
        ("mean_latency_us", Json::Num(s.mean_latency_us)),
        ("max_latency_us", Json::Num(s.max_latency_us)),
        ("p50_latency_us", Json::Num(s.p50_latency_us)),
        ("p95_latency_us", Json::Num(s.p95_latency_us)),
        ("p99_latency_us", Json::Num(s.p99_latency_us)),
        ("mean_batch_fill", Json::Num(s.mean_batch_fill)),
        (
            "operator_requests",
            Json::Num(reg.counter("serve_operator_requests").get() as f64),
        ),
        (
            "operator_errors",
            Json::Num(reg.counter("serve_operator_errors").get() as f64),
        ),
        ("cache", cache_stats_json()),
        ("workers", workers),
    ]
}

/// Encode a stats response (includes one object per batcher worker,
/// the bucketed latency percentiles, compile-cache occupancy and the
/// operator-path request counters).
pub fn encode_stats(s: &MetricsSnapshot) -> String {
    let workers = Json::Arr(
        s.workers
            .iter()
            .map(|w| {
                Json::obj(vec![
                    ("requests", Json::Num(w.requests as f64)),
                    ("batches", Json::Num(w.batches as f64)),
                    ("batched_points", Json::Num(w.batched_points as f64)),
                    ("errors", Json::Num(w.errors as f64)),
                ])
            })
            .collect(),
    );
    Json::obj(vec![("stats", Json::obj(stats_fields(s, workers)))]).dump()
}

/// Encode the `{"stats":"full"}` reply: every plain-stats field plus the
/// four latency-segment histogram documents (total / queue-wait /
/// execute / write, each with occupied buckets and p50/p95/p99),
/// per-worker latency percentiles, and the sorted
/// [`crate::obs`] registry counters (cache hit/miss families, kernel
/// phase totals, …).
pub fn encode_stats_full(s: &MetricsSnapshot) -> String {
    let workers = Json::Arr(
        s.workers
            .iter()
            .map(|w| {
                Json::obj(vec![
                    ("requests", Json::Num(w.requests as f64)),
                    ("batches", Json::Num(w.batches as f64)),
                    ("batched_points", Json::Num(w.batched_points as f64)),
                    ("errors", Json::Num(w.errors as f64)),
                    ("p50_latency_us", Json::Num(w.p50_latency_us)),
                    ("p99_latency_us", Json::Num(w.p99_latency_us)),
                    ("max_latency_us", Json::Num(w.max_latency_us)),
                ])
            })
            .collect(),
    );
    let mut fields = stats_fields(s, workers);
    fields.push(("latency", s.latency.to_json()));
    fields.push(("queue_wait", s.queue_wait.to_json()));
    fields.push(("execute", s.execute.to_json()));
    fields.push(("write", s.write.to_json()));
    let counters = Json::Obj(
        crate::obs::registry()
            .counters()
            .into_iter()
            .map(|(k, v)| (k, Json::Num(v as f64)))
            .collect(),
    );
    fields.push(("counters", counters));
    Json::obj(vec![("stats", Json::obj(fields))]).dump()
}

/// Decode an evaluation response (client side).
pub fn parse_channels(line: &str) -> Result<Vec<Vec<f64>>, String> {
    let v = Json::parse(line).map_err(|e| e.to_string())?;
    if let Some(err) = v.get("error").and_then(Json::as_str) {
        return Err(err.to_string());
    }
    v.get("channels")
        .and_then(Json::as_arr)
        .ok_or_else(|| "missing 'channels'".to_string())?
        .iter()
        .map(|c| c.as_f64_vec().ok_or_else(|| "bad channel".to_string()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_eval_request() {
        let r = parse_request(r#"{"points": [0.5, -1.0]}"#).unwrap();
        assert_eq!(
            r,
            WireRequest::Eval { points: vec![0.5, -1.0], activation: None }
        );
    }

    #[test]
    fn parses_activation_field() {
        let r = parse_request(r#"{"points": [0.5], "activation": "sin"}"#).unwrap();
        assert_eq!(
            r,
            WireRequest::Eval {
                points: vec![0.5],
                activation: Some(ActivationKind::Sine)
            }
        );
        assert!(parse_request(r#"{"points": [0.5], "activation": "relu"}"#).is_err());
        assert!(parse_request(r#"{"points": [0.5], "activation": 3}"#).is_err());
    }

    #[test]
    fn encode_request_roundtrips() {
        for activation in [None, Some(ActivationKind::Gelu)] {
            let line = encode_request(&[0.25, -0.5], activation);
            let parsed = parse_request(&line).unwrap();
            assert_eq!(
                parsed,
                WireRequest::Eval { points: vec![0.25, -0.5], activation }
            );
        }
        // Wire compatibility: no field at all unless requested.
        assert!(!encode_request(&[1.0], None).contains("activation"));
    }

    #[test]
    fn parses_operator_request() {
        let r = parse_request(r#"{"points_nd": [[0.1, 0.2], [0.3, 0.4]], "operator": "d20+d02"}"#)
            .unwrap();
        assert_eq!(
            r,
            WireRequest::EvalOperator {
                points: vec![vec![0.1, 0.2], vec![0.3, 0.4]],
                operator: "d20+d02".to_string(),
                activation: None
            }
        );
        // Missing operator, empty rows, ragged arity: rejected.
        assert!(parse_request(r#"{"points_nd": [[0.1, 0.2]]}"#).is_err());
        assert!(parse_request(r#"{"points_nd": [], "operator": "d20"}"#).is_err());
        assert!(parse_request(r#"{"points_nd": [[0.1], [0.2, 0.3]], "operator": "d2"}"#).is_err());
        assert!(parse_request(r#"{"points_nd": [0.1], "operator": "d2"}"#).is_err());
    }

    #[test]
    fn operator_request_roundtrips() {
        let pts = vec![vec![0.25, -0.5], vec![0.5, 0.75]];
        for activation in [None, Some(ActivationKind::Sine)] {
            let line = encode_operator_request(&pts, "heat2d", activation);
            let parsed = parse_request(&line).unwrap();
            assert_eq!(
                parsed,
                WireRequest::EvalOperator {
                    points: pts.clone(),
                    operator: "heat2d".to_string(),
                    activation
                }
            );
        }
        // Scalar requests never grow the new fields, and the activation
        // field stays absent unless requested.
        assert!(!encode_request(&[1.0], None).contains("points_nd"));
        assert!(!encode_operator_request(&pts, "heat2d", None).contains("activation"));
        assert!(
            parse_request(r#"{"points_nd": [[0.1, 0.2]], "operator": "d20", "activation": "relu"}"#)
                .is_err()
        );
    }

    #[test]
    fn operator_values_roundtrip() {
        let line = encode_operator_values(&[1.0, 2.0], &[-0.5, 0.25]);
        assert_eq!(
            parse_operator_values(&line).unwrap(),
            (vec![1.0, 2.0], vec![-0.5, 0.25])
        );
        assert!(parse_operator_values(&encode_error("nope")).is_err());
    }

    #[test]
    fn parses_stats_request() {
        assert_eq!(parse_request(r#"{"cmd": "stats"}"#).unwrap(), WireRequest::Stats);
        assert!(parse_request(r#"{"cmd": "bogus"}"#).is_err());
    }

    #[test]
    fn rejects_bad_requests() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"points": []}"#).is_err());
        assert!(parse_request(r#"{"points": ["a"]}"#).is_err());
        assert!(parse_request(r#"{}"#).is_err());
    }

    #[test]
    fn channels_roundtrip() {
        let channels = vec![vec![1.0, 2.0], vec![-0.5, 0.25]];
        let line = encode_channels(&channels);
        assert_eq!(parse_channels(&line).unwrap(), channels);
    }

    #[test]
    fn error_roundtrip() {
        let line = encode_error("boom");
        assert_eq!(parse_channels(&line).unwrap_err(), "boom");
    }

    /// A populated snapshot for the stats-encoding tests (driving real
    /// `Metrics` instead of a struct literal keeps the test in sync with
    /// the snapshot's derived histogram fields).
    fn sample_snapshot() -> MetricsSnapshot {
        let m = super::super::metrics::Metrics::with_workers(1);
        m.record_request(0, 5);
        m.record_request(0, 5);
        m.record_request(0, 5);
        m.record_batch(0, 10);
        m.record_batch(0, 5);
        m.record_latency_on(0, 12_000);
        m.record_latency_on(0, 20_000);
        m.record_segments(3_000, 9_000);
        m.record_write(700);
        m.record_shed();
        for _ in 0..5 {
            m.record_plan_lookup(true);
        }
        m.record_plan_lookup(false);
        m.record_plan_lookup(false);
        m.snapshot()
    }

    #[test]
    fn stats_encode_mentions_fields() {
        let line = encode_stats(&sample_snapshot());
        assert!(line.contains("\"requests\":3"));
        assert!(line.contains("mean_batch_fill"));
        assert!(line.contains("\"workers\""));
        assert!(line.contains("\"batched_points\":10"));
        assert!(line.contains("\"shed\":1"));
        assert!(line.contains("\"plan_hits\":5"));
        assert!(line.contains("\"plan_misses\":2"));
        assert!(line.contains("\"p50_latency_us\""));
        assert!(line.contains("\"cache\""));
        assert!(line.contains("\"operator_evictions\""));
        assert!(line.contains("\"operator_requests\""));
        // The plain reply stays compact: no bucket documents.
        assert!(!line.contains("\"buckets\""));
    }

    #[test]
    fn parses_stats_full_request() {
        assert_eq!(
            parse_request(r#"{"stats": "full"}"#).unwrap(),
            WireRequest::StatsFull
        );
        assert!(parse_request(r#"{"stats": "summary"}"#).is_err());
        assert!(parse_request(r#"{"stats": 1}"#).is_err());
    }

    #[test]
    fn stats_full_is_a_parseable_superset() {
        let s = sample_snapshot();
        let full = encode_stats_full(&s);
        let doc = Json::parse(&full).unwrap();
        let stats = doc.get("stats").expect("stats object");
        // Every plain field is present…
        for key in ["requests", "shed", "plan_hits", "mean_batch_fill", "cache"] {
            assert!(stats.get(key).is_some(), "missing {key}");
        }
        // …plus the four segment histograms with self-consistent counts
        // and percentiles that match the snapshot's quoted values.
        for key in ["latency", "queue_wait", "execute", "write"] {
            let h = stats.get(key).unwrap_or_else(|| panic!("missing {key}"));
            assert!(h.get("count").and_then(Json::as_f64).is_some(), "{key}");
            assert!(h.get("p99").and_then(Json::as_f64).is_some(), "{key}");
        }
        let p50_ns = stats.get("latency").unwrap().get("p50").unwrap().as_f64().unwrap();
        let p50_us = stats.get("p50_latency_us").unwrap().as_f64().unwrap();
        assert!((p50_ns / 1e3 - p50_us).abs() < 1e-9);
        // Worker rows carry their percentiles.
        let workers = stats.get("workers").and_then(Json::as_arr).unwrap();
        assert!(workers[0].get("p99_latency_us").is_some());
        assert!(stats.get("counters").is_some());
    }

    #[test]
    fn shed_response_roundtrips() {
        let line = encode_shed(50);
        let (msg, retry) = parse_error(&line).unwrap();
        assert_eq!(msg, "overloaded");
        assert_eq!(retry, Some(50));
        // Plain errors carry no retry hint; non-errors parse to None.
        assert_eq!(parse_error(&encode_error("boom")), Some(("boom".into(), None)));
        assert_eq!(parse_error(&encode_channels(&[vec![1.0]])), None);
        // A shed response fails the typed decoders with the message.
        assert_eq!(parse_channels(&line).unwrap_err(), "overloaded");
        assert_eq!(parse_operator_values(&line).unwrap_err(), "overloaded");
    }

    // ---------------------------------------------- transport framing

    #[test]
    fn frame_roundtrips_and_pipelines() {
        let mut buf = Vec::new();
        write_frame(&mut buf, r#"{"cmd":"stats"}"#).unwrap();
        write_frame(&mut buf, r#"{"points":[1.0]}"#).unwrap();
        let mut r = std::io::BufReader::new(buf.as_slice());
        assert_eq!(
            read_message(&mut r).unwrap(),
            Incoming::Frame(r#"{"cmd":"stats"}"#.to_string())
        );
        assert_eq!(
            read_message(&mut r).unwrap(),
            Incoming::Frame(r#"{"points":[1.0]}"#.to_string())
        );
        assert_eq!(read_message(&mut r).unwrap(), Incoming::Eof);
    }

    #[test]
    fn frames_and_lines_interleave_on_one_stream() {
        let mut buf = Vec::new();
        buf.extend_from_slice(b"{\"cmd\":\"stats\"}\n");
        write_frame(&mut buf, r#"{"points":[0.5]}"#).unwrap();
        buf.extend_from_slice(b"\n  {\"a\":1}\n");
        let mut r = std::io::BufReader::new(buf.as_slice());
        assert_eq!(
            read_message(&mut r).unwrap(),
            Incoming::Line("{\"cmd\":\"stats\"}".to_string())
        );
        assert_eq!(
            read_message(&mut r).unwrap(),
            Incoming::Frame(r#"{"points":[0.5]}"#.to_string())
        );
        assert_eq!(
            read_message(&mut r).unwrap(),
            Incoming::Line("{\"a\":1}".to_string())
        );
        assert_eq!(read_message(&mut r).unwrap(), Incoming::Eof);
    }

    #[test]
    fn unterminated_final_line_is_served() {
        let mut r = std::io::BufReader::new(&b"{\"cmd\":\"stats\"}"[..]);
        assert_eq!(
            read_message(&mut r).unwrap(),
            Incoming::Line("{\"cmd\":\"stats\"}".to_string())
        );
        assert_eq!(read_message(&mut r).unwrap(), Incoming::Eof);
    }

    #[test]
    fn oversized_frame_declaration_is_rejected_unread() {
        let mut buf = vec![FRAME_MAGIC];
        buf.extend_from_slice(&(MAX_FRAME_LEN as u32 + 1).to_be_bytes());
        let mut r = std::io::BufReader::new(buf.as_slice());
        match read_message(&mut r) {
            Err(ReadError::TooLarge { framed: true, len }) => {
                assert_eq!(len, MAX_FRAME_LEN + 1)
            }
            other => panic!("expected TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn truncated_frame_is_an_io_error() {
        let mut buf = vec![FRAME_MAGIC];
        buf.extend_from_slice(&100u32.to_be_bytes());
        buf.extend_from_slice(b"only a few bytes");
        let mut r = std::io::BufReader::new(buf.as_slice());
        assert!(matches!(read_message(&mut r), Err(ReadError::Io(_))));
        // So is a frame cut inside the length header.
        let mut r = std::io::BufReader::new(&[FRAME_MAGIC, 0, 0][..]);
        assert!(matches!(read_message(&mut r), Err(ReadError::Io(_))));
    }

    #[test]
    fn non_utf8_frame_payload_is_rejected() {
        let mut buf = vec![FRAME_MAGIC];
        buf.extend_from_slice(&2u32.to_be_bytes());
        buf.extend_from_slice(&[0xff, 0xfe]);
        let mut r = std::io::BufReader::new(buf.as_slice());
        assert!(matches!(read_message(&mut r), Err(ReadError::BadUtf8)));
    }

    // ------------------------------- property-style randomized round-trips

    /// A deterministic value grid covering the numeric shapes the JSON
    /// layer must preserve exactly: signs, zero, subnormal-ish, large
    /// magnitudes, and long fractions.
    fn value_grid(seed: u64, len: usize) -> Vec<f64> {
        let mut rng = crate::util::prng::Prng::seeded(seed);
        let specials = [0.0, -0.0, 1.0, -1.0, 1e-12, -1e300, 1e300, 0.1 + 0.2];
        (0..len)
            .map(|i| {
                if i < specials.len() {
                    specials[i]
                } else {
                    rng.uniform_in(-0.5, 0.5) * 10f64.powi(rng.below(13) as i32 - 6)
                }
            })
            .collect()
    }

    /// Every request variant survives encode → parse across a
    /// randomized value grid, through both transports.
    #[test]
    fn randomized_requests_roundtrip_exactly() {
        let activations: Vec<Option<ActivationKind>> = std::iter::once(None)
            .chain(ActivationKind::ALL.iter().map(|&k| Some(k)))
            .collect();
        for trial in 0..32 {
            let vals = value_grid(1000 + trial, 9 + (trial as usize % 7));
            let activation = activations[trial as usize % activations.len()];

            let line = encode_request(&vals, activation);
            assert_eq!(
                parse_request(&line).unwrap(),
                WireRequest::Eval { points: vals.clone(), activation },
                "eval trial {trial}"
            );

            let dim = 1 + (trial as usize % 3);
            let rows: Vec<Vec<f64>> = vals.chunks(dim).filter(|c| c.len() == dim).map(<[f64]>::to_vec).collect();
            let spec = ["d20+d02", "heat2d", "d10-0.1*d02", "d10+u*d01+d03"][trial as usize % 4];
            let line = encode_operator_request(&rows, spec, activation);
            assert_eq!(
                parse_request(&line).unwrap(),
                WireRequest::EvalOperator {
                    points: rows,
                    operator: spec.to_string(),
                    activation
                },
                "operator trial {trial}"
            );

            // Both transports deliver the identical payload.
            let mut buf = Vec::new();
            write_frame(&mut buf, &line).unwrap();
            let mut r = std::io::BufReader::new(buf.as_slice());
            assert_eq!(read_message(&mut r).unwrap(), Incoming::Frame(line.clone()));
            let terminated = [line.as_bytes(), b"\n"].concat();
            let mut r = std::io::BufReader::new(terminated.as_slice());
            assert_eq!(read_message(&mut r).unwrap(), Incoming::Line(line));
        }
    }

    /// Every response variant survives encode → parse across the same
    /// grid.
    #[test]
    fn randomized_responses_roundtrip_exactly() {
        for trial in 0..32 {
            let vals = value_grid(2000 + trial, 8);
            let channels: Vec<Vec<f64>> = vals.chunks(4).map(<[f64]>::to_vec).collect();
            assert_eq!(
                parse_channels(&encode_channels(&channels)).unwrap(),
                channels,
                "channels trial {trial}"
            );
            let (u, lu) = (vals[..4].to_vec(), vals[4..].to_vec());
            assert_eq!(
                parse_operator_values(&encode_operator_values(&u, &lu)).unwrap(),
                (u, lu),
                "operator values trial {trial}"
            );
            let msg = format!("error #{trial} with \"quotes\" and \\ slashes");
            assert_eq!(parse_channels(&encode_error(&msg)).unwrap_err(), msg);
            assert_eq!(parse_error(&encode_error(&msg)), Some((msg, None)));
            let retry = 1 + trial * 7;
            assert_eq!(
                parse_error(&encode_shed(retry)),
                Some(("overloaded".to_string(), Some(retry)))
            );
        }
    }

    /// Malformed and adversarial inputs: every decoder returns a clean
    /// error (or `None`), never panics.
    #[test]
    fn malformed_inputs_never_panic() {
        let cases = [
            "",
            "   ",
            "not json",
            "{",
            "}{",
            "[]",
            "42",
            "\"str\"",
            "{\"points\": {}}",
            "{\"points\": [1.0, \"x\"]}",
            "{\"points\": [1.0], \"activation\": []}",
            "{\"points_nd\": [[1.0], []], \"operator\": \"d2\"}",
            "{\"points_nd\": \"x\", \"operator\": \"d2\"}",
            "{\"points_nd\": [[1.0]], \"operator\": 3}",
            "{\"channels\": 7}",
            "{\"channels\": [7]}",
            "{\"u\": \"x\", \"operator\": []}",
            "{\"cmd\": 12}",
            "{\"error\": 5}",
            "\u{0}\u{1}\u{2}",
        ];
        for c in cases {
            let _ = parse_request(c);
            let _ = parse_channels(c);
            let _ = parse_operator_values(c);
            let _ = parse_error(c);
        }
        // Truncations of a valid request must parse or error, never panic.
        let full = encode_operator_request(
            &[vec![0.1, 0.2]],
            "d20+d02",
            Some(ActivationKind::Gelu),
        );
        for cut in 0..full.len() {
            let _ = parse_request(&full[..cut]);
        }
    }
}
