//! Lock-free service metrics (atomic counters, snapshot-on-read).
//!
//! A service built with [`Metrics::with_workers`] additionally tracks one
//! [`WorkerCounters`] row per batcher worker, so the sharded pool can
//! report how traffic distributes across activation shards.

use std::sync::atomic::{AtomicU64, Ordering};

/// Shared counters updated by the batcher loop and connection threads.
#[derive(Default, Debug)]
pub struct Metrics {
    /// Requests answered.
    pub requests: AtomicU64,
    /// Points requested.
    pub points: AtomicU64,
    /// Backend batches executed.
    pub batches: AtomicU64,
    /// Points executed inside batches.
    pub batched_points: AtomicU64,
    /// Requests answered with an error.
    pub errors: AtomicU64,
    /// Total request latency in nanoseconds (enqueue → response).
    pub latency_ns: AtomicU64,
    /// Max single-request latency in nanoseconds.
    pub latency_max_ns: AtomicU64,
    /// Requests shed with an `overloaded` response because the target
    /// worker's ingress queue was full.
    pub shed: AtomicU64,
    /// Serving-cache lookups (plans, engines, operators) that hit.
    pub plan_hits: AtomicU64,
    /// Serving-cache lookups that missed and compiled.
    pub plan_misses: AtomicU64,
    /// Per-worker counters (empty for metrics built with `default()`,
    /// e.g. in unit tests that drive `serve_batch` directly).
    workers: Vec<WorkerCounters>,
}

/// Counters attributed to one batcher worker of the pool.
#[derive(Default, Debug)]
pub struct WorkerCounters {
    /// Requests answered by this worker.
    pub requests: AtomicU64,
    /// Backend batches this worker executed.
    pub batches: AtomicU64,
    /// Points this worker executed inside batches.
    pub batched_points: AtomicU64,
    /// Requests this worker answered with an error.
    pub errors: AtomicU64,
}

/// A point-in-time copy of the counters with derived ratios.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricsSnapshot {
    /// Requests answered.
    pub requests: u64,
    /// Points requested.
    pub points: u64,
    /// Backend batches executed.
    pub batches: u64,
    /// Points executed inside batches.
    pub batched_points: u64,
    /// Requests answered with an error.
    pub errors: u64,
    /// Requests shed with an `overloaded` response.
    pub shed: u64,
    /// Serving-cache lookups that hit.
    pub plan_hits: u64,
    /// Serving-cache lookups that missed and compiled.
    pub plan_misses: u64,
    /// Mean enqueue-to-response latency in microseconds.
    pub mean_latency_us: f64,
    /// Max enqueue-to-response latency in microseconds.
    pub max_latency_us: f64,
    /// Average number of requests coalesced per backend call.
    pub mean_batch_fill: f64,
    /// Per-worker counter snapshots, indexed by worker id (empty when the
    /// metrics were not built with [`Metrics::with_workers`]).
    pub workers: Vec<WorkerSnapshot>,
}

/// Snapshot of one worker's counters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkerSnapshot {
    /// Requests answered by this worker.
    pub requests: u64,
    /// Backend batches this worker executed.
    pub batches: u64,
    /// Points this worker executed inside batches.
    pub batched_points: u64,
    /// Requests this worker answered with an error.
    pub errors: u64,
}

impl Metrics {
    /// Metrics with `n` per-worker counter rows.
    pub fn with_workers(n: usize) -> Metrics {
        Metrics {
            workers: (0..n).map(|_| WorkerCounters::default()).collect(),
            ..Metrics::default()
        }
    }

    /// Number of per-worker counter rows.
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Count one answered request of `n_points` from `worker`.
    pub fn record_request(&self, worker: usize, n_points: usize) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.points.fetch_add(n_points as u64, Ordering::Relaxed);
        if let Some(w) = self.workers.get(worker) {
            w.requests.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Count one executed backend batch of `n_points` on `worker`.
    pub fn record_batch(&self, worker: usize, n_points: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_points.fetch_add(n_points as u64, Ordering::Relaxed);
        if let Some(w) = self.workers.get(worker) {
            w.batches.fetch_add(1, Ordering::Relaxed);
            w.batched_points.fetch_add(n_points as u64, Ordering::Relaxed);
        }
    }

    /// Count one errored request on `worker`.
    pub fn record_error(&self, worker: usize) {
        self.errors.fetch_add(1, Ordering::Relaxed);
        if let Some(w) = self.workers.get(worker) {
            w.errors.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record one request's enqueue-to-response latency.
    pub fn record_latency(&self, ns: u64) {
        self.latency_ns.fetch_add(ns, Ordering::Relaxed);
        self.latency_max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    /// Count one request shed with an `overloaded` response.
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one serving-cache lookup (plan/engine/operator).
    pub fn record_plan_lookup(&self, hit: bool) {
        if hit {
            self.plan_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.plan_misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// A point-in-time copy of all counters with derived ratios.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let requests = self.requests.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        MetricsSnapshot {
            requests,
            points: self.points.load(Ordering::Relaxed),
            batches,
            batched_points: self.batched_points.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            plan_hits: self.plan_hits.load(Ordering::Relaxed),
            plan_misses: self.plan_misses.load(Ordering::Relaxed),
            mean_latency_us: if requests > 0 {
                self.latency_ns.load(Ordering::Relaxed) as f64 / requests as f64 / 1e3
            } else {
                0.0
            },
            max_latency_us: self.latency_max_ns.load(Ordering::Relaxed) as f64 / 1e3,
            mean_batch_fill: if batches > 0 {
                requests as f64 / batches as f64
            } else {
                0.0
            },
            workers: self
                .workers
                .iter()
                .map(|w| WorkerSnapshot {
                    requests: w.requests.load(Ordering::Relaxed),
                    batches: w.batches.load(Ordering::Relaxed),
                    batched_points: w.batched_points.load(Ordering::Relaxed),
                    errors: w.errors.load(Ordering::Relaxed),
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_counters() {
        let m = Metrics::default();
        m.record_request(0, 10);
        m.record_request(0, 5);
        m.record_batch(0, 15);
        m.record_latency(2_000);
        m.record_latency(4_000);
        m.record_shed();
        m.record_plan_lookup(true);
        m.record_plan_lookup(true);
        m.record_plan_lookup(false);
        let s = m.snapshot();
        assert_eq!(s.shed, 1);
        assert_eq!(s.plan_hits, 2);
        assert_eq!(s.plan_misses, 1);
        assert_eq!(s.requests, 2);
        assert_eq!(s.points, 15);
        assert_eq!(s.batches, 1);
        assert_eq!(s.mean_batch_fill, 2.0);
        assert_eq!(s.mean_latency_us, 3.0);
        assert_eq!(s.max_latency_us, 4.0);
        assert_eq!(s.errors, 0);
        // Default metrics track no per-worker rows; out-of-range worker
        // ids are silently absorbed by the totals.
        assert!(s.workers.is_empty());
    }

    #[test]
    fn empty_snapshot_has_no_nans() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.mean_latency_us, 0.0);
        assert_eq!(s.mean_batch_fill, 0.0);
    }

    #[test]
    fn per_worker_counters_attribute_to_the_right_row() {
        let m = Metrics::with_workers(3);
        assert_eq!(m.n_workers(), 3);
        m.record_request(0, 2);
        m.record_batch(0, 2);
        m.record_request(2, 7);
        m.record_batch(2, 4);
        m.record_batch(2, 3);
        m.record_error(2);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.batches, 3);
        assert_eq!(s.workers.len(), 3);
        assert_eq!(s.workers[0].requests, 1);
        assert_eq!(s.workers[0].batches, 1);
        assert_eq!(s.workers[1].requests, 0);
        assert_eq!(s.workers[2].requests, 1);
        assert_eq!(s.workers[2].batches, 2);
        assert_eq!(s.workers[2].batched_points, 7);
        assert_eq!(s.workers[2].errors, 1);
        // The global rows are the sum of the per-worker rows.
        let sum: u64 = s.workers.iter().map(|w| w.batches).sum();
        assert_eq!(sum, s.batches);
    }
}
