//! Lock-free service metrics (atomic counters, snapshot-on-read).

use std::sync::atomic::{AtomicU64, Ordering};

/// Shared counters updated by the batcher loop and connection threads.
#[derive(Default, Debug)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub points: AtomicU64,
    pub batches: AtomicU64,
    pub batched_points: AtomicU64,
    pub errors: AtomicU64,
    /// Total request latency in nanoseconds (enqueue → response).
    pub latency_ns: AtomicU64,
    /// Max single-request latency in nanoseconds.
    pub latency_max_ns: AtomicU64,
}

/// A point-in-time copy of the counters with derived ratios.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub points: u64,
    pub batches: u64,
    pub batched_points: u64,
    pub errors: u64,
    pub mean_latency_us: f64,
    pub max_latency_us: f64,
    /// Average number of requests coalesced per backend call.
    pub mean_batch_fill: f64,
}

impl Metrics {
    pub fn record_request(&self, n_points: usize) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.points.fetch_add(n_points as u64, Ordering::Relaxed);
    }

    pub fn record_batch(&self, n_points: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_points.fetch_add(n_points as u64, Ordering::Relaxed);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_latency(&self, ns: u64) {
        self.latency_ns.fetch_add(ns, Ordering::Relaxed);
        self.latency_max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let requests = self.requests.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        MetricsSnapshot {
            requests,
            points: self.points.load(Ordering::Relaxed),
            batches,
            batched_points: self.batched_points.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            mean_latency_us: if requests > 0 {
                self.latency_ns.load(Ordering::Relaxed) as f64 / requests as f64 / 1e3
            } else {
                0.0
            },
            max_latency_us: self.latency_max_ns.load(Ordering::Relaxed) as f64 / 1e3,
            mean_batch_fill: if batches > 0 {
                requests as f64 / batches as f64
            } else {
                0.0
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_counters() {
        let m = Metrics::default();
        m.record_request(10);
        m.record_request(5);
        m.record_batch(15);
        m.record_latency(2_000);
        m.record_latency(4_000);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.points, 15);
        assert_eq!(s.batches, 1);
        assert_eq!(s.mean_batch_fill, 2.0);
        assert_eq!(s.mean_latency_us, 3.0);
        assert_eq!(s.max_latency_us, 4.0);
        assert_eq!(s.errors, 0);
    }

    #[test]
    fn empty_snapshot_has_no_nans() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.mean_latency_us, 0.0);
        assert_eq!(s.mean_batch_fill, 0.0);
    }
}
