//! Lock-free service metrics (atomic counters, snapshot-on-read).
//!
//! A service built with [`Metrics::with_workers`] additionally tracks one
//! [`WorkerCounters`] row per batcher worker, so the sharded pool can
//! report how traffic distributes across activation shards.
//!
//! Latency is tracked as [`crate::obs::Histogram`]s — the crate's single
//! definition of p50/p95/p99 (`bench serve` and the `{"stats":"full"}`
//! wire reply quote the same bucketing) — split into the request's
//! pipeline segments: total enqueue→response latency, queue wait
//! (enqueue→batch start), execute (backend batch evaluation), and the
//! response-write segment on the connection's writer thread. Histograms
//! carry exact sums and maxima, so the mean/max fields of
//! [`MetricsSnapshot`] are exact, not bucketed.

use crate::obs::{Histogram, HistogramSnapshot};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Shared counters updated by the batcher loop and connection threads.
#[derive(Default, Debug)]
pub struct Metrics {
    /// Requests answered.
    pub requests: AtomicU64,
    /// Points requested.
    pub points: AtomicU64,
    /// Backend batches executed.
    pub batches: AtomicU64,
    /// Points executed inside batches.
    pub batched_points: AtomicU64,
    /// Requests answered with an error.
    pub errors: AtomicU64,
    /// Enqueue-to-response latency histogram (nanoseconds).
    pub latency: Arc<Histogram>,
    /// Enqueue-to-batch-start (queue wait) histogram (nanoseconds).
    pub queue_wait: Arc<Histogram>,
    /// Backend batch-execution histogram (nanoseconds).
    pub execute: Arc<Histogram>,
    /// Response-write segment histogram (nanoseconds, writer thread).
    pub write: Arc<Histogram>,
    /// Requests shed with an `overloaded` response because the target
    /// worker's ingress queue was full.
    pub shed: AtomicU64,
    /// Serving-cache lookups (plans, engines, operators) that hit.
    pub plan_hits: AtomicU64,
    /// Serving-cache lookups that missed and compiled.
    pub plan_misses: AtomicU64,
    /// Per-worker counters (empty for metrics built with `default()`,
    /// e.g. in unit tests that drive `serve_batch` directly).
    workers: Vec<WorkerCounters>,
}

/// Counters attributed to one batcher worker of the pool.
#[derive(Default, Debug)]
pub struct WorkerCounters {
    /// Requests answered by this worker.
    pub requests: AtomicU64,
    /// Backend batches this worker executed.
    pub batches: AtomicU64,
    /// Points this worker executed inside batches.
    pub batched_points: AtomicU64,
    /// Requests this worker answered with an error.
    pub errors: AtomicU64,
    /// This worker's enqueue-to-response latency histogram (ns).
    pub latency: Histogram,
}

/// A point-in-time copy of the counters with derived ratios.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricsSnapshot {
    /// Requests answered.
    pub requests: u64,
    /// Points requested.
    pub points: u64,
    /// Backend batches executed.
    pub batches: u64,
    /// Points executed inside batches.
    pub batched_points: u64,
    /// Requests answered with an error.
    pub errors: u64,
    /// Requests shed with an `overloaded` response.
    pub shed: u64,
    /// Serving-cache lookups that hit.
    pub plan_hits: u64,
    /// Serving-cache lookups that missed and compiled.
    pub plan_misses: u64,
    /// Mean enqueue-to-response latency in microseconds (exact).
    pub mean_latency_us: f64,
    /// Max enqueue-to-response latency in microseconds (exact).
    pub max_latency_us: f64,
    /// Median enqueue-to-response latency in microseconds (bucketed).
    pub p50_latency_us: f64,
    /// 95th-percentile latency in microseconds (bucketed).
    pub p95_latency_us: f64,
    /// 99th-percentile latency in microseconds (bucketed).
    pub p99_latency_us: f64,
    /// Full enqueue-to-response latency histogram (nanoseconds).
    pub latency: HistogramSnapshot,
    /// Queue-wait segment histogram (nanoseconds).
    pub queue_wait: HistogramSnapshot,
    /// Execute segment histogram (nanoseconds).
    pub execute: HistogramSnapshot,
    /// Response-write segment histogram (nanoseconds).
    pub write: HistogramSnapshot,
    /// Average number of requests coalesced per backend call.
    pub mean_batch_fill: f64,
    /// Per-worker counter snapshots, indexed by worker id (empty when the
    /// metrics were not built with [`Metrics::with_workers`]).
    pub workers: Vec<WorkerSnapshot>,
}

/// Snapshot of one worker's counters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WorkerSnapshot {
    /// Requests answered by this worker.
    pub requests: u64,
    /// Backend batches this worker executed.
    pub batches: u64,
    /// Points this worker executed inside batches.
    pub batched_points: u64,
    /// Requests this worker answered with an error.
    pub errors: u64,
    /// This worker's median latency in microseconds (bucketed; 0 when
    /// the worker answered nothing).
    pub p50_latency_us: f64,
    /// This worker's 99th-percentile latency in microseconds (bucketed).
    pub p99_latency_us: f64,
    /// This worker's max latency in microseconds (exact).
    pub max_latency_us: f64,
}

fn us(ns: f64) -> f64 {
    ns / 1e3
}

impl Metrics {
    /// Metrics with `n` per-worker counter rows.
    pub fn with_workers(n: usize) -> Metrics {
        Metrics {
            workers: (0..n).map(|_| WorkerCounters::default()).collect(),
            ..Metrics::default()
        }
    }

    /// Number of per-worker counter rows.
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Count one answered request of `n_points` from `worker`.
    pub fn record_request(&self, worker: usize, n_points: usize) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.points.fetch_add(n_points as u64, Ordering::Relaxed);
        if let Some(w) = self.workers.get(worker) {
            w.requests.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Count one executed backend batch of `n_points` on `worker`.
    pub fn record_batch(&self, worker: usize, n_points: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_points.fetch_add(n_points as u64, Ordering::Relaxed);
        if let Some(w) = self.workers.get(worker) {
            w.batches.fetch_add(1, Ordering::Relaxed);
            w.batched_points.fetch_add(n_points as u64, Ordering::Relaxed);
        }
    }

    /// Count one errored request on `worker`.
    pub fn record_error(&self, worker: usize) {
        self.errors.fetch_add(1, Ordering::Relaxed);
        if let Some(w) = self.workers.get(worker) {
            w.errors.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record one request's enqueue-to-response latency (global
    /// histogram only; use [`record_latency_on`](Self::record_latency_on)
    /// from the pool to attribute it to a worker too).
    pub fn record_latency(&self, ns: u64) {
        self.latency.record(ns);
    }

    /// Record one request's enqueue-to-response latency against the
    /// global histogram *and* `worker`'s row.
    pub fn record_latency_on(&self, worker: usize, ns: u64) {
        self.latency.record(ns);
        if let Some(w) = self.workers.get(worker) {
            w.latency.record(ns);
        }
    }

    /// Record one request's queue-wait and execute segments (the batcher
    /// splits enqueue→response into wait-in-queue and backend-batch
    /// time).
    pub fn record_segments(&self, queue_ns: u64, exec_ns: u64) {
        self.queue_wait.record(queue_ns);
        self.execute.record(exec_ns);
    }

    /// Record one response's write segment on the connection writer.
    pub fn record_write(&self, ns: u64) {
        self.write.record(ns);
    }

    /// Count one request shed with an `overloaded` response.
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one serving-cache lookup (plan/engine/operator).
    pub fn record_plan_lookup(&self, hit: bool) {
        if hit {
            self.plan_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.plan_misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// A point-in-time copy of all counters with derived ratios.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let requests = self.requests.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        let latency = self.latency.snapshot();
        MetricsSnapshot {
            requests,
            points: self.points.load(Ordering::Relaxed),
            batches,
            batched_points: self.batched_points.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            plan_hits: self.plan_hits.load(Ordering::Relaxed),
            plan_misses: self.plan_misses.load(Ordering::Relaxed),
            mean_latency_us: if latency.count > 0 {
                us(latency.mean())
            } else {
                0.0
            },
            max_latency_us: us(latency.max as f64),
            p50_latency_us: us(latency.percentile(0.50).unwrap_or(0.0)),
            p95_latency_us: us(latency.percentile(0.95).unwrap_or(0.0)),
            p99_latency_us: us(latency.percentile(0.99).unwrap_or(0.0)),
            latency,
            queue_wait: self.queue_wait.snapshot(),
            execute: self.execute.snapshot(),
            write: self.write.snapshot(),
            mean_batch_fill: if batches > 0 {
                requests as f64 / batches as f64
            } else {
                0.0
            },
            workers: self
                .workers
                .iter()
                .map(|w| {
                    let lat = w.latency.snapshot();
                    WorkerSnapshot {
                        requests: w.requests.load(Ordering::Relaxed),
                        batches: w.batches.load(Ordering::Relaxed),
                        batched_points: w.batched_points.load(Ordering::Relaxed),
                        errors: w.errors.load(Ordering::Relaxed),
                        p50_latency_us: us(lat.percentile(0.50).unwrap_or(0.0)),
                        p99_latency_us: us(lat.percentile(0.99).unwrap_or(0.0)),
                        max_latency_us: us(lat.max as f64),
                    }
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_counters() {
        let m = Metrics::default();
        m.record_request(0, 10);
        m.record_request(0, 5);
        m.record_batch(0, 15);
        m.record_latency(2_000);
        m.record_latency(4_000);
        m.record_shed();
        m.record_plan_lookup(true);
        m.record_plan_lookup(true);
        m.record_plan_lookup(false);
        let s = m.snapshot();
        assert_eq!(s.shed, 1);
        assert_eq!(s.plan_hits, 2);
        assert_eq!(s.plan_misses, 1);
        assert_eq!(s.requests, 2);
        assert_eq!(s.points, 15);
        assert_eq!(s.batches, 1);
        assert_eq!(s.mean_batch_fill, 2.0);
        // Mean and max come from the histogram's exact sum/max.
        assert_eq!(s.mean_latency_us, 3.0);
        assert_eq!(s.max_latency_us, 4.0);
        assert_eq!(s.latency.count, 2);
        // Percentiles are bucketed: within ±10% of the true order stats.
        assert!((s.p50_latency_us - 2.0).abs() / 2.0 < 0.15, "{}", s.p50_latency_us);
        assert!((s.p99_latency_us - 4.0).abs() / 4.0 < 0.15, "{}", s.p99_latency_us);
        assert_eq!(s.errors, 0);
        // Default metrics track no per-worker rows; out-of-range worker
        // ids are silently absorbed by the totals.
        assert!(s.workers.is_empty());
    }

    #[test]
    fn empty_snapshot_has_no_nans() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.mean_latency_us, 0.0);
        assert_eq!(s.mean_batch_fill, 0.0);
        assert_eq!(s.p50_latency_us, 0.0);
        assert_eq!(s.latency.count, 0);
    }

    #[test]
    fn segments_and_writes_fill_their_histograms() {
        let m = Metrics::default();
        m.record_segments(1_000, 9_000);
        m.record_segments(2_000, 8_000);
        m.record_write(500);
        let s = m.snapshot();
        assert_eq!(s.queue_wait.count, 2);
        assert_eq!(s.queue_wait.sum, 3_000);
        assert_eq!(s.execute.count, 2);
        assert_eq!(s.execute.sum, 17_000);
        assert_eq!(s.write.count, 1);
        assert_eq!(s.write.max, 500);
    }

    #[test]
    fn per_worker_counters_attribute_to_the_right_row() {
        let m = Metrics::with_workers(3);
        assert_eq!(m.n_workers(), 3);
        m.record_request(0, 2);
        m.record_batch(0, 2);
        m.record_latency_on(0, 1_000);
        m.record_request(2, 7);
        m.record_batch(2, 4);
        m.record_batch(2, 3);
        m.record_error(2);
        m.record_latency_on(2, 8_000);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.batches, 3);
        assert_eq!(s.workers.len(), 3);
        assert_eq!(s.workers[0].requests, 1);
        assert_eq!(s.workers[0].batches, 1);
        assert_eq!(s.workers[1].requests, 0);
        assert_eq!(s.workers[2].requests, 1);
        assert_eq!(s.workers[2].batches, 2);
        assert_eq!(s.workers[2].batched_points, 7);
        assert_eq!(s.workers[2].errors, 1);
        // Latency attributed per worker: worker 1 saw nothing.
        assert_eq!(s.workers[1].p50_latency_us, 0.0);
        assert_eq!(s.workers[2].max_latency_us, 8.0);
        assert_eq!(s.latency.count, 2);
        // The global rows are the sum of the per-worker rows.
        let sum: u64 = s.workers.iter().map(|w| w.batches).sum();
        assert_eq!(sum, s.batches);
    }
}
