//! L3 coordinator: a batching derivative-evaluation service.
//!
//! After a PINN is trained, downstream consumers (ODE post-processing,
//! plotting, UQ sweeps) need `u, u', ..., u^(n)` at arbitrary points. The
//! coordinator serves those queries over compiled artifacts: requests
//! arrive (in-process or via the TCP JSON-lines front), the handle shards
//! them per activation across a pool of batcher workers, each worker's
//! dynamic batcher packs its shard into backend-sized batches, and
//! responses are scattered back per request.
//!
//! Built on std threads + channels (tokio is not available offline); the
//! structure mirrors a vLLM-style router: front → sharded queues →
//! batcher pool → backends → scatter, with global and per-worker metrics.
//! A pool of size 1 behaves exactly like the original single-worker
//! service; native backends can additionally chunk each batch across
//! threads via [`crate::ntp::ParallelPolicy`].
//!
//! Requests may carry an optional `"activation"` field (any registered
//! [`crate::ntp::ActivationKind`] name) selecting the derivative tower
//! applied to the served weights; the batcher coalesces per activation.
//! Requests without the field behave exactly as before it existed (the
//! served model's own activation), keeping the protocol wire-compatible.

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod protocol;
pub mod service;

pub use backend::{EvalBackend, NativeBackend, PjrtBackend};
pub use batcher::BatcherConfig;
pub use metrics::{Metrics, MetricsSnapshot, WorkerSnapshot};
pub use service::{
    serve_connection, serve_connection_with, serve_tcp, serve_tcp_with, OperatorServer,
    PendingEval, Service, ServiceHandle, SubmitError, TcpClient, MAX_SERVED_OPERATOR_ORDER,
    PIPELINE_WINDOW,
};
