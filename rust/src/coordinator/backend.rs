//! Evaluation backends for the coordinator: the native Rust n-TangentProp
//! engine and the AOT-compiled PJRT executable.

use crate::nn::{params, Mlp};
use crate::ntp::{ActivationKind, NtpEngine, ParallelPolicy};
use crate::runtime::Executable;
use crate::tensor::Tensor;
use anyhow::{bail, ensure, Result};

/// Something that evaluates the derivative stack for a batch of points.
///
/// Not `Send`: PJRT executables hold thread-local handles, so the service
/// constructs its backend *inside* the worker thread (see
/// [`crate::coordinator::Service::start`]'s factory argument).
pub trait EvalBackend {
    /// Largest batch a single `eval_batch` call accepts (compiled shape
    /// for PJRT; a soft cap for the native engine).
    fn max_batch(&self) -> usize;

    /// Number of output channels (n + 1).
    fn n_channels(&self) -> usize;

    /// Evaluate `xs` (length ≤ `max_batch`); returns `n_channels` vectors
    /// of length `xs.len()`.
    fn eval_batch(&mut self, xs: &[f64]) -> Result<Vec<Vec<f64>>>;

    /// Evaluate with an optional per-request activation override (`None`
    /// = the served model's own activation). Backends that can't switch
    /// towers reject the override; the native engine overrides this.
    fn eval_batch_act(
        &mut self,
        xs: &[f64],
        activation: Option<ActivationKind>,
    ) -> Result<Vec<Vec<f64>>> {
        match activation {
            None => self.eval_batch(xs),
            Some(kind) => bail!(
                "backend does not support per-request activation '{}'",
                kind.name()
            ),
        }
    }
}

/// Native backend: the pure-Rust n-TangentProp engine (no artifacts
/// required). The engine comes from the process-wide
/// [`crate::pde::cache`], so a pool of `W` workers serving the same
/// `(n, policy)` compiles the Faà di Bruno program and activation
/// towers once and shares one engine (scratch buffers are pooled
/// internally per engine, so sharing is contention-free).
pub struct NativeBackend {
    engine: std::sync::Arc<NtpEngine>,
    mlp: Mlp,
    n: usize,
    cap: usize,
}

impl NativeBackend {
    /// Serve `mlp`'s first `n` derivatives with batch cap `cap`.
    pub fn new(mlp: Mlp, n: usize, cap: usize) -> NativeBackend {
        NativeBackend::new_parallel(mlp, n, cap, ParallelPolicy::Serial)
    }

    /// Native backend whose engine chunks each batch across threads
    /// according to `policy` (bitwise identical to the serial engine).
    pub fn new_parallel(mlp: Mlp, n: usize, cap: usize, policy: ParallelPolicy) -> NativeBackend {
        let (engine, _hit) = crate::pde::cache::shared_scalar_engine(n, policy);
        NativeBackend { engine, mlp, n, cap }
    }
}

impl EvalBackend for NativeBackend {
    fn max_batch(&self) -> usize {
        self.cap
    }

    fn n_channels(&self) -> usize {
        self.n + 1
    }

    fn eval_batch(&mut self, xs: &[f64]) -> Result<Vec<Vec<f64>>> {
        ensure!(!xs.is_empty() && xs.len() <= self.cap, "bad batch size {}", xs.len());
        // A multi-input checkpoint can't serve scalar 'points' requests
        // — surface a protocol error instead of panicking the worker
        // (multivariate requests go through the operator front).
        ensure!(
            self.mlp.input_dim() == 1,
            "served model has input dim {}; use a points_nd + operator request",
            self.mlp.input_dim()
        );
        let x = Tensor::from_vec(xs.to_vec(), &[xs.len(), 1]);
        let channels = self.engine.forward(&self.mlp, &x);
        Ok(channels.into_iter().map(Tensor::into_vec).collect())
    }

    /// The native engine has towers for every registered activation, so a
    /// per-request activation just retags the served weights.
    fn eval_batch_act(
        &mut self,
        xs: &[f64],
        activation: Option<ActivationKind>,
    ) -> Result<Vec<Vec<f64>>> {
        let original = self.mlp.activation;
        if let Some(kind) = activation {
            self.mlp.activation = kind;
        }
        let result = self.eval_batch(xs);
        self.mlp.activation = original;
        result
    }
}

/// PJRT backend: a compiled `ntp_fwd_*` artifact with a fixed batch shape.
/// Short batches are padded to the compiled size and trimmed on the way
/// out (padding never leaks across requests — asserted by the tests).
pub struct PjrtBackend {
    exe: Executable,
    theta: Tensor,
    batch: usize,
    n_channels: usize,
}

impl PjrtBackend {
    /// `theta` is the flat parameter vector baked per-call (slot 0);
    /// `batch` must match the artifact's compiled shape.
    pub fn new(exe: Executable, theta: Tensor, batch: usize, n_derivs: usize) -> PjrtBackend {
        PjrtBackend {
            exe,
            theta,
            batch,
            n_channels: n_derivs + 1,
        }
    }

    /// Swap in new parameters (e.g. after further training).
    pub fn set_theta(&mut self, theta: Tensor) {
        self.theta = theta;
    }
}

impl EvalBackend for PjrtBackend {
    fn max_batch(&self) -> usize {
        self.batch
    }

    fn n_channels(&self) -> usize {
        self.n_channels
    }

    fn eval_batch(&mut self, xs: &[f64]) -> Result<Vec<Vec<f64>>> {
        ensure!(!xs.is_empty() && xs.len() <= self.batch, "bad batch size {}", xs.len());
        // Pad to the compiled shape.
        let mut padded = xs.to_vec();
        padded.resize(self.batch, 0.0);
        let x = Tensor::from_vec(padded, &[self.batch, 1]);
        let outputs = self.exe.run(&[self.theta.clone(), x])?;
        ensure!(
            outputs.len() == 1,
            "ntp_fwd artifact should return one stacked tensor, got {}",
            outputs.len()
        );
        let stacked = &outputs[0]; // [n+1, batch]
        ensure!(
            stacked.shape() == [self.n_channels, self.batch],
            "unexpected artifact output shape {:?}",
            stacked.shape()
        );
        let mut channels = Vec::with_capacity(self.n_channels);
        for c in 0..self.n_channels {
            let row = &stacked.data()[c * self.batch..c * self.batch + xs.len()];
            channels.push(row.to_vec());
        }
        Ok(channels)
    }

    /// Compiled artifacts bake their activation in; only an explicit tanh
    /// request (the artifacts' activation) is accepted as an override.
    fn eval_batch_act(
        &mut self,
        xs: &[f64],
        activation: Option<ActivationKind>,
    ) -> Result<Vec<Vec<f64>>> {
        match activation {
            None | Some(ActivationKind::Tanh) => self.eval_batch(xs),
            Some(kind) => bail!(
                "pjrt backend is compiled for tanh; cannot serve activation '{}'",
                kind.name()
            ),
        }
    }
}

/// Convenience: build a [`NativeBackend`] whose parameters come from a
/// flat theta (as produced by training / stored in checkpoints).
pub fn native_from_flat(template: &Mlp, theta: &Tensor, n: usize, cap: usize) -> NativeBackend {
    let mut mlp = template.clone();
    params::unflatten_into(&mut mlp, theta);
    NativeBackend::new(mlp, n, cap)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn native_backend_matches_engine() {
        let mut rng = Prng::seeded(9);
        let mlp = Mlp::uniform(1, 8, 2, 1, &mut rng);
        let mut backend = NativeBackend::new(mlp.clone(), 3, 64);
        assert_eq!(backend.n_channels(), 4);
        let xs = [0.1, -0.4, 0.8];
        let channels = backend.eval_batch(&xs).unwrap();
        assert_eq!(channels.len(), 4);
        assert_eq!(channels[0].len(), 3);
        let direct = NtpEngine::new(3).forward(&mlp, &Tensor::from_vec(xs.to_vec(), &[3, 1]));
        for (c, d) in channels.iter().zip(&direct) {
            assert_eq!(c.as_slice(), d.data());
        }
    }

    #[test]
    fn native_backend_serves_activation_overrides() {
        let mut rng = Prng::seeded(11);
        let mlp = Mlp::uniform(1, 8, 2, 1, &mut rng);
        let mut backend = NativeBackend::new(mlp.clone(), 2, 16);
        let xs = [0.2, -0.9];
        for kind in ActivationKind::ALL {
            let channels = backend.eval_batch_act(&xs, Some(kind)).unwrap();
            let mut retagged = mlp.clone();
            retagged.activation = kind;
            let direct =
                NtpEngine::new(2).forward(&retagged, &Tensor::from_vec(xs.to_vec(), &[2, 1]));
            for (c, d) in channels.iter().zip(&direct) {
                assert_eq!(c.as_slice(), d.data(), "{}", kind.name());
            }
        }
        // The override must not stick.
        let plain = backend.eval_batch(&xs).unwrap();
        let direct = NtpEngine::new(2).forward(&mlp, &Tensor::from_vec(xs.to_vec(), &[2, 1]));
        assert_eq!(plain[0].as_slice(), direct[0].data());
    }

    #[test]
    fn parallel_backend_matches_serial_backend() {
        let mut rng = Prng::seeded(12);
        let mlp = Mlp::uniform(1, 8, 2, 1, &mut rng);
        let xs: Vec<f64> = (0..37).map(|i| -1.0 + i as f64 * 0.05).collect();
        let serial = NativeBackend::new(mlp.clone(), 3, 64).eval_batch(&xs).unwrap();
        let parallel = NativeBackend::new_parallel(mlp, 3, 64, ParallelPolicy::Fixed(4))
            .eval_batch(&xs)
            .unwrap();
        assert_eq!(serial, parallel);
    }

    #[test]
    fn native_backend_rejects_oversize() {
        let mut rng = Prng::seeded(10);
        let mlp = Mlp::uniform(1, 4, 1, 1, &mut rng);
        let mut backend = NativeBackend::new(mlp, 2, 4);
        assert!(backend.eval_batch(&[0.0; 5]).is_err());
        assert!(backend.eval_batch(&[]).is_err());
    }
}
