//! Runtime-dispatched SIMD kernels for the fused n-TangentProp hot loops.
//!
//! Every hot elementwise/reduction loop in the crate — the fused kernel's
//! power fills and compiled-op interpreter, the activation towers' Horner
//! and Hermite sweeps, the 4×8 stacked-channel GEMM microkernel, and the
//! optimizer update/reduction helpers — has exactly **one scalar body and
//! one vector body per ISA**, owned by this module and selected through
//! [`Isa`]. The vector bodies use explicit `std::arch` intrinsics (AVX2
//! on x86_64, NEON on aarch64); the scalar bodies are always compiled and
//! are the portable fallback.
//!
//! # The bitwise contract
//!
//! Vector selection must never change results: for every kernel here the
//! scalar and vector bodies are **bitwise identical**, which keeps the
//! crate's serial-vs-parallel and golden-fixture guarantees independent
//! of the host CPU. Two rules make that possible:
//!
//! - **No FMA contraction.** Vector bodies use separate `mul` and `add`
//!   (exactly the two roundings the scalar code performs); `sqrt`/`div`
//!   are correctly rounded per IEEE-754 and therefore lane-exact too.
//!   The `fma` CPU feature is *detected* (it travels with AVX2 on every
//!   x86-64-v3 part) but fused intrinsics are deliberately not used.
//! - **Lane-stable reductions.** Reducing kernels ([`Isa::dot`],
//!   [`Isa::sum`], the GEMM microkernel) fix a 4-lane accumulation
//!   pattern: lane `j` accumulates elements `4c + j` and the lanes
//!   combine as `(l0 + l2) + (l1 + l3) + tail` — the same convention as
//!   [`crate::tensor::linalg::dot_unrolled`]. One AVX2 register (or an
//!   aarch64 pair of 128-bit registers) performs exactly those four
//!   chains, so the vector reduction reproduces the scalar bits.
//!
//! # Dispatch
//!
//! [`Isa::active`] resolves the process-wide choice **once** (a
//! [`OnceLock`]): the `NTANGENT_SIMD` environment variable is consulted
//! first (`scalar`, `avx2`, `neon`, or `auto`), then CPU feature
//! detection. An explicitly requested vector ISA that the host cannot run
//! falls back to `scalar`, never to a crash. Engines capture the resolved
//! [`Isa`] at construction; tests construct engines with explicit ISAs
//! (`NtpEngine::with_isa`) to compare both paths in one process.

use std::sync::OnceLock;

/// Coefficient bundle of one Adam update step, shared by the scalar and
/// vector bodies of [`Isa::adam_block`].
#[derive(Clone, Copy, Debug)]
pub struct AdamCoeffs {
    /// First-moment decay.
    pub beta1: f64,
    /// Second-moment decay.
    pub beta2: f64,
    /// Bias-corrected learning rate of this step.
    pub lr_t: f64,
    /// Denominator fuzz.
    pub eps: f64,
}

/// An instruction-set choice for the vectorized kernels.
///
/// Carries no data — the variant *is* the dispatch decision, resolved
/// once per process by [`Isa::active`] (or pinned explicitly in tests).
/// Every kernel produces bitwise identical results under every variant;
/// see the module docs for why.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar bodies — always available, the fallback.
    Scalar,
    /// 256-bit AVX2 bodies (x86_64; requires `avx2` + `fma` detection).
    #[cfg(target_arch = "x86_64")]
    Avx2,
    /// 128-bit NEON bodies (aarch64 baseline).
    #[cfg(target_arch = "aarch64")]
    Neon,
}

impl Isa {
    /// The process-wide ISA, resolved exactly once: `NTANGENT_SIMD`
    /// (`scalar` | `avx2` | `neon` | `auto`) first, CPU detection
    /// otherwise. Unknown values mean `auto`.
    pub fn active() -> Isa {
        static ACTIVE: OnceLock<Isa> = OnceLock::new();
        *ACTIVE.get_or_init(|| Isa::resolve(std::env::var("NTANGENT_SIMD").ok().as_deref()))
    }

    /// Resolve an explicit request (the parsed `NTANGENT_SIMD` value) to
    /// a runnable ISA: `scalar` is always honored, a vector request is
    /// honored only when the host supports it (falling back to
    /// [`Isa::Scalar`] otherwise), and `None`/`auto`/anything else means
    /// [`Isa::detect`].
    pub fn resolve(request: Option<&str>) -> Isa {
        let req = request.map(|s| s.trim().to_ascii_lowercase());
        match req.as_deref() {
            Some("scalar") => Isa::Scalar,
            Some("avx2") => {
                #[cfg(target_arch = "x86_64")]
                {
                    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
                        Isa::Avx2
                    } else {
                        Isa::Scalar
                    }
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    Isa::Scalar
                }
            }
            Some("neon") => {
                #[cfg(target_arch = "aarch64")]
                {
                    Isa::Neon
                }
                #[cfg(not(target_arch = "aarch64"))]
                {
                    Isa::Scalar
                }
            }
            _ => Isa::detect(),
        }
    }

    /// CPU feature detection alone (no environment override): AVX2+FMA
    /// on x86_64, NEON on aarch64 (baseline), scalar elsewhere.
    pub fn detect() -> Isa {
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
                Isa::Avx2
            } else {
                Isa::Scalar
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            Isa::Neon
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            Isa::Scalar
        }
    }

    /// The best *vector* ISA this host can run, if any — what tests use
    /// to pit a vector engine against a scalar one (and to skip cleanly
    /// on scalar-only hosts).
    pub fn vector() -> Option<Isa> {
        let isa = Isa::detect();
        if isa == Isa::Scalar {
            None
        } else {
            Some(isa)
        }
    }

    /// Canonical lowercase name (the accepted `NTANGENT_SIMD` spelling).
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => "avx2",
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => "neon",
        }
    }
}

// ------------------------------------------------------------- kernels
//
// Each method asserts the slice-length contract once, then dispatches.
// The vector bodies are `#[target_feature]` functions; constructing a
// vector variant requires the matching CPU detection (see `resolve` /
// `detect`), which is what makes the `unsafe` calls sound.

impl Isa {
    /// `Σ a[i]·b[i]` in the fixed 4-lane accumulation pattern of
    /// [`crate::tensor::linalg::dot_unrolled`] — bitwise identical under
    /// every ISA.
    #[inline]
    pub fn dot(self, a: &[f64], b: &[f64]) -> f64 {
        assert_eq!(a.len(), b.len(), "dot: length mismatch");
        match self {
            Isa::Scalar => crate::tensor::linalg::dot_unrolled(a, b),
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => unsafe { avx2::dot(a, b) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => unsafe { neon::dot(a, b) },
        }
    }

    /// `Σ a[i]` in the same fixed 4-lane pattern as [`Isa::dot`].
    #[inline]
    pub fn sum(self, a: &[f64]) -> f64 {
        match self {
            Isa::Scalar => scalar::sum(a),
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => unsafe { avx2::sum(a) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => unsafe { neon::sum(a) },
        }
    }

    /// `dst[i] = a[i]·b[i]` (the fused kernel's channel-power fills).
    #[inline]
    pub fn mul_into(self, dst: &mut [f64], a: &[f64], b: &[f64]) {
        assert_eq!(dst.len(), a.len(), "mul_into: length mismatch");
        assert_eq!(dst.len(), b.len(), "mul_into: length mismatch");
        match self {
            Isa::Scalar => scalar::mul_into(dst, a, b),
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => unsafe { avx2::mul_into(dst, a, b) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => unsafe { neon::mul_into(dst, a, b) },
        }
    }

    /// `dst[i] = c·a[i]` (seeds the interpreter's k-factor product).
    #[inline]
    pub fn scale_into(self, dst: &mut [f64], c: f64, a: &[f64]) {
        assert_eq!(dst.len(), a.len(), "scale_into: length mismatch");
        match self {
            Isa::Scalar => scalar::scale_into(dst, c, a),
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => unsafe { avx2::scale_into(dst, c, a) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => unsafe { neon::scale_into(dst, c, a) },
        }
    }

    /// `dst[i] *= a[i]`.
    #[inline]
    pub fn mul_assign(self, dst: &mut [f64], a: &[f64]) {
        assert_eq!(dst.len(), a.len(), "mul_assign: length mismatch");
        match self {
            Isa::Scalar => scalar::mul_assign(dst, a),
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => unsafe { avx2::mul_assign(dst, a) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => unsafe { neon::mul_assign(dst, a) },
        }
    }

    /// `dst[i] += a[i]` (bias rows, ξ accumulation of k-factor terms).
    #[inline]
    pub fn add_assign(self, dst: &mut [f64], a: &[f64]) {
        assert_eq!(dst.len(), a.len(), "add_assign: length mismatch");
        match self {
            Isa::Scalar => scalar::add_assign(dst, a),
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => unsafe { avx2::add_assign(dst, a) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => unsafe { neon::add_assign(dst, a) },
        }
    }

    /// `dst[i] = -a[i]` (the sine tower's sign flips; a pure sign-bit
    /// XOR in the vector bodies — exact under IEEE-754).
    #[inline]
    pub fn neg_into(self, dst: &mut [f64], a: &[f64]) {
        assert_eq!(dst.len(), a.len(), "neg_into: length mismatch");
        match self {
            Isa::Scalar => scalar::neg_into(dst, a),
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => unsafe { avx2::neg_into(dst, a) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => unsafe { neon::neg_into(dst, a) },
        }
    }

    /// `dst[i] = x·w[i] + b[i]` — the scalar-input seed rows of the
    /// fused forward (`y0 = x·W0ᵀ + b0` one batch row at a time).
    #[inline]
    pub fn axpb_into(self, dst: &mut [f64], x: f64, w: &[f64], b: &[f64]) {
        assert_eq!(dst.len(), w.len(), "axpb_into: length mismatch");
        assert_eq!(dst.len(), b.len(), "axpb_into: length mismatch");
        match self {
            Isa::Scalar => scalar::axpb_into(dst, x, w, b),
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => unsafe { avx2::axpb_into(dst, x, w, b) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => unsafe { neon::axpb_into(dst, x, w, b) },
        }
    }

    /// `xi[i] += coeff·ts[i]·a[i]` — the compiled-op interpreter's
    /// single-factor partition terms.
    #[inline]
    pub fn xi_acc1(self, xi: &mut [f64], coeff: f64, ts: &[f64], a: &[f64]) {
        assert_eq!(xi.len(), ts.len(), "xi_acc1: length mismatch");
        assert_eq!(xi.len(), a.len(), "xi_acc1: length mismatch");
        match self {
            Isa::Scalar => scalar::xi_acc1(xi, coeff, ts, a),
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => unsafe { avx2::xi_acc1(xi, coeff, ts, a) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => unsafe { neon::xi_acc1(xi, coeff, ts, a) },
        }
    }

    /// `xi[i] += coeff·ts[i]·a[i]·b[i]` — the two-factor partition terms.
    #[inline]
    pub fn xi_acc2(self, xi: &mut [f64], coeff: f64, ts: &[f64], a: &[f64], b: &[f64]) {
        assert_eq!(xi.len(), ts.len(), "xi_acc2: length mismatch");
        assert_eq!(xi.len(), a.len(), "xi_acc2: length mismatch");
        assert_eq!(xi.len(), b.len(), "xi_acc2: length mismatch");
        match self {
            Isa::Scalar => scalar::xi_acc2(xi, coeff, ts, a, b),
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => unsafe { avx2::xi_acc2(xi, coeff, ts, a, b) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => unsafe { neon::xi_acc2(xi, coeff, ts, a, b) },
        }
    }

    /// Horner sweep `out[e] = P(t[e])` (low-to-high `coeffs`) — the tanh
    /// and softplus tower planes.
    #[inline]
    pub fn horner_into(self, t: &[f64], coeffs: &[f64], out: &mut [f64]) {
        assert_eq!(t.len(), out.len(), "horner_into: length mismatch");
        match coeffs.len() {
            0 => out.fill(0.0),
            1 => out.fill(coeffs[0]),
            _ => match self {
                Isa::Scalar => scalar::horner_into(t, coeffs, out),
                #[cfg(target_arch = "x86_64")]
                Isa::Avx2 => unsafe { avx2::horner_into(t, coeffs, out) },
                #[cfg(target_arch = "aarch64")]
                Isa::Neon => unsafe { neon::horner_into(t, coeffs, out) },
            },
        }
    }

    /// In-place Horner sweep `vals[e] = P(vals[e])` (the softplus sigmoid
    /// staging plane consuming itself).
    #[inline]
    pub fn horner_inplace(self, vals: &mut [f64], coeffs: &[f64]) {
        match coeffs.len() {
            0 => vals.fill(0.0),
            1 => vals.fill(coeffs[0]),
            _ => match self {
                Isa::Scalar => scalar::horner_inplace(vals, coeffs),
                #[cfg(target_arch = "x86_64")]
                Isa::Avx2 => unsafe { avx2::horner_inplace(vals, coeffs) },
                #[cfg(target_arch = "aarch64")]
                Isa::Neon => unsafe { neon::horner_inplace(vals, coeffs) },
            },
        }
    }

    /// The GELU tower's strided tail from precomputed `cdf`/`pdf` blocks:
    /// plane 0 gets `x·Φ(x)`, plane 1 `Φ + x·φ`, planes `k ≥ 2` the
    /// rolled Hermite recurrence — written to `out[k·stride + e]`.
    /// `pdf` is only read when `n ≥ 1`.
    #[inline]
    pub fn gelu_tail(self, xs: &[f64], cdf: &[f64], pdf: &[f64], n: usize, out: &mut [f64], stride: usize) {
        assert_eq!(xs.len(), cdf.len(), "gelu_tail: length mismatch");
        assert_eq!(xs.len(), pdf.len(), "gelu_tail: length mismatch");
        assert!(stride >= xs.len(), "gelu_tail: stride shorter than the block");
        assert!(out.len() >= n * stride + xs.len(), "gelu_tail: output too short");
        match self {
            Isa::Scalar => scalar::gelu_tail(xs, cdf, pdf, n, out, stride),
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => unsafe { avx2::gelu_tail(xs, cdf, pdf, n, out, stride) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => unsafe { neon::gelu_tail(xs, cdf, pdf, n, out, stride) },
        }
    }

    /// The 4×8 register microkernel of the blocked NT GEMM: 32
    /// single-accumulator chains over the packed k-major `panel`
    /// (`panel[p·8 + q]` = column `q` at k-step `p`), written to `c`
    /// (pre-offset at the tile's top-left element) with rows
    /// `row_stride` apart. `first` assigns instead of accumulating.
    #[inline]
    pub fn gemm_micro_4x8(self, ar: [&[f64]; 4], panel: &[f64], c: &mut [f64], row_stride: usize, first: bool) {
        let kl = ar[0].len();
        for row in &ar {
            assert_eq!(row.len(), kl, "gemm_micro_4x8: ragged A rows");
        }
        assert_eq!(panel.len(), GEMM_NR * kl, "gemm_micro_4x8: panel size");
        assert!(row_stride >= GEMM_NR, "gemm_micro_4x8: row stride too small");
        assert!(c.len() >= 3 * row_stride + GEMM_NR, "gemm_micro_4x8: output too short");
        match self {
            Isa::Scalar => scalar::gemm_micro_4x8(ar, panel, c, row_stride, first),
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => unsafe { avx2::gemm_micro_4x8(ar, panel, c, row_stride, first) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => unsafe { neon::gemm_micro_4x8(ar, panel, c, row_stride, first) },
        }
    }

    /// One Adam block update (`m`, `v`, `θ` in place from `g`): the exact
    /// per-element op sequence of the historical serial update.
    #[inline]
    pub fn adam_block(self, m: &mut [f64], v: &mut [f64], th: &mut [f64], g: &[f64], co: AdamCoeffs) {
        assert_eq!(m.len(), g.len(), "adam_block: length mismatch");
        assert_eq!(v.len(), g.len(), "adam_block: length mismatch");
        assert_eq!(th.len(), g.len(), "adam_block: length mismatch");
        match self {
            Isa::Scalar => scalar::adam_block(m, v, th, g, co),
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => unsafe { avx2::adam_block(m, v, th, g, co) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => unsafe { neon::adam_block(m, v, th, g, co) },
        }
    }

    /// One SGD(+momentum) block update (`v`, `θ` in place from `g`).
    #[inline]
    pub fn sgd_block(self, v: &mut [f64], th: &mut [f64], g: &[f64], lr: f64, momentum: f64) {
        assert_eq!(v.len(), g.len(), "sgd_block: length mismatch");
        assert_eq!(th.len(), g.len(), "sgd_block: length mismatch");
        match self {
            Isa::Scalar => scalar::sgd_block(v, th, g, lr, momentum),
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => unsafe { avx2::sgd_block(v, th, g, lr, momentum) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => unsafe { neon::sgd_block(v, th, g, lr, momentum) },
        }
    }

    /// `true` iff every element is finite (no NaN, no ±∞) — the numeric
    /// health probe scanned over losses, gradient blocks and activation
    /// tower tiles each training step. A boolean predicate has no
    /// rounding at all, so the scalar≡vector contract holds trivially;
    /// the vector bodies test `|x| < +∞` per lane (NaN compares false)
    /// and may short-circuit per block, which cannot change the answer.
    #[inline]
    pub fn all_finite(self, xs: &[f64]) -> bool {
        match self {
            Isa::Scalar => scalar::all_finite(xs),
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => unsafe { avx2::all_finite(xs) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => unsafe { neon::all_finite(xs) },
        }
    }
}

use crate::tensor::linalg::GEMM_NR;

/// Portable scalar bodies — the dispatch fallback and the bitwise
/// specification the vector bodies are held to.
mod scalar {
    use super::{AdamCoeffs, GEMM_NR};

    /// 4-lane sum: lane `j` accumulates elements `4c + j`, lanes combine
    /// as `(l0 + l2) + (l1 + l3) + tail` (the `dot_unrolled` convention).
    pub fn sum(a: &[f64]) -> f64 {
        let mut acc = [0.0f64; 4];
        let chunks = a.len() / 4;
        for c in 0..chunks {
            let i = 4 * c;
            acc[0] += a[i];
            acc[1] += a[i + 1];
            acc[2] += a[i + 2];
            acc[3] += a[i + 3];
        }
        let mut tail = 0.0;
        for &v in &a[4 * chunks..] {
            tail += v;
        }
        (acc[0] + acc[2]) + (acc[1] + acc[3]) + tail
    }

    pub fn mul_into(dst: &mut [f64], a: &[f64], b: &[f64]) {
        for ((d, &x), &y) in dst.iter_mut().zip(a).zip(b) {
            *d = x * y;
        }
    }

    pub fn scale_into(dst: &mut [f64], c: f64, a: &[f64]) {
        for (d, &x) in dst.iter_mut().zip(a) {
            *d = c * x;
        }
    }

    pub fn mul_assign(dst: &mut [f64], a: &[f64]) {
        for (d, &x) in dst.iter_mut().zip(a) {
            *d *= x;
        }
    }

    pub fn add_assign(dst: &mut [f64], a: &[f64]) {
        for (d, &x) in dst.iter_mut().zip(a) {
            *d += x;
        }
    }

    pub fn neg_into(dst: &mut [f64], a: &[f64]) {
        for (d, &x) in dst.iter_mut().zip(a) {
            *d = -x;
        }
    }

    pub fn axpb_into(dst: &mut [f64], x: f64, w: &[f64], b: &[f64]) {
        for (d, (&wv, &bv)) in dst.iter_mut().zip(w.iter().zip(b)) {
            *d = x * wv + bv;
        }
    }

    pub fn xi_acc1(xi: &mut [f64], coeff: f64, ts: &[f64], a: &[f64]) {
        for (o, (&tv, &av)) in xi.iter_mut().zip(ts.iter().zip(a)) {
            *o += coeff * tv * av;
        }
    }

    pub fn xi_acc2(xi: &mut [f64], coeff: f64, ts: &[f64], a: &[f64], b: &[f64]) {
        for (o, ((&tv, &av), &bv)) in xi.iter_mut().zip(ts.iter().zip(a).zip(b)) {
            *o += coeff * tv * av * bv;
        }
    }

    /// Caller guarantees `coeffs.len() >= 2` (the dispatch method handles
    /// the degenerate polynomials).
    pub fn horner_into(t: &[f64], coeffs: &[f64], out: &mut [f64]) {
        let top = coeffs[coeffs.len() - 1];
        let low = &coeffs[..coeffs.len() - 1];
        for (o, &ti) in out.iter_mut().zip(t) {
            let mut acc = top;
            for &ci in low.iter().rev() {
                acc = acc * ti + ci;
            }
            *o = acc;
        }
    }

    /// Caller guarantees `coeffs.len() >= 2`.
    pub fn horner_inplace(vals: &mut [f64], coeffs: &[f64]) {
        let top = coeffs[coeffs.len() - 1];
        let low = &coeffs[..coeffs.len() - 1];
        for v in vals.iter_mut() {
            let ti = *v;
            let mut acc = top;
            for &ci in low.iter().rev() {
                acc = acc * ti + ci;
            }
            *v = acc;
        }
    }

    pub fn gelu_tail(xs: &[f64], cdf: &[f64], pdf: &[f64], n: usize, out: &mut [f64], stride: usize) {
        for (e, &x) in xs.iter().enumerate() {
            let c = cdf[e];
            out[e] = x * c;
            if n >= 1 {
                let p = pdf[e];
                out[stride + e] = c + x * p;
                let mut h0 = 1.0; // He_{k-2}
                let mut h1 = x; // He_{k-1}
                for k in 2..=n {
                    let hk = x * h1 - (k - 1) as f64 * h0;
                    let sign = if (k - 1) % 2 == 0 { 1.0 } else { -1.0 };
                    out[k * stride + e] = sign * p * (hk - h0);
                    h0 = h1;
                    h1 = hk;
                }
            }
        }
    }

    /// 32 single-accumulator chains in ascending-k order; `c` is
    /// pre-offset at the tile's top-left element.
    pub fn gemm_micro_4x8(ar: [&[f64]; 4], panel: &[f64], c: &mut [f64], row_stride: usize, first: bool) {
        let mut acc = [[0.0f64; GEMM_NR]; 4];
        for (p, bv) in panel.chunks_exact(GEMM_NR).enumerate() {
            let av = [ar[0][p], ar[1][p], ar[2][p], ar[3][p]];
            for (accr, &a) in acc.iter_mut().zip(&av) {
                for (o, &b) in accr.iter_mut().zip(bv) {
                    *o += a * b;
                }
            }
        }
        for (r, accr) in acc.iter().enumerate() {
            let crow = &mut c[r * row_stride..r * row_stride + GEMM_NR];
            if first {
                crow.copy_from_slice(accr);
            } else {
                for (o, &v) in crow.iter_mut().zip(accr) {
                    *o += v;
                }
            }
        }
    }

    pub fn adam_block(m: &mut [f64], v: &mut [f64], th: &mut [f64], g: &[f64], co: AdamCoeffs) {
        let omb1 = 1.0 - co.beta1;
        let omb2 = 1.0 - co.beta2;
        for i in 0..g.len() {
            m[i] = co.beta1 * m[i] + omb1 * g[i];
            v[i] = co.beta2 * v[i] + omb2 * g[i] * g[i];
            th[i] -= co.lr_t * m[i] / (v[i].sqrt() + co.eps);
        }
    }

    pub fn sgd_block(v: &mut [f64], th: &mut [f64], g: &[f64], lr: f64, momentum: f64) {
        for i in 0..g.len() {
            v[i] = momentum * v[i] - lr * g[i];
            th[i] += v[i];
        }
    }

    pub fn all_finite(xs: &[f64]) -> bool {
        xs.iter().all(|x| x.is_finite())
    }
}

/// AVX2 bodies. Every function is `#[target_feature(enable = "avx2")]`
/// and only reached through an [`Isa::Avx2`] value, which is only
/// constructed after `is_x86_feature_detected!("avx2")` succeeded. No
/// FMA intrinsics — separate `mul`/`add` keep every lane bitwise equal
/// to the scalar bodies.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    #![allow(clippy::missing_safety_doc)]

    use super::{AdamCoeffs, GEMM_NR};
    use core::arch::x86_64::*;

    #[target_feature(enable = "avx2")]
    pub unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len();
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        let mut acc = _mm256_setzero_pd();
        let mut i = 0;
        while i + 4 <= n {
            acc = _mm256_add_pd(
                acc,
                _mm256_mul_pd(_mm256_loadu_pd(ap.add(i)), _mm256_loadu_pd(bp.add(i))),
            );
            i += 4;
        }
        let mut tail = 0.0;
        while i < n {
            tail += *ap.add(i) * *bp.add(i);
            i += 1;
        }
        let mut l = [0.0f64; 4];
        _mm256_storeu_pd(l.as_mut_ptr(), acc);
        (l[0] + l[2]) + (l[1] + l[3]) + tail
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn sum(a: &[f64]) -> f64 {
        let n = a.len();
        let ap = a.as_ptr();
        let mut acc = _mm256_setzero_pd();
        let mut i = 0;
        while i + 4 <= n {
            acc = _mm256_add_pd(acc, _mm256_loadu_pd(ap.add(i)));
            i += 4;
        }
        let mut tail = 0.0;
        while i < n {
            tail += *ap.add(i);
            i += 1;
        }
        let mut l = [0.0f64; 4];
        _mm256_storeu_pd(l.as_mut_ptr(), acc);
        (l[0] + l[2]) + (l[1] + l[3]) + tail
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn mul_into(dst: &mut [f64], a: &[f64], b: &[f64]) {
        let n = dst.len();
        let (dp, ap, bp) = (dst.as_mut_ptr(), a.as_ptr(), b.as_ptr());
        let mut i = 0;
        while i + 4 <= n {
            _mm256_storeu_pd(
                dp.add(i),
                _mm256_mul_pd(_mm256_loadu_pd(ap.add(i)), _mm256_loadu_pd(bp.add(i))),
            );
            i += 4;
        }
        while i < n {
            *dp.add(i) = *ap.add(i) * *bp.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn scale_into(dst: &mut [f64], c: f64, a: &[f64]) {
        let n = dst.len();
        let (dp, ap) = (dst.as_mut_ptr(), a.as_ptr());
        let cv = _mm256_set1_pd(c);
        let mut i = 0;
        while i + 4 <= n {
            _mm256_storeu_pd(dp.add(i), _mm256_mul_pd(cv, _mm256_loadu_pd(ap.add(i))));
            i += 4;
        }
        while i < n {
            *dp.add(i) = c * *ap.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn mul_assign(dst: &mut [f64], a: &[f64]) {
        let n = dst.len();
        let (dp, ap) = (dst.as_mut_ptr(), a.as_ptr());
        let mut i = 0;
        while i + 4 <= n {
            _mm256_storeu_pd(
                dp.add(i),
                _mm256_mul_pd(_mm256_loadu_pd(dp.add(i)), _mm256_loadu_pd(ap.add(i))),
            );
            i += 4;
        }
        while i < n {
            *dp.add(i) *= *ap.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn add_assign(dst: &mut [f64], a: &[f64]) {
        let n = dst.len();
        let (dp, ap) = (dst.as_mut_ptr(), a.as_ptr());
        let mut i = 0;
        while i + 4 <= n {
            _mm256_storeu_pd(
                dp.add(i),
                _mm256_add_pd(_mm256_loadu_pd(dp.add(i)), _mm256_loadu_pd(ap.add(i))),
            );
            i += 4;
        }
        while i < n {
            *dp.add(i) += *ap.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn neg_into(dst: &mut [f64], a: &[f64]) {
        let n = dst.len();
        let (dp, ap) = (dst.as_mut_ptr(), a.as_ptr());
        let sign = _mm256_set1_pd(-0.0);
        let mut i = 0;
        while i + 4 <= n {
            _mm256_storeu_pd(dp.add(i), _mm256_xor_pd(_mm256_loadu_pd(ap.add(i)), sign));
            i += 4;
        }
        while i < n {
            *dp.add(i) = -*ap.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn axpb_into(dst: &mut [f64], x: f64, w: &[f64], b: &[f64]) {
        let n = dst.len();
        let (dp, wp, bp) = (dst.as_mut_ptr(), w.as_ptr(), b.as_ptr());
        let xv = _mm256_set1_pd(x);
        let mut i = 0;
        while i + 4 <= n {
            _mm256_storeu_pd(
                dp.add(i),
                _mm256_add_pd(
                    _mm256_mul_pd(xv, _mm256_loadu_pd(wp.add(i))),
                    _mm256_loadu_pd(bp.add(i)),
                ),
            );
            i += 4;
        }
        while i < n {
            *dp.add(i) = x * *wp.add(i) + *bp.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn xi_acc1(xi: &mut [f64], coeff: f64, ts: &[f64], a: &[f64]) {
        let n = xi.len();
        let (xp, tp, ap) = (xi.as_mut_ptr(), ts.as_ptr(), a.as_ptr());
        let cv = _mm256_set1_pd(coeff);
        let mut i = 0;
        while i + 4 <= n {
            let prod = _mm256_mul_pd(
                _mm256_mul_pd(cv, _mm256_loadu_pd(tp.add(i))),
                _mm256_loadu_pd(ap.add(i)),
            );
            _mm256_storeu_pd(xp.add(i), _mm256_add_pd(_mm256_loadu_pd(xp.add(i)), prod));
            i += 4;
        }
        while i < n {
            *xp.add(i) += coeff * *tp.add(i) * *ap.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn xi_acc2(xi: &mut [f64], coeff: f64, ts: &[f64], a: &[f64], b: &[f64]) {
        let n = xi.len();
        let (xp, tp, ap, bp) = (xi.as_mut_ptr(), ts.as_ptr(), a.as_ptr(), b.as_ptr());
        let cv = _mm256_set1_pd(coeff);
        let mut i = 0;
        while i + 4 <= n {
            let prod = _mm256_mul_pd(
                _mm256_mul_pd(
                    _mm256_mul_pd(cv, _mm256_loadu_pd(tp.add(i))),
                    _mm256_loadu_pd(ap.add(i)),
                ),
                _mm256_loadu_pd(bp.add(i)),
            );
            _mm256_storeu_pd(xp.add(i), _mm256_add_pd(_mm256_loadu_pd(xp.add(i)), prod));
            i += 4;
        }
        while i < n {
            *xp.add(i) += coeff * *tp.add(i) * *ap.add(i) * *bp.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn horner_into(t: &[f64], coeffs: &[f64], out: &mut [f64]) {
        let n = t.len();
        let top = coeffs[coeffs.len() - 1];
        let low = &coeffs[..coeffs.len() - 1];
        let (tp, op) = (t.as_ptr(), out.as_mut_ptr());
        let mut i = 0;
        while i + 4 <= n {
            let tv = _mm256_loadu_pd(tp.add(i));
            let mut acc = _mm256_set1_pd(top);
            for &ci in low.iter().rev() {
                acc = _mm256_add_pd(_mm256_mul_pd(acc, tv), _mm256_set1_pd(ci));
            }
            _mm256_storeu_pd(op.add(i), acc);
            i += 4;
        }
        while i < n {
            let ti = *tp.add(i);
            let mut acc = top;
            for &ci in low.iter().rev() {
                acc = acc * ti + ci;
            }
            *op.add(i) = acc;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn horner_inplace(vals: &mut [f64], coeffs: &[f64]) {
        let n = vals.len();
        let top = coeffs[coeffs.len() - 1];
        let low = &coeffs[..coeffs.len() - 1];
        let vp = vals.as_mut_ptr();
        let mut i = 0;
        while i + 4 <= n {
            let tv = _mm256_loadu_pd(vp.add(i));
            let mut acc = _mm256_set1_pd(top);
            for &ci in low.iter().rev() {
                acc = _mm256_add_pd(_mm256_mul_pd(acc, tv), _mm256_set1_pd(ci));
            }
            _mm256_storeu_pd(vp.add(i), acc);
            i += 4;
        }
        while i < n {
            let ti = *vp.add(i);
            let mut acc = top;
            for &ci in low.iter().rev() {
                acc = acc * ti + ci;
            }
            *vp.add(i) = acc;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn gelu_tail(xs: &[f64], cdf: &[f64], pdf: &[f64], n: usize, out: &mut [f64], stride: usize) {
        let m = xs.len();
        let (xp, cp, pp, op) = (xs.as_ptr(), cdf.as_ptr(), pdf.as_ptr(), out.as_mut_ptr());
        let mut e = 0;
        while e + 4 <= m {
            let x = _mm256_loadu_pd(xp.add(e));
            let c = _mm256_loadu_pd(cp.add(e));
            _mm256_storeu_pd(op.add(e), _mm256_mul_pd(x, c));
            if n >= 1 {
                let p = _mm256_loadu_pd(pp.add(e));
                _mm256_storeu_pd(op.add(stride + e), _mm256_add_pd(c, _mm256_mul_pd(x, p)));
                let mut h0 = _mm256_set1_pd(1.0);
                let mut h1 = x;
                for k in 2..=n {
                    let hk = _mm256_sub_pd(
                        _mm256_mul_pd(x, h1),
                        _mm256_mul_pd(_mm256_set1_pd((k - 1) as f64), h0),
                    );
                    let sign = if (k - 1) % 2 == 0 { 1.0 } else { -1.0 };
                    _mm256_storeu_pd(
                        op.add(k * stride + e),
                        _mm256_mul_pd(
                            _mm256_mul_pd(_mm256_set1_pd(sign), p),
                            _mm256_sub_pd(hk, h0),
                        ),
                    );
                    h0 = h1;
                    h1 = hk;
                }
            }
            e += 4;
        }
        while e < m {
            let x = *xp.add(e);
            let c = *cp.add(e);
            *op.add(e) = x * c;
            if n >= 1 {
                let p = *pp.add(e);
                *op.add(stride + e) = c + x * p;
                let mut h0 = 1.0;
                let mut h1 = x;
                for k in 2..=n {
                    let hk = x * h1 - (k - 1) as f64 * h0;
                    let sign = if (k - 1) % 2 == 0 { 1.0 } else { -1.0 };
                    *op.add(k * stride + e) = sign * p * (hk - h0);
                    h0 = h1;
                    h1 = hk;
                }
            }
            e += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn gemm_micro_4x8(
        ar: [&[f64]; 4],
        panel: &[f64],
        c: &mut [f64],
        row_stride: usize,
        first: bool,
    ) {
        let mut acc = [[_mm256_setzero_pd(); 2]; 4];
        for (p, bv) in panel.chunks_exact(GEMM_NR).enumerate() {
            let b0 = _mm256_loadu_pd(bv.as_ptr());
            let b1 = _mm256_loadu_pd(bv.as_ptr().add(4));
            for (accr, row) in acc.iter_mut().zip(&ar) {
                let a = _mm256_set1_pd(*row.get_unchecked(p));
                accr[0] = _mm256_add_pd(accr[0], _mm256_mul_pd(a, b0));
                accr[1] = _mm256_add_pd(accr[1], _mm256_mul_pd(a, b1));
            }
        }
        for (r, accr) in acc.iter().enumerate() {
            let co = c.as_mut_ptr().add(r * row_stride);
            if first {
                _mm256_storeu_pd(co, accr[0]);
                _mm256_storeu_pd(co.add(4), accr[1]);
            } else {
                _mm256_storeu_pd(co, _mm256_add_pd(_mm256_loadu_pd(co), accr[0]));
                _mm256_storeu_pd(co.add(4), _mm256_add_pd(_mm256_loadu_pd(co.add(4)), accr[1]));
            }
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn adam_block(m: &mut [f64], v: &mut [f64], th: &mut [f64], g: &[f64], co: AdamCoeffs) {
        let n = g.len();
        let (mp, vp, tp, gp) = (m.as_mut_ptr(), v.as_mut_ptr(), th.as_mut_ptr(), g.as_ptr());
        let b1 = _mm256_set1_pd(co.beta1);
        let b2 = _mm256_set1_pd(co.beta2);
        let omb1 = _mm256_set1_pd(1.0 - co.beta1);
        let omb2 = _mm256_set1_pd(1.0 - co.beta2);
        let lrt = _mm256_set1_pd(co.lr_t);
        let eps = _mm256_set1_pd(co.eps);
        let mut i = 0;
        while i + 4 <= n {
            let gv = _mm256_loadu_pd(gp.add(i));
            let mv = _mm256_add_pd(
                _mm256_mul_pd(b1, _mm256_loadu_pd(mp.add(i))),
                _mm256_mul_pd(omb1, gv),
            );
            _mm256_storeu_pd(mp.add(i), mv);
            let vv = _mm256_add_pd(
                _mm256_mul_pd(b2, _mm256_loadu_pd(vp.add(i))),
                _mm256_mul_pd(_mm256_mul_pd(omb2, gv), gv),
            );
            _mm256_storeu_pd(vp.add(i), vv);
            let step = _mm256_div_pd(
                _mm256_mul_pd(lrt, mv),
                _mm256_add_pd(_mm256_sqrt_pd(vv), eps),
            );
            _mm256_storeu_pd(tp.add(i), _mm256_sub_pd(_mm256_loadu_pd(tp.add(i)), step));
            i += 4;
        }
        while i < n {
            let gi = *gp.add(i);
            let mi = co.beta1 * *mp.add(i) + (1.0 - co.beta1) * gi;
            *mp.add(i) = mi;
            let vi = co.beta2 * *vp.add(i) + (1.0 - co.beta2) * gi * gi;
            *vp.add(i) = vi;
            *tp.add(i) -= co.lr_t * mi / (vi.sqrt() + co.eps);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn sgd_block(v: &mut [f64], th: &mut [f64], g: &[f64], lr: f64, momentum: f64) {
        let n = g.len();
        let (vp, tp, gp) = (v.as_mut_ptr(), th.as_mut_ptr(), g.as_ptr());
        let mo = _mm256_set1_pd(momentum);
        let lrv = _mm256_set1_pd(lr);
        let mut i = 0;
        while i + 4 <= n {
            let vv = _mm256_sub_pd(
                _mm256_mul_pd(mo, _mm256_loadu_pd(vp.add(i))),
                _mm256_mul_pd(lrv, _mm256_loadu_pd(gp.add(i))),
            );
            _mm256_storeu_pd(vp.add(i), vv);
            _mm256_storeu_pd(tp.add(i), _mm256_add_pd(_mm256_loadu_pd(tp.add(i)), vv));
            i += 4;
        }
        while i < n {
            let vi = momentum * *vp.add(i) - lr * *gp.add(i);
            *vp.add(i) = vi;
            *tp.add(i) += vi;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn all_finite(xs: &[f64]) -> bool {
        let n = xs.len();
        let xp = xs.as_ptr();
        // |x| < +inf per lane: clearing the sign bit maps ±inf onto +inf
        // and NaN stays NaN, and the ordered-quiet compare is false for
        // both — exactly `f64::is_finite`.
        let abs_mask = _mm256_set1_pd(f64::from_bits(0x7fff_ffff_ffff_ffff));
        let inf = _mm256_set1_pd(f64::INFINITY);
        let mut i = 0;
        while i + 4 <= n {
            let a = _mm256_and_pd(_mm256_loadu_pd(xp.add(i)), abs_mask);
            let ok = _mm256_cmp_pd::<_CMP_LT_OQ>(a, inf);
            if _mm256_movemask_pd(ok) != 0xF {
                return false;
            }
            i += 4;
        }
        while i < n {
            if !(*xp.add(i)).is_finite() {
                return false;
            }
            i += 1;
        }
        true
    }
}

/// NEON bodies (aarch64 — NEON is baseline, so detection always
/// succeeds there). 128-bit registers hold two lanes, so the 4-lane
/// reduction convention uses a register pair; elementwise kernels step
/// two lanes at a time. Same no-FMA rule as the AVX2 bodies.
#[cfg(target_arch = "aarch64")]
mod neon {
    #![allow(clippy::missing_safety_doc)]

    use super::{AdamCoeffs, GEMM_NR};
    use core::arch::aarch64::*;

    #[target_feature(enable = "neon")]
    pub unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len();
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        // acc01 carries lanes 0/1, acc23 lanes 2/3 of the 4-lane pattern.
        let mut acc01 = vdupq_n_f64(0.0);
        let mut acc23 = vdupq_n_f64(0.0);
        let mut i = 0;
        while i + 4 <= n {
            acc01 = vaddq_f64(acc01, vmulq_f64(vld1q_f64(ap.add(i)), vld1q_f64(bp.add(i))));
            acc23 = vaddq_f64(
                acc23,
                vmulq_f64(vld1q_f64(ap.add(i + 2)), vld1q_f64(bp.add(i + 2))),
            );
            i += 4;
        }
        let mut tail = 0.0;
        while i < n {
            tail += *ap.add(i) * *bp.add(i);
            i += 1;
        }
        let l = [
            vgetq_lane_f64::<0>(acc01),
            vgetq_lane_f64::<1>(acc01),
            vgetq_lane_f64::<0>(acc23),
            vgetq_lane_f64::<1>(acc23),
        ];
        (l[0] + l[2]) + (l[1] + l[3]) + tail
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn sum(a: &[f64]) -> f64 {
        let n = a.len();
        let ap = a.as_ptr();
        let mut acc01 = vdupq_n_f64(0.0);
        let mut acc23 = vdupq_n_f64(0.0);
        let mut i = 0;
        while i + 4 <= n {
            acc01 = vaddq_f64(acc01, vld1q_f64(ap.add(i)));
            acc23 = vaddq_f64(acc23, vld1q_f64(ap.add(i + 2)));
            i += 4;
        }
        let mut tail = 0.0;
        while i < n {
            tail += *ap.add(i);
            i += 1;
        }
        let l = [
            vgetq_lane_f64::<0>(acc01),
            vgetq_lane_f64::<1>(acc01),
            vgetq_lane_f64::<0>(acc23),
            vgetq_lane_f64::<1>(acc23),
        ];
        (l[0] + l[2]) + (l[1] + l[3]) + tail
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn mul_into(dst: &mut [f64], a: &[f64], b: &[f64]) {
        let n = dst.len();
        let (dp, ap, bp) = (dst.as_mut_ptr(), a.as_ptr(), b.as_ptr());
        let mut i = 0;
        while i + 2 <= n {
            vst1q_f64(dp.add(i), vmulq_f64(vld1q_f64(ap.add(i)), vld1q_f64(bp.add(i))));
            i += 2;
        }
        while i < n {
            *dp.add(i) = *ap.add(i) * *bp.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn scale_into(dst: &mut [f64], c: f64, a: &[f64]) {
        let n = dst.len();
        let (dp, ap) = (dst.as_mut_ptr(), a.as_ptr());
        let cv = vdupq_n_f64(c);
        let mut i = 0;
        while i + 2 <= n {
            vst1q_f64(dp.add(i), vmulq_f64(cv, vld1q_f64(ap.add(i))));
            i += 2;
        }
        while i < n {
            *dp.add(i) = c * *ap.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn mul_assign(dst: &mut [f64], a: &[f64]) {
        let n = dst.len();
        let (dp, ap) = (dst.as_mut_ptr(), a.as_ptr());
        let mut i = 0;
        while i + 2 <= n {
            vst1q_f64(dp.add(i), vmulq_f64(vld1q_f64(dp.add(i)), vld1q_f64(ap.add(i))));
            i += 2;
        }
        while i < n {
            *dp.add(i) *= *ap.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn add_assign(dst: &mut [f64], a: &[f64]) {
        let n = dst.len();
        let (dp, ap) = (dst.as_mut_ptr(), a.as_ptr());
        let mut i = 0;
        while i + 2 <= n {
            vst1q_f64(dp.add(i), vaddq_f64(vld1q_f64(dp.add(i)), vld1q_f64(ap.add(i))));
            i += 2;
        }
        while i < n {
            *dp.add(i) += *ap.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn neg_into(dst: &mut [f64], a: &[f64]) {
        let n = dst.len();
        let (dp, ap) = (dst.as_mut_ptr(), a.as_ptr());
        let mut i = 0;
        while i + 2 <= n {
            vst1q_f64(dp.add(i), vnegq_f64(vld1q_f64(ap.add(i))));
            i += 2;
        }
        while i < n {
            *dp.add(i) = -*ap.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn axpb_into(dst: &mut [f64], x: f64, w: &[f64], b: &[f64]) {
        let n = dst.len();
        let (dp, wp, bp) = (dst.as_mut_ptr(), w.as_ptr(), b.as_ptr());
        let xv = vdupq_n_f64(x);
        let mut i = 0;
        while i + 2 <= n {
            vst1q_f64(
                dp.add(i),
                vaddq_f64(vmulq_f64(xv, vld1q_f64(wp.add(i))), vld1q_f64(bp.add(i))),
            );
            i += 2;
        }
        while i < n {
            *dp.add(i) = x * *wp.add(i) + *bp.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn xi_acc1(xi: &mut [f64], coeff: f64, ts: &[f64], a: &[f64]) {
        let n = xi.len();
        let (xp, tp, ap) = (xi.as_mut_ptr(), ts.as_ptr(), a.as_ptr());
        let cv = vdupq_n_f64(coeff);
        let mut i = 0;
        while i + 2 <= n {
            let prod = vmulq_f64(vmulq_f64(cv, vld1q_f64(tp.add(i))), vld1q_f64(ap.add(i)));
            vst1q_f64(xp.add(i), vaddq_f64(vld1q_f64(xp.add(i)), prod));
            i += 2;
        }
        while i < n {
            *xp.add(i) += coeff * *tp.add(i) * *ap.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn xi_acc2(xi: &mut [f64], coeff: f64, ts: &[f64], a: &[f64], b: &[f64]) {
        let n = xi.len();
        let (xp, tp, ap, bp) = (xi.as_mut_ptr(), ts.as_ptr(), a.as_ptr(), b.as_ptr());
        let cv = vdupq_n_f64(coeff);
        let mut i = 0;
        while i + 2 <= n {
            let prod = vmulq_f64(
                vmulq_f64(vmulq_f64(cv, vld1q_f64(tp.add(i))), vld1q_f64(ap.add(i))),
                vld1q_f64(bp.add(i)),
            );
            vst1q_f64(xp.add(i), vaddq_f64(vld1q_f64(xp.add(i)), prod));
            i += 2;
        }
        while i < n {
            *xp.add(i) += coeff * *tp.add(i) * *ap.add(i) * *bp.add(i);
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn horner_into(t: &[f64], coeffs: &[f64], out: &mut [f64]) {
        let n = t.len();
        let top = coeffs[coeffs.len() - 1];
        let low = &coeffs[..coeffs.len() - 1];
        let (tp, op) = (t.as_ptr(), out.as_mut_ptr());
        let mut i = 0;
        while i + 2 <= n {
            let tv = vld1q_f64(tp.add(i));
            let mut acc = vdupq_n_f64(top);
            for &ci in low.iter().rev() {
                acc = vaddq_f64(vmulq_f64(acc, tv), vdupq_n_f64(ci));
            }
            vst1q_f64(op.add(i), acc);
            i += 2;
        }
        while i < n {
            let ti = *tp.add(i);
            let mut acc = top;
            for &ci in low.iter().rev() {
                acc = acc * ti + ci;
            }
            *op.add(i) = acc;
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn horner_inplace(vals: &mut [f64], coeffs: &[f64]) {
        let n = vals.len();
        let top = coeffs[coeffs.len() - 1];
        let low = &coeffs[..coeffs.len() - 1];
        let vp = vals.as_mut_ptr();
        let mut i = 0;
        while i + 2 <= n {
            let tv = vld1q_f64(vp.add(i));
            let mut acc = vdupq_n_f64(top);
            for &ci in low.iter().rev() {
                acc = vaddq_f64(vmulq_f64(acc, tv), vdupq_n_f64(ci));
            }
            vst1q_f64(vp.add(i), acc);
            i += 2;
        }
        while i < n {
            let ti = *vp.add(i);
            let mut acc = top;
            for &ci in low.iter().rev() {
                acc = acc * ti + ci;
            }
            *vp.add(i) = acc;
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn gelu_tail(xs: &[f64], cdf: &[f64], pdf: &[f64], n: usize, out: &mut [f64], stride: usize) {
        let m = xs.len();
        let (xp, cp, pp, op) = (xs.as_ptr(), cdf.as_ptr(), pdf.as_ptr(), out.as_mut_ptr());
        let mut e = 0;
        while e + 2 <= m {
            let x = vld1q_f64(xp.add(e));
            let c = vld1q_f64(cp.add(e));
            vst1q_f64(op.add(e), vmulq_f64(x, c));
            if n >= 1 {
                let p = vld1q_f64(pp.add(e));
                vst1q_f64(op.add(stride + e), vaddq_f64(c, vmulq_f64(x, p)));
                let mut h0 = vdupq_n_f64(1.0);
                let mut h1 = x;
                for k in 2..=n {
                    let hk = vsubq_f64(
                        vmulq_f64(x, h1),
                        vmulq_f64(vdupq_n_f64((k - 1) as f64), h0),
                    );
                    let sign = if (k - 1) % 2 == 0 { 1.0 } else { -1.0 };
                    vst1q_f64(
                        op.add(k * stride + e),
                        vmulq_f64(vmulq_f64(vdupq_n_f64(sign), p), vsubq_f64(hk, h0)),
                    );
                    h0 = h1;
                    h1 = hk;
                }
            }
            e += 2;
        }
        while e < m {
            let x = *xp.add(e);
            let c = *cp.add(e);
            *op.add(e) = x * c;
            if n >= 1 {
                let p = *pp.add(e);
                *op.add(stride + e) = c + x * p;
                let mut h0 = 1.0;
                let mut h1 = x;
                for k in 2..=n {
                    let hk = x * h1 - (k - 1) as f64 * h0;
                    let sign = if (k - 1) % 2 == 0 { 1.0 } else { -1.0 };
                    *op.add(k * stride + e) = sign * p * (hk - h0);
                    h0 = h1;
                    h1 = hk;
                }
            }
            e += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn gemm_micro_4x8(
        ar: [&[f64]; 4],
        panel: &[f64],
        c: &mut [f64],
        row_stride: usize,
        first: bool,
    ) {
        let mut acc = [[vdupq_n_f64(0.0); 4]; 4];
        for (p, bv) in panel.chunks_exact(GEMM_NR).enumerate() {
            let b = [
                vld1q_f64(bv.as_ptr()),
                vld1q_f64(bv.as_ptr().add(2)),
                vld1q_f64(bv.as_ptr().add(4)),
                vld1q_f64(bv.as_ptr().add(6)),
            ];
            for (accr, row) in acc.iter_mut().zip(&ar) {
                let a = vdupq_n_f64(*row.get_unchecked(p));
                for (o, &bb) in accr.iter_mut().zip(&b) {
                    *o = vaddq_f64(*o, vmulq_f64(a, bb));
                }
            }
        }
        for (r, accr) in acc.iter().enumerate() {
            let co = c.as_mut_ptr().add(r * row_stride);
            if first {
                for (q, &v) in accr.iter().enumerate() {
                    vst1q_f64(co.add(2 * q), v);
                }
            } else {
                for (q, &v) in accr.iter().enumerate() {
                    let pq = co.add(2 * q);
                    vst1q_f64(pq, vaddq_f64(vld1q_f64(pq), v));
                }
            }
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn adam_block(m: &mut [f64], v: &mut [f64], th: &mut [f64], g: &[f64], co: AdamCoeffs) {
        let n = g.len();
        let (mp, vp, tp, gp) = (m.as_mut_ptr(), v.as_mut_ptr(), th.as_mut_ptr(), g.as_ptr());
        let b1 = vdupq_n_f64(co.beta1);
        let b2 = vdupq_n_f64(co.beta2);
        let omb1 = vdupq_n_f64(1.0 - co.beta1);
        let omb2 = vdupq_n_f64(1.0 - co.beta2);
        let lrt = vdupq_n_f64(co.lr_t);
        let eps = vdupq_n_f64(co.eps);
        let mut i = 0;
        while i + 2 <= n {
            let gv = vld1q_f64(gp.add(i));
            let mv = vaddq_f64(vmulq_f64(b1, vld1q_f64(mp.add(i))), vmulq_f64(omb1, gv));
            vst1q_f64(mp.add(i), mv);
            let vv = vaddq_f64(
                vmulq_f64(b2, vld1q_f64(vp.add(i))),
                vmulq_f64(vmulq_f64(omb2, gv), gv),
            );
            vst1q_f64(vp.add(i), vv);
            let step = vdivq_f64(vmulq_f64(lrt, mv), vaddq_f64(vsqrtq_f64(vv), eps));
            vst1q_f64(tp.add(i), vsubq_f64(vld1q_f64(tp.add(i)), step));
            i += 2;
        }
        while i < n {
            let gi = *gp.add(i);
            let mi = co.beta1 * *mp.add(i) + (1.0 - co.beta1) * gi;
            *mp.add(i) = mi;
            let vi = co.beta2 * *vp.add(i) + (1.0 - co.beta2) * gi * gi;
            *vp.add(i) = vi;
            *tp.add(i) -= co.lr_t * mi / (vi.sqrt() + co.eps);
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn sgd_block(v: &mut [f64], th: &mut [f64], g: &[f64], lr: f64, momentum: f64) {
        let n = g.len();
        let (vp, tp, gp) = (v.as_mut_ptr(), th.as_mut_ptr(), g.as_ptr());
        let mo = vdupq_n_f64(momentum);
        let lrv = vdupq_n_f64(lr);
        let mut i = 0;
        while i + 2 <= n {
            let vv = vsubq_f64(
                vmulq_f64(mo, vld1q_f64(vp.add(i))),
                vmulq_f64(lrv, vld1q_f64(gp.add(i))),
            );
            vst1q_f64(vp.add(i), vv);
            vst1q_f64(tp.add(i), vaddq_f64(vld1q_f64(tp.add(i)), vv));
            i += 2;
        }
        while i < n {
            let vi = momentum * *vp.add(i) - lr * *gp.add(i);
            *vp.add(i) = vi;
            *tp.add(i) += vi;
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn all_finite(xs: &[f64]) -> bool {
        let n = xs.len();
        let xp = xs.as_ptr();
        // |x| < +inf per lane (NaN compares false) — exactly
        // `f64::is_finite`.
        let inf = vdupq_n_f64(f64::INFINITY);
        let mut i = 0;
        while i + 2 <= n {
            let ok = vcltq_f64(vabsq_f64(vld1q_f64(xp.add(i))), inf);
            if vgetq_lane_u64::<0>(ok) == 0 || vgetq_lane_u64::<1>(ok) == 0 {
                return false;
            }
            i += 2;
        }
        while i < n {
            if !(*xp.add(i)).is_finite() {
                return false;
            }
            i += 1;
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn resolve_honors_explicit_requests() {
        assert_eq!(Isa::resolve(Some("scalar")), Isa::Scalar);
        assert_eq!(Isa::resolve(Some(" Scalar ")), Isa::Scalar);
        assert_eq!(Isa::resolve(None), Isa::detect());
        assert_eq!(Isa::resolve(Some("auto")), Isa::detect());
        assert_eq!(Isa::resolve(Some("definitely-not-an-isa")), Isa::detect());
        if let Some(v) = Isa::vector() {
            assert_eq!(Isa::resolve(Some(v.name())), v);
        }
        // An explicitly requested vector ISA the host cannot run falls
        // back to scalar instead of crashing.
        #[cfg(not(target_arch = "aarch64"))]
        assert_eq!(Isa::resolve(Some("neon")), Isa::Scalar);
        #[cfg(not(target_arch = "x86_64"))]
        assert_eq!(Isa::resolve(Some("avx2")), Isa::Scalar);
    }

    #[test]
    fn names_roundtrip_through_resolve() {
        assert_eq!(Isa::resolve(Some(Isa::Scalar.name())), Isa::Scalar);
        assert_eq!(Isa::active(), Isa::active(), "active() is stable");
    }

    /// Every elementwise kernel is bitwise scalar == vector at lengths
    /// that exercise both the vector body and its scalar tail.
    #[test]
    fn elementwise_kernels_match_scalar_bitwise() {
        let Some(v) = Isa::vector() else {
            eprintln!("skipping: no vector ISA on this host");
            return;
        };
        let mut rng = Prng::seeded(0x51D);
        for len in [1usize, 2, 3, 4, 5, 7, 8, 31, 128, 1001] {
            let a = rng.normal_vec(len, 0.0, 1.0);
            let b = rng.normal_vec(len, 0.0, 1.0);
            let base = rng.normal_vec(len, 0.0, 1.0);

            let pairs: [(&str, fn(Isa, &mut [f64], &[f64], &[f64]) -> ()); 4] = [
                ("mul_into", |isa, d, x, y| isa.mul_into(d, x, y)),
                ("add_assign", |isa, d, x, _| isa.add_assign(d, x)),
                ("mul_assign", |isa, d, x, _| isa.mul_assign(d, x)),
                ("neg_into", |isa, d, x, _| isa.neg_into(d, x)),
            ];
            for (name, k) in pairs {
                let mut ds = base.clone();
                let mut dv = base.clone();
                k(Isa::Scalar, &mut ds, &a, &b);
                k(v, &mut dv, &a, &b);
                assert_eq!(ds, dv, "{name} len={len}");
            }

            assert_eq!(
                Isa::Scalar.dot(&a, &b).to_bits(),
                v.dot(&a, &b).to_bits(),
                "dot len={len}"
            );
            assert_eq!(Isa::Scalar.sum(&a).to_bits(), v.sum(&a).to_bits(), "sum len={len}");

            let mut xs = base.clone();
            let mut xv = base.clone();
            Isa::Scalar.xi_acc2(&mut xs, 1.75, &a, &b, &base.clone());
            v.xi_acc2(&mut xv, 1.75, &a, &b, &base.clone());
            assert_eq!(xs, xv, "xi_acc2 len={len}");

            let coeffs = [0.5, -1.25, 2.0, 0.125, -0.75];
            let mut hs = vec![0.0; len];
            let mut hv = vec![0.0; len];
            Isa::Scalar.horner_into(&a, &coeffs, &mut hs);
            v.horner_into(&a, &coeffs, &mut hv);
            assert_eq!(hs, hv, "horner len={len}");
        }
    }

    /// `all_finite` agrees with the scalar specification for every ISA:
    /// clean blocks, and each poison kind (NaN, ±∞) planted at positions
    /// covering every vector lane and the scalar tail.
    #[test]
    fn all_finite_matches_scalar_for_every_poison_position() {
        let isas: Vec<Isa> = std::iter::once(Isa::Scalar).chain(Isa::vector()).collect();
        let mut rng = Prng::seeded(0xF1A7);
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 63, 64, 130] {
            let clean = rng.normal_vec(len, 0.0, 1e3);
            for &isa in &isas {
                assert!(isa.all_finite(&clean), "{} len={len} clean", isa.name());
            }
            for poison in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
                for pos in 0..len {
                    let mut xs = clean.clone();
                    xs[pos] = poison;
                    for &isa in &isas {
                        assert!(
                            !isa.all_finite(&xs),
                            "{} len={len} pos={pos} poison={poison}",
                            isa.name()
                        );
                    }
                }
            }
        }
        // Subnormals, zeros and extreme-but-finite magnitudes are finite.
        let edge = [0.0, -0.0, f64::MIN_POSITIVE / 2.0, f64::MAX, f64::MIN];
        for &isa in &isas {
            assert!(isa.all_finite(&edge), "{} edge values", isa.name());
        }
    }
}
