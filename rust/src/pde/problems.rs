//! The PDE scenario library: named multi-dimensional problems with
//! manufactured exact solutions, source terms and box domains.
//!
//! Every problem is posed in *residual* form `L[u] = f` with Dirichlet
//! data from the exact solution on the full box boundary (for the
//! time-dependent problems that includes the initial face — the usual
//! manufactured-solution PINN setup). The exact solutions make every
//! scenario self-validating: training reports a true L2 error, the wire
//! protocol can serve residuals of known fields, and the golden tests
//! pin the operators against closed forms.

use super::operator::DiffOperator;
use crate::tensor::Tensor;
use crate::util::prng::Prng;
use std::f64::consts::PI;

/// Diffusivity κ of [`PdeProblem::Heat2d`] and [`PdeProblem::Heat100d`].
pub const HEAT_KAPPA: f64 = 0.1;
/// Wave speed c of [`PdeProblem::Wave2d`].
pub const WAVE_SPEED: f64 = 1.0;
/// Soliton speed c of [`PdeProblem::Kdv`].
pub const KDV_SPEED: f64 = 0.8;
/// Diffusion coefficient σ of [`PdeProblem::Hjb10d`].
pub const HJB_SIGMA: f64 = 0.5;
/// Control-cost coefficient μ of [`PdeProblem::Hjb10d`]'s `|∇u|²` term.
pub const HJB_MU: f64 = 0.25;

/// A named PDE scenario over a box domain.
///
/// ```
/// use ntangent::pde::PdeProblem;
///
/// let heat = PdeProblem::from_name("heat2d").unwrap();
/// assert_eq!(heat.dim(), 2);
/// assert_eq!(heat.operator().describe(), "d10-0.1*d02");
/// // The exact solution satisfies L[u*] = f (here f = 0).
/// assert_eq!(heat.source(&[0.3, 0.7]), 0.0);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PdeProblem {
    /// 1+1-D heat equation `u_t − κ·u_xx = 0` over `(t, x) ∈ [0,1]²`,
    /// `u* = exp(−κπ²t)·sin(πx)`.
    Heat2d,
    /// 2-D Poisson `Δu = f` over `(x, y) ∈ [0,1]²`,
    /// `u* = sin(πx)·sin(πy)`, `f = −2π²·u*`.
    Poisson2d,
    /// 1+1-D wave equation `u_tt − c²·u_xx = 0` over `(t, x) ∈ [0,1]²`,
    /// `u* = cos(πct)·sin(πx)`.
    Wave2d,
    /// Korteweg-de Vries `u_t + u·u_x + u_xxx = 0` over
    /// `t ∈ [0,1], x ∈ [−6,6]`, single soliton
    /// `u* = 3c·sech²(√c·(x − ct)/2)` — the nonlinear-term showcase.
    Kdv,
    /// 2-D biharmonic `Δ²u = f` over `(x, y) ∈ [0,1]²`,
    /// `u* = sin(πx)·sin(πy)`, `f = 4π⁴·u*` — the order-4 stress test.
    Biharmonic2d,
    /// 10-D Poisson `Δu = f` over `[0,1]^10`,
    /// `u* = (1/10)·Σᵢ sin(πxᵢ)`, `f = −π²·u*` — the exact plan needs
    /// 55 directions here; the STDE path samples a handful of axes.
    Poisson10d,
    /// 100-D heat equation `u_t − κ·Δ_x u = 0` over
    /// `t ∈ [0,1], x ∈ [0,1]^99`,
    /// `u* = exp(−κπ²t)·(1/99)·Σᵢ sin(πxᵢ)` — 100 pure-axis terms,
    /// far beyond any exact plan (5050 directions), the STDE showcase.
    Heat100d,
    /// 10-D Hamilton–Jacobi–Bellman example
    /// `u_t + σ·Δ_x u − μ·|∇_x u|² = f` over `t ∈ [0,1], x ∈ [0,1]^9`,
    /// `u* = exp(−t)·(1/9)·Σᵢ sin(πxᵢ)` — the high-dim *nonlinear*
    /// stress test (9 quadratic gradient terms).
    Hjb10d,
}

impl PdeProblem {
    /// Every library problem, in CLI listing order.
    pub const ALL: [PdeProblem; 8] = [
        PdeProblem::Heat2d,
        PdeProblem::Poisson2d,
        PdeProblem::Wave2d,
        PdeProblem::Kdv,
        PdeProblem::Biharmonic2d,
        PdeProblem::Poisson10d,
        PdeProblem::Heat100d,
        PdeProblem::Hjb10d,
    ];

    /// CLI / wire name.
    pub fn name(self) -> &'static str {
        match self {
            PdeProblem::Heat2d => "heat2d",
            PdeProblem::Poisson2d => "poisson2d",
            PdeProblem::Wave2d => "wave2d",
            PdeProblem::Kdv => "kdv",
            PdeProblem::Biharmonic2d => "biharmonic2d",
            PdeProblem::Poisson10d => "poisson10d",
            PdeProblem::Heat100d => "heat100d",
            PdeProblem::Hjb10d => "hjb10d",
        }
    }

    /// Look a problem up by its [`PdeProblem::name`].
    pub fn from_name(name: &str) -> Option<PdeProblem> {
        PdeProblem::ALL.iter().copied().find(|p| p.name() == name)
    }

    /// Number of input axes (time-like axes count; the classic library
    /// is 2-D, the stochastic-estimator workloads go to 10 and 100).
    pub fn dim(self) -> usize {
        match self {
            PdeProblem::Poisson10d | PdeProblem::Hjb10d => 10,
            PdeProblem::Heat100d => 100,
            _ => 2,
        }
    }

    /// `true` for the problems whose dimension puts the *exact*
    /// `JetPlan` out of reach (its direction count is combinatorial in
    /// [`PdeProblem::dim`]) — these train and validate through the
    /// stochastic estimator ([`crate::ntp::stde`]).
    pub fn needs_stde(self) -> bool {
        self.dim() > 10
    }

    /// The differential operator `L` of the residual `L[u] − f`.
    pub fn operator(self) -> DiffOperator {
        match self {
            PdeProblem::Heat2d => DiffOperator::new(2)
                .with_term(1.0, vec![1, 0])
                .with_term(-HEAT_KAPPA, vec![0, 2]),
            PdeProblem::Poisson2d => DiffOperator::laplacian(2),
            PdeProblem::Wave2d => DiffOperator::new(2)
                .with_term(1.0, vec![2, 0])
                .with_term(-WAVE_SPEED * WAVE_SPEED, vec![0, 2]),
            PdeProblem::Kdv => DiffOperator::new(2)
                .with_term(1.0, vec![1, 0])
                .with_product(1.0, vec![vec![0, 0], vec![0, 1]])
                .with_term(1.0, vec![0, 3]),
            PdeProblem::Biharmonic2d => DiffOperator::biharmonic(2),
            PdeProblem::Poisson10d => DiffOperator::laplacian(10),
            PdeProblem::Heat100d => {
                // ∂_t − κ·Σ_{i=1..99} ∂²_i over (t, x₁..x₉₉).
                let d = 100;
                let mut time = vec![0; d];
                time[0] = 1;
                let mut op = DiffOperator::new(d).with_term(1.0, time);
                for i in 1..d {
                    let mut alpha = vec![0; d];
                    alpha[i] = 2;
                    op = op.with_term(-HEAT_KAPPA, alpha);
                }
                op
            }
            PdeProblem::Hjb10d => {
                // ∂_t + σ·Δ_x − μ·Σ_{i=1..9} (∂_i u)² over (t, x₁..x₉).
                let d = 10;
                let mut time = vec![0; d];
                time[0] = 1;
                let mut op = DiffOperator::new(d).with_term(1.0, time);
                for i in 1..d {
                    let mut alpha = vec![0; d];
                    alpha[i] = 2;
                    op = op.with_term(HJB_SIGMA, alpha);
                }
                for i in 1..d {
                    let mut grad = vec![0; d];
                    grad[i] = 1;
                    op = op.with_product(-HJB_MU, vec![grad.clone(), grad]);
                }
                op
            }
        }
    }

    /// Per-axis bounds of the box domain.
    pub fn domain(self) -> Vec<(f64, f64)> {
        match self {
            PdeProblem::Kdv => vec![(0.0, 1.0), (-6.0, 6.0)],
            _ => vec![(0.0, 1.0); self.dim()],
        }
    }

    /// The manufactured exact solution `u*` at point `p` (length
    /// [`PdeProblem::dim`]).
    pub fn u_exact(self, p: &[f64]) -> f64 {
        match self {
            PdeProblem::Heat2d => {
                let (t, x) = (p[0], p[1]);
                (-HEAT_KAPPA * PI * PI * t).exp() * (PI * x).sin()
            }
            PdeProblem::Poisson2d | PdeProblem::Biharmonic2d => {
                (PI * p[0]).sin() * (PI * p[1]).sin()
            }
            PdeProblem::Wave2d => {
                let (t, x) = (p[0], p[1]);
                (PI * WAVE_SPEED * t).cos() * (PI * x).sin()
            }
            PdeProblem::Kdv => {
                let (t, x) = (p[0], p[1]);
                let arg = KDV_SPEED.sqrt() * (x - KDV_SPEED * t) / 2.0;
                let sech = 1.0 / arg.cosh();
                3.0 * KDV_SPEED * sech * sech
            }
            PdeProblem::Poisson10d => {
                let d = p.len() as f64;
                p.iter().map(|&x| (PI * x).sin()).sum::<f64>() / d
            }
            PdeProblem::Heat100d => {
                let spatial = &p[1..];
                let mean =
                    spatial.iter().map(|&x| (PI * x).sin()).sum::<f64>() / spatial.len() as f64;
                (-HEAT_KAPPA * PI * PI * p[0]).exp() * mean
            }
            PdeProblem::Hjb10d => {
                let spatial = &p[1..];
                let mean =
                    spatial.iter().map(|&x| (PI * x).sin()).sum::<f64>() / spatial.len() as f64;
                (-p[0]).exp() * mean
            }
        }
    }

    /// The source `f` with `L[u*] = f` at point `p` (zero for the
    /// evolution equations, analytic for Poisson/biharmonic).
    pub fn source(self, p: &[f64]) -> f64 {
        match self {
            PdeProblem::Heat2d
            | PdeProblem::Wave2d
            | PdeProblem::Kdv
            | PdeProblem::Heat100d => 0.0,
            PdeProblem::Poisson2d => -2.0 * PI * PI * self.u_exact(p),
            PdeProblem::Biharmonic2d => 4.0 * PI.powi(4) * self.u_exact(p),
            PdeProblem::Poisson10d => -PI * PI * self.u_exact(p),
            PdeProblem::Hjb10d => {
                // f = u*_t + σ·Δ_x u* − μ·|∇_x u*|²
                //   = −(1 + σπ²)·u* − μ·(π·e^{−t}/9)²·Σ cos²(πxᵢ).
                let u = self.u_exact(p);
                let scale = PI * (-p[0]).exp() / 9.0;
                let grad_sq: f64 = p[1..]
                    .iter()
                    .map(|&x| {
                        let c = scale * (PI * x).cos();
                        c * c
                    })
                    .sum();
                -(1.0 + HJB_SIGMA * PI * PI) * u - HJB_MU * grad_sq
            }
        }
    }

    /// Second boundary operator for problems whose order exceeds 2:
    /// prescribing `u` alone does not determine a 4th-order field (any
    /// `h` with `Δ²h = 0`, `h|∂Ω = 0` could be added), so the
    /// biharmonic problem additionally pins `Δu` on the boundary — the
    /// standard `(u, Δu)` Navier pair, whose exact trace is analytic
    /// for the manufactured solution. `None` for the order-≤3 problems.
    pub fn boundary_operator(self) -> Option<DiffOperator> {
        match self {
            PdeProblem::Biharmonic2d => Some(DiffOperator::laplacian(2)),
            _ => None,
        }
    }

    /// Exact trace of [`PdeProblem::boundary_operator`] at point `p`
    /// (panics for problems without one).
    pub fn boundary_operator_exact(self, p: &[f64]) -> f64 {
        match self {
            // Δ(sin πx · sin πy) = −2π²·u*.
            PdeProblem::Biharmonic2d => -2.0 * PI * PI * self.u_exact(p),
            _ => panic!("{} has no secondary boundary operator", self.name()),
        }
    }

    /// `n` interior collocation points, uniform in the box, `[n, dim]`.
    pub fn sample_interior(self, n: usize, rng: &mut Prng) -> Tensor {
        let domain = self.domain();
        let d = domain.len();
        let mut data = Vec::with_capacity(n * d);
        for _ in 0..n {
            for &(lo, hi) in &domain {
                data.push(lo + (hi - lo) * rng.uniform());
            }
        }
        Tensor::from_vec(data, &[n, d])
    }

    /// `n` boundary points, cycling over the box faces (axis 0 low, axis
    /// 0 high, axis 1 low, ...), uniform over each face, `[n, dim]`.
    pub fn sample_boundary(self, n: usize, rng: &mut Prng) -> Tensor {
        let domain = self.domain();
        let d = domain.len();
        let mut data = Vec::with_capacity(n * d);
        for i in 0..n {
            let face = i % (2 * d);
            let axis = face / 2;
            let hi_side = face % 2 == 1;
            for (a, &(lo, hi)) in domain.iter().enumerate() {
                if a == axis {
                    data.push(if hi_side { hi } else { lo });
                } else {
                    data.push(lo + (hi - lo) * rng.uniform());
                }
            }
        }
        Tensor::from_vec(data, &[n, d])
    }

    /// Exact-solution values at the rows of `x: [B, dim]`, shaped
    /// `[B, 1]` (Dirichlet targets / validation truth).
    pub fn u_exact_rows(self, x: &Tensor) -> Tensor {
        let d = self.dim();
        let b = x.shape()[0];
        let data: Vec<f64> = x.data().chunks_exact(d).map(|p| self.u_exact(p)).collect();
        Tensor::from_vec(data, &[b, 1])
    }

    /// Source values at the rows of `x: [B, dim]`, shaped `[B, 1]`.
    pub fn source_rows(self, x: &Tensor) -> Tensor {
        let d = self.dim();
        let b = x.shape()[0];
        let data: Vec<f64> = x.data().chunks_exact(d).map(|p| self.source(p)).collect();
        Tensor::from_vec(data, &[b, 1])
    }

    /// [`PdeProblem::boundary_operator_exact`] values at the rows of
    /// `x: [B, dim]`, shaped `[B, 1]`.
    pub fn boundary_operator_rows(self, x: &Tensor) -> Tensor {
        let d = self.dim();
        let b = x.shape()[0];
        let data: Vec<f64> = x
            .data()
            .chunks_exact(d)
            .map(|p| self.boundary_operator_exact(p))
            .collect();
        Tensor::from_vec(data, &[b, 1])
    }
}

/// Resolve an operator argument: a library problem name (`"poisson2d"`)
/// or a [`DiffOperator::parse`] spec (`"d20+d02"`), checked against
/// `dim`.
pub fn resolve_operator(spec: &str, dim: usize) -> Result<DiffOperator, String> {
    if let Some(p) = PdeProblem::from_name(spec) {
        if p.dim() != dim {
            return Err(format!(
                "operator '{spec}' is {}-dimensional but the model input is {dim}-dimensional",
                p.dim()
            ));
        }
        return Ok(p.operator());
    }
    DiffOperator::parse(spec, dim)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Nested central finite difference of `f` at `p` for multi-index
    /// `alpha` — an operator-independent oracle for the library's
    /// exact-solution/source pairs.
    fn fd_partial(f: &dyn Fn(&[f64]) -> f64, p: &[f64], alpha: &[usize], h: f64) -> f64 {
        match alpha.iter().position(|&a| a > 0) {
            None => f(p),
            Some(axis) => {
                let mut lower = alpha.to_vec();
                lower[axis] -= 1;
                let mut pp = p.to_vec();
                pp[axis] += h;
                let hi = fd_partial(f, &pp, &lower, h);
                pp[axis] = p[axis] - h;
                let lo = fd_partial(f, &pp, &lower, h);
                (hi - lo) / (2.0 * h)
            }
        }
    }

    /// Every library problem's exact solution satisfies its PDE:
    /// `L[u*](p) ≈ f(p)` under a finite-difference evaluation of the
    /// operator (tolerance scaled to the FD truncation error of the
    /// operator's order).
    #[test]
    fn exact_solutions_satisfy_their_pdes() {
        for problem in PdeProblem::ALL {
            let op = problem.operator();
            // Absolute FD truncation budget: h²·(next derivative scale)
            // per nested difference, growing with the operator order.
            let tol = match op.max_order() {
                0..=2 => 0.05,
                3 => 0.2,
                _ => 3.0,
            };
            for trial in 0..3usize {
                // Deterministic interior fractions of the right arity,
                // mapped into the problem's own domain (works for the
                // 2-D classics and the 10/100-D estimator workloads).
                let dom = problem.domain();
                let p: Vec<f64> = dom
                    .iter()
                    .enumerate()
                    .map(|(axis, &(lo, hi))| {
                        let frac = (0.17 + 0.61 * (axis + 3 * trial) as f64).fract() * 0.8 + 0.1;
                        lo + (hi - lo) * frac
                    })
                    .collect();
                let f = |q: &[f64]| problem.u_exact(q);
                let mut lhs = 0.0;
                for term in op.terms() {
                    let mut prod = term.coeff;
                    for alpha in &term.factors {
                        prod *= fd_partial(&f, &p, alpha, 0.02);
                    }
                    lhs += prod;
                }
                let rhs = problem.source(&p);
                assert!(
                    (lhs - rhs).abs() < tol,
                    "{}: L[u*]({p:?}) = {lhs} vs f = {rhs}",
                    problem.name()
                );
            }
        }
    }

    /// The biharmonic second boundary condition is the exact Laplacian
    /// trace of the manufactured solution (FD oracle), and only the
    /// order-4 problem carries one.
    #[test]
    fn secondary_boundary_operator_matches_exact_trace() {
        for p in PdeProblem::ALL {
            match p.boundary_operator() {
                None => assert!(p.operator().max_order() <= 3, "{}", p.name()),
                Some(bop) => {
                    assert_eq!(bop, DiffOperator::laplacian(2));
                    let f = |q: &[f64]| p.u_exact(q);
                    for pt in [[0.0, 0.37], [1.0, 0.21], [0.64, 0.0]] {
                        let mut lhs = 0.0;
                        for term in bop.terms() {
                            let mut prod = term.coeff;
                            for alpha in &term.factors {
                                prod *= fd_partial(&f, &pt, alpha, 0.01);
                            }
                            lhs += prod;
                        }
                        let want = p.boundary_operator_exact(&pt);
                        assert!(
                            (lhs - want).abs() < 0.05,
                            "{} at {pt:?}: Δu* = {lhs} vs exact {want}",
                            p.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn names_roundtrip_and_dims_match() {
        for p in PdeProblem::ALL {
            assert_eq!(PdeProblem::from_name(p.name()), Some(p));
            assert_eq!(p.operator().dim(), p.dim());
            assert_eq!(p.domain().len(), p.dim());
        }
        assert_eq!(PdeProblem::from_name("burgers9d"), None);
    }

    #[test]
    fn samplers_respect_the_domain() {
        let mut rng = Prng::seeded(11);
        for p in PdeProblem::ALL {
            let d = p.dim();
            let interior = p.sample_interior(40, &mut rng);
            assert_eq!(interior.shape(), &[40, d]);
            let dom = p.domain();
            for row in interior.data().chunks_exact(d) {
                for (x, &(lo, hi)) in row.iter().zip(&dom) {
                    assert!(*x >= lo && *x <= hi, "{} interior {row:?}", p.name());
                }
            }
            let boundary = p.sample_boundary(17, &mut rng);
            for row in boundary.data().chunks_exact(d) {
                let on_face = row
                    .iter()
                    .zip(&dom)
                    .any(|(x, &(lo, hi))| *x == lo || *x == hi);
                assert!(on_face, "{} boundary point {row:?} not on a face", p.name());
            }
        }
    }

    #[test]
    fn resolve_operator_accepts_names_and_specs() {
        assert_eq!(
            resolve_operator("poisson2d", 2).unwrap(),
            DiffOperator::laplacian(2)
        );
        assert_eq!(
            resolve_operator("d20+d02", 2).unwrap(),
            DiffOperator::laplacian(2)
        );
        assert!(resolve_operator("poisson2d", 3).is_err());
        assert!(resolve_operator("nonsense", 2).is_err());
    }

    #[test]
    fn exact_rows_match_pointwise_eval() {
        let mut rng = Prng::seeded(3);
        let p = PdeProblem::Poisson2d;
        let x = p.sample_interior(9, &mut rng);
        let u = p.u_exact_rows(&x);
        let f = p.source_rows(&x);
        assert_eq!(u.shape(), &[9, 1]);
        for (i, row) in x.data().chunks_exact(2).enumerate() {
            assert_eq!(u.data()[i], p.u_exact(row));
            assert_eq!(f.data()[i], p.source(row));
        }
    }
}
