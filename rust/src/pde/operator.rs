//! Differential-operator descriptions: linear combinations of
//! mixed-partial products, with a text spec parser and exact evaluation
//! through directional jets (inference) or tape nodes (training).

use crate::autodiff::{Graph, NodeId};
use crate::ntp::MultiJet;
use crate::tensor::Tensor;
use std::collections::HashMap;

/// One term `coeff · Π_f ∂^{α_f} u` of a [`DiffOperator`].
///
/// A single factor makes the term linear in `u`; several factors encode
/// polynomial nonlinearities (KdV's advection `u·∂_x u` is
/// `coeff = 1, factors = [[0,0], [0,1]]`).
#[derive(Clone, Debug, PartialEq)]
pub struct OpTerm {
    /// Scalar coefficient of the term.
    pub coeff: f64,
    /// Multi-indices of the factors (`[0; dim]` is `u` itself).
    pub factors: Vec<Vec<usize>>,
}

/// Structural sparsity of a [`DiffOperator`] (see
/// [`DiffOperator::sparsity`]): the raw material for operator-adapted
/// stochastic sampling.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct OpSparsity {
    /// Sorted axes that appear with a nonzero derivative order in any
    /// factor (axes the operator never differentiates along are absent).
    pub axes: Vec<usize>,
    /// Number of terms available to the term subsampler.
    pub n_terms: usize,
    /// Largest per-factor axis support (how many axes a single `∂^α`
    /// factor couples; 0 for a derivative-free operator).
    pub max_support: usize,
    /// `true` when every factor differentiates along at most one axis —
    /// the cheap case where each sampled term costs a single direction.
    pub pure_axis: bool,
}

/// A differential operator `L[u] = Σ_t coeff_t · Π_f ∂^{α_{t,f}} u` over
/// `dim` input axes.
///
/// ```
/// use ntangent::pde::DiffOperator;
///
/// // Heat operator ∂_t − κ·∂_xx over (t, x), κ = 0.1:
/// let heat = DiffOperator::new(2)
///     .with_term(1.0, vec![1, 0])
///     .with_term(-0.1, vec![0, 2]);
/// assert_eq!(heat.max_order(), 2);
/// assert!(heat.is_linear());
/// assert_eq!(heat, DiffOperator::parse("d10-0.1*d02", 2).unwrap());
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct DiffOperator {
    dim: usize,
    terms: Vec<OpTerm>,
}

impl DiffOperator {
    /// An empty operator over `dim` axes (add terms with
    /// [`DiffOperator::with_term`] / [`DiffOperator::with_product`]).
    ///
    /// Any `dim ≥ 1` is accepted for programmatic construction — the
    /// high-dimensional library problems build 10-D and 100-D operators
    /// this way. The *text* grammar ([`DiffOperator::parse`]) stays
    /// one-digit-per-axis and therefore caps at `dim ≤ 9`.
    pub fn new(dim: usize) -> DiffOperator {
        assert!(dim >= 1, "operator needs at least one input axis");
        DiffOperator { dim, terms: Vec::new() }
    }

    /// Append a linear term `coeff · ∂^α u`.
    pub fn with_term(self, coeff: f64, alpha: Vec<usize>) -> DiffOperator {
        self.with_product(coeff, vec![alpha])
    }

    /// Append a product term `coeff · Π_f ∂^{α_f} u` (the nonlinear-term
    /// hook).
    pub fn with_product(mut self, coeff: f64, factors: Vec<Vec<usize>>) -> DiffOperator {
        assert!(!factors.is_empty(), "a term needs at least one factor");
        for f in &factors {
            assert_eq!(f.len(), self.dim, "factor arity must match the operator dim");
        }
        self.terms.push(OpTerm { coeff, factors });
        self
    }

    /// The Laplacian `Σ_i ∂²/∂x_i²` over `dim` axes.
    pub fn laplacian(dim: usize) -> DiffOperator {
        let mut op = DiffOperator::new(dim);
        for i in 0..dim {
            let mut alpha = vec![0; dim];
            alpha[i] = 2;
            op = op.with_term(1.0, alpha);
        }
        op
    }

    /// The biharmonic operator `Δ² = Σ_i Σ_j ∂²_i ∂²_j` over `dim` axes
    /// (in 2-D: `∂_xxxx + 2·∂_xxyy + ∂_yyyy`).
    pub fn biharmonic(dim: usize) -> DiffOperator {
        let mut op = DiffOperator::new(dim);
        for i in 0..dim {
            for j in i..dim {
                let mut alpha = vec![0; dim];
                alpha[i] += 2;
                alpha[j] += 2;
                op = op.with_term(if i == j { 1.0 } else { 2.0 }, alpha);
            }
        }
        op
    }

    /// Number of input axes.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// The terms, in insertion order.
    pub fn terms(&self) -> &[OpTerm] {
        &self.terms
    }

    /// Highest derivative order any factor requests (0 for the empty
    /// operator).
    pub fn max_order(&self) -> usize {
        self.terms
            .iter()
            .flat_map(|t| t.factors.iter())
            .map(|f| f.iter().sum())
            .max()
            .unwrap_or(0)
    }

    /// `true` when every term has a single factor (no `u`-products).
    pub fn is_linear(&self) -> bool {
        self.terms.iter().all(|t| t.factors.len() == 1)
    }

    /// The distinct multi-indices the operator needs, in first-use order.
    pub fn needed_partials(&self) -> Vec<Vec<usize>> {
        let mut out: Vec<Vec<usize>> = Vec::new();
        for term in &self.terms {
            for f in &term.factors {
                if !out.contains(f) {
                    out.push(f.clone());
                }
            }
        }
        out
    }

    /// Structural sparsity analysis — what the stochastic estimator's
    /// operator-adapted sampler keys on (see [`crate::ntp::stde`]): how
    /// many terms there are to subsample, which axes the operator
    /// touches at all, and how *coupled* each derivative factor is (a
    /// pure-axis factor like `∂²/∂x_i²` recombines from a single
    /// direction, while a `k`-axis mixed factor needs a `k`-dimensional
    /// moment system).
    pub fn sparsity(&self) -> OpSparsity {
        let mut axes: Vec<usize> = Vec::new();
        let mut max_support = 0usize;
        let mut pure_axis = true;
        for term in &self.terms {
            for f in &term.factors {
                let support = f.iter().filter(|&&a| a > 0).count();
                max_support = max_support.max(support);
                if support > 1 {
                    pure_axis = false;
                }
                for (axis, &a) in f.iter().enumerate() {
                    if a > 0 && !axes.contains(&axis) {
                        axes.push(axis);
                    }
                }
            }
        }
        axes.sort_unstable();
        OpSparsity {
            axes,
            n_terms: self.terms.len(),
            max_support,
            pure_axis,
        }
    }

    /// Parse a compact operator spec over `dim` axes.
    ///
    /// Grammar: terms joined by `+`/`-`; each term is `*`-separated
    /// factors, where a factor is a plain decimal coefficient, `u` (the
    /// function itself), or `d` followed by exactly `dim` digits — the
    /// per-axis derivative orders. Examples (2-D):
    /// `"d20+d02"` (Laplacian), `"d10-0.1*d02"` (heat, κ = 0.1),
    /// `"d10+u*d01+d03"` (KdV with the nonlinear advection product).
    pub fn parse(spec: &str, dim: usize) -> Result<DiffOperator, String> {
        let mut op = DiffOperator::new(dim);
        let s: Vec<char> = spec.chars().collect();
        let mut i = 0;
        let skip_ws = |i: &mut usize| {
            while *i < s.len() && s[*i].is_whitespace() {
                *i += 1;
            }
        };
        skip_ws(&mut i);
        if i == s.len() {
            return Err("empty operator spec".into());
        }
        let mut first = true;
        while i < s.len() {
            // Term sign ('+'/'-' separator; optional on the first term).
            let mut sign = 1.0;
            match s[i] {
                '+' => i += 1,
                '-' => {
                    sign = -1.0;
                    i += 1;
                }
                _ if first => {}
                other => return Err(format!("expected '+' or '-' before '{other}'")),
            }
            first = false;
            // Factors separated by '*'.
            let mut coeff = sign;
            let mut factors: Vec<Vec<usize>> = Vec::new();
            loop {
                skip_ws(&mut i);
                if i == s.len() {
                    return Err("operator spec ends inside a term".into());
                }
                match s[i] {
                    'd' => {
                        i += 1;
                        let mut alpha = Vec::with_capacity(dim);
                        for _ in 0..dim {
                            let c = *s
                                .get(i)
                                .ok_or_else(|| format!("'d' needs {dim} digits (one per axis)"))?;
                            let v = c
                                .to_digit(10)
                                .ok_or_else(|| format!("'d' needs {dim} digits, found '{c}'"))?;
                            alpha.push(v as usize);
                            i += 1;
                        }
                        factors.push(alpha);
                    }
                    'u' => {
                        i += 1;
                        factors.push(vec![0; dim]);
                    }
                    c if c.is_ascii_digit() || c == '.' => {
                        let start = i;
                        while i < s.len() && (s[i].is_ascii_digit() || s[i] == '.') {
                            i += 1;
                        }
                        let text: String = s[start..i].iter().collect();
                        let v: f64 = text
                            .parse()
                            .map_err(|_| format!("bad coefficient '{text}'"))?;
                        coeff *= v;
                    }
                    other => return Err(format!("unexpected '{other}' in operator spec")),
                }
                skip_ws(&mut i);
                if i < s.len() && s[i] == '*' {
                    i += 1;
                    continue;
                }
                break;
            }
            if factors.is_empty() {
                return Err("a term needs at least one 'd...' or 'u' factor".into());
            }
            op = op.with_product(coeff, factors);
            skip_ws(&mut i);
        }
        Ok(op)
    }

    /// Render the operator back into the [`DiffOperator::parse`] spec
    /// format.
    pub fn describe(&self) -> String {
        let mut out = String::new();
        for (t, term) in self.terms.iter().enumerate() {
            let mag = term.coeff.abs();
            if t == 0 {
                if term.coeff < 0.0 {
                    out.push('-');
                }
            } else {
                out.push(if term.coeff < 0.0 { '-' } else { '+' });
            }
            if (mag - 1.0).abs() > 1e-12 {
                out.push_str(&format!("{mag}*"));
            }
            let fs: Vec<String> = term
                .factors
                .iter()
                .map(|f| {
                    if f.iter().all(|&a| a == 0) {
                        "u".to_string()
                    } else {
                        let digits: String = f.iter().map(|a| a.to_string()).collect();
                        format!("d{digits}")
                    }
                })
                .collect();
            out.push_str(&fs.join("*"));
        }
        out
    }

    /// Evaluate the operator over a directional jet set:
    /// `L[u](x) : [B, out]`, every `∂^α` assembled exactly from the jets.
    pub fn apply(&self, jet: &MultiJet<'_>) -> Tensor {
        let mut acc: Option<Tensor> = None;
        for term in &self.terms {
            let mut prod: Option<Tensor> = None;
            for f in &term.factors {
                let p = jet.partial(f);
                prod = Some(match prod {
                    None => p,
                    Some(q) => q.mul(&p),
                });
            }
            let t = prod.expect("term has at least one factor").scale(term.coeff);
            acc = Some(match acc {
                None => t,
                Some(a) => a.add(&t),
            });
        }
        acc.expect("operator has at least one term")
    }

    /// Record the operator on a tape from prebuilt mixed-partial nodes
    /// (one entry per [`DiffOperator::needed_partials`] multi-index) —
    /// the training route: the returned node backprops through every
    /// factor.
    pub fn apply_nodes(&self, g: &mut Graph, partials: &HashMap<Vec<usize>, NodeId>) -> NodeId {
        let mut acc: Option<NodeId> = None;
        for term in &self.terms {
            let mut prod: Option<NodeId> = None;
            for f in &term.factors {
                let p = *partials
                    .get(f)
                    .expect("a partial node for every needed multi-index");
                prod = Some(match prod {
                    None => p,
                    Some(q) => g.mul(q, p),
                });
            }
            let t = g.scale(prod.expect("term has at least one factor"), term.coeff);
            acc = Some(match acc {
                None => t,
                Some(a) => g.add(a, t),
            });
        }
        acc.expect("operator has at least one term")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Mlp;
    use crate::ntp::MultiJetEngine;
    use crate::util::prng::Prng;

    #[test]
    fn parse_linear_operators() {
        let lap = DiffOperator::parse("d20+d02", 2).unwrap();
        assert_eq!(lap, DiffOperator::laplacian(2));
        let heat = DiffOperator::parse(" d10 - 0.1 * d02 ", 2).unwrap();
        assert_eq!(heat.terms().len(), 2);
        assert_eq!(heat.terms()[1].coeff, -0.1);
        assert_eq!(heat.terms()[1].factors, vec![vec![0, 2]]);
        assert_eq!(heat.max_order(), 2);
        let bih = DiffOperator::parse("d40+2*d22+d04", 2).unwrap();
        assert_eq!(bih, DiffOperator::biharmonic(2));
        assert_eq!(bih.max_order(), 4);
    }

    #[test]
    fn parse_nonlinear_and_roundtrip() {
        let kdv = DiffOperator::parse("d10+u*d01+d03", 2).unwrap();
        assert!(!kdv.is_linear());
        assert_eq!(kdv.terms()[1].factors, vec![vec![0, 0], vec![0, 1]]);
        assert_eq!(kdv.needed_partials().len(), 4);
        // describe() → parse() is the identity on structure.
        for spec in ["d20+d02", "d10-0.1*d02", "d10+u*d01+d03", "-2.5*d11+u*u"] {
            let op = DiffOperator::parse(spec, 2).unwrap();
            let back = DiffOperator::parse(&op.describe(), 2).unwrap();
            assert_eq!(op, back, "spec '{spec}' → '{}'", op.describe());
        }
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(DiffOperator::parse("", 2).is_err());
        assert!(DiffOperator::parse("   ", 2).is_err());
        assert!(DiffOperator::parse("d2", 2).is_err()); // needs dim digits
        assert!(DiffOperator::parse("d20+", 2).is_err());
        assert!(DiffOperator::parse("q20", 2).is_err());
        assert!(DiffOperator::parse("d20*", 2).is_err());
        assert!(DiffOperator::parse("1.2.3*d20", 2).is_err());
        // A bare coefficient is not a term: every term needs a u/d factor.
        assert!(DiffOperator::parse("2.0+d02", 2).is_err());
    }

    /// Error paths return messages that name the actual problem — the
    /// serving front forwards them verbatim to wire clients.
    #[test]
    fn parse_error_messages_name_the_problem() {
        let err = |spec: &str, dim: usize| DiffOperator::parse(spec, dim).unwrap_err();
        assert_eq!(err("", 2), "empty operator spec");
        assert_eq!(err("  \t ", 3), "empty operator spec");
        // Unknown term/factor: the offending character is quoted.
        assert!(err("q20", 2).contains("'q'"));
        assert!(err("d20+foo", 2).contains("'f'"));
        // Bad exponent digits: 'd' must be followed by exactly dim digits.
        assert!(err("dx0", 2).contains("2 digits"));
        assert!(err("d2", 2).contains("2 digits"));
        assert!(err("d2z1", 3).contains("3 digits"));
        // Trailing garbage after a complete term.
        assert!(err("d20 d02", 2).contains("expected '+' or '-'"));
        assert!(err("d20+d02!", 2).contains("'!'"));
        // Dangling separators end inside a term.
        assert_eq!(err("d20+", 2), "operator spec ends inside a term");
        assert_eq!(err("d20*", 2), "operator spec ends inside a term");
        // Malformed coefficient literals.
        assert!(err("1.2.3*d20", 2).contains("bad coefficient '1.2.3'"));
        // Terms of nothing but coefficients.
        assert!(err("2.0+d02", 2).contains("at least one"));
        // Parse failures never cache: the same bad spec keeps erroring
        // and valid lookups still work (see `pde::cache` tests for the
        // cached-vs-fresh bitwise check).
        assert!(crate::pde::cache::shared_operator("q20", 2).is_err());
        assert!(crate::pde::cache::shared_operator("q20", 2).is_err());
        assert!(crate::pde::cache::shared_operator("d20+d02", 2).is_ok());
    }

    /// The sparsity analysis agrees with a brute-force scan of the term
    /// list for every library problem — the operator-adapted sampler
    /// keys on these fields, so they must stay honest as the zoo grows.
    #[test]
    fn sparsity_analysis_over_the_problem_library() {
        use crate::pde::PdeProblem;
        for p in PdeProblem::ALL {
            let op = p.operator();
            let sp = op.sparsity();
            assert_eq!(sp.n_terms, op.terms().len(), "{}", p.name());
            for axis in 0..op.dim() {
                let touched = op
                    .terms()
                    .iter()
                    .flat_map(|t| t.factors.iter())
                    .any(|f| f[axis] > 0);
                assert_eq!(
                    sp.axes.contains(&axis),
                    touched,
                    "{} axis {axis}",
                    p.name()
                );
            }
            let max_support = op
                .terms()
                .iter()
                .flat_map(|t| t.factors.iter())
                .map(|f| f.iter().filter(|&&a| a > 0).count())
                .max()
                .unwrap_or(0);
            assert_eq!(sp.max_support, max_support, "{}", p.name());
            assert_eq!(sp.pure_axis, max_support <= 1, "{}", p.name());
        }
    }

    /// Spot checks of the sparsity fields on known shapes, including
    /// the coupled biharmonic cross term and an axis left untouched.
    #[test]
    fn sparsity_known_values() {
        let heat = DiffOperator::parse("d10-0.1*d02", 2).unwrap();
        let sp = heat.sparsity();
        assert_eq!(sp.axes, vec![0, 1]);
        assert_eq!(sp.n_terms, 2);
        assert!(sp.pure_axis);
        assert_eq!(sp.max_support, 1);

        let bih = DiffOperator::biharmonic(2).sparsity();
        assert!(!bih.pure_axis); // the d22 cross term couples both axes
        assert_eq!(bih.max_support, 2);

        // An operator that never differentiates along axis 1.
        let skewed = DiffOperator::new(3)
            .with_term(1.0, vec![2, 0, 0])
            .with_product(1.0, vec![vec![0, 0, 0], vec![0, 0, 1]]);
        let sp = skewed.sparsity();
        assert_eq!(sp.axes, vec![0, 2]);
        assert!(sp.pure_axis); // u·∂_z u is single-axis per factor
        assert_eq!(sp.n_terms, 2);

        // Derivative-free operator: no axes, support 0.
        let plain = DiffOperator::parse("u*u", 2).unwrap().sparsity();
        assert!(plain.axes.is_empty());
        assert_eq!(plain.max_support, 0);
        assert!(plain.pure_axis);
    }

    /// `apply` on jets equals the hand-assembled combination of
    /// `jet.partial` calls, including the nonlinear product.
    #[test]
    fn apply_matches_manual_assembly() {
        let mut rng = Prng::seeded(21);
        let mlp = Mlp::uniform(2, 8, 2, 1, &mut rng);
        let x = Tensor::rand_uniform(&[9, 2], -1.0, 1.0, &mut rng);
        let engine = MultiJetEngine::new(2, 3);
        let jet = engine.jet(&mlp, &x);
        let kdv = DiffOperator::parse("d10+u*d01+d03", 2).unwrap();
        let got = kdv.apply(&jet);
        let want = jet
            .partial(&[1, 0])
            .add(&jet.partial(&[0, 0]).mul(&jet.partial(&[0, 1])))
            .add(&jet.partial(&[0, 3]));
        assert_eq!(got, want);
    }
}
