//! Process-wide compile caches for the serving path.
//!
//! Every operator request needs a compiled [`DiffOperator`] and a
//! [`MultiJetEngine`] (whose [`crate::ntp::JetPlan`] solves an exact
//! rational moment system), and every pool worker needs a scalar
//! [`NtpEngine`] (Faà di Bruno program + activation towers). All three
//! are pure functions of a small key — `(dim, spec)`, `(dim, n, policy)`
//! and `(n, policy)` respectively — so the serving layer shares one
//! compiled instance per key across all `OperatorServer`s, connection
//! threads and pool workers instead of recompiling per request.
//!
//! The caches are `OnceLock`-initialized `RwLock<HashMap>`s: lookups
//! take a read lock, misses compile *outside* any lock and then
//! insert under a write lock (first inserter wins, so concurrent
//! misses still converge on one shared instance). Engines and plans
//! are deterministic, so a cached instance is bitwise interchangeable
//! with a fresh compile — asserted by the tests below and consumed by
//! the serving-layer hit/miss counters in
//! [`crate::coordinator::Metrics`].
//!
//! The operator map is the only client-influenced key space (specs are
//! client-chosen strings), so it is capped at
//! [`MAX_CACHED_OPERATORS`]: once full, inserting a new spec **evicts**
//! an arbitrary resident entry (counted by the
//! `cache_operator_evictions` registry counter), bounding memory under
//! adversarial traffic while keeping recurring specs cacheable.
//!
//! Every lookup also bumps per-cache hit/miss counters in the
//! [`crate::obs`] registry (`cache_engine_*`, `cache_scalar_*`,
//! `cache_operator_*`), so cache behaviour shows up in the Prometheus /
//! `{"stats":"full"}` export alongside the serving-layer
//! [`crate::coordinator::Metrics`] plan counters.

use crate::ntp::{MultiJetEngine, NtpEngine, ParallelPolicy};
use crate::obs;
use crate::pde::{resolve_operator, DiffOperator};
use std::collections::HashMap;
use std::sync::{Arc, OnceLock, RwLock};

/// Hard cap on distinct cached operator specs (client-chosen keys).
pub const MAX_CACHED_OPERATORS: usize = 512;

/// Hashable mirror of [`ParallelPolicy`] (which deliberately carries no
/// `Hash` derive on its public surface).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
enum PolicyKey {
    Serial,
    Fixed(usize),
    Auto,
}

fn policy_key(policy: ParallelPolicy) -> PolicyKey {
    match policy {
        ParallelPolicy::Serial => PolicyKey::Serial,
        ParallelPolicy::Fixed(t) => PolicyKey::Fixed(t),
        ParallelPolicy::Auto => PolicyKey::Auto,
    }
}

type EngineMap = HashMap<(usize, usize, PolicyKey), Arc<MultiJetEngine>>;
type ScalarMap = HashMap<(usize, PolicyKey), Arc<NtpEngine>>;
type OperatorMap = HashMap<(usize, String), Arc<DiffOperator>>;

fn engines() -> &'static RwLock<EngineMap> {
    static CELL: OnceLock<RwLock<EngineMap>> = OnceLock::new();
    CELL.get_or_init(|| RwLock::new(HashMap::new()))
}

fn scalar_engines() -> &'static RwLock<ScalarMap> {
    static CELL: OnceLock<RwLock<ScalarMap>> = OnceLock::new();
    CELL.get_or_init(|| RwLock::new(HashMap::new()))
}

fn operators() -> &'static RwLock<OperatorMap> {
    static CELL: OnceLock<RwLock<OperatorMap>> = OnceLock::new();
    CELL.get_or_init(|| RwLock::new(HashMap::new()))
}

/// The shared [`MultiJetEngine`] for `(dim, n, policy)`; the `bool` is
/// `true` on a cache hit. Misses compile outside the lock; the first
/// inserter wins, so every caller ends up holding the same `Arc`.
pub fn shared_engine(dim: usize, n: usize, policy: ParallelPolicy) -> (Arc<MultiJetEngine>, bool) {
    let key = (dim, n, policy_key(policy));
    if let Some(e) = engines().read().expect("engine cache poisoned").get(&key) {
        obs::registry().counter("cache_engine_hits").inc();
        return (e.clone(), true);
    }
    obs::registry().counter("cache_engine_misses").inc();
    let fresh = Arc::new(MultiJetEngine::with_policy(dim, n, policy));
    let mut map = engines().write().expect("engine cache poisoned");
    (map.entry(key).or_insert(fresh).clone(), false)
}

/// The shared scalar [`NtpEngine`] for `(n, policy)` — pool workers
/// serving the same derivative order reuse one compiled Faà di Bruno
/// program and activation-tower set. The `bool` is `true` on a hit.
pub fn shared_scalar_engine(n: usize, policy: ParallelPolicy) -> (Arc<NtpEngine>, bool) {
    let key = (n, policy_key(policy));
    if let Some(e) = scalar_engines().read().expect("scalar engine cache poisoned").get(&key) {
        obs::registry().counter("cache_scalar_hits").inc();
        return (e.clone(), true);
    }
    obs::registry().counter("cache_scalar_misses").inc();
    let fresh = Arc::new(NtpEngine::with_policy(n, policy));
    let mut map = scalar_engines().write().expect("scalar engine cache poisoned");
    (map.entry(key).or_insert(fresh).clone(), false)
}

/// The shared compiled [`DiffOperator`] for `(spec, dim)`; the `bool`
/// is `true` on a hit. Parse errors are returned (never cached), and
/// once the map holds [`MAX_CACHED_OPERATORS`] distinct specs each new
/// insert evicts an arbitrary resident entry (counted by the
/// `cache_operator_evictions` registry counter), so memory stays
/// bounded under adversarial spec traffic without freezing the cache.
pub fn shared_operator(spec: &str, dim: usize) -> Result<(Arc<DiffOperator>, bool), String> {
    let key = (dim, spec.to_string());
    if let Some(op) = operators().read().expect("operator cache poisoned").get(&key) {
        obs::registry().counter("cache_operator_hits").inc();
        return Ok((op.clone(), true));
    }
    obs::registry().counter("cache_operator_misses").inc();
    let fresh = Arc::new(resolve_operator(spec, dim)?);
    let mut map = operators().write().expect("operator cache poisoned");
    if let Some(op) = map.get(&key) {
        return Ok((op.clone(), true));
    }
    if map.len() >= MAX_CACHED_OPERATORS {
        // Evict an arbitrary resident entry (cheap, no LRU bookkeeping
        // on the hot path); an Arc still held by in-flight requests
        // stays alive until they finish.
        if let Some(victim) = map.keys().next().cloned() {
            map.remove(&victim);
            obs::registry().counter("cache_operator_evictions").inc();
        }
    }
    map.insert(key, fresh.clone());
    Ok((fresh, false))
}

/// Operator-cache observables for the stats endpoint:
/// `(resident entries, lifetime evictions)`.
pub fn operator_cache_stats() -> (usize, u64) {
    let size = operators().read().expect("operator cache poisoned").len();
    let evictions = obs::registry().counter("cache_operator_evictions").get();
    (size, evictions)
}

/// Current entry counts `(engines, scalar_engines, operators)` —
/// observability for tests and the stats endpoint.
pub fn cache_sizes() -> (usize, usize, usize) {
    (
        engines().read().expect("engine cache poisoned").len(),
        scalar_engines().read().expect("scalar engine cache poisoned").len(),
        operators().read().expect("operator cache poisoned").len(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Mlp;
    use crate::tensor::Tensor;
    use crate::util::prng::Prng;

    #[test]
    fn shared_engine_hits_after_first_lookup() {
        let (a, _) = shared_engine(2, 3, ParallelPolicy::Serial);
        let (b, hit) = shared_engine(2, 3, ParallelPolicy::Serial);
        assert!(hit);
        assert!(Arc::ptr_eq(&a, &b));
        // A different key is a distinct engine.
        let (c, _) = shared_engine(2, 2, ParallelPolicy::Serial);
        assert!(!Arc::ptr_eq(&a, &c));
    }

    #[test]
    fn shared_scalar_engine_hits_after_first_lookup() {
        let (a, _) = shared_scalar_engine(5, ParallelPolicy::Serial);
        let (b, hit) = shared_scalar_engine(5, ParallelPolicy::Serial);
        assert!(hit);
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn shared_operator_hits_and_rejects_bad_specs() {
        let (a, _) = shared_operator("d20+d02", 2).unwrap();
        let (b, hit) = shared_operator("d20+d02", 2).unwrap();
        assert!(hit);
        assert!(Arc::ptr_eq(&a, &b));
        assert!(shared_operator("not an operator", 2).is_err());
        // Library names resolve through the same cache.
        let (h, _) = shared_operator("heat2d", 2).unwrap();
        let (h2, hit2) = shared_operator("heat2d", 2).unwrap();
        assert!(hit2);
        assert!(Arc::ptr_eq(&h, &h2));
    }

    /// Cache correctness: evaluating through the cached engine/operator
    /// pair is bitwise identical to a freshly compiled pair.
    #[test]
    fn cached_evaluation_is_bitwise_identical_to_fresh() {
        let mut rng = Prng::seeded(404);
        let mlp = Mlp::uniform(2, 6, 2, 1, &mut rng);
        let x = Tensor::rand_uniform(&[9, 2], -1.0, 1.0, &mut rng);

        let (engine, _) = shared_engine(2, 4, ParallelPolicy::Serial);
        let (op, _) = shared_operator("d40+d04+d20*d02", 2).unwrap();
        let jet = engine.jet(&mlp, &x);
        let cached_u = jet.value();
        let cached_vals = op.apply(&jet);

        let fresh_engine = MultiJetEngine::new(2, 4);
        let fresh_op = resolve_operator("d40+d04+d20*d02", 2).unwrap();
        let fresh_jet = fresh_engine.jet(&mlp, &x);
        assert_eq!(cached_u.data(), fresh_jet.value().data());
        assert_eq!(cached_vals.data(), fresh_op.apply(&fresh_jet).data());
    }

    #[test]
    fn cache_sizes_are_monotone_observables() {
        shared_engine(2, 2, ParallelPolicy::Serial);
        shared_operator("d20+d02", 2).unwrap();
        let (e, s, o) = cache_sizes();
        assert!(e >= 1);
        // The scalar map may or may not have been touched by other
        // tests in this process; it only ever grows.
        assert_eq!(cache_sizes(), (e, s, o));
        assert!(o >= 1);
    }
}
