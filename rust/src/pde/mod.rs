//! Multi-dimensional differential operators and a PDE scenario library.
//!
//! [`DiffOperator`] describes an operator as a sum of *product terms* —
//! each term a coefficient times a product of mixed partials `∂^α u` —
//! which covers linear operators (heat `∂_t − κ∂_xx`, Poisson
//! `∂_xx + ∂_yy`, biharmonic `Δ²`) and the quadratic nonlinearities PINN
//! practice needs (KdV's `u·∂_x u`) through the same hook. Operators are
//! built programmatically or parsed from a compact text spec
//! (`"d20+d02"`, `"d10-0.1*d02"`, `"d10+u*d01+d03"`).
//!
//! Evaluation has two routes, both exact:
//!
//! - **inference**: [`DiffOperator::apply`] consumes a
//!   [`crate::ntp::MultiJet`] — one direction-stacked fused n-TangentProp
//!   batch — and recombines the jets into every needed `∂^α u`
//!   (`D · O(n log n)` cost; `ntangent bench operators` measures it
//!   against the nested-tape baseline);
//! - **training**: [`DiffOperator::apply_nodes`] assembles the same sum
//!   from mixed-partial *tape nodes* so residual losses backprop through
//!   the operator (see [`crate::pinn::MultiObjective`]).
//!
//! [`PdeProblem`] is the scenario library: named problems with
//! manufactured exact solutions, source terms and box domains, used by
//! `ntangent train --pde <name>`, the wire protocol's operator requests
//! and the operator benches. The classics are 2-D; the
//! stochastic-estimator workloads (`poisson10d`, `heat100d`, `hjb10d`)
//! go to 10 and 100 axes, where only the sampled path
//! ([`crate::ntp::stde`]) is tractable — [`DiffOperator::sparsity`]
//! feeds its operator-adapted sampler.

pub mod cache;
pub mod operator;
pub mod problems;

pub use operator::{DiffOperator, OpSparsity, OpTerm};
pub use problems::{
    resolve_operator, PdeProblem, HEAT_KAPPA, HJB_MU, HJB_SIGMA, KDV_SPEED, WAVE_SPEED,
};
