//! Parameter flattening — the bridge between [`crate::nn::Mlp`] structure,
//! the flat vectors the optimizers work on, and the single `theta` input
//! of the AOT-compiled PJRT artifacts.
//!
//! Order: `W0 (row-major), b0, W1, b1, ...` — the Python side
//! (`python/compile/model.py::unflatten`) uses the same order so a flat
//! vector trained in Rust is directly loadable there and vice versa.

use super::Mlp;
use crate::tensor::Tensor;

/// Flatten all parameters of an MLP into one `[M]` tensor.
pub fn flatten(mlp: &Mlp) -> Tensor {
    let mut data = Vec::with_capacity(mlp.n_params());
    for layer in &mlp.layers {
        data.extend_from_slice(layer.w.data());
        data.extend_from_slice(layer.b.data());
    }
    Tensor::from_vec(data, &[mlp.n_params()])
}

/// Flatten a list of tensors (e.g. per-parameter gradients in slot order)
/// into one `[sum numel]` tensor.
pub fn flatten_tensors(tensors: &[Tensor]) -> Tensor {
    let total: usize = tensors.iter().map(Tensor::numel).sum();
    let mut data = Vec::with_capacity(total);
    for t in tensors {
        data.extend_from_slice(t.data());
    }
    Tensor::from_vec(data, &[total])
}

/// Write a flat `[M]` vector back into the MLP's layers.
pub fn unflatten_into(mlp: &mut Mlp, flat: &Tensor) {
    assert_eq!(flat.numel(), mlp.n_params(), "flat vector length mismatch");
    let mut off = 0;
    for layer in &mut mlp.layers {
        let wn = layer.w.numel();
        layer
            .w
            .data_mut()
            .copy_from_slice(&flat.data()[off..off + wn]);
        off += wn;
        let bn = layer.b.numel();
        layer
            .b
            .data_mut()
            .copy_from_slice(&flat.data()[off..off + bn]);
        off += bn;
    }
}

/// Split a flat `[M]` vector into per-parameter tensors in slot order
/// (`W0, b0, W1, b1, ...`), using `mlp` for the shapes.
pub fn split_like(mlp: &Mlp, flat: &Tensor) -> Vec<Tensor> {
    assert_eq!(flat.numel(), mlp.n_params());
    let mut out = Vec::with_capacity(2 * mlp.layers.len());
    let mut off = 0;
    for layer in &mlp.layers {
        for shape in [layer.w.shape(), layer.b.shape()] {
            let n: usize = shape.iter().product();
            out.push(Tensor::from_vec(
                flat.data()[off..off + n].to_vec(),
                shape,
            ));
            off += n;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;
    use crate::util::ptest;

    #[test]
    fn flatten_unflatten_roundtrip() {
        ptest::quickcheck(
            |rng| {
                let width = 1 + rng.below(6) as usize;
                let depth = 1 + rng.below(3) as usize;
                let mut mlp = Mlp::uniform(1, width, depth, 1, rng);
                // Randomize biases too (xavier zeroes them).
                for layer in &mut mlp.layers {
                    let n = layer.b.numel();
                    layer.b = Tensor::from_vec(rng.normal_vec(n, 0.0, 1.0), &[n]);
                }
                mlp
            },
            |mlp| {
                let flat = flatten(mlp);
                if flat.numel() != mlp.n_params() {
                    return Err("flatten length".into());
                }
                let mut rng2 = Prng::seeded(0);
                let mut other = Mlp::uniform(
                    1,
                    mlp.layers[0].fan_out(),
                    mlp.layers.len() - 1,
                    1,
                    &mut rng2,
                );
                unflatten_into(&mut other, &flat);
                let flat2 = flatten(&other);
                if flat.data() == flat2.data() {
                    Ok(())
                } else {
                    Err("roundtrip mismatch".into())
                }
            },
        );
    }

    #[test]
    fn split_matches_param_tensors() {
        let mut rng = Prng::seeded(8);
        let mlp = Mlp::uniform(1, 5, 2, 1, &mut rng);
        let flat = flatten(&mlp);
        let split = split_like(&mlp, &flat);
        let direct = mlp.param_tensors();
        assert_eq!(split.len(), direct.len());
        for (a, b) in split.iter().zip(&direct) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn flatten_tensors_concatenates() {
        let a = Tensor::from_vec(vec![1.0, 2.0], &[2]);
        let b = Tensor::from_vec(vec![3.0], &[1]);
        let f = flatten_tensors(&[a, b]);
        assert_eq!(f.data(), &[1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn unflatten_length_checked() {
        let mut rng = Prng::seeded(1);
        let mut mlp = Mlp::uniform(1, 4, 1, 1, &mut rng);
        unflatten_into(&mut mlp, &Tensor::zeros(&[3]));
    }
}
