//! Densely-connected feed-forward networks (the paper's model family) and
//! parameter (un)flattening for the optimizers and the PJRT artifacts.

pub mod checkpoint;
pub mod params;

pub use checkpoint::{
    AdamResume, Checkpoint, CheckpointError, LbfgsResume, ResumePhase, ResumeState,
};

use crate::autodiff::{Graph, NodeId};
use crate::ntp::activation::ActivationKind;
use crate::tensor::Tensor;
use crate::util::prng::Prng;

/// A dense layer `y = x W^T + b` with `W: [out, in]`, `b: [out]`.
#[derive(Clone, Debug)]
pub struct Dense {
    /// Weights `[out, in]`.
    pub w: Tensor,
    /// Bias `[out]`.
    pub b: Tensor,
}

impl Dense {
    /// Xavier/Glorot-uniform initialization (the PINN default).
    pub fn xavier(input: usize, output: usize, rng: &mut Prng) -> Dense {
        let bound = (6.0 / (input + output) as f64).sqrt();
        Dense {
            w: Tensor::rand_uniform(&[output, input], -bound, bound, rng),
            b: Tensor::zeros(&[output]),
        }
    }

    /// Input width.
    pub fn fan_in(&self) -> usize {
        self.w.shape()[1]
    }

    /// Output width.
    pub fn fan_out(&self) -> usize {
        self.w.shape()[0]
    }

    /// `x: [B, in] -> [B, out]`.
    pub fn apply(&self, x: &Tensor) -> Tensor {
        let mut out = x.matmul_nt(&self.w);
        out.add_bias_inplace(&self.b);
        out
    }

    /// Linear part only (no bias) — derivative channels are affine-free.
    pub fn apply_linear(&self, x: &Tensor) -> Tensor {
        x.matmul_nt(&self.w)
    }

    /// Parameter count (`w` + `b`).
    pub fn n_params(&self) -> usize {
        self.w.numel() + self.b.numel()
    }
}

/// A feed-forward network with smooth hidden activations and a linear
/// head — the architecture of the paper's experiments (e.g. 3 hidden
/// layers of 24 neurons for the standard PINN). The hidden activation is
/// a runtime-selectable [`ActivationKind`] (tanh by default, the paper's
/// choice) that every consumer — plain forward, the tape, the n-TP
/// engine, checkpoints — dispatches on.
#[derive(Clone, Debug)]
pub struct Mlp {
    /// Dense layers, input to output.
    pub layers: Vec<Dense>,
    /// Hidden-layer activation (the output head stays linear).
    pub activation: ActivationKind,
}

impl Mlp {
    /// Build from a size spec like `[1, 24, 24, 24, 1]` (tanh hidden
    /// activations, the paper's default).
    pub fn new(sizes: &[usize], rng: &mut Prng) -> Mlp {
        Mlp::with_activation(sizes, ActivationKind::Tanh, rng)
    }

    /// Build from a size spec with an explicit hidden activation.
    pub fn with_activation(sizes: &[usize], activation: ActivationKind, rng: &mut Prng) -> Mlp {
        assert!(sizes.len() >= 2, "need at least input and output sizes");
        let layers = sizes
            .windows(2)
            .map(|w| Dense::xavier(w[0], w[1], rng))
            .collect();
        Mlp { layers, activation }
    }

    /// Convenience: `input -> width x depth -> output` (tanh).
    pub fn uniform(input: usize, width: usize, depth: usize, output: usize, rng: &mut Prng) -> Mlp {
        Mlp::uniform_with(input, width, depth, output, ActivationKind::Tanh, rng)
    }

    /// Convenience: `input -> width x depth -> output` with an explicit
    /// hidden activation.
    pub fn uniform_with(
        input: usize,
        width: usize,
        depth: usize,
        output: usize,
        activation: ActivationKind,
        rng: &mut Prng,
    ) -> Mlp {
        let mut sizes = vec![input];
        sizes.extend(std::iter::repeat(width).take(depth));
        sizes.push(output);
        Mlp::with_activation(&sizes, activation, rng)
    }

    /// Input dimension.
    pub fn input_dim(&self) -> usize {
        self.layers[0].fan_in()
    }

    /// Output dimension.
    pub fn output_dim(&self) -> usize {
        self.layers.last().unwrap().fan_out()
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        self.layers.iter().map(Dense::n_params).sum()
    }

    /// Layer widths, e.g. `[1, 24, 24, 24, 1]`.
    pub fn sizes(&self) -> Vec<usize> {
        let mut out = vec![self.input_dim()];
        out.extend(self.layers.iter().map(Dense::fan_out));
        out
    }

    /// Plain forward pass `x: [B, in] -> [B, out]` (smooth hidden
    /// activation, linear head).
    pub fn forward(&self, x: &Tensor) -> Tensor {
        let last = self.layers.len() - 1;
        let mut h = x.clone();
        for (i, layer) in self.layers.iter().enumerate() {
            h = layer.apply(&h);
            if i != last {
                h = self.activation.eval_tensor(&h);
            }
        }
        h
    }

    /// Record the forward pass on an autodiff [`Graph`].
    ///
    /// Parameters enter as graph nodes (`param_nodes`, two per layer:
    /// `W` then `b`) so the caller decides whether they are constants
    /// (input-derivative benchmarks) or inputs (training).
    pub fn forward_graph(&self, g: &mut Graph, x: NodeId, param_nodes: &[NodeId]) -> NodeId {
        assert_eq!(param_nodes.len(), 2 * self.layers.len());
        let last = self.layers.len() - 1;
        let mut h = x;
        for (i, _) in self.layers.iter().enumerate() {
            let w = param_nodes[2 * i];
            let b = param_nodes[2 * i + 1];
            let lin = g.matmul_nt(h, w);
            h = g.add_bias(lin, b);
            if i != last {
                h = g.act(h, self.activation, 0);
            }
        }
        h
    }

    /// Embed all parameters as constants; returns the node list expected by
    /// [`Mlp::forward_graph`].
    pub fn const_param_nodes(&self, g: &mut Graph) -> Vec<NodeId> {
        let mut nodes = Vec::with_capacity(2 * self.layers.len());
        for layer in &self.layers {
            nodes.push(g.constant(layer.w.clone()));
            nodes.push(g.constant(layer.b.clone()));
        }
        nodes
    }

    /// Declare all parameters as graph inputs; returns the node list.
    /// Evaluation order of the slots matches [`params::flatten_tensors`].
    pub fn input_param_nodes(&self, g: &mut Graph) -> Vec<NodeId> {
        let mut nodes = Vec::with_capacity(2 * self.layers.len());
        for layer in &self.layers {
            nodes.push(g.input(layer.w.shape()));
            nodes.push(g.input(layer.b.shape()));
        }
        nodes
    }

    /// Parameter tensors in slot order (`W0, b0, W1, b1, ...`).
    pub fn param_tensors(&self) -> Vec<Tensor> {
        let mut out = Vec::with_capacity(2 * self.layers.len());
        for layer in &self.layers {
            out.push(layer.w.clone());
            out.push(layer.b.clone());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::allclose_slice;

    #[test]
    fn shapes_and_counts() {
        let mut rng = Prng::seeded(3);
        let mlp = Mlp::uniform(1, 24, 3, 1, &mut rng);
        assert_eq!(mlp.sizes(), vec![1, 24, 24, 24, 1]);
        // M = 24*1+24 + 24*24+24 + 24*24+24 + 1*24+1 = 48 + 600 + 600 + 25
        assert_eq!(mlp.n_params(), 1273);
        let x = Tensor::zeros(&[7, 1]);
        assert_eq!(mlp.forward(&x).shape(), &[7, 1]);
    }

    #[test]
    fn xavier_bounds() {
        let mut rng = Prng::seeded(4);
        let d = Dense::xavier(24, 24, &mut rng);
        let bound = (6.0 / 48.0f64).sqrt();
        assert!(d.w.data().iter().all(|x| x.abs() <= bound));
        assert!(d.b.data().iter().all(|x| *x == 0.0));
    }

    #[test]
    fn graph_forward_matches_tensor_forward_for_all_activations() {
        for kind in ActivationKind::ALL {
            let mut rng = Prng::seeded(5 + kind.index() as u64);
            let mlp = Mlp::uniform_with(1, 8, 2, 1, kind, &mut rng);
            let x = Tensor::linspace(-1.0, 1.0, 6).reshape(&[6, 1]);

            let direct = mlp.forward(&x);

            let mut g = Graph::new();
            let xn = g.input(&[6, 1]);
            let pn = mlp.const_param_nodes(&mut g);
            let out = mlp.forward_graph(&mut g, xn, &pn);
            let vals = g.eval(&[x.clone()], &[out]);
            assert!(
                allclose_slice(vals.get(out).data(), direct.data(), 1e-14, 1e-14),
                "{}",
                kind.name()
            );

            // Params-as-inputs path must agree too.
            let mut g2 = Graph::new();
            let xn2 = g2.input(&[6, 1]);
            let pn2 = mlp.input_param_nodes(&mut g2);
            let out2 = mlp.forward_graph(&mut g2, xn2, &pn2);
            let mut inputs = vec![x];
            inputs.extend(mlp.param_tensors());
            let vals2 = g2.eval(&inputs, &[out2]);
            assert!(
                allclose_slice(vals2.get(out2).data(), direct.data(), 1e-14, 1e-14),
                "{}",
                kind.name()
            );
        }
    }

    #[test]
    fn deterministic_init_given_seed() {
        let a = Mlp::uniform(1, 4, 2, 1, &mut Prng::seeded(9));
        let b = Mlp::uniform(1, 4, 2, 1, &mut Prng::seeded(9));
        assert_eq!(a.layers[0].w, b.layers[0].w);
    }
}
