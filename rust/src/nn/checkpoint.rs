//! Checkpoints: JSON serialization of trained networks (+ metadata such
//! as the inferred λ), shared by the CLI trainer, the serving coordinator
//! and the examples.

use super::{params, Mlp};
use crate::ntp::activation::ActivationKind;
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::prng::Prng;
use anyhow::{Context, Result};
use std::path::Path;

/// A saved model: architecture, activation, flat parameters and training
/// metadata. Checkpoints written before the activation field existed load
/// as tanh (the only activation they could have been trained with).
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// Layer widths, e.g. `[1, 24, 24, 24, 1]`.
    pub sizes: Vec<usize>,
    /// Hidden-layer activation; defaults to tanh for old artifacts.
    pub activation: ActivationKind,
    /// Flat parameters in `params::flatten` order.
    pub theta: Vec<f64>,
    /// Inferred inverse parameter λ (inverse-problem runs).
    pub lambda: Option<f64>,
    /// Burgers profile the model was trained on.
    pub profile_k: Option<usize>,
    /// Final training loss.
    pub final_loss: Option<f64>,
}

impl Checkpoint {
    /// Snapshot a network (no training metadata).
    pub fn from_mlp(mlp: &Mlp) -> Checkpoint {
        Checkpoint {
            sizes: mlp.sizes(),
            activation: mlp.activation,
            theta: params::flatten(mlp).into_vec(),
            lambda: None,
            profile_k: None,
            final_loss: None,
        }
    }

    /// Rebuild the network.
    pub fn to_mlp(&self) -> Result<Mlp> {
        let mut rng = Prng::seeded(0);
        let mut mlp = Mlp::with_activation(&self.sizes, self.activation, &mut rng);
        anyhow::ensure!(
            self.theta.len() == mlp.n_params(),
            "checkpoint has {} params, architecture {:?} wants {}",
            self.theta.len(),
            self.sizes,
            mlp.n_params()
        );
        params::unflatten_into(
            &mut mlp,
            &Tensor::from_vec(self.theta.clone(), &[self.theta.len()]),
        );
        Ok(mlp)
    }

    /// Serialize to the checkpoint JSON object.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            (
                "sizes",
                Json::Arr(self.sizes.iter().map(|&s| Json::Num(s as f64)).collect()),
            ),
            ("activation", Json::Str(self.activation.name().to_string())),
            ("theta", Json::num_arr(&self.theta)),
        ];
        if let Some(l) = self.lambda {
            fields.push(("lambda", Json::Num(l)));
        }
        if let Some(k) = self.profile_k {
            fields.push(("profile_k", Json::Num(k as f64)));
        }
        if let Some(f) = self.final_loss {
            fields.push(("final_loss", Json::Num(f)));
        }
        Json::obj(fields)
    }

    /// Parse a checkpoint JSON object.
    pub fn from_json(v: &Json) -> Result<Checkpoint> {
        let sizes = v
            .get("sizes")
            .and_then(Json::as_arr)
            .context("checkpoint missing sizes")?
            .iter()
            .map(|s| s.as_usize().context("bad size"))
            .collect::<Result<Vec<_>>>()?;
        let theta = v
            .get("theta")
            .and_then(Json::as_f64_vec)
            .context("checkpoint missing theta")?;
        let activation = match v.get("activation") {
            // Pre-activation-field checkpoints were all tanh.
            None => ActivationKind::Tanh,
            Some(a) => {
                let name = a.as_str().context("checkpoint activation must be a string")?;
                ActivationKind::from_name(name)
                    .with_context(|| format!("unknown checkpoint activation '{name}'"))?
            }
        };
        Ok(Checkpoint {
            sizes,
            activation,
            theta,
            lambda: v.get("lambda").and_then(Json::as_f64),
            profile_k: v.get("profile_k").and_then(Json::as_usize),
            final_loss: v.get("final_loss").and_then(Json::as_f64),
        })
    }

    /// Write the checkpoint JSON to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().dump())
            .with_context(|| format!("writing checkpoint {}", path.display()))
    }

    /// Load a checkpoint JSON from `path`.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        let v = Json::parse(&text).context("checkpoint is not valid JSON")?;
        Self::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_through_json() {
        let mut rng = Prng::seeded(4);
        let mlp = Mlp::uniform(1, 6, 2, 1, &mut rng);
        let mut ck = Checkpoint::from_mlp(&mlp);
        ck.lambda = Some(0.5);
        ck.profile_k = Some(1);
        ck.final_loss = Some(1e-6);
        let parsed = Checkpoint::from_json(&Json::parse(&ck.to_json().dump()).unwrap()).unwrap();
        assert_eq!(parsed.sizes, ck.sizes);
        assert_eq!(parsed.lambda, Some(0.5));
        assert_eq!(parsed.profile_k, Some(1));
        let back = parsed.to_mlp().unwrap();
        let x = Tensor::linspace(-1.0, 1.0, 4).reshape(&[4, 1]);
        assert_eq!(back.forward(&x), mlp.forward(&x));
    }

    #[test]
    fn file_roundtrip() {
        let mut rng = Prng::seeded(5);
        let mlp = Mlp::uniform(1, 4, 1, 1, &mut rng);
        let ck = Checkpoint::from_mlp(&mlp);
        let path = std::env::temp_dir().join("ntangent_ck_test.json");
        ck.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.theta, ck.theta);
    }

    #[test]
    fn arity_mismatch_rejected() {
        let ck = Checkpoint {
            sizes: vec![1, 4, 1],
            activation: ActivationKind::Tanh,
            theta: vec![0.0; 3], // wrong
            lambda: None,
            profile_k: None,
            final_loss: None,
        };
        assert!(ck.to_mlp().is_err());
    }

    /// Acceptance: a checkpoint saved with any registered activation
    /// reloads and reproduces *identical* derivative channels.
    #[test]
    fn roundtrip_preserves_activation_and_channels() {
        use crate::ntp::NtpEngine;
        for kind in ActivationKind::ALL {
            let mut rng = Prng::seeded(40 + kind.index() as u64);
            let mlp = Mlp::uniform_with(1, 6, 2, 1, kind, &mut rng);
            let ck = Checkpoint::from_mlp(&mlp);
            let parsed =
                Checkpoint::from_json(&Json::parse(&ck.to_json().dump()).unwrap()).unwrap();
            assert_eq!(parsed.activation, kind);
            let back = parsed.to_mlp().unwrap();
            assert_eq!(back.activation, kind);
            let x = Tensor::linspace(-1.0, 1.0, 5).reshape(&[5, 1]);
            let engine = NtpEngine::new(4);
            let a = engine.forward(&mlp, &x);
            let b = engine.forward(&back, &x);
            for (ca, cb) in a.iter().zip(&b) {
                assert_eq!(ca, cb, "{} channels changed across roundtrip", kind.name());
            }
        }
    }

    #[test]
    fn legacy_checkpoint_without_activation_defaults_to_tanh() {
        let mut rng = Prng::seeded(50);
        let mlp = Mlp::uniform(1, 4, 1, 1, &mut rng);
        let ck = Checkpoint::from_mlp(&mlp);
        // Simulate an old artifact: strip the activation field.
        let dumped = ck.to_json().dump();
        let parsed = Json::parse(&dumped).unwrap();
        let stripped = match parsed {
            Json::Obj(fields) => {
                Json::Obj(fields.into_iter().filter(|(k, _)| k != "activation").collect())
            }
            other => other,
        };
        let loaded = Checkpoint::from_json(&stripped).unwrap();
        assert_eq!(loaded.activation, ActivationKind::Tanh);
        assert!(Checkpoint::from_json(
            &Json::parse(&dumped.replace("\"tanh\"", "\"relu\"")).unwrap()
        )
        .is_err());
    }
}
