//! Checkpoints: JSON serialization of trained networks (+ metadata such
//! as the inferred λ), shared by the CLI trainer, the serving coordinator
//! and the examples.
//!
//! Since the resilient-training work this layer is also the crash-safety
//! boundary of a run:
//!
//! - [`Checkpoint::save`] is **atomic**: the payload is written to a
//!   sibling temp file, fsynced, then renamed over the target — a kill at
//!   any instant leaves either the previous checkpoint or the new one on
//!   disk, never a half-written hybrid.
//! - [`Checkpoint::load`] is **hardened**: truncated or corrupted files,
//!   schema violations, non-finite parameters and architecture/parameter
//!   count mismatches each fail with a classified [`CheckpointError`] —
//!   never a panic, never a silently-wrong model.
//! - An optional [`ResumeState`] carries the full mid-trajectory
//!   optimizer state (Adam moments, L-BFGS curvature memory, the STDE
//!   draw counter, the divergence-recovery schedule position) so
//!   `train --resume` can restart **bitwise identical** to the
//!   uninterrupted run (`rust/tests/training_resilience.rs`).

use super::{params, Mlp};
use crate::ntp::activation::ActivationKind;
use crate::simd::Isa;
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::prng::Prng;
use anyhow::{Context, Result};
use std::fmt;
use std::fs::File;
use std::io::Write;
use std::path::Path;

/// Classified checkpoint-load failures — the taxonomy callers (CLI,
/// server, resume) report instead of raw parse errors. The `Display`
/// form always starts with `checkpoint <kind>:` so the class survives
/// through `anyhow` context chains.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CheckpointError {
    /// The file could not be read at all (missing, permissions, I/O).
    Io(String),
    /// The bytes are not a valid JSON document — the signature of a
    /// truncated or corrupted write.
    Corrupted(String),
    /// Valid JSON that is not a checkpoint: missing or mistyped fields.
    Schema(String),
    /// A parameter or optimizer value is NaN/±∞ — the artifact of a
    /// diverged run and unusable for serving or resume.
    NonFinite(String),
    /// The declared architecture and the stored parameter counts
    /// disagree.
    ShapeMismatch(String),
}

impl CheckpointError {
    /// The stable taxonomy tag (`io`, `corrupted`, `schema`,
    /// `non-finite`, `shape-mismatch`).
    pub fn kind(&self) -> &'static str {
        match self {
            CheckpointError::Io(_) => "io",
            CheckpointError::Corrupted(_) => "corrupted",
            CheckpointError::Schema(_) => "schema",
            CheckpointError::NonFinite(_) => "non-finite",
            CheckpointError::ShapeMismatch(_) => "shape-mismatch",
        }
    }

    fn detail(&self) -> &str {
        match self {
            CheckpointError::Io(s)
            | CheckpointError::Corrupted(s)
            | CheckpointError::Schema(s)
            | CheckpointError::NonFinite(s)
            | CheckpointError::ShapeMismatch(s) => s,
        }
    }
}

impl fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "checkpoint {}: {}", self.kind(), self.detail())
    }
}

impl std::error::Error for CheckpointError {}

/// Which phase of the two-phase Adam → L-BFGS schedule a resume snapshot
/// was taken in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResumePhase {
    /// The Adam exploration phase.
    Adam,
    /// The L-BFGS refinement phase.
    Lbfgs,
}

impl ResumePhase {
    /// Canonical lowercase name used in the JSON encoding.
    pub fn name(self) -> &'static str {
        match self {
            ResumePhase::Adam => "adam",
            ResumePhase::Lbfgs => "lbfgs",
        }
    }

    /// Parse the JSON encoding back.
    pub fn from_name(name: &str) -> Option<ResumePhase> {
        match name {
            "adam" => Some(ResumePhase::Adam),
            "lbfgs" => Some(ResumePhase::Lbfgs),
            _ => None,
        }
    }
}

/// Adam moment state at snapshot time (see [`crate::opt::Adam`]).
#[derive(Clone, Debug, PartialEq)]
pub struct AdamResume {
    /// First moments, one per optimizer coordinate.
    pub m: Vec<f64>,
    /// Second moments, one per optimizer coordinate.
    pub v: Vec<f64>,
    /// Bias-correction step counter (steps taken so far).
    pub t: u64,
}

/// L-BFGS curvature memory at snapshot time (see [`crate::opt::Lbfgs`]).
#[derive(Clone, Debug, PartialEq)]
pub struct LbfgsResume {
    /// Stored `s = θ_{k+1} − θ_k` displacement vectors, oldest first.
    pub s: Vec<Vec<f64>>,
    /// Stored `y = ∇f_{k+1} − ∇f_k` vectors, paired with `s`.
    pub y: Vec<Vec<f64>>,
    /// The gradient the optimizer carried over from its last successful
    /// step (reused instead of a fresh `value_grad` call — serializing
    /// it is what keeps resumed trajectories bitwise identical).
    pub last_grad: Option<Vec<f64>>,
}

/// The full mid-trajectory training state: everything beyond the network
/// weights that the next optimizer step reads. A checkpoint carrying one
/// of these can restart the run bitwise-identically to never having
/// stopped.
#[derive(Clone, Debug, PartialEq)]
pub struct ResumeState {
    /// Schedule phase of the snapshot.
    pub phase: ResumePhase,
    /// Epochs already completed **within that phase**.
    pub epoch: usize,
    /// The full optimizer parameter vector — network weights plus any
    /// trailing inverse parameter (λ), i.e. `Objective::dim()` long,
    /// which can exceed `Checkpoint::theta` (the weights alone).
    pub theta: Vec<f64>,
    /// Adam moments, when the snapshot falls in (or after) the Adam
    /// phase.
    pub adam: Option<AdamResume>,
    /// L-BFGS memory, when the snapshot falls in the L-BFGS phase.
    pub lbfgs: Option<LbfgsResume>,
    /// STDE draw counter of the objective at snapshot time (0 for exact
    /// runs); the resumed objective rebuilds its shards at this counter
    /// so forward-only line-search probes see the identical draw.
    pub stde_step: u64,
    /// Divergence-recovery retries consumed so far (positions the
    /// deterministic intervention schedule).
    pub retries: u64,
    /// Consecutive line-search failures at snapshot time (the stall
    /// detector's counter — serialized so a kill between two failures
    /// still resumes bitwise).
    pub ls_failures: u64,
    /// Current deterministic learning-rate backoff factor (1.0 until a
    /// recovery intervened).
    pub lr_scale: f64,
}

impl ResumeState {
    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("phase", Json::Str(self.phase.name().to_string())),
            ("epoch", Json::Num(self.epoch as f64)),
            ("theta", Json::num_arr(&self.theta)),
            ("stde_step", Json::Num(self.stde_step as f64)),
            ("retries", Json::Num(self.retries as f64)),
            ("ls_failures", Json::Num(self.ls_failures as f64)),
            ("lr_scale", Json::Num(self.lr_scale)),
        ];
        if let Some(a) = &self.adam {
            fields.push((
                "adam",
                Json::obj(vec![
                    ("m", Json::num_arr(&a.m)),
                    ("v", Json::num_arr(&a.v)),
                    ("t", Json::Num(a.t as f64)),
                ]),
            ));
        }
        if let Some(l) = &self.lbfgs {
            let pairs = |vecs: &[Vec<f64>]| {
                Json::Arr(vecs.iter().map(|v| Json::num_arr(v)).collect())
            };
            let mut lf = vec![("s", pairs(&l.s)), ("y", pairs(&l.y))];
            if let Some(g) = &l.last_grad {
                lf.push(("last_grad", Json::num_arr(g)));
            }
            fields.push(("lbfgs", Json::obj(lf)));
        }
        Json::obj(fields)
    }

    fn from_json(v: &Json) -> Result<ResumeState> {
        let phase_name = v
            .get("phase")
            .and_then(Json::as_str)
            .context("resume state missing phase")?;
        let phase = ResumePhase::from_name(phase_name)
            .with_context(|| format!("unknown resume phase '{phase_name}'"))?;
        let epoch = v
            .get("epoch")
            .and_then(Json::as_usize)
            .context("resume state missing epoch")?;
        let theta = v
            .get("theta")
            .and_then(Json::as_f64_vec)
            .context("resume state missing theta")?;
        let adam = match v.get("adam") {
            None => None,
            Some(a) => Some(AdamResume {
                m: a.get("m")
                    .and_then(Json::as_f64_vec)
                    .context("adam state missing m")?,
                v: a.get("v")
                    .and_then(Json::as_f64_vec)
                    .context("adam state missing v")?,
                t: a.get("t")
                    .and_then(Json::as_usize)
                    .context("adam state missing t")? as u64,
            }),
        };
        let lbfgs = match v.get("lbfgs") {
            None => None,
            Some(l) => {
                let pairs = |key: &str| -> Result<Vec<Vec<f64>>> {
                    l.get(key)
                        .and_then(Json::as_arr)
                        .with_context(|| format!("lbfgs state missing {key}"))?
                        .iter()
                        .map(|e| {
                            e.as_f64_vec()
                                .with_context(|| format!("lbfgs {key} entry is not numeric"))
                        })
                        .collect()
                };
                let last_grad = match l.get("last_grad") {
                    None => None,
                    Some(g) => {
                        Some(g.as_f64_vec().context("lbfgs last_grad is not numeric")?)
                    }
                };
                Some(LbfgsResume {
                    s: pairs("s")?,
                    y: pairs("y")?,
                    last_grad,
                })
            }
        };
        let stde_step = v
            .get("stde_step")
            .and_then(Json::as_usize)
            .unwrap_or(0) as u64;
        let retries = v.get("retries").and_then(Json::as_usize).unwrap_or(0) as u64;
        let ls_failures = v.get("ls_failures").and_then(Json::as_usize).unwrap_or(0) as u64;
        let lr_scale = v.get("lr_scale").and_then(Json::as_f64).unwrap_or(1.0);
        Ok(ResumeState {
            phase,
            epoch,
            theta,
            adam,
            lbfgs,
            stde_step,
            retries,
            ls_failures,
            lr_scale,
        })
    }

    /// Structural validation against the optimizer dimension `dim`
    /// (network parameters + any inverse parameter). Every stored vector
    /// must be `dim` long and finite.
    fn validate(&self, dim_weights: usize) -> Result<(), CheckpointError> {
        let dim = self.theta.len();
        if dim != dim_weights && dim != dim_weights + 1 {
            return Err(CheckpointError::ShapeMismatch(format!(
                "resume theta has {dim} values, architecture wants {dim_weights} (+1 for λ)"
            )));
        }
        let finite = |name: &str, xs: &[f64]| -> Result<(), CheckpointError> {
            if Isa::active().all_finite(xs) {
                Ok(())
            } else {
                Err(CheckpointError::NonFinite(format!(
                    "resume {name} contains NaN/Inf"
                )))
            }
        };
        let sized = |name: &str, xs: &[f64]| -> Result<(), CheckpointError> {
            if xs.len() == dim {
                finite(name, xs)
            } else {
                Err(CheckpointError::ShapeMismatch(format!(
                    "resume {name} has {} values, theta has {dim}",
                    xs.len()
                )))
            }
        };
        finite("theta", &self.theta)?;
        if let Some(a) = &self.adam {
            sized("adam.m", &a.m)?;
            sized("adam.v", &a.v)?;
        }
        if let Some(l) = &self.lbfgs {
            if l.s.len() != l.y.len() {
                return Err(CheckpointError::ShapeMismatch(format!(
                    "lbfgs history has {} s vectors but {} y vectors",
                    l.s.len(),
                    l.y.len()
                )));
            }
            for (i, (s, y)) in l.s.iter().zip(&l.y).enumerate() {
                sized(&format!("lbfgs.s[{i}]"), s)?;
                sized(&format!("lbfgs.y[{i}]"), y)?;
            }
            if let Some(g) = &l.last_grad {
                sized("lbfgs.last_grad", g)?;
            }
        }
        if !self.lr_scale.is_finite() || self.lr_scale <= 0.0 {
            return Err(CheckpointError::NonFinite(format!(
                "resume lr_scale {} is not a positive finite number",
                self.lr_scale
            )));
        }
        Ok(())
    }
}

/// A saved model: architecture, activation, flat parameters and training
/// metadata. Checkpoints written before the activation field existed load
/// as tanh (the only activation they could have been trained with);
/// checkpoints written before the resume field existed load with
/// `resume: None` and can still be served and evaluated.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// Layer widths, e.g. `[1, 24, 24, 24, 1]`.
    pub sizes: Vec<usize>,
    /// Hidden-layer activation; defaults to tanh for old artifacts.
    pub activation: ActivationKind,
    /// Flat parameters in `params::flatten` order.
    pub theta: Vec<f64>,
    /// Inferred inverse parameter λ (inverse-problem runs).
    pub lambda: Option<f64>,
    /// Burgers profile the model was trained on.
    pub profile_k: Option<usize>,
    /// Final training loss.
    pub final_loss: Option<f64>,
    /// Mid-trajectory optimizer state for `train --resume`.
    pub resume: Option<ResumeState>,
}

/// Expected flat parameter count of an architecture (`W` + `b` per
/// layer) without building the network.
fn param_count(sizes: &[usize]) -> usize {
    sizes
        .windows(2)
        .map(|w| w[0] * w[1] + w[1])
        .sum()
}

impl Checkpoint {
    /// Snapshot a network (no training metadata).
    pub fn from_mlp(mlp: &Mlp) -> Checkpoint {
        Checkpoint {
            sizes: mlp.sizes(),
            activation: mlp.activation,
            theta: params::flatten(mlp).into_vec(),
            lambda: None,
            profile_k: None,
            final_loss: None,
            resume: None,
        }
    }

    /// Rebuild the network.
    pub fn to_mlp(&self) -> Result<Mlp> {
        let mut rng = Prng::seeded(0);
        let mut mlp = Mlp::with_activation(&self.sizes, self.activation, &mut rng);
        anyhow::ensure!(
            self.theta.len() == mlp.n_params(),
            "checkpoint has {} params, architecture {:?} wants {}",
            self.theta.len(),
            self.sizes,
            mlp.n_params()
        );
        params::unflatten_into(
            &mut mlp,
            &Tensor::from_vec(self.theta.clone(), &[self.theta.len()]),
        );
        Ok(mlp)
    }

    /// Serialize to the checkpoint JSON object.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            (
                "sizes",
                Json::Arr(self.sizes.iter().map(|&s| Json::Num(s as f64)).collect()),
            ),
            ("activation", Json::Str(self.activation.name().to_string())),
            ("theta", Json::num_arr(&self.theta)),
        ];
        if let Some(l) = self.lambda {
            fields.push(("lambda", Json::Num(l)));
        }
        if let Some(k) = self.profile_k {
            fields.push(("profile_k", Json::Num(k as f64)));
        }
        if let Some(f) = self.final_loss {
            fields.push(("final_loss", Json::Num(f)));
        }
        if let Some(r) = &self.resume {
            fields.push(("resume", r.to_json()));
        }
        Json::obj(fields)
    }

    /// Parse a checkpoint JSON object.
    pub fn from_json(v: &Json) -> Result<Checkpoint> {
        let sizes = v
            .get("sizes")
            .and_then(Json::as_arr)
            .context("checkpoint missing sizes")?
            .iter()
            .map(|s| s.as_usize().context("bad size"))
            .collect::<Result<Vec<_>>>()?;
        let theta = v
            .get("theta")
            .and_then(Json::as_f64_vec)
            .context("checkpoint missing theta (or theta holds non-numeric entries)")?;
        let activation = match v.get("activation") {
            // Pre-activation-field checkpoints were all tanh.
            None => ActivationKind::Tanh,
            Some(a) => {
                let name = a.as_str().context("checkpoint activation must be a string")?;
                ActivationKind::from_name(name)
                    .with_context(|| format!("unknown checkpoint activation '{name}'"))?
            }
        };
        let resume = match v.get("resume") {
            None => None,
            Some(r) => Some(ResumeState::from_json(r).context("bad resume state")?),
        };
        Ok(Checkpoint {
            sizes,
            activation,
            theta,
            lambda: v.get("lambda").and_then(Json::as_f64),
            profile_k: v.get("profile_k").and_then(Json::as_usize),
            final_loss: v.get("final_loss").and_then(Json::as_f64),
            resume,
        })
    }

    /// Structural + numeric validation — the [`Checkpoint::load`] gate,
    /// exposed so in-memory checkpoints (e.g. a just-built resume
    /// snapshot) can be checked without a disk roundtrip.
    pub fn validate(&self) -> Result<(), CheckpointError> {
        if self.sizes.len() < 2 {
            return Err(CheckpointError::Schema(format!(
                "architecture needs at least input and output sizes, got {:?}",
                self.sizes
            )));
        }
        if self.sizes.iter().any(|&s| s == 0) {
            return Err(CheckpointError::Schema(format!(
                "architecture has a zero-width layer: {:?}",
                self.sizes
            )));
        }
        let want = param_count(&self.sizes);
        if self.theta.len() != want {
            return Err(CheckpointError::ShapeMismatch(format!(
                "theta has {} values, architecture {:?} wants {want}",
                self.theta.len(),
                self.sizes
            )));
        }
        if !Isa::active().all_finite(&self.theta) {
            let bad = self
                .theta
                .iter()
                .position(|x| !x.is_finite())
                .unwrap_or(0);
            return Err(CheckpointError::NonFinite(format!(
                "theta[{bad}] is {} — refusing to serve or resume a diverged model",
                self.theta[bad]
            )));
        }
        if let Some(l) = self.lambda {
            if !l.is_finite() {
                return Err(CheckpointError::NonFinite(format!("lambda is {l}")));
            }
        }
        if let Some(r) = &self.resume {
            r.validate(want)?;
        }
        Ok(())
    }

    /// Write the checkpoint JSON to `path` **atomically**: the payload
    /// goes to a sibling `*.tmp` file which is fsynced and then renamed
    /// over the target. A crash mid-save leaves the previous checkpoint
    /// intact; the reader can never observe a half-written file under
    /// the final name.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating checkpoint dir {}", parent.display()))?;
            }
        }
        let mut tmp_name = path
            .file_name()
            .map(|n| n.to_os_string())
            .context("checkpoint path has no file name")?;
        tmp_name.push(".tmp");
        let tmp = path.with_file_name(tmp_name);
        {
            let mut f = File::create(&tmp)
                .with_context(|| format!("creating checkpoint temp {}", tmp.display()))?;
            f.write_all(self.to_json().dump().as_bytes())
                .with_context(|| format!("writing checkpoint temp {}", tmp.display()))?;
            f.sync_all()
                .with_context(|| format!("syncing checkpoint temp {}", tmp.display()))?;
        }
        std::fs::rename(&tmp, path)
            .with_context(|| format!("publishing checkpoint {}", path.display()))?;
        // Make the rename itself durable where the platform allows
        // fsyncing a directory; a failure here degrades durability, not
        // atomicity, so it is not fatal.
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                if let Ok(dir) = File::open(parent) {
                    let _ = dir.sync_all();
                }
            }
        }
        Ok(())
    }

    /// Load a checkpoint from `path`, classifying every failure mode as
    /// a [`CheckpointError`] (I/O, truncated/corrupted JSON, schema,
    /// non-finite values, shape mismatch).
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            CheckpointError::Io(format!("reading {}: {e}", path.display()))
        })?;
        let v = Json::parse(&text).map_err(|e| {
            CheckpointError::Corrupted(format!(
                "{} is not valid JSON ({e}) — truncated or corrupted write?",
                path.display()
            ))
        })?;
        let ck = Self::from_json(&v).map_err(|e| {
            CheckpointError::Schema(format!("{}: {e:#}", path.display()))
        })?;
        ck.validate()
            .map_err(|e| anyhow::Error::msg(format!("{}: {e}", path.display())))?;
        Ok(ck)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kind_of(err: &anyhow::Error) -> String {
        // The taxonomy tag survives context chains through the stable
        // `checkpoint <kind>:` Display prefix.
        let text = format!("{err:#}");
        for kind in ["io", "corrupted", "schema", "non-finite", "shape-mismatch"] {
            if text.contains(&format!("checkpoint {kind}:")) {
                return kind.to_string();
            }
        }
        format!("unclassified: {text}")
    }

    #[test]
    fn roundtrip_through_json() {
        let mut rng = Prng::seeded(4);
        let mlp = Mlp::uniform(1, 6, 2, 1, &mut rng);
        let mut ck = Checkpoint::from_mlp(&mlp);
        ck.lambda = Some(0.5);
        ck.profile_k = Some(1);
        ck.final_loss = Some(1e-6);
        let parsed = Checkpoint::from_json(&Json::parse(&ck.to_json().dump()).unwrap()).unwrap();
        assert_eq!(parsed.sizes, ck.sizes);
        assert_eq!(parsed.lambda, Some(0.5));
        assert_eq!(parsed.profile_k, Some(1));
        let back = parsed.to_mlp().unwrap();
        let x = Tensor::linspace(-1.0, 1.0, 4).reshape(&[4, 1]);
        assert_eq!(back.forward(&x), mlp.forward(&x));
    }

    #[test]
    fn file_roundtrip() {
        let mut rng = Prng::seeded(5);
        let mlp = Mlp::uniform(1, 4, 1, 1, &mut rng);
        let ck = Checkpoint::from_mlp(&mlp);
        let path = std::env::temp_dir().join("ntangent_ck_test.json");
        ck.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.theta, ck.theta);
    }

    #[test]
    fn arity_mismatch_rejected() {
        let ck = Checkpoint {
            sizes: vec![1, 4, 1],
            activation: ActivationKind::Tanh,
            theta: vec![0.0; 3], // wrong
            lambda: None,
            profile_k: None,
            final_loss: None,
            resume: None,
        };
        assert!(ck.to_mlp().is_err());
        assert_eq!(
            ck.validate().unwrap_err().kind(),
            "shape-mismatch",
            "validate classifies the arity mismatch"
        );
    }

    /// Acceptance: a checkpoint saved with any registered activation
    /// reloads and reproduces *identical* derivative channels.
    #[test]
    fn roundtrip_preserves_activation_and_channels() {
        use crate::ntp::NtpEngine;
        for kind in ActivationKind::ALL {
            let mut rng = Prng::seeded(40 + kind.index() as u64);
            let mlp = Mlp::uniform_with(1, 6, 2, 1, kind, &mut rng);
            let ck = Checkpoint::from_mlp(&mlp);
            let parsed =
                Checkpoint::from_json(&Json::parse(&ck.to_json().dump()).unwrap()).unwrap();
            assert_eq!(parsed.activation, kind);
            let back = parsed.to_mlp().unwrap();
            assert_eq!(back.activation, kind);
            let x = Tensor::linspace(-1.0, 1.0, 5).reshape(&[5, 1]);
            let engine = NtpEngine::new(4);
            let a = engine.forward(&mlp, &x);
            let b = engine.forward(&back, &x);
            for (ca, cb) in a.iter().zip(&b) {
                assert_eq!(ca, cb, "{} channels changed across roundtrip", kind.name());
            }
        }
    }

    #[test]
    fn legacy_checkpoint_without_activation_defaults_to_tanh() {
        let mut rng = Prng::seeded(50);
        let mlp = Mlp::uniform(1, 4, 1, 1, &mut rng);
        let ck = Checkpoint::from_mlp(&mlp);
        // Simulate an old artifact: strip the activation field.
        let dumped = ck.to_json().dump();
        let parsed = Json::parse(&dumped).unwrap();
        let stripped = match parsed {
            Json::Obj(fields) => {
                Json::Obj(fields.into_iter().filter(|(k, _)| k != "activation").collect())
            }
            other => other,
        };
        let loaded = Checkpoint::from_json(&stripped).unwrap();
        assert_eq!(loaded.activation, ActivationKind::Tanh);
        assert!(Checkpoint::from_json(
            &Json::parse(&dumped.replace("\"tanh\"", "\"relu\"")).unwrap()
        )
        .is_err());
    }

    /// The resume state — both optimizers' memory, the STDE counter and
    /// the recovery schedule position — survives a JSON disk roundtrip
    /// bitwise (the writer uses shortest-roundtrip float encoding).
    #[test]
    fn resume_state_roundtrips_bitwise() {
        let mut rng = Prng::seeded(77);
        let mlp = Mlp::uniform(1, 5, 2, 1, &mut rng);
        let dim = mlp.n_params() + 1; // + λ
        let noise = |rng: &mut Prng, n: usize| -> Vec<f64> {
            (0..n).map(|_| rng.normal_with(0.0, 1.0) * 1e-3 + 0.123456789).collect()
        };
        let mut ck = Checkpoint::from_mlp(&mlp);
        ck.lambda = Some(0.987654321);
        ck.resume = Some(ResumeState {
            phase: ResumePhase::Lbfgs,
            epoch: 17,
            theta: noise(&mut rng, dim),
            adam: Some(AdamResume {
                m: noise(&mut rng, dim),
                v: noise(&mut rng, dim).iter().map(|x| x * x).collect(),
                t: 300,
            }),
            lbfgs: Some(LbfgsResume {
                s: vec![noise(&mut rng, dim), noise(&mut rng, dim)],
                y: vec![noise(&mut rng, dim), noise(&mut rng, dim)],
                last_grad: Some(noise(&mut rng, dim)),
            }),
            stde_step: 42,
            retries: 1,
            ls_failures: 1,
            lr_scale: 0.5,
        });
        let path = std::env::temp_dir().join("ntangent_ck_resume_test.json");
        ck.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.theta, ck.theta);
        let want = ck.resume.unwrap();
        let got = loaded.resume.expect("resume state survived");
        assert_eq!(got, want);
    }

    /// Simulated mid-write truncation: every prefix of a valid
    /// checkpoint file fails `load` with the `corrupted` (or, for the
    /// empty file, still `corrupted`) classification — never a panic.
    #[test]
    fn truncated_files_fail_with_corrupted_taxonomy() {
        let mut rng = Prng::seeded(51);
        let mlp = Mlp::uniform(1, 4, 1, 1, &mut rng);
        let ck = Checkpoint::from_mlp(&mlp);
        let full = ck.to_json().dump();
        let path = std::env::temp_dir().join("ntangent_ck_trunc_test.json");
        for cut in [0, 1, full.len() / 4, full.len() / 2, full.len() - 1] {
            std::fs::write(&path, &full[..cut]).unwrap();
            let err = Checkpoint::load(&path).unwrap_err();
            assert_eq!(kind_of(&err), "corrupted", "cut at {cut}: {err:#}");
        }
        // The full file still loads.
        std::fs::write(&path, &full).unwrap();
        assert!(Checkpoint::load(&path).is_ok());
    }

    #[test]
    fn load_failures_are_classified() {
        let dir = std::env::temp_dir();
        let path = dir.join("ntangent_ck_taxonomy_test.json");

        // io: missing file
        let missing = dir.join("ntangent_ck_does_not_exist.json");
        let _ = std::fs::remove_file(&missing);
        assert_eq!(kind_of(&Checkpoint::load(&missing).unwrap_err()), "io");

        // corrupted: not JSON at all
        std::fs::write(&path, "not json {{{").unwrap();
        assert_eq!(kind_of(&Checkpoint::load(&path).unwrap_err()), "corrupted");

        // schema: valid JSON, wrong shape of document
        std::fs::write(&path, "[1,2,3]").unwrap();
        assert_eq!(kind_of(&Checkpoint::load(&path).unwrap_err()), "schema");

        // schema: theta with a null hole (the writer's encoding of a
        // non-finite value)
        std::fs::write(
            &path,
            r#"{"sizes":[1,2,1],"activation":"tanh","theta":[0.1,null,0.2]}"#,
        )
        .unwrap();
        assert_eq!(kind_of(&Checkpoint::load(&path).unwrap_err()), "schema");

        // non-finite: an overflowing literal parses to +inf
        let inf_theta: Vec<String> =
            (0..7).map(|i| if i == 3 { "1e999".to_string() } else { "0.1".to_string() }).collect();
        std::fs::write(
            &path,
            format!(
                r#"{{"sizes":[1,2,1],"activation":"tanh","theta":[{}]}}"#,
                inf_theta.join(",")
            ),
        )
        .unwrap();
        assert_eq!(kind_of(&Checkpoint::load(&path).unwrap_err()), "non-finite");

        // shape-mismatch: sizes want 7 params, theta has 5
        std::fs::write(
            &path,
            r#"{"sizes":[1,2,1],"activation":"tanh","theta":[0.1,0.1,0.1,0.1,0.1]}"#,
        )
        .unwrap();
        assert_eq!(kind_of(&Checkpoint::load(&path).unwrap_err()), "shape-mismatch");
    }

    /// The atomic save leaves no `*.tmp` debris and replaces the target
    /// in one step: after overwriting an existing checkpoint the old
    /// content is fully gone and the new content fully present.
    #[test]
    fn atomic_save_replaces_cleanly() {
        let mut rng = Prng::seeded(52);
        let a = Checkpoint::from_mlp(&Mlp::uniform(1, 4, 1, 1, &mut rng));
        let b = Checkpoint::from_mlp(&Mlp::uniform(1, 4, 1, 1, &mut rng));
        let path = std::env::temp_dir().join("ntangent_ck_atomic_test.json");
        a.save(&path).unwrap();
        b.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.theta, b.theta);
        assert_ne!(loaded.theta, a.theta);
        let tmp = path.with_file_name("ntangent_ck_atomic_test.json.tmp");
        assert!(!tmp.exists(), "temp file must not survive a save");
    }

    #[test]
    fn resume_shape_violations_are_rejected() {
        let mut rng = Prng::seeded(53);
        let mlp = Mlp::uniform(1, 4, 1, 1, &mut rng);
        let dim = mlp.n_params();
        let mut ck = Checkpoint::from_mlp(&mlp);
        ck.resume = Some(ResumeState {
            phase: ResumePhase::Adam,
            epoch: 3,
            theta: vec![0.1; dim],
            adam: Some(AdamResume { m: vec![0.0; dim - 1], v: vec![0.0; dim], t: 3 }),
            lbfgs: None,
            stde_step: 0,
            retries: 0,
            ls_failures: 0,
            lr_scale: 1.0,
        });
        assert_eq!(ck.validate().unwrap_err().kind(), "shape-mismatch");

        let mut nan = ck.clone();
        if let Some(r) = nan.resume.as_mut() {
            r.adam = None;
            r.theta[1] = f64::NAN;
        }
        assert_eq!(nan.validate().unwrap_err().kind(), "non-finite");
    }
}
