//! Adam (Kingma & Ba, 2015) with bias correction — the paper's
//! exploration-phase optimizer.
//!
//! The moment/parameter update is elementwise, so a [`ParallelPolicy`]
//! can split it across contiguous blocks on scoped threads with results
//! that are bitwise identical to the serial update for any worker count
//! (no cross-element reductions anywhere). The block splitting itself is
//! the shared [`crate::util::par::update_blocks`] skeleton (same as
//! [`super::Sgd`]).

use super::Objective;
use crate::ntp::ParallelPolicy;
use crate::simd::{AdamCoeffs, Isa};
use crate::tensor::Tensor;
use crate::util::par;

/// Adam state over a flat parameter vector.
#[derive(Clone, Debug)]
pub struct Adam {
    /// Learning rate.
    pub lr: f64,
    /// First-moment decay.
    pub beta1: f64,
    /// Second-moment decay.
    pub beta2: f64,
    /// Denominator fuzz.
    pub eps: f64,
    m: Tensor,
    v: Tensor,
    t: u64,
    policy: ParallelPolicy,
}

impl Adam {
    /// Fresh state for `dim` parameters (serial updates).
    pub fn new(dim: usize, lr: f64) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: Tensor::zeros(&[dim]),
            v: Tensor::zeros(&[dim]),
            t: 0,
            policy: ParallelPolicy::Serial,
        }
    }

    /// Split the elementwise update across threads per `policy` (bitwise
    /// identical to serial for any worker count).
    pub fn with_policy(mut self, policy: ParallelPolicy) -> Adam {
        self.policy = policy;
        self
    }

    /// The update-parallelism policy.
    pub fn policy(&self) -> ParallelPolicy {
        self.policy
    }

    /// One update in place; returns the step's loss.
    pub fn step(&mut self, obj: &mut dyn Objective, theta: &mut Tensor) -> f64 {
        let (loss, grad) = obj.value_grad(theta);
        self.apply(theta, &grad);
        loss
    }

    /// Apply a raw gradient (used when the caller already has it).
    pub fn apply(&mut self, theta: &mut Tensor, grad: &Tensor) {
        assert_eq!(theta.numel(), grad.numel());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        let lr_t = self.lr * b2t.sqrt() / b1t;
        let co = AdamCoeffs { beta1: self.beta1, beta2: self.beta2, lr_t, eps: self.eps };
        let isa = Isa::active();
        par::update_blocks(
            self.policy,
            par::UPDATE_BLOCK,
            [self.m.data_mut(), self.v.data_mut(), theta.data_mut()],
            grad.data(),
            |muts, g| {
                let [m, v, th] = muts;
                isa.adam_block(m, v, th, g, co);
            },
        );
    }

    /// Number of updates applied so far.
    pub fn steps_taken(&self) -> u64 {
        self.t
    }

    /// Export the moment state for a resume checkpoint
    /// (`(m, v, steps_taken)`).
    pub fn export_state(&self) -> (Vec<f64>, Vec<f64>, u64) {
        (self.m.data().to_vec(), self.v.data().to_vec(), self.t)
    }

    /// Restore state exported by [`Adam::export_state`] — the next
    /// [`Adam::apply`] then produces the bitwise-identical update the
    /// uninterrupted run would have. `m`/`v` must match the optimizer
    /// dimension.
    pub fn restore_state(&mut self, m: &[f64], v: &[f64], t: u64) {
        assert_eq!(m.len(), self.m.numel(), "adam m length mismatch");
        assert_eq!(v.len(), self.v.numel(), "adam v length mismatch");
        self.m = Tensor::from_vec(m.to_vec(), &[m.len()]);
        self.v = Tensor::from_vec(v.to_vec(), &[v.len()]);
        self.t = t;
    }

    /// Reset moments (used when switching phases).
    pub fn reset(&mut self) {
        self.m = Tensor::zeros(self.m.shape());
        self.v = Tensor::zeros(self.v.shape());
        self.t = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::{Quadratic, Rosenbrock};
    use crate::util::prng::Prng;

    #[test]
    fn converges_on_quadratic() {
        let center = Tensor::from_vec(vec![1.0, -2.0, 3.0], &[3]);
        let mut obj = Quadratic { center: center.clone() };
        let mut theta = Tensor::zeros(&[3]);
        let mut adam = Adam::new(3, 0.05);
        for _ in 0..2000 {
            adam.step(&mut obj, &mut theta);
        }
        let err = theta.sub(&center).norm();
        assert!(err < 1e-4, "err {err}");
    }

    #[test]
    fn makes_progress_on_rosenbrock() {
        let mut obj = Rosenbrock;
        let mut theta = Tensor::from_vec(vec![-1.2, 1.0], &[2]);
        let mut adam = Adam::new(2, 0.01);
        let first = adam.step(&mut obj, &mut theta);
        let mut last = first;
        for _ in 0..5000 {
            last = adam.step(&mut obj, &mut theta);
        }
        assert!(last < first * 0.01, "first {first} last {last}");
    }

    #[test]
    fn first_step_magnitude_is_lr() {
        // With bias correction, the first Adam step is ≈ lr in magnitude.
        let mut obj = Quadratic { center: Tensor::from_vec(vec![10.0], &[1]) };
        let mut theta = Tensor::zeros(&[1]);
        let mut adam = Adam::new(1, 0.1);
        adam.step(&mut obj, &mut theta);
        assert!((theta.data()[0].abs() - 0.1).abs() < 1e-6, "{:?}", theta.data());
    }

    #[test]
    fn reset_clears_state() {
        let mut adam = Adam::new(2, 0.1);
        let mut theta = Tensor::zeros(&[2]);
        adam.apply(&mut theta, &Tensor::ones(&[2]));
        assert_eq!(adam.steps_taken(), 1);
        adam.reset();
        assert_eq!(adam.steps_taken(), 0);
        assert_eq!(adam.m.data(), &[0.0, 0.0]);
    }

    /// Parallel updates are bitwise identical to serial ones, for sizes
    /// around the block boundaries and repeated (stateful) steps.
    #[test]
    fn parallel_apply_is_bitwise_identical_to_serial() {
        const UPDATE_BLOCK: usize = par::UPDATE_BLOCK;
        for dim in [3usize, UPDATE_BLOCK - 1, UPDATE_BLOCK + 1, 3 * UPDATE_BLOCK + 17] {
            let mut rng = Prng::seeded(0xADA + dim as u64);
            let mut serial = Adam::new(dim, 0.01);
            let mut parallel = Adam::new(dim, 0.01).with_policy(ParallelPolicy::Fixed(3));
            let mut ta = Tensor::rand_normal(&[dim], 0.0, 1.0, &mut rng);
            let mut tb = ta.clone();
            for _ in 0..3 {
                let g = Tensor::rand_normal(&[dim], 0.0, 1.0, &mut rng);
                serial.apply(&mut ta, &g);
                parallel.apply(&mut tb, &g);
                assert_eq!(ta, tb, "dim {dim}");
            }
        }
    }

    /// Export at step k, restore into a fresh optimizer, continue: the
    /// trajectory is bitwise identical to never having stopped.
    #[test]
    fn export_restore_resumes_bitwise() {
        let dim = 37;
        let mut rng = Prng::seeded(0xADB);
        let grads: Vec<Tensor> =
            (0..8).map(|_| Tensor::rand_normal(&[dim], 0.0, 1.0, &mut rng)).collect();
        let theta0 = Tensor::rand_normal(&[dim], 0.0, 1.0, &mut rng);

        let mut full = Adam::new(dim, 0.01);
        let mut tf = theta0.clone();
        for g in &grads {
            full.apply(&mut tf, g);
        }

        let mut first = Adam::new(dim, 0.01);
        let mut tr = theta0.clone();
        for g in &grads[..3] {
            first.apply(&mut tr, g);
        }
        let (m, v, t) = first.export_state();
        let mut resumed = Adam::new(dim, 0.01);
        resumed.restore_state(&m, &v, t);
        for g in &grads[3..] {
            resumed.apply(&mut tr, g);
        }
        assert_eq!(tr, tf);
        assert_eq!(resumed.steps_taken(), full.steps_taken());
    }
}
