//! Adam (Kingma & Ba, 2015) with bias correction — the paper's
//! exploration-phase optimizer.

use super::Objective;
use crate::tensor::Tensor;

/// Adam state over a flat parameter vector.
#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Tensor,
    v: Tensor,
    t: u64,
}

impl Adam {
    pub fn new(dim: usize, lr: f64) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: Tensor::zeros(&[dim]),
            v: Tensor::zeros(&[dim]),
            t: 0,
        }
    }

    /// One update in place; returns the step's loss.
    pub fn step(&mut self, obj: &mut dyn Objective, theta: &mut Tensor) -> f64 {
        let (loss, grad) = obj.value_grad(theta);
        self.apply(theta, &grad);
        loss
    }

    /// Apply a raw gradient (used when the caller already has it).
    pub fn apply(&mut self, theta: &mut Tensor, grad: &Tensor) {
        assert_eq!(theta.numel(), grad.numel());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        let lr_t = self.lr * b2t.sqrt() / b1t;
        let (m, v) = (self.m.data_mut(), self.v.data_mut());
        let g = grad.data();
        let th = theta.data_mut();
        for i in 0..g.len() {
            m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * g[i];
            v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * g[i] * g[i];
            th[i] -= lr_t * m[i] / (v[i].sqrt() + self.eps);
        }
    }

    pub fn steps_taken(&self) -> u64 {
        self.t
    }

    /// Reset moments (used when switching phases).
    pub fn reset(&mut self) {
        self.m = Tensor::zeros(self.m.shape());
        self.v = Tensor::zeros(self.v.shape());
        self.t = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::{Quadratic, Rosenbrock};

    #[test]
    fn converges_on_quadratic() {
        let center = Tensor::from_vec(vec![1.0, -2.0, 3.0], &[3]);
        let mut obj = Quadratic { center: center.clone() };
        let mut theta = Tensor::zeros(&[3]);
        let mut adam = Adam::new(3, 0.05);
        for _ in 0..2000 {
            adam.step(&mut obj, &mut theta);
        }
        let err = theta.sub(&center).norm();
        assert!(err < 1e-4, "err {err}");
    }

    #[test]
    fn makes_progress_on_rosenbrock() {
        let mut obj = Rosenbrock;
        let mut theta = Tensor::from_vec(vec![-1.2, 1.0], &[2]);
        let mut adam = Adam::new(2, 0.01);
        let first = adam.step(&mut obj, &mut theta);
        let mut last = first;
        for _ in 0..5000 {
            last = adam.step(&mut obj, &mut theta);
        }
        assert!(last < first * 0.01, "first {first} last {last}");
    }

    #[test]
    fn first_step_magnitude_is_lr() {
        // With bias correction, the first Adam step is ≈ lr in magnitude.
        let mut obj = Quadratic { center: Tensor::from_vec(vec![10.0], &[1]) };
        let mut theta = Tensor::zeros(&[1]);
        let mut adam = Adam::new(1, 0.1);
        adam.step(&mut obj, &mut theta);
        assert!((theta.data()[0].abs() - 0.1).abs() < 1e-6, "{:?}", theta.data());
    }

    #[test]
    fn reset_clears_state() {
        let mut adam = Adam::new(2, 0.1);
        let mut theta = Tensor::zeros(&[2]);
        adam.apply(&mut theta, &Tensor::ones(&[2]));
        assert_eq!(adam.steps_taken(), 1);
        adam.reset();
        assert_eq!(adam.steps_taken(), 0);
        assert_eq!(adam.m.data(), &[0.0, 0.0]);
    }
}
