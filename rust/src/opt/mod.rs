//! First- and quasi-second-order optimizers over flat parameter vectors.
//!
//! The paper's training schedule is Adam (exploration) followed by L-BFGS
//! with a line search (refinement) — the L-BFGS line search performs
//! multiple *forward* passes per step, which is where n-TangentProp's
//! forward-pass advantage compounds (paper §IV-C, Fig. 6).
//!
//! All three optimizers accept a [`crate::ntp::ParallelPolicy`] via
//! `with_policy`: Adam/SGD split their elementwise updates across scoped
//! threads, L-BFGS computes its inner products with the deterministic
//! chunked reduction of [`crate::util::par`]. In every case the policy is
//! scheduling-only — results are bitwise identical to serial, which is
//! what keeps multi-threaded training trajectories reproducible.

pub mod adam;
pub mod lbfgs;
pub mod sgd;

pub use adam::Adam;
pub use lbfgs::{Lbfgs, LbfgsStatus};
pub use sgd::Sgd;

use crate::tensor::Tensor;

/// A differentiable objective over a flat parameter vector.
///
/// `value_grad` returns `(loss, dloss/dtheta)`; `value` alone may be
/// cheaper (L-BFGS line searches exploit that — the paper's Fig. 6
/// mechanism).
pub trait Objective {
    /// `(loss, dloss/dtheta)` at `theta`.
    fn value_grad(&mut self, theta: &Tensor) -> (f64, Tensor);

    /// Loss only; default delegates to `value_grad`.
    fn value(&mut self, theta: &Tensor) -> f64 {
        self.value_grad(theta).0
    }

    /// Losses at several parameter vectors at once — the line-search
    /// batch hook. The default evaluates sequentially; sharded
    /// objectives override it to fan `trials × shards` tasks through
    /// one worker-pool sweep. Implementations must return exactly what
    /// per-trial [`Objective::value`] calls would (bitwise), so
    /// optimizers may batch freely without perturbing trajectories.
    fn value_batch(&mut self, thetas: &[Tensor]) -> Vec<f64> {
        thetas.iter().map(|t| self.value(t)).collect()
    }

    /// Number of parameters.
    fn dim(&self) -> usize;
}

/// A quadratic bowl objective for optimizer tests: `0.5·||x - c||²`.
pub struct Quadratic {
    /// The minimum location `c`.
    pub center: Tensor,
}

impl Objective for Quadratic {
    fn value_grad(&mut self, theta: &Tensor) -> (f64, Tensor) {
        let d = theta.sub(&self.center);
        (0.5 * d.dot(&d), d)
    }

    fn dim(&self) -> usize {
        self.center.numel()
    }
}

/// The 2-D Rosenbrock function — the classic L-BFGS acceptance test.
pub struct Rosenbrock;

impl Objective for Rosenbrock {
    fn value_grad(&mut self, theta: &Tensor) -> (f64, Tensor) {
        let (x, y) = (theta.data()[0], theta.data()[1]);
        let f = (1.0 - x).powi(2) + 100.0 * (y - x * x).powi(2);
        let gx = -2.0 * (1.0 - x) - 400.0 * x * (y - x * x);
        let gy = 200.0 * (y - x * x);
        (f, Tensor::from_vec(vec![gx, gy], &[2]))
    }

    fn dim(&self) -> usize {
        2
    }
}
