//! L-BFGS with line search (Nocedal & Wright, Algorithms 7.4/7.5 + 3.5/3.6).
//!
//! Two line searches are provided:
//!
//! - [`LineSearch::Backtracking`] — Armijo backtracking using **function
//!   values only**. This mirrors the open-source PyTorch-LBFGS the paper
//!   uses: each trial point costs one *forward* pass and the step costs a
//!   single backward pass, which is exactly why the paper's forward-pass
//!   speedups compound during the L-BFGS phase (Fig. 6). Trial points
//!   past the interpolation candidate form a data-independent halving
//!   ladder, so they pipeline through [`Objective::value_batch`] — on a
//!   sharded objective one pool sweep evaluates `trials × shards` tapes
//!   instead of serializing a sweep per trial.
//! - [`LineSearch::StrongWolfe`] — bracketing + zoom enforcing the strong
//!   Wolfe conditions (needs gradients at trial points; more robust).
//!
//! The optimizer counts value and gradient evaluations so the benchmark
//! harness can report the forward/backward mix.
//!
//! Every inner product (the two-loop recursion, curvature updates, line
//! searches) goes through [`par::det_dot`]: partial sums over fixed
//! element chunks combined with a deterministic pairwise tree, so the
//! optimizer state trajectory is **bitwise identical for every
//! [`ParallelPolicy`]** — the property the data-parallel trainer's
//! determinism test leans on.

use super::Objective;
use crate::ntp::ParallelPolicy;
use crate::tensor::Tensor;
use crate::util::par;

/// Line-search strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LineSearch {
    /// Armijo backtracking on function values only (forward-pass cheap).
    Backtracking,
    /// Bracketing + zoom enforcing the strong Wolfe conditions.
    StrongWolfe,
}

/// Step outcome.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LbfgsStatus {
    /// Gradient norm below tolerance before stepping.
    Converged,
    /// A step satisfying the line-search conditions was taken.
    StepTaken,
    /// No acceptable step found; parameters unchanged.
    LineSearchFailed,
}

/// L-BFGS state.
pub struct Lbfgs {
    /// History size (pairs kept for the two-loop recursion).
    pub m: usize,
    /// Armijo constant.
    pub c1: f64,
    /// Curvature constant (strong Wolfe).
    pub c2: f64,
    /// Gradient-norm convergence tolerance.
    pub tol_grad: f64,
    /// Max line-search trials per step.
    pub max_ls: usize,
    /// Line-search strategy.
    pub line_search: LineSearch,
    history: Vec<(Tensor, Tensor)>, // (s, y) pairs, newest last
    last_grad: Option<Tensor>,
    policy: ParallelPolicy,
    /// Count of `value`-only evaluations (forward passes).
    pub n_value_evals: u64,
    /// Count of `value_grad` evaluations (forward+backward passes).
    pub n_grad_evals: u64,
}

impl Lbfgs {
    /// Fresh state (backtracking line search, serial reductions).
    pub fn new(_dim: usize) -> Lbfgs {
        Lbfgs {
            m: 10,
            c1: 1e-4,
            c2: 0.9,
            tol_grad: 1e-12,
            max_ls: 25,
            line_search: LineSearch::Backtracking,
            history: Vec::new(),
            last_grad: None,
            policy: ParallelPolicy::Serial,
            n_value_evals: 0,
            n_grad_evals: 0,
        }
    }

    /// Select the line-search strategy.
    pub fn with_line_search(mut self, ls: LineSearch) -> Lbfgs {
        self.line_search = ls;
        self
    }

    /// Compute inner products on a `policy`-sized thread pool. Purely a
    /// scheduling knob: [`par::det_dot`] returns the same bits for every
    /// policy, so trajectories never depend on the worker count.
    pub fn with_policy(mut self, policy: ParallelPolicy) -> Lbfgs {
        self.policy = policy;
        self
    }

    /// The reduction-parallelism policy.
    pub fn policy(&self) -> ParallelPolicy {
        self.policy
    }

    /// Thread-count-invariant inner product (see the module docs).
    fn dot(&self, a: &Tensor, b: &Tensor) -> f64 {
        par::det_dot(a.data(), b.data(), self.policy)
    }

    fn value(&mut self, obj: &mut dyn Objective, theta: &Tensor) -> f64 {
        self.n_value_evals += 1;
        obj.value(theta)
    }

    fn value_batch(&mut self, obj: &mut dyn Objective, trials: &[Tensor]) -> Vec<f64> {
        self.n_value_evals += trials.len() as u64;
        obj.value_batch(trials)
    }

    fn value_grad(&mut self, obj: &mut dyn Objective, theta: &Tensor) -> (f64, Tensor) {
        self.n_grad_evals += 1;
        obj.value_grad(theta)
    }

    /// Two-loop recursion: approximate `H·g` (descent direction is `-H·g`).
    fn direction(&self, grad: &Tensor) -> Tensor {
        let mut q = grad.clone();
        let k = self.history.len();
        let mut alphas = vec![0.0; k];
        let mut rhos = vec![0.0; k];
        for i in (0..k).rev() {
            let (s, y) = &self.history[i];
            rhos[i] = 1.0 / self.dot(y, s);
            alphas[i] = rhos[i] * self.dot(s, &q);
            q.axpy_inplace(-alphas[i], y);
        }
        // Initial Hessian scaling gamma = s·y / y·y (N&W eq. 7.20).
        if let Some((s, y)) = self.history.last() {
            let gamma = self.dot(s, y) / self.dot(y, y);
            q = q.scale(gamma);
        }
        for i in 0..k {
            let (s, y) = &self.history[i];
            let beta = rhos[i] * self.dot(y, &q);
            q.axpy_inplace(alphas[i] - beta, s);
        }
        q.neg()
    }

    /// One L-BFGS iteration; updates `theta` in place on success.
    /// Returns `(loss at the start of the step, status)`.
    pub fn step(&mut self, obj: &mut dyn Objective, theta: &mut Tensor) -> (f64, LbfgsStatus) {
        let (f0, g0) = match self.last_grad.take() {
            // Reuse the gradient computed at the end of the previous step.
            Some(g) => {
                let f = self.value(obj, theta);
                (f, g)
            }
            None => self.value_grad(obj, theta),
        };
        if g0.norm() < self.tol_grad {
            self.last_grad = Some(g0);
            return (f0, LbfgsStatus::Converged);
        }

        let mut dir = self.direction(&g0);
        let mut dg0 = self.dot(&dir, &g0);
        if dg0 >= 0.0 {
            // Not a descent direction (stale curvature) — reset to steepest.
            self.history.clear();
            dir = g0.neg();
            dg0 = self.dot(&dir, &g0);
        }

        let result = match self.line_search {
            LineSearch::Backtracking => self.backtracking(obj, theta, &dir, f0, dg0),
            LineSearch::StrongWolfe => self.strong_wolfe(obj, theta, &dir, f0, dg0, &g0),
        };

        match result {
            Some((alpha, f_new, g_new)) => {
                let step = dir.scale(alpha);
                let s = step.clone();
                let new_theta = theta.add(&step);
                let g_new = match g_new {
                    Some(g) => g,
                    None => self.value_grad(obj, &new_theta).1,
                };
                let y = g_new.sub(&g0);
                let sy = self.dot(&s, &y);
                if sy > 1e-10 * s.norm() * y.norm() {
                    self.history.push((s, y));
                    if self.history.len() > self.m {
                        self.history.remove(0);
                    }
                }
                *theta = new_theta;
                self.last_grad = Some(g_new);
                let _ = f_new;
                (f0, LbfgsStatus::StepTaken)
            }
            None => {
                // Drop stale curvature so the next step falls back to
                // (scaled) steepest descent instead of retrying the same
                // direction forever.
                self.history.clear();
                self.last_grad = Some(g0);
                (f0, LbfgsStatus::LineSearchFailed)
            }
        }
    }

    /// Armijo backtracking: values only, gradient deferred to the accepted
    /// point. The unit step is probed alone (the common accept — one
    /// forward pass), then the quadratic-interpolation candidate alone;
    /// past that the ladder is pure halving, **data-independent**, so its
    /// trials go through [`Objective::value_batch`] in waves — a sharded
    /// objective evaluates `trials × shards` tapes in one pool sweep.
    /// Acceptance is the first Armijo-satisfying trial in ladder order and
    /// `value_batch` is bitwise-equal to sequential `value` calls, so the
    /// trajectory is a pure function of the objective, never the policy.
    /// Returns `(alpha, f(alpha), None)`.
    fn backtracking(
        &mut self,
        obj: &mut dyn Objective,
        theta: &Tensor,
        dir: &Tensor,
        f0: f64,
        dg0: f64,
    ) -> Option<(f64, f64, Option<Tensor>)> {
        const WAVE: usize = 4;
        let c1 = self.c1;

        // Wave 0: the unit step alone.
        let f1 = self.value_batch(obj, &[theta.axpy(1.0, dir)])[0];
        if f1.is_finite() && f1 <= f0 + c1 * dg0 {
            return Some((1.0, f1, None));
        }
        // Quadratic interpolation on φ(α) using φ(0)=f0, φ'(0)=dg0,
        // φ(1)=f1 seeds the ladder (halving fallback when degenerate).
        let denom = 2.0 * (f1 - f0 - dg0);
        let seed = if f1.is_finite() && denom > 0.0 {
            (-dg0 / denom).clamp(0.1, 0.5)
        } else {
            0.5
        };

        let mut alpha = seed;
        let mut used = 1;
        let mut wave_len = 1; // interp candidate alone, then full waves
        while used < self.max_ls {
            let wave = wave_len.min(self.max_ls - used);
            let alphas: Vec<f64> = (0..wave).map(|i| alpha * 0.5f64.powi(i as i32)).collect();
            let trials: Vec<Tensor> = alphas.iter().map(|&a| theta.axpy(a, dir)).collect();
            let fs = self.value_batch(obj, &trials);
            for (&a, &f) in alphas.iter().zip(&fs) {
                if f.is_finite() && f <= f0 + c1 * a * dg0 {
                    return Some((a, f, None));
                }
            }
            used += wave;
            alpha *= 0.5f64.powi(wave as i32);
            wave_len = WAVE;
        }
        None
    }

    /// Strong-Wolfe bracketing + zoom (N&W alg. 3.5/3.6). Returns the
    /// accepted `(alpha, f, grad)` with the gradient already computed.
    fn strong_wolfe(
        &mut self,
        obj: &mut dyn Objective,
        theta: &Tensor,
        dir: &Tensor,
        f0: f64,
        dg0: f64,
        _g0: &Tensor,
    ) -> Option<(f64, f64, Option<Tensor>)> {
        let phi = |this: &mut Self, obj: &mut dyn Objective, a: f64| {
            let trial = theta.axpy(a, dir);
            let (f, g) = this.value_grad(obj, &trial);
            let dphi = this.dot(&g, dir);
            (f, dphi, g)
        };

        let mut alpha_prev = 0.0;
        let mut f_prev = f0;
        let mut alpha = 1.0;
        let alpha_max = 20.0;
        for i in 0..self.max_ls {
            let (f, dphi, g) = phi(self, obj, alpha);
            if !f.is_finite() || f > f0 + self.c1 * alpha * dg0 || (i > 0 && f >= f_prev) {
                return self.zoom(obj, theta, dir, f0, dg0, alpha_prev, f_prev, alpha);
            }
            if dphi.abs() <= -self.c2 * dg0 {
                return Some((alpha, f, Some(g)));
            }
            if dphi >= 0.0 {
                return self.zoom(obj, theta, dir, f0, dg0, alpha, f, alpha_prev);
            }
            alpha_prev = alpha;
            f_prev = f;
            alpha = (alpha * 2.0).min(alpha_max);
        }
        None
    }

    #[allow(clippy::too_many_arguments)]
    fn zoom(
        &mut self,
        obj: &mut dyn Objective,
        theta: &Tensor,
        dir: &Tensor,
        f0: f64,
        dg0: f64,
        mut lo: f64,
        mut f_lo: f64,
        mut hi: f64,
    ) -> Option<(f64, f64, Option<Tensor>)> {
        // Bisection needs ~50 halvings to localize a narrow Armijo window
        // (e.g. deep inside the Rosenbrock valley); give it more budget
        // than the bracketing phase.
        for _ in 0..(3 * self.max_ls) {
            let alpha = 0.5 * (lo + hi);
            let trial = theta.axpy(alpha, dir);
            let (f, g) = self.value_grad(obj, &trial);
            let dphi = self.dot(&g, dir);
            if !f.is_finite() || f > f0 + self.c1 * alpha * dg0 || f >= f_lo {
                hi = alpha;
            } else {
                if dphi.abs() <= -self.c2 * dg0 {
                    return Some((alpha, f, Some(g)));
                }
                if dphi * (hi - lo) >= 0.0 {
                    hi = lo;
                }
                lo = alpha;
                f_lo = f;
            }
            if (hi - lo).abs() < 1e-16 {
                break;
            }
        }
        None
    }

    /// Clear curvature history (e.g. when the objective changes).
    pub fn reset(&mut self) {
        self.history.clear();
        self.last_grad = None;
    }

    /// The gradient carried over from the last successful step (the one
    /// the next [`Lbfgs::step`] reuses instead of a fresh backward
    /// pass). Numeric health guards probe it for NaN/Inf between steps.
    pub fn last_grad(&self) -> Option<&Tensor> {
        self.last_grad.as_ref()
    }

    /// Export the curvature memory for a resume checkpoint:
    /// `(s vectors, y vectors, last_grad)`, oldest pair first.
    pub fn export_state(&self) -> (Vec<Vec<f64>>, Vec<Vec<f64>>, Option<Vec<f64>>) {
        let s = self.history.iter().map(|(s, _)| s.data().to_vec()).collect();
        let y = self.history.iter().map(|(_, y)| y.data().to_vec()).collect();
        let g = self.last_grad.as_ref().map(|g| g.data().to_vec());
        (s, y, g)
    }

    /// Restore state exported by [`Lbfgs::export_state`] — the next
    /// [`Lbfgs::step`] then walks the bitwise-identical trajectory the
    /// uninterrupted run would have (the carried-over gradient is what
    /// makes the first resumed step a `value`-only probe, exactly like
    /// the original run's next step). `s` and `y` must be paired.
    pub fn restore_state(&mut self, s: &[Vec<f64>], y: &[Vec<f64>], last_grad: Option<&[f64]>) {
        assert_eq!(s.len(), y.len(), "lbfgs history pairs mismatch");
        self.history = s
            .iter()
            .zip(y)
            .map(|(si, yi)| {
                assert_eq!(si.len(), yi.len(), "lbfgs s/y length mismatch");
                (
                    Tensor::from_vec(si.clone(), &[si.len()]),
                    Tensor::from_vec(yi.clone(), &[yi.len()]),
                )
            })
            .collect();
        self.last_grad = last_grad.map(|g| Tensor::from_vec(g.to_vec(), &[g.len()]));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::{Objective, Quadratic, Rosenbrock};

    fn minimize(
        obj: &mut dyn Objective,
        theta: &mut Tensor,
        ls: LineSearch,
        iters: usize,
    ) -> (f64, Lbfgs) {
        let mut opt = Lbfgs::new(theta.numel()).with_line_search(ls);
        let mut last = f64::INFINITY;
        for _ in 0..iters {
            let (f, status) = opt.step(obj, theta);
            last = f;
            if status == LbfgsStatus::Converged {
                break;
            }
        }
        (last, opt)
    }

    #[test]
    fn solves_quadratic_in_few_steps() {
        for ls in [LineSearch::Backtracking, LineSearch::StrongWolfe] {
            let center = Tensor::from_vec(vec![3.0, -1.0, 0.5, 2.0], &[4]);
            let mut obj = Quadratic { center: center.clone() };
            let mut theta = Tensor::zeros(&[4]);
            minimize(&mut obj, &mut theta, ls, 25);
            assert!(theta.sub(&center).norm() < 1e-8, "{ls:?}");
        }
    }

    #[test]
    fn solves_rosenbrock() {
        for ls in [LineSearch::Backtracking, LineSearch::StrongWolfe] {
            let mut obj = Rosenbrock;
            let mut theta = Tensor::from_vec(vec![-1.2, 1.0], &[2]);
            // Armijo-only backtracking traverses the valley slowly; give it
            // the budget the paper's L-BFGS phase would get.
            minimize(&mut obj, &mut theta, ls, 1500);
            let err = theta.sub(&Tensor::from_vec(vec![1.0, 1.0], &[2])).norm();
            assert!(err < 1e-5, "{ls:?}: theta {:?}", theta.data());
        }
    }

    #[test]
    fn backtracking_uses_more_values_than_grads() {
        // The Fig. 6 mechanism: line-search L-BFGS is forward-pass heavy.
        let mut obj = Rosenbrock;
        let mut theta = Tensor::from_vec(vec![-1.2, 1.0], &[2]);
        let (_, opt) = minimize(&mut obj, &mut theta, LineSearch::Backtracking, 100);
        assert!(
            opt.n_value_evals > opt.n_grad_evals,
            "values {} grads {}",
            opt.n_value_evals,
            opt.n_grad_evals
        );
    }

    #[test]
    fn accepted_steps_satisfy_armijo() {
        struct Wrapped {
            inner: Rosenbrock,
            trace: Vec<(Tensor, f64)>,
        }
        impl Objective for Wrapped {
            fn value_grad(&mut self, t: &Tensor) -> (f64, Tensor) {
                let (f, g) = self.inner.value_grad(t);
                self.trace.push((t.clone(), f));
                (f, g)
            }
            fn value(&mut self, t: &Tensor) -> f64 {
                let f = self.inner.value_grad(t).0;
                self.trace.push((t.clone(), f));
                f
            }
            fn dim(&self) -> usize {
                2
            }
        }
        let mut obj = Wrapped { inner: Rosenbrock, trace: vec![] };
        let mut theta = Tensor::from_vec(vec![-0.5, 0.8], &[2]);
        let mut opt = Lbfgs::new(2);
        let mut prev_f = f64::INFINITY;
        for _ in 0..50 {
            let (f, status) = opt.step(&mut obj, &mut theta);
            if status == LbfgsStatus::StepTaken {
                assert!(f <= prev_f + 1e-12, "loss increased: {prev_f} -> {f}");
                prev_f = f;
            }
        }
        // End loss must be well below start.
        let final_f = Rosenbrock.value_grad(&theta).0;
        assert!(final_f < 1e-3, "final {final_f}");
    }

    #[test]
    fn line_search_failure_leaves_theta_unchanged() {
        // An objective whose value is always +inf away from start forces
        // line-search failure.
        struct Wall;
        impl Objective for Wall {
            fn value_grad(&mut self, t: &Tensor) -> (f64, Tensor) {
                if t.norm() == 0.0 {
                    (1.0, Tensor::ones(&[2]))
                } else {
                    (f64::INFINITY, Tensor::ones(&[2]))
                }
            }
            fn dim(&self) -> usize {
                2
            }
        }
        let mut theta = Tensor::zeros(&[2]);
        let mut opt = Lbfgs::new(2);
        let (_, status) = opt.step(&mut Wall, &mut theta);
        assert_eq!(status, LbfgsStatus::LineSearchFailed);
        assert_eq!(theta.data(), &[0.0, 0.0]);
    }

    /// The reduction policy is a pure scheduling knob: trajectories on a
    /// high-dimensional objective (several reduction chunks) are bitwise
    /// identical across policies.
    #[test]
    fn policy_does_not_change_trajectory_bitwise() {
        let dim = 3000; // > 2 reduction chunks
        let center = Tensor::linspace(-1.0, 1.0, dim);
        let run = |policy: ParallelPolicy| {
            let mut obj = Quadratic { center: center.clone() };
            let mut theta = Tensor::zeros(&[dim]);
            let mut opt = Lbfgs::new(dim).with_policy(policy);
            let mut trace = Vec::new();
            for _ in 0..10 {
                opt.step(&mut obj, &mut theta);
                trace.push(theta.clone());
            }
            trace
        };
        let want = run(ParallelPolicy::Serial);
        for policy in [
            ParallelPolicy::Fixed(2),
            ParallelPolicy::Fixed(8),
            ParallelPolicy::Auto,
        ] {
            let got = run(policy);
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                assert_eq!(a, b, "{policy:?} step {i}");
            }
        }
    }

    /// Deep backtracking pipelines its trials: the whole `max_ls` budget
    /// is spent through a handful of `value_batch` waves, never one call
    /// per trial point.
    #[test]
    fn backtracking_batches_line_search_trials() {
        struct Cliff {
            batch_calls: u64,
            points: u64,
        }
        impl Objective for Cliff {
            fn value_grad(&mut self, t: &Tensor) -> (f64, Tensor) {
                if t.norm() == 0.0 {
                    (1.0, Tensor::ones(&[2]))
                } else {
                    (f64::INFINITY, Tensor::ones(&[2]))
                }
            }
            fn value_batch(&mut self, ts: &[Tensor]) -> Vec<f64> {
                self.batch_calls += 1;
                self.points += ts.len() as u64;
                ts.iter()
                    .map(|t| if t.norm() == 0.0 { 1.0 } else { f64::INFINITY })
                    .collect()
            }
            fn dim(&self) -> usize {
                2
            }
        }
        let mut obj = Cliff { batch_calls: 0, points: 0 };
        let mut theta = Tensor::zeros(&[2]);
        let mut opt = Lbfgs::new(2);
        let (_, status) = opt.step(&mut obj, &mut theta);
        assert_eq!(status, LbfgsStatus::LineSearchFailed);
        assert_eq!(opt.n_value_evals, 25, "the full trial budget is spent");
        assert_eq!(obj.points, 25);
        // unit + interp + ceil(23/4) halving waves = 8 pool sweeps.
        assert!(obj.batch_calls <= 8, "got {} waves", obj.batch_calls);
        assert_eq!(theta.data(), &[0.0, 0.0]);
    }

    /// Export at step k, restore into a fresh optimizer, continue: the
    /// trajectory (history, carried gradient, theta) is bitwise
    /// identical to never having stopped.
    #[test]
    fn export_restore_resumes_bitwise() {
        let dim = 6;
        let center = Tensor::linspace(-1.0, 2.0, dim);
        let mut obj = Quadratic { center: center.clone() };

        let mut full = Lbfgs::new(dim);
        let mut tf = Tensor::zeros(&[dim]);
        for _ in 0..8 {
            full.step(&mut obj, &mut tf);
        }

        let mut first = Lbfgs::new(dim);
        let mut tr = Tensor::zeros(&[dim]);
        for _ in 0..3 {
            first.step(&mut obj, &mut tr);
        }
        let (s, y, g) = first.export_state();
        let mut resumed = Lbfgs::new(dim);
        resumed.restore_state(&s, &y, g.as_deref());
        for _ in 0..5 {
            resumed.step(&mut obj, &mut tr);
        }
        assert_eq!(tr, tf);
        let (sf, yf, gf) = full.export_state();
        let (sr, yr, gr) = resumed.export_state();
        assert_eq!(sr, sf);
        assert_eq!(yr, yf);
        assert_eq!(gr, gf);
    }

    #[test]
    fn converged_status_near_optimum() {
        let center = Tensor::from_vec(vec![1.0], &[1]);
        let mut obj = Quadratic { center: center.clone() };
        let mut theta = center.clone();
        let mut opt = Lbfgs::new(1);
        let (_, status) = opt.step(&mut obj, &mut theta);
        assert_eq!(status, LbfgsStatus::Converged);
    }
}
