//! Plain SGD with optional momentum — baseline optimizer and ablation.
//!
//! Like [`super::Adam`], the update is elementwise, so a
//! [`ParallelPolicy`] splits it across contiguous blocks with bitwise
//! serial-identical results — through the shared
//! [`crate::util::par::update_blocks`] skeleton.

use super::Objective;
use crate::ntp::ParallelPolicy;
use crate::simd::Isa;
use crate::tensor::Tensor;
use crate::util::par;

/// SGD(+momentum) state over a flat parameter vector.
#[derive(Clone, Debug)]
pub struct Sgd {
    /// Learning rate.
    pub lr: f64,
    /// Momentum coefficient (0 disables).
    pub momentum: f64,
    velocity: Tensor,
    policy: ParallelPolicy,
}

impl Sgd {
    /// Fresh state for `dim` parameters (serial updates).
    pub fn new(dim: usize, lr: f64, momentum: f64) -> Sgd {
        Sgd {
            lr,
            momentum,
            velocity: Tensor::zeros(&[dim]),
            policy: ParallelPolicy::Serial,
        }
    }

    /// Split the elementwise update across threads per `policy` (bitwise
    /// identical to serial for any worker count).
    pub fn with_policy(mut self, policy: ParallelPolicy) -> Sgd {
        self.policy = policy;
        self
    }

    /// The update-parallelism policy.
    pub fn policy(&self) -> ParallelPolicy {
        self.policy
    }

    /// One update in place; returns the step's loss.
    pub fn step(&mut self, obj: &mut dyn Objective, theta: &mut Tensor) -> f64 {
        let (loss, grad) = obj.value_grad(theta);
        self.apply(theta, &grad);
        loss
    }

    /// Apply a raw gradient (used when the caller already has it).
    pub fn apply(&mut self, theta: &mut Tensor, grad: &Tensor) {
        assert_eq!(theta.numel(), grad.numel());
        let (lr, momentum) = (self.lr, self.momentum);
        let isa = Isa::active();
        par::update_blocks(
            self.policy,
            par::UPDATE_BLOCK,
            [self.velocity.data_mut(), theta.data_mut()],
            grad.data(),
            |muts, g| {
                let [v, th] = muts;
                isa.sgd_block(v, th, g, lr, momentum);
            },
        );
    }

    /// Export the momentum buffer for a resume checkpoint.
    pub fn export_state(&self) -> Vec<f64> {
        self.velocity.data().to_vec()
    }

    /// Restore state exported by [`Sgd::export_state`] — the next
    /// [`Sgd::apply`] then produces the bitwise-identical update the
    /// uninterrupted run would have.
    pub fn restore_state(&mut self, velocity: &[f64]) {
        assert_eq!(velocity.len(), self.velocity.numel(), "sgd velocity length mismatch");
        self.velocity = Tensor::from_vec(velocity.to_vec(), &[velocity.len()]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::Quadratic;
    use crate::util::prng::Prng;

    #[test]
    fn converges_on_quadratic() {
        let center = Tensor::from_vec(vec![2.0, -1.0], &[2]);
        let mut obj = Quadratic { center: center.clone() };
        let mut theta = Tensor::zeros(&[2]);
        let mut sgd = Sgd::new(2, 0.1, 0.0);
        for _ in 0..500 {
            sgd.step(&mut obj, &mut theta);
        }
        assert!(theta.sub(&center).norm() < 1e-6);
    }

    #[test]
    fn momentum_accelerates() {
        let center = Tensor::from_vec(vec![5.0], &[1]);
        let run = |momentum: f64| {
            let mut obj = Quadratic { center: center.clone() };
            let mut theta = Tensor::zeros(&[1]);
            let mut sgd = Sgd::new(1, 0.01, momentum);
            for _ in 0..100 {
                sgd.step(&mut obj, &mut theta);
            }
            (theta.sub(&center)).norm()
        };
        assert!(run(0.9) < run(0.0));
    }

    /// Parallel updates are bitwise identical to serial ones.
    #[test]
    fn parallel_apply_is_bitwise_identical_to_serial() {
        let dim = 2 * par::UPDATE_BLOCK + 13;
        let mut rng = Prng::seeded(0x56D);
        let mut serial = Sgd::new(dim, 0.05, 0.9);
        let mut parallel = Sgd::new(dim, 0.05, 0.9).with_policy(ParallelPolicy::Fixed(4));
        let mut ta = Tensor::rand_normal(&[dim], 0.0, 1.0, &mut rng);
        let mut tb = ta.clone();
        for _ in 0..3 {
            let g = Tensor::rand_normal(&[dim], 0.0, 1.0, &mut rng);
            serial.apply(&mut ta, &g);
            parallel.apply(&mut tb, &g);
            assert_eq!(ta, tb);
        }
    }

    /// Export at step k, restore into a fresh optimizer, continue: the
    /// trajectory is bitwise identical to never having stopped.
    #[test]
    fn export_restore_resumes_bitwise() {
        let dim = 11;
        let mut rng = Prng::seeded(0x56E);
        let grads: Vec<Tensor> =
            (0..6).map(|_| Tensor::rand_normal(&[dim], 0.0, 1.0, &mut rng)).collect();
        let theta0 = Tensor::rand_normal(&[dim], 0.0, 1.0, &mut rng);

        let mut full = Sgd::new(dim, 0.05, 0.9);
        let mut tf = theta0.clone();
        for g in &grads {
            full.apply(&mut tf, g);
        }

        let mut first = Sgd::new(dim, 0.05, 0.9);
        let mut tr = theta0.clone();
        for g in &grads[..2] {
            first.apply(&mut tr, g);
        }
        let v = first.export_state();
        let mut resumed = Sgd::new(dim, 0.05, 0.9);
        resumed.restore_state(&v);
        for g in &grads[2..] {
            resumed.apply(&mut tr, g);
        }
        assert_eq!(tr, tf);
    }
}
