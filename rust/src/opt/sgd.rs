//! Plain SGD with optional momentum — baseline optimizer and ablation.

use super::Objective;
use crate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct Sgd {
    pub lr: f64,
    pub momentum: f64,
    velocity: Tensor,
}

impl Sgd {
    pub fn new(dim: usize, lr: f64, momentum: f64) -> Sgd {
        Sgd {
            lr,
            momentum,
            velocity: Tensor::zeros(&[dim]),
        }
    }

    pub fn step(&mut self, obj: &mut dyn Objective, theta: &mut Tensor) -> f64 {
        let (loss, grad) = obj.value_grad(theta);
        self.apply(theta, &grad);
        loss
    }

    pub fn apply(&mut self, theta: &mut Tensor, grad: &Tensor) {
        let v = self.velocity.data_mut();
        let g = grad.data();
        let th = theta.data_mut();
        for i in 0..g.len() {
            v[i] = self.momentum * v[i] - self.lr * g[i];
            th[i] += v[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::Quadratic;

    #[test]
    fn converges_on_quadratic() {
        let center = Tensor::from_vec(vec![2.0, -1.0], &[2]);
        let mut obj = Quadratic { center: center.clone() };
        let mut theta = Tensor::zeros(&[2]);
        let mut sgd = Sgd::new(2, 0.1, 0.0);
        for _ in 0..500 {
            sgd.step(&mut obj, &mut theta);
        }
        assert!(theta.sub(&center).norm() < 1e-6);
    }

    #[test]
    fn momentum_accelerates() {
        let center = Tensor::from_vec(vec![5.0], &[1]);
        let run = |momentum: f64| {
            let mut obj = Quadratic { center: center.clone() };
            let mut theta = Tensor::zeros(&[1]);
            let mut sgd = Sgd::new(1, 0.01, momentum);
            for _ in 0..100 {
                sgd.step(&mut obj, &mut theta);
            }
            (theta.sub(&center)).norm()
        };
        assert!(run(0.9) < run(0.0));
    }
}
