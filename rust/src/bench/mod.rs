//! Benchmark harness: regenerates every figure of the paper's evaluation.
//!
//! | Paper figure | Runner | Output |
//! |---|---|---|
//! | Fig 1 (total pass times)   | [`passes::run`]   | `results/fig1_total.csv` |
//! | Fig 2 (forward times)      | [`passes::run`]   | `results/fig2_forward.csv` |
//! | Fig 3 (backward times)     | [`passes::run`]   | `results/fig3_backward.csv` |
//! | Fig 4 (forward ratio grid) | [`grid::run`]     | `results/fig4_forward_ratio.csv` |
//! | Fig 5 (total ratio grid)   | [`grid::run`]     | `results/fig5_total_ratio.csv` |
//! | Fig 6 (profile-1 training) | [`training::run`] | `results/fig6_training.csv` |
//! | Figs 7-10 (profiles 1-4)   | [`profiles::run`] | `results/fig{7..10}_*.csv` |
//! | §IV-B memory note          | [`memory::run`]   | `results/mem_scaling.csv` |
//! | serial vs parallel forward | [`parallel::run`] | `results/parallel_speedup.csv` |
//! | serial vs parallel training | [`train_par::run`] | `results/training_speedup.csv` |
//! | fused vs reference kernel  | `kernels::run` (needs `--features reference-oracle`) | `results/kernel_speedup.csv` + `BENCH_kernels.json` |
//! | directional vs nested-tape operators | [`operators::run`] | `results/operator_speedup.csv` + `BENCH_operators.json` |
//! | TCP serving load (pipelining + plan cache) | [`serve::run`] | `results/serve_load.csv` + `BENCH_serve.json` |
//! | tracing overhead (spans + phase sampling) | [`obs::run`] | `results/obs_overhead.csv` + `BENCH_obs.json` |
//!
//! Absolute times differ from the paper (single CPU host vs A6000 GPU);
//! the *shapes* — exponential vs quasilinear in `n`, crossover at small
//! `n`, ratios growing with `n`, L-BFGS amplifying the gap — are the
//! reproduction targets (see EXPERIMENTS.md).

pub mod grid;
#[cfg(feature = "reference-oracle")]
pub mod kernels;
pub mod memory;
pub mod obs;
pub mod operators;
pub mod parallel;
pub mod passes;
pub mod profiles;
pub mod serve;
pub mod train_par;
pub mod training;

use crate::autodiff::{higher, Graph};
use crate::nn::Mlp;
use crate::ntp::{ActivationKind, NtpEngine};
use crate::tensor::Tensor;
use crate::util::prng::Prng;
use std::time::Instant;

/// Forward / backward wall-clock seconds for one configuration.
#[derive(Clone, Copy, Debug, Default)]
pub struct PassTimes {
    /// Forward seconds.
    pub fwd: f64,
    /// Backward seconds.
    pub bwd: f64,
}

impl PassTimes {
    /// Forward + backward seconds.
    pub fn total(&self) -> f64 {
        self.fwd + self.bwd
    }
}

/// Which engine a measurement used.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// n-TangentProp (the paper's method).
    Ntp,
    /// Repeated reverse-mode autodiff (the baseline).
    Autodiff,
}

impl Engine {
    /// Name used in CSV output.
    pub fn name(&self) -> &'static str {
        match self {
            Engine::Ntp => "ntangentprop",
            Engine::Autodiff => "autodiff",
        }
    }
}

/// One timed measurement cell.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Engine measured.
    pub engine: Engine,
    /// Derivative order.
    pub n: usize,
    /// Hidden width.
    pub width: usize,
    /// Hidden depth.
    pub depth: usize,
    /// Batch size.
    pub batch: usize,
    /// Hidden activation of the measured network.
    pub activation: ActivationKind,
    /// The measured (or projected) pass times.
    pub times: PassTimes,
    /// False when the value was *projected* from an exponential fit
    /// because the measured point exceeded the time cap (the paper does
    /// the same for profiles 3/4).
    pub measured: bool,
}

/// Time one full training-style iteration with the chosen engine:
/// `fwd` = building + evaluating the derivative channels (what the PINN
/// loss consumes), `bwd` = building + evaluating `dL/dθ` for a
/// derivative-MSE loss. Mirrors the paper's §IV-B methodology (graph is
/// rebuilt per iteration, as eager PyTorch does).
pub fn time_pass(engine: Engine, mlp: &Mlp, x: &Tensor, n: usize) -> PassTimes {
    let t0 = Instant::now();
    let mut g = Graph::new();
    let (channels, param_nodes, inputs) = match engine {
        Engine::Ntp => {
            let xn = g.constant(x.clone());
            let pn = mlp.input_param_nodes(&mut g);
            let eng = NtpEngine::new(n);
            let ch = eng.forward_graph(&mut g, mlp, xn, &pn, n);
            (ch, pn, mlp.param_tensors())
        }
        Engine::Autodiff => {
            // The input must be an Input node to differentiate against.
            let xi = g.input(x.shape());
            let pn = mlp.input_param_nodes(&mut g);
            let u = mlp.forward_graph(&mut g, xi, &pn);
            let stack = higher::derivative_stack(&mut g, u, xi, n);
            let mut v = vec![x.clone()];
            v.extend(mlp.param_tensors());
            (stack, pn, v)
        }
    };
    let vals = g.eval(&inputs, &channels);
    std::hint::black_box(vals.get(channels[n]).data());
    let fwd = t0.elapsed().as_secs_f64();

    // Loss over the channels (computed outside the timed regions in the
    // paper; the building of its backward graph is the backward cost).
    let t1 = Instant::now();
    let mut loss: Option<crate::autodiff::NodeId> = None;
    for &c in &channels {
        let ms = g.mean_square(c);
        loss = Some(match loss {
            None => ms,
            Some(acc) => g.add(acc, ms),
        });
    }
    let loss = loss.unwrap();
    let grads = g.backward(loss, &param_nodes);
    let vals = g.eval(&inputs, &grads);
    std::hint::black_box(vals.get(grads[0]).data());
    let bwd = t1.elapsed().as_secs_f64();
    PassTimes { fwd, bwd }
}

/// Average [`time_pass`] over `trials` runs after `warmup` runs.
pub fn time_pass_avg(
    engine: Engine,
    mlp: &Mlp,
    x: &Tensor,
    n: usize,
    warmup: usize,
    trials: usize,
) -> PassTimes {
    for _ in 0..warmup {
        time_pass(engine, mlp, x, n);
    }
    let mut acc = PassTimes::default();
    for _ in 0..trials {
        let t = time_pass(engine, mlp, x, n);
        acc.fwd += t.fwd;
        acc.bwd += t.bwd;
    }
    PassTimes {
        fwd: acc.fwd / trials as f64,
        bwd: acc.bwd / trials as f64,
    }
}

/// Standard network + batch used by Figs 1-3 (3 hidden layers of 24,
/// batch 256 — "a common PINN architecture").
pub fn standard_mlp(seed: u64) -> (Mlp, Tensor) {
    let mut rng = Prng::seeded(seed);
    let mlp = Mlp::uniform(1, 24, 3, 1, &mut rng);
    let x = Tensor::rand_uniform(&[256, 1], -1.0, 1.0, &mut rng);
    (mlp, x)
}

/// Sweep `n = 1..=n_max` for one engine, capping runtime: once a measured
/// total exceeds `cap_seconds`, the remaining orders are projected with an
/// exponential fit of the measured prefix (flagged `measured = false`).
#[allow(clippy::too_many_arguments)]
pub fn sweep_orders(
    engine: Engine,
    mlp: &Mlp,
    x: &Tensor,
    n_max: usize,
    warmup: usize,
    trials: usize,
    cap_seconds: f64,
) -> Vec<Measurement> {
    let mut out: Vec<Measurement> = Vec::new();
    let width = mlp.layers[0].fan_out();
    let depth = mlp.layers.len() - 1;
    let batch = x.shape()[0];
    let activation = mlp.activation;
    let mut capped = false;
    for n in 1..=n_max {
        if !capped {
            let times = time_pass_avg(engine, mlp, x, n, warmup, trials);
            // Keep measuring until we have the two points the
            // exponential projection needs.
            if times.total() > cap_seconds && out.len() >= 2 {
                capped = true;
            }
            out.push(Measurement {
                engine,
                n,
                width,
                depth,
                batch,
                activation,
                times,
                measured: true,
            });
        } else {
            // Project from the measured prefix.
            let ns: Vec<f64> = out.iter().map(|m| m.n as f64).collect();
            let fw: Vec<f64> = out.iter().map(|m| m.times.fwd.max(1e-9)).collect();
            let bw: Vec<f64> = out.iter().map(|m| m.times.bwd.max(1e-9)).collect();
            let (cf, rf, _) = crate::util::stats::exponential_fit(&ns, &fw);
            let (cb, rb, _) = crate::util::stats::exponential_fit(&ns, &bw);
            out.push(Measurement {
                engine,
                n,
                width,
                depth,
                batch,
                activation,
                times: PassTimes {
                    fwd: cf * rf.powf(n as f64),
                    bwd: cb * rb.powf(n as f64),
                },
                measured: false,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_pass_returns_positive_times() {
        let (mlp, _) = standard_mlp(1);
        let x = Tensor::rand_uniform(&[8, 1], -1.0, 1.0, &mut Prng::seeded(2));
        for engine in [Engine::Ntp, Engine::Autodiff] {
            let t = time_pass(engine, &mlp, &x, 2);
            assert!(t.fwd > 0.0 && t.bwd > 0.0, "{engine:?}");
        }
    }

    #[test]
    fn sweep_caps_and_projects() {
        let mut rng = Prng::seeded(3);
        let mlp = Mlp::uniform(1, 8, 2, 1, &mut rng);
        let x = Tensor::rand_uniform(&[16, 1], -1.0, 1.0, &mut rng);
        // Absurdly low cap forces projection as soon as the exponential
        // fit has its two measured points (plus the one that tripped it).
        let ms = sweep_orders(Engine::Autodiff, &mlp, &x, 5, 0, 1, 0.0);
        assert_eq!(ms.len(), 5);
        assert!(ms.iter().take(3).all(|m| m.measured));
        assert!(ms.iter().skip(3).all(|m| !m.measured));
        // Projection is positive and grows.
        assert!(ms[4].times.total() >= ms[3].times.total());
    }

    #[test]
    fn engines_time_the_same_computation() {
        // Sanity: both engines produce channels; ntp should not be slower
        // than autodiff by orders of magnitude at n=4 (it should be
        // faster, but keep the assertion robust on noisy CI).
        let (mlp, _) = standard_mlp(4);
        let x = Tensor::rand_uniform(&[32, 1], -1.0, 1.0, &mut Prng::seeded(5));
        let ntp = time_pass_avg(Engine::Ntp, &mlp, &x, 4, 1, 3);
        let ad = time_pass_avg(Engine::Autodiff, &mlp, &x, 4, 1, 3);
        assert!(ntp.total() < ad.total() * 3.0);
    }
}
