//! Figs 4-5: the ratio of autodiff to n-TangentProp pass times over a
//! grid of activations × widths × depths × batch sizes × derivative
//! orders — the activation axis sweeps the same way width/depth/order do,
//! so tower-cost differences show up per cell.

use super::{sweep_orders, Engine, Measurement};
use crate::nn::Mlp;
use crate::ntp::ActivationKind;
use crate::tensor::Tensor;
use crate::util::csv::Table;
use crate::util::prng::Prng;
use std::path::Path;

/// Architecture/batch grid for the fig 4/5 ratio sweeps.
#[derive(Clone, Debug)]
pub struct GridConfig {
    /// Hidden widths to sweep.
    pub widths: Vec<usize>,
    /// Hidden depths to sweep.
    pub depths: Vec<usize>,
    /// Batch sizes to sweep.
    pub batches: Vec<usize>,
    /// Hidden activations to sweep (default: tanh only, the paper grid).
    pub activations: Vec<ActivationKind>,
    /// Max derivative order.
    pub n_max: usize,
    /// Untimed warmup trials per cell.
    pub warmup: usize,
    /// Timed trials per cell.
    pub trials: usize,
    /// Once an engine's measured total exceeds this, project the rest.
    pub cap_seconds: f64,
    /// PRNG seed.
    pub seed: u64,
}

impl Default for GridConfig {
    fn default() -> Self {
        GridConfig {
            // Paper grid: widths {16,24,64,128} × depths {2,3,4,8} ×
            // batches {2^6..2^12}; CPU defaults cover the interesting
            // region, expandable from the CLI.
            widths: vec![16, 24, 64],
            depths: vec![2, 3, 4],
            batches: vec![64, 256],
            activations: vec![ActivationKind::Tanh],
            n_max: 6,
            warmup: 0,
            trials: 3,
            cap_seconds: 1.5,
            seed: 11,
        }
    }
}

/// All measurements over the grid (both engines).
pub fn run(cfg: &GridConfig, progress: impl Fn(&str)) -> Vec<Measurement> {
    let mut out = Vec::new();
    for &activation in &cfg.activations {
        for &width in &cfg.widths {
            for &depth in &cfg.depths {
                for &batch in &cfg.batches {
                    progress(&format!(
                        "grid cell act={} width={width} depth={depth} batch={batch}",
                        activation.name()
                    ));
                    let mut rng =
                        Prng::seeded(cfg.seed ^ (width * 31 + depth * 7 + batch) as u64);
                    let mlp = Mlp::uniform_with(1, width, depth, 1, activation, &mut rng);
                    let x = Tensor::rand_uniform(&[batch, 1], -1.0, 1.0, &mut rng);
                    for engine in [Engine::Ntp, Engine::Autodiff] {
                        out.extend(sweep_orders(
                            engine,
                            &mlp,
                            &x,
                            cfg.n_max,
                            cfg.warmup,
                            cfg.trials,
                            cfg.cap_seconds,
                        ));
                    }
                }
            }
        }
    }
    out
}

/// Ratio rows: one per (activation, width, depth, batch, n) cell.
/// `which` selects forward (Fig 4) or total (Fig 5).
pub fn ratio_table(measurements: &[Measurement], forward_only: bool) -> Table {
    let mut t = Table::new(&[
        "width", "depth", "batch", "n", "activation", "autodiff_s", "ntp_s", "ratio", "measured",
    ]);
    for m in measurements.iter().filter(|m| m.engine == Engine::Autodiff) {
        if let Some(ntp) = measurements.iter().find(|o| {
            o.engine == Engine::Ntp
                && o.n == m.n
                && o.width == m.width
                && o.depth == m.depth
                && o.batch == m.batch
                && o.activation == m.activation
        }) {
            let (a, b) = if forward_only {
                (m.times.fwd, ntp.times.fwd)
            } else {
                (m.times.total(), ntp.times.total())
            };
            t.push(vec![
                m.width.to_string(),
                m.depth.to_string(),
                m.batch.to_string(),
                m.n.to_string(),
                m.activation.name().to_string(),
                format!("{a:.6e}"),
                format!("{b:.6e}"),
                format!("{:.4}", a / b),
                (m.measured && ntp.measured).to_string(),
            ]);
        }
    }
    t
}

/// Write `fig4_forward_ratio.csv` and `fig5_total_ratio.csv`.
pub fn save(measurements: &[Measurement], dir: &Path) -> std::io::Result<()> {
    ratio_table(measurements, true).save(&dir.join("fig4_forward_ratio.csv"))?;
    ratio_table(measurements, false).save(&dir.join("fig5_total_ratio.csv"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> GridConfig {
        GridConfig {
            widths: vec![8],
            depths: vec![2],
            batches: vec![16],
            activations: vec![ActivationKind::Tanh],
            n_max: 3,
            warmup: 0,
            trials: 1,
            cap_seconds: 5.0,
            seed: 1,
        }
    }

    #[test]
    fn grid_produces_full_cartesian_product() {
        let ms = run(&tiny_cfg(), |_| {});
        // 1 cell × 2 engines × 3 orders
        assert_eq!(ms.len(), 6);
        let t = ratio_table(&ms, true);
        assert_eq!(t.rows.len(), 3);
        let ratios = t.col_f64("ratio").unwrap();
        assert!(ratios.iter().all(|r| *r > 0.0));
    }

    #[test]
    fn activation_axis_multiplies_cells() {
        let mut cfg = tiny_cfg();
        cfg.activations = vec![ActivationKind::Tanh, ActivationKind::Sine];
        let ms = run(&cfg, |_| {});
        // 2 activations × 1 cell × 2 engines × 3 orders
        assert_eq!(ms.len(), 12);
        let t = ratio_table(&ms, true);
        assert_eq!(t.rows.len(), 6);
        // Every row pairs measurements of the same activation.
        let acts: Vec<&String> = t.rows.iter().map(|r| &r[4]).collect();
        assert!(acts.iter().filter(|a| a.as_str() == "tanh").count() == 3);
        assert!(acts.iter().filter(|a| a.as_str() == "sin").count() == 3);
    }

    #[test]
    fn ratio_grows_with_n() {
        // The paper's central shape: the autodiff/ntp ratio increases with
        // the number of derivatives. Use enough trials to de-noise.
        let mut cfg = tiny_cfg();
        cfg.n_max = 5;
        cfg.trials = 3;
        cfg.widths = vec![16];
        cfg.batches = vec![32];
        let ms = run(&cfg, |_| {});
        let t = ratio_table(&ms, false);
        let ratios = t.col_f64("ratio").unwrap();
        let ns = t.col_f64("n").unwrap();
        let hi = ratios[ns.iter().position(|&n| n == 5.0).unwrap()];
        let lo = ratios[ns.iter().position(|&n| n == 1.0).unwrap()];
        assert!(hi > lo, "ratio at n=5 ({hi}) should exceed n=1 ({lo})");
    }
}
