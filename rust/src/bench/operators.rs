//! Operator bench: multivariate PDE operators (2-D Laplacian,
//! biharmonic) through the **directional n-TangentProp** path — one
//! direction-stacked fused batch plus exact recombination — against the
//! nested-tape autodiff baseline (`ntangent bench operators`,
//! `results/operator_speedup.csv`; `--json BENCH_operators.json` writes
//! the machine-readable document CI's `bench-smoke` job exercises).
//!
//! The baseline rebuilds its graph per trial (the eager-framework
//! methodology every other bench in this crate uses: repeated
//! `backward` re-differentiates an already-grown graph, which is
//! exactly the exponential cost the paper measures). Before timing,
//! both paths are differentially checked against each other on a
//! subsample — a speedup measured on wrong numbers is worthless.

use crate::autodiff::{higher, Graph};
use crate::nn::Mlp;
use crate::ntp::stde::exact_direction_count;
use crate::ntp::{ActivationKind, MultiJetEngine, StdeConfig, StdeEngine};
use crate::pde::{DiffOperator, PdeProblem};
use crate::pinn::{train_pde_with_estimator, EstimatorMode, MultiPinnSpec, TrainConfig};
use crate::tensor::Tensor;
use crate::util::csv::Table;
use crate::util::json::Json;
use crate::util::prng::Prng;
use crate::util::stats::Summary;
use crate::util::timer::time_trials;
use std::collections::HashMap;
use std::path::Path;

/// Configuration of the operator bench.
#[derive(Clone, Debug)]
pub struct OperatorBenchConfig {
    /// Hidden width.
    pub width: usize,
    /// Hidden depth.
    pub depth: usize,
    /// Hidden activation.
    pub activation: ActivationKind,
    /// Collocation points per timed evaluation.
    pub batch: usize,
    /// Rows of the pre-timing differential check.
    pub check_rows: usize,
    /// Untimed warmup trials per leg.
    pub warmup: usize,
    /// Timed trials per leg.
    pub trials: usize,
    /// PRNG seed.
    pub seed: u64,
    /// High-dim leg: interior collocation points.
    pub hd_points: usize,
    /// High-dim leg: boundary collocation points.
    pub hd_bc_points: usize,
    /// High-dim leg: Adam epochs of the fixed training budget.
    pub hd_adam: usize,
    /// High-dim leg: L-BFGS epochs of the fixed training budget.
    pub hd_lbfgs: usize,
    /// High-dim leg: STDE term samples per step (K).
    pub hd_samples: usize,
    /// High-dim leg: counter steps per variance probe.
    pub hd_var_steps: usize,
}

impl Default for OperatorBenchConfig {
    fn default() -> Self {
        // The acceptance shape: B = 4096 over the paper's 3x24 net,
        // Laplacian (n = 2) and biharmonic (n = 4).
        OperatorBenchConfig {
            width: 24,
            depth: 3,
            activation: ActivationKind::Tanh,
            batch: 4096,
            check_rows: 64,
            warmup: 1,
            trials: 5,
            seed: 29,
            hd_points: 512,
            hd_bc_points: 128,
            hd_adam: 400,
            hd_lbfgs: 150,
            hd_samples: 4,
            hd_var_steps: 64,
        }
    }
}

impl OperatorBenchConfig {
    /// The CI smoke shape: same operators and checks, minutes-budget
    /// sizes.
    pub fn smoke() -> OperatorBenchConfig {
        OperatorBenchConfig {
            batch: 512,
            check_rows: 32,
            trials: 3,
            hd_points: 96,
            hd_bc_points: 32,
            hd_adam: 60,
            hd_lbfgs: 25,
            hd_var_steps: 16,
            ..OperatorBenchConfig::default()
        }
    }
}

/// One measured operator.
#[derive(Clone, Debug)]
pub struct OperatorCell {
    /// Operator name.
    pub name: &'static str,
    /// Collocation points per evaluation.
    pub batch: usize,
    /// Operator order (highest |α|).
    pub n: usize,
    /// Directional passes per evaluation (the `D` of `D·O(n log n)`).
    pub directions: usize,
    /// Hidden width.
    pub width: usize,
    /// Hidden depth.
    pub depth: usize,
    /// Mean seconds per directional-jet evaluation.
    pub ntp_s: f64,
    /// Mean seconds per nested-tape evaluation (graph rebuilt per
    /// trial, eager-style).
    pub autodiff_s: f64,
}

impl OperatorCell {
    /// Directional-path speedup over the nested-tape baseline.
    pub fn speedup(&self) -> f64 {
        self.autodiff_s / self.ntp_s
    }
}

/// One high-dimensional training leg: a fixed Adam → L-BFGS budget on a
/// library problem, exact plan vs STDE.
#[derive(Clone, Debug)]
pub struct HighDimCell {
    /// Problem name.
    pub problem: &'static str,
    /// Input dimension.
    pub dim: usize,
    /// "exact" or "stde".
    pub estimator: &'static str,
    /// STDE term samples per step (0 for the exact leg).
    pub samples: usize,
    /// Mean directional passes launched per interior evaluation.
    pub directions_per_step: f64,
    /// Direction count of the exact `|α| ≤ n` plan (the denominator of
    /// the pass-ratio metric).
    pub exact_directions: f64,
    /// Relative L2 error of `u` after the budget (Monte-Carlo interior
    /// cloud, error RMS over truth RMS).
    pub rel_l2: f64,
    /// Training wall-clock seconds.
    pub seconds: f64,
}

impl HighDimCell {
    /// How many times fewer directional passes per step than the exact
    /// plan (1.0 for the exact leg itself).
    pub fn pass_ratio(&self) -> f64 {
        self.exact_directions / self.directions_per_step
    }
}

/// One point of the variance-vs-K probe: MSE of the STDE estimate
/// against the exact d=10 oracle, averaged over counter steps and rows.
#[derive(Clone, Debug)]
pub struct VarianceCell {
    /// Term samples per step (K).
    pub samples: usize,
    /// Antithetic pairing on?
    pub antithetic: bool,
    /// Mean squared estimation error.
    pub mse: f64,
}

impl VarianceCell {
    /// `MSE·K` — flat across K when the variance decays like 1/K.
    pub fn mse_times_k(&self) -> f64 {
        self.mse * self.samples as f64
    }
}

/// The high-dim section of the bench document.
pub struct HighDimReport {
    /// Training legs (exact vs STDE on the same problem and budget).
    pub training: Vec<HighDimCell>,
    /// Variance-vs-K probe cells.
    pub variance: Vec<VarianceCell>,
}

/// The benched operators: the acceptance pair.
fn bench_operators() -> Vec<(&'static str, DiffOperator)> {
    vec![
        ("laplacian2d", DiffOperator::laplacian(2)),
        ("biharmonic2d", DiffOperator::biharmonic(2)),
    ]
}

/// Evaluate `op[u]` over `x` with the nested-tape baseline: build the
/// graph (repeated backward per multi-index), evaluate, return the
/// operator values.
fn autodiff_operator_eval(mlp: &Mlp, x: &Tensor, op: &DiffOperator) -> Tensor {
    let mut g = Graph::new();
    let pn = mlp.const_param_nodes(&mut g);
    let xn = g.input(x.shape());
    let u = mlp.forward_graph(&mut g, xn, &pn);
    let mut partials = HashMap::new();
    for alpha in op.needed_partials() {
        let node = if alpha.iter().all(|&a| a == 0) {
            u
        } else {
            higher::mixed_partial(&mut g, u, xn, &alpha)
        };
        partials.insert(alpha, node);
    }
    let lhs = op.apply_nodes(&mut g, &partials);
    let vals = g.eval(&[x.clone()], &[lhs]);
    vals.get(lhs).clone()
}

fn mean_s(ts: &[f64]) -> f64 {
    Summary::of(ts).mean
}

/// Run the operator sweep (differentially checking the two paths on a
/// subsample before each timed cell).
pub fn run(cfg: &OperatorBenchConfig, progress: impl Fn(&str)) -> Vec<OperatorCell> {
    let mut rng = Prng::seeded(cfg.seed);
    let mlp = Mlp::uniform_with(2, cfg.width, cfg.depth, 1, cfg.activation, &mut rng);
    let x = Tensor::rand_uniform(&[cfg.batch, 2], -1.0, 1.0, &mut rng);
    let mut out = Vec::new();
    for (name, op) in bench_operators() {
        let n = op.max_order();
        let engine = MultiJetEngine::new(2, n);
        progress(&format!(
            "operator {name}: n={n}, {} directions, B={}",
            engine.plan().n_directions(),
            cfg.batch
        ));

        // Differential check on a subsample: the two exact paths must
        // agree far below any interesting perf difference.
        let rows = cfg.check_rows.min(cfg.batch).max(1);
        let xs = Tensor::from_vec(x.data()[..rows * 2].to_vec(), &[rows, 2]);
        let jet = engine.jet(&mlp, &xs);
        let got = op.apply(&jet);
        let want = autodiff_operator_eval(&mlp, &xs, &op);
        for (i, (&a, &b)) in got.data().iter().zip(want.data()).enumerate() {
            assert!(
                (a - b).abs() <= 1e-8 * (1.0 + b.abs()),
                "{name}: directional {a} vs nested-tape {b} at row {i}"
            );
        }

        let ntp_s = mean_s(&time_trials(cfg.warmup, cfg.trials, || {
            let jet = engine.jet(&mlp, &x);
            std::hint::black_box(op.apply(&jet));
        }));
        let autodiff_s = mean_s(&time_trials(cfg.warmup, cfg.trials, || {
            std::hint::black_box(autodiff_operator_eval(&mlp, &x, &op));
        }));
        out.push(OperatorCell {
            name,
            batch: cfg.batch,
            n,
            directions: engine.plan().n_directions(),
            width: cfg.width,
            depth: cfg.depth,
            ntp_s,
            autodiff_s,
        });
    }
    out
}

/// Relative L2 error of `mlp` against the manufactured solution over a
/// fresh Monte-Carlo interior cloud (error RMS over truth RMS).
fn rel_l2(problem: PdeProblem, mlp: &Mlp, n_pts: usize, seed: u64) -> f64 {
    let mut rng = Prng::seeded(seed);
    let x = problem.sample_interior(n_pts, &mut rng);
    let u = mlp.forward(&x);
    let truth = problem.u_exact_rows(&x);
    let mut num = 0.0;
    let mut den = 0.0;
    for (&a, &b) in u.data().iter().zip(truth.data()) {
        num += (a - b) * (a - b);
        den += b * b;
    }
    (num / den).sqrt()
}

/// The high-dim leg: exact-plan vs STDE training on `poisson10d` under
/// one fixed budget (the pass-ratio acceptance metric), plus a
/// variance-vs-K probe against the exact d=10 oracle. `poisson10d` is
/// the one library problem where both sides exist: its exact plan is
/// still tractable (55 directions), so exactness and cost can be
/// compared head-on; `heat100d` has no exact side to compare against.
pub fn run_highdim(cfg: &OperatorBenchConfig, progress: impl Fn(&str)) -> HighDimReport {
    let problem = PdeProblem::Poisson10d;
    let op = problem.operator();
    let dim = problem.dim();
    let exact_dirs = exact_direction_count(dim, op.max_order()) as f64;

    // --- Variance-vs-K probe: STDE estimates on a frozen random net
    // against the exact 55-direction oracle. -------------------------
    let mut rng = Prng::seeded(cfg.seed);
    let mlp = Mlp::uniform_with(dim, cfg.width, cfg.depth, 1, cfg.activation, &mut rng);
    let x = problem.sample_interior(cfg.check_rows.max(1), &mut rng);
    let oracle = MultiJetEngine::new(dim, op.max_order());
    let exact = op.apply(&oracle.jet(&mlp, &x));
    let mut variance = Vec::new();
    for &(k, anti) in &[(1, false), (2, false), (4, false), (8, false), (4, true)] {
        let est = StdeEngine::new(
            op.clone(),
            StdeConfig { seed: cfg.seed, samples: k, antithetic: anti },
        );
        let mut acc = 0.0;
        let mut count = 0usize;
        for step in 0..cfg.hd_var_steps.max(1) {
            let e = est.estimate(&mlp, &x, step as u64);
            for (&a, &b) in e.values.data().iter().zip(exact.data()) {
                acc += (a - b) * (a - b);
                count += 1;
            }
        }
        let cell = VarianceCell { samples: k, antithetic: anti, mse: acc / count as f64 };
        progress(&format!(
            "stde variance: K={k}{} mse={:.3e} mse*K={:.3e}",
            if anti { " antithetic" } else { "" },
            cell.mse,
            cell.mse_times_k()
        ));
        variance.push(cell);
    }

    // Mean directional passes an STDE step actually launches (pure
    // function of the counter stream — measured on the sampler itself).
    let est = StdeEngine::new(
        op.clone(),
        StdeConfig { seed: cfg.seed, samples: cfg.hd_samples, antithetic: false },
    );
    let probe_x = problem.sample_interior(1, &mut rng);
    let mean_dirs = (0..cfg.hd_var_steps.max(1))
        .map(|s| est.estimate(&mlp, &probe_x, s as u64).n_directions as f64)
        .sum::<f64>()
        / cfg.hd_var_steps.max(1) as f64;

    // --- Fixed-budget training: exact plan vs STDE. ------------------
    let train_cfg = TrainConfig {
        width: cfg.width,
        depth: cfg.depth,
        activation: cfg.activation,
        adam_epochs: cfg.hd_adam,
        lbfgs_epochs: cfg.hd_lbfgs,
        seed: cfg.seed,
        log_every: usize::MAX,
        ..TrainConfig::default()
    };
    let mut training = Vec::new();
    let legs = [
        (EstimatorMode::Exact, "exact", 0usize, exact_dirs),
        (
            EstimatorMode::Stde { seed: cfg.seed, samples: cfg.hd_samples, antithetic: false },
            "stde",
            cfg.hd_samples,
            mean_dirs,
        ),
    ];
    for (mode, label, samples, dirs) in legs {
        let mut spec = MultiPinnSpec::for_problem(problem);
        spec.n_interior = cfg.hd_points;
        spec.n_boundary = cfg.hd_bc_points;
        progress(&format!(
            "training {} [{label}]: {} + {} points, {} + {} epochs, {dirs:.1} dirs/step",
            problem.name(),
            cfg.hd_points,
            cfg.hd_bc_points,
            cfg.hd_adam,
            cfg.hd_lbfgs
        ));
        let result =
            train_pde_with_estimator(spec, &train_cfg, crate::pinn::DerivEngine::Ntp, mode);
        let cell = HighDimCell {
            problem: problem.name(),
            dim,
            estimator: label,
            samples,
            directions_per_step: dirs,
            exact_directions: exact_dirs,
            rel_l2: rel_l2(problem, &result.mlp, 512, cfg.seed + 1),
            seconds: result.seconds,
        };
        progress(&format!(
            "  -> rel L2 {:.3e} in {:.1}s ({:.1}x fewer passes/step than exact)",
            cell.rel_l2,
            cell.seconds,
            cell.pass_ratio()
        ));
        training.push(cell);
    }
    HighDimReport { training, variance }
}

/// One row per operator, with the speedup column the acceptance bar
/// reads.
pub fn table(cells: &[OperatorCell]) -> Table {
    let mut t = Table::new(&[
        "operator",
        "batch",
        "n",
        "directions",
        "width",
        "depth",
        "ntp_s",
        "autodiff_s",
        "speedup",
    ]);
    for c in cells {
        t.push(vec![
            c.name.to_string(),
            c.batch.to_string(),
            c.n.to_string(),
            c.directions.to_string(),
            c.width.to_string(),
            c.depth.to_string(),
            format!("{:.6e}", c.ntp_s),
            format!("{:.6e}", c.autodiff_s),
            format!("{:.4}", c.speedup()),
        ]);
    }
    t
}

/// Write `operator_speedup.csv`.
pub fn save(cells: &[OperatorCell], dir: &Path) -> std::io::Result<()> {
    table(cells).save(&dir.join("operator_speedup.csv"))
}

/// The high-dim training legs as a table (one row per leg).
pub fn highdim_table(report: &HighDimReport) -> Table {
    let mut t = Table::new(&[
        "problem",
        "dim",
        "estimator",
        "samples",
        "dirs_per_step",
        "exact_dirs",
        "pass_ratio",
        "rel_l2",
        "seconds",
    ]);
    for c in &report.training {
        t.push(vec![
            c.problem.to_string(),
            c.dim.to_string(),
            c.estimator.to_string(),
            c.samples.to_string(),
            format!("{:.2}", c.directions_per_step),
            format!("{:.0}", c.exact_directions),
            format!("{:.2}", c.pass_ratio()),
            format!("{:.6e}", c.rel_l2),
            format!("{:.3}", c.seconds),
        ]);
    }
    t
}

/// Write `stde_highdim.csv`.
pub fn save_highdim(report: &HighDimReport, dir: &Path) -> std::io::Result<()> {
    highdim_table(report).save(&dir.join("stde_highdim.csv"))
}

/// The `BENCH_operators.json` document: config + per-operator results +
/// the high-dim STDE section.
pub fn to_json(cfg: &OperatorBenchConfig, cells: &[OperatorCell], hd: &HighDimReport) -> Json {
    let results: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("operator", Json::Str(c.name.into())),
                ("n", Json::Num(c.n as f64)),
                ("directions", Json::Num(c.directions as f64)),
                ("ntp_s", Json::Num(c.ntp_s)),
                ("autodiff_s", Json::Num(c.autodiff_s)),
                ("speedup", Json::Num(c.speedup())),
            ])
        })
        .collect();
    let training: Vec<Json> = hd
        .training
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("problem", Json::Str(c.problem.into())),
                ("dim", Json::Num(c.dim as f64)),
                ("estimator", Json::Str(c.estimator.into())),
                ("samples", Json::Num(c.samples as f64)),
                ("dirs_per_step", Json::Num(c.directions_per_step)),
                ("exact_dirs", Json::Num(c.exact_directions)),
                ("pass_ratio", Json::Num(c.pass_ratio())),
                ("rel_l2", Json::Num(c.rel_l2)),
                ("seconds", Json::Num(c.seconds)),
            ])
        })
        .collect();
    let variance: Vec<Json> = hd
        .variance
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("samples", Json::Num(c.samples as f64)),
                ("antithetic", Json::Bool(c.antithetic)),
                ("mse", Json::Num(c.mse)),
                ("mse_times_k", Json::Num(c.mse_times_k())),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::Str("operators".into())),
        (
            "config",
            Json::obj(vec![
                ("batch", Json::Num(cfg.batch as f64)),
                ("width", Json::Num(cfg.width as f64)),
                ("depth", Json::Num(cfg.depth as f64)),
                ("activation", Json::Str(cfg.activation.name().into())),
                ("trials", Json::Num(cfg.trials as f64)),
                ("hd_points", Json::Num(cfg.hd_points as f64)),
                ("hd_epochs", Json::Num((cfg.hd_adam + cfg.hd_lbfgs) as f64)),
                ("hd_samples", Json::Num(cfg.hd_samples as f64)),
            ]),
        ),
        ("results", Json::Arr(results)),
        (
            "highdim",
            Json::obj(vec![
                ("training", Json::Arr(training)),
                ("variance", Json::Arr(variance)),
            ]),
        ),
    ])
}

/// Write the `BENCH_operators.json` document to `path`.
pub fn save_json(
    cfg: &OperatorBenchConfig,
    cells: &[OperatorCell],
    hd: &HighDimReport,
    path: &Path,
) -> std::io::Result<()> {
    std::fs::write(path, to_json(cfg, cells, hd).dump() + "\n")
}

/// Human-readable summary for the CLI.
pub fn summarize(cells: &[OperatorCell]) -> String {
    let mut out =
        String::from("directional n-TangentProp vs nested-tape autodiff (mean seconds)\n");
    for c in cells {
        out.push_str(&format!(
            "  {:<14} B={:<6} n={} D={:<2} directional {:>10.1} µs  \
             nested-tape {:>12.1} µs ({:.1}x)\n",
            c.name,
            c.batch,
            c.n,
            c.directions,
            c.ntp_s * 1e6,
            c.autodiff_s * 1e6,
            c.speedup()
        ));
    }
    out
}

/// Human-readable summary of the high-dim section.
pub fn summarize_highdim(report: &HighDimReport) -> String {
    let mut out = String::from("high-dim STDE vs exact plan (fixed training budget)\n");
    for c in &report.training {
        out.push_str(&format!(
            "  {:<12} d={:<4} {:<6} {:>6.1} dirs/step (exact {:>4.0})  \
             rel L2 {:>10.3e}  {:>7.1}s  {:>5.1}x fewer passes\n",
            c.problem,
            c.dim,
            c.estimator,
            c.directions_per_step,
            c.exact_directions,
            c.rel_l2,
            c.seconds,
            c.pass_ratio()
        ));
    }
    out.push_str("variance vs K (MSE against the exact d=10 oracle)\n");
    for v in &report.variance {
        out.push_str(&format!(
            "  K={:<3}{} mse {:>10.3e}  mse*K {:>10.3e}\n",
            v.samples,
            if v.antithetic { " anti" } else { "     " },
            v.mse,
            v.mse_times_k()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> OperatorBenchConfig {
        OperatorBenchConfig {
            width: 6,
            depth: 2,
            batch: 24,
            check_rows: 8,
            warmup: 0,
            trials: 1,
            hd_points: 24,
            hd_bc_points: 8,
            hd_adam: 3,
            hd_lbfgs: 2,
            hd_var_steps: 4,
            ..OperatorBenchConfig::default()
        }
    }

    #[test]
    fn tiny_operator_bench_produces_csv_and_json() {
        let cfg = tiny_cfg();
        let cells = run(&cfg, |_| {});
        assert_eq!(cells.len(), 2);
        for c in &cells {
            assert!(c.ntp_s > 0.0 && c.autodiff_s > 0.0);
        }
        assert_eq!(cells[0].n, 2);
        assert_eq!(cells[1].n, 4);
        let t = table(&cells);
        assert_eq!(t.rows.len(), 2);
        assert!(summarize(&cells).contains("directional"));
        let hd = run_highdim(&cfg, |_| {});
        let dir = std::env::temp_dir().join("ntangent_test_operator_bench");
        std::fs::create_dir_all(&dir).unwrap();
        save(&cells, &dir).unwrap();
        save_highdim(&hd, &dir).unwrap();
        assert!(dir.join("operator_speedup.csv").exists());
        assert!(dir.join("stde_highdim.csv").exists());
        let jpath = dir.join("BENCH_operators.json");
        save_json(&cfg, &cells, &hd, &jpath).unwrap();
        let text = std::fs::read_to_string(&jpath).unwrap();
        let doc = Json::parse(text.trim()).unwrap();
        assert_eq!(doc.get("bench").and_then(Json::as_str), Some("operators"));
        assert_eq!(
            doc.get("results").and_then(Json::as_arr).map(<[Json]>::len),
            Some(2)
        );
        let highdim = doc.get("highdim").expect("high-dim section present");
        assert_eq!(
            highdim.get("training").and_then(Json::as_arr).map(<[Json]>::len),
            Some(2)
        );
        assert_eq!(
            highdim.get("variance").and_then(Json::as_arr).map(<[Json]>::len),
            Some(5)
        );
    }

    #[test]
    fn highdim_report_is_structurally_sound() {
        let hd = run_highdim(&tiny_cfg(), |_| {});
        let [exact, stde] = &hd.training[..] else {
            panic!("expected the exact and stde legs")
        };
        assert_eq!(exact.estimator, "exact");
        assert_eq!(stde.estimator, "stde");
        assert_eq!(exact.exact_directions, 55.0);
        assert!((exact.pass_ratio() - 1.0).abs() < 1e-12);
        // K=4 samples of a pure-axis operator launch at most 4
        // directions — the >=10x pass-ratio acceptance metric.
        assert!(stde.directions_per_step <= 4.0 + 1e-12);
        assert!(stde.pass_ratio() >= 10.0);
        assert!(hd.training.iter().all(|c| c.rel_l2.is_finite() && c.seconds >= 0.0));
        // Variance cells carry finite MSE; the probe stream is a pure
        // function of (seed, step), so a rerun reproduces it bitwise.
        assert!(hd.variance.iter().all(|v| v.mse.is_finite()));
        let again = run_highdim(&tiny_cfg(), |_| {});
        for (a, b) in hd.variance.iter().zip(&again.variance) {
            assert_eq!(a.mse.to_bits(), b.mse.to_bits());
        }
    }
}
