//! Operator bench: multivariate PDE operators (2-D Laplacian,
//! biharmonic) through the **directional n-TangentProp** path — one
//! direction-stacked fused batch plus exact recombination — against the
//! nested-tape autodiff baseline (`ntangent bench operators`,
//! `results/operator_speedup.csv`; `--json BENCH_operators.json` writes
//! the machine-readable document CI's `bench-smoke` job exercises).
//!
//! The baseline rebuilds its graph per trial (the eager-framework
//! methodology every other bench in this crate uses: repeated
//! `backward` re-differentiates an already-grown graph, which is
//! exactly the exponential cost the paper measures). Before timing,
//! both paths are differentially checked against each other on a
//! subsample — a speedup measured on wrong numbers is worthless.

use crate::autodiff::{higher, Graph};
use crate::nn::Mlp;
use crate::ntp::{ActivationKind, MultiJetEngine};
use crate::pde::DiffOperator;
use crate::tensor::Tensor;
use crate::util::csv::Table;
use crate::util::json::Json;
use crate::util::prng::Prng;
use crate::util::stats::Summary;
use crate::util::timer::time_trials;
use std::collections::HashMap;
use std::path::Path;

/// Configuration of the operator bench.
#[derive(Clone, Debug)]
pub struct OperatorBenchConfig {
    /// Hidden width.
    pub width: usize,
    /// Hidden depth.
    pub depth: usize,
    /// Hidden activation.
    pub activation: ActivationKind,
    /// Collocation points per timed evaluation.
    pub batch: usize,
    /// Rows of the pre-timing differential check.
    pub check_rows: usize,
    /// Untimed warmup trials per leg.
    pub warmup: usize,
    /// Timed trials per leg.
    pub trials: usize,
    /// PRNG seed.
    pub seed: u64,
}

impl Default for OperatorBenchConfig {
    fn default() -> Self {
        // The acceptance shape: B = 4096 over the paper's 3x24 net,
        // Laplacian (n = 2) and biharmonic (n = 4).
        OperatorBenchConfig {
            width: 24,
            depth: 3,
            activation: ActivationKind::Tanh,
            batch: 4096,
            check_rows: 64,
            warmup: 1,
            trials: 5,
            seed: 29,
        }
    }
}

impl OperatorBenchConfig {
    /// The CI smoke shape: same operators and checks, minutes-budget
    /// sizes.
    pub fn smoke() -> OperatorBenchConfig {
        OperatorBenchConfig {
            batch: 512,
            check_rows: 32,
            trials: 3,
            ..OperatorBenchConfig::default()
        }
    }
}

/// One measured operator.
#[derive(Clone, Debug)]
pub struct OperatorCell {
    /// Operator name.
    pub name: &'static str,
    /// Collocation points per evaluation.
    pub batch: usize,
    /// Operator order (highest |α|).
    pub n: usize,
    /// Directional passes per evaluation (the `D` of `D·O(n log n)`).
    pub directions: usize,
    /// Hidden width.
    pub width: usize,
    /// Hidden depth.
    pub depth: usize,
    /// Mean seconds per directional-jet evaluation.
    pub ntp_s: f64,
    /// Mean seconds per nested-tape evaluation (graph rebuilt per
    /// trial, eager-style).
    pub autodiff_s: f64,
}

impl OperatorCell {
    /// Directional-path speedup over the nested-tape baseline.
    pub fn speedup(&self) -> f64 {
        self.autodiff_s / self.ntp_s
    }
}

/// The benched operators: the acceptance pair.
fn bench_operators() -> Vec<(&'static str, DiffOperator)> {
    vec![
        ("laplacian2d", DiffOperator::laplacian(2)),
        ("biharmonic2d", DiffOperator::biharmonic(2)),
    ]
}

/// Evaluate `op[u]` over `x` with the nested-tape baseline: build the
/// graph (repeated backward per multi-index), evaluate, return the
/// operator values.
fn autodiff_operator_eval(mlp: &Mlp, x: &Tensor, op: &DiffOperator) -> Tensor {
    let mut g = Graph::new();
    let pn = mlp.const_param_nodes(&mut g);
    let xn = g.input(x.shape());
    let u = mlp.forward_graph(&mut g, xn, &pn);
    let mut partials = HashMap::new();
    for alpha in op.needed_partials() {
        let node = if alpha.iter().all(|&a| a == 0) {
            u
        } else {
            higher::mixed_partial(&mut g, u, xn, &alpha)
        };
        partials.insert(alpha, node);
    }
    let lhs = op.apply_nodes(&mut g, &partials);
    let vals = g.eval(&[x.clone()], &[lhs]);
    vals.get(lhs).clone()
}

fn mean_s(ts: &[f64]) -> f64 {
    Summary::of(ts).mean
}

/// Run the operator sweep (differentially checking the two paths on a
/// subsample before each timed cell).
pub fn run(cfg: &OperatorBenchConfig, progress: impl Fn(&str)) -> Vec<OperatorCell> {
    let mut rng = Prng::seeded(cfg.seed);
    let mlp = Mlp::uniform_with(2, cfg.width, cfg.depth, 1, cfg.activation, &mut rng);
    let x = Tensor::rand_uniform(&[cfg.batch, 2], -1.0, 1.0, &mut rng);
    let mut out = Vec::new();
    for (name, op) in bench_operators() {
        let n = op.max_order();
        let engine = MultiJetEngine::new(2, n);
        progress(&format!(
            "operator {name}: n={n}, {} directions, B={}",
            engine.plan().n_directions(),
            cfg.batch
        ));

        // Differential check on a subsample: the two exact paths must
        // agree far below any interesting perf difference.
        let rows = cfg.check_rows.min(cfg.batch).max(1);
        let xs = Tensor::from_vec(x.data()[..rows * 2].to_vec(), &[rows, 2]);
        let jet = engine.jet(&mlp, &xs);
        let got = op.apply(&jet);
        let want = autodiff_operator_eval(&mlp, &xs, &op);
        for (i, (&a, &b)) in got.data().iter().zip(want.data()).enumerate() {
            assert!(
                (a - b).abs() <= 1e-8 * (1.0 + b.abs()),
                "{name}: directional {a} vs nested-tape {b} at row {i}"
            );
        }

        let ntp_s = mean_s(&time_trials(cfg.warmup, cfg.trials, || {
            let jet = engine.jet(&mlp, &x);
            std::hint::black_box(op.apply(&jet));
        }));
        let autodiff_s = mean_s(&time_trials(cfg.warmup, cfg.trials, || {
            std::hint::black_box(autodiff_operator_eval(&mlp, &x, &op));
        }));
        out.push(OperatorCell {
            name,
            batch: cfg.batch,
            n,
            directions: engine.plan().n_directions(),
            width: cfg.width,
            depth: cfg.depth,
            ntp_s,
            autodiff_s,
        });
    }
    out
}

/// One row per operator, with the speedup column the acceptance bar
/// reads.
pub fn table(cells: &[OperatorCell]) -> Table {
    let mut t = Table::new(&[
        "operator",
        "batch",
        "n",
        "directions",
        "width",
        "depth",
        "ntp_s",
        "autodiff_s",
        "speedup",
    ]);
    for c in cells {
        t.push(vec![
            c.name.to_string(),
            c.batch.to_string(),
            c.n.to_string(),
            c.directions.to_string(),
            c.width.to_string(),
            c.depth.to_string(),
            format!("{:.6e}", c.ntp_s),
            format!("{:.6e}", c.autodiff_s),
            format!("{:.4}", c.speedup()),
        ]);
    }
    t
}

/// Write `operator_speedup.csv`.
pub fn save(cells: &[OperatorCell], dir: &Path) -> std::io::Result<()> {
    table(cells).save(&dir.join("operator_speedup.csv"))
}

/// The `BENCH_operators.json` document: config + per-operator results.
pub fn to_json(cfg: &OperatorBenchConfig, cells: &[OperatorCell]) -> Json {
    let results: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("operator", Json::Str(c.name.into())),
                ("n", Json::Num(c.n as f64)),
                ("directions", Json::Num(c.directions as f64)),
                ("ntp_s", Json::Num(c.ntp_s)),
                ("autodiff_s", Json::Num(c.autodiff_s)),
                ("speedup", Json::Num(c.speedup())),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::Str("operators".into())),
        (
            "config",
            Json::obj(vec![
                ("batch", Json::Num(cfg.batch as f64)),
                ("width", Json::Num(cfg.width as f64)),
                ("depth", Json::Num(cfg.depth as f64)),
                ("activation", Json::Str(cfg.activation.name().into())),
                ("trials", Json::Num(cfg.trials as f64)),
            ]),
        ),
        ("results", Json::Arr(results)),
    ])
}

/// Write the `BENCH_operators.json` document to `path`.
pub fn save_json(
    cfg: &OperatorBenchConfig,
    cells: &[OperatorCell],
    path: &Path,
) -> std::io::Result<()> {
    std::fs::write(path, to_json(cfg, cells).dump() + "\n")
}

/// Human-readable summary for the CLI.
pub fn summarize(cells: &[OperatorCell]) -> String {
    let mut out =
        String::from("directional n-TangentProp vs nested-tape autodiff (mean seconds)\n");
    for c in cells {
        out.push_str(&format!(
            "  {:<14} B={:<6} n={} D={:<2} directional {:>10.1} µs  \
             nested-tape {:>12.1} µs ({:.1}x)\n",
            c.name,
            c.batch,
            c.n,
            c.directions,
            c.ntp_s * 1e6,
            c.autodiff_s * 1e6,
            c.speedup()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_operator_bench_produces_csv_and_json() {
        let cfg = OperatorBenchConfig {
            width: 6,
            depth: 2,
            batch: 24,
            check_rows: 8,
            warmup: 0,
            trials: 1,
            ..OperatorBenchConfig::default()
        };
        let cells = run(&cfg, |_| {});
        assert_eq!(cells.len(), 2);
        for c in &cells {
            assert!(c.ntp_s > 0.0 && c.autodiff_s > 0.0);
        }
        assert_eq!(cells[0].n, 2);
        assert_eq!(cells[1].n, 4);
        let t = table(&cells);
        assert_eq!(t.rows.len(), 2);
        assert!(summarize(&cells).contains("directional"));
        let dir = std::env::temp_dir().join("ntangent_test_operator_bench");
        std::fs::create_dir_all(&dir).unwrap();
        save(&cells, &dir).unwrap();
        assert!(dir.join("operator_speedup.csv").exists());
        let jpath = dir.join("BENCH_operators.json");
        save_json(&cfg, &cells, &jpath).unwrap();
        let text = std::fs::read_to_string(&jpath).unwrap();
        let doc = Json::parse(text.trim()).unwrap();
        assert_eq!(doc.get("bench").and_then(Json::as_str), Some("operators"));
        assert_eq!(
            doc.get("results").and_then(Json::as_arr).map(<[Json]>::len),
            Some(2)
        );
    }
}
