//! Fig 6: end-to-end PINN training for the first Burgers profile with
//! both engines — loss, λ and the cumulative-runtime ratio per epoch.

use crate::pinn::{train_burgers, BurgersLossSpec, DerivEngine, TrainConfig, TrainResult};
use crate::util::csv::Table;
use std::path::Path;

/// Configuration of the fig 6 training comparison.
#[derive(Clone, Debug)]
pub struct TrainingBenchConfig {
    /// Burgers profile index.
    pub profile_k: usize,
    /// Trainer configuration (shared by both engines).
    pub train: TrainConfig,
    /// Optional loss-spec override (defaults to the profile's spec).
    pub spec_overrides: Option<BurgersLossSpec>,
    /// Skip the autodiff leg when its projected cost is prohibitive
    /// (profiles ≥ 3, as in the paper).
    pub run_autodiff: bool,
}

impl Default for TrainingBenchConfig {
    fn default() -> Self {
        TrainingBenchConfig {
            profile_k: 1,
            train: TrainConfig::default(),
            spec_overrides: None,
            run_autodiff: true,
        }
    }
}

/// Both engines' training results.
pub struct TrainingBenchResult {
    /// The n-TangentProp run.
    pub ntp: TrainResult,
    /// The autodiff baseline run (when not skipped).
    pub autodiff: Option<TrainResult>,
}

impl TrainingBenchResult {
    /// End-to-end speedup (autodiff seconds / ntp seconds).
    pub fn speedup(&self) -> Option<f64> {
        self.autodiff.as_ref().map(|ad| ad.seconds / self.ntp.seconds)
    }
}

/// Train with n-TangentProp and (optionally) the autodiff baseline.
pub fn run(cfg: &TrainingBenchConfig) -> TrainingBenchResult {
    let spec = cfg
        .spec_overrides
        .clone()
        .unwrap_or_else(|| BurgersLossSpec::for_profile(cfg.profile_k));
    let ntp = train_burgers(spec.clone(), &cfg.train, DerivEngine::Ntp);
    let autodiff = if cfg.run_autodiff {
        Some(train_burgers(spec, &cfg.train, DerivEngine::Autodiff))
    } else {
        None
    };
    TrainingBenchResult { ntp, autodiff }
}

/// Per-epoch CSV: epoch, phase, loss/λ/elapsed for each engine and the
/// cumulative runtime ratio (the bottom panel of Fig 6).
pub fn save(result: &TrainingBenchResult, path: &Path) -> std::io::Result<()> {
    let mut t = Table::new(&[
        "epoch",
        "phase",
        "loss_ntp",
        "lambda_ntp",
        "elapsed_ntp",
        "loss_autodiff",
        "lambda_autodiff",
        "elapsed_autodiff",
        "runtime_ratio",
    ]);
    for (i, log) in result.ntp.logs.iter().enumerate() {
        let ad = result.autodiff.as_ref().and_then(|r| r.logs.get(i));
        let (la, lm, el, ratio) = match ad {
            Some(a) => (
                format!("{:.6e}", a.loss),
                format!("{:.8}", a.lambda),
                format!("{:.4}", a.elapsed),
                format!("{:.4}", a.elapsed / log.elapsed.max(1e-12)),
            ),
            None => (String::new(), String::new(), String::new(), String::new()),
        };
        t.push(vec![
            log.epoch.to_string(),
            log.phase.to_string(),
            format!("{:.6e}", log.loss),
            format!("{:.8}", log.lambda),
            format!("{:.4}", log.elapsed),
            la,
            lm,
            el,
            ratio,
        ]);
    }
    t.save(path)
}

/// Headline numbers for EXPERIMENTS.md.
pub fn summarize(result: &TrainingBenchResult) -> String {
    let mut out = String::new();
    let k = result.ntp.profile.k;
    out.push_str(&format!(
        "profile k={k} (λ* = {:.6}): ntp {:.2}s, λ = {:.6} (err {:.2e}), loss {:.3e}, fwd/bwd = {}/{}\n",
        result.ntp.profile.lambda_smooth(),
        result.ntp.seconds,
        result.ntp.lambda,
        result.ntp.lambda_error(),
        result.ntp.final_loss,
        result.ntp.n_forward,
        result.ntp.n_backward,
    ));
    if let Some(ad) = &result.autodiff {
        out.push_str(&format!(
            "autodiff {:.2}s, λ = {:.6} (err {:.2e}), loss {:.3e} → end-to-end speedup {:.2}x\n",
            ad.seconds,
            ad.lambda,
            ad.lambda_error(),
            ad.final_loss,
            result.speedup().unwrap()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_benchmark_produces_ratio() {
        let mut spec = BurgersLossSpec::for_profile(1);
        spec.n_res = 32;
        spec.n_org = 8;
        let cfg = TrainingBenchConfig {
            profile_k: 1,
            train: TrainConfig {
                width: 10,
                depth: 2,
                adam_epochs: 20,
                lbfgs_epochs: 10,
                adam_lr: 1e-3,
                seed: 2,
                log_every: 5,
                ..TrainConfig::default()
            },
            spec_overrides: Some(spec),
            run_autodiff: true,
        };
        let result = run(&cfg);
        let speedup = result.speedup().unwrap();
        assert!(speedup > 0.0);
        let dir = std::env::temp_dir().join("ntangent_test_training");
        std::fs::create_dir_all(&dir).unwrap();
        save(&result, &dir.join("fig6.csv")).unwrap();
        let text = std::fs::read_to_string(dir.join("fig6.csv")).unwrap();
        assert!(text.contains("runtime_ratio"));
        assert!(summarize(&result).contains("speedup"));
    }
}
