//! `bench obs`: tracing-overhead bench of the fused forward
//! (`ntangent bench obs`, `results/obs_overhead.csv`; `--json
//! BENCH_obs.json` writes the committed baseline document).
//!
//! For each derivative order on the `BENCH_kernels.json` reference shape
//! (B = 4096, width 64, depth 4, tanh) it times the fused `forward_n`
//! twice — tracing off, then tracing on with kernel-phase sampling at
//! the configured stride — and reports the relative overhead plus the
//! per-phase nanosecond breakdown the sampled tiles accumulated
//! ([`crate::obs::kernel_phase_totals`]).
//!
//! Before any timing, the traced output is checked **bitwise** against
//! the untraced one: the observability contract says instrumentation
//! never touches the float path, so an overhead number measured on
//! different numbers would mean the contract is broken, not that the
//! tracer is slow. The acceptance bar is `max_overhead_pct ≤ 2`.

use crate::nn::Mlp;
use crate::ntp::{ActivationKind, NtpEngine};
use crate::obs;
use crate::tensor::Tensor;
use crate::util::csv::Table;
use crate::util::json::Json;
use crate::util::prng::Prng;
use crate::util::stats::Summary;
use crate::util::timer::time_trials;
use std::collections::BTreeMap;
use std::path::Path;

/// The overhead budget `bench obs` holds the tracer to (percent).
pub const OVERHEAD_BUDGET_PCT: f64 = 2.0;

/// Configuration of the tracing-overhead bench.
#[derive(Clone, Debug)]
pub struct ObsBenchConfig {
    /// Hidden width.
    pub width: usize,
    /// Hidden depth.
    pub depth: usize,
    /// Hidden activation.
    pub activation: ActivationKind,
    /// Batch size of the timed forwards.
    pub batch: usize,
    /// Derivative orders to sweep.
    pub orders: Vec<usize>,
    /// Kernel-phase sampling stride of the traced leg.
    pub kernel_sample: u32,
    /// Untimed warmup trials per leg.
    pub warmup: usize,
    /// Timed trials per leg.
    pub trials: usize,
    /// PRNG seed.
    pub seed: u64,
}

impl Default for ObsBenchConfig {
    fn default() -> Self {
        // The BENCH_kernels reference shape, so the overhead numbers are
        // read against the same cells as the kernel-speedup baseline.
        ObsBenchConfig {
            width: 64,
            depth: 4,
            activation: ActivationKind::Tanh,
            batch: 4096,
            orders: vec![4, 6, 8],
            kernel_sample: 16,
            warmup: 2,
            trials: 10,
            seed: 23,
        }
    }
}

impl ObsBenchConfig {
    /// The CI smoke shape: same legs, checks and schema, seconds budget.
    pub fn smoke() -> ObsBenchConfig {
        ObsBenchConfig {
            batch: 512,
            orders: vec![4, 6],
            warmup: 1,
            trials: 3,
            ..ObsBenchConfig::default()
        }
    }
}

/// One measured derivative order.
#[derive(Clone, Debug)]
pub struct ObsCell {
    /// Derivative order.
    pub n: usize,
    /// Batch size.
    pub batch: usize,
    /// Mean seconds per fused forward, tracing disabled.
    pub untraced_s: f64,
    /// Mean seconds per fused forward, tracing + phase sampling enabled.
    pub traced_s: f64,
    /// Sampled nanoseconds per kernel phase over the traced trials
    /// (`(name, ns)`, phases with data only).
    pub phase_ns: Vec<(&'static str, u64)>,
    /// Tiles swept by the traced trials.
    pub tiles: u64,
    /// Tiles actually sampled (every `kernel_sample`-th).
    pub samples: u64,
}

impl ObsCell {
    /// Traced-over-untraced overhead in percent (can be slightly
    /// negative in the noise floor).
    pub fn overhead_pct(&self) -> f64 {
        if self.untraced_s > 0.0 {
            (self.traced_s / self.untraced_s - 1.0) * 100.0
        } else {
            0.0
        }
    }
}

/// The worst overhead across the sweep — the acceptance number.
pub fn max_overhead_pct(cells: &[ObsCell]) -> f64 {
    cells
        .iter()
        .map(ObsCell::overhead_pct)
        .fold(f64::NEG_INFINITY, f64::max)
}

fn mean_s(ts: &[f64]) -> f64 {
    Summary::of(ts).mean
}

/// Snapshot the cumulative kernel-phase counters as a map (the bench
/// works in before/after deltas so it never resets the global registry).
fn phase_counters() -> (BTreeMap<&'static str, u64>, u64, u64) {
    let (phases, tiles, samples) = obs::kernel_phase_totals();
    (phases.into_iter().collect(), tiles, samples)
}

/// Run the order sweep (bitwise-checking traced vs untraced output
/// before each timed cell).
pub fn run(cfg: &ObsBenchConfig, progress: impl Fn(&str)) -> Vec<ObsCell> {
    let was_enabled = obs::enabled();
    let was_sample = obs::kernel_sample();
    let mut rng = Prng::seeded(cfg.seed);
    let mlp = Mlp::uniform_with(1, cfg.width, cfg.depth, 1, cfg.activation, &mut rng);
    let x = Tensor::rand_uniform(&[cfg.batch, 1], -1.0, 1.0, &mut rng);
    let mut out = Vec::new();
    for &n in &cfg.orders {
        progress(&format!("obs cell n={n} B={}", cfg.batch));
        let eng = NtpEngine::new(n);

        // Bitwise identity first: an overhead measured on different
        // floats would mean the no-touch contract is broken.
        obs::set_enabled(false);
        let want = eng.forward_n(&mlp, &x, n);
        obs::set_enabled(true);
        obs::set_kernel_sample(cfg.kernel_sample);
        let got = eng.forward_n(&mlp, &x, n);
        for (k, (a, b)) in want.iter().zip(&got).enumerate() {
            for (&ea, &eb) in a.data().iter().zip(b.data()) {
                assert!(
                    ea.to_bits() == eb.to_bits(),
                    "traced forward diverged bitwise at n={n} channel {k}"
                );
            }
        }

        obs::set_enabled(false);
        let untraced_s = mean_s(&time_trials(cfg.warmup, cfg.trials, || {
            std::hint::black_box(eng.forward_n(&mlp, &x, n));
        }));

        obs::set_enabled(true);
        let (before, tiles0, samples0) = phase_counters();
        let traced_s = mean_s(&time_trials(cfg.warmup, cfg.trials, || {
            std::hint::black_box(eng.forward_n(&mlp, &x, n));
        }));
        let (after, tiles1, samples1) = phase_counters();
        let phase_ns: Vec<(&'static str, u64)> = obs::KERNEL_PHASES
            .iter()
            .filter_map(|&name| {
                let d = after.get(name).copied().unwrap_or(0)
                    - before.get(name).copied().unwrap_or(0);
                (d > 0).then_some((name, d))
            })
            .collect();

        out.push(ObsCell {
            n,
            batch: cfg.batch,
            untraced_s,
            traced_s,
            phase_ns,
            tiles: tiles1 - tiles0,
            samples: samples1 - samples0,
        });
    }
    obs::set_enabled(was_enabled);
    obs::set_kernel_sample(was_sample);
    out
}

/// One row per order, phases as fixed columns (0 when unsampled).
pub fn table(cells: &[ObsCell]) -> Table {
    let mut cols = vec![
        "n",
        "batch",
        "untraced_s",
        "traced_s",
        "overhead_pct",
        "tiles",
        "samples",
    ];
    cols.extend(obs::KERNEL_PHASES.iter().map(|&p| match p {
        "pack" => "pack_ns",
        "tower" => "tower_ns",
        "powers" => "powers_ns",
        "interpret" => "interpret_ns",
        "unpack" => "unpack_ns",
        _ => "gemm_ns",
    }));
    let mut t = Table::new(&cols);
    for c in cells {
        let mut row = vec![
            c.n.to_string(),
            c.batch.to_string(),
            format!("{:.6e}", c.untraced_s),
            format!("{:.6e}", c.traced_s),
            format!("{:.3}", c.overhead_pct()),
            c.tiles.to_string(),
            c.samples.to_string(),
        ];
        for &name in &obs::KERNEL_PHASES {
            let ns = c
                .phase_ns
                .iter()
                .find(|(p, _)| *p == name)
                .map_or(0, |&(_, ns)| ns);
            row.push(ns.to_string());
        }
        t.push(row);
    }
    t
}

/// Write `obs_overhead.csv`.
pub fn save(cells: &[ObsCell], dir: &Path) -> std::io::Result<()> {
    table(cells).save(&dir.join("obs_overhead.csv"))
}

/// The `BENCH_obs.json` document: config + per-order results + the
/// worst-case overhead against the committed budget.
pub fn to_json(cfg: &ObsBenchConfig, cells: &[ObsCell]) -> Json {
    let results: Vec<Json> = cells
        .iter()
        .map(|c| {
            let phases = Json::obj(
                c.phase_ns
                    .iter()
                    .map(|&(name, ns)| (name, Json::Num(ns as f64)))
                    .collect(),
            );
            Json::obj(vec![
                ("n", Json::Num(c.n as f64)),
                ("untraced_s", Json::Num(c.untraced_s)),
                ("traced_s", Json::Num(c.traced_s)),
                ("overhead_pct", Json::Num(c.overhead_pct())),
                ("tiles", Json::Num(c.tiles as f64)),
                ("samples", Json::Num(c.samples as f64)),
                ("phases_ns", phases),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::Str("obs".into())),
        (
            "config",
            Json::obj(vec![
                ("batch", Json::Num(cfg.batch as f64)),
                ("width", Json::Num(cfg.width as f64)),
                ("depth", Json::Num(cfg.depth as f64)),
                ("activation", Json::Str(cfg.activation.name().into())),
                ("kernel_sample", Json::Num(cfg.kernel_sample as f64)),
                ("trials", Json::Num(cfg.trials as f64)),
            ]),
        ),
        ("results", Json::Arr(results)),
        ("max_overhead_pct", Json::Num(max_overhead_pct(cells))),
        ("budget_pct", Json::Num(OVERHEAD_BUDGET_PCT)),
    ])
}

/// Write the `BENCH_obs.json` document to `path`.
pub fn save_json(cfg: &ObsBenchConfig, cells: &[ObsCell], path: &Path) -> std::io::Result<()> {
    std::fs::write(path, to_json(cfg, cells).dump() + "\n")
}

/// Human-readable summary for the CLI.
pub fn summarize(cells: &[ObsCell]) -> String {
    let mut out = String::from("tracing overhead of the fused forward (mean seconds)\n");
    for c in cells {
        out.push_str(&format!(
            "  B={:<6} n={}  untraced {:>10.1} µs  traced {:>10.1} µs  ({:+.2}%)  \
             {} tiles, {} sampled\n",
            c.batch,
            c.n,
            c.untraced_s * 1e6,
            c.traced_s * 1e6,
            c.overhead_pct(),
            c.tiles,
            c.samples
        ));
        if !c.phase_ns.is_empty() {
            let total: u64 = c.phase_ns.iter().map(|&(_, ns)| ns).sum();
            let shares: Vec<String> = c
                .phase_ns
                .iter()
                .map(|&(name, ns)| {
                    format!("{name} {:.0}%", ns as f64 / total.max(1) as f64 * 100.0)
                })
                .collect();
            out.push_str(&format!("           phase split: {}\n", shares.join(", ")));
        }
    }
    out.push_str(&format!(
        "  worst overhead {:+.2}% (budget {:.1}%)\n",
        max_overhead_pct(cells),
        OVERHEAD_BUDGET_PCT
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_obs_bench_produces_csv_and_json() {
        let _g = obs::test_guard();
        let cfg = ObsBenchConfig {
            width: 8,
            depth: 2,
            batch: 64,
            orders: vec![2, 3],
            kernel_sample: 4,
            warmup: 0,
            trials: 1,
            ..ObsBenchConfig::default()
        };
        let cells = run(&cfg, |_| {});
        assert_eq!(cells.len(), 2);
        for c in &cells {
            assert!(c.untraced_s > 0.0 && c.traced_s > 0.0);
            assert!(c.overhead_pct().is_finite());
            assert!(c.tiles > 0 && c.samples > 0, "traced leg must sample tiles");
        }
        let t = table(&cells);
        assert_eq!(t.rows.len(), 2);
        assert!(summarize(&cells).contains("tracing overhead"));
        let dir = std::env::temp_dir().join("ntangent_test_obs_bench");
        std::fs::create_dir_all(&dir).unwrap();
        save(&cells, &dir).unwrap();
        assert!(dir.join("obs_overhead.csv").exists());
        let jpath = dir.join("BENCH_obs.json");
        save_json(&cfg, &cells, &jpath).unwrap();
        let doc = Json::parse(std::fs::read_to_string(&jpath).unwrap().trim()).unwrap();
        assert_eq!(doc.get("bench").and_then(Json::as_str), Some("obs"));
        assert_eq!(
            doc.get("results").and_then(Json::as_arr).map(<[Json]>::len),
            Some(2)
        );
        assert!(doc.get("max_overhead_pct").and_then(Json::as_f64).is_some());
    }
}
