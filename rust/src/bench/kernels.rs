//! Fused-kernel speedup bench: the fused element-tiled `forward_n`
//! against the pre-fusion [`NtpEngine::forward_reference`] path, serial
//! and under `Fixed(t)` batch parallelism — the headline numbers of the
//! kernel-fusion PR (`ntangent bench kernels`, `results/kernel_speedup.csv`,
//! and the committed `BENCH_kernels.json` baseline).
//!
//! Before timing, every order's fused output is differentially checked
//! against the reference path (≤ 1e-12 relative) — a speedup measured on
//! wrong numbers is worthless.

use crate::nn::Mlp;
use crate::ntp::{ActivationKind, NtpEngine, ParallelPolicy};
use crate::tensor::Tensor;
use crate::util::csv::Table;
use crate::util::json::Json;
use crate::util::prng::Prng;
use crate::util::stats::Summary;
use crate::util::timer::time_trials;
use std::path::Path;

/// Configuration of the fused-vs-reference kernel bench.
#[derive(Clone, Debug)]
pub struct KernelBenchConfig {
    /// Hidden width.
    pub width: usize,
    /// Hidden depth.
    pub depth: usize,
    /// Hidden activation.
    pub activation: ActivationKind,
    /// Batch size of the timed forwards.
    pub batch: usize,
    /// Derivative orders to sweep.
    pub orders: Vec<usize>,
    /// Worker threads of the parallel fused leg.
    pub par_threads: usize,
    /// Untimed warmup trials per leg.
    pub warmup: usize,
    /// Timed trials per leg.
    pub trials: usize,
    /// PRNG seed.
    pub seed: u64,
}

impl Default for KernelBenchConfig {
    fn default() -> Self {
        // The acceptance shape of the kernel-fusion PR: B = 4096,
        // width 64, depth 4, n = 4 / 6 / 8, Fixed(4) for the parallel leg.
        KernelBenchConfig {
            width: 64,
            depth: 4,
            activation: ActivationKind::Tanh,
            batch: 4096,
            orders: vec![4, 6, 8],
            par_threads: 4,
            warmup: 2,
            trials: 10,
            seed: 23,
        }
    }
}

impl KernelBenchConfig {
    /// The CI smoke shape: small enough for a minutes-budget job, same
    /// schema and checks as the full run.
    pub fn smoke() -> KernelBenchConfig {
        KernelBenchConfig {
            batch: 1024,
            orders: vec![4, 6],
            warmup: 1,
            trials: 3,
            ..KernelBenchConfig::default()
        }
    }
}

/// One measured derivative order.
#[derive(Clone, Copy, Debug)]
pub struct KernelCell {
    /// Batch size.
    pub batch: usize,
    /// Derivative order.
    pub n: usize,
    /// Hidden width.
    pub width: usize,
    /// Hidden depth.
    pub depth: usize,
    /// Worker threads of the parallel fused leg.
    pub par_threads: usize,
    /// Mean seconds per pre-fusion reference forward (serial).
    pub reference_s: f64,
    /// Mean seconds per fused forward (serial).
    pub fused_s: f64,
    /// Mean seconds per fused forward under `Fixed(par_threads)`.
    pub fused_par_s: f64,
}

impl KernelCell {
    /// Serial fused speedup over the reference path.
    pub fn fused_speedup(&self) -> f64 {
        self.reference_s / self.fused_s
    }

    /// Parallel fused speedup over the (serial) reference path.
    pub fn par_speedup(&self) -> f64 {
        self.reference_s / self.fused_par_s
    }
}

fn mean_s(ts: &[f64]) -> f64 {
    Summary::of(ts).mean
}

/// Run the order sweep (differentially checking fused vs reference
/// before each timed cell).
pub fn run(cfg: &KernelBenchConfig, progress: impl Fn(&str)) -> Vec<KernelCell> {
    let mut rng = Prng::seeded(cfg.seed);
    let mlp = Mlp::uniform_with(1, cfg.width, cfg.depth, 1, cfg.activation, &mut rng);
    let x = Tensor::rand_uniform(&[cfg.batch, 1], -1.0, 1.0, &mut rng);
    let mut out = Vec::new();
    for &n in &cfg.orders {
        progress(&format!("kernel cell n={n} B={}", cfg.batch));
        let serial = NtpEngine::new(n);
        let par = NtpEngine::with_policy(n, ParallelPolicy::Fixed(cfg.par_threads));
        let want = serial.forward_reference(&mlp, &x, n);
        let got = serial.forward_n(&mlp, &x, n);
        for (k, (a, b)) in want.iter().zip(&got).enumerate() {
            for (&ea, &eb) in a.data().iter().zip(b.data()) {
                assert!(
                    (ea - eb).abs() <= 1e-12 * (1.0 + ea.abs()),
                    "fused kernel diverged from reference at n={n} channel {k}"
                );
            }
        }
        let reference_s = mean_s(&time_trials(cfg.warmup, cfg.trials, || {
            std::hint::black_box(serial.forward_reference(&mlp, &x, n));
        }));
        let fused_s = mean_s(&time_trials(cfg.warmup, cfg.trials, || {
            std::hint::black_box(serial.forward_n(&mlp, &x, n));
        }));
        let fused_par_s = mean_s(&time_trials(cfg.warmup, cfg.trials, || {
            std::hint::black_box(par.forward_n(&mlp, &x, n));
        }));
        out.push(KernelCell {
            batch: cfg.batch,
            n,
            width: cfg.width,
            depth: cfg.depth,
            par_threads: cfg.par_threads,
            reference_s,
            fused_s,
            fused_par_s,
        });
    }
    out
}

/// One row per order, with the speedup columns the acceptance bar reads.
pub fn table(cells: &[KernelCell]) -> Table {
    let mut t = Table::new(&[
        "batch",
        "n",
        "width",
        "depth",
        "par_threads",
        "reference_s",
        "fused_serial_s",
        "fused_parallel_s",
        "serial_speedup",
        "parallel_speedup",
    ]);
    for c in cells {
        t.push(vec![
            c.batch.to_string(),
            c.n.to_string(),
            c.width.to_string(),
            c.depth.to_string(),
            c.par_threads.to_string(),
            format!("{:.6e}", c.reference_s),
            format!("{:.6e}", c.fused_s),
            format!("{:.6e}", c.fused_par_s),
            format!("{:.4}", c.fused_speedup()),
            format!("{:.4}", c.par_speedup()),
        ]);
    }
    t
}

/// Write `kernel_speedup.csv`.
pub fn save(cells: &[KernelCell], dir: &Path) -> std::io::Result<()> {
    table(cells).save(&dir.join("kernel_speedup.csv"))
}

/// The `BENCH_kernels.json` document: config + per-order results, the
/// perf-trajectory format the repo commits a baseline of.
pub fn to_json(cfg: &KernelBenchConfig, cells: &[KernelCell]) -> Json {
    let results: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("n", Json::Num(c.n as f64)),
                ("reference_s", Json::Num(c.reference_s)),
                ("fused_serial_s", Json::Num(c.fused_s)),
                ("fused_parallel_s", Json::Num(c.fused_par_s)),
                ("serial_speedup", Json::Num(c.fused_speedup())),
                ("parallel_speedup", Json::Num(c.par_speedup())),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::Str("kernels".into())),
        (
            "config",
            Json::obj(vec![
                ("batch", Json::Num(cfg.batch as f64)),
                ("width", Json::Num(cfg.width as f64)),
                ("depth", Json::Num(cfg.depth as f64)),
                ("activation", Json::Str(cfg.activation.name().into())),
                ("par_threads", Json::Num(cfg.par_threads as f64)),
                ("trials", Json::Num(cfg.trials as f64)),
            ]),
        ),
        ("results", Json::Arr(results)),
    ])
}

/// Write the `BENCH_kernels.json` document to `path`.
pub fn save_json(
    cfg: &KernelBenchConfig,
    cells: &[KernelCell],
    path: &Path,
) -> std::io::Result<()> {
    std::fs::write(path, to_json(cfg, cells).dump() + "\n")
}

/// Human-readable summary for the CLI.
pub fn summarize(cells: &[KernelCell]) -> String {
    let mut out = String::from("fused kernel vs pre-fusion reference (mean seconds)\n");
    for c in cells {
        out.push_str(&format!(
            "  B={:<6} n={}  reference {:>10.1} µs  fused {:>10.1} µs ({:.2}x)  \
             fused t={} {:>10.1} µs ({:.2}x)\n",
            c.batch,
            c.n,
            c.reference_s * 1e6,
            c.fused_s * 1e6,
            c.fused_speedup(),
            c.par_threads,
            c.fused_par_s * 1e6,
            c.par_speedup()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_kernel_bench_produces_grid_csv_and_json() {
        let cfg = KernelBenchConfig {
            width: 8,
            depth: 2,
            batch: 32,
            orders: vec![2, 3],
            par_threads: 2,
            warmup: 0,
            trials: 1,
            ..KernelBenchConfig::default()
        };
        let cells = run(&cfg, |_| {});
        assert_eq!(cells.len(), 2);
        for c in &cells {
            assert!(c.reference_s > 0.0 && c.fused_s > 0.0 && c.fused_par_s > 0.0);
        }
        let t = table(&cells);
        assert_eq!(t.rows.len(), 2);
        assert!(summarize(&cells).contains("fused"));
        let dir = std::env::temp_dir().join("ntangent_test_kernel_bench");
        std::fs::create_dir_all(&dir).unwrap();
        save(&cells, &dir).unwrap();
        assert!(dir.join("kernel_speedup.csv").exists());
        let jpath = dir.join("BENCH_kernels.json");
        save_json(&cfg, &cells, &jpath).unwrap();
        let text = std::fs::read_to_string(&jpath).unwrap();
        let doc = Json::parse(text.trim()).unwrap();
        assert_eq!(doc.get("bench").and_then(Json::as_str), Some("kernels"));
        assert_eq!(doc.get("results").and_then(Json::as_arr).map(<[Json]>::len), Some(2));
    }
}
