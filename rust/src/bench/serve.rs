//! `bench serve`: closed-loop load benchmark of the TCP serving stack
//! (`ntangent bench serve`, `results/serve_load.csv`; `--json
//! BENCH_serve.json` writes the machine-readable document CI's
//! `bench-smoke` job exercises).
//!
//! Three legs, all over real TCP loopback with the production
//! [`crate::coordinator::serve_tcp_with`] stack:
//!
//! - **mixed**: `requests` pipelined requests across `connections`
//!   persistent connections, each keeping `window` requests in flight —
//!   ~70% scalar derivative-stack requests with randomized activation
//!   overrides, ~30% one-dimensional operator requests, and a stats
//!   probe sprinkled in — reporting throughput and p50/p95/p99 latency;
//! - **operator_cached**: pipelined 2-D Laplacian operator requests
//!   against the default (plan/operator-cached) [`OperatorServer`];
//! - **operator_uncached**: the pre-cache baseline — a fresh connection
//!   *and* a fresh operator + engine compile per request
//!   ([`OperatorServer::uncached`], no pipelining).
//!
//! The ratio of the two operator throughputs
//! ([`operator_speedup`]) is the serving-cache acceptance number
//! (`BENCH_serve.json` / `operator_speedup`, expected ≥ 2).

use crate::coordinator::{
    protocol, serve_tcp_with, BatcherConfig, EvalBackend, NativeBackend, OperatorServer, Service,
    ServiceHandle, TcpClient,
};
use crate::nn::Mlp;
use crate::ntp::{ActivationKind, ParallelPolicy};
use crate::obs::{ns_since, ns_to_us, Histogram};
use crate::util::csv::Table;
use crate::util::json::Json;
use crate::util::prng::Prng;
use std::collections::VecDeque;
use std::net::TcpListener;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

/// Configuration of the serving load benchmark.
#[derive(Clone, Debug)]
pub struct ServeBenchConfig {
    /// Total requests of the mixed pipelined leg.
    pub requests: usize,
    /// Persistent connections (client threads) for the pipelined legs.
    pub connections: usize,
    /// Requests each connection keeps in flight.
    pub window: usize,
    /// Points per scalar request.
    pub points: usize,
    /// Requests of the cached-operator pipelined leg.
    pub operator_requests: usize,
    /// Requests of the uncached one-shot baseline leg.
    pub baseline_requests: usize,
    /// Hidden width of the served models.
    pub width: usize,
    /// Hidden depth of the served models.
    pub depth: usize,
    /// Batcher workers behind the mixed endpoint.
    pub workers: usize,
    /// PRNG seed.
    pub seed: u64,
}

impl Default for ServeBenchConfig {
    fn default() -> Self {
        // The acceptance shape: O(10^5) pipelined requests end to end.
        ServeBenchConfig {
            requests: 100_000,
            connections: 4,
            window: 64,
            points: 8,
            operator_requests: 4_000,
            baseline_requests: 300,
            width: 24,
            depth: 3,
            workers: 2,
            seed: 31,
        }
    }
}

impl ServeBenchConfig {
    /// The CI smoke shape: same legs and protocol path, seconds-budget
    /// sizes.
    pub fn smoke() -> ServeBenchConfig {
        ServeBenchConfig {
            requests: 2_000,
            connections: 2,
            window: 32,
            operator_requests: 300,
            baseline_requests: 30,
            ..ServeBenchConfig::default()
        }
    }
}

/// One measured serving leg.
#[derive(Clone, Debug)]
pub struct ServeCell {
    /// Leg name (`mixed`, `operator_cached`, `operator_uncached`).
    pub leg: &'static str,
    /// Requests completed.
    pub requests: usize,
    /// Concurrent connections used.
    pub connections: usize,
    /// Pipeline window per connection (1 = one-shot).
    pub window: usize,
    /// Wall-clock seconds for the whole leg.
    pub elapsed_s: f64,
    /// Median request latency (µs), quoted from the same log-scale
    /// [`crate::obs::Histogram`] the server's stats endpoint uses — so
    /// client-side and `{"stats":"full"}` quantiles agree to within one
    /// bucket (~±9.5%) by construction.
    pub p50_us: f64,
    /// 95th-percentile request latency (µs, bucketed as above).
    pub p95_us: f64,
    /// 99th-percentile request latency (µs, bucketed as above).
    pub p99_us: f64,
    /// Requests answered with an error payload (shed replies included).
    pub errors: usize,
    /// Server-side shed count over the leg.
    pub shed: u64,
    /// Serving-cache hits over the leg.
    pub plan_hits: u64,
    /// Serving-cache misses over the leg.
    pub plan_misses: u64,
}

impl ServeCell {
    /// Completed requests per wall-clock second.
    pub fn throughput_rps(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.requests as f64 / self.elapsed_s
        } else {
            0.0
        }
    }
}

/// Cached-over-uncached operator throughput ratio (the acceptance
/// number); `None` until both operator legs are present.
pub fn operator_speedup(cells: &[ServeCell]) -> Option<f64> {
    let cached = cells.iter().find(|c| c.leg == "operator_cached")?;
    let uncached = cells.iter().find(|c| c.leg == "operator_uncached")?;
    Some(cached.throughput_rps() / uncached.throughput_rps())
}

/// Quote (p50, p95, p99) in µs from a latency histogram of nanoseconds.
fn quantiles_us(hist: &Histogram) -> (f64, f64, f64) {
    let snap = hist.snapshot();
    let q = |p: f64| ns_to_us(snap.percentile(p).unwrap_or(0.0));
    (q(0.50), q(0.95), q(0.99))
}

/// Spin up a loopback endpoint: a native-backend service pool plus an
/// operator front over `op_mlp`. The accept loop thread is detached
/// (it lives until process exit; each leg uses its own endpoint).
fn spawn_endpoint(
    scalar_mlp: &Mlp,
    op_mlp: &Mlp,
    workers: usize,
    cached: bool,
) -> (String, Service, ServiceHandle) {
    let backend_mlp = scalar_mlp.clone();
    let service = Service::start_pool(
        move |_w| {
            Ok(Box::new(NativeBackend::new(backend_mlp.clone(), 3, 256)) as Box<dyn EvalBackend>)
        },
        workers,
        BatcherConfig::default(),
    );
    let handle = service.handle();
    let ops = if cached {
        OperatorServer::new(op_mlp.clone(), ParallelPolicy::Serial)
    } else {
        OperatorServer::uncached(op_mlp.clone(), ParallelPolicy::Serial)
    };
    let ops = Arc::new(ops.with_metrics(handle.metrics_handle()));
    let listener = TcpListener::bind("127.0.0.1:0").expect("binding loopback");
    let addr = listener.local_addr().expect("local addr").to_string();
    let serve_handle = handle.clone();
    std::thread::spawn(move || serve_tcp_with(listener, serve_handle, Some(ops)));
    (addr, service, handle)
}

/// What one pipelined client thread submits next.
enum NextRequest {
    Scalar(Vec<f64>, Option<ActivationKind>),
    Operator(Vec<Vec<f64>>, &'static str),
    Stats,
}

/// Drive `quota` pipelined requests over one persistent connection,
/// keeping up to `window` in flight; returns (latency histogram in
/// nanoseconds, errors).
fn drive_connection(
    addr: &str,
    quota: usize,
    window: usize,
    mut gen: impl FnMut(&mut Prng) -> NextRequest,
    seed: u64,
) -> (Histogram, usize) {
    let latencies = Histogram::new();
    let mut rng = Prng::seeded(seed);
    let mut client = match TcpClient::connect(addr) {
        Ok(c) => c,
        Err(_) => return (latencies, quota),
    };
    let mut errors = 0usize;
    let mut inflight: VecDeque<Instant> = VecDeque::with_capacity(window);
    let mut submitted = 0usize;
    let mut done = 0usize;
    while done < quota {
        if submitted < quota && inflight.len() < window {
            let sent = match gen(&mut rng) {
                NextRequest::Scalar(points, act) => client.submit_eval(&points, act),
                NextRequest::Operator(points, op) => client.submit_operator(&points, op, None),
                NextRequest::Stats => client.submit_raw("{\"cmd\":\"stats\"}"),
            };
            if sent.is_err() {
                errors += quota - done;
                break;
            }
            inflight.push_back(Instant::now());
            submitted += 1;
            continue;
        }
        match client.recv_raw() {
            Ok(payload) => {
                let t0 = inflight.pop_front().expect("response without a request");
                latencies.record(ns_since(t0));
                if protocol::parse_error(&payload).is_some() {
                    errors += 1;
                }
                done += 1;
            }
            Err(_) => {
                errors += quota - done;
                break;
            }
        }
    }
    (latencies, errors)
}

/// Run one pipelined leg: `requests` split across `connections`
/// threads, each generated by `gen` (a fresh closure per thread).
fn run_pipelined_leg(
    leg: &'static str,
    addr: &str,
    handle: &ServiceHandle,
    requests: usize,
    connections: usize,
    window: usize,
    seed: u64,
    gen: impl Fn(usize) -> Box<dyn FnMut(&mut Prng) -> NextRequest + Send> + Sync,
) -> ServeCell {
    let before = handle.metrics();
    let t0 = Instant::now();
    let mut threads = Vec::new();
    for c in 0..connections {
        let quota = requests / connections + usize::from(c < requests % connections);
        let addr = addr.to_string();
        let mut g = gen(c);
        threads.push(std::thread::spawn(move || {
            drive_connection(&addr, quota, window, &mut g, seed + 1000 + c as u64)
        }));
    }
    let latencies = Histogram::new();
    let mut errors = 0usize;
    for th in threads {
        let (l, e) = th.join().expect("client thread panicked");
        l.merge_into(&latencies);
        errors += e;
    }
    let elapsed_s = t0.elapsed().as_secs_f64();
    let after = handle.metrics();
    let (p50_us, p95_us, p99_us) = quantiles_us(&latencies);
    ServeCell {
        leg,
        requests,
        connections,
        window,
        elapsed_s,
        p50_us,
        p95_us,
        p99_us,
        errors,
        shed: after.shed - before.shed,
        plan_hits: after.plan_hits - before.plan_hits,
        plan_misses: after.plan_misses - before.plan_misses,
    }
}

/// Run the three legs and return one [`ServeCell`] per leg.
pub fn run(cfg: &ServeBenchConfig, progress: impl Fn(&str)) -> Vec<ServeCell> {
    let mut rng = Prng::seeded(cfg.seed);
    let scalar_mlp = Mlp::uniform(1, cfg.width, cfg.depth, 1, &mut rng);
    let op_mlp = Mlp::uniform(2, cfg.width, cfg.depth, 1, &mut rng);
    let mut cells = Vec::new();

    // --- Leg 1: mixed pipelined traffic -----------------------------
    progress(&format!(
        "mixed: {} requests, {} connections, window {}",
        cfg.requests, cfg.connections, cfg.window
    ));
    {
        // The mixed endpoint serves the 1-D checkpoint on both fronts
        // (scalar stacks and dim-1 operator specs), like `ntangent
        // serve` does with one checkpoint.
        let (addr, service, handle) = spawn_endpoint(&scalar_mlp, &scalar_mlp, cfg.workers, true);
        let points = cfg.points;
        cells.push(run_pipelined_leg(
            "mixed",
            &addr,
            &handle,
            cfg.requests,
            cfg.connections,
            cfg.window,
            cfg.seed,
            |_c| {
                let mut count = 0usize;
                Box::new(move |rng: &mut Prng| {
                    count += 1;
                    if count % 512 == 0 {
                        return NextRequest::Stats;
                    }
                    if rng.below(10) < 7 {
                        let pts: Vec<f64> =
                            (0..points).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
                        let act = match rng.below(5) {
                            0 => None,
                            i => Some(ActivationKind::ALL[(i - 1) as usize]),
                        };
                        NextRequest::Scalar(pts, act)
                    } else {
                        let pts: Vec<Vec<f64>> = (0..points)
                            .map(|_| vec![rng.uniform_in(-1.0, 1.0)])
                            .collect();
                        NextRequest::Operator(pts, if rng.below(2) == 0 { "d2" } else { "d3" })
                    }
                })
            },
        ));
        service.shutdown();
    }

    // --- Leg 2: cached operator pipelined ---------------------------
    progress(&format!(
        "operator_cached: {} Laplacian requests, {} connections, window {}",
        cfg.operator_requests, cfg.connections, cfg.window
    ));
    {
        let (addr, service, handle) = spawn_endpoint(&scalar_mlp, &op_mlp, 1, true);
        let points = cfg.points;
        cells.push(run_pipelined_leg(
            "operator_cached",
            &addr,
            &handle,
            cfg.operator_requests,
            cfg.connections,
            cfg.window,
            cfg.seed + 1,
            |_c| {
                Box::new(move |rng: &mut Prng| {
                    let pts: Vec<Vec<f64>> = (0..points)
                        .map(|_| vec![rng.uniform_in(-1.0, 1.0), rng.uniform_in(-1.0, 1.0)])
                        .collect();
                    NextRequest::Operator(pts, "d20+d02")
                })
            },
        ));
        service.shutdown();
    }

    // --- Leg 3: uncached one-shot baseline --------------------------
    progress(&format!(
        "operator_uncached: {} one-shot requests (fresh connection + compile each)",
        cfg.baseline_requests
    ));
    {
        let (addr, service, handle) = spawn_endpoint(&scalar_mlp, &op_mlp, 1, false);
        let before = handle.metrics();
        let latencies = Histogram::new();
        let mut errors = 0usize;
        let t0 = Instant::now();
        for _ in 0..cfg.baseline_requests {
            let pts: Vec<Vec<f64>> = (0..cfg.points)
                .map(|_| vec![rng.uniform_in(-1.0, 1.0), rng.uniform_in(-1.0, 1.0)])
                .collect();
            let r0 = Instant::now();
            match TcpClient::connect(&addr).and_then(|mut c| c.eval_operator(&pts, "d20+d02")) {
                Ok(_) => latencies.record(ns_since(r0)),
                Err(_) => errors += 1,
            }
        }
        let elapsed_s = t0.elapsed().as_secs_f64();
        let after = handle.metrics();
        let (p50_us, p95_us, p99_us) = quantiles_us(&latencies);
        cells.push(ServeCell {
            leg: "operator_uncached",
            requests: cfg.baseline_requests,
            connections: 1,
            window: 1,
            elapsed_s,
            p50_us,
            p95_us,
            p99_us,
            errors,
            shed: after.shed - before.shed,
            plan_hits: after.plan_hits - before.plan_hits,
            plan_misses: after.plan_misses - before.plan_misses,
        });
        service.shutdown();
    }

    cells
}

/// One row per leg, with the throughput and percentile columns.
pub fn table(cells: &[ServeCell]) -> Table {
    let mut t = Table::new(&[
        "leg",
        "requests",
        "connections",
        "window",
        "elapsed_s",
        "throughput_rps",
        "p50_us",
        "p95_us",
        "p99_us",
        "errors",
        "shed",
        "plan_hits",
        "plan_misses",
    ]);
    for c in cells {
        t.push(vec![
            c.leg.to_string(),
            c.requests.to_string(),
            c.connections.to_string(),
            c.window.to_string(),
            format!("{:.4}", c.elapsed_s),
            format!("{:.1}", c.throughput_rps()),
            format!("{:.1}", c.p50_us),
            format!("{:.1}", c.p95_us),
            format!("{:.1}", c.p99_us),
            c.errors.to_string(),
            c.shed.to_string(),
            c.plan_hits.to_string(),
            c.plan_misses.to_string(),
        ]);
    }
    t
}

/// Write `serve_load.csv`.
pub fn save(cells: &[ServeCell], dir: &Path) -> std::io::Result<()> {
    table(cells).save(&dir.join("serve_load.csv"))
}

/// The `BENCH_serve.json` document: config + per-leg results + the
/// cached/uncached operator throughput ratio.
pub fn to_json(cfg: &ServeBenchConfig, cells: &[ServeCell]) -> Json {
    let results: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("leg", Json::Str(c.leg.into())),
                ("requests", Json::Num(c.requests as f64)),
                ("connections", Json::Num(c.connections as f64)),
                ("window", Json::Num(c.window as f64)),
                ("elapsed_s", Json::Num(c.elapsed_s)),
                ("throughput_rps", Json::Num(c.throughput_rps())),
                ("p50_us", Json::Num(c.p50_us)),
                ("p95_us", Json::Num(c.p95_us)),
                ("p99_us", Json::Num(c.p99_us)),
                ("errors", Json::Num(c.errors as f64)),
                ("shed", Json::Num(c.shed as f64)),
                ("plan_hits", Json::Num(c.plan_hits as f64)),
                ("plan_misses", Json::Num(c.plan_misses as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", Json::Str("serve".into())),
        (
            "config",
            Json::obj(vec![
                ("requests", Json::Num(cfg.requests as f64)),
                ("connections", Json::Num(cfg.connections as f64)),
                ("window", Json::Num(cfg.window as f64)),
                ("points", Json::Num(cfg.points as f64)),
                ("operator_requests", Json::Num(cfg.operator_requests as f64)),
                ("baseline_requests", Json::Num(cfg.baseline_requests as f64)),
                ("width", Json::Num(cfg.width as f64)),
                ("depth", Json::Num(cfg.depth as f64)),
                ("workers", Json::Num(cfg.workers as f64)),
            ]),
        ),
        ("results", Json::Arr(results)),
        (
            "operator_speedup",
            Json::Num(operator_speedup(cells).unwrap_or(0.0)),
        ),
    ])
}

/// Write the `BENCH_serve.json` document to `path`.
pub fn save_json(cfg: &ServeBenchConfig, cells: &[ServeCell], path: &Path) -> std::io::Result<()> {
    std::fs::write(path, to_json(cfg, cells).dump() + "\n")
}

/// Human-readable summary for the CLI.
pub fn summarize(cells: &[ServeCell]) -> String {
    let mut out = String::from("serving load (closed-loop TCP loopback)\n");
    for c in cells {
        out.push_str(&format!(
            "  {:<18} {:>7} req  {:>9.1} req/s  p50 {:>8.1} µs  p95 {:>8.1} µs  \
             p99 {:>8.1} µs  errors {} shed {}\n",
            c.leg,
            c.requests,
            c.throughput_rps(),
            c.p50_us,
            c.p95_us,
            c.p99_us,
            c.errors,
            c.shed
        ));
    }
    if let Some(s) = operator_speedup(cells) {
        out.push_str(&format!(
            "  operator cache+pipelining speedup over one-shot uncached: {s:.1}x\n"
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_serve_bench_produces_csv_and_json() {
        let cfg = ServeBenchConfig {
            requests: 60,
            connections: 2,
            window: 8,
            points: 3,
            operator_requests: 16,
            baseline_requests: 4,
            width: 6,
            depth: 2,
            workers: 1,
            ..ServeBenchConfig::default()
        };
        let cells = run(&cfg, |_| {});
        assert_eq!(cells.len(), 3);
        for c in &cells {
            assert_eq!(c.errors, 0, "leg {} had errors", c.leg);
            assert!(c.elapsed_s > 0.0 && c.throughput_rps() > 0.0, "leg {}", c.leg);
            assert!(c.p50_us <= c.p95_us && c.p95_us <= c.p99_us, "leg {}", c.leg);
        }
        assert!(operator_speedup(&cells).unwrap() > 0.0);
        // The cached leg compiles at most once per (operator, engine);
        // later requests hit.
        let cached = cells.iter().find(|c| c.leg == "operator_cached").unwrap();
        assert!(cached.plan_hits > cached.plan_misses);
        let t = table(&cells);
        assert_eq!(t.rows.len(), 3);
        let dir = std::env::temp_dir().join("ntangent_test_serve_bench");
        std::fs::create_dir_all(&dir).unwrap();
        save(&cells, &dir).unwrap();
        assert!(dir.join("serve_load.csv").exists());
        let jpath = dir.join("BENCH_serve.json");
        save_json(&cfg, &cells, &jpath).unwrap();
        let doc = Json::parse(std::fs::read_to_string(&jpath).unwrap().trim()).unwrap();
        assert_eq!(doc.get("bench").and_then(Json::as_str), Some("serve"));
        assert_eq!(
            doc.get("results").and_then(Json::as_arr).map(<[Json]>::len),
            Some(3)
        );
        assert!(doc.get("operator_speedup").and_then(Json::as_f64).is_some());
        assert!(summarize(&cells).contains("serving load"));
    }
}
