//! Serial-vs-parallel forward speedup grid: times `NtpEngine::forward_n`
//! under [`ParallelPolicy::Serial`] against `Fixed(t)` over a batch ×
//! thread-count grid (the CLI's `bench par` target, `parallel_speedup.csv`).
//!
//! The batch axis is the embarrassingly parallel one, so the interesting
//! regime is large `B` at moderate `n` (the serving/collocation shape).
//! Each parallel run is checked bitwise against the serial output before
//! timing — a speedup measured on wrong numbers is worthless.

use crate::nn::Mlp;
use crate::ntp::{ActivationKind, NtpEngine, ParallelPolicy};
use crate::tensor::Tensor;
use crate::util::csv::Table;
use crate::util::prng::Prng;
use crate::util::timer::time_trials;
use std::path::Path;

/// Configuration of the forward-speedup bench.
#[derive(Clone, Debug)]
pub struct ParallelBenchConfig {
    /// Hidden width.
    pub width: usize,
    /// Hidden depth.
    pub depth: usize,
    /// Hidden activation.
    pub activation: ActivationKind,
    /// Derivative order of the timed forward.
    pub n: usize,
    /// Batch sizes to sweep.
    pub batches: Vec<usize>,
    /// Worker-thread counts to compare against serial.
    pub threads: Vec<usize>,
    /// Untimed warmup trials per cell.
    pub warmup: usize,
    /// Timed trials per cell.
    pub trials: usize,
    /// PRNG seed.
    pub seed: u64,
}

impl Default for ParallelBenchConfig {
    fn default() -> Self {
        ParallelBenchConfig {
            width: 24,
            depth: 3,
            activation: ActivationKind::Tanh,
            n: 4,
            batches: vec![1024, 4096],
            threads: vec![2, 4, 8],
            warmup: 2,
            trials: 10,
            seed: 17,
        }
    }
}

/// One measured (batch, threads) cell.
#[derive(Clone, Copy, Debug)]
pub struct ParallelCell {
    /// Batch size.
    pub batch: usize,
    /// Worker threads of the parallel leg.
    pub threads: usize,
    /// Derivative order.
    pub n: usize,
    /// Mean serial seconds per forward.
    pub serial_s: f64,
    /// Mean parallel seconds per forward.
    pub parallel_s: f64,
}

impl ParallelCell {
    /// Serial time over parallel time.
    pub fn speedup(&self) -> f64 {
        self.serial_s / self.parallel_s
    }
}

/// Mean seconds per forward over the configured trials.
fn time_forward(
    engine: &NtpEngine,
    mlp: &Mlp,
    x: &Tensor,
    n: usize,
    cfg: &ParallelBenchConfig,
) -> f64 {
    let ts = time_trials(cfg.warmup, cfg.trials, || {
        std::hint::black_box(engine.forward_n(mlp, x, n));
    });
    ts.iter().sum::<f64>() / ts.len() as f64
}

/// Run the batch × thread grid (bitwise-checking each parallel output).
pub fn run(cfg: &ParallelBenchConfig, progress: impl Fn(&str)) -> Vec<ParallelCell> {
    let mut rng = Prng::seeded(cfg.seed);
    let mlp = Mlp::uniform_with(1, cfg.width, cfg.depth, 1, cfg.activation, &mut rng);
    let serial_engine = NtpEngine::new(cfg.n);
    let mut out = Vec::new();
    for &batch in &cfg.batches {
        let x = Tensor::rand_uniform(&[batch, 1], -1.0, 1.0, &mut rng);
        let want = serial_engine.forward_n(&mlp, &x, cfg.n);
        let serial_s = time_forward(&serial_engine, &mlp, &x, cfg.n, cfg);
        for &threads in &cfg.threads {
            progress(&format!("parallel cell B={batch} threads={threads}"));
            let engine = NtpEngine::with_policy(cfg.n, ParallelPolicy::Fixed(threads));
            let got = engine.forward_n(&mlp, &x, cfg.n);
            for (k, (a, b)) in want.iter().zip(&got).enumerate() {
                assert_eq!(a, b, "parallel output diverged at channel {k}");
            }
            let parallel_s = time_forward(&engine, &mlp, &x, cfg.n, cfg);
            out.push(ParallelCell {
                batch,
                threads,
                n: cfg.n,
                serial_s,
                parallel_s,
            });
        }
    }
    out
}

/// One row per cell, with the speedup column the acceptance bar reads.
pub fn table(cells: &[ParallelCell]) -> Table {
    let mut t = Table::new(&["batch", "threads", "n", "serial_s", "parallel_s", "speedup"]);
    for c in cells {
        t.push(vec![
            c.batch.to_string(),
            c.threads.to_string(),
            c.n.to_string(),
            format!("{:.6e}", c.serial_s),
            format!("{:.6e}", c.parallel_s),
            format!("{:.4}", c.speedup()),
        ]);
    }
    t
}

/// Write `parallel_speedup.csv`.
pub fn save(cells: &[ParallelCell], dir: &Path) -> std::io::Result<()> {
    table(cells).save(&dir.join("parallel_speedup.csv"))
}

/// Human-readable summary for the CLI.
pub fn summarize(cells: &[ParallelCell]) -> String {
    let mut out = String::from("serial vs parallel forward (mean seconds)\n");
    for c in cells {
        out.push_str(&format!(
            "  B={:<6} t={:<2} n={}  serial {:>10.1} µs  parallel {:>10.1} µs  speedup {:.2}x\n",
            c.batch,
            c.threads,
            c.n,
            c.serial_s * 1e6,
            c.parallel_s * 1e6,
            c.speedup()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_parallel_bench_produces_grid_and_csv() {
        let cfg = ParallelBenchConfig {
            width: 8,
            depth: 2,
            n: 3,
            batches: vec![64],
            threads: vec![2],
            warmup: 0,
            trials: 2,
            ..ParallelBenchConfig::default()
        };
        let cells = run(&cfg, |_| {});
        assert_eq!(cells.len(), 1);
        assert!(cells[0].serial_s > 0.0 && cells[0].parallel_s > 0.0);
        let t = table(&cells);
        assert_eq!(t.rows.len(), 1);
        assert!(summarize(&cells).contains("speedup"));
        let dir = std::env::temp_dir().join("ntangent_test_parallel_bench");
        std::fs::create_dir_all(&dir).unwrap();
        save(&cells, &dir).unwrap();
        assert!(dir.join("parallel_speedup.csv").exists());
    }
}
