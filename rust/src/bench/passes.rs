//! Figs 1-3: forward/backward/total pass times vs derivative order for
//! the standard 3×24 PINN network at batch 256, autodiff vs n-TangentProp.

use super::{standard_mlp, sweep_orders, Engine, Measurement};
use crate::util::csv::Table;
use std::path::Path;

/// Configuration (paper: n up to 9-10, 100 trials; CPU defaults smaller,
/// overridable from the CLI).
#[derive(Clone, Debug)]
pub struct PassesConfig {
    /// Max derivative order.
    pub n_max: usize,
    /// Untimed warmup trials per cell.
    pub warmup: usize,
    /// Timed trials per cell.
    pub trials: usize,
    /// Once an engine's measured total exceeds this, project the rest.
    pub cap_seconds: f64,
    /// PRNG seed.
    pub seed: u64,
}

impl Default for PassesConfig {
    fn default() -> Self {
        PassesConfig {
            n_max: 9,
            warmup: 1,
            trials: 5,
            cap_seconds: 3.0,
            seed: 7,
        }
    }
}

/// Run the sweep for both engines.
pub fn run(cfg: &PassesConfig) -> Vec<Measurement> {
    let (mlp, x) = standard_mlp(cfg.seed);
    let mut out = sweep_orders(
        Engine::Ntp,
        &mlp,
        &x,
        cfg.n_max,
        cfg.warmup,
        cfg.trials,
        cfg.cap_seconds,
    );
    out.extend(sweep_orders(
        Engine::Autodiff,
        &mlp,
        &x,
        cfg.n_max,
        cfg.warmup,
        cfg.trials,
        cfg.cap_seconds,
    ));
    out
}

/// Write `fig1_total.csv`, `fig2_forward.csv`, `fig3_backward.csv`.
pub fn save(measurements: &[Measurement], dir: &Path) -> std::io::Result<()> {
    for (fname, pick) in [
        ("fig1_total.csv", 0usize),
        ("fig2_forward.csv", 1),
        ("fig3_backward.csv", 2),
    ] {
        let mut t = Table::new(&["n", "engine", "seconds", "measured"]);
        for m in measurements {
            let secs = match pick {
                0 => m.times.total(),
                1 => m.times.fwd,
                _ => m.times.bwd,
            };
            t.push(vec![
                m.n.to_string(),
                m.engine.name().to_string(),
                format!("{secs:.6e}"),
                m.measured.to_string(),
            ]);
        }
        t.save(&dir.join(fname))?;
    }
    Ok(())
}

/// Markdown summary with the paper-shape checks (printed by the CLI and
/// quoted in EXPERIMENTS.md).
pub fn summarize(measurements: &[Measurement]) -> String {
    let mut t = Table::new(&["n", "ntp total (s)", "autodiff total (s)", "ratio ad/ntp", "note"]);
    let n_max = measurements.iter().map(|m| m.n).max().unwrap_or(0);
    for n in 1..=n_max {
        let ntp = measurements
            .iter()
            .find(|m| m.engine == Engine::Ntp && m.n == n);
        let ad = measurements
            .iter()
            .find(|m| m.engine == Engine::Autodiff && m.n == n);
        if let (Some(a), Some(b)) = (ntp, ad) {
            t.push(vec![
                n.to_string(),
                format!("{:.4e}", a.times.total()),
                format!("{:.4e}", b.times.total()),
                format!("{:.2}", b.times.total() / a.times.total()),
                if a.measured && b.measured {
                    String::new()
                } else {
                    "projected".into()
                },
            ]);
        }
    }
    t.to_markdown()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_produces_both_engines() {
        let cfg = PassesConfig {
            n_max: 3,
            warmup: 0,
            trials: 1,
            cap_seconds: 10.0,
            seed: 1,
        };
        let ms = run(&cfg);
        assert_eq!(ms.len(), 6);
        assert!(ms.iter().any(|m| m.engine == Engine::Ntp));
        assert!(ms.iter().any(|m| m.engine == Engine::Autodiff));
        let md = summarize(&ms);
        assert!(md.contains("ratio"));
    }

    #[test]
    fn save_writes_three_csvs() {
        let cfg = PassesConfig {
            n_max: 2,
            warmup: 0,
            trials: 1,
            cap_seconds: 10.0,
            seed: 1,
        };
        let ms = run(&cfg);
        let dir = std::env::temp_dir().join("ntangent_test_passes");
        save(&ms, &dir).unwrap();
        for f in ["fig1_total.csv", "fig2_forward.csv", "fig3_backward.csv"] {
            let text = std::fs::read_to_string(dir.join(f)).unwrap();
            assert!(text.lines().count() >= 5, "{f}");
        }
    }
}
