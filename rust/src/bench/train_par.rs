//! Serial-vs-parallel *training* speedup grid: times one full
//! loss+gradient accumulation of the sharded PINN objective
//! ([`ParallelObjective`]) under [`ParallelPolicy::Serial`] against
//! `Fixed(t)` worker pools (the CLI's `bench train-par` target,
//! `training_speedup.csv`).
//!
//! The timed quantity is `value_grad` — the per-epoch cost that both the
//! Adam phase and the L-BFGS gradient evaluations multiply into. Each
//! parallel gradient is checked **bitwise** against the serial one before
//! timing (the deterministic tree reduction makes that an equality, not a
//! tolerance, check).

use crate::nn::Mlp;
use crate::ntp::{ActivationKind, ParallelPolicy};
use crate::opt::Objective;
use crate::pinn::{BurgersLossSpec, DerivEngine, ParallelObjective};
use crate::tensor::Tensor;
use crate::util::csv::Table;
use crate::util::prng::Prng;
use crate::util::timer::time_trials;
use std::path::Path;

/// Configuration of the training-speedup bench.
#[derive(Clone, Debug)]
pub struct TrainParBenchConfig {
    /// Burgers profile `k` (sets the derivative order 2k+1).
    pub profile_k: usize,
    /// Hidden-layer width.
    pub width: usize,
    /// Number of hidden layers.
    pub depth: usize,
    /// Hidden activation.
    pub activation: ActivationKind,
    /// Residual collocation points (denser than the training default so
    /// the shard pool has enough work per thread).
    pub n_res: usize,
    /// Near-origin collocation points.
    pub n_org: usize,
    /// Collocation rows per shard.
    pub chunk: usize,
    /// Worker-thread counts to compare against serial.
    pub threads: Vec<usize>,
    /// Untimed warmup evaluations per cell.
    pub warmup: usize,
    /// Timed evaluations per cell.
    pub trials: usize,
    /// PRNG seed (network init + collocation sampling).
    pub seed: u64,
}

impl Default for TrainParBenchConfig {
    fn default() -> Self {
        TrainParBenchConfig {
            profile_k: 1,
            width: 24,
            depth: 3,
            activation: ActivationKind::Tanh,
            n_res: 512,
            n_org: 64,
            chunk: 32,
            threads: vec![2, 4, 8],
            warmup: 2,
            trials: 10,
            seed: 17,
        }
    }
}

/// One measured thread-count cell.
#[derive(Clone, Copy, Debug)]
pub struct TrainParCell {
    /// Total collocation points (residual + origin).
    pub points: usize,
    /// Shards the cloud was split into.
    pub shards: usize,
    /// Rows per shard.
    pub chunk: usize,
    /// Worker threads of the parallel leg.
    pub threads: usize,
    /// Mean seconds per serial `value_grad`.
    pub serial_s: f64,
    /// Mean seconds per parallel `value_grad`.
    pub parallel_s: f64,
}

impl TrainParCell {
    /// Serial time over parallel time.
    pub fn speedup(&self) -> f64 {
        self.serial_s / self.parallel_s
    }
}

/// Mean seconds per `value_grad` over the configured trials.
fn time_grad(obj: &mut ParallelObjective, theta: &Tensor, cfg: &TrainParBenchConfig) -> f64 {
    let ts = time_trials(cfg.warmup, cfg.trials, || {
        std::hint::black_box(obj.value_grad(theta));
    });
    ts.iter().sum::<f64>() / ts.len() as f64
}

/// Run the grid. The same objective is re-timed under each policy (the
/// shard layout is fixed at build time, so `set_policy` is purely a
/// scheduling change).
pub fn run(cfg: &TrainParBenchConfig, progress: impl Fn(&str)) -> Vec<TrainParCell> {
    let mut spec = BurgersLossSpec::for_profile(cfg.profile_k);
    spec.n_res = cfg.n_res;
    spec.n_org = cfg.n_org;
    let points = spec.n_res + spec.n_org;

    let mut rng = Prng::seeded(cfg.seed);
    let mlp = Mlp::uniform_with(1, cfg.width, cfg.depth, 1, cfg.activation, &mut rng);
    let mut obj = ParallelObjective::build(
        spec,
        &mlp,
        DerivEngine::Ntp,
        ParallelPolicy::Serial,
        cfg.chunk,
        &mut rng,
    );
    let theta = obj.theta_init(&mlp);
    let (_, want_grad) = obj.value_grad(&theta);
    let serial_s = time_grad(&mut obj, &theta, cfg);

    let mut out = Vec::new();
    for &threads in &cfg.threads {
        progress(&format!(
            "train-par cell shards={} threads={threads}",
            obj.n_shards()
        ));
        obj.set_policy(ParallelPolicy::Fixed(threads));
        let (_, got_grad) = obj.value_grad(&theta);
        assert_eq!(
            want_grad, got_grad,
            "parallel gradient diverged at t={threads} — determinism broken"
        );
        let parallel_s = time_grad(&mut obj, &theta, cfg);
        out.push(TrainParCell {
            points,
            shards: obj.n_shards(),
            chunk: cfg.chunk,
            threads,
            serial_s,
            parallel_s,
        });
    }
    obj.set_policy(ParallelPolicy::Serial);
    out
}

/// One row per cell, with the speedup column the acceptance bar reads.
pub fn table(cells: &[TrainParCell]) -> Table {
    let mut t = Table::new(&[
        "points", "shards", "chunk", "threads", "serial_s", "parallel_s", "speedup",
    ]);
    for c in cells {
        t.push(vec![
            c.points.to_string(),
            c.shards.to_string(),
            c.chunk.to_string(),
            c.threads.to_string(),
            format!("{:.6e}", c.serial_s),
            format!("{:.6e}", c.parallel_s),
            format!("{:.4}", c.speedup()),
        ]);
    }
    t
}

/// Write `training_speedup.csv`.
pub fn save(cells: &[TrainParCell], dir: &Path) -> std::io::Result<()> {
    table(cells).save(&dir.join("training_speedup.csv"))
}

/// Human-readable summary for the CLI.
pub fn summarize(cells: &[TrainParCell]) -> String {
    let mut out = String::from("serial vs parallel training step (mean seconds per value+grad)\n");
    for c in cells {
        out.push_str(&format!(
            "  pts={:<5} shards={:<3} t={:<2}  serial {:>9.2} ms  parallel {:>9.2} ms  \
             speedup {:.2}x\n",
            c.points,
            c.shards,
            c.threads,
            c.serial_s * 1e3,
            c.parallel_s * 1e3,
            c.speedup()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_train_par_bench_produces_grid_and_csv() {
        let cfg = TrainParBenchConfig {
            width: 8,
            depth: 2,
            n_res: 48,
            n_org: 8,
            chunk: 16,
            threads: vec![2],
            warmup: 0,
            trials: 2,
            ..TrainParBenchConfig::default()
        };
        let cells = run(&cfg, |_| {});
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].shards, 3);
        assert!(cells[0].serial_s > 0.0 && cells[0].parallel_s > 0.0);
        assert_eq!(table(&cells).rows.len(), 1);
        assert!(summarize(&cells).contains("speedup"));
        let dir = std::env::temp_dir().join("ntangent_test_train_par_bench");
        std::fs::create_dir_all(&dir).unwrap();
        save(&cells, &dir).unwrap();
        assert!(dir.join("training_speedup.csv").exists());
    }
}
