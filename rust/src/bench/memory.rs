//! Memory scaling vs derivative order (§IV-B: autodiff exhausted the
//! paper's 49 GB GPU beyond nine derivatives; n-TangentProp is `O(nM)`).
//!
//! Backend-independent metrics: tape node count and bytes allocated while
//! building + evaluating the derivative channels, per engine and order.

use super::{Engine, standard_mlp};
use crate::autodiff::{higher, Graph};
use crate::nn::Mlp;
use crate::ntp::NtpEngine;
use crate::tensor::{alloc, Tensor};
use crate::util::csv::Table;
use std::path::Path;

/// Configuration of the memory-scaling sweep.
#[derive(Clone, Debug)]
pub struct MemoryConfig {
    /// Max derivative order.
    pub n_max: usize,
    /// Skip autodiff cells whose predicted allocation exceeds this many
    /// bytes (the "OOM" point on this host).
    pub byte_cap: u64,
    /// PRNG seed.
    pub seed: u64,
    /// Batch size of the measured forward.
    pub batch: usize,
}

impl Default for MemoryConfig {
    fn default() -> Self {
        MemoryConfig {
            n_max: 10,
            byte_cap: 4 << 30, // 4 GiB
            seed: 13,
            batch: 256,
        }
    }
}

/// One engine × order memory measurement.
#[derive(Clone, Debug)]
pub struct MemoryCell {
    /// Engine measured.
    pub engine: Engine,
    /// Derivative order.
    pub n: usize,
    /// Graph nodes built (tape-size metric).
    pub graph_nodes: usize,
    /// Peak accounted allocation in bytes.
    pub bytes: u64,
    /// False when the cell was projected past the byte cap.
    pub measured: bool,
}

fn measure_cell(engine: Engine, mlp: &Mlp, x: &Tensor, n: usize) -> MemoryCell {
    alloc::reset();
    let mut g = Graph::new();
    let (channels, inputs) = match engine {
        Engine::Ntp => {
            let xn = g.constant(x.clone());
            let pn = mlp.const_param_nodes(&mut g);
            let eng = NtpEngine::new(n);
            (eng.forward_graph(&mut g, mlp, xn, &pn, n), vec![])
        }
        Engine::Autodiff => {
            let xi = g.input(x.shape());
            let pn = mlp.const_param_nodes(&mut g);
            let u = mlp.forward_graph(&mut g, xi, &pn);
            (higher::derivative_stack(&mut g, u, xi, n), vec![x.clone()])
        }
    };
    let vals = g.eval(&inputs, &channels);
    std::hint::black_box(vals.get(channels[n]).data());
    MemoryCell {
        engine,
        n,
        graph_nodes: g.len(),
        bytes: alloc::stats().total,
        measured: true,
    }
}

/// Run the memory sweep for both engines.
pub fn run(cfg: &MemoryConfig) -> Vec<MemoryCell> {
    let (mlp, _) = standard_mlp(cfg.seed);
    let mut rng = crate::util::prng::Prng::seeded(cfg.seed + 1);
    let x = Tensor::rand_uniform(&[cfg.batch, 1], -1.0, 1.0, &mut rng);
    let mut out = Vec::new();
    for engine in [Engine::Ntp, Engine::Autodiff] {
        let mut last_bytes = 0u64;
        let mut growth = 2.0f64;
        for n in 1..=cfg.n_max {
            let projected = (last_bytes as f64 * growth) as u64;
            if engine == Engine::Autodiff && last_bytes > 0 && projected > cfg.byte_cap {
                // Project instead of measuring: this is the OOM region.
                out.push(MemoryCell {
                    engine,
                    n,
                    graph_nodes: 0,
                    bytes: projected,
                    measured: false,
                });
                last_bytes = projected;
                continue;
            }
            let cell = measure_cell(engine, &mlp, &x, n);
            if last_bytes > 0 {
                growth = cell.bytes as f64 / last_bytes as f64;
            }
            last_bytes = cell.bytes;
            out.push(cell);
        }
    }
    out
}

/// Write `mem_scaling.csv`.
pub fn save(cells: &[MemoryCell], path: &Path) -> std::io::Result<()> {
    let mut t = Table::new(&["n", "engine", "graph_nodes", "bytes", "measured"]);
    for c in cells {
        t.push(vec![
            c.n.to_string(),
            c.engine.name().to_string(),
            c.graph_nodes.to_string(),
            c.bytes.to_string(),
            c.measured.to_string(),
        ]);
    }
    t.save(path)
}

/// Human-readable summary for the CLI.
pub fn summarize(cells: &[MemoryCell]) -> String {
    let mut t = Table::new(&["n", "ntp bytes", "autodiff bytes", "ratio", "note"]);
    let n_max = cells.iter().map(|c| c.n).max().unwrap_or(0);
    for n in 1..=n_max {
        let ntp = cells.iter().find(|c| c.engine == Engine::Ntp && c.n == n);
        let ad = cells.iter().find(|c| c.engine == Engine::Autodiff && c.n == n);
        if let (Some(a), Some(b)) = (ntp, ad) {
            t.push(vec![
                n.to_string(),
                a.bytes.to_string(),
                b.bytes.to_string(),
                format!("{:.1}", b.bytes as f64 / a.bytes as f64),
                if b.measured { String::new() } else { "projected (OOM region)".into() },
            ]);
        }
    }
    t.to_markdown()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ntp_memory_is_subexponential_autodiff_is_not() {
        let cfg = MemoryConfig {
            n_max: 6,
            byte_cap: 1 << 30,
            seed: 1,
            batch: 32,
        };
        let cells = run(&cfg);
        let pick = |e: Engine| -> Vec<f64> {
            (1..=6)
                .map(|n| {
                    cells
                        .iter()
                        .find(|c| c.engine == e && c.n == n)
                        .unwrap()
                        .bytes as f64
                })
                .collect()
        };
        let ntp = pick(Engine::Ntp);
        let ad = pick(Engine::Autodiff);
        let ntp_ratio = ntp[5] / ntp[4];
        let ad_ratio = ad[5] / ad[4];
        assert!(
            ntp_ratio < 1.8 && ad_ratio > 1.9,
            "ntp {ntp:?} (r={ntp_ratio}), ad {ad:?} (r={ad_ratio})"
        );
    }

    #[test]
    fn byte_cap_triggers_projection() {
        let cfg = MemoryConfig {
            n_max: 8,
            byte_cap: 1 << 20, // 1 MiB: autodiff blows through this fast
            seed: 1,
            batch: 64,
        };
        let cells = run(&cfg);
        assert!(cells
            .iter()
            .any(|c| c.engine == Engine::Autodiff && !c.measured));
        // Projections keep growing.
        let ad: Vec<&MemoryCell> = cells.iter().filter(|c| c.engine == Engine::Autodiff).collect();
        for w in ad.windows(2) {
            assert!(w[1].bytes >= w[0].bytes);
        }
    }
}
