//! Figs 7-10: train profiles k = 1..4 with n-TangentProp and compare the
//! learned solution (and its derivatives) against the exact profile.
//!
//! The paper's Figs 8, 9, 7, 10 correspond to k = 1, 2, 3, 4; each plots
//! the learned `u^(j)` (j = 0..=k) against the truth plus the loss and λ
//! histories. We emit one curves CSV and one history CSV per profile.

use crate::nn::Mlp;
use crate::ntp::ParallelPolicy;
use crate::pinn::{
    eval_channels, grid_points, train_burgers, train_burgers_sharded, BurgersLossSpec,
    DerivEngine, ParallelObjective, ResilienceConfig, TrainConfig, TrainResult,
};
use crate::util::csv::Table;
use crate::util::prng::Prng;
use std::path::Path;

/// Configuration of one Burgers-profile reproduction run (figs 7-10).
#[derive(Clone, Debug)]
pub struct ProfilesConfig {
    /// Burgers profile index.
    pub k: usize,
    /// Trainer configuration.
    pub train: TrainConfig,
    /// Optional loss-spec override (defaults to the profile's spec).
    pub spec_overrides: Option<BurgersLossSpec>,
    /// Number of plot points for the curve comparison.
    pub n_plot: usize,
    /// Highest derivative order to export (defaults to k, as plotted).
    pub order_max: Option<usize>,
    /// Batch-parallelism for the post-training curve evaluation (the
    /// plot grid is a dense collocation cloud; output is policy-invariant).
    pub parallel: ParallelPolicy,
}

impl ProfilesConfig {
    /// Paper-flavored defaults for profile `k`.
    pub fn for_profile(k: usize) -> ProfilesConfig {
        ProfilesConfig {
            k,
            train: TrainConfig::default(),
            spec_overrides: None,
            n_plot: 201,
            order_max: None,
            parallel: ParallelPolicy::Serial,
        }
    }
}

/// A finished profile run: the training result plus exported curves.
pub struct ProfileRun {
    /// The training result.
    pub result: TrainResult,
    /// Curve table (x, truth, prediction per order).
    pub curves: Table,
    /// RMS error per derivative order 0..=order_max.
    pub rms_errors: Vec<f64>,
}

/// Train the profile and export its comparison curves.
pub fn run(cfg: &ProfilesConfig) -> ProfileRun {
    let spec = cfg
        .spec_overrides
        .clone()
        .unwrap_or_else(|| BurgersLossSpec::for_profile(cfg.k));
    let x_max = spec.x_max;
    let result = train_burgers(spec, &cfg.train, DerivEngine::Ntp);
    export_run(cfg, x_max, result)
}

/// Shard-pool identity for [`run_sweep`]: two runs reuse one pool iff
/// the loss spec, network geometry, init/collocation seed and shard
/// chunking all match. Schedule knobs (epochs, learning rate, thread
/// policy) are free to differ — they never touch the tapes.
fn build_key(spec: &BurgersLossSpec, train: &TrainConfig) -> String {
    format!(
        "{spec:?}|{}x{}|{}|seed{}|chunk{}",
        train.depth,
        train.width,
        train.activation.name(),
        train.seed,
        train.chunk
    )
}

/// Train several profile configs as one sweep, reusing the shard pool
/// (the [`ParallelObjective`]'s per-chunk compiled tapes) across
/// consecutive runs with the same problem build instead of rebuilding
/// it per run — the ROADMAP's carried sweep debt. Reuse is bitwise
/// invisible: the pool is rebuilt whenever the build key changes, and a
/// policy change is pure scheduling, so every run matches a fresh
/// [`crate::pinn::train_burgers_parallel`] of the same config bit for
/// bit.
pub fn run_sweep(cfgs: &[ProfilesConfig], mut progress: impl FnMut(&str)) -> Vec<ProfileRun> {
    let mut out = Vec::with_capacity(cfgs.len());
    let mut pool: Option<(String, ParallelObjective, Mlp)> = None;
    for cfg in cfgs {
        let spec = cfg
            .spec_overrides
            .clone()
            .unwrap_or_else(|| BurgersLossSpec::for_profile(cfg.k));
        let x_max = spec.x_max;
        let key = build_key(&spec, &cfg.train);
        let (obj, mlp) = match pool.take() {
            Some((have, obj, mlp)) if have == key => {
                progress(&format!(
                    "profile k={}: reusing the shard pool ({} tapes)",
                    cfg.k,
                    obj.n_shards()
                ));
                (obj, mlp)
            }
            _ => {
                let mut rng = Prng::seeded(cfg.train.seed);
                let mlp = Mlp::uniform_with(
                    1,
                    cfg.train.width,
                    cfg.train.depth,
                    1,
                    cfg.train.activation,
                    &mut rng,
                );
                let obj = ParallelObjective::build(
                    spec,
                    &mlp,
                    DerivEngine::Ntp,
                    cfg.train.policy,
                    cfg.train.chunk,
                    &mut rng,
                );
                progress(&format!(
                    "profile k={}: built {} shard tapes",
                    cfg.k,
                    obj.n_shards()
                ));
                (obj, mlp)
            }
        };
        let (result, obj) = train_burgers_sharded(
            obj,
            &mlp,
            &cfg.train,
            &ResilienceConfig::default(),
            None,
        );
        pool = Some((key, obj, mlp));
        out.push(export_run(cfg, x_max, result));
    }
    out
}

/// Save the sweep comparison table (`profiles_sweep.csv`): one row per
/// run with its label (e.g. the thread count swept by `bench profiles`).
pub fn save_sweep(runs: &[ProfileRun], labels: &[String], dir: &Path) -> std::io::Result<()> {
    let mut t = Table::new(&["run", "lambda", "final_loss", "seconds"]);
    for (r, label) in runs.iter().zip(labels) {
        t.push(vec![
            label.clone(),
            format!("{:.8}", r.result.lambda),
            format!("{:.6e}", r.result.final_loss),
            format!("{:.3}", r.result.seconds),
        ]);
    }
    t.save(&dir.join("profiles_sweep.csv"))
}

/// Evaluate the learned curves against the truth and package the run.
fn export_run(cfg: &ProfilesConfig, x_max: f64, result: TrainResult) -> ProfileRun {
    let order_max = cfg.order_max.unwrap_or(cfg.k);
    let xs = grid_points(-x_max, x_max, cfg.n_plot);
    let learned = eval_channels(&result.mlp, &xs, order_max, cfg.parallel);

    let mut header = vec!["x".to_string()];
    for j in 0..=order_max {
        header.push(format!("learned_d{j}"));
        header.push(format!("true_d{j}"));
    }
    let mut curves = Table::new(&header.iter().map(String::as_str).collect::<Vec<_>>());
    let mut sq_err = vec![0.0; order_max + 1];
    for (i, &x) in xs.data().iter().enumerate() {
        let truth = result.profile.derivatives_true(x, order_max);
        let mut row = vec![format!("{x:.6}")];
        for j in 0..=order_max {
            let l = learned[j].data()[i];
            row.push(format!("{l:.8e}"));
            row.push(format!("{:.8e}", truth[j]));
            sq_err[j] += (l - truth[j]).powi(2);
        }
        curves.push(row);
    }
    let rms_errors = sq_err
        .iter()
        .map(|s| (s / cfg.n_plot as f64).sqrt())
        .collect();

    ProfileRun {
        result,
        curves,
        rms_errors,
    }
}

/// Save `fig{N}_profile{k}_curves.csv` + `..._history.csv`.
pub fn save(run: &ProfileRun, k: usize, dir: &Path) -> std::io::Result<()> {
    // Paper figure numbering: k=1 → Fig 8, k=2 → Fig 9, k=3 → Fig 7, k=4 → Fig 10.
    let fig = match k {
        1 => 8,
        2 => 9,
        3 => 7,
        _ => 10,
    };
    run.curves
        .save(&dir.join(format!("fig{fig}_profile{k}_curves.csv")))?;
    let mut hist = Table::new(&["epoch", "phase", "loss", "lambda", "elapsed"]);
    for log in &run.result.logs {
        hist.push(vec![
            log.epoch.to_string(),
            log.phase.to_string(),
            format!("{:.6e}", log.loss),
            format!("{:.8}", log.lambda),
            format!("{:.4}", log.elapsed),
        ]);
    }
    hist.save(&dir.join(format!("fig{fig}_profile{k}_history.csv")))
}

/// Human-readable summary for the CLI.
pub fn summarize(run: &ProfileRun) -> String {
    let k = run.result.profile.k;
    let mut out = format!(
        "profile k={k}: λ = {:.6} (target {:.6}, err {:.2e}), final loss {:.3e}, {:.1}s\n",
        run.result.lambda,
        run.result.profile.lambda_smooth(),
        run.result.lambda_error(),
        run.result.final_loss,
        run.result.seconds
    );
    for (j, rms) in run.rms_errors.iter().enumerate() {
        out.push_str(&format!("  RMS error u^({j}): {rms:.3e}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_profile_run_exports_curves() {
        let mut spec = BurgersLossSpec::for_profile(1);
        spec.n_res = 32;
        spec.n_org = 8;
        let cfg = ProfilesConfig {
            k: 1,
            train: TrainConfig {
                width: 10,
                depth: 2,
                adam_epochs: 60,
                lbfgs_epochs: 40,
                adam_lr: 2e-3,
                seed: 5,
                log_every: 10,
                ..TrainConfig::default()
            },
            spec_overrides: Some(spec),
            n_plot: 21,
            order_max: Some(1),
            parallel: ParallelPolicy::Fixed(2),
        };
        let pr = run(&cfg);
        assert_eq!(pr.curves.rows.len(), 21);
        assert_eq!(pr.rms_errors.len(), 2);
        // Order-0 error should beat the trivial zero predictor by a lot.
        assert!(pr.rms_errors[0] < 0.5, "rms {:?}", pr.rms_errors);
        let dir = std::env::temp_dir().join("ntangent_test_profiles");
        std::fs::create_dir_all(&dir).unwrap();
        save(&pr, 1, &dir).unwrap();
        assert!(dir.join("fig8_profile1_curves.csv").exists());
        assert!(dir.join("fig8_profile1_history.csv").exists());
        assert!(summarize(&pr).contains("RMS"));
    }

    /// The carried-debt fix: a sweep over schedule knobs reuses one
    /// shard pool, and the reuse is bitwise invisible — every swept run
    /// matches a fresh `train_burgers_parallel` of the same config.
    #[test]
    fn sweep_reuses_pool_and_stays_bitwise_identical() {
        let mut spec = BurgersLossSpec::for_profile(1);
        spec.n_res = 24;
        spec.n_org = 8;
        let base = TrainConfig {
            width: 8,
            depth: 2,
            adam_epochs: 20,
            lbfgs_epochs: 10,
            adam_lr: 2e-3,
            seed: 6,
            log_every: 5,
            chunk: 8,
            ..TrainConfig::default()
        };
        let mk = |policy| ProfilesConfig {
            k: 1,
            train: TrainConfig { policy, ..base.clone() },
            spec_overrides: Some(spec.clone()),
            n_plot: 11,
            order_max: Some(1),
            parallel: ParallelPolicy::Serial,
        };
        let cfgs = [mk(ParallelPolicy::Serial), mk(ParallelPolicy::Fixed(2))];
        let mut msgs: Vec<String> = Vec::new();
        let runs = run_sweep(&cfgs, |m| msgs.push(m.to_string()));
        assert_eq!(runs.len(), 2);
        assert_eq!(
            msgs.iter().filter(|m| m.contains("built")).count(),
            1,
            "second run must reuse the pool: {msgs:?}"
        );
        assert_eq!(msgs.iter().filter(|m| m.contains("reusing")).count(), 1);
        // Thread-policy invariance holds across the reuse boundary.
        assert_eq!(
            runs[0].result.final_loss.to_bits(),
            runs[1].result.final_loss.to_bits()
        );
        assert_eq!(runs[0].result.lambda.to_bits(), runs[1].result.lambda.to_bits());
        // And each swept run matches a fresh sharded build bit for bit.
        let fresh =
            crate::pinn::train_burgers_parallel(spec.clone(), &cfgs[1].train, DerivEngine::Ntp);
        assert_eq!(runs[1].result.final_loss.to_bits(), fresh.final_loss.to_bits());
        assert_eq!(runs[1].result.lambda.to_bits(), fresh.lambda.to_bits());
        assert!(runs[1].result.n_backward > 0, "per-run counters must be baselined");
        assert_eq!(runs[1].result.n_backward, fresh.n_backward);

        let dir = std::env::temp_dir().join("ntangent_test_profiles_sweep");
        std::fs::create_dir_all(&dir).unwrap();
        save_sweep(&runs, &["serial".into(), "fixed2".into()], &dir).unwrap();
        assert!(dir.join("profiles_sweep.csv").exists());
    }
}
