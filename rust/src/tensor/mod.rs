//! A small dense `f64` tensor engine — the compute substrate for the
//! autodiff tape and the n-TangentProp engine.
//!
//! Row-major, rank ≤ 2 in practice (PINN batches are `[B, F]`). Every
//! allocation is accounted (see [`alloc`]) so the benchmark harness can
//! report the memory-vs-derivative-order curves the paper discusses
//! (autodiff OOMs beyond 9 derivatives on a 49 GB GPU; n-TangentProp is
//! linear in `n`).

pub mod alloc;
pub mod linalg;
pub mod ops;

use crate::util::prng::Prng;

/// A dense row-major `f64` tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f64>,
}

impl Tensor {
    // ------------------------------------------------------------ creation

    /// Build from raw data; panics if `data.len() != product(shape)`.
    pub fn from_vec(data: Vec<f64>, shape: &[usize]) -> Tensor {
        let numel: usize = shape.iter().product();
        assert_eq!(
            data.len(),
            numel,
            "from_vec: data length {} != shape {:?} numel {}",
            data.len(),
            shape,
            numel
        );
        alloc::record(numel);
        Tensor { shape: shape.to_vec(), data }
    }

    /// A `[1]` tensor holding `x`.
    pub fn scalar(x: f64) -> Tensor {
        Tensor::from_vec(vec![x], &[1])
    }

    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        let numel: usize = shape.iter().product();
        alloc::record(numel);
        Tensor { shape: shape.to_vec(), data: vec![0.0; numel] }
    }

    /// All-ones tensor.
    pub fn ones(shape: &[usize]) -> Tensor {
        Tensor::full(shape, 1.0)
    }

    /// Tensor filled with `value`.
    pub fn full(shape: &[usize], value: f64) -> Tensor {
        let numel: usize = shape.iter().product();
        alloc::record(numel);
        Tensor { shape: shape.to_vec(), data: vec![value; numel] }
    }

    /// `n` evenly spaced points including both endpoints; shape `[n]`.
    pub fn linspace(lo: f64, hi: f64, n: usize) -> Tensor {
        assert!(n >= 2, "linspace needs n >= 2");
        let step = (hi - lo) / (n - 1) as f64;
        Tensor::from_vec((0..n).map(|i| lo + step * i as f64).collect(), &[n])
    }

    /// Uniform random entries on `[lo, hi)`.
    pub fn rand_uniform(shape: &[usize], lo: f64, hi: f64, rng: &mut Prng) -> Tensor {
        let numel: usize = shape.iter().product();
        Tensor::from_vec(rng.uniform_vec(numel, lo, hi), shape)
    }

    /// Normal random entries.
    pub fn rand_normal(shape: &[usize], mean: f64, std: f64, rng: &mut Prng) -> Tensor {
        let numel: usize = shape.iter().product();
        Tensor::from_vec(rng.normal_vec(numel, mean, std), shape)
    }

    // ------------------------------------------------------------- queries

    /// The shape.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// The elements, row-major.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable access to the elements, row-major.
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the raw element vector.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// The single element of a `[1]`/scalar tensor.
    pub fn item(&self) -> f64 {
        assert_eq!(self.numel(), 1, "item() on non-scalar of shape {:?}", self.shape);
        self.data[0]
    }

    /// 2-D element accessor.
    pub fn at(&self, i: usize, j: usize) -> f64 {
        assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// 2-D element setter.
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        assert_eq!(self.rank(), 2);
        let cols = self.shape[1];
        self.data[i * cols + j] = v;
    }

    // ------------------------------------------------------------ reshape

    /// Reinterpret the data with a new shape of equal numel.
    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        let numel: usize = shape.iter().product();
        assert_eq!(self.numel(), numel, "reshape {:?} -> {:?}", self.shape, shape);
        Tensor { shape: shape.to_vec(), data: self.data.clone() }
    }

    /// Row `i` of a 2-D tensor as a fresh `[cols]` tensor.
    pub fn row(&self, i: usize) -> Tensor {
        assert_eq!(self.rank(), 2);
        let cols = self.shape[1];
        Tensor::from_vec(self.data[i * cols..(i + 1) * cols].to_vec(), &[cols])
    }

    /// Stack `[rows]`-shaped tensors into `[k, rows]`.
    pub fn stack_rows(rows: &[&Tensor]) -> Tensor {
        assert!(!rows.is_empty());
        let cols = rows[0].numel();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            assert_eq!(r.numel(), cols, "stack_rows: ragged input");
            data.extend_from_slice(r.data());
        }
        Tensor::from_vec(data, &[rows.len(), cols])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creation_and_queries() {
        let t = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.at(1, 2), 6.0);
        assert_eq!(t.row(1).data(), &[4.0, 5.0, 6.0]);
    }

    #[test]
    #[should_panic(expected = "from_vec")]
    fn bad_shape_panics() {
        Tensor::from_vec(vec![1.0], &[2, 2]);
    }

    #[test]
    fn linspace_endpoints() {
        let t = Tensor::linspace(-1.0, 1.0, 5);
        assert_eq!(t.data(), &[-1.0, -0.5, 0.0, 0.5, 1.0]);
    }

    #[test]
    fn reshape_roundtrip() {
        let t = Tensor::linspace(0.0, 5.0, 6).reshape(&[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.reshape(&[6]).shape(), &[6]);
    }

    #[test]
    fn set_and_at() {
        let mut t = Tensor::zeros(&[2, 2]);
        t.set(0, 1, 3.5);
        assert_eq!(t.at(0, 1), 3.5);
    }

    #[test]
    fn stack_rows_shapes() {
        let a = Tensor::from_vec(vec![1.0, 2.0], &[2]);
        let b = Tensor::from_vec(vec![3.0, 4.0], &[2]);
        let s = Tensor::stack_rows(&[&a, &b]);
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.data(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn random_tensors_in_bounds() {
        let mut rng = Prng::seeded(1);
        let t = Tensor::rand_uniform(&[100], -2.0, 2.0, &mut rng);
        assert!(t.data().iter().all(|x| (-2.0..2.0).contains(x)));
    }
}
