//! Allocation accounting for the memory-scaling experiments.
//!
//! The paper reports that repeated autodifferentiation exhausted the
//! 49 GB of an A6000 beyond nine derivatives while n-TangentProp's memory
//! is linear in `n`. We reproduce that curve by counting every `f64`
//! allocated through the tensor constructors (thread-local, zero overhead
//! when not inspected).

use std::cell::Cell;

thread_local! {
    static LIVE: Cell<u64> = const { Cell::new(0) };
    static TOTAL: Cell<u64> = const { Cell::new(0) };
    static PEAK: Cell<u64> = const { Cell::new(0) };
}

/// Record a tensor allocation of `numel` elements.
#[inline]
pub fn record(numel: usize) {
    let bytes = (numel * std::mem::size_of::<f64>()) as u64;
    TOTAL.with(|t| t.set(t.get() + bytes));
    LIVE.with(|l| {
        let now = l.get() + bytes;
        l.set(now);
        PEAK.with(|p| {
            if now > p.get() {
                p.set(now);
            }
        });
    });
}

/// Record a tensor drop. (Only the scopes that care call this; `live` is
/// approximate, `total` is exact.)
#[inline]
pub fn release(numel: usize) {
    let bytes = (numel * std::mem::size_of::<f64>()) as u64;
    LIVE.with(|l| l.set(l.get().saturating_sub(bytes)));
}

/// Snapshot of the counters, in bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AllocStats {
    /// Total bytes ever allocated on this thread.
    pub total: u64,
    /// Peak concurrently-live bytes (approximate; see [`release`]).
    pub peak: u64,
}

/// Current thread's allocation counters.
pub fn stats() -> AllocStats {
    AllocStats {
        total: TOTAL.with(|t| t.get()),
        peak: PEAK.with(|p| p.get()),
    }
}

/// Reset all counters (benchmark harness calls this per measurement).
pub fn reset() {
    LIVE.with(|l| l.set(0));
    TOTAL.with(|t| t.set(0));
    PEAK.with(|p| p.set(0));
}

/// Run `f` and return `(result, bytes allocated during f)`.
pub fn measure<T>(f: impl FnOnce() -> T) -> (T, u64) {
    let before = stats().total;
    let out = f();
    (out, stats().total - before)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn counts_tensor_allocations() {
        reset();
        let (_t, bytes) = measure(|| Tensor::zeros(&[10, 10]));
        assert_eq!(bytes, 100 * 8);
        let (_t2, bytes2) = measure(|| Tensor::ones(&[3]));
        assert_eq!(bytes2, 24);
    }

    #[test]
    fn peak_tracks_live_maximum() {
        reset();
        {
            let _a = Tensor::zeros(&[1000]);
            let _b = Tensor::zeros(&[1000]);
        }
        assert!(stats().peak >= 16_000);
    }
}
