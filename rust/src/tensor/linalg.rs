//! Dense linear algebra: matmul (plus the transposed variants the autodiff
//! vector-Jacobian products need) and 2-D transpose.
//!
//! The matmul kernel is a cache-friendly `i-k-j` loop over row-major data;
//! the `_tn`/`_nt` variants fuse the transposes the backward pass needs so
//! no explicit transposed copies are materialized on the training hot path.

use super::Tensor;

impl Tensor {
    /// 2-D transpose.
    pub fn transpose(&self) -> Tensor {
        assert_eq!(self.rank(), 2, "transpose expects rank 2");
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(&[c, r]);
        for i in 0..r {
            for j in 0..c {
                out.data[j * r + i] = self.data[i * c + j];
            }
        }
        out
    }

    /// `C = A @ B` for `A:[m,k]`, `B:[k,n]`.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2, "matmul lhs rank");
        assert_eq!(other.rank(), 2, "matmul rhs rank");
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
        let mut out = Tensor::zeros(&[m, n]);
        matmul_into(&self.data, &other.data, &mut out.data, m, k, n);
        out
    }

    /// `C = A^T @ B` for `A:[k,m]`, `B:[k,n]` without materializing `A^T`.
    pub fn matmul_tn(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        let (k, m) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul_tn inner dims {k} vs {k2}");
        let mut out = Tensor::zeros(&[m, n]);
        // out[i, j] += A[p, i] * B[p, j]: accumulate rank-1 updates row by row.
        for p in 0..k {
            let arow = &self.data[p * m..(p + 1) * m];
            let brow = &other.data[p * n..(p + 1) * n];
            for i in 0..m {
                let a = arow[i];
                if a == 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    /// `C = A @ B^T` for `A:[m,k]`, `B:[n,k]` without materializing `B^T`.
    ///
    /// §Perf: both operands are walked row-contiguously (ideal for this
    /// layout), and the dot product uses four independent accumulators so
    /// the compiler can vectorize despite FP-add ordering constraints.
    pub fn matmul_nt(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (n, k2) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul_nt inner dims {k} vs {k2}");
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out.data[i * n..(i + 1) * n];
            for (j, o) in orow.iter_mut().enumerate() {
                let brow = &other.data[j * k..(j + 1) * k];
                *o = dot_unrolled(arow, brow);
            }
        }
        out
    }
}

/// Dot product with four independent accumulators (lets LLVM vectorize
/// the reduction; a single serial accumulator cannot be reordered).
#[inline]
pub fn dot_unrolled(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = 4 * c;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut tail = 0.0;
    for i in 4 * chunks..a.len() {
        tail += a[i] * b[i];
    }
    (acc[0] + acc[2]) + (acc[1] + acc[3]) + tail
}

/// Row-major `i-k-j` matmul into a preallocated (zeroed) buffer.
pub fn matmul_into(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;
    use crate::util::{allclose_slice, ptest};

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[1];
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for p in 0..k {
                    acc += a.at(i, p) * b.at(p, j);
                }
                out.set(i, j, acc);
            }
        }
        out
    }

    #[test]
    fn matmul_known_values() {
        let a = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        let b = Tensor::ones(&[2, 2]);
        assert_eq!(a.matmul(&b).data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Prng::seeded(11);
        let a = Tensor::rand_normal(&[3, 5], 0.0, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().shape(), &[5, 3]);
    }

    #[test]
    fn matmul_matches_naive_random() {
        ptest::quickcheck(
            |rng| {
                let m = 1 + rng.below(6) as usize;
                let k = 1 + rng.below(6) as usize;
                let n = 1 + rng.below(6) as usize;
                let a = Tensor::rand_normal(&[m, k], 0.0, 1.0, rng);
                let b = Tensor::rand_normal(&[k, n], 0.0, 1.0, rng);
                (a, b)
            },
            |(a, b)| {
                let fast = a.matmul(b);
                let slow = naive_matmul(a, b);
                if allclose_slice(fast.data(), slow.data(), 1e-12, 1e-12) {
                    Ok(())
                } else {
                    Err("matmul != naive".into())
                }
            },
        );
    }

    #[test]
    fn fused_transpose_variants_match_explicit() {
        ptest::quickcheck(
            |rng| {
                let m = 1 + rng.below(5) as usize;
                let k = 1 + rng.below(5) as usize;
                let n = 1 + rng.below(5) as usize;
                let a = Tensor::rand_normal(&[k, m], 0.0, 1.0, rng);
                let b = Tensor::rand_normal(&[k, n], 0.0, 1.0, rng);
                let c = Tensor::rand_normal(&[m, k], 0.0, 1.0, rng);
                let d = Tensor::rand_normal(&[n, k], 0.0, 1.0, rng);
                (a, b, c, d)
            },
            |(a, b, c, d)| {
                let tn = a.matmul_tn(b);
                let tn_ref = a.transpose().matmul(b);
                let nt = c.matmul_nt(d);
                let nt_ref = c.matmul(&d.transpose());
                if allclose_slice(tn.data(), tn_ref.data(), 1e-12, 1e-12)
                    && allclose_slice(nt.data(), nt_ref.data(), 1e-12, 1e-12)
                {
                    Ok(())
                } else {
                    Err("fused transpose matmul mismatch".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "inner dims")]
    fn inner_dim_mismatch_panics() {
        Tensor::zeros(&[2, 3]).matmul(&Tensor::zeros(&[4, 2]));
    }
}
