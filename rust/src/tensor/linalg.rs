//! Dense linear algebra: matmul (plus the transposed variants the autodiff
//! vector-Jacobian products need) and 2-D transpose.
//!
//! The matmul kernel is a cache-friendly `i-k-j` loop over row-major data;
//! the `_tn`/`_nt` variants fuse the transposes the backward pass needs so
//! no explicit transposed copies are materialized on the training hot path.

use super::Tensor;
use crate::simd::Isa;

/// Square tile edge of the cache-blocked [`Tensor::transpose`]: a 32×32
/// f64 tile is 8 KB read + 8 KB written, so both the row-major reads and
/// the column-major writes of one tile stay L1-resident.
const TRANSPOSE_TILE: usize = 32;

impl Tensor {
    /// 2-D transpose (cache-blocked: the matrix is walked in 32×32
    /// tiles so the strided writes hit L1 instead of missing on every
    /// element once a row of the output exceeds a page).
    pub fn transpose(&self) -> Tensor {
        assert_eq!(self.rank(), 2, "transpose expects rank 2");
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(&[c, r]);
        for ib in (0..r).step_by(TRANSPOSE_TILE) {
            let ih = (ib + TRANSPOSE_TILE).min(r);
            for jb in (0..c).step_by(TRANSPOSE_TILE) {
                let jh = (jb + TRANSPOSE_TILE).min(c);
                for i in ib..ih {
                    for j in jb..jh {
                        out.data[j * r + i] = self.data[i * c + j];
                    }
                }
            }
        }
        out
    }

    /// `C = A @ B` for `A:[m,k]`, `B:[k,n]`.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2, "matmul lhs rank");
        assert_eq!(other.rank(), 2, "matmul rhs rank");
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
        let mut out = Tensor::zeros(&[m, n]);
        matmul_into(&self.data, &other.data, &mut out.data, m, k, n);
        out
    }

    /// `C = A^T @ B` for `A:[k,m]`, `B:[k,n]` without materializing `A^T`.
    pub fn matmul_tn(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        let (k, m) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul_tn inner dims {k} vs {k2}");
        let mut out = Tensor::zeros(&[m, n]);
        // out[i, j] += A[p, i] * B[p, j]: accumulate rank-1 updates row by row.
        for p in 0..k {
            let arow = &self.data[p * m..(p + 1) * m];
            let brow = &other.data[p * n..(p + 1) * n];
            for i in 0..m {
                let a = arow[i];
                if a == 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    /// `C = A @ B^T` for `A:[m,k]`, `B:[n,k]` without materializing `B^T`.
    ///
    /// §Perf: both operands are walked row-contiguously (ideal for this
    /// layout), and the dot product uses four independent accumulators so
    /// the compiler can vectorize despite FP-add ordering constraints.
    pub fn matmul_nt(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (n, k2) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul_nt inner dims {k} vs {k2}");
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out.data[i * n..(i + 1) * n];
            for (j, o) in orow.iter_mut().enumerate() {
                let brow = &other.data[j * k..(j + 1) * k];
                *o = dot_unrolled(arow, brow);
            }
        }
        out
    }
}

/// Dot product with four independent accumulators (lets LLVM vectorize
/// the reduction; a single serial accumulator cannot be reordered).
#[inline]
pub fn dot_unrolled(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = 4 * c;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut tail = 0.0;
    for i in 4 * chunks..a.len() {
        tail += a[i] * b[i];
    }
    (acc[0] + acc[2]) + (acc[1] + acc[3]) + tail
}

/// Inner-dimension elements per cache block of the blocked NT matmul —
/// panels of `B` this long stay L1/L2-resident while `A` streams through.
const GEMM_KC: usize = 256;
/// Output columns (rows of the NT-form `B`) per cache block.
const GEMM_NC: usize = 64;
/// Output columns per packed `B` panel / microkernel invocation (also
/// the panel width the `simd` microkernel bodies are written against).
pub(crate) const GEMM_NR: usize = 8;

/// Blocked `C = A @ B^T` into a caller-owned buffer, for `A:[m,k]`,
/// `B:[n,k]`, `C:[m,n]`, all row-major — the fused n-TangentProp
/// kernel's stacked-channel GEMM (`m = (n_derivs+1)·B_tile` rows share
/// one weight panel).
///
/// kc/nc cache tiling around a 4×8 register microkernel fed by *packed*
/// `B` panels: the 8 weight rows of one column group are repacked
/// k-major into a stack-resident panel once per (k-block, column group)
/// and then streamed contiguously for **every** row of `A`, so the
/// microkernel's inner step is 12 contiguous loads feeding 32
/// multiply-adds. Scalar cells cover the row/column edges.
///
/// `c` need not be zeroed: the first k-block assigns, later ones
/// accumulate. Determinism contract: every output element's summation
/// order is a pure function of `k` alone — within each `GEMM_KC` block a
/// single accumulator runs in ascending-k order, and block sums are
/// added onto `c` in ascending block order — independent of `m`, of the
/// row/column blocking, of the panel packing, and of whether the
/// interior microkernel or an edge cell computed it. So splitting the
/// rows of `A` across threads reproduces the serial bits exactly. (Note
/// this is *not* bitwise equal to one sequential accumulator over all of
/// `k` once `k > GEMM_KC`, and retuning `GEMM_KC` changes rounding for
/// such shapes.)
pub fn matmul_nt_block_into(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    matmul_nt_block_into_with(Isa::active(), a, b, c, m, k, n);
}

/// [`matmul_nt_block_into`] with an explicit [`Isa`] instead of the
/// process-wide one — the fused engine threads its construction-time ISA
/// through here, and the dispatch tests pit scalar against vector
/// microkernels in one process. The determinism contract above holds
/// *per element and per ISA by construction of the microkernels*: every
/// vector body keeps one ascending-k accumulator chain per output
/// element (vectorizing across the 8 output columns, never across k), so
/// scalar and vector results are bitwise identical.
pub fn matmul_nt_block_into_with(
    isa: Isa,
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    if k == 0 {
        c.fill(0.0);
        return;
    }
    // Packed B panel for one column group: GEMM_NR columns × GEMM_KC
    // k-steps, k-major (16 KB — stack-resident, no heap traffic).
    let mut panel = [0.0f64; GEMM_NR * GEMM_KC];
    for kb in (0..k).step_by(GEMM_KC) {
        let kl = GEMM_KC.min(k - kb);
        let first = kb == 0;
        for nb in (0..n).step_by(GEMM_NC) {
            let nl = GEMM_NC.min(n - nb);
            let mut j = 0;
            while j + GEMM_NR <= nl {
                let jj = nb + j;
                // Pack the group's B rows k-major: panel[p*8 + q] =
                // B[jj+q][kb+p]. Packed once, reused for all m rows.
                for (p, slot) in panel.chunks_exact_mut(GEMM_NR).take(kl).enumerate() {
                    for (q, o) in slot.iter_mut().enumerate() {
                        *o = b[(jj + q) * k + kb + p];
                    }
                }
                let mut i = 0;
                while i + 4 <= m {
                    let ar = [
                        &a[i * k + kb..i * k + kb + kl],
                        &a[(i + 1) * k + kb..(i + 1) * k + kb + kl],
                        &a[(i + 2) * k + kb..(i + 2) * k + kb + kl],
                        &a[(i + 3) * k + kb..(i + 3) * k + kb + kl],
                    ];
                    isa.gemm_micro_4x8(ar, &panel[..GEMM_NR * kl], &mut c[i * n + jj..], n, first);
                    i += 4;
                }
                while i < m {
                    let arow = &a[i * k + kb..i * k + kb + kl];
                    for q in 0..GEMM_NR {
                        nt_cell(
                            arow,
                            &b[(jj + q) * k + kb..(jj + q) * k + kb + kl],
                            &mut c[i * n + jj + q],
                            first,
                        );
                    }
                    i += 1;
                }
                j += GEMM_NR;
            }
            // Column edge (< GEMM_NR remaining): scalar cells, same
            // ascending-k single-accumulator order as the microkernel.
            while j < nl {
                let jj = nb + j;
                for i in 0..m {
                    nt_cell(
                        &a[i * k + kb..i * k + kb + kl],
                        &b[jj * k + kb..jj * k + kb + kl],
                        &mut c[i * n + jj],
                        first,
                    );
                }
                j += 1;
            }
        }
    }
}

/// Scalar edge cell of [`matmul_nt_block_into`]: the same ascending-k,
/// single-accumulator order as the microkernel, so edge elements are
/// bitwise identical no matter which kernel shape covered them.
#[inline]
fn nt_cell(arow: &[f64], brow: &[f64], out: &mut f64, first: bool) {
    let mut acc = 0.0;
    for (&x, &y) in arow.iter().zip(brow) {
        acc += x * y;
    }
    if first {
        *out = acc;
    } else {
        *out += acc;
    }
}

/// Row-major `i-k-j` matmul into a preallocated (zeroed) buffer.
pub fn matmul_into(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;
    use crate::util::{allclose_slice, ptest};

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[1];
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for p in 0..k {
                    acc += a.at(i, p) * b.at(p, j);
                }
                out.set(i, j, acc);
            }
        }
        out
    }

    #[test]
    fn matmul_known_values() {
        let a = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        let b = Tensor::ones(&[2, 2]);
        assert_eq!(a.matmul(&b).data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Prng::seeded(11);
        let a = Tensor::rand_normal(&[3, 5], 0.0, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().shape(), &[5, 3]);
    }

    #[test]
    fn matmul_matches_naive_random() {
        ptest::quickcheck(
            |rng| {
                let m = 1 + rng.below(6) as usize;
                let k = 1 + rng.below(6) as usize;
                let n = 1 + rng.below(6) as usize;
                let a = Tensor::rand_normal(&[m, k], 0.0, 1.0, rng);
                let b = Tensor::rand_normal(&[k, n], 0.0, 1.0, rng);
                (a, b)
            },
            |(a, b)| {
                let fast = a.matmul(b);
                let slow = naive_matmul(a, b);
                if allclose_slice(fast.data(), slow.data(), 1e-12, 1e-12) {
                    Ok(())
                } else {
                    Err("matmul != naive".into())
                }
            },
        );
    }

    #[test]
    fn fused_transpose_variants_match_explicit() {
        ptest::quickcheck(
            |rng| {
                let m = 1 + rng.below(5) as usize;
                let k = 1 + rng.below(5) as usize;
                let n = 1 + rng.below(5) as usize;
                let a = Tensor::rand_normal(&[k, m], 0.0, 1.0, rng);
                let b = Tensor::rand_normal(&[k, n], 0.0, 1.0, rng);
                let c = Tensor::rand_normal(&[m, k], 0.0, 1.0, rng);
                let d = Tensor::rand_normal(&[n, k], 0.0, 1.0, rng);
                (a, b, c, d)
            },
            |(a, b, c, d)| {
                let tn = a.matmul_tn(b);
                let tn_ref = a.transpose().matmul(b);
                let nt = c.matmul_nt(d);
                let nt_ref = c.matmul(&d.transpose());
                if allclose_slice(tn.data(), tn_ref.data(), 1e-12, 1e-12)
                    && allclose_slice(nt.data(), nt_ref.data(), 1e-12, 1e-12)
                {
                    Ok(())
                } else {
                    Err("fused transpose matmul mismatch".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "inner dims")]
    fn inner_dim_mismatch_panics() {
        Tensor::zeros(&[2, 3]).matmul(&Tensor::zeros(&[4, 2]));
    }

    /// The blocked NT kernel matches the reference matmul across shapes
    /// that exercise both the 4×4 microkernel and every edge path,
    /// including k past the cache-block boundary.
    #[test]
    fn blocked_nt_matmul_matches_reference() {
        ptest::check(
            ptest::Config { cases: 24, seed: 0xB10C },
            |rng: &mut Prng| {
                let m = 1 + rng.below(19) as usize;
                let k = 1 + rng.below(300) as usize; // crosses GEMM_KC = 256
                let n = 1 + rng.below(70) as usize; // crosses GEMM_NC = 64
                let a = Tensor::rand_normal(&[m, k], 0.0, 1.0, rng);
                let b = Tensor::rand_normal(&[n, k], 0.0, 1.0, rng);
                (a, b)
            },
            |(a, b)| {
                let (m, k) = (a.shape()[0], a.shape()[1]);
                let n = b.shape()[0];
                // Poisoned output: the kernel must overwrite, not accumulate.
                let mut c = vec![f64::NAN; m * n];
                matmul_nt_block_into(a.data(), b.data(), &mut c, m, k, n);
                let want = a.matmul(&b.transpose());
                if allclose_slice(&c, want.data(), 1e-11, 1e-11) {
                    Ok(())
                } else {
                    Err("blocked NT matmul != reference".into())
                }
            },
        );
    }

    /// For `k ≤ GEMM_KC` the determinism contract pins every output
    /// element to one ascending-k accumulator — exactly a sequential dot
    /// product, bit for bit. Shapes cross the 8-column packed-panel
    /// boundary and the 4-row microkernel edge, so packed, microkernel
    /// and scalar-edge paths all face the same oracle.
    #[test]
    fn blocked_nt_matmul_single_kblock_matches_sequential_accumulator_bitwise() {
        let mut rng = Prng::seeded(0x48);
        for (m, k, n) in [(1usize, 7usize, 1usize), (5, 64, 9), (12, 200, 19), (4, 256, 8)] {
            let a = Tensor::rand_normal(&[m, k], 0.0, 1.0, &mut rng);
            let b = Tensor::rand_normal(&[n, k], 0.0, 1.0, &mut rng);
            let mut c = vec![f64::NAN; m * n];
            matmul_nt_block_into(a.data(), b.data(), &mut c, m, k, n);
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0.0;
                    for p in 0..k {
                        acc += a.data()[i * k + p] * b.data()[j * k + p];
                    }
                    let got = c[i * n + j];
                    assert_eq!(got.to_bits(), acc.to_bits(), "m={m} k={k} n={n} ({i},{j})");
                }
            }
        }
    }

    /// Row-chunk invariance — the determinism contract the fused kernel's
    /// parallel path relies on: computing any horizontal slice of `A`
    /// separately yields bitwise the same rows of `C`.
    #[test]
    fn blocked_nt_matmul_is_row_chunk_invariant_bitwise() {
        let mut rng = Prng::seeded(0xC0C);
        let (m, k, n) = (23usize, 64usize, 17usize);
        let a = Tensor::rand_normal(&[m, k], 0.0, 1.0, &mut rng);
        let b = Tensor::rand_normal(&[n, k], 0.0, 1.0, &mut rng);
        let mut full = vec![0.0; m * n];
        matmul_nt_block_into(a.data(), b.data(), &mut full, m, k, n);
        for split in [1usize, 4, 5, 22] {
            let mut lo = vec![0.0; split * n];
            let mut hi = vec![0.0; (m - split) * n];
            matmul_nt_block_into(&a.data()[..split * k], b.data(), &mut lo, split, k, n);
            matmul_nt_block_into(&a.data()[split * k..], b.data(), &mut hi, m - split, k, n);
            let stitched: Vec<f64> = lo.iter().chain(&hi).copied().collect();
            for (i, (x, y)) in full.iter().zip(&stitched).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "split={split} elem {i}");
            }
        }
    }

    /// Blocked transpose edge shapes: tile-boundary and sub-tile sizes.
    #[test]
    fn blocked_transpose_matches_naive_shapes() {
        let mut rng = Prng::seeded(0x7A);
        for (r, c) in [(1usize, 1usize), (3, 70), (32, 32), (33, 31), (64, 65), (100, 7)] {
            let a = Tensor::rand_normal(&[r, c], 0.0, 1.0, &mut rng);
            let t = a.transpose();
            assert_eq!(t.shape(), &[c, r]);
            for i in 0..r {
                for j in 0..c {
                    assert_eq!(t.at(j, i), a.at(i, j), "({i},{j})");
                }
            }
        }
    }
}
