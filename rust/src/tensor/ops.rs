//! Elementwise, broadcast and reduction operations.

use super::Tensor;

impl Tensor {
    fn zip(&self, other: &Tensor, f: impl Fn(f64, f64) -> f64) -> Tensor {
        assert_eq!(
            self.shape, other.shape,
            "elementwise op on mismatched shapes {:?} vs {:?}",
            self.shape, other.shape
        );
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| f(*a, *b))
            .collect();
        Tensor::from_vec(data, &self.shape)
    }

    /// Apply `f` to every element.
    pub fn map(&self, f: impl Fn(f64) -> f64) -> Tensor {
        Tensor::from_vec(self.data.iter().map(|x| f(*x)).collect(), &self.shape)
    }

    /// In-place map (no allocation) — hot-path helper.
    pub fn map_inplace(&mut self, f: impl Fn(f64) -> f64) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    /// Elementwise sum.
    pub fn add(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a + b)
    }

    /// Elementwise difference.
    pub fn sub(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a - b)
    }

    /// Elementwise product.
    pub fn mul(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a * b)
    }

    /// Elementwise quotient.
    pub fn div(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a / b)
    }

    /// Fused `self + alpha * other` (hot path: optimizer updates, combines).
    pub fn axpy(&self, alpha: f64, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a + alpha * b)
    }

    /// In-place `self += alpha * other`.
    pub fn axpy_inplace(&mut self, alpha: f64, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Elementwise negation.
    pub fn neg(&self) -> Tensor {
        self.map(|x| -x)
    }

    /// Elementwise `alpha · x`.
    pub fn scale(&self, alpha: f64) -> Tensor {
        self.map(|x| alpha * x)
    }

    /// Elementwise `x + c`.
    pub fn add_scalar(&self, c: f64) -> Tensor {
        self.map(|x| x + c)
    }

    /// Elementwise hyperbolic tangent.
    pub fn tanh(&self) -> Tensor {
        self.map(f64::tanh)
    }

    /// Integer power (exponentiation by squaring per element).
    pub fn powi(&self, k: i32) -> Tensor {
        self.map(|x| x.powi(k))
    }

    // ----------------------------------------------------------- broadcast

    /// Add a `[F]` bias row to every row of a `[B, F]` tensor.
    pub fn add_bias(&self, bias: &Tensor) -> Tensor {
        let mut out = self.clone();
        out.add_bias_inplace(bias);
        out
    }

    /// In-place `[B, F] += bias[F]` row broadcast — the affine layers'
    /// hot path (no clone, same per-element arithmetic as
    /// [`Tensor::add_bias`]).
    pub fn add_bias_inplace(&mut self, bias: &Tensor) {
        assert_eq!(self.rank(), 2, "add_bias expects rank-2 lhs");
        assert_eq!(bias.rank(), 1, "add_bias expects rank-1 bias");
        let f = self.shape[1];
        assert_eq!(bias.shape[0], f, "bias width mismatch");
        if f == 0 {
            return;
        }
        for row in self.data.chunks_exact_mut(f) {
            for (o, &b) in row.iter_mut().zip(&bias.data) {
                *o += b;
            }
        }
    }

    /// Replicate a `[F]` row into `[B, F]`.
    pub fn broadcast_rows(&self, b: usize) -> Tensor {
        assert_eq!(self.rank(), 1, "broadcast_rows expects rank-1 input");
        let f = self.shape[0];
        let mut data = Vec::with_capacity(b * f);
        for _ in 0..b {
            data.extend_from_slice(&self.data);
        }
        Tensor::from_vec(data, &[b, f])
    }

    /// Fill a tensor of `shape` with the single element of `self`.
    pub fn broadcast_scalar(&self, shape: &[usize]) -> Tensor {
        Tensor::full(shape, self.item())
    }

    // ---------------------------------------------------------- reductions

    /// Sum of all elements, as a `[1]` tensor.
    pub fn sum_all(&self) -> Tensor {
        Tensor::scalar(self.data.iter().sum())
    }

    /// Column sums of a `[B, F]` tensor → `[F]`.
    pub fn sum_axis0(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (b, f) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0; f];
        for i in 0..b {
            for j in 0..f {
                out[j] += self.data[i * f + j];
            }
        }
        Tensor::from_vec(out, &[f])
    }

    /// Mean of all elements (scalar f64).
    pub fn mean(&self) -> f64 {
        self.data.iter().sum::<f64>() / self.numel() as f64
    }

    /// Dot product of two same-shaped tensors (flattened).
    pub fn dot(&self, other: &Tensor) -> f64 {
        assert_eq!(self.numel(), other.numel(), "dot: length mismatch");
        self.data.iter().zip(&other.data).map(|(a, b)| a * b).sum()
    }

    /// Euclidean norm.
    pub fn norm(&self) -> f64 {
        self.dot(self).sqrt()
    }

    /// Largest absolute element.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, x| m.max(x.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2(data: &[f64]) -> Tensor {
        Tensor::from_vec(data.to_vec(), &[2, 2])
    }

    #[test]
    fn elementwise_basics() {
        let a = t2(&[1.0, 2.0, 3.0, 4.0]);
        let b = t2(&[5.0, 6.0, 7.0, 8.0]);
        assert_eq!(a.add(&b).data(), &[6.0, 8.0, 10.0, 12.0]);
        assert_eq!(b.sub(&a).data(), &[4.0, 4.0, 4.0, 4.0]);
        assert_eq!(a.mul(&b).data(), &[5.0, 12.0, 21.0, 32.0]);
        assert_eq!(b.div(&a).data(), &[5.0, 3.0, 7.0 / 3.0, 2.0]);
        assert_eq!(a.neg().data(), &[-1.0, -2.0, -3.0, -4.0]);
        assert_eq!(a.scale(2.0).data(), &[2.0, 4.0, 6.0, 8.0]);
        assert_eq!(a.axpy(2.0, &b).data(), &[11.0, 14.0, 17.0, 20.0]);
    }

    #[test]
    #[should_panic(expected = "mismatched shapes")]
    fn mismatched_shapes_panic() {
        let a = Tensor::zeros(&[2, 2]);
        let b = Tensor::zeros(&[2, 3]);
        a.add(&b);
    }

    #[test]
    fn bias_and_broadcast() {
        let x = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let bias = Tensor::from_vec(vec![10.0, 20.0, 30.0], &[3]);
        assert_eq!(x.add_bias(&bias).data(), &[11.0, 22.0, 33.0, 14.0, 25.0, 36.0]);
        let mut y = x.clone();
        y.add_bias_inplace(&bias);
        assert_eq!(y, x.add_bias(&bias));
        let r = bias.broadcast_rows(2);
        assert_eq!(r.shape(), &[2, 3]);
        assert_eq!(r.data(), &[10.0, 20.0, 30.0, 10.0, 20.0, 30.0]);
        let s = Tensor::scalar(7.0).broadcast_scalar(&[2, 2]);
        assert_eq!(s.data(), &[7.0; 4]);
    }

    #[test]
    fn reductions() {
        let x = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        assert_eq!(x.sum_all().item(), 21.0);
        assert_eq!(x.sum_axis0().data(), &[5.0, 7.0, 9.0]);
        assert_eq!(x.mean(), 3.5);
        assert_eq!(x.dot(&x), 91.0);
        assert_eq!(x.max_abs(), 6.0);
        assert!((x.norm() - 91.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn powi_matches_repeated_mul() {
        let x = Tensor::from_vec(vec![2.0, -3.0], &[2]);
        assert_eq!(x.powi(3).data(), &[8.0, -27.0]);
        assert_eq!(x.powi(0).data(), &[1.0, 1.0]);
    }

    #[test]
    fn axpy_inplace_matches_axpy() {
        let mut a = t2(&[1.0, 2.0, 3.0, 4.0]);
        let b = t2(&[1.0, 1.0, 1.0, 1.0]);
        let expect = a.axpy(0.5, &b);
        a.axpy_inplace(0.5, &b);
        assert_eq!(a, expect);
    }
}
