//! # `ntangent` — n-TangentProp for deep feed-forward networks
//!
//! A reproduction of *"A Quasilinear Algorithm for Computing Higher-Order
//! Derivatives of Deep Feed-Forward Neural Networks"* (Chickering, 2024).
//!
//! The library computes the exact input-derivatives `d^n/dx^n f(x)` of a
//! densely-connected feed-forward network `f` with a smooth activation in
//! quasilinear `O(e^√n · M)` time by propagating derivative *channels*
//! through the network with Faà di Bruno's formula (n-TangentProp), instead
//! of the exponential `O(M^n)` cost of repeatedly applying reverse-mode
//! autodifferentiation.
//!
//! ## Crate layout
//!
//! - [`tensor`] — a small dense `f64` tensor engine (the compute substrate).
//! - [`autodiff`] — a tape-based reverse-mode engine with *create-graph*
//!   double-backward; repeated application of it is the paper's baseline.
//!   The activation is a generic tape op tagged with an
//!   [`ntp::ActivationKind`], so the baseline re-differentiates every
//!   registered activation exactly.
//! - [`ntp`] — the paper's contribution: integer partitions, Faà di Bruno /
//!   Bell coefficient tables compiled to flat kernel programs
//!   ([`ntp::FdbProgram`]), pluggable activation derivative towers
//!   (tanh, sine, softplus, GELU — each exact), and the n-TangentProp
//!   forward pass (both a pure fast path and a tape-recorded path that
//!   supports backprop-through-derivatives for training). The fast path
//!   is a fused element-tiled kernel — interleaved channel tiles plus a
//!   stacked-channel GEMM, with its hot loops running on the
//!   runtime-dispatched [`simd`] kernels — and the pre-fusion pass is
//!   retained as `forward_reference` behind the `reference-oracle` cargo
//!   feature (see `docs/ARCHITECTURE.md`). The engine is
//!   `Send + Sync` and carries a [`ntp::ParallelPolicy`]
//!   (serial / fixed-threads / auto): the batch axis is embarrassingly
//!   parallel, so `forward_n` chunks rows across scoped threads with
//!   bitwise-identical output (see `rust/tests/parallel_determinism.rs`).
//!   The same kernel serves multi-dimensional inputs through
//!   **directional jets** (`forward_directional`): [`ntp::multi`]
//!   compiles exact integer direction sets with rational recombination
//!   matrices so arbitrary mixed partials `∂^α u` assemble from one
//!   direction-stacked fused batch ([`ntp::MultiJetEngine`]).
//! - [`pde`] — differential-operator descriptions (linear terms plus the
//!   `u·∂u` nonlinear-term hook, a text spec parser) and a library of
//!   2-D scenarios (heat, Poisson, wave, KdV, biharmonic) with
//!   manufactured exact solutions. `ntangent bench operators` measures
//!   the directional-jet path against the nested-tape baseline.
//! - [`nn`] — dense MLPs (each carrying its [`ntp::ActivationKind`]) and
//!   parameter (un)flattening.
//! - [`opt`] — Adam, SGD and L-BFGS with a strong-Wolfe line search. All
//!   three accept a [`ntp::ParallelPolicy`]; their updates/reductions are
//!   bitwise thread-count-invariant (see [`util::par`]).
//! - [`pinn`] — a physics-informed-network training framework (collocation
//!   sampling, Sobolev losses, Leibniz residual derivatives, boundary
//!   conditions, inverse parameters) plus the paper's self-similar Burgers
//!   benchmark problem with a ground-truth solver. Training is
//!   data-parallel on demand: [`pinn::ParallelObjective`] shards the
//!   collocation cloud into fixed row-chunks (one tape each) and combines
//!   per-shard gradients with a deterministic pairwise tree reduction, so
//!   `ntangent train --threads N` is bitwise reproducible for any `N`
//!   (`rust/tests/training_determinism.rs`; `ntangent bench train-par`
//!   writes `results/training_speedup.csv`).
//! - [`runtime`] — a PJRT runtime that loads AOT-compiled HLO artifacts
//!   produced by the build-time JAX/Pallas layers and executes them from
//!   Rust (Python is never on the hot path).
//! - [`coordinator`] — a batching derivative-evaluation service on top of
//!   the runtime: a pool of batcher workers behind per-activation request
//!   sharding (`Service::start_pool`), dynamic batching per shard, TCP
//!   JSON-lines protocol, and global + per-worker metrics. Reproduce the
//!   speedups with `cargo bench --bench ntp_kernels` (serial vs parallel
//!   forward), `cargo bench --bench coordinator` (1/2/4-worker pool), or
//!   `ntangent bench par` (writes `parallel_speedup.csv`).
//! - [`simd`] — runtime-dispatched vector kernels (AVX2 / NEON with an
//!   always-compiled scalar fallback, `NTANGENT_SIMD` override) behind a
//!   bitwise scalar≡vector contract; every hot loop above dispatches
//!   through it.
//! - [`obs`] — crate-wide observability: hierarchical tracing spans, a
//!   unified metrics registry (counters / gauges / log-scale latency
//!   histograms), sampled kernel-phase profiling hooks, and Prometheus /
//!   JSON export. Off by default; `NTANGENT_TRACE=1` (or `serve --obs`,
//!   `train --telemetry`, `ntangent trace`) enables it, and
//!   instrumented runs stay **bitwise identical** to uninstrumented ones
//!   (`rust/tests/obs_overhead.rs`).
//! - [`bench`] — the harness that regenerates every figure of the paper.
//! - [`util`] — substrates built from scratch for offline use: PRNG, JSON,
//!   CLI parsing, stats, timers and a mini property-testing helper.
//!
//! ## Quickstart
//!
//! ```
//! use ntangent::nn::Mlp;
//! use ntangent::ntp::{ActivationKind, NtpEngine};
//! use ntangent::tensor::Tensor;
//! use ntangent::util::prng::Prng;
//!
//! let mut rng = Prng::seeded(7);
//! let mlp = Mlp::new(&[1, 24, 24, 24, 1], &mut rng); // tanh by default
//! let x = Tensor::linspace(-1.0, 1.0, 8).reshape(&[8, 1]);
//! let engine = NtpEngine::new(4); // up to 4 derivatives, any activation
//! let channels = engine.forward(&mlp, &x); // [u, u', u'', u''', u'''']
//! assert_eq!(channels.len(), 5);
//!
//! // The activation is a runtime-selectable axis: the same engine serves
//! // e.g. a sine-activated (SIREN-style) network.
//! let siren = Mlp::with_activation(&[1, 24, 24, 1], ActivationKind::Sine, &mut rng);
//! let sine_channels = engine.forward(&siren, &x);
//! assert_eq!(sine_channels.len(), 5);
//! ```
//!
//! A top-to-bottom architecture map (layers, the two parallelism models
//! and their determinism guarantees) lives in `docs/ARCHITECTURE.md`; the
//! coordinator's wire protocol is specified in `docs/PROTOCOL.md`.

#![warn(missing_docs)]

pub mod autodiff;
pub mod bench;
pub mod coordinator;
pub mod nn;
pub mod ntp;
pub mod obs;
pub mod opt;
pub mod pde;
pub mod pinn;
pub mod runtime;
pub mod simd;
pub mod tensor;
pub mod util;
